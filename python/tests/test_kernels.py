"""L1 kernel correctness: every Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes and block sizes — including blocks that don't
divide the problem (remainder tiles) — which is exactly the regime the
FTL schedules run in.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused, gelu as gelu_k, gemm as gemm_k, ref

jax.config.update("jax_platform_name", "cpu")


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


dims = st.integers(min_value=1, max_value=96)
blocks = st.sampled_from([8, 16, 32, 128])


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, bm=blocks, bn=blocks, seed=st.integers(0, 2**31 - 1))
def test_gemm_matches_ref(m, k, n, bm, bn, seed):
    rng = np.random.default_rng(seed)
    a, b = rand(rng, m, k), rand(rng, k, n)
    got = gemm_k.gemm(a, b, bm=bm, bn=bn)
    want = ref.gemm(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, bm=blocks, bn=blocks, seed=st.integers(0, 2**31 - 1))
def test_gemm_bias_matches_ref(m, k, n, bm, bn, seed):
    rng = np.random.default_rng(seed)
    a, b, bias = rand(rng, m, k), rand(rng, k, n), rand(rng, n)
    got = gemm_k.gemm(a, b, bias, bm=bm, bn=bn)
    want = ref.gemm(a, b, bias)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(m=dims, n=dims, bm=blocks, bn=blocks, seed=st.integers(0, 2**31 - 1))
def test_gelu_matches_ref(m, n, bm, bn, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, m, n)
    got = gelu_k.gelu(x, bm=bm, bn=bn)
    np.testing.assert_allclose(got, ref.gelu(x), rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, bm=blocks, bn=blocks, seed=st.integers(0, 2**31 - 1))
def test_fused_gemm_gelu_matches_ref(m, k, n, bm, bn, seed):
    rng = np.random.default_rng(seed)
    a, b, bias = rand(rng, m, k), rand(rng, k, n), rand(rng, n)
    got = fused.gemm_gelu(a, b, bias, bm=bm, bn=bn)
    want = ref.gemm_gelu(a, b, bias)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(m=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_relu_and_add(m, n, seed):
    rng = np.random.default_rng(seed)
    x, y = rand(rng, m, n), rand(rng, m, n)
    np.testing.assert_allclose(gelu_k.relu(x), ref.relu(x), rtol=1e-6)
    np.testing.assert_allclose(gelu_k.add(x, y), ref.add(x, y), rtol=1e-6)


def test_gelu_known_values():
    x = jnp.asarray([[0.0, 1.0, -1.0, 10.0, -10.0]], dtype=jnp.float32)
    got = np.asarray(gelu_k.gelu(x))
    assert abs(got[0, 0]) < 1e-7
    assert abs(got[0, 1] - 0.841192) < 1e-4  # tanh-approx value
    assert abs(got[0, 3] - 10.0) < 1e-3
    assert abs(got[0, 4]) < 1e-3


def test_fused_equals_two_step_pipeline():
    """The FTL invariant at kernel level: fusing must not change numerics."""
    rng = np.random.default_rng(0)
    a, b, bias = rand(rng, 64, 48), rand(rng, 48, 80), rand(rng, 80)
    two_step = gelu_k.gelu(gemm_k.gemm(a, b, bias, bm=16, bn=16), bm=16, bn=16)
    one_step = fused.gemm_gelu(a, b, bias, bm=16, bn=16)
    np.testing.assert_allclose(one_step, two_step, rtol=1e-5, atol=1e-5)


def test_paper_stage_shape():
    """The paper's exact workload (197x768->3072) at a realistic block."""
    rng = np.random.default_rng(7)
    a = rand(rng, 197, 768)
    b = rand(rng, 768, 3072)
    bias = rand(rng, 3072)
    got = fused.gemm_gelu(a, b, bias, bm=128, bn=512)
    want = ref.gemm_gelu(a, b, bias)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("bm,bn", [(8, 8), (32, 16), (128, 128)])
def test_vmem_and_mxu_estimators(bm, bn):
    v = gemm_k.vmem_bytes(197, 768, 3072, bm, bn)
    assert v > 0
    u = gemm_k.mxu_utilization(197, 768, 3072, bm, bn)
    assert 0.0 < u <= 1.0
    # full-MXU blocks hit utilisation 1.0
    assert gemm_k.mxu_utilization(256, 768, 256, 128, 128) == 1.0


def test_hbm_traffic_model_fused_smaller():
    base = fused.hbm_traffic_bytes(197, 768, 3072, 128, 128, fused=False)
    ftl = fused.hbm_traffic_bytes(197, 768, 3072, 128, 128, fused=True)
    assert ftl < base
    # the delta is exactly the intermediate round trip
    assert base - ftl == 2 * 197 * 3072 * 4
