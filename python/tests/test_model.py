"""L2 model correctness: baseline and FTL variants vs the jnp oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


dims = st.integers(min_value=4, max_value=64)


@settings(max_examples=10, deadline=None)
@given(s=dims, d=dims, h=dims, seed=st.integers(0, 2**31 - 1))
def test_stage_variants_match_oracle(s, d, h, seed):
    rng = np.random.default_rng(seed)
    x, w1, b1 = rand(rng, s, d), rand(rng, d, h), rand(rng, h)
    want = model.mlp_stage_ref(x, w1, b1)
    base = model.mlp_stage_baseline(x, w1, b1, bm=16, bn=16)
    ftl = model.mlp_stage_ftl(x, w1, b1, bm=16, bn=16)
    np.testing.assert_allclose(base, want, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(ftl, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(s=dims, d=dims, h=dims, seed=st.integers(0, 2**31 - 1))
def test_full_mlp_variants_match_oracle(s, d, h, seed):
    rng = np.random.default_rng(seed)
    x, w1, b1 = rand(rng, s, d), rand(rng, d, h), rand(rng, h)
    w2, b2 = rand(rng, h, d), rand(rng, d)
    want = model.mlp_ref(x, w1, b1, w2, b2)
    base = model.mlp_baseline(x, w1, b1, w2, b2, bm=16, bn=16)
    ftl = model.mlp_ftl(x, w1, b1, w2, b2, bm=16, bn=16)
    np.testing.assert_allclose(base, want, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(ftl, want, rtol=2e-4, atol=2e-4)


def test_baseline_and_ftl_bitwise_close():
    """Fusion must not change the result beyond float reassociation."""
    rng = np.random.default_rng(3)
    x, w1, b1 = rand(rng, 32, 24), rand(rng, 24, 40), rand(rng, 40)
    base = np.asarray(model.mlp_stage_baseline(x, w1, b1, bm=8, bn=8))
    ftl = np.asarray(model.mlp_stage_ftl(x, w1, b1, bm=8, bn=8))
    np.testing.assert_allclose(base, ftl, rtol=1e-5, atol=1e-6)
