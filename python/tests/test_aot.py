"""AOT path: lowering to HLO text, manifest generation, shape metadata."""

import json
import sys

import numpy as np
import pytest

from compile import aot


def test_to_hlo_text_contains_entry():
    lowered = aot.lower_entry("gemm", [[4, 8], [8, 6]])
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[4,8]" in text
    # return_tuple=True → tuple root
    assert "(f32[4,6]" in text


@pytest.mark.parametrize(
    "kind,in_shapes,out_shape",
    [
        ("gemm", [[4, 8], [8, 6]], [4, 6]),
        ("gemm", [[4, 8], [8, 6], [6]], [4, 6]),
        ("gelu", [[5, 7]], [5, 7]),
        ("relu", [[5, 7]], [5, 7]),
        ("add", [[3, 9], [3, 9]], [3, 9]),
        ("gemm_gelu", [[4, 8], [8, 6], [6]], [4, 6]),
    ],
)
def test_lower_entry_shapes(kind, in_shapes, out_shape):
    lowered = aot.lower_entry(kind, in_shapes)
    assert aot.out_shape_of(lowered) == out_shape


def test_lower_entry_unknown_kind():
    with pytest.raises(ValueError):
        aot.lower_entry("warp", [[2, 2]])


def test_main_writes_manifest(tmp_path, monkeypatch):
    tiles = {
        "workload": {"seq": 8, "dim": 12, "hidden": 16},
        "entries": [
            {"name": "gemm_b_m8_k12_n16", "kind": "gemm",
             "in_shapes": [[8, 12], [12, 16], [16]], "out_shape": [8, 16]},
            {"name": "gelu_8x16", "kind": "gelu",
             "in_shapes": [[8, 16]], "out_shape": [8, 16]},
        ],
    }
    tiles_path = tmp_path / "tiles.json"
    tiles_path.write_text(json.dumps(tiles))
    out = tmp_path / "artifacts"
    monkeypatch.setattr(sys, "argv", ["aot", "--out", str(out), "--tiles", str(tiles_path)])
    aot.main()
    manifest = json.loads((out / "manifest.json").read_text())
    names = {e["name"] for e in manifest["entries"]}
    # tile executables
    assert "gemm_b_m8_k12_n16" in names
    assert "gelu_8x16" in names
    # auto-added fused variant for the biased GEMM
    assert "gemm_gelu_b_m8_k12_n16" in names
    # whole-stage models at the tiles.json workload size
    assert "stage_ref_8x12x16" in names
    assert "stage_baseline_8x12x16" in names
    assert "stage_ftl_8x12x16" in names
    # every entry's file exists and parses as HLO text
    for e in manifest["entries"]:
        text = (out / e["file"]).read_text()
        assert "ENTRY" in text


def test_roundtrip_numerics_through_xla_client(tmp_path):
    """Compile the lowered HLO with the *python* xla_client and compare to
    the oracle — the same numerics the Rust PJRT client will see."""
    import jax

    lowered = aot.lower_entry("gemm_gelu", [[6, 10], [10, 8], [8]])
    compiled = jax.jit(
        lambda a, b, bias: lowered  # placeholder; recompile directly below
    )
    del compiled
    rng = np.random.default_rng(11)
    a = rng.standard_normal((6, 10), dtype=np.float32)
    b = rng.standard_normal((10, 8), dtype=np.float32)
    bias = rng.standard_normal(8, dtype=np.float32)
    # Execute the lowered computation via jax's own AOT path.
    out = lowered.compile()(a, b, bias)[0]
    from compile.kernels import ref

    want = ref.gemm_gelu(a, b, bias)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)
