"""K-blocked GEMM variant vs oracle, including remainder K blocks."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import gemm as gemm_k, gemm_kblocked, ref


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


dims = st.integers(min_value=1, max_value=80)
blocks = st.sampled_from([8, 16, 32, 128])


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, bm=blocks, bn=blocks, bk=blocks, seed=st.integers(0, 2**31 - 1))
def test_kblocked_matches_ref(m, k, n, bm, bn, bk, seed):
    rng = np.random.default_rng(seed)
    a, b = rand(rng, m, k), rand(rng, k, n)
    got = gemm_kblocked.gemm_kblocked(a, b, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, ref.gemm(a, b), rtol=1e-4, atol=1e-5)


def test_kblocked_equals_kwhole():
    rng = np.random.default_rng(1)
    a, b = rand(rng, 64, 96), rand(rng, 96, 48)
    whole = gemm_k.gemm(a, b, bm=16, bn=16)
    blocked = gemm_kblocked.gemm_kblocked(a, b, bm=16, bn=16, bk=32)
    np.testing.assert_allclose(blocked, whole, rtol=1e-5, atol=1e-5)


def test_vmem_tradeoff():
    """The point of the variant: for large K it needs far less VMEM per
    step than the K-whole schedule."""
    k = 3072
    whole = gemm_k.vmem_bytes(197, k, 3072, 128, 128)
    blocked = gemm_kblocked.vmem_bytes(128, 128, 128)
    assert blocked < whole / 5
