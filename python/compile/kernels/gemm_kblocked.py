"""L1 Pallas kernel: K-blocked GEMM — the §Perf alternative schedule.

The default :mod:`.gemm` keeps K whole per block (FTL kernel policy). For
very large K the ``(bm, K)`` and ``(K, bn)`` stripes dominate VMEM; this
variant adds a third grid dimension over K and accumulates into the
output block across grid steps (``@pl.when(k == 0)`` zero-init), trading
VMEM footprint for output-block revisits:

    VMEM/step:  (bm·bk + bk·bn + bm·bn) · 4 B   vs  (bm·K + K·bn + bm·bn) · 4 B
    HBM traffic: out block written grid_k times vs once

Used by the §Perf block-size study in EXPERIMENTS.md; the deployment
default stays K-whole (the paper's int8 requantisation policy needs the
full accumulation before requant anyway).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def _divisor_at_most(k, bk):
    """Largest divisor of ``k`` that is ≤ ``bk``.

    The reduction dimension must be covered by *full* blocks: a remainder
    K block would accumulate the block-padding region (undefined values)
    into valid outputs. M/N remainders are safe (the padded output region
    is simply masked on store), so only K is restricted.
    """
    bk = min(bk, k)
    while k % bk != 0:
        bk -= 1
    return bk


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gemm_kblocked(a, b, *, bm=128, bn=128, bk=128):
    """``a @ b`` with a 3-D grid ``(M/bm, N/bn, K/bk)`` and accumulation."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"K mismatch: {k} vs {k2}"
    bm, bn, bk = min(bm, m), min(bn, n), _divisor_at_most(k, bk)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def vmem_bytes(bm, bn, bk, elem=4, double_buffer=True):
    """VMEM per grid step — compare with :func:`..gemm.vmem_bytes`."""
    tiles = bm * bk + bk * bn + bm * bn
    return tiles * elem * (2 if double_buffer else 1)
