"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal of the L1 layer: every Pallas
kernel in this package is pytest-compared against these functions, and
``rust/src/runtime/reference.rs`` mirrors them exactly (same tanh-GeLU
constants) so the Rust native backend, the PJRT artifacts, and this file
all agree to float tolerance.
"""

import jax.numpy as jnp

SQRT_2_OVER_PI = 0.7978845608028654


def gelu(x):
    """GeLU, tanh approximation (matches ``jax.nn.gelu(approximate=True)``)."""
    return 0.5 * x * (1.0 + jnp.tanh(SQRT_2_OVER_PI * (x + 0.044715 * x * x * x)))


def gemm(a, b, bias=None):
    """Plain f32 GEMM with optional bias: ``a @ b (+ bias)``."""
    out = jnp.matmul(a, b, preferred_element_type=jnp.float32)
    if bias is not None:
        out = out + bias
    return out


def gemm_gelu(a, b, bias=None):
    """The fused MLP stage: ``gelu(a @ b + bias)`` — the paper's benchmark."""
    return gelu(gemm(a, b, bias))


def mlp(x, w1, b1, w2, b2):
    """Full ViT MLP: ``gelu(x @ w1 + b1) @ w2 + b2``."""
    return gemm(gemm_gelu(x, w1, b1), w2, b2)


def relu(x):
    """ReLU."""
    return jnp.maximum(x, 0.0)


def add(a, b):
    """Elementwise addition."""
    return a + b
