"""L1 Pallas kernel: **fused GEMM+GeLU** — the FTL insight at kernel level.

One ``pallas_call`` computes ``gelu(a @ b + bias)`` per output block: the
GEMM result tile lives only in VMEM registers/scratch and the activation
is applied before the block is written back. The intermediate tensor is
never materialised in HBM — exactly what FTL does with the Siracusa L1
TCDM, where the fused schedule applies the GeLU kernel to the GEMM's
output tile in place and only the activated tile is DMA'd out.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import SQRT_2_OVER_PI


def _fused_kernel(a_ref, b_ref, o_ref):
    acc = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = 0.5 * acc * (1.0 + jnp.tanh(SQRT_2_OVER_PI * (acc + 0.044715 * acc * acc * acc)))


def _fused_bias_kernel(a_ref, b_ref, bias_ref, o_ref):
    acc = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    acc = acc + bias_ref[...][None, :]
    o_ref[...] = 0.5 * acc * (1.0 + jnp.tanh(SQRT_2_OVER_PI * (acc + 0.044715 * acc * acc * acc)))


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def gemm_gelu(a, b, bias=None, *, bm=128, bn=128):
    """Fused ``gelu(a @ b (+ bias))`` — the paper's MLP stage in one kernel."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"K mismatch: {k} vs {k2}"
    bm, bn = min(bm, m), min(bn, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    a_spec = pl.BlockSpec((bm, k), lambda i, j: (i, 0))
    b_spec = pl.BlockSpec((k, bn), lambda i, j: (0, j))
    o_spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    out_shape = jax.ShapeDtypeStruct((m, n), jnp.float32)
    if bias is None:
        return pl.pallas_call(
            _fused_kernel,
            grid=grid,
            in_specs=[a_spec, b_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            interpret=True,
        )(a, b)
    bias_spec = pl.BlockSpec((bn,), lambda i, j: (j,))
    return pl.pallas_call(
        _fused_bias_kernel,
        grid=grid,
        in_specs=[a_spec, b_spec, bias_spec],
        out_specs=o_spec,
        out_shape=out_shape,
        interpret=True,
    )(a, b, bias)


def hbm_traffic_bytes(m, k, n, bm, bn, elem=4, fused=True):
    """Analytic HBM traffic of the stage (the paper's DMA-volume metric,
    translated): the un-fused pipeline writes + re-reads the ``m×n``
    intermediate; the fused kernel does not."""
    grid_m = -(-m // bm)
    grid_n = -(-n // bn)
    a_traffic = grid_n * m * k          # A re-read per N block-column
    b_traffic = grid_m * k * n          # B re-read per M block-row
    out = m * n
    inter = 0 if fused else 2 * m * n   # write + read of the intermediate
    return (a_traffic + b_traffic + out + inter) * elem
