"""L1 Pallas kernel: VMEM-tiled GEMM (+bias).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper tiles
int8 GEMMs into the Siracusa L1 TCDM with explicit DMA; on TPU the same
schedule is expressed with a Pallas ``BlockSpec`` grid — each grid step
owns an ``(bm, K) × (K, bn)`` pair of VMEM-resident blocks, mirroring the
FTL kernel-policy constraint that the reduction dimension K is *not*
tiled (the paper's int8 requantisation needs the full accumulation; here
it keeps the MXU pipeline saturated without a scratch accumulator).

Kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret-mode lowers to plain HLO that
both pytest and the Rust runtime can run. Real-TPU performance is
*estimated* from the VMEM footprint + MXU utilisation in EXPERIMENTS.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def _gemm_bias_kernel(a_ref, b_ref, bias_ref, o_ref):
    acc = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = acc + bias_ref[...][None, :]


def _block(m, n, bm, bn):
    """Clamp requested block sizes to the problem size."""
    return min(bm, m), min(bn, n)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def gemm(a, b, bias=None, *, bm=128, bn=128):
    """Tiled ``a @ b (+ bias)`` as a Pallas kernel.

    a: ``[M, K]``, b: ``[K, N]``, bias: ``[N]`` or None. Grid over
    ``(M/bm, N/bn)``; K whole per block (FTL kernel-policy constraint).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"K mismatch: {k} vs {k2}"
    bm, bn = _block(m, n, bm, bn)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    a_spec = pl.BlockSpec((bm, k), lambda i, j: (i, 0))
    b_spec = pl.BlockSpec((k, bn), lambda i, j: (0, j))
    o_spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    out_shape = jax.ShapeDtypeStruct((m, n), jnp.float32)
    if bias is None:
        return pl.pallas_call(
            _gemm_kernel,
            grid=grid,
            in_specs=[a_spec, b_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            interpret=True,
        )(a, b)
    bias_spec = pl.BlockSpec((bn,), lambda i, j: (j,))
    return pl.pallas_call(
        _gemm_bias_kernel,
        grid=grid,
        in_specs=[a_spec, b_spec, bias_spec],
        out_specs=o_spec,
        out_shape=out_shape,
        interpret=True,
    )(a, b, bias)


def vmem_bytes(m, k, n, bm, bn, elem=4, double_buffer=True):
    """Estimated VMEM footprint of one grid step (the L1-capacity analogue
    the FTL solver enforces; used by the §Perf block-size sweep)."""
    bm, bn = _block(m, n, bm, bn)
    tiles = bm * k + k * bn + bm * bn
    factor = 2 if double_buffer else 1
    return tiles * elem * factor


def mxu_utilization(m, k, n, bm, bn, mxu=(128, 128)):
    """Fraction of MXU lanes a block keeps busy — 1.0 when bm and bn fill
    the 128×128 systolic array (edge blocks waste lanes)."""
    bm, bn = _block(m, n, bm, bn)
    eff_m = bm / (((bm + mxu[0] - 1) // mxu[0]) * mxu[0])
    eff_n = bn / (((bn + mxu[1] - 1) // mxu[1]) * mxu[1])
    return eff_m * eff_n
