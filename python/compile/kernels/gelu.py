"""L1 Pallas kernel: tiled GeLU.

The standalone activation kernel of the *baseline* (layer-per-layer)
deployment: it reads the materialised intermediate back from HBM (the
paper's L3 round trip) block by block. Under FTL this kernel disappears
into :mod:`.fused`.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import SQRT_2_OVER_PI


def _gelu_kernel(x_ref, o_ref):
    x = x_ref[...]
    o_ref[...] = 0.5 * x * (1.0 + jnp.tanh(SQRT_2_OVER_PI * (x + 0.044715 * x * x * x)))


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def gelu(x, *, bm=128, bn=512):
    """Tiled tanh-GeLU over a 2-D tensor."""
    m, n = x.shape
    bm, bn = min(bm, m), min(bn, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        _gelu_kernel,
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x)


def _relu_kernel(x_ref, o_ref):
    o_ref[...] = jnp.maximum(x_ref[...], 0.0)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def relu(x, *, bm=128, bn=512):
    """Tiled ReLU (used by the extension workloads)."""
    m, n = x.shape
    bm, bn = min(bm, m), min(bn, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        _relu_kernel,
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x)


def _add_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def add(a, b, *, bm=128, bn=512):
    """Tiled elementwise addition (residual connections)."""
    m, n = a.shape
    bm, bn = min(bm, m), min(bn, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        _add_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)
