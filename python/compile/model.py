"""L2 — the JAX compute graph of the paper's workload.

The ViT MLP stage (``gelu(x @ w1 + b1)``) and the full MLP, each in two
variants:

* ``*_baseline`` — layer-per-layer: the GEMM's output is a materialised
  array between two separate Pallas calls (the intermediate round-trips
  through HBM, the L3 analogue);
* ``*_ftl`` — fused: one Pallas kernel per stage, intermediate confined
  to VMEM (the L1 analogue).

Everything here is lowered **once** by :mod:`compile.aot` to HLO text and
executed from Rust via PJRT — Python is never on the request path.
"""

from .kernels import fused, gelu as gelu_k, gemm as gemm_k, ref


def mlp_stage_baseline(x, w1, b1, *, bm=128, bn=128):
    """GEMM then GeLU as two tiled Pallas calls (intermediate materialised)."""
    h = gemm_k.gemm(x, w1, b1, bm=bm, bn=bn)
    return gelu_k.gelu(h, bm=bm, bn=bn)


def mlp_stage_ftl(x, w1, b1, *, bm=128, bn=128):
    """GEMM+GeLU as one fused Pallas kernel (FTL at kernel level)."""
    return fused.gemm_gelu(x, w1, b1, bm=bm, bn=bn)


def mlp_baseline(x, w1, b1, w2, b2, *, bm=128, bn=128):
    """Full MLP, layer-per-layer."""
    a = mlp_stage_baseline(x, w1, b1, bm=bm, bn=bn)
    return gemm_k.gemm(a, w2, b2, bm=bm, bn=bn)


def mlp_ftl(x, w1, b1, w2, b2, *, bm=128, bn=128):
    """Full MLP with the stage fused."""
    a = mlp_stage_ftl(x, w1, b1, bm=bm, bn=bn)
    return gemm_k.gemm(a, w2, b2, bm=bm, bn=bn)


def mlp_stage_ref(x, w1, b1):
    """Pure-jnp oracle of the stage."""
    return ref.gemm_gelu(x, w1, b1)


def mlp_ref(x, w1, b1, w2, b2):
    """Pure-jnp oracle of the full MLP."""
    return ref.mlp(x, w1, b1, w2, b2)
