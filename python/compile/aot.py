"""AOT compiler: lower the L2/L1 computations to HLO **text** artifacts.

Usage (from ``python/``)::

    python -m compile.aot --out ../artifacts [--tiles ../artifacts/tiles.json]

Two-pass build (see Makefile): the Rust planner first runs
``ftl emit-tiles`` to export the exact (op, tile-shape) signatures its
schedules will invoke; this module then AOT-compiles one executable per
signature plus the whole-model oracles, and writes ``manifest.json``. The
Rust runtime (`rust/src/runtime/pjrt.rs`) loads the manifest and executes
the tiles via the PJRT C API — Python never runs at request time.

HLO *text* (not ``HloModuleProto.serialize()``) is the interchange
format: jax ≥ 0.5 emits protos with 64-bit instruction ids which the
``xla`` crate's xla_extension 0.5.1 rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).
"""

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import fused, gelu as gelu_k, gemm as gemm_k


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape):
    """f32 ShapeDtypeStruct."""
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def lower_entry(kind, in_shapes):
    """Build + lower the jitted function for one tile signature."""
    specs = [spec(s) for s in in_shapes]
    if kind == "gemm":
        if len(in_shapes) == 3:
            fn = lambda a, b, bias: (gemm_k.gemm(a, b, bias),)  # noqa: E731
        else:
            fn = lambda a, b: (gemm_k.gemm(a, b),)  # noqa: E731
    elif kind == "gelu":
        fn = lambda x: (gelu_k.gelu(x),)  # noqa: E731
    elif kind == "relu":
        fn = lambda x: (gelu_k.relu(x),)  # noqa: E731
    elif kind == "add":
        fn = lambda a, b: (gelu_k.add(a, b),)  # noqa: E731
    elif kind == "gemm_gelu":
        if len(in_shapes) == 3:
            fn = lambda a, b, bias: (fused.gemm_gelu(a, b, bias),)  # noqa: E731
        else:
            fn = lambda a, b: (fused.gemm_gelu(a, b),)  # noqa: E731
    else:
        raise ValueError(f"unknown kind '{kind}'")
    return jax.jit(fn).lower(*specs)


def out_shape_of(lowered):
    """Output shape of a lowered 1-tuple function."""
    (out,) = lowered.out_info
    return list(out.shape)


def emit(out_dir: pathlib.Path, name: str, lowered, manifest: list):
    """Write one artifact + record it in the manifest."""
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    (out_dir / fname).write_text(text)
    in_shapes = [list(a.shape) for a in jax.tree_util.tree_leaves(lowered.in_avals)]
    manifest.append(
        {
            "name": name,
            "file": fname,
            "in_shapes": in_shapes,
            "out_shape": out_shape_of(lowered),
        }
    )
    print(f"  {name}: {len(text)} chars, in={in_shapes}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output directory")
    ap.add_argument("--tiles", default=None, help="tiles.json from `ftl emit-tiles`")
    ap.add_argument("--seq", type=int, default=197)
    ap.add_argument("--dim", type=int, default=768)
    ap.add_argument("--hidden", type=int, default=3072)
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest: list = []

    # --- Tile executables (exact shapes the Rust schedules invoke) -------
    fused_pairs = set()
    if args.tiles:
        tiles = json.loads(pathlib.Path(args.tiles).read_text())
        wl = tiles.get("workload", {})
        args.seq = wl.get("seq", args.seq)
        args.dim = wl.get("dim", args.dim)
        args.hidden = wl.get("hidden", args.hidden)
        print(f"compiling {len(tiles['entries'])} tile executables")
        for e in tiles["entries"]:
            lowered = lower_entry(e["kind"], e["in_shapes"])
            emit(out_dir, e["name"], lowered, manifest)
            # For every biased GEMM tile also emit the fused GEMM+GeLU
            # variant — the FTL kernel the fused schedule can call.
            if e["kind"] == "gemm" and len(e["in_shapes"]) == 3:
                m, k = e["in_shapes"][0]
                n = e["in_shapes"][1][1]
                fused_pairs.add((m, k, n))
    for m, k, n in sorted(fused_pairs):
        name = f"gemm_gelu_b_m{m}_k{k}_n{n}"
        lowered = lower_entry("gemm_gelu", [[m, k], [k, n], [n]])
        emit(out_dir, name, lowered, manifest)

    # --- Whole-model oracles + stage variants (e2e example, benches) -----
    s, d, h = args.seq, args.dim, args.hidden
    xs, ws, bs = [s, d], [d, h], [h]
    print(f"compiling whole-stage models ({s}x{d}->{h})")
    emit(
        out_dir,
        f"stage_ref_{s}x{d}x{h}",
        jax.jit(lambda x, w, b: (model.mlp_stage_ref(x, w, b),)).lower(spec(xs), spec(ws), spec(bs)),
        manifest,
    )
    emit(
        out_dir,
        f"stage_baseline_{s}x{d}x{h}",
        jax.jit(lambda x, w, b: (model.mlp_stage_baseline(x, w, b),)).lower(spec(xs), spec(ws), spec(bs)),
        manifest,
    )
    emit(
        out_dir,
        f"stage_ftl_{s}x{d}x{h}",
        jax.jit(lambda x, w, b: (model.mlp_stage_ftl(x, w, b),)).lower(spec(xs), spec(ws), spec(bs)),
        manifest,
    )

    (out_dir / "manifest.json").write_text(json.dumps({"entries": manifest}, indent=2))
    print(f"wrote {len(manifest)} artifacts + manifest.json to {out_dir}")


if __name__ == "__main__":
    main()
