//! Fusion-benefit sweep — where does FTL pay off, and by how much?
//!
//! Sweeps the MLP hidden dimension across the L2-overflow boundary (the
//! paper's mechanism) and the fusion chain length on a deep MLP, printing
//! runtime + DMA volume for baseline vs FTL on both SoC variants.
//!
//! ```text
//! cargo run --release --example fusion_sweep
//! ```

use anyhow::Result;

use ftl::config::DeployConfig;
use ftl::coordinator::{experiments, Deployer};
use ftl::ir::builder::deep_mlp;
use ftl::ir::DType;
use ftl::metrics::Table;
use ftl::tiling::{FusionPolicy, Strategy};

fn main() -> Result<()> {
    // ---- Sweep 1: hidden dim across the L2 overflow boundary ------------
    println!("== hidden-dim sweep (seq=197, d=768) — L2 overflow crossover ==\n");
    let hs = [256, 512, 1024, 1536, 2048, 3072, 4096, 6144];
    for soc in ["cluster-only", "siracusa"] {
        println!("--- {soc} ---");
        let mut t = Table::new(&["hidden", "intermediate KiB", "baseline cyc", "ftl cyc", "reduction"]);
        for (h, base, ftl, red) in experiments::hidden_sweep(197, 768, &hs, soc)? {
            t.row(&[
                h.to_string(),
                format!("{:.0}", (197 * h) as f64 / 1024.0),
                base.to_string(),
                ftl.to_string(),
                format!("{:.1}%", -red),
            ]);
        }
        println!("{}", t.render());
    }

    // ---- Sweep 2: fusion chain length on a deep MLP ----------------------
    println!("== fusion chain-length sweep (deep MLP, seq=128, width=1024) ==\n");
    let mut t = Table::new(&["max_len", "groups", "cycles", "dma bytes"]);
    for max_len in [1, 2, 4, 8] {
        let graph = deep_mlp(128, 1024, 4, DType::Int8);
        let cfg = DeployConfig::preset("siracusa", Strategy::Ftl)?;
        let dep = Deployer::new(graph, cfg)
            .with_policy(FusionPolicy { max_len, elementwise_only: true })
            .with_workload_name("deep-mlp");
        let (plan, report) = dep.deploy()?;
        t.row(&[
            max_len.to_string(),
            plan.groups.len().to_string(),
            report.sim.total_cycles.to_string(),
            report.sim.dma.total_bytes().to_string(),
        ]);
    }
    println!("{}", t.render());

    // ---- Sweep 3: aggressive (non-elementwise) fusion fallback ----------
    println!("== aggressive fusion (GEMM->GEMM attempted, solver falls back) ==\n");
    let graph = ftl::ir::builder::vit_mlp(197, 768, 3072, DType::Int8);
    let cfg = DeployConfig::preset("siracusa", Strategy::Ftl)?;
    let dep = Deployer::new(graph, cfg)
        .with_policy(FusionPolicy { max_len: 8, elementwise_only: false })
        .with_workload_name("vit-base-mlp-aggressive");
    let (plan, report) = dep.deploy()?;
    println!(
        "requested 1 group of 3 nodes; solver split into {} groups (capacity-driven fallback)",
        plan.groups.len()
    );
    println!("total: {} cycles, {} B DMA", report.sim.total_cycles, report.sim.dma.total_bytes());
    Ok(())
}
