//! Deployment server — the coordinator as a long-running service.
//!
//! A minimal line-oriented TCP protocol (std-only; the build is fully
//! offline): each request line is
//!
//! ```text
//! DEPLOY <workload> <soc> <strategy>            e.g. DEPLOY vit-base-stage siracusa ftl
//! ```
//!
//! and the response is one JSON line with the deploy report. Worker
//! threads serve requests concurrently; planning is CPU-bound, so a
//! thread per connection is the right concurrency model here.
//!
//! ```text
//! cargo run --release --example deploy_server &          # listens on 127.0.0.1:7117
//! printf 'DEPLOY vit-base-stage siracusa ftl\n' | nc 127.0.0.1 7117
//! ```
//!
//! Pass `--self-test` to spin up the server, fire a batch of client
//! requests against it, verify the responses, and exit — used as the
//! runnable demo (and by the integration tests).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use ftl::config::DeployConfig;
use ftl::coordinator::{experiments, Deployer};
use ftl::tiling::Strategy;
use ftl::util::json::Json;

fn handle_request(line: &str, served: &AtomicU64) -> Json {
    match serve(line) {
        Ok(j) => {
            served.fetch_add(1, Ordering::Relaxed);
            j
        }
        Err(e) => Json::obj(vec![("error", Json::str(format!("{e:#}")))]),
    }
}

fn serve(line: &str) -> Result<Json> {
    let parts: Vec<&str> = line.split_whitespace().collect();
    match parts.as_slice() {
        ["DEPLOY", workload, soc, strategy] => {
            let strategy =
                Strategy::parse(strategy).ok_or_else(|| anyhow!("bad strategy '{strategy}'"))?;
            let graph = match *workload {
                "vit-base-stage" => experiments::vit_mlp_stage(197, 768, 3072),
                "vit-tiny-stage" => experiments::vit_mlp_stage(197, 192, 768),
                other => ftl::ir::builder::vit_mlp_preset(other)
                    .ok_or_else(|| anyhow!("unknown workload '{other}'"))?,
            };
            let cfg = DeployConfig::preset(soc, strategy)?;
            let soc_cfg = cfg.soc.clone();
            let (_, report) = Deployer::new(graph, cfg).with_workload_name(*workload).deploy()?;
            Ok(report.to_json(&soc_cfg))
        }
        ["PING"] => Ok(Json::obj(vec![("pong", Json::Bool(true))])),
        _ => bail!("bad request: '{line}' (expected: DEPLOY <workload> <soc> <strategy>)"),
    }
}

fn client(conn: TcpStream, served: Arc<AtomicU64>) {
    let peer = conn.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    let reader = BufReader::new(conn.try_clone().expect("clone stream"));
    let mut writer = conn;
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let response = handle_request(line.trim(), &served);
        if writeln!(writer, "{}", response.to_string()).is_err() {
            break;
        }
    }
    eprintln!("[server] {peer} disconnected");
}

fn run_server(addr: &str) -> Result<(TcpListener, Arc<AtomicU64>)> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let served = Arc::new(AtomicU64::new(0));
    Ok((listener, served))
}

fn main() -> Result<()> {
    let self_test = std::env::args().any(|a| a == "--self-test");
    let addr = "127.0.0.1:7117";
    let (listener, served) = run_server(addr)?;
    println!("[server] listening on {addr} (protocol: DEPLOY <workload> <soc> <strategy>)");

    if self_test {
        let served2 = served.clone();
        let local = listener.local_addr()?;
        std::thread::spawn(move || {
            for conn in listener.incoming().flatten() {
                let served = served2.clone();
                std::thread::spawn(move || client(conn, served));
            }
        });
        // Fire a concurrent batch of requests.
        let requests = [
            "DEPLOY vit-base-stage siracusa ftl",
            "DEPLOY vit-base-stage siracusa baseline",
            "DEPLOY vit-base-stage cluster-only ftl",
            "DEPLOY vit-tiny-stage cluster-only baseline",
            "PING",
        ];
        let handles: Vec<_> = requests
            .iter()
            .map(|req| {
                let req = req.to_string();
                std::thread::spawn(move || -> Result<String> {
                    let mut conn = TcpStream::connect(local)?;
                    writeln!(conn, "{req}")?;
                    let mut line = String::new();
                    BufReader::new(conn).read_line(&mut line)?;
                    Ok(line)
                })
            })
            .collect();
        let mut ftl_cycles = 0i64;
        let mut base_cycles = 0i64;
        for (req, h) in requests.iter().zip(handles) {
            let line = h.join().map_err(|_| anyhow!("client thread panicked"))??;
            let v = ftl::util::json::parse(line.trim())?;
            if v.get_opt("error").is_some() {
                bail!("request '{req}' failed: {line}");
            }
            if let Some(sim) = v.get_opt("sim") {
                let cycles = sim.get("total_cycles")?.as_usize()? as i64;
                println!("[client] {req} -> {cycles} cycles");
                if req.contains("siracusa ftl") {
                    ftl_cycles = cycles;
                } else if req.contains("siracusa baseline") {
                    base_cycles = cycles;
                }
            } else {
                println!("[client] {req} -> {}", line.trim());
            }
        }
        assert!(ftl_cycles > 0 && base_cycles > ftl_cycles, "FTL must beat baseline over the wire too");
        println!("[server] served {} requests; self-test OK", served.load(Ordering::Relaxed));
        return Ok(());
    }

    for conn in listener.incoming().flatten() {
        let served = served.clone();
        std::thread::spawn(move || client(conn, served));
    }
    Ok(())
}
