//! Deployment server — the serve layer (`ftl::serve`) as a long-running
//! TCP service.
//!
//! A minimal line-oriented protocol (std-only; the build is fully
//! offline): each request line is one of
//!
//! ```text
//! DEPLOY <workload> <soc> <strategy> [deadline-ms] [lane=<name>]
//!                                         e.g. DEPLOY vit-base-stage siracusa ftl 500 lane=gold
//! STATS                                   plan-cache / single-flight / per-lane counters
//! PING
//! ```
//!
//! and the response is one JSON line. Requests are handled by a thread
//! per connection, but the heavy lifting is shared: every DEPLOY goes
//! through the [`BatchScheduler`] (admission control + SoC-grouped
//! batching) into the [`PlanService`], so structurally identical
//! requests are served from the sharded plan + sim caches (`"cached"` /
//! `"sim_cached"` in the response), concurrent misses for the same key
//! coalesce into a single branch-&-bound solve, and overload sheds
//! (`"outcome": "SHED"`) instead of stalling the queue.
//!
//! ```text
//! cargo run --release --example deploy_server &          # listens on 127.0.0.1:7117
//! printf 'DEPLOY vit-base-stage siracusa ftl\n' | nc 127.0.0.1 7117
//! printf 'STATS\n' | nc 127.0.0.1 7117
//! ```
//!
//! Pass `--self-test` to spin up the server, fire concurrent client
//! batches against it (including duplicates), verify the responses *and*
//! the cache/single-flight accounting — then snapshot the warm caches and
//! **restart** into a fresh service pointed at the same `--cache-dir`
//! (default: a temp dir), proving every previously seen request is served
//! with zero solves and zero simulator runs — then run a two-lane 3:1
//! priority-lane saturation wave (weighted fair queuing must hand the
//! heavy tenant ~3/4 of the early cold work; greppable
//! `lane_wave early gold=…/… quanta` shares) — and exit.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::{anyhow, bail, ensure, Context, Result};

use ftl::serve::{handle_line, BatchOptions, BatchScheduler, PersistOptions, PlanService, ServeOptions, Snapshotter};
use ftl::util::json::Json;

fn client(conn: TcpStream, scheduler: Arc<BatchScheduler>) {
    let peer = conn.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    let reader = BufReader::new(conn.try_clone().expect("clone stream"));
    let mut writer = conn;
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        // Protocol handling lives in ftl::serve::handle_line, shared with
        // the `ftl serve` subcommand.
        let response = handle_line(&scheduler, line.trim());
        if writeln!(writer, "{response}").is_err() {
            break;
        }
    }
    eprintln!("[server] {peer} disconnected");
}

/// Fire one request over a fresh connection, return the parsed response.
fn request(addr: std::net::SocketAddr, req: &str) -> Result<Json> {
    let mut conn = TcpStream::connect(addr)?;
    writeln!(conn, "{req}")?;
    let mut line = String::new();
    BufReader::new(conn).read_line(&mut line)?;
    let v = ftl::util::json::parse(line.trim())?;
    if let Some(err) = v.get_opt("error") {
        bail!("request '{req}' failed: {}", err.as_str().unwrap_or("?"));
    }
    Ok(v)
}

fn self_test(listener: TcpListener, scheduler: Arc<BatchScheduler>, cache_dir: Option<String>) -> Result<()> {
    let local = listener.local_addr()?;
    let accept_scheduler = scheduler.clone();
    std::thread::spawn(move || {
        for conn in listener.incoming().flatten() {
            let scheduler = accept_scheduler.clone();
            std::thread::spawn(move || client(conn, scheduler));
        }
    });

    // Wave 1: concurrent batch with duplicates — the three duplicates of
    // the siracusa/ftl deploy must coalesce onto one solve.
    let requests = [
        "DEPLOY vit-base-stage siracusa ftl",
        "DEPLOY vit-base-stage siracusa ftl",
        "DEPLOY vit-base-stage siracusa ftl",
        "DEPLOY vit-base-stage siracusa baseline",
        "DEPLOY vit-base-stage cluster-only ftl",
        "DEPLOY vit-tiny-stage cluster-only baseline",
    ];
    let unique = 4u64;
    let handles: Vec<_> = requests
        .iter()
        .map(|req| {
            let req = req.to_string();
            std::thread::spawn(move || -> Result<Json> { request(local, &req) })
        })
        .collect();
    let mut ftl_cycles = 0i64;
    let mut base_cycles = 0i64;
    for (req, h) in requests.iter().zip(handles) {
        let v = h.join().map_err(|_| anyhow!("client thread panicked"))??;
        ensure!(v.get("outcome")?.as_str()? == "OK", "wave-1 request '{req}' not served");
        let sim = v.get("sim").context("DEPLOY response missing sim")?;
        let cycles = sim.get("total_cycles")?.as_usize()? as i64;
        println!("[client] {req} -> {cycles} cycles (cached: {})", v.get("cached")?);
        if req.contains("siracusa ftl") {
            ftl_cycles = cycles;
        } else if req.contains("siracusa baseline") {
            base_cycles = cycles;
        }
    }
    ensure!(ftl_cycles > 0 && base_cycles > ftl_cycles, "FTL must beat baseline over the wire too");

    // Wave 2: repeat everything — now every response must hit both the
    // plan cache and the sim-report cache.
    for req in &requests {
        let v = request(local, req)?;
        ensure!(
            v.get("cached")?.as_bool()?,
            "second-wave request '{req}' was not served from the plan cache"
        );
        ensure!(
            v.get("sim_cached")?.as_bool()?,
            "second-wave request '{req}' re-ran the simulation engine"
        );
    }

    // Accounting: exactly one solve + one simulation per distinct
    // (workload, soc, strategy).
    let stats = request(local, "STATS")?;
    let solves = stats.get("solves")?.as_usize()? as u64;
    ensure!(
        solves == unique,
        "expected exactly {unique} solves for {unique} distinct requests, got {solves}"
    );
    let sims = stats.get("sims")?.as_usize()? as u64;
    ensure!(sims == unique, "expected exactly {unique} sims, got {sims}");
    let hits = stats.get("plan_cache")?.get("hits")?.as_usize()?;
    ensure!(hits >= requests.len(), "second wave must hit the cache ({hits} hits)");
    // Wave 1's cold requests flow through the batch queue (at least one
    // per distinct fingerprint); wave 2 is fully warm and takes the
    // cache fast path, bypassing the queue.
    let batched = stats.get("batch")?.get("batched_requests")?.as_usize()?;
    ensure!(
        batched >= unique as usize && batched <= requests.len(),
        "cold wave must flow through the batch queue ({batched})"
    );
    let pong = request(local, "PING")?;
    ensure!(pong.get("pong")?.as_bool()?, "PING must pong");

    // Wave 3: persistence — snapshot the warm caches, then "restart" into
    // a fresh service pointed at the same directory. Every previously
    // seen request must now be served straight from the loaded snapshot:
    // zero branch-&-bound solves, zero simulator runs.
    let using_temp = cache_dir.is_none();
    let dir = cache_dir.unwrap_or_else(|| {
        std::env::temp_dir()
            .join(format!("ftl-deploy-server-snap-{}", std::process::id()))
            .to_string_lossy()
            .into_owned()
    });
    {
        let snap = Snapshotter::attach(scheduler.service().clone(), &dir, PersistOptions::manual())?;
        // A pre-populated --cache-dir counts as already written: flush
        // only covers whatever the load pass didn't find on disk.
        let already = snap.counters().loaded();
        let written = snap.flush();
        ensure!(
            written as u64 + already >= 2 * unique,
            "snapshot must persist one plan + one sim per distinct fingerprint (wrote {written}, loaded {already})"
        );
    }
    let service2 = Arc::new(PlanService::new(ServeOptions::default()));
    let snap2 = Snapshotter::attach(service2.clone(), &dir, PersistOptions::manual())?;
    ensure!(snap2.counters().loaded() >= 2 * unique, "restart must load the snapshot back");
    let sched2 = BatchScheduler::new(service2.clone(), BatchOptions::default());
    for req in &requests {
        let v = handle_line(&sched2, req);
        ensure!(v.get_opt("error").is_none(), "restart request '{req}' failed: {v}");
        ensure!(v.get("cached")?.as_bool()?, "restarted service must hit the loaded plan cache for '{req}'");
        ensure!(v.get("sim_cached")?.as_bool()?, "restarted service must hit the loaded sim cache for '{req}'");
    }
    let s2 = service2.stats();
    ensure!(
        s2.solves == 0 && s2.sims == 0,
        "warm restart must serve with zero solves/sims (got {}/{})",
        s2.solves,
        s2.sims
    );
    println!("[server] warm restart from {dir}: {} requests, 0 solves, 0 sims", requests.len());
    if using_temp {
        let _ = std::fs::remove_dir_all(&dir);
    }

    // Wave 4: priority-lane fairness under saturation (its own fresh
    // scheduler — the waves above exercised the default single lane).
    lane_wave()?;

    println!("[server] stats: {}", scheduler.stats_json());
    println!(
        "[server] served {} plan requests with {} solves / {} sims; self-test OK",
        scheduler.service().stats().requests,
        solves,
        sims
    );
    Ok(())
}

/// Wave 4: two tenants — "gold" (weight 3) and "free" (weight 1) —
/// flood a fresh scheduler with distinct cold requests at the same
/// instant, one request per WFQ quantum. Weighted fair queuing must
/// give gold ~3/4 of the early service (exactly 12 of the first 16
/// under the virtual clock; the threaded run tolerates startup
/// raggedness). The shared driver ([`ftl::serve::wave`], also run by
/// the `lane_contention` bench) samples the early share from the
/// dispatcher's own counters and asserts the drain invariants.
fn lane_wave() -> Result<()> {
    let report = ftl::serve::wave::two_tenant_wave(12, 16)?;
    let expect = 3.0 * report.total_early as f64 / 4.0;
    println!(
        "[server] lane_wave early gold={}/{} quanta (weights 3:1, expect ~{expect:.0})",
        report.gold_early, report.total_early
    );
    // The 3:1 split only holds while both lanes stay backlogged (gold
    // drains after 12 quanta); a pathologically late sample has nothing
    // left to judge.
    if report.total_early <= 20 {
        ensure!(
            (report.gold_early as f64 - expect).abs() <= 3.0,
            "3:1 lanes must give gold a ~3/4 share of early service (got {}/{})",
            report.gold_early,
            report.total_early
        );
    } else {
        println!("[server] lane_wave sample landed past the window; skipping the share assert");
    }
    println!("{}", report.stats.lanes_table());
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let self_test_mode = argv.iter().any(|a| a == "--self-test");
    let cache_dir = argv.iter().position(|a| a == "--cache-dir").and_then(|i| argv.get(i + 1).cloned());
    // Port 0 in self-test mode: parallel test runs must not collide.
    let addr = if self_test_mode { "127.0.0.1:0" } else { "127.0.0.1:7117" };
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let service = Arc::new(PlanService::new(ServeOptions::default()));
    // In long-running mode, a --cache-dir persists the caches across
    // restarts (warm start + 1 s write-behind); in self-test mode the
    // restart wave attaches its own snapshotters instead.
    let _snapshotter = match (&cache_dir, self_test_mode) {
        (Some(dir), false) => Some(Snapshotter::attach(service.clone(), dir, PersistOptions::default())?),
        _ => None,
    };
    let scheduler = Arc::new(BatchScheduler::new(service, BatchOptions::default()));
    println!(
        "[server] listening on {} (protocol: DEPLOY <workload> <soc> <strategy> [deadline-ms] | STATS | PING)",
        listener.local_addr()?
    );

    if self_test_mode {
        return self_test(listener, scheduler, cache_dir);
    }

    for conn in listener.incoming().flatten() {
        let scheduler = scheduler.clone();
        std::thread::spawn(move || client(conn, scheduler));
    }
    Ok(())
}
