//! Deployment server — the serve layer (`ftl::serve`) as a long-running
//! TCP service.
//!
//! A minimal line-oriented protocol (std-only; the build is fully
//! offline): each request line is one of
//!
//! ```text
//! DEPLOY <workload> <soc> <strategy> [deadline-ms] [lane=<name>]
//!                                         e.g. DEPLOY vit-base-stage siracusa ftl 500 lane=gold
//! STATS                                   plan-cache / single-flight / per-lane / latency counters
//! METRICS                                 Prometheus-style text exposition
//! TRACE [n]                               newest n spans from the trace journal (JSON lines)
//! SLOW [n]                                newest n slowlog spans (JSON lines)
//! PING
//! ```
//!
//! and the response is one JSON line (`METRICS`/`TRACE`/`SLOW` are
//! multi-line). Commands may also be framed `FTL1 <id> <command...>`
//! for multiplexed ids and streamed partial replies — see PROTOCOL.md.
//! Connections are served by the async front door
//! ([`ftl::serve::Frontend`]: one readiness-polled event loop, many
//! in-flight requests per connection), and the heavy lifting is shared:
//! every DEPLOY goes through the [`BatchScheduler`] (admission control
//! + SoC-grouped batching) into the [`PlanService`], so structurally
//! identical requests are served from the sharded plan + sim caches
//! (`"cached"` / `"sim_cached"` in the response), concurrent misses for
//! the same key coalesce into a single branch-&-bound solve, and
//! overload sheds (`"outcome": "SHED"`) instead of stalling the queue.
//!
//! ```text
//! cargo run --release --example deploy_server &          # listens on 127.0.0.1:7117
//! printf 'DEPLOY vit-base-stage siracusa ftl\n' | nc 127.0.0.1 7117
//! printf 'STATS\n' | nc 127.0.0.1 7117
//! ```
//!
//! Pass `--self-test` to spin up the server, fire concurrent client
//! batches against it (including duplicates), verify the responses *and*
//! the cache/single-flight accounting — then snapshot the warm caches and
//! **restart** into a fresh service pointed at the same `--cache-dir`
//! (default: a temp dir), proving every previously seen request is served
//! with zero solves and zero simulator runs — then run a two-lane 3:1
//! priority-lane saturation wave (weighted fair queuing must hand the
//! heavy tenant ~3/4 of the early cold work; greppable
//! `lane_wave early gold=…/… quanta` shares) — then a tracing wave
//! against a dedicated low-slowlog server, asserting every reply's
//! trace id is journalled with monotone stage offsets and the
//! deliberately slow cold deploy through the weight-1 lane lands in
//! `SLOW` — and finally probe the v1 front door itself (streamed
//! plan/sim/done events, out-of-order ids, legacy v0 ordering;
//! greppable `stream_wave` / `v0_wave` lines) — and exit.

use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::{anyhow, bail, ensure, Context, Result};

use ftl::serve::{
    handle_line, BatchOptions, BatchScheduler, Frontend, FrontendOptions, LaneSpec, PersistOptions,
    PlanService, ServeOptions, Snapshotter, TraceOptions,
};
use ftl::util::json::Json;

/// Fire one request over a fresh connection, return the parsed response.
fn request(addr: std::net::SocketAddr, req: &str) -> Result<Json> {
    let mut conn = TcpStream::connect(addr)?;
    writeln!(conn, "{req}")?;
    let mut line = String::new();
    BufReader::new(conn).read_line(&mut line)?;
    let v = ftl::util::json::parse(line.trim())?;
    if let Some(err) = v.get_opt("error") {
        bail!("request '{req}' failed: {}", err.as_str().unwrap_or("?"));
    }
    Ok(v)
}

/// Fire one request whose response spans multiple lines
/// (METRICS/TRACE/SLOW): close the write half so the server's line loop
/// ends, then read to EOF.
fn request_lines(addr: std::net::SocketAddr, req: &str) -> Result<Vec<String>> {
    let mut conn = TcpStream::connect(addr)?;
    writeln!(conn, "{req}")?;
    conn.shutdown(Shutdown::Write)?;
    let mut lines = Vec::new();
    for line in BufReader::new(conn).lines() {
        let line = line?;
        if !line.is_empty() {
            lines.push(line);
        }
    }
    ensure!(!lines.is_empty(), "request '{req}' got no response");
    Ok(lines)
}

fn self_test(listener: TcpListener, scheduler: Arc<BatchScheduler>, cache_dir: Option<String>) -> Result<()> {
    // The same front door as production mode serves the whole self-test.
    let door = Frontend::new(scheduler.clone(), FrontendOptions::default()).serve(listener)?;
    let local = door.addr();

    // Wave 1: concurrent batch with duplicates — the three duplicates of
    // the siracusa/ftl deploy must coalesce onto one solve.
    let requests = [
        "DEPLOY vit-base-stage siracusa ftl",
        "DEPLOY vit-base-stage siracusa ftl",
        "DEPLOY vit-base-stage siracusa ftl",
        "DEPLOY vit-base-stage siracusa baseline",
        "DEPLOY vit-base-stage cluster-only ftl",
        "DEPLOY vit-tiny-stage cluster-only baseline",
    ];
    let unique = 4u64;
    let handles: Vec<_> = requests
        .iter()
        .map(|req| {
            let req = req.to_string();
            std::thread::spawn(move || -> Result<Json> { request(local, &req) })
        })
        .collect();
    let mut ftl_cycles = 0i64;
    let mut base_cycles = 0i64;
    for (req, h) in requests.iter().zip(handles) {
        let v = h.join().map_err(|_| anyhow!("client thread panicked"))??;
        ensure!(v.get("outcome")?.as_str()? == "OK", "wave-1 request '{req}' not served");
        let sim = v.get("sim").context("DEPLOY response missing sim")?;
        let cycles = sim.get("total_cycles")?.as_usize()? as i64;
        println!("[client] {req} -> {cycles} cycles (cached: {})", v.get("cached")?);
        if req.contains("siracusa ftl") {
            ftl_cycles = cycles;
        } else if req.contains("siracusa baseline") {
            base_cycles = cycles;
        }
    }
    ensure!(ftl_cycles > 0 && base_cycles > ftl_cycles, "FTL must beat baseline over the wire too");

    // Wave 2: repeat everything — now every response must hit both the
    // plan cache and the sim-report cache.
    for req in &requests {
        let v = request(local, req)?;
        ensure!(
            v.get("cached")?.as_bool()?,
            "second-wave request '{req}' was not served from the plan cache"
        );
        ensure!(
            v.get("sim_cached")?.as_bool()?,
            "second-wave request '{req}' re-ran the simulation engine"
        );
    }

    // Accounting: exactly one solve + one simulation per distinct
    // (workload, soc, strategy).
    let stats = request(local, "STATS")?;
    let solves = stats.get("solves")?.as_usize()? as u64;
    ensure!(
        solves == unique,
        "expected exactly {unique} solves for {unique} distinct requests, got {solves}"
    );
    let sims = stats.get("sims")?.as_usize()? as u64;
    ensure!(sims == unique, "expected exactly {unique} sims, got {sims}");
    let hits = stats.get("plan_cache")?.get("hits")?.as_usize()?;
    ensure!(hits >= requests.len(), "second wave must hit the cache ({hits} hits)");
    // Wave 1's cold requests flow through the batch queue (at least one
    // per distinct fingerprint); wave 2 is fully warm and takes the
    // cache fast path, bypassing the queue.
    let batched = stats.get("batch")?.get("batched_requests")?.as_usize()?;
    ensure!(
        batched >= unique as usize && batched <= requests.len(),
        "cold wave must flow through the batch queue ({batched})"
    );
    let pong = request(local, "PING")?;
    ensure!(pong.get("pong")?.as_bool()?, "PING must pong");

    // Wave 3: persistence — snapshot the warm caches, then "restart" into
    // a fresh service pointed at the same directory. Every previously
    // seen request must now be served straight from the loaded snapshot:
    // zero branch-&-bound solves, zero simulator runs.
    let using_temp = cache_dir.is_none();
    let dir = cache_dir.unwrap_or_else(|| {
        std::env::temp_dir()
            .join(format!("ftl-deploy-server-snap-{}", std::process::id()))
            .to_string_lossy()
            .into_owned()
    });
    {
        let snap = Snapshotter::attach(scheduler.service().clone(), &dir, PersistOptions::manual())?;
        // A pre-populated --cache-dir counts as already written: flush
        // only covers whatever the load pass didn't find on disk.
        let already = snap.counters().loaded();
        let written = snap.flush();
        ensure!(
            written as u64 + already >= 2 * unique,
            "snapshot must persist one plan + one sim per distinct fingerprint (wrote {written}, loaded {already})"
        );
    }
    let service2 = Arc::new(PlanService::new(ServeOptions::default()));
    let snap2 = Snapshotter::attach(service2.clone(), &dir, PersistOptions::manual())?;
    ensure!(snap2.counters().loaded() >= 2 * unique, "restart must load the snapshot back");
    let sched2 = BatchScheduler::new(service2.clone(), BatchOptions::default());
    for req in &requests {
        let v = handle_line(&sched2, req);
        ensure!(v.get_opt("error").is_none(), "restart request '{req}' failed: {v}");
        ensure!(v.get("cached")?.as_bool()?, "restarted service must hit the loaded plan cache for '{req}'");
        ensure!(v.get("sim_cached")?.as_bool()?, "restarted service must hit the loaded sim cache for '{req}'");
    }
    let s2 = service2.stats();
    ensure!(
        s2.solves == 0 && s2.sims == 0,
        "warm restart must serve with zero solves/sims (got {}/{})",
        s2.solves,
        s2.sims
    );
    println!("[server] warm restart from {dir}: {} requests, 0 solves, 0 sims", requests.len());
    if using_temp {
        let _ = std::fs::remove_dir_all(&dir);
    }

    // Wave 4: priority-lane fairness under saturation (its own fresh
    // scheduler — the waves above exercised the default single lane).
    lane_wave()?;

    // Wave 5: end-to-end tracing over the wire (its own server with a
    // deliberately low slowlog threshold).
    trace_wave()?;

    // Wave 6: the v1 front door contract, over the main server — cold
    // deploys stream plan → sim* → done, warm ones collapse to a single
    // frame, ids complete out of order, and bare v0 lines stay ordered
    // with their legacy reply shape (shared probes in ftl::serve::wave,
    // also run by `ftl serve --self-test`).
    let addr_text = local.to_string();
    let probe = ftl::serve::wave::streaming_probe(&addr_text)?;
    println!(
        "[server] stream_wave plan={} sim={} done={} out_of_order={}",
        probe.plan_events, probe.sim_events, probe.done_events, probe.out_of_order
    );
    let v0_replies = ftl::serve::wave::v0_probe(&addr_text)?;
    println!("[server] v0_wave replies={v0_replies} (legacy lines, ordered)");
    ensure!(door.counters().protocol_errors.get() == 0, "clean waves must not count protocol errors");

    println!("[server] stats: {}", scheduler.stats_json());
    println!(
        "[server] served {} plan requests with {} solves / {} sims; self-test OK",
        scheduler.service().stats().requests,
        solves,
        sims
    );
    Ok(())
}

/// Wave 4: two tenants — "gold" (weight 3) and "free" (weight 1) —
/// flood a fresh scheduler with distinct cold requests at the same
/// instant, one request per WFQ quantum. Weighted fair queuing must
/// give gold ~3/4 of the early service (exactly 12 of the first 16
/// under the virtual clock; the threaded run tolerates startup
/// raggedness). The shared driver ([`ftl::serve::wave`], also run by
/// the `lane_contention` bench) samples the early share from the
/// dispatcher's own counters and asserts the drain invariants.
fn lane_wave() -> Result<()> {
    let report = ftl::serve::wave::two_tenant_wave(12, 16)?;
    let expect = 3.0 * report.total_early as f64 / 4.0;
    println!(
        "[server] lane_wave early gold={}/{} quanta (weights 3:1, expect ~{expect:.0})",
        report.gold_early, report.total_early
    );
    // The 3:1 split only holds while both lanes stay backlogged (gold
    // drains after 12 quanta); a pathologically late sample has nothing
    // left to judge.
    if report.total_early <= 20 {
        ensure!(
            (report.gold_early as f64 - expect).abs() <= 3.0,
            "3:1 lanes must give gold a ~3/4 share of early service (got {}/{})",
            report.gold_early,
            report.total_early
        );
    } else {
        println!("[server] lane_wave sample landed past the window; skipping the share assert");
    }
    println!("{}", report.stats.lanes_table());
    Ok(())
}

/// Wave 5: end-to-end tracing over the wire. A dedicated two-lane
/// server with a 1 ms slowlog threshold serves a mix of cold and warm
/// deploys; every reply's `"trace"` id must be found in the `TRACE`
/// journal with monotone stage offsets, the deliberately slow request —
/// a cold full-size solve routed through the weight-1 "slow" lane —
/// must cross the threshold and surface in `SLOW`, and `METRICS` must
/// satisfy the strict exposition parser.
fn trace_wave() -> Result<()> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let local = listener.local_addr()?;
    let service = Arc::new(PlanService::new(ServeOptions::default()));
    let scheduler = Arc::new(BatchScheduler::new(
        service,
        BatchOptions {
            lanes: vec![LaneSpec::new("gold", 3, 64), LaneSpec::new("slow", 1, 64)],
            trace: TraceOptions { slowlog_ms: 1, ..TraceOptions::default() },
            ..BatchOptions::default()
        },
    ));
    let _door = Frontend::new(scheduler.clone(), FrontendOptions::default()).serve(listener)?;

    // Cold then warm through gold; the repeat takes the cache fast path.
    let mut ids = Vec::new();
    for _ in 0..2 {
        let v = request(local, "DEPLOY vit-tiny-stage cluster-only ftl lane=gold")?;
        ids.push(v.get("trace")?.as_u64()?);
    }
    // The deliberately slow request: a cold full-size branch-&-bound
    // solve through the weight-1 lane, far past the 1 ms threshold.
    let slow_id = request(local, "DEPLOY vit-base-stage siracusa ftl lane=slow")?.get("trace")?.as_u64()?;
    ids.push(slow_id);

    let dump = request_lines(local, "TRACE 64")?;
    let header = ftl::util::json::parse(&dump[0])?;
    ensure!(header.get("spans")?.as_usize()? >= ids.len(), "TRACE must journal every request");
    let mut seen = Vec::new();
    for line in &dump[1..] {
        let span = ftl::util::json::parse(line)?;
        let id = span.get("id")?.as_u64()?;
        seen.push(id);
        let mut prev = 0u64;
        for key in ["queued_us", "picked_us", "solved_us", "simmed_us", "total_us"] {
            if let Some(v) = span.get_opt(key) {
                let v = v.as_u64()?;
                ensure!(v >= prev, "span {id} stages must be monotone ({key}={v} < {prev})");
                prev = v;
            }
        }
        if id == slow_id {
            ensure!(span.get("lane")?.as_str()? == "slow", "slow deploy must be attributed to its lane");
            ensure!(!span.get("warm")?.as_bool()?, "the slow deploy was cold");
        }
    }
    for id in &ids {
        ensure!(seen.contains(id), "reply trace id {id} missing from the TRACE journal");
    }

    let slow_dump = request_lines(local, "SLOW 64")?;
    let slow_ids: Vec<u64> = slow_dump[1..]
        .iter()
        .map(|l| -> Result<u64> { Ok(ftl::util::json::parse(l)?.get("id")?.as_u64()?) })
        .collect::<Result<_>>()?;
    ensure!(slow_ids.contains(&slow_id), "the slow cold deploy must land in SLOW (got ids {slow_ids:?})");

    let metrics = request_lines(local, "METRICS")?;
    let samples = ftl::metrics::expo::parse(&metrics.join("\n"))?;
    ensure!(
        samples.iter().any(|s| s.name == "ftl_latency_us_count"),
        "METRICS must expose per-lane latency histograms"
    );
    println!(
        "[server] trace_wave: {} spans journalled, slow id {slow_id} in SLOW, {} metric samples",
        seen.len(),
        samples.len()
    );
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let self_test_mode = argv.iter().any(|a| a == "--self-test");
    let cache_dir = argv.iter().position(|a| a == "--cache-dir").and_then(|i| argv.get(i + 1).cloned());
    // Port 0 in self-test mode: parallel test runs must not collide.
    let addr = if self_test_mode { "127.0.0.1:0" } else { "127.0.0.1:7117" };
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let service = Arc::new(PlanService::new(ServeOptions::default()));
    // In long-running mode, a --cache-dir persists the caches across
    // restarts (warm start + 1 s write-behind); in self-test mode the
    // restart wave attaches its own snapshotters instead.
    let _snapshotter = match (&cache_dir, self_test_mode) {
        (Some(dir), false) => Some(Snapshotter::attach(service.clone(), dir, PersistOptions::default())?),
        _ => None,
    };
    let scheduler = Arc::new(BatchScheduler::new(service, BatchOptions::default()));
    println!(
        "[server] listening on {} (protocol: DEPLOY <workload> <soc> <strategy> [deadline-ms] \
         [lane=<name>] | STATS | METRICS | TRACE [n] | SLOW [n] | PING; \
         FTL1 <id> framing for multiplexed streaming — see PROTOCOL.md)",
        listener.local_addr()?
    );

    if self_test_mode {
        return self_test(listener, scheduler, cache_dir);
    }

    let handle = Frontend::new(scheduler, FrontendOptions::default()).serve(listener)?;
    handle.join();
    Ok(())
}
