//! Quickstart: deploy a small MLP with FTL in ~30 lines.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;

use ftl::config::DeployConfig;
use ftl::coordinator::Deployer;
use ftl::ir::{ActKind, DType, GraphBuilder};
use ftl::runtime::NativeBackend;
use ftl::tiling::Strategy;

fn main() -> Result<()> {
    // 1. Describe the network (a small MLP stage: Linear -> GeLU).
    let mut b = GraphBuilder::new(DType::Int8);
    let x = b.input("x", &[64, 256]);
    let fc = b.linear("fc", x, 1024, true);
    let act = b.act("gelu", ActKind::Gelu, fc);
    let graph = b.finish(act)?;

    // 2. Pick a target SoC + strategy and deploy.
    let config = DeployConfig::preset("siracusa", Strategy::Ftl)?;
    let soc = config.soc.clone();
    let deployer = Deployer::new(graph, config).with_workload_name("quickstart-mlp");
    let (plan, report) = deployer.deploy()?;

    // 3. Inspect the result.
    println!("{}", report.render(&soc));
    println!(
        "fused into {} phase(s); peak L1 tile arena: {} B of {} B",
        plan.groups.len(),
        plan.solution.peak_l1(),
        soc.mem.l1.capacity
    );

    // 4. Prove the tiled plan computes the same numbers as the un-tiled
    //    network (pure-Rust backend; use `make run-e2e` for PJRT).
    let worst = deployer.validate_numerics(NativeBackend, 7)?;
    println!("numerics: max |tiled - oracle| = {worst:.2e}");

    // 5. Compare against the layer-per-layer baseline.
    let mut base_cfg = DeployConfig::preset("siracusa", Strategy::LayerPerLayer)?;
    base_cfg.double_buffer = false;
    let mut bld = GraphBuilder::new(DType::Int8);
    let x = bld.input("x", &[64, 256]);
    let fc = bld.linear("fc", x, 1024, true);
    let act = bld.act("gelu", ActKind::Gelu, fc);
    let base = Deployer::new(bld.finish(act)?, base_cfg).deploy()?.1;
    let red = report.sim.runtime_reduction_vs(&base.sim);
    println!("FTL vs baseline: {:.1}% runtime reduction", red);
    Ok(())
}
