//! End-to-end driver — the full three-layer stack on the paper's workload.
//!
//! 1. Build the ViT-Base MLP stage (GEMM 768→3072 + bias, GeLU; the
//!    paper's Fig. 3 benchmark) in the IR.
//! 2. Plan it twice (layer-per-layer baseline, FTL) on both SoC variants
//!    and *simulate* — reproducing Fig. 3's four bars and the DMA metric.
//! 3. Execute the FTL *tiled* schedule numerically through the AOT
//!    artifacts on the PJRT CPU client (Layer-1 Pallas kernels inside),
//!    compare tile-by-tile against the un-tiled oracle — proving the
//!    transformation is numerics-preserving end to end.
//! 4. Run the whole-stage Pallas artifacts (fused vs two-kernel pipeline
//!    vs jnp reference) and cross-check the Rust oracle against the jnp
//!    oracle.
//!
//! Run with: `make run-e2e` (builds artifacts first) — results are
//! recorded in EXPERIMENTS.md.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use ftl::config::DeployConfig;
use ftl::coordinator::{experiments, Deployer};
use ftl::runtime::{reference, HostTensor, NativeBackend, PjrtBackend, TileExecutor};
use ftl::tiling::Strategy;

fn main() -> Result<()> {
    let (seq, d, h) = (197usize, 768usize, 3072usize);
    println!("=== FTL end-to-end: ViT-Base MLP stage ({seq}x{d} -> {h}) ===\n");

    // ---- Fig. 3 reproduction (simulation) -------------------------------
    println!("[1/4] Fig. 3 runtime comparison (GVSoC-style simulation)");
    let rows = experiments::fig3(seq, d, h, false)?;
    println!("{}", experiments::fig3_table(&rows));
    let dma = experiments::dma_reduction(seq, d, h, "cluster-only")?;
    println!(
        "DMA data movement: {} B -> {} B ({:.1}% reduction; paper: 47.1%)\n",
        dma.base_bytes, dma.ftl_bytes, dma.byte_reduction_pct
    );

    // ---- Numerics through the artifacts ---------------------------------
    let graph = experiments::vit_mlp_stage(seq, d, h);
    let cfg = DeployConfig::preset("siracusa", Strategy::Ftl)?;
    let deployer = Deployer::new(graph, cfg).with_workload_name("vit-base-stage");
    let plan = deployer.plan()?;
    println!(
        "[2/4] FTL plan: {} fused group(s), peak L1 {} B, {} DMA commands",
        plan.groups.len(),
        plan.solution.peak_l1(),
        plan.schedule.dma_count()
    );

    let artifact_dir = Path::new("artifacts");
    if !artifact_dir.join("manifest.json").exists() {
        bail!("artifacts/manifest.json missing — run `make artifacts` first");
    }

    // Bindings + oracle (pure-Rust reference, mirrors ref.py).
    let graph = deployer.graph();
    let bindings = reference::random_bindings(graph, 2024);
    let oracle_env = reference::run_graph(graph, &bindings)?;
    let out_id = graph.outputs()[0];

    // Tiled execution through PJRT artifacts.
    let backend = PjrtBackend::new(artifact_dir)?;
    let mut exec = TileExecutor::new(backend);
    let env = exec.run(graph, &plan.solution, &bindings)?;
    let diff_pjrt = env[&out_id].max_abs_diff(&oracle_env[&out_id]);
    println!(
        "[3/4] tiled execution via PJRT artifacts: {} tiles, {} kernels, {} PJRT invocations",
        exec.tiles_run,
        exec.kernels_run,
        exec.backend().invocations
    );
    println!("      max |tiled_pjrt - oracle| = {diff_pjrt:.3e}");
    if diff_pjrt > 1e-3 {
        bail!("PJRT tiled execution deviates from oracle by {diff_pjrt}");
    }

    // Same check with the native backend (isolates PJRT vs tiling issues).
    let mut native = TileExecutor::new(NativeBackend);
    let env_native = native.run(graph, &plan.solution, &bindings)?;
    let diff_native = env_native[&out_id].max_abs_diff(&oracle_env[&out_id]);
    println!("      max |tiled_native - oracle| = {diff_native:.3e}");

    // ---- Whole-stage artifacts: baseline vs FTL Pallas variants ----------
    println!("[4/4] whole-stage Pallas artifacts (fused vs two-kernel pipeline)");
    let mut backend = PjrtBackend::new(artifact_dir)?;
    let x = bindings[&graph.tensor_by_name("x").unwrap().0].clone();
    let w1 = bindings[&graph.tensor_by_name("fc1.w").unwrap().0].clone();
    let b1 = bindings[&graph.tensor_by_name("fc1.b").unwrap().0].clone();
    let mut results: HashMap<&str, HostTensor> = HashMap::new();
    for variant in ["ref", "baseline", "ftl"] {
        let key = format!("stage_{variant}_{seq}x{d}x{h}");
        let out = backend
            .run(&key, &[&x, &w1, &b1])
            .with_context(|| format!("running whole-stage artifact {key}"))?;
        results.insert(variant, out);
    }
    let d_base = results["baseline"].max_abs_diff(&results["ref"]);
    let d_ftl = results["ftl"].max_abs_diff(&results["ref"]);
    let d_fuse = results["ftl"].max_abs_diff(&results["baseline"]);
    println!("      |pallas_baseline - jnp_ref| = {d_base:.3e}");
    println!("      |pallas_fused    - jnp_ref| = {d_ftl:.3e}");
    println!("      |pallas_fused - pallas_baseline| = {d_fuse:.3e}");
    if d_base > 1e-2 || d_ftl > 1e-2 {
        bail!("whole-stage Pallas artifacts deviate from the jnp oracle");
    }
    // And the rust-side oracle agrees with the jnp one:
    let d_cross = results["ref"].max_abs_diff(&oracle_env[&out_id]);
    println!("      |jnp_ref - rust_ref| = {d_cross:.3e} (cross-language oracle agreement)");
    if d_cross > 1e-2 {
        bail!("rust and jnp oracles disagree by {d_cross}");
    }

    println!("\nE2E OK: Fig.3 shape reproduced, tiled+fused execution numerics-preserving.");
    Ok(())
}
