//! Vendored, dependency-free stand-in for the `anyhow` crate.
//!
//! This workspace builds fully offline (no crates.io access), so instead
//! of the real `anyhow` this micro-implementation provides exactly the
//! subset the `ftl` crate uses:
//!
//! * [`Error`] — a context-chained error value (`Display` prints the
//!   outermost message; the `{:#}` alternate form prints the whole chain,
//!   matching anyhow's behaviour relied on by `eprintln!("{e:#}")`);
//! * [`Result`] — `Result<T, Error>` alias with a default type parameter;
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the formatting macros;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on both
//!   `Result<T, E>` (for any std error *or* an [`Error`]) and `Option<T>`.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket `From<E>` and the
//! `Context` impls coherent.

use std::convert::Infallible;
use std::fmt;

use self::private::IntoError;

/// A context-chained error value.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// Crate-standard result alias (default error type = [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Error from a displayable message.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap this error with an outer context message (the form used by
    /// `Err(e.context(format!(..)))` call-sites).
    pub fn context(self, c: impl fmt::Display) -> Self {
        Error { msg: c.to_string(), source: Some(Box::new(self)) }
    }

    /// The innermost error message in the chain.
    pub fn root_cause(&self) -> &str {
        let mut cur = self;
        while let Some(s) = cur.source.as_deref() {
            cur = s;
        }
        &cur.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.source.is_some() {
            write!(f, "\n\nCaused by:")?;
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, "\n    {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

/// Any std error converts into [`Error`], capturing its source chain.
/// (Coherent because [`Error`] itself is not a `std::error::Error`.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut msgs = vec![e.to_string()];
        let mut cur: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(c) = cur {
            msgs.push(c.to_string());
            cur = c.source();
        }
        let mut err: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            err = Some(Error { msg: m, source: err.map(Box::new) });
        }
        err.expect("at least one message")
    }
}

mod private {
    /// Sealed conversion used by [`super::Context`]: either a std error or
    /// an [`super::Error`] becomes the inner error of the new context.
    pub trait IntoError {
        fn into_error(self) -> super::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> super::Error {
            super::Error::from(self)
        }
    }

    impl IntoError for super::Error {
        fn into_error(self) -> super::Error {
            self
        }
    }
}

/// Context extension for `Result` and `Option`.
pub trait Context<T, E> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Wrap the error (or `None`) with a lazily-built context message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: private::IntoError> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!("condition failed: {}", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Result::<(), _>::Err(io_err()).context("reading x").unwrap_err();
        assert_eq!(format!("{e}"), "reading x");
        assert_eq!(format!("{e:#}"), "reading x: gone");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn macros_and_option_context() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x > 1, "x too small: {x}");
            if x > 10 {
                bail!("x too big: {}", x);
            }
            let v = Some(x).context("missing")?;
            Ok(v)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(0).unwrap_err()), "x too small: 0");
        assert_eq!(format!("{}", f(11).unwrap_err()), "x too big: 11");
        let none: Option<usize> = None;
        assert_eq!(format!("{}", none.context("absent").unwrap_err()), "absent");
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
    }

    #[test]
    fn question_mark_from_std_error() {
        fn g() -> Result<String> {
            let s = std::fs::read_to_string("/nonexistent/ftl-vendor-anyhow")?;
            Ok(s)
        }
        assert!(g().is_err());
    }

    #[test]
    fn error_context_method() {
        let e = anyhow!("inner").context(format!("outer {}", 1));
        assert_eq!(format!("{e}"), "outer 1");
        assert_eq!(format!("{e:#}"), "outer 1: inner");
    }
}
