//! Bench: L3 hot paths — the §Perf targets.
//!
//! Micro-benchmarks for every stage of the deployment pipeline plus the
//! runtime-side tile machinery. These are the numbers tracked in
//! EXPERIMENTS.md §Perf (before/after each optimisation).
//!
//! `pipeline/solve_graph` runs the production parallel branch-and-bound
//! solver; `pipeline/solve_graph_exhaustive` is the pre-optimisation
//! flat sweep (the B&B's correctness oracle) and
//! `pipeline/solve_graph_threads1` isolates the pruning win from the
//! parallel win. `FTL_BENCH_SMOKE=1` shrinks sampling so CI can execute
//! the harness end-to-end without paying full measurement time.

use std::time::Duration;

use ftl::config::DeployConfig;
use ftl::coordinator::{experiments, Deployer};
use ftl::memory::{AllocRequest, StaticAllocator};
use ftl::runtime::{reference, HostTensor, NativeBackend, TileExecutor};
use ftl::schedule::build_schedule;
use ftl::sim::simulate;
use ftl::tiling::{
    assign_homes, fuse_groups, solve_graph, solve_graph_in, solve_group_exhaustive, FusionPolicy, HomesPolicy,
    SolverOptions, SolverPool, Strategy,
};
use ftl::util::bench::bench;
use ftl::util::prop::Rng;

fn main() {
    let smoke = std::env::var("FTL_BENCH_SMOKE").is_ok();
    let t = |secs: u64| if smoke { Duration::from_millis(40) } else { Duration::from_secs(secs) };

    let graph = experiments::vit_mlp_stage(197, 768, 3072);
    let soc = ftl::soc::siracusa_reduced();
    let groups = fuse_groups(&graph, Strategy::Ftl, FusionPolicy::default());
    let (groups, sol) = solve_graph(&graph, &soc, groups.clone(), &SolverOptions::default(), false).unwrap();
    let sched = build_schedule(&graph, &soc, &sol).unwrap();
    println!("=== L3 hot paths (EXPERIMENTS.md §Perf) ===\n");

    bench("pipeline/fuse_groups", t(1), || {
        let _ = fuse_groups(&graph, Strategy::Ftl, FusionPolicy::default());
    });
    bench("pipeline/assign_homes", t(1), || {
        let _ = assign_homes(&graph, &groups, &soc);
    });
    bench("pipeline/solve_graph", t(3), || {
        let g = fuse_groups(&graph, Strategy::Ftl, FusionPolicy::default());
        let _ = solve_graph(&graph, &soc, g, &SolverOptions::default(), false).unwrap();
    });
    // Pruning-only win (no parallel fan-out), and the pre-B&B baseline.
    let pool1 = SolverPool::new(1);
    bench("pipeline/solve_graph_threads1", t(3), || {
        let g = fuse_groups(&graph, Strategy::Ftl, FusionPolicy::default());
        let _ = solve_graph_in(
            &graph,
            &soc,
            g,
            &SolverOptions::default(),
            false,
            HomesPolicy::Resident,
            &pool1,
        )
        .unwrap();
    });
    bench("pipeline/solve_graph_exhaustive", t(3), || {
        let g = fuse_groups(&graph, Strategy::Ftl, FusionPolicy::default());
        let homes = assign_homes(&graph, &g, &soc);
        for gr in &g {
            let _ = solve_group_exhaustive(&graph, &soc, gr, &homes, &SolverOptions::default(), false).unwrap();
        }
    });
    bench("pipeline/build_schedule", t(2), || {
        let _ = build_schedule(&graph, &soc, &sol).unwrap();
    });
    bench("pipeline/simulate", t(2), || {
        let _ = simulate(&sched, &soc).unwrap();
    });
    bench("pipeline/deploy_end_to_end", t(3), || {
        let g = experiments::vit_mlp_stage(197, 768, 3072);
        let cfg = DeployConfig::preset("siracusa", Strategy::Ftl).unwrap();
        let _ = Deployer::new(g, cfg).deploy().unwrap();
    });

    // Static allocator under load (many overlapping lifetimes).
    let mut rng = Rng::new(42);
    let reqs: Vec<AllocRequest> = (0..512)
        .map(|i| {
            let birth = rng.range(0, 200);
            AllocRequest::new(i, rng.range(64, 8192), birth, birth + rng.range(0, 40))
        })
        .collect();
    let alloc = StaticAllocator::new(16 << 20, 8);
    bench("memory/static_alloc_512", t(2), || {
        let _ = alloc.solve(&reqs).unwrap();
    });

    // Runtime tile machinery (native backend).
    let small = experiments::vit_mlp_stage(64, 96, 192);
    let cfg = DeployConfig::preset("siracusa", Strategy::Ftl).unwrap();
    let dep = Deployer::new(small, cfg);
    let plan = dep.plan().unwrap();
    let bindings = reference::random_bindings(dep.graph(), 1);
    bench("runtime/tile_executor_native_64x96x192", t(2), || {
        let mut exec = TileExecutor::new(NativeBackend);
        let _ = exec.run(dep.graph(), &plan.solution, &bindings).unwrap();
    });

    // Gather/scatter micro-cost.
    let big = HostTensor::random(&[1024, 1024], 3);
    bench("runtime/gather_128x128", t(1), || {
        let _ = big.gather(&[512, 512], &[128, 128]);
    });

    // Search-space accounting over everything the global pool solved
    // above: pruning, not scoring, must carry the search.
    let s = SolverPool::global().stats();
    println!(
        "\nsolver counters (global pool): solves={} space={} scored={} capacity_pruned={} \
         bound_pruned={} subtrees_cut={}",
        s.solves, s.space, s.scored, s.capacity_pruned, s.bound_pruned, s.subtrees_cut
    );
}
