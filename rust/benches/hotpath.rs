//! Bench: L3 hot paths — the §Perf targets.
//!
//! Micro-benchmarks for every stage of the deployment pipeline plus the
//! runtime-side tile machinery. These are the numbers tracked in
//! EXPERIMENTS.md §Perf (before/after each optimisation).

use std::time::Duration;

use ftl::config::DeployConfig;
use ftl::coordinator::{experiments, Deployer};
use ftl::memory::{AllocRequest, StaticAllocator};
use ftl::runtime::{reference, HostTensor, NativeBackend, TileExecutor};
use ftl::schedule::build_schedule;
use ftl::sim::simulate;
use ftl::tiling::{assign_homes, fuse_groups, solve_graph, FusionPolicy, SolverOptions, Strategy};
use ftl::util::bench::bench;
use ftl::util::prop::Rng;

fn main() {
    let graph = experiments::vit_mlp_stage(197, 768, 3072);
    let soc = ftl::soc::siracusa_reduced();
    let groups = fuse_groups(&graph, Strategy::Ftl, FusionPolicy::default());
    let (groups, sol) = solve_graph(&graph, &soc, groups.clone(), &SolverOptions::default(), false).unwrap();
    let sched = build_schedule(&graph, &soc, &sol).unwrap();
    println!("=== L3 hot paths (EXPERIMENTS.md §Perf) ===\n");

    bench("pipeline/fuse_groups", Duration::from_secs(1), || {
        let _ = fuse_groups(&graph, Strategy::Ftl, FusionPolicy::default());
    });
    bench("pipeline/assign_homes", Duration::from_secs(1), || {
        let _ = assign_homes(&graph, &groups, &soc);
    });
    bench("pipeline/solve_graph", Duration::from_secs(3), || {
        let g = fuse_groups(&graph, Strategy::Ftl, FusionPolicy::default());
        let _ = solve_graph(&graph, &soc, g, &SolverOptions::default(), false).unwrap();
    });
    bench("pipeline/build_schedule", Duration::from_secs(2), || {
        let _ = build_schedule(&graph, &soc, &sol).unwrap();
    });
    bench("pipeline/simulate", Duration::from_secs(2), || {
        let _ = simulate(&sched, &soc).unwrap();
    });
    bench("pipeline/deploy_end_to_end", Duration::from_secs(3), || {
        let g = experiments::vit_mlp_stage(197, 768, 3072);
        let cfg = DeployConfig::preset("siracusa", Strategy::Ftl).unwrap();
        let _ = Deployer::new(g, cfg).deploy().unwrap();
    });

    // Static allocator under load (many overlapping lifetimes).
    let mut rng = Rng::new(42);
    let reqs: Vec<AllocRequest> = (0..512)
        .map(|i| {
            let birth = rng.range(0, 200);
            AllocRequest::new(i, rng.range(64, 8192), birth, birth + rng.range(0, 40))
        })
        .collect();
    let alloc = StaticAllocator::new(16 << 20, 8);
    bench("memory/static_alloc_512", Duration::from_secs(2), || {
        let _ = alloc.solve(&reqs).unwrap();
    });

    // Runtime tile machinery (native backend).
    let small = experiments::vit_mlp_stage(64, 96, 192);
    let cfg = DeployConfig::preset("siracusa", Strategy::Ftl).unwrap();
    let dep = Deployer::new(small, cfg);
    let plan = dep.plan().unwrap();
    let bindings = reference::random_bindings(dep.graph(), 1);
    bench("runtime/tile_executor_native_64x96x192", Duration::from_secs(2), || {
        let mut exec = TileExecutor::new(NativeBackend);
        let _ = exec.run(dep.graph(), &plan.solution, &bindings).unwrap();
    });

    // Gather/scatter micro-cost.
    let big = HostTensor::random(&[1024, 1024], 3);
    bench("runtime/gather_128x128", Duration::from_secs(1), || {
        let _ = big.gather(&[512, 512], &[128, 128]);
    });
}
