//! Bench: **Fig. 3** — MLP-stage runtime, baseline vs FTL, cluster-only
//! and cluster+NPU. Prints the paper's four bars (simulated cycles) plus
//! the wall-clock cost of the deployment pipeline itself.
//!
//! Paper reference: −28.8 % (cluster), −60.1 % (cluster+NPU).

use std::time::Duration;

use ftl::coordinator::experiments;
use ftl::util::bench::bench;

fn main() {
    let (seq, d, h) = (197, 768, 3072);
    println!("=== Fig. 3: ViT MLP stage ({seq}x{d}->{h}) ===\n");
    let rows = experiments::fig3(seq, d, h, false).expect("fig3");
    println!("{}", experiments::fig3_table(&rows));

    let cluster = rows.iter().find(|r| r.config == "cluster" && r.strategy == "ftl").unwrap();
    let npu = rows.iter().find(|r| r.config == "cluster+npu" && r.strategy == "ftl").unwrap();
    println!("paper:    cluster -28.8%   cluster+npu -60.1%");
    println!("measured: cluster -{:.1}%   cluster+npu -{:.1}%\n", cluster.reduction_pct, npu.reduction_pct);

    // Deployment-pipeline wall clock (solver + allocator + schedule + sim).
    println!("--- deployment pipeline wall-clock ---");
    bench("fig3/full_pipeline_4way", Duration::from_secs(3), || {
        let _ = experiments::fig3(seq, d, h, false).unwrap();
    });
    bench("fig3/single_deploy_ftl_npu", Duration::from_secs(2), || {
        let graph = experiments::vit_mlp_stage(seq, d, h);
        let cfg = ftl::config::DeployConfig::preset("siracusa", ftl::tiling::Strategy::Ftl).unwrap();
        let _ = ftl::coordinator::Deployer::new(graph, cfg).deploy().unwrap();
    });
}
