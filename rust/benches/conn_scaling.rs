//! Bench: front-door connection scaling — many concurrent connections,
//! many in-flight ids per connection, over real TCP.
//!
//! Spins the async front door ([`ftl::serve::Frontend`]) on a loopback
//! port and drives it with a fleet of client connections in two phases:
//!
//! * **Warm phase** — every connection pipelines a burst of id'd v1
//!   `DEPLOY` frames for one pre-warmed fingerprint and then reads its
//!   terminal frames back, asserting that exactly the sent id set comes
//!   back (each id once). This measures the multiplexed front door's
//!   warm-path throughput (`warm_rps`) with *all* connections open at
//!   once — the event loop, not a thread per connection, carries them.
//! * **Cold phase** — a subset of the connections each submit one
//!   *distinct* cold solve (`stage-<seq>x24x48`) immediately followed
//!   by a warm request on a second id. The warm terminal must overtake
//!   the cold one (out-of-order completion on one connection, counted
//!   in `out_of_order`), and the cold stream must arrive as
//!   `plan` → `sim`* → `done`. This measures end-to-end cold
//!   solve throughput (`cold_rps`) under concurrent load.
//!
//! Writes `BENCH_conn_scaling.json` and prints a greppable
//! `conn_scaling conns=… warm_rps=… cold_rps=…` line for CI.
//! `FTL_BENCH_SMOKE=1` shrinks the fleet so CI can execute the harness
//! end-to-end; the full run holds ≥ 1000 concurrent connections.

use std::collections::HashSet;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use ftl::config::DeployConfig;
use ftl::coordinator::experiments;
use ftl::serve::{
    AdmissionPolicy, BatchOptions, BatchScheduler, Frontend, FrontendOptions, PlanService,
    ServeOptions, TraceOptions,
};
use ftl::tiling::Strategy;
use ftl::util::json::Json;

fn smoke() -> bool {
    std::env::var("FTL_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn read_json(reader: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    let n = reader.read_line(&mut line).expect("read reply");
    assert!(n > 0, "server closed the connection mid-bench");
    ftl::util::json::parse(line.trim()).expect("parse reply")
}

fn main() {
    let smoke = smoke();
    // Fleet sizing: the full run sustains >= 1000 concurrent
    // connections; smoke keeps CI fast on small runners.
    let conns = if smoke { 64 } else { 1000 };
    let warm_per_conn = if smoke { 4u64 } else { 8u64 };
    let cold_conns = if smoke { 8 } else { 128 };
    let threads = if smoke { 8 } else { 16 };

    println!("=== front door: connection scaling ({conns} conns, {warm_per_conn} warm ids each) ===\n");

    let service = Arc::new(PlanService::new(ServeOptions {
        cache_capacity: 32,
        sim_cache_capacity: 64,
        cache_shards: 4,
        workers: 2,
        ..ServeOptions::default()
    }));
    let scheduler = Arc::new(BatchScheduler::new(
        service,
        BatchOptions {
            queue_capacity: 4096,
            batch_window: Duration::ZERO,
            max_batch: 64,
            policy: AdmissionPolicy::Block,
            trace: TraceOptions::disabled(),
            ..BatchOptions::default()
        },
    ));
    // Pre-warm the shared fingerprint in process so every warm-phase
    // frame takes the fast path.
    let warm_graph = experiments::vit_mlp_stage(16, 24, 48);
    let warm_cfg = DeployConfig::preset("cluster-only", Strategy::Ftl).unwrap();
    let outcome = scheduler.deploy("prewarm", warm_graph, warm_cfg).unwrap();
    assert_eq!(outcome.kind(), "OK", "pre-warm deploy must be served");

    let door = Frontend::new(scheduler, FrontendOptions::default())
        .serve(TcpListener::bind("127.0.0.1:0").expect("bind bench port"))
        .expect("start front door");
    let addr = door.addr();

    let mut fleet: Vec<TcpStream> = (0..conns)
        .map(|i| {
            let stream = TcpStream::connect(addr).unwrap_or_else(|e| panic!("connect #{i}: {e}"));
            stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
            stream
        })
        .collect();

    // ---- Warm phase: pipelined id'd frames on every connection. ----
    let chunk = fleet.len().div_ceil(threads);
    let t_warm = Instant::now();
    let mut warm_replies = 0u64;
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for part in fleet.chunks_mut(chunk) {
            handles.push(s.spawn(move || -> u64 {
                let mut replies = 0u64;
                for conn in part.iter_mut() {
                    let mut payload = String::new();
                    for k in 0..warm_per_conn {
                        payload.push_str(&format!(
                            "FTL1 {} DEPLOY stage-16x24x48 cluster-only ftl\n",
                            100 + k
                        ));
                    }
                    conn.write_all(payload.as_bytes()).expect("write warm burst");
                    let mut reader = BufReader::new(conn.try_clone().expect("clone conn"));
                    let mut seen: HashSet<u64> = HashSet::new();
                    while (seen.len() as u64) < warm_per_conn {
                        let j = read_json(&mut reader);
                        assert_eq!(j.get("event").unwrap().as_str().unwrap(), "done", "warm reply: {j}");
                        let id = j.get("id").unwrap().as_u64().unwrap();
                        assert!((100..100 + warm_per_conn).contains(&id), "unexpected id {id}");
                        assert!(seen.insert(id), "duplicate terminal frame for id {id}");
                        replies += 1;
                    }
                }
                replies
            }));
        }
        for h in handles {
            warm_replies += h.join().expect("warm client thread panicked");
        }
    });
    let warm_elapsed = t_warm.elapsed();
    let warm_rps = warm_replies as f64 / warm_elapsed.as_secs_f64().max(1e-9);
    assert_eq!(warm_replies, conns as u64 * warm_per_conn, "every sent id must come back exactly once");
    assert!(
        door.counters().open() >= conns as u64,
        "the loop must hold all {conns} connections open (got {})",
        door.counters().open()
    );
    println!(
        "warm: {warm_replies} replies over {conns} conns in {warm_elapsed:.2?} ({warm_rps:.0} rps)"
    );

    // ---- Cold phase: distinct cold solve + warm overtake per conn. ----
    let cold_chunk = cold_conns.div_ceil(threads).max(1);
    let t_cold = Instant::now();
    let (mut cold_done, mut out_of_order, mut sim_events) = (0u64, 0u64, 0u64);
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (part_idx, part) in fleet[..cold_conns].chunks_mut(cold_chunk).enumerate() {
            handles.push(s.spawn(move || -> (u64, u64, u64) {
                let (mut done, mut ooo, mut sims) = (0u64, 0u64, 0u64);
                for (i, conn) in part.iter_mut().enumerate() {
                    // Distinct per connection: always a fresh fingerprint.
                    let seq = 24 + 8 * (part_idx * cold_chunk + i);
                    conn.write_all(
                        format!(
                            "FTL1 1 DEPLOY stage-{seq}x24x48 cluster-only ftl\n\
                             FTL1 2 DEPLOY stage-16x24x48 cluster-only ftl\n"
                        )
                        .as_bytes(),
                    )
                    .expect("write cold pair");
                    let mut reader = BufReader::new(conn.try_clone().expect("clone conn"));
                    let mut terminals: Vec<u64> = Vec::new();
                    let mut saw_plan = false;
                    while terminals.len() < 2 {
                        let j = read_json(&mut reader);
                        let id = j.get("id").unwrap().as_u64().unwrap();
                        match j.get("event").unwrap().as_str().unwrap() {
                            "done" => terminals.push(id),
                            "plan" => {
                                assert_eq!(id, 1, "only the cold deploy streams partials");
                                assert!(!terminals.contains(&1), "plan must precede done");
                                saw_plan = true;
                            }
                            "sim" => {
                                assert_eq!(id, 1, "only the cold deploy streams partials");
                                sims += 1;
                            }
                            other => panic!("unexpected event '{other}': {j}"),
                        }
                    }
                    assert!(saw_plan, "cold deploy must stream its plan event");
                    assert!(terminals.contains(&1) && terminals.contains(&2), "both ids must finish");
                    if terminals == [2, 1] {
                        ooo += 1;
                    }
                    done += 1;
                }
                (done, ooo, sims)
            }));
        }
        for h in handles {
            let (done, ooo, sims) = h.join().expect("cold client thread panicked");
            cold_done += done;
            out_of_order += ooo;
            sim_events += sims;
        }
    });
    let cold_elapsed = t_cold.elapsed();
    let cold_rps = cold_done as f64 / cold_elapsed.as_secs_f64().max(1e-9);
    assert_eq!(cold_done, cold_conns as u64, "every cold connection must finish its pair");
    assert!(
        out_of_order == cold_done,
        "the warm id must overtake the cold solve on every connection ({out_of_order}/{cold_done})"
    );
    assert!(sim_events >= cold_done, "every cold solve must stream per-phase sim events");
    println!(
        "cold: {cold_done} distinct solves (+{cold_done} warm overtakes) in {cold_elapsed:.2?} \
         ({cold_rps:.0} solves/s, {sim_events} sim events)"
    );

    drop(fleet);
    let counters = door.counters();
    let out = Json::obj(vec![
        ("name", Json::str("conn_scaling")),
        ("conns", Json::Num(conns as f64)),
        ("warm_requests", Json::Num(warm_replies as f64)),
        ("warm_rps", Json::Num(warm_rps)),
        ("cold_solves", Json::Num(cold_done as f64)),
        ("cold_rps", Json::Num(cold_rps)),
        ("out_of_order", Json::Num(out_of_order as f64)),
        ("sim_events", Json::Num(sim_events as f64)),
        ("frames_in", Json::Num(counters.frames_in.get() as f64)),
        ("frames_out", Json::Num(counters.frames_out.get() as f64)),
        ("protocol_errors", Json::Num(counters.protocol_errors.get() as f64)),
    ]);
    std::fs::write("BENCH_conn_scaling.json", format!("{}\n", out.pretty())).unwrap();
    println!(
        "conn_scaling conns={conns} warm_rps={warm_rps:.0} cold_rps={cold_rps:.0} out_of_order={out_of_order}"
    );
    println!("wrote BENCH_conn_scaling.json");
    door.join();
}
