//! Bench: **Ext-B** — double-buffering ablation.
//!
//! The paper notes: *"If double-buffering is used, FTL speeds up
//! execution only if the kernel runtime is less than the DMA's runtime.
//! As reported in Fig 3, this is the case when using the cluster and the
//! NPU."* This bench quantifies that: with double buffering the baseline
//! hides most DMA behind the (slow) cluster GEMM, so FTL's win shrinks on
//! cluster-only but persists on the DMA-bound NPU configuration.

use ftl::coordinator::experiments;
use ftl::metrics::Table;

fn main() {
    let (seq, d, h) = (197, 768, 3072);
    println!("=== Ext-B: double-buffering ablation (ViT MLP stage) ===\n");
    let mut t = Table::new(&[
        "soc",
        "base 1-buf",
        "ftl 1-buf",
        "red 1-buf",
        "base 2-buf",
        "ftl 2-buf",
        "red 2-buf",
    ]);
    for preset in ["cluster-only", "siracusa"] {
        let (b1, f1, b2, f2) = experiments::dbuf_ablation(seq, d, h, preset).expect("ablation");
        let red = |b: u64, f: u64| format!("-{:.1}%", 100.0 * (b as f64 - f as f64) / b as f64);
        t.row(&[
            preset.to_string(),
            b1.to_string(),
            f1.to_string(),
            red(b1, f1),
            b2.to_string(),
            f2.to_string(),
            red(b2, f2),
        ]);
    }
    println!("{}", t.render());
    println!("expected shape: double buffering helps both strategies; FTL's relative win");
    println!("is larger where phases are DMA-bound (NPU config) — the paper's observation.");
}
