//! Bench: **Ext-E** — L2 home-assignment policy ablation.
//!
//! `resident` keeps every materialised tensor in L2 for the whole
//! inference (the calibrated default); `lifetime` is Deeploy-style
//! lifetime-interval allocation (activations share L2 slots when their
//! live ranges are disjoint; weights stay resident). The ablation shows
//! the paper's overflow mechanism is *robust* to the smarter allocator on
//! the ViT-Base stage — the intermediate's live range overlaps the
//! resident weights, so it still spills — while the lifetime policy
//! shrinks the spill window on multi-layer graphs.

use ftl::config::DeployConfig;
use ftl::coordinator::{experiments, Deployer};
use ftl::ir::builder::{deep_mlp, vit_mlp};
use ftl::ir::DType;
use ftl::metrics::Table;
use ftl::tiling::{HomesPolicy, Strategy};

fn run(graph: ftl::ir::Graph, strategy: Strategy, homes: HomesPolicy) -> (u64, u64) {
    let mut cfg = DeployConfig::preset("cluster-only", strategy).unwrap();
    cfg.homes = homes;
    let (_, report) = Deployer::new(graph, cfg).deploy().unwrap();
    (report.sim.total_cycles, report.sim.dma.total_bytes())
}

fn main() {
    println!("=== Ext-E: L2 home-assignment policy (resident vs lifetime) ===\n");
    for (name, mk) in [
        ("vit-base-stage", 0),
        ("vit-base-mlp", 1),
        // 4-layer 768-wide MLP over 512 tokens: resident packing
        // overflows L2 (weights 2.3 MiB + 6 activations x 384 KiB) but
        // lifetime packing keeps every activation on-chip (only ~2 live
        // at once) — the policies diverge here.
        ("deep-mlp-512x768x4", 2),
    ] {
        let graph = || match mk {
            0 => experiments::vit_mlp_stage(197, 768, 3072),
            1 => vit_mlp(197, 768, 3072, DType::Int8),
            _ => deep_mlp(512, 768, 4, DType::Int8),
        };
        println!("--- {name} ---");
        let mut t = Table::new(&["policy", "strategy", "cycles", "dma bytes", "ftl reduction"]);
        for homes in [HomesPolicy::Resident, HomesPolicy::Lifetime] {
            let (bc, bb) = run(graph(), Strategy::LayerPerLayer, homes);
            let (fc, fb) = run(graph(), Strategy::Ftl, homes);
            let label = match homes {
                HomesPolicy::Resident => "resident",
                HomesPolicy::Lifetime => "lifetime",
            };
            t.row(&[label.into(), "baseline".into(), bc.to_string(), bb.to_string(), "—".into()]);
            t.row(&[
                label.into(),
                "ftl".into(),
                fc.to_string(),
                fb.to_string(),
                format!("-{:.1}%", 100.0 * (bc as f64 - fc as f64) / bc as f64),
            ]);
        }
        println!("{}", t.render());
    }
    println!("expected: on the stage, FTL's win survives the lifetime allocator (the");
    println!("intermediate still overlaps the resident weights); on deeper graphs the");
    println!("lifetime policy lowers baseline DMA by keeping more activations in L2.");
}
