//! Bench: **Ext-A** — hidden-dimension sweep. Shows the L2-overflow
//! crossover: FTL's benefit jumps exactly where the intermediate tensor
//! (seq × hidden) stops fitting in L2 and the baseline starts paying the
//! L3 round trip (the paper's mechanism, swept over the axis).

use ftl::coordinator::experiments;
use ftl::metrics::Table;
use ftl::soc::siracusa_reduced;

fn main() {
    let (seq, d) = (197, 768);
    let hs = [256, 512, 1024, 1536, 2048, 2560, 3072, 4096, 6144, 8192];
    let soc = siracusa_reduced();
    println!("=== Ext-A: hidden-dim sweep (seq={seq}, d={d}) ===");
    println!(
        "L2 = {} B; baseline resident set grows with hidden dim; FTL never materialises the intermediate\n",
        soc.mem.l2.capacity
    );

    for preset in ["cluster-only", "siracusa"] {
        println!("--- {preset} ---");
        let rows = experiments::hidden_sweep(seq, d, &hs, preset).expect("sweep");
        let mut t = Table::new(&["hidden", "interm. KiB", "baseline cyc", "ftl cyc", "reduction"]);
        for (h, base, ftl, red) in rows {
            t.row(&[
                h.to_string(),
                format!("{:.0}", (seq * h) as f64 / 1024.0),
                base.to_string(),
                ftl.to_string(),
                format!("{:.1}%", -red),
            ]);
        }
        println!("{}", t.render());
    }
}
