//! Bench: the paper's inline **−47.1 % DMA** metric — transfer commands
//! and payload bytes, baseline vs FTL, on both SoC variants, plus a
//! per-channel breakdown showing where the savings come from (the L3
//! round trip of the spilled intermediate).

use ftl::config::DeployConfig;
use ftl::coordinator::{experiments, Deployer};
use ftl::memory::Level;
use ftl::metrics::Table;
use ftl::tiling::Strategy;

fn main() {
    let (seq, d, h) = (197, 768, 3072);
    println!("=== DMA volume: ViT MLP stage ({seq}x{d}->{h}) — paper: -47.1% ===\n");

    for soc in ["cluster-only", "siracusa"] {
        println!("--- {soc} ---");
        let mut t = Table::new(&["strategy", "commands", "bytes", "L2-ch bytes", "L3-ch bytes", "in", "out"]);
        let mut base_bytes = 0u64;
        for strategy in [Strategy::LayerPerLayer, Strategy::Ftl] {
            let graph = experiments::vit_mlp_stage(seq, d, h);
            let cfg = DeployConfig::preset(soc, strategy).unwrap();
            let (_, report) = Deployer::new(graph, cfg).deploy().unwrap();
            let dma = &report.sim.dma;
            if strategy == Strategy::LayerPerLayer {
                base_bytes = dma.total_bytes();
            }
            t.row(&[
                strategy.name().to_string(),
                dma.total_transfers().to_string(),
                dma.total_bytes().to_string(),
                dma.bytes_at(Level::L2).to_string(),
                dma.bytes_at(Level::L3).to_string(),
                dma.bytes_in.to_string(),
                dma.bytes_out.to_string(),
            ]);
            if strategy == Strategy::Ftl {
                let red = 100.0 * (base_bytes as f64 - dma.total_bytes() as f64) / base_bytes as f64;
                println!("{}", t.render());
                println!("byte reduction: -{red:.1}% (paper: -47.1%)\n");
            }
        }
    }

    let r = experiments::dma_reduction(seq, d, h, "cluster-only").unwrap();
    println!(
        "summary: commands {} -> {} (-{:.1}%), bytes {} -> {} (-{:.1}%)",
        r.base_transfers,
        r.ftl_transfers,
        r.transfer_reduction_pct,
        r.base_bytes,
        r.ftl_bytes,
        r.byte_reduction_pct
    );
}
