//! Bench: the batching scheduler — admission + fan-out overhead vs the
//! work it saves.
//!
//! Three numbers tell the story:
//! * `warm_batched_deploy` — a fully warm request through the whole
//!   admit → batch → hit → hit → reply path (queue + window overhead on
//!   top of two cache hits);
//! * `fanout_8x_identical` — 8 concurrent identical cold requests
//!   through a fresh scheduler: one solve + one simulation total, the
//!   rest fan out (per-iteration cost tracks ~1 solve, not 8);
//! * `sim_rerun` vs `sim_cache_hit` — what the sim-report cache saves on
//!   a warm plan (the engine run the old serve layer paid per request).
//!
//! `FTL_BENCH_SMOKE=1` shrinks the workload and measurement windows so
//! CI can execute the harness end-to-end.

use std::sync::Arc;
use std::time::Duration;

use ftl::config::DeployConfig;
use ftl::coordinator::experiments;
use ftl::ir::Graph;
use ftl::serve::{AdmissionPolicy, BatchOptions, BatchScheduler, PlanService, ServeOptions};
use ftl::tiling::Strategy;
use ftl::util::bench::bench;

fn smoke() -> bool {
    std::env::var("FTL_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn main() {
    let smoke = smoke();
    let graph: Graph = if smoke {
        experiments::vit_mlp_stage(64, 96, 192)
    } else {
        experiments::vit_mlp_stage(197, 768, 3072)
    };
    let secs = |n: u64| if smoke { Duration::from_millis(150) } else { Duration::from_secs(n) };
    let cfg = DeployConfig::preset("siracusa", Strategy::Ftl).unwrap();
    let opts = ServeOptions { cache_capacity: 32, cache_shards: 4, workers: 1, ..ServeOptions::default() };
    // Zero window for the latency numbers: batching pays off under
    // concurrency, and the fan-out bench opens its own window.
    let fast = BatchOptions {
        queue_capacity: 64,
        batch_window: Duration::ZERO,
        max_batch: 64,
        policy: AdmissionPolicy::Block,
        ..BatchOptions::default()
    };

    println!("=== serve layer: batching scheduler + sim-report cache ===\n");

    // Warm path: both caches hot; measures pure scheduler overhead.
    let warm_sched = BatchScheduler::new(Arc::new(PlanService::new(opts)), fast.clone());
    warm_sched.deploy("warmup", graph.clone(), cfg.clone()).unwrap();
    let warm = bench("batch/warm_batched_deploy", secs(2), || {
        let outcome = warm_sched.deploy("warm", graph.clone(), cfg.clone()).unwrap();
        let reply = outcome.served().expect("warm request must be served");
        assert!(reply.cached && reply.sim_cached);
    });

    // Fan-out: 8 concurrent identical cold requests, one solve + one sim.
    let window = BatchOptions { batch_window: Duration::from_millis(5), ..fast };
    let fanout = bench("batch/fanout_8x_identical_cold", secs(3), || {
        let service = Arc::new(PlanService::new(opts));
        let sched = Arc::new(BatchScheduler::new(service.clone(), window.clone()));
        let mut handles = Vec::new();
        for i in 0..8 {
            let sched = sched.clone();
            let graph = graph.clone();
            let cfg = cfg.clone();
            handles.push(std::thread::spawn(move || {
                sched.deploy(&format!("r{i}"), graph, cfg).unwrap().served().expect("served")
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let stats = service.stats();
        assert_eq!(stats.solves, 1, "fan-out must coalesce to one solve");
        assert_eq!(stats.sims, 1, "fan-out must coalesce to one simulation");
    });

    // Sim-report cache: engine run vs cache hit on an already-hot plan.
    let svc = PlanService::new(opts);
    let plan = svc.plan(&graph, &cfg).unwrap().plan;
    let rerun = bench("batch/sim_rerun(engine)", secs(2), || {
        let sim = plan.simulate(&cfg).unwrap();
        assert!(sim.total_cycles > 0);
    });
    svc.deploy("seed", &graph, &cfg).unwrap();
    let hit = bench("batch/sim_cache_hit", secs(2), || {
        let reply = svc.deploy("hit", &graph, &cfg).unwrap();
        assert!(reply.sim_cached);
    });

    let sim_speedup = rerun.median.as_nanos() as f64 / hit.median.as_nanos().max(1) as f64;
    let amortised = fanout.median.as_nanos() as f64 / 8.0;
    println!("\nwarm batched deploy (queue + 2 cache hits): {:?}", warm.median);
    println!("fan-out 8x cold: {:?} total (~{:.0} ns/request amortised)", fanout.median, amortised);
    println!("sim-cache speedup vs engine re-run: {sim_speedup:.1}x");
    assert!(
        sim_speedup >= 2.0,
        "sim-cache hit must clearly beat an engine re-run (got {sim_speedup:.2}x)"
    );
}
