//! Bench: **Ext-C** — solver ablations.
//!
//! (a) performance-constraint class on/off (paper step ②, third class);
//! (b) candidate-budget sweep (solve quality vs solve time);
//! (c) solver wall-clock per fusion-group size.

use std::time::Duration;

use ftl::config::DeployConfig;
use ftl::coordinator::{experiments, Deployer};
use ftl::ir::builder::deep_mlp;
use ftl::ir::DType;
use ftl::metrics::Table;
use ftl::tiling::{fuse_groups, solve_graph, FusionPolicy, SolverOptions, Strategy};
use ftl::util::bench::bench;

fn main() {
    let (seq, d, h) = (197, 768, 3072);
    println!("=== Ext-C: solver ablations ===\n");

    // (a) performance constraints on/off
    let (with, without) = experiments::perf_constraint_ablation(seq, d, h, "siracusa").expect("ablation");
    println!("(a) performance-constraint class (step 2, third class):");
    println!("    with:    {with} cycles");
    println!("    without: {without} cycles");
    println!(
        "    delta:   {:+.2}% (constraints steer tiles to SIMD/PE-width multiples)\n",
        100.0 * (without as f64 - with as f64) / with as f64
    );

    // (b) candidate budget sweep
    println!("(b) candidate budget (solve quality vs. effort):");
    let mut t = Table::new(&["max_candidates", "est. cycles", "sim cycles"]);
    for cands in [4, 8, 16, 32, 64, 128] {
        let graph = experiments::vit_mlp_stage(seq, d, h);
        let mut cfg = DeployConfig::preset("siracusa", Strategy::Ftl).unwrap();
        cfg.solver.max_candidates = cands;
        let dep = Deployer::new(graph, cfg);
        let (plan, report) = dep.deploy().unwrap();
        t.row(&[
            cands.to_string(),
            plan.solution.estimated_cycles().to_string(),
            report.sim.total_cycles.to_string(),
        ]);
    }
    println!("{}", t.render());

    // (c) solver wall-clock
    println!("(c) solver wall-clock:");
    let graph = experiments::vit_mlp_stage(seq, d, h);
    let soc = ftl::soc::siracusa_reduced();
    bench("solver/stage_ftl_group", Duration::from_secs(2), || {
        let groups = fuse_groups(&graph, Strategy::Ftl, FusionPolicy::default());
        let _ = solve_graph(&graph, &soc, groups, &SolverOptions::default(), false).unwrap();
    });
    let deep = deep_mlp(128, 512, 6, DType::Int8);
    bench("solver/deep_mlp_12_nodes", Duration::from_secs(2), || {
        let groups = fuse_groups(&deep, Strategy::Ftl, FusionPolicy::default());
        let _ = solve_graph(&deep, &soc, groups, &SolverOptions::default(), false).unwrap();
    });
}
