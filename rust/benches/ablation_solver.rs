//! Bench: **Ext-C** — solver ablations.
//!
//! (a) performance-constraint class on/off (paper step ②, third class);
//! (b) candidate-budget sweep (solve quality vs solve time);
//! (c) solver wall-clock per fusion-group size;
//! (d) branch-and-bound vs exhaustive sweep — wall-clock and exact
//!     search-space accounting (scored vs pruned points), single- and
//!     multi-threaded, on the paper's ViT MLP stage.

use std::time::Duration;

use ftl::config::DeployConfig;
use ftl::coordinator::{experiments, Deployer};
use ftl::ir::builder::deep_mlp;
use ftl::ir::DType;
use ftl::metrics::Table;
use ftl::tiling::{
    assign_homes, fuse_groups, solve_graph, solve_group_exhaustive, solve_group_in, FusionPolicy, SolverOptions,
    SolverPool, Strategy,
};
use ftl::util::bench::bench;

fn main() {
    let smoke = std::env::var("FTL_BENCH_SMOKE").is_ok();
    let t = |secs: u64| if smoke { Duration::from_millis(40) } else { Duration::from_secs(secs) };
    let (seq, d, h) = (197, 768, 3072);
    println!("=== Ext-C: solver ablations ===\n");

    // (a) performance constraints on/off
    let (with, without) = experiments::perf_constraint_ablation(seq, d, h, "siracusa").expect("ablation");
    println!("(a) performance-constraint class (step 2, third class):");
    println!("    with:    {with} cycles");
    println!("    without: {without} cycles");
    println!(
        "    delta:   {:+.2}% (constraints steer tiles to SIMD/PE-width multiples)\n",
        100.0 * (without as f64 - with as f64) / with as f64
    );

    // (b) candidate budget sweep
    println!("(b) candidate budget (solve quality vs. effort):");
    let mut budget_table = Table::new(&["max_candidates", "est. cycles", "sim cycles"]);
    for cands in [4, 8, 16, 32, 64, 128] {
        let graph = experiments::vit_mlp_stage(seq, d, h);
        let mut cfg = DeployConfig::preset("siracusa", Strategy::Ftl).unwrap();
        cfg.solver.max_candidates = cands;
        let dep = Deployer::new(graph, cfg);
        let (plan, report) = dep.deploy().unwrap();
        budget_table.row(&[
            cands.to_string(),
            plan.solution.estimated_cycles().to_string(),
            report.sim.total_cycles.to_string(),
        ]);
    }
    println!("{}", budget_table.render());

    // (c) solver wall-clock
    println!("(c) solver wall-clock:");
    let graph = experiments::vit_mlp_stage(seq, d, h);
    let soc = ftl::soc::siracusa_reduced();
    bench("solver/stage_ftl_group", t(2), || {
        let groups = fuse_groups(&graph, Strategy::Ftl, FusionPolicy::default());
        let _ = solve_graph(&graph, &soc, groups, &SolverOptions::default(), false).unwrap();
    });
    let deep = deep_mlp(128, 512, 6, DType::Int8);
    bench("solver/deep_mlp_12_nodes", t(2), || {
        let groups = fuse_groups(&deep, Strategy::Ftl, FusionPolicy::default());
        let _ = solve_graph(&deep, &soc, groups, &SolverOptions::default(), false).unwrap();
    });

    // (d) branch-and-bound vs exhaustive sweep
    println!("\n(d) branch-and-bound vs exhaustive (ViT MLP stage, fused group):");
    let groups = fuse_groups(&graph, Strategy::Ftl, FusionPolicy::default());
    let homes = assign_homes(&graph, &groups, &soc);
    let exh = bench("solver/bnb_off_exhaustive", t(2), || {
        for gr in &groups {
            let _ = solve_group_exhaustive(&graph, &soc, gr, &homes, &SolverOptions::default(), false).unwrap();
        }
    });
    let mut rows: Vec<(String, std::time::Duration, Option<ftl::tiling::SearchStats>)> =
        vec![("exhaustive".into(), exh.median, None)];
    for threads in [1usize, 0] {
        let pool = SolverPool::new(threads);
        let label = if threads == 1 { "bnb threads=1" } else { "bnb threads=auto" };
        let r = bench(&format!("solver/{}", label.replace(' ', "_").replace('=', "-")), t(2), || {
            for gr in &groups {
                let _ =
                    solve_group_in(&graph, &soc, gr, &homes, &SolverOptions::default(), false, &pool).unwrap();
            }
        });
        rows.push((label.into(), r.median, Some(pool.stats())));
    }
    let mut table = Table::new(&["solver", "median", "speedup", "space", "scored", "cap-pruned", "bound-pruned"]);
    let base = rows[0].1.as_nanos().max(1) as f64;
    for (label, median, stats) in &rows {
        let (space, scored, cap, bound) = match stats {
            // Per-solve averages: the bench harness repeats the solve, so
            // divide the pool's running totals by the solve count.
            Some(s) if s.solves > 0 => (
                (s.space / s.solves).to_string(),
                (s.scored / s.solves).to_string(),
                (s.capacity_pruned / s.solves).to_string(),
                (s.bound_pruned / s.solves).to_string(),
            ),
            _ => ("-".into(), "all".into(), "-".into(), "-".into()),
        };
        table.row(&[
            label.clone(),
            format!("{:.2?}", median),
            format!("{:.1}x", base / median.as_nanos().max(1) as f64),
            space,
            scored,
            cap,
            bound,
        ]);
    }
    println!("{}", table.render());
}
