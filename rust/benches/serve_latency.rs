//! Bench: tracing overhead on the warm serve path.
//!
//! Paired comparison: the same warm DEPLOY (both caches hot, zero batch
//! window) through two schedulers — one tracing at the defaults (span
//! journal + per-lane latency histograms), one built with
//! `TraceOptions::disabled()` (no tracer allocated at all). Samples
//! alternate between the two, flipping order every pair, so clock drift
//! and allocator state can't systematically favour either side.
//!
//! Asserts the contract from the serve layer's docs: tracing costs less
//! than 5% on the warm-path p50 (plus a small absolute jitter floor),
//! and writes the measured numbers to `BENCH_serve_latency.json`.
//!
//! `FTL_BENCH_SMOKE=1` shrinks the workload and sample counts so CI can
//! execute the harness end-to-end.

use std::sync::Arc;
use std::time::{Duration, Instant};

use ftl::config::DeployConfig;
use ftl::coordinator::experiments;
use ftl::ir::Graph;
use ftl::serve::{
    AdmissionPolicy, BatchOptions, BatchScheduler, PlanService, ServeOptions, TraceOptions,
};
use ftl::tiling::Strategy;
use ftl::util::json::Json;

fn smoke() -> bool {
    std::env::var("FTL_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn median(samples: &mut [Duration]) -> Duration {
    samples.sort_unstable();
    samples[samples.len() / 2]
}

/// A single-lane scheduler over its own service, pre-warmed so every
/// bench deploy takes the warm fast path (both caches hit).
fn warm_scheduler(graph: &Graph, cfg: &DeployConfig, trace: TraceOptions) -> BatchScheduler {
    let opts = ServeOptions { cache_capacity: 32, cache_shards: 4, workers: 1, ..ServeOptions::default() };
    let sched = BatchScheduler::new(
        Arc::new(PlanService::new(opts)),
        BatchOptions {
            queue_capacity: 64,
            batch_window: Duration::ZERO,
            max_batch: 64,
            policy: AdmissionPolicy::Block,
            trace,
            ..BatchOptions::default()
        },
    );
    let outcome = sched.deploy("warmup", graph.clone(), cfg.clone()).unwrap();
    assert!(outcome.served().is_some(), "warmup request must be served");
    sched
}

fn main() {
    let smoke = smoke();
    let graph: Graph = if smoke {
        experiments::vit_mlp_stage(64, 96, 192)
    } else {
        experiments::vit_mlp_stage(197, 768, 3072)
    };
    let cfg = DeployConfig::preset("siracusa", Strategy::Ftl).unwrap();
    let pairs = if smoke { 60 } else { 400 };

    println!("=== serve layer: warm-path tracing overhead ===\n");

    let traced = warm_scheduler(&graph, &cfg, TraceOptions::default());
    let baseline = warm_scheduler(&graph, &cfg, TraceOptions::disabled());
    assert!(traced.tracer().is_some(), "default options must trace");
    assert!(baseline.tracer().is_none(), "disabled() must drop the tracer entirely");

    let deploy = |sched: &BatchScheduler| {
        let t = Instant::now();
        let outcome = sched.deploy("warm", graph.clone(), cfg.clone()).unwrap();
        let elapsed = t.elapsed();
        let reply = outcome.served().expect("warm request must be served");
        assert!(reply.cached && reply.sim_cached, "bench path must stay fully warm");
        elapsed
    };

    let (mut with, mut without) = (Vec::with_capacity(pairs), Vec::with_capacity(pairs));
    for i in 0..pairs {
        if i % 2 == 0 {
            with.push(deploy(&traced));
            without.push(deploy(&baseline));
        } else {
            without.push(deploy(&baseline));
            with.push(deploy(&traced));
        }
    }
    let traced_p50 = median(&mut with);
    let baseline_p50 = median(&mut without);
    let overhead_pct =
        (traced_p50.as_nanos() as f64 / baseline_p50.as_nanos().max(1) as f64 - 1.0) * 100.0;
    println!("warm deploy p50: traced {traced_p50:?} vs untraced {baseline_p50:?} ({overhead_pct:+.2}%)");

    // Cross-check against the tracer's own accounting: every traced
    // deploy must have landed a span, and the warm histogram's p50 is
    // the same quantity we just measured (within bucket resolution).
    let tracer = traced.tracer().unwrap();
    let hist_p50_us = tracer.warm_hist(0).quantile(0.5);
    println!("tracer warm-histogram p50: {hist_p50_us} µs over {} spans", tracer.overall().count());
    assert!(tracer.overall().count() as usize >= pairs, "every traced deploy must record a span");

    // The contract: < 5% overhead on the warm p50. The absolute floor
    // keeps ns-scale scheduler jitter from flaking short smoke runs.
    let budget = Duration::from_nanos((baseline_p50.as_nanos() as f64 * 1.05) as u64)
        + Duration::from_micros(25);
    assert!(
        traced_p50 <= budget,
        "tracing overhead too high: traced p50 {traced_p50:?} vs budget {budget:?} (untraced {baseline_p50:?})"
    );

    let out = Json::obj(vec![
        ("name", Json::str("serve_latency")),
        ("samples_per_path", Json::Num(pairs as f64)),
        ("baseline_warm_p50_ns", Json::Num(baseline_p50.as_nanos() as f64)),
        ("traced_warm_p50_ns", Json::Num(traced_p50.as_nanos() as f64)),
        ("overhead_pct", Json::Num(overhead_pct)),
        ("tracer_hist_warm_p50_us", Json::Num(hist_p50_us as f64)),
        ("tracer_spans", Json::Num(tracer.overall().count() as f64)),
    ]);
    std::fs::write("BENCH_serve_latency.json", format!("{}\n", out.pretty())).unwrap();
    println!("wrote BENCH_serve_latency.json");
}
