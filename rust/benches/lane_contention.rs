//! Bench: priority lanes under two-tenant saturation.
//!
//! Three questions, three sections:
//!
//! * **(a) share tables** — the deterministic WFQ core (the same
//!   `LaneSet` the dispatcher schedules with) under saturation at
//!   1:1, 3:1 and strict-ish (1 000 000:1) weight splits: the served
//!   cold-work share must track the weight share within one quantum.
//!   Unit costs, virtual clock — the table is exact and reproducible
//!   (it is recorded in EXPERIMENTS.md §Perf).
//! * **(b) scheduling overhead** — wall-clock cost of one WFQ quantum
//!   (pick + drain + charge) and of admission (try_push), i.e. what the
//!   lanes add on top of the old single FIFO's `VecDeque` ops. This is
//!   the number that must stay negligible against a solve (µs vs ms).
//! * **(c) threaded contention** — a real `BatchScheduler` two-tenant
//!   3:1 wave with distinct cold solves (one request per quantum, via
//!   the shared `ftl::serve::wave` driver): reports the heavy tenant's
//!   share of early quanta (sampled from the dispatcher's own
//!   counters) and the end-state per-lane cold-work counters.
//!
//! `FTL_BENCH_SMOKE=1` shrinks quanta counts and the threaded wave so
//! CI can execute the harness end-to-end.

use std::time::Duration;

use ftl::serve::wave::{saturated_shares, two_tenant_wave};
use ftl::serve::{LaneSet, LaneSpec};
use ftl::util::bench::bench;

fn smoke() -> bool {
    std::env::var("FTL_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// Saturated two-lane run on the deterministic core (shared
/// `ftl::serve::wave` driver — the same loop behind the self-test's
/// `lane_shares` line).
fn share_split(weights: (u64, u64), quanta: u64) -> (u64, u64) {
    let served = saturated_shares(&[("a", weights.0), ("b", weights.1)], quanta);
    (served[0], served[1])
}

fn main() {
    let smoke = smoke();
    let quanta: u64 = if smoke { 64 } else { 4096 };
    let secs = |n: u64| if smoke { Duration::from_millis(150) } else { Duration::from_secs(n) };

    println!("=== serve layer: priority lanes (weighted fair queuing) ===\n");

    // (a) Deterministic share tables (virtual clock, unit costs).
    println!("(a) two-tenant saturation, {quanta} unit quanta (deterministic core):\n");
    println!("{:<18} {:>10} {:>10} {:>14}", "weights", "tenant A", "tenant B", "A share");
    for weights in [(1u64, 1u64), (3, 1), (1_000_000, 1)] {
        let (a, b) = share_split(weights, quanta);
        let label = format!("{}:{}", weights.0, weights.1);
        println!("{label:<18} {a:>10} {b:>10} {:>13.1}%", 100.0 * a as f64 / quanta as f64);
        // Weighted fairness within one quantum (the strict split only
        // bounds the light tenant to ~1 quantum of service).
        let expect_a = quanta as f64 * weights.0 as f64 / (weights.0 + weights.1) as f64;
        assert!(
            (a as f64 - expect_a).abs() <= 1.0,
            "{}:{} split must track the weight share within one quantum (got {a}, expected {expect_a:.1})",
            weights.0,
            weights.1
        );
    }
    println!();

    // (b) Scheduling overhead per quantum and per admission.
    let mut lanes: LaneSet<u64> = LaneSet::new(vec![
        LaneSpec::new("gold", 3, 1024),
        LaneSpec::new("silver", 2, 1024),
        LaneSpec::new("free", 1, 1024),
    ]);
    let idx: Vec<usize> = ["gold", "silver", "free"].iter().map(|&n| lanes.resolve(Some(n))).collect();
    let quantum = bench("lanes/quantum(pick+drain+charge)", secs(2), || {
        for &l in &idx {
            let _ = lanes.try_push(l, 1);
        }
        let lane = lanes.pick().expect("saturated");
        lanes.drain(lane, 1);
        lanes.charge(lane, 1);
    });

    // (c) Threaded two-tenant 3:1 wave over a real scheduler: distinct
    // cold solves, one request per quantum. The shared driver
    // (`ftl::serve::wave`, also run by the example self-test) asserts
    // the drain invariants (all served, exact per-lane cold work, lane
    // sums == scheduler totals) internally.
    let per_lane: usize = if smoke { 4 } else { 12 };
    let window = (4 * per_lane / 3) as u64;
    let report = two_tenant_wave(per_lane, window).expect("two-tenant wave failed");
    println!(
        "\n(c) threaded 3:1 wave ({per_lane} distinct cold requests/lane): gold {}/{} of early quanta",
        report.gold_early, report.total_early
    );
    println!("{}", report.stats.lanes_table());

    println!("\nWFQ quantum overhead (vs ~ms solves): {:?}", quantum.median);
    assert!(
        quantum.median < Duration::from_millis(1),
        "lane scheduling must stay negligible against a solve (got {:?})",
        quantum.median
    );
}
