//! Bench: restart-to-warm — per-entry JSON snapshots vs binary segments.
//!
//! The restart path is the whole point of persistence: a replica that
//! crashes or redeploys must come back serving warm (zero solves, zero
//! simulator runs) as fast as the disk allows. This harness populates a
//! service with thousands of synthetic cache entries (a handful of real
//! solved `stage-<seq>x<dim>x<hidden>` workloads, replicated under
//! derived fingerprints with a spread of lane hints), snapshots the
//! caches in both codecs, then measures the wall-clock of
//! `Snapshotter::attach` against a fresh service — the restart-to-warm
//! time — for each.
//!
//! The segmented codec wins on every axis the JSON-per-entry layout
//! loses on: a few sequential file reads instead of thousands of
//! open/read/close round trips, compact binary decode instead of JSON
//! parsing, and the decode fanned out across the solver pool. The
//! acceptance bar (asserted at full scale) is a >=5x restart-to-warm
//! speedup at 10k entries.
//!
//! Writes the measured numbers to `BENCH_warm_start.json` and prints a
//! greppable `warm_start:` summary line. `FTL_BENCH_SMOKE=1` shrinks
//! the entry count so CI can execute the harness end-to-end.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use ftl::config::DeployConfig;
use ftl::serve::{resolve_workload, PersistOptions, PlanService, ServeOptions, SnapshotFormat, Snapshotter};
use ftl::tiling::Strategy;
use ftl::util::json::Json;

/// `FTL_BENCH_SMOKE=1` shrinks the entry count so CI can execute the
/// harness end-to-end without paying full bench time.
fn smoke() -> bool {
    std::env::var("FTL_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn bench_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ftl-warm-start-bench-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn dir_stats(dir: &Path) -> (usize, u64) {
    let mut files = 0usize;
    let mut bytes = 0u64;
    if let Ok(rd) = std::fs::read_dir(dir) {
        for entry in rd.flatten() {
            if let Ok(meta) = entry.metadata() {
                if meta.is_file() {
                    files += 1;
                    bytes += meta.len();
                }
            }
        }
    }
    (files, bytes)
}

fn service(entries: usize) -> Arc<PlanService> {
    // Capacity comfortably above the synthetic population so the load
    // path never evicts — we are measuring I/O + decode, not LRU churn.
    let cap = (entries * 2).max(1024);
    Arc::new(PlanService::new(ServeOptions {
        cache_capacity: cap,
        sim_cache_capacity: cap,
        cache_shards: 16,
        workers: 1,
        ..ServeOptions::default()
    }))
}

/// One timed restart-to-warm round for `format`: populate, snapshot,
/// then attach a cold service to the directory and time the attach.
struct Round {
    format: SnapshotFormat,
    entries: usize,
    flush: Duration,
    load: Duration,
    files: usize,
    bytes: u64,
}

impl Round {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::str(self.format.name())),
            ("entries", Json::Num(self.entries as f64)),
            ("flush_ms", Json::Num(self.flush.as_secs_f64() * 1e3)),
            ("load_ms", Json::Num(self.load.as_secs_f64() * 1e3)),
            ("files", Json::Num(self.files as f64)),
            ("bytes", Json::Num(self.bytes as f64)),
        ])
    }
}

#[allow(clippy::type_complexity)]
fn run_round(
    format: SnapshotFormat,
    plans: &[(ftl::serve::Fingerprint, Arc<ftl::coordinator::Deployment>, u64)],
    sims: &[(ftl::serve::Fingerprint, Arc<ftl::sim::SimReport>, u64)],
    replicas: usize,
) -> Round {
    let dir = bench_dir(format.name());
    let total = replicas * 2;

    // Populate: each replica clones one solved plan and one sim report
    // under a fresh derived fingerprint, with lane hints spread 0..100
    // so the loader's heaviest-first ordering has real work to do.
    let svc = service(total);
    let opts = PersistOptions::manual().with_format(format);
    let snap = Snapshotter::attach(svc.clone(), &dir, opts).unwrap();
    for i in 0..replicas {
        let hint = (i % 100) as u64;
        let (pk, plan, _) = &plans[i % plans.len()];
        let key = pk.derive(&format!("warm-start-bench-plan-{i}"));
        assert!(svc.import_plan_hinted(key, plan.clone(), hint), "synthetic plan import must land");
        let (sk, sim, _) = &sims[i % sims.len()];
        svc.import_sim_hinted(sk.derive(&format!("warm-start-bench-sim-{i}")), sim.clone(), hint);
    }
    let flush_start = Instant::now();
    let wrote = snap.flush();
    let flush = flush_start.elapsed();
    snap.shutdown();
    assert_eq!(wrote, total, "every synthetic entry must reach disk");
    let (files, bytes) = dir_stats(&dir);

    // Restart: a cold service pointed at the populated directory.
    // `attach` returns only after every entry is decoded and sitting
    // in the caches — its wall-clock IS the restart-to-warm time.
    let cold = service(total);
    let load_start = Instant::now();
    let warm_snap = Snapshotter::attach(cold.clone(), &dir, PersistOptions::manual().with_format(format)).unwrap();
    let load = load_start.elapsed();
    warm_snap.shutdown();

    let stats = cold.stats();
    assert_eq!(stats.cache.entries, replicas, "every plan entry must be warm after restart");
    assert_eq!(stats.sim_cache.entries, replicas, "every sim entry must be warm after restart");
    assert_eq!(stats.solves, 0, "warm start must not solve");
    assert_eq!(stats.sims, 0, "warm start must not simulate");

    let _ = std::fs::remove_dir_all(&dir);
    Round { format, entries: total, flush, load, files, bytes }
}

fn main() {
    let smoke = smoke();
    // Full scale: 5k plan + 5k sim entries = the issue's 10k-entry bar.
    let replicas = if smoke { 500 } else { 5000 };

    // A handful of real solved workloads to replicate — distinct shapes
    // so the payloads are not byte-identical.
    let shapes = [
        (16, 16, 32),
        (16, 24, 48),
        (24, 16, 64),
        (32, 24, 48),
        (16, 32, 32),
        (24, 24, 96),
        (32, 16, 48),
        (48, 16, 32),
    ];
    let seed_svc = service(64);
    let cfg = DeployConfig::preset("cluster-only", Strategy::Ftl).unwrap();
    for (s, d, h) in shapes {
        let graph = resolve_workload(&format!("stage-{s}x{d}x{h}")).unwrap();
        seed_svc.deploy(&format!("stage-{s}x{d}x{h}"), &graph, &cfg).unwrap();
    }
    let plans = seed_svc.export_plans_hinted();
    let sims = seed_svc.export_sims_hinted();
    assert_eq!(plans.len(), shapes.len());
    assert_eq!(sims.len(), shapes.len());

    println!("=== restart-to-warm: JSON per-entry vs binary segments ({} entries) ===\n", replicas * 2);

    let json = run_round(SnapshotFormat::Json, &plans, &sims, replicas);
    let bin = run_round(SnapshotFormat::Bin, &plans, &sims, replicas);

    for r in [&json, &bin] {
        println!(
            "{:<28} flush: {:>9.1?}   restart-to-warm: {:>9.1?}   ({} files, {:.1} MiB)",
            format!("snapshot-format={}", r.format.name()),
            r.flush,
            r.load,
            r.files,
            r.bytes as f64 / (1024.0 * 1024.0)
        );
    }

    let speedup = json.load.as_nanos() as f64 / bin.load.as_nanos().max(1) as f64;
    let flush_speedup = json.flush.as_nanos() as f64 / bin.flush.as_nanos().max(1) as f64;
    let compression = json.bytes as f64 / (bin.bytes as f64).max(1.0);
    println!(
        "\nwarm_start: entries={} json_load_ms={:.1} bin_load_ms={:.1} speedup={speedup:.1}x \
         flush_speedup={flush_speedup:.1}x size_ratio={compression:.2}x",
        json.entries,
        json.load.as_secs_f64() * 1e3,
        bin.load.as_secs_f64() * 1e3,
    );

    let out = Json::obj(vec![
        ("bench", Json::str("warm_start")),
        ("smoke", Json::Bool(smoke)),
        ("entries", Json::Num(json.entries as f64)),
        ("json", json.to_json()),
        ("bin", bin.to_json()),
        ("load_speedup", Json::Num(speedup)),
        ("flush_speedup", Json::Num(flush_speedup)),
        ("size_ratio", Json::Num(compression)),
    ]);
    std::fs::write("BENCH_warm_start.json", format!("{}\n", out.pretty())).unwrap();
    println!("wrote BENCH_warm_start.json");

    // The acceptance bar only binds at full scale: smoke runs are too
    // small (and CI machines too noisy) for a meaningful ratio.
    if !smoke {
        assert!(
            speedup >= 5.0,
            "segmented restart-to-warm must be >=5x faster than JSON at 10k entries (got {speedup:.1}x)"
        );
    }
}
