//! Bench: the serve layer — cold solve vs warm plan-cache hit vs
//! contended single-flight.
//!
//! The acceptance bar for `ftl::serve` is a >=10x latency reduction for
//! warm-cache DEPLOY requests (they skip the branch-&-bound solver
//! entirely); in practice the gap is orders of magnitude. The contended
//! number shows N concurrent identical cold requests costing ~one solve
//! (single-flight), not N solves.

use std::time::Duration;

use ftl::config::DeployConfig;
use ftl::coordinator::experiments;
use ftl::serve::{PlanService, ServeOptions};
use ftl::tiling::Strategy;
use ftl::util::bench::bench;

/// `FTL_BENCH_SMOKE=1` shrinks measurement windows (and the workload) so
/// CI can execute the harness end-to-end without paying full bench time.
fn smoke() -> bool {
    std::env::var("FTL_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn main() {
    let smoke = smoke();
    let graph = if smoke {
        experiments::vit_mlp_stage(64, 96, 192)
    } else {
        experiments::vit_mlp_stage(197, 768, 3072)
    };
    let secs = |n: u64| if smoke { Duration::from_millis(150) } else { Duration::from_secs(n) };
    let cfg = DeployConfig::preset("siracusa", Strategy::Ftl).unwrap();
    let opts = ServeOptions { cache_capacity: 32, cache_shards: 4, workers: 1, ..ServeOptions::default() };

    println!("=== serve layer: plan-cache + single-flight (vit-base-stage, siracusa/ftl) ===\n");

    // Cold: a fresh service per call — fingerprint, miss, full solve.
    let cold = bench("serve/cold_plan(solve)", secs(3), || {
        let svc = PlanService::new(opts);
        let outcome = svc.plan(&graph, &cfg).unwrap();
        assert!(!outcome.cached);
    });

    // Warm: one service, the key stays hot — fingerprint + LRU hit only.
    let warm_svc = PlanService::new(opts);
    warm_svc.plan(&graph, &cfg).unwrap();
    let warm = bench("serve/warm_hit", secs(2), || {
        let outcome = warm_svc.plan(&graph, &cfg).unwrap();
        assert!(outcome.cached);
    });

    // Warm with the verification gate on: `check_deployment` runs at cache
    // insertion only, so a warm hit does byte-for-byte the same work as
    // without the gate. The counter assert locks the zero-warm-overhead
    // claim structurally (timing asserts would be flaky); the printed
    // ratio shows it empirically.
    let gated_svc = PlanService::new(ServeOptions { verify_plans: true, ..opts });
    gated_svc.plan(&graph, &cfg).unwrap();
    let gated = bench("serve/warm_hit_verify_on", secs(2), || {
        let outcome = gated_svc.plan(&graph, &cfg).unwrap();
        assert!(outcome.cached);
    });
    let checked = gated_svc
        .stats_json()
        .get("verify")
        .and_then(|v| v.get("checked"))
        .and_then(|c| c.as_usize())
        .unwrap();
    assert_eq!(checked, 1, "warm hits must never re-run the verifier (verify.checked grew past the one insertion)");

    // Contended: 8 threads race the same cold key; single-flight coalesces
    // them onto one solve, so the wall-clock tracks `cold`, not 8x cold.
    let contended = bench("serve/contended_8x_single_flight", secs(3), || {
        let svc = PlanService::new(opts);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    svc.plan(&graph, &cfg).unwrap();
                });
            }
        });
        assert_eq!(svc.stats().solves, 1, "contended requests must coalesce to one solve");
    });

    let speedup = cold.median.as_nanos() as f64 / warm.median.as_nanos().max(1) as f64;
    let amortised = contended.median.as_nanos() as f64 / cold.median.as_nanos().max(1) as f64;
    let gate_ratio = gated.median.as_nanos() as f64 / warm.median.as_nanos().max(1) as f64;
    println!("\nwarm-cache speedup vs cold solve: {speedup:.0}x (acceptance bar: >=10x)");
    println!("warm hit with --verify-plans vs without: {gate_ratio:.2}x (gate runs at insertion only)");
    println!("contended(8 threads) / cold(1 thread): {amortised:.2}x (single-flight: ~1x, not 8x)");
    assert!(speedup >= 10.0, "warm cache hit must be >=10x faster than a cold solve (got {speedup:.1}x)");
}
