//! Ergonomic graph construction, plus the stock workloads used by the
//! paper's evaluation (ViT MLP variants).

#![forbid(unsafe_code)]

use anyhow::Result;

use super::{ActKind, DType, Graph, Op, Tensor, TensorId, TensorKind};

/// Fluent builder over [`Graph`].
///
/// (`no_run`: doctest binaries bypass the crate's rpath to the bundled
/// libstdc++ that the `xla` native library needs; the same snippet runs
/// as `examples/quickstart.rs`.)
///
/// ```no_run
/// use ftl::ir::{GraphBuilder, DType, ActKind};
/// let mut b = GraphBuilder::new(DType::Int8);
/// let x = b.input("x", &[197, 768]);
/// let h = b.linear("fc1", x, 3072, true);
/// let a = b.act("gelu", ActKind::Gelu, h);
/// let y = b.linear("fc2", a, 768, true);
/// let g = b.finish(y).unwrap();
/// assert_eq!(g.nodes.len(), 4);
/// ```
pub struct GraphBuilder {
    graph: Graph,
    dtype: DType,
    fresh: usize,
}

impl GraphBuilder {
    /// New builder; all tensors use `dtype` unless stated otherwise.
    pub fn new(dtype: DType) -> Self {
        Self { graph: Graph::new(), dtype, fresh: 0 }
    }

    fn fresh_name(&mut self, stem: &str) -> String {
        self.fresh += 1;
        format!("{stem}_{}", self.fresh)
    }

    /// Declare a graph input.
    pub fn input(&mut self, name: &str, shape: &[usize]) -> TensorId {
        self.graph
            .add_tensor(Tensor::new(name, shape.to_vec(), self.dtype, TensorKind::Input))
            .expect("duplicate input name")
    }

    /// Declare a weight tensor.
    pub fn weight(&mut self, name: &str, shape: &[usize]) -> TensorId {
        self.graph
            .add_tensor(Tensor::new(name, shape.to_vec(), self.dtype, TensorKind::Weight))
            .expect("duplicate weight name")
    }

    /// Fully-connected layer: `x [M,K] → [M,N]`, weights auto-declared.
    pub fn linear(&mut self, name: &str, x: TensorId, n: usize, bias: bool) -> TensorId {
        let k = *self.graph.tensors[x].shape.last().expect("linear input must have rank >= 1");
        let w = self.weight(&format!("{name}.w"), &[k, n]);
        let mut inputs = vec![x, w];
        if bias {
            let b = self.weight(&format!("{name}.b"), &[n]);
            inputs.push(b);
        }
        let out = self.fresh_name(name);
        let (_, t) = self
            .graph
            .add_node(name, Op::Gemm { transpose_b: false, has_bias: bias }, inputs, out, TensorKind::Intermediate)
            .expect("linear build failed");
        t
    }

    /// Elementwise activation.
    pub fn act(&mut self, name: &str, kind: ActKind, x: TensorId) -> TensorId {
        let out = self.fresh_name(name);
        let (_, t) = self
            .graph
            .add_node(name, Op::Act(kind), vec![x], out, TensorKind::Intermediate)
            .expect("act build failed");
        t
    }

    /// Elementwise addition.
    pub fn add(&mut self, name: &str, a: TensorId, b: TensorId) -> TensorId {
        let out = self.fresh_name(name);
        let (_, t) = self.graph.add_node(name, Op::Add, vec![a, b], out, TensorKind::Intermediate).expect("add failed");
        t
    }

    /// LayerNorm over the last axis; gamma/beta auto-declared.
    pub fn layernorm(&mut self, name: &str, x: TensorId) -> TensorId {
        let c = *self.graph.tensors[x].shape.last().unwrap();
        let gamma = self.weight(&format!("{name}.gamma"), &[c]);
        let beta = self.weight(&format!("{name}.beta"), &[c]);
        let out = self.fresh_name(name);
        let (_, t) = self
            .graph
            .add_node(name, Op::LayerNorm { eps: 1e-5 }, vec![x, gamma, beta], out, TensorKind::Intermediate)
            .expect("layernorm failed");
        t
    }

    /// Mark `out` as the graph output, validate, and return the graph.
    pub fn finish(mut self, out: TensorId) -> Result<Graph> {
        self.graph.tensors[out].kind = TensorKind::Output;
        self.graph.validate()?;
        Ok(self.graph)
    }

    /// Access the graph under construction.
    pub fn graph(&self) -> &Graph {
        &self.graph
    }
}

/// The paper's benchmark workload: a ViT MLP block,
/// `GEMM(d→h) → GeLU → GEMM(h→d)`, over `seq` tokens.
///
/// ViT-Base: `seq=197, d=768, h=3072` (h = 4d), int8 — the configuration
/// whose intermediate tensor (`seq×h` ≈ 605 KiB) overflows the reduced
/// Siracusa L2, triggering the paper's L3-spill mechanism.
pub fn vit_mlp(seq: usize, d: usize, h: usize, dtype: DType) -> Graph {
    let mut b = GraphBuilder::new(dtype);
    let x = b.input("x", &[seq, d]);
    let fc1 = b.linear("fc1", x, h, true);
    let act = b.act("gelu", ActKind::Gelu, fc1);
    let fc2 = b.linear("fc2", act, d, true);
    b.finish(fc2).expect("vit_mlp is valid by construction")
}

/// Named ViT MLP presets (model dims from Dosovitskiy et al., ICLR'21).
pub fn vit_mlp_preset(name: &str) -> Option<Graph> {
    let (seq, d, h) = match name {
        "vit-tiny" => (197, 192, 768),
        "vit-small" => (197, 384, 1536),
        "vit-base" => (197, 768, 3072),
        "vit-large" => (197, 1024, 4096),
        _ => return None,
    };
    Some(vit_mlp(seq, d, h, DType::Int8))
}

/// A deeper MLP chain (for fusion-length ablations): `n_layers` of
/// Linear(+bias)→GeLU with constant width.
pub fn deep_mlp(seq: usize, width: usize, n_layers: usize, dtype: DType) -> Graph {
    let mut b = GraphBuilder::new(dtype);
    let mut t = b.input("x", &[seq, width]);
    for i in 0..n_layers {
        t = b.linear(&format!("fc{i}"), t, width, true);
        t = b.act(&format!("act{i}"), ActKind::Gelu, t);
    }
    b.finish(t).expect("deep_mlp is valid by construction")
}

/// A single-head self-attention block over `seq` tokens of width `d`
/// with head dim `dh`:
/// `Q = X·Wq, K = X·Wk, V = X·Wv, S = softmax(Q·Kᵀ), O = (S·V)·Wo`.
///
/// Exercises the `transpose_b` GEMM path (`Q·Kᵀ` via `Gemm{transpose_b}`)
/// and the Softmax whole-row kernel policy inside a real deployment.
pub fn attention_head(seq: usize, d: usize, dh: usize, dtype: DType) -> Graph {
    let mut g = Graph::new();
    let x = g.add_tensor(Tensor::new("x", vec![seq, d], dtype, TensorKind::Input)).expect("fresh graph");
    let wq = g.add_tensor(Tensor::new("wq", vec![d, dh], dtype, TensorKind::Weight)).unwrap();
    let wk = g.add_tensor(Tensor::new("wk", vec![d, dh], dtype, TensorKind::Weight)).unwrap();
    let wv = g.add_tensor(Tensor::new("wv", vec![d, dh], dtype, TensorKind::Weight)).unwrap();
    let wo = g.add_tensor(Tensor::new("wo", vec![dh, d], dtype, TensorKind::Weight)).unwrap();
    let gemm = |tb| Op::Gemm { transpose_b: tb, has_bias: false };
    let (_, q) = g.add_node("q_proj", gemm(false), vec![x, wq], "q", TensorKind::Intermediate).unwrap();
    let (_, k) = g.add_node("k_proj", gemm(false), vec![x, wk], "k", TensorKind::Intermediate).unwrap();
    let (_, v) = g.add_node("v_proj", gemm(false), vec![x, wv], "v", TensorKind::Intermediate).unwrap();
    // scores = Q · Kᵀ  (K stored [seq, dh] → transpose_b)
    let (_, s) = g.add_node("scores", gemm(true), vec![q, k], "s", TensorKind::Intermediate).unwrap();
    let (_, p) = g.add_node("softmax", Op::Softmax, vec![s], "p", TensorKind::Intermediate).unwrap();
    let (_, av) = g.add_node("attend", gemm(false), vec![p, v], "av", TensorKind::Intermediate).unwrap();
    g.add_node("out_proj", gemm(false), vec![av, wo], "y", TensorKind::Output).unwrap();
    g.validate().expect("attention_head is valid by construction");
    g
}

/// A full pre-norm transformer MLP sub-block with residual:
/// `LN → FC1 → GeLU → FC2 → Add(residual)`.
pub fn vit_mlp_block(seq: usize, d: usize, h: usize, dtype: DType) -> Graph {
    let mut b = GraphBuilder::new(dtype);
    let x = b.input("x", &[seq, d]);
    let ln = b.layernorm("ln", x);
    let fc1 = b.linear("fc1", ln, h, true);
    let act = b.act("gelu", ActKind::Gelu, fc1);
    let fc2 = b.linear("fc2", act, d, true);
    let res = b.add("residual", fc2, x);
    b.finish(res).expect("vit_mlp_block is valid by construction")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vit_base_shapes() {
        let g = vit_mlp(197, 768, 3072, DType::Int8);
        g.validate().unwrap();
        let (_, h) = g.tensor_by_name("fc1_1").unwrap();
        assert_eq!(h.shape, vec![197, 3072]);
        let out = g.outputs();
        assert_eq!(g.tensors[out[0]].shape, vec![197, 768]);
        // intermediate ≈ 605 KiB in int8
        assert_eq!(h.size_bytes(), 197 * 3072);
    }

    #[test]
    fn presets_exist() {
        for p in ["vit-tiny", "vit-small", "vit-base", "vit-large"] {
            let g = vit_mlp_preset(p).unwrap();
            g.validate().unwrap();
        }
        assert!(vit_mlp_preset("nope").is_none());
    }

    #[test]
    fn deep_mlp_layers() {
        let g = deep_mlp(64, 128, 4, DType::Int8);
        g.validate().unwrap();
        assert_eq!(g.nodes.len(), 8);
    }

    #[test]
    fn attention_head_shapes() {
        let g = attention_head(197, 768, 64, DType::Int8);
        g.validate().unwrap();
        assert_eq!(g.nodes.len(), 7);
        let (_, s) = g.tensor_by_name("s").unwrap();
        assert_eq!(s.shape, vec![197, 197], "scores are seq x seq");
        let out = g.outputs();
        assert_eq!(g.tensors[out[0]].shape, vec![197, 768]);
    }

    #[test]
    fn mlp_block_residual() {
        let g = vit_mlp_block(16, 32, 64, DType::F32);
        g.validate().unwrap();
        assert_eq!(g.nodes.last().unwrap().op, Op::Add);
    }
}
