//! Operator definitions and shape inference.

#![forbid(unsafe_code)]

use anyhow::{bail, ensure, Result};

/// Elementwise activation kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ActKind {
    /// Gaussian Error Linear Unit (the paper's benchmark op).
    Gelu,
    /// Rectified Linear Unit.
    Relu,
    /// Logistic sigmoid.
    Sigmoid,
    /// Identity (useful for testing the fusion machinery).
    Identity,
}

impl ActKind {
    /// Short name used in reports and the JSON format.
    pub const fn name(self) -> &'static str {
        match self {
            ActKind::Gelu => "gelu",
            ActKind::Relu => "relu",
            ActKind::Sigmoid => "sigmoid",
            ActKind::Identity => "identity",
        }
    }
}

/// Operator node payload.
///
/// Shapes use the conventions:
/// * `Gemm`: `A [M,K] × B [K,N] (+ bias [N]) → [M,N]` (`transpose_b` flips B
///   to `[N,K]`).
/// * Elementwise ops preserve shape.
/// * `LayerNorm`/`Softmax` normalise over the last axis.
/// * `Conv2d`: NHWC activation `[N,H,W,C]`, weights `[Kh,Kw,C,F] → [N,H',W',F]`.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// General matrix multiplication with optional bias.
    Gemm {
        /// If true, the second input is stored `[N, K]`.
        transpose_b: bool,
        /// If true, a third input (bias, `[N]`) is expected.
        has_bias: bool,
    },
    /// Elementwise activation.
    Act(ActKind),
    /// Elementwise addition of two tensors of identical shape.
    Add,
    /// Layer normalisation over the last axis (gamma/beta inputs `[C]`).
    LayerNorm {
        /// Numerical-stability epsilon (recorded for codegen; cost model
        /// does not depend on it).
        eps: f32,
    },
    /// Softmax over the last axis.
    Softmax,
    /// 2-D transpose of a matrix `[M,N] → [N,M]`.
    Transpose,
    /// 2-D convolution, NHWC.
    Conv2d {
        /// Kernel height.
        kh: usize,
        /// Kernel width.
        kw: usize,
        /// Stride (same in H and W).
        stride: usize,
        /// Symmetric zero padding (same in H and W).
        pad: usize,
    },
    /// Requantisation (int32 accumulator → int8), elementwise.
    Requant,
}

impl Op {
    /// Human-readable operator name.
    pub fn name(&self) -> String {
        match self {
            Op::Gemm { .. } => "gemm".into(),
            Op::Act(k) => k.name().into(),
            Op::Add => "add".into(),
            Op::LayerNorm { .. } => "layernorm".into(),
            Op::Softmax => "softmax".into(),
            Op::Transpose => "transpose".into(),
            Op::Conv2d { .. } => "conv2d".into(),
            Op::Requant => "requant".into(),
        }
    }

    /// Number of tensor inputs this op expects.
    pub fn arity(&self) -> usize {
        match self {
            Op::Gemm { has_bias, .. } => 2 + usize::from(*has_bias),
            Op::Act(_) | Op::Softmax | Op::Transpose | Op::Requant => 1,
            Op::Add => 2,
            Op::LayerNorm { .. } => 3,
            Op::Conv2d { .. } => 2,
        }
    }

    /// Infer the output shape from input shapes; errors on rank/shape
    /// mismatches. This is the single source of truth used by the graph
    /// validator and the tiling constraint generator.
    pub fn infer_shape(&self, inputs: &[&[usize]]) -> Result<Vec<usize>> {
        ensure!(
            inputs.len() == self.arity(),
            "{}: expected {} inputs, got {}",
            self.name(),
            self.arity(),
            inputs.len()
        );
        match self {
            Op::Gemm { transpose_b, has_bias } => {
                let a = inputs[0];
                let b = inputs[1];
                ensure!(a.len() == 2 && b.len() == 2, "gemm expects rank-2 inputs");
                let (m, k) = (a[0], a[1]);
                let (bk, n) = if *transpose_b { (b[1], b[0]) } else { (b[0], b[1]) };
                ensure!(k == bk, "gemm K mismatch: A has K={k}, B has K={bk}");
                if *has_bias {
                    let bias = inputs[2];
                    ensure!(bias == [n], "gemm bias must be [{n}], got {bias:?}");
                }
                Ok(vec![m, n])
            }
            Op::Act(_) | Op::Softmax | Op::Requant => Ok(inputs[0].to_vec()),
            Op::Add => {
                ensure!(inputs[0] == inputs[1], "add shape mismatch: {:?} vs {:?}", inputs[0], inputs[1]);
                Ok(inputs[0].to_vec())
            }
            Op::LayerNorm { .. } => {
                let x = inputs[0];
                ensure!(!x.is_empty(), "layernorm input must have rank >= 1");
                let c = *x.last().unwrap();
                ensure!(inputs[1] == [c], "layernorm gamma must be [{c}]");
                ensure!(inputs[2] == [c], "layernorm beta must be [{c}]");
                Ok(x.to_vec())
            }
            Op::Transpose => {
                let x = inputs[0];
                ensure!(x.len() == 2, "transpose expects rank-2 input");
                Ok(vec![x[1], x[0]])
            }
            Op::Conv2d { kh, kw, stride, pad } => {
                let x = inputs[0];
                let w = inputs[1];
                ensure!(x.len() == 4, "conv2d expects NHWC input");
                ensure!(w.len() == 4, "conv2d expects KhKwCF weights");
                let (n, h, wi, c) = (x[0], x[1], x[2], x[3]);
                ensure!(w[0] == *kh && w[1] == *kw, "conv2d weight kernel dims mismatch");
                ensure!(w[2] == c, "conv2d channel mismatch: input C={c}, weight C={}", w[2]);
                let f = w[3];
                let ho = conv_out(h, *kh, *stride, *pad)?;
                let wo = conv_out(wi, *kw, *stride, *pad)?;
                Ok(vec![n, ho, wo, f])
            }
        }
    }

    /// Multiply–accumulate count for the full (un-tiled) op — the basis of
    /// the compute cost models.
    pub fn macs(&self, inputs: &[&[usize]], output: &[usize]) -> usize {
        match self {
            Op::Gemm { transpose_b, .. } => {
                let k = if *transpose_b { inputs[1][1] } else { inputs[1][0] };
                output.iter().product::<usize>() * k
            }
            Op::Conv2d { kh, kw, .. } => {
                let c = inputs[0][3];
                output.iter().product::<usize>() * kh * kw * c
            }
            // Elementwise / normalisation ops: ~1 "op" per element.
            _ => output.iter().product(),
        }
    }

    /// True for ops whose tile-output dims map 1:1 to tile-input dims
    /// (elementwise), which makes them trivially fusable.
    pub fn is_elementwise(&self) -> bool {
        matches!(self, Op::Act(_) | Op::Add | Op::Requant)
    }
}

fn conv_out(dim: usize, k: usize, stride: usize, pad: usize) -> Result<usize> {
    let padded = dim + 2 * pad;
    if padded < k {
        bail!("conv2d: input dim {dim} (+2*{pad}) smaller than kernel {k}");
    }
    Ok((padded - k) / stride + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_shape() {
        let op = Op::Gemm { transpose_b: false, has_bias: true };
        let out = op.infer_shape(&[&[197, 768], &[768, 3072], &[3072]]).unwrap();
        assert_eq!(out, vec![197, 3072]);
    }

    #[test]
    fn gemm_transposed_b() {
        let op = Op::Gemm { transpose_b: true, has_bias: false };
        let out = op.infer_shape(&[&[4, 8], &[16, 8]]).unwrap();
        assert_eq!(out, vec![4, 16]);
    }

    #[test]
    fn gemm_k_mismatch() {
        let op = Op::Gemm { transpose_b: false, has_bias: false };
        assert!(op.infer_shape(&[&[4, 8], &[9, 16]]).is_err());
    }

    #[test]
    fn gemm_bad_bias() {
        let op = Op::Gemm { transpose_b: false, has_bias: true };
        assert!(op.infer_shape(&[&[4, 8], &[8, 16], &[15]]).is_err());
    }

    #[test]
    fn elementwise_shapes() {
        assert_eq!(Op::Act(ActKind::Gelu).infer_shape(&[&[5, 7]]).unwrap(), vec![5, 7]);
        assert_eq!(Op::Add.infer_shape(&[&[5, 7], &[5, 7]]).unwrap(), vec![5, 7]);
        assert!(Op::Add.infer_shape(&[&[5, 7], &[5, 8]]).is_err());
    }

    #[test]
    fn layernorm_shape() {
        let op = Op::LayerNorm { eps: 1e-5 };
        assert_eq!(op.infer_shape(&[&[197, 768], &[768], &[768]]).unwrap(), vec![197, 768]);
        assert!(op.infer_shape(&[&[197, 768], &[767], &[768]]).is_err());
    }

    #[test]
    fn conv2d_shape() {
        let op = Op::Conv2d { kh: 3, kw: 3, stride: 1, pad: 1 };
        let out = op.infer_shape(&[&[1, 32, 32, 16], &[3, 3, 16, 64]]).unwrap();
        assert_eq!(out, vec![1, 32, 32, 64]);
        let op = Op::Conv2d { kh: 3, kw: 3, stride: 2, pad: 0 };
        let out = op.infer_shape(&[&[1, 33, 33, 16], &[3, 3, 16, 64]]).unwrap();
        assert_eq!(out, vec![1, 16, 16, 64]);
    }

    #[test]
    fn macs_gemm() {
        let op = Op::Gemm { transpose_b: false, has_bias: false };
        assert_eq!(op.macs(&[&[4, 8], &[8, 16]], &[4, 16]), 4 * 16 * 8);
    }

    #[test]
    fn transpose_shape() {
        assert_eq!(Op::Transpose.infer_shape(&[&[3, 5]]).unwrap(), vec![5, 3]);
    }
}
