//! Tensor declarations.

#![forbid(unsafe_code)]


use super::DType;

/// What role a tensor plays in the graph — this decides its *home* memory
/// level and its lifetime for static allocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TensorKind {
    /// Network input activation (lives in L2, streamed from host/L3).
    Input,
    /// Network output activation.
    Output,
    /// Constant parameter (weights/bias) — resident in L3/L2, read-only.
    Weight,
    /// Intermediate activation produced and consumed inside the graph.
    Intermediate,
}

/// A statically-shaped tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Tensor {
    /// Unique name within the graph.
    pub name: String,
    /// Static shape; row-major (last dim contiguous).
    pub shape: Vec<usize>,
    /// Element type.
    pub dtype: DType,
    /// Role of the tensor.
    pub kind: TensorKind,
}

impl Tensor {
    /// Create a new tensor declaration.
    pub fn new(name: impl Into<String>, shape: Vec<usize>, dtype: DType, kind: TensorKind) -> Self {
        Self { name: name.into(), shape, dtype, kind }
    }

    /// Number of elements.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Total size in bytes.
    pub fn size_bytes(&self) -> usize {
        self.numel() * self.dtype.size_bytes()
    }

    /// Rank (number of dimensions).
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Row-major strides in *elements*.
    pub fn strides(&self) -> Vec<usize> {
        let mut strides = vec![1usize; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            strides[i] = strides[i + 1] * self.shape[i + 1];
        }
        strides
    }

    /// True if this tensor is an activation (not a constant parameter).
    pub fn is_activation(&self) -> bool {
        !matches!(self.kind, TensorKind::Weight)
    }
}

impl std::fmt::Display for Tensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {:?} {}", self.name, self.shape, self.dtype)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numel_and_bytes() {
        let t = Tensor::new("x", vec![197, 768], DType::Int8, TensorKind::Input);
        assert_eq!(t.numel(), 197 * 768);
        assert_eq!(t.size_bytes(), 197 * 768);
        let t = Tensor::new("w", vec![768, 3072], DType::F32, TensorKind::Weight);
        assert_eq!(t.size_bytes(), 768 * 3072 * 4);
    }

    #[test]
    fn strides_row_major() {
        let t = Tensor::new("x", vec![4, 3, 2], DType::F32, TensorKind::Input);
        assert_eq!(t.strides(), vec![6, 2, 1]);
        let t1 = Tensor::new("s", vec![5], DType::F32, TensorKind::Input);
        assert_eq!(t1.strides(), vec![1]);
    }

    #[test]
    fn activation_flag() {
        assert!(Tensor::new("x", vec![1], DType::Int8, TensorKind::Input).is_activation());
        assert!(!Tensor::new("w", vec![1], DType::Int8, TensorKind::Weight).is_activation());
    }
}
