//! Graph intermediate representation.
//!
//! A deliberately small, Deeploy-style IR: a [`Graph`] is a list of tensor
//! declarations plus a list of operator nodes in topological order. Every
//! tensor has a static shape (DNN graphs are static — the property the
//! whole paper builds on), a dtype, and a *home* memory level (weights and
//! activations start in L3/L2 and are tiled down to L1 by the FTL engine).

#![forbid(unsafe_code)]

pub mod builder;
mod dtype;
mod graph;
mod loader;
mod op;
mod tensor;

pub use builder::GraphBuilder;
pub use dtype::DType;
pub use graph::{Graph, Node, NodeId, TensorId};
pub use loader::{graph_from_file, graph_from_json, graph_to_json, op_from_bin, op_from_json, op_to_bin, op_to_json};
pub use op::{ActKind, Op};
pub use tensor::{Tensor, TensorKind};
