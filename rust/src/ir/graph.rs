//! The graph container: tensors + nodes, topological order, validation.

#![forbid(unsafe_code)]

use std::collections::HashMap;

use anyhow::{bail, ensure, Context, Result};

use super::{Op, Tensor, TensorKind};

/// Index of a tensor within a [`Graph`].
pub type TensorId = usize;
/// Index of a node within a [`Graph`].
pub type NodeId = usize;

/// One operator application.
#[derive(Debug, Clone)]
pub struct Node {
    /// Node name (unique, used in reports and schedules).
    pub name: String,
    /// The operator.
    pub op: Op,
    /// Input tensor ids, in the op's expected order.
    pub inputs: Vec<TensorId>,
    /// Output tensor id (single-output ops only — enough for this IR).
    pub output: TensorId,
}

/// A static DNN graph.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    /// All tensor declarations.
    pub tensors: Vec<Tensor>,
    /// Operator nodes, stored in topological order (validated).
    pub nodes: Vec<Node>,
}

impl Graph {
    /// Empty graph.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a tensor; errors if the name already exists.
    pub fn add_tensor(&mut self, t: Tensor) -> Result<TensorId> {
        ensure!(
            !self.tensors.iter().any(|x| x.name == t.name),
            "duplicate tensor name {}",
            t.name
        );
        self.tensors.push(t);
        Ok(self.tensors.len() - 1)
    }

    /// Add a node whose output shape is inferred from its inputs. The
    /// output tensor is created with the given name and kind.
    pub fn add_node(
        &mut self,
        name: impl Into<String>,
        op: Op,
        inputs: Vec<TensorId>,
        out_name: impl Into<String>,
        out_kind: TensorKind,
    ) -> Result<(NodeId, TensorId)> {
        let name = name.into();
        for &i in &inputs {
            ensure!(i < self.tensors.len(), "node {name}: input tensor id {i} out of range");
        }
        let shapes: Vec<&[usize]> = inputs.iter().map(|&i| self.tensors[i].shape.as_slice()).collect();
        let out_shape = op
            .infer_shape(&shapes)
            .with_context(|| format!("shape inference failed for node {name}"))?;
        let dtype = self.tensors[inputs[0]].dtype;
        let out = self.add_tensor(Tensor::new(out_name, out_shape, dtype, out_kind))?;
        self.nodes.push(Node { name, op, inputs, output: out });
        Ok((self.nodes.len() - 1, out))
    }

    /// Tensor lookup by name.
    pub fn tensor_by_name(&self, name: &str) -> Option<(TensorId, &Tensor)> {
        self.tensors.iter().enumerate().find(|(_, t)| t.name == name)
    }

    /// Node lookup by name.
    pub fn node_by_name(&self, name: &str) -> Option<(NodeId, &Node)> {
        self.nodes.iter().enumerate().find(|(_, n)| n.name == name)
    }

    /// Producer node of each tensor (None for graph inputs/weights).
    pub fn producers(&self) -> Vec<Option<NodeId>> {
        let mut p = vec![None; self.tensors.len()];
        for (nid, n) in self.nodes.iter().enumerate() {
            p[n.output] = Some(nid);
        }
        p
    }

    /// Consumer nodes of each tensor.
    pub fn consumers(&self) -> Vec<Vec<NodeId>> {
        let mut c = vec![Vec::new(); self.tensors.len()];
        for (nid, n) in self.nodes.iter().enumerate() {
            for &i in &n.inputs {
                c[i].push(nid);
            }
        }
        c
    }

    /// Graph input tensors (kind == Input).
    pub fn inputs(&self) -> Vec<TensorId> {
        self.ids_of_kind(TensorKind::Input)
    }

    /// Graph output tensors (kind == Output).
    pub fn outputs(&self) -> Vec<TensorId> {
        self.ids_of_kind(TensorKind::Output)
    }

    /// Weight tensors.
    pub fn weights(&self) -> Vec<TensorId> {
        self.ids_of_kind(TensorKind::Weight)
    }

    fn ids_of_kind(&self, kind: TensorKind) -> Vec<TensorId> {
        self.tensors
            .iter()
            .enumerate()
            .filter(|(_, t)| t.kind == kind)
            .map(|(i, _)| i)
            .collect()
    }

    /// Total bytes of all weight tensors.
    pub fn weight_bytes(&self) -> usize {
        self.weights().iter().map(|&i| self.tensors[i].size_bytes()).sum()
    }

    /// Total MAC count over all nodes.
    pub fn total_macs(&self) -> usize {
        self.nodes
            .iter()
            .map(|n| {
                let shapes: Vec<&[usize]> = n.inputs.iter().map(|&i| self.tensors[i].shape.as_slice()).collect();
                n.op.macs(&shapes, &self.tensors[n.output].shape)
            })
            .sum()
    }

    /// Validate the whole graph: names unique, node inputs defined before
    /// use (topological order), shapes consistent with `infer_shape`,
    /// every Intermediate has exactly one producer and ≥1 consumer.
    pub fn validate(&self) -> Result<()> {
        let mut names = HashMap::new();
        for (i, t) in self.tensors.iter().enumerate() {
            if let Some(prev) = names.insert(t.name.clone(), i) {
                bail!("duplicate tensor name {} (ids {prev} and {i})", t.name);
            }
            ensure!(!t.shape.is_empty(), "tensor {} has empty shape", t.name);
            ensure!(t.shape.iter().all(|&d| d > 0), "tensor {} has zero dim", t.name);
        }

        let mut defined: Vec<bool> = self
            .tensors
            .iter()
            .map(|t| !matches!(t.kind, TensorKind::Intermediate | TensorKind::Output))
            .collect();
        for n in &self.nodes {
            for &i in &n.inputs {
                ensure!(
                    defined[i],
                    "node {} uses tensor {} before it is produced (not topological)",
                    n.name,
                    self.tensors[i].name
                );
            }
            let shapes: Vec<&[usize]> = n.inputs.iter().map(|&i| self.tensors[i].shape.as_slice()).collect();
            let inferred = n.op.infer_shape(&shapes)?;
            ensure!(
                inferred == self.tensors[n.output].shape,
                "node {}: declared output shape {:?} != inferred {:?}",
                n.name,
                self.tensors[n.output].shape,
                inferred
            );
            ensure!(!defined[n.output], "tensor {} produced twice", self.tensors[n.output].name);
            defined[n.output] = true;
        }

        let consumers = self.consumers();
        let producers = self.producers();
        for (i, t) in self.tensors.iter().enumerate() {
            match t.kind {
                TensorKind::Intermediate => {
                    ensure!(producers[i].is_some(), "intermediate {} has no producer", t.name);
                    ensure!(!consumers[i].is_empty(), "intermediate {} has no consumer", t.name);
                }
                TensorKind::Output => {
                    ensure!(producers[i].is_some(), "output {} has no producer", t.name);
                }
                _ => {}
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ActKind, DType};

    fn mlp() -> Graph {
        let mut g = Graph::new();
        let x = g.add_tensor(Tensor::new("x", vec![8, 16], DType::F32, TensorKind::Input)).unwrap();
        let w1 = g.add_tensor(Tensor::new("w1", vec![16, 32], DType::F32, TensorKind::Weight)).unwrap();
        let gemm = Op::Gemm { transpose_b: false, has_bias: false };
        let (_, h) = g.add_node("fc1", gemm.clone(), vec![x, w1], "h", TensorKind::Intermediate).unwrap();
        let (_, a) = g.add_node("act", Op::Act(ActKind::Gelu), vec![h], "a", TensorKind::Intermediate).unwrap();
        let w2 = g.add_tensor(Tensor::new("w2", vec![32, 16], DType::F32, TensorKind::Weight)).unwrap();
        g.add_node("fc2", gemm, vec![a, w2], "y", TensorKind::Output).unwrap();
        g
    }

    #[test]
    fn build_and_validate() {
        let g = mlp();
        g.validate().unwrap();
        assert_eq!(g.nodes.len(), 3);
        assert_eq!(g.inputs().len(), 1);
        assert_eq!(g.outputs().len(), 1);
        assert_eq!(g.weights().len(), 2);
    }

    #[test]
    fn producers_consumers() {
        let g = mlp();
        let p = g.producers();
        let c = g.consumers();
        let (h, _) = g.tensor_by_name("h").unwrap();
        assert_eq!(p[h], Some(0));
        assert_eq!(c[h], vec![1]);
        let (x, _) = g.tensor_by_name("x").unwrap();
        assert_eq!(p[x], None);
    }

    #[test]
    fn duplicate_name_rejected() {
        let mut g = Graph::new();
        g.add_tensor(Tensor::new("x", vec![1], DType::F32, TensorKind::Input)).unwrap();
        assert!(g.add_tensor(Tensor::new("x", vec![2], DType::F32, TensorKind::Input)).is_err());
    }

    #[test]
    fn total_macs() {
        let g = mlp();
        // fc1: 8*32*16, gelu: 8*32, fc2: 8*16*32
        assert_eq!(g.total_macs(), 8 * 32 * 16 + 8 * 32 + 8 * 16 * 32);
    }

    #[test]
    fn non_topological_rejected() {
        let mut g = mlp();
        g.nodes.swap(0, 2);
        assert!(g.validate().is_err());
    }
}
