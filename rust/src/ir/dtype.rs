//! Element datatypes.

#![forbid(unsafe_code)]


/// Element type of a tensor.
///
/// The paper's kernels are int8 (XpulpV2 SIMD / NE16 NPU); the PJRT
/// numerics path uses f32 because the Pallas oracle kernels are lowered in
/// f32. Cost models only care about `size_bytes`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    /// 8-bit signed integer (quantised activations/weights).
    Int8,
    /// 16-bit signed integer.
    Int16,
    /// 32-bit signed integer (accumulators, requant params).
    Int32,
    /// 16-bit brain float.
    BF16,
    /// 32-bit IEEE float.
    F32,
}

impl DType {
    /// Size of one element in bytes.
    pub const fn size_bytes(self) -> usize {
        match self {
            DType::Int8 => 1,
            DType::Int16 | DType::BF16 => 2,
            DType::Int32 | DType::F32 => 4,
        }
    }

    /// Short lowercase name, matching the JSON network format.
    pub const fn name(self) -> &'static str {
        match self {
            DType::Int8 => "int8",
            DType::Int16 => "int16",
            DType::Int32 => "int32",
            DType::BF16 => "bf16",
            DType::F32 => "f32",
        }
    }

    /// Parse from the JSON network format name.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "int8" | "i8" => DType::Int8,
            "int16" | "i16" => DType::Int16,
            "int32" | "i32" => DType::Int32,
            "bf16" => DType::BF16,
            "f32" | "float32" => DType::F32,
            _ => return None,
        })
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(DType::Int8.size_bytes(), 1);
        assert_eq!(DType::Int16.size_bytes(), 2);
        assert_eq!(DType::Int32.size_bytes(), 4);
        assert_eq!(DType::BF16.size_bytes(), 2);
        assert_eq!(DType::F32.size_bytes(), 4);
    }

    #[test]
    fn parse_roundtrip() {
        for d in [DType::Int8, DType::Int16, DType::Int32, DType::BF16, DType::F32] {
            assert_eq!(DType::parse(d.name()), Some(d));
        }
        assert_eq!(DType::parse("i8"), Some(DType::Int8));
        assert_eq!(DType::parse("nope"), None);
    }
}
