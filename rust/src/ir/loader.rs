//! JSON (de)serialisation of networks — the on-disk interchange format of
//! the deployment flow (`ftl deploy --network net.json`).
//!
//! Format:
//!
//! ```json
//! {
//!   "tensors": [ {"name":"x","shape":[197,768],"dtype":"int8","kind":"input"}, ... ],
//!   "nodes":   [ {"name":"fc1","op":"gemm","attrs":{"transpose_b":false,"has_bias":true},
//!                 "inputs":[0,1,2],"output":3}, ... ]
//! }
//! ```

#![forbid(unsafe_code)]

use anyhow::{anyhow, bail, Context, Result};

use crate::util::bincode::{BinReader, BinWriter};
use crate::util::json::{parse, Json};

use super::{ActKind, DType, Graph, Node, Op, Tensor, TensorKind};

fn kind_name(k: TensorKind) -> &'static str {
    match k {
        TensorKind::Input => "input",
        TensorKind::Output => "output",
        TensorKind::Weight => "weight",
        TensorKind::Intermediate => "intermediate",
    }
}

fn kind_parse(s: &str) -> Result<TensorKind> {
    Ok(match s {
        "input" => TensorKind::Input,
        "output" => TensorKind::Output,
        "weight" => TensorKind::Weight,
        "intermediate" => TensorKind::Intermediate,
        _ => bail!("unknown tensor kind '{s}'"),
    })
}

/// Canonical JSON encoding of one operator (`{"op": name, "attrs": {...}}`)
/// — shared by the network interchange format and the snapshot codec
/// ([`crate::serve::persist`]).
pub fn op_to_json(op: &Op) -> Json {
    let (name, attrs) = match op {
        Op::Gemm { transpose_b, has_bias } => (
            "gemm",
            Json::obj(vec![("transpose_b", Json::Bool(*transpose_b)), ("has_bias", Json::Bool(*has_bias))]),
        ),
        Op::Act(k) => ("act", Json::obj(vec![("kind", Json::str(k.name()))])),
        Op::Add => ("add", Json::obj(vec![])),
        Op::LayerNorm { eps } => ("layernorm", Json::obj(vec![("eps", Json::Num(*eps as f64))])),
        Op::Softmax => ("softmax", Json::obj(vec![])),
        Op::Transpose => ("transpose", Json::obj(vec![])),
        Op::Conv2d { kh, kw, stride, pad } => (
            "conv2d",
            Json::obj(vec![
                ("kh", Json::int(*kh)),
                ("kw", Json::int(*kw)),
                ("stride", Json::int(*stride)),
                ("pad", Json::int(*pad)),
            ]),
        ),
        Op::Requant => ("requant", Json::obj(vec![])),
    };
    Json::obj(vec![("op", Json::str(name)), ("attrs", attrs)])
}

/// Decode the canonical operator encoding (inverse of [`op_to_json`]).
pub fn op_from_json(v: &Json) -> Result<Op> {
    let name = v.get("op")?.as_str()?;
    let attrs = v.get_opt("attrs").cloned().unwrap_or_else(|| Json::obj(vec![]));
    Ok(match name {
        "gemm" => Op::Gemm {
            transpose_b: attrs.get_opt("transpose_b").map(|b| b.as_bool()).transpose()?.unwrap_or(false),
            has_bias: attrs.get_opt("has_bias").map(|b| b.as_bool()).transpose()?.unwrap_or(false),
        },
        "act" => {
            let k = attrs.get("kind")?.as_str()?;
            let kind = match k {
                "gelu" => ActKind::Gelu,
                "relu" => ActKind::Relu,
                "sigmoid" => ActKind::Sigmoid,
                "identity" => ActKind::Identity,
                _ => bail!("unknown activation '{k}'"),
            };
            Op::Act(kind)
        }
        "gelu" => Op::Act(ActKind::Gelu),
        "relu" => Op::Act(ActKind::Relu),
        "add" => Op::Add,
        "layernorm" => {
            Op::LayerNorm { eps: attrs.get_opt("eps").map(|e| e.as_f64()).transpose()?.unwrap_or(1e-5) as f32 }
        }
        "softmax" => Op::Softmax,
        "transpose" => Op::Transpose,
        "conv2d" => Op::Conv2d {
            kh: attrs.get("kh")?.as_usize()?,
            kw: attrs.get("kw")?.as_usize()?,
            stride: attrs.get("stride")?.as_usize()?,
            pad: attrs.get("pad")?.as_usize()?,
        },
        "requant" => Op::Requant,
        _ => bail!("unknown op '{name}'"),
    })
}

// Binary operator tags (`ftl-bin-v1`). Append-only: new operators get new
// tags; repurposing a released tag requires a format-string bump.
const OP_GEMM: u8 = 0;
const OP_ACT: u8 = 1;
const OP_ADD: u8 = 2;
const OP_LAYERNORM: u8 = 3;
const OP_SOFTMAX: u8 = 4;
const OP_TRANSPOSE: u8 = 5;
const OP_CONV2D: u8 = 6;
const OP_REQUANT: u8 = 7;

/// Canonical binary encoding of one operator — the `ftl-bin-v1`
/// counterpart of [`op_to_json`] (see [`crate::serve::persist`]).
pub fn op_to_bin(op: &Op, w: &mut BinWriter) {
    match op {
        Op::Gemm { transpose_b, has_bias } => {
            w.u8(OP_GEMM);
            w.bool(*transpose_b);
            w.bool(*has_bias);
        }
        Op::Act(k) => {
            w.u8(OP_ACT);
            w.str(k.name());
        }
        Op::Add => w.u8(OP_ADD),
        Op::LayerNorm { eps } => {
            w.u8(OP_LAYERNORM);
            w.f32(*eps);
        }
        Op::Softmax => w.u8(OP_SOFTMAX),
        Op::Transpose => w.u8(OP_TRANSPOSE),
        Op::Conv2d { kh, kw, stride, pad } => {
            w.u8(OP_CONV2D);
            w.usize(*kh);
            w.usize(*kw);
            w.usize(*stride);
            w.usize(*pad);
        }
        Op::Requant => w.u8(OP_REQUANT),
    }
}

/// Decode the canonical binary operator encoding (inverse of
/// [`op_to_bin`]).
pub fn op_from_bin(r: &mut BinReader) -> Result<Op> {
    Ok(match r.u8()? {
        OP_GEMM => Op::Gemm { transpose_b: r.bool()?, has_bias: r.bool()? },
        OP_ACT => {
            let k = r.str()?;
            let kind = match k.as_str() {
                "gelu" => ActKind::Gelu,
                "relu" => ActKind::Relu,
                "sigmoid" => ActKind::Sigmoid,
                "identity" => ActKind::Identity,
                _ => bail!("unknown activation '{k}'"),
            };
            Op::Act(kind)
        }
        OP_ADD => Op::Add,
        OP_LAYERNORM => Op::LayerNorm { eps: r.f32()? },
        OP_SOFTMAX => Op::Softmax,
        OP_TRANSPOSE => Op::Transpose,
        OP_CONV2D => Op::Conv2d { kh: r.usize()?, kw: r.usize()?, stride: r.usize()?, pad: r.usize()? },
        OP_REQUANT => Op::Requant,
        t => bail!("unknown binary op tag {t}"),
    })
}

/// Parse a graph from JSON text and validate it.
pub fn graph_from_json(text: &str) -> Result<Graph> {
    let v = parse(text).context("parsing network JSON")?;
    let mut g = Graph::new();
    for (i, t) in v.get("tensors")?.as_arr()?.iter().enumerate() {
        let name = t.get("name")?.as_str()?.to_string();
        let shape: Vec<usize> =
            t.get("shape")?.as_arr()?.iter().map(|d| d.as_usize()).collect::<Result<_>>()?;
        let dtype = DType::parse(t.get("dtype")?.as_str()?)
            .ok_or_else(|| anyhow!("tensor {i}: unknown dtype"))?;
        let kind = kind_parse(t.get("kind")?.as_str()?)?;
        g.add_tensor(Tensor::new(name, shape, dtype, kind))?;
    }
    for n in v.get("nodes")?.as_arr()? {
        let name = n.get("name")?.as_str()?.to_string();
        let op = op_from_json(n)?;
        let inputs: Vec<usize> =
            n.get("inputs")?.as_arr()?.iter().map(|i| i.as_usize()).collect::<Result<_>>()?;
        let output = n.get("output")?.as_usize()?;
        for &i in inputs.iter().chain(std::iter::once(&output)) {
            if i >= g.tensors.len() {
                bail!("node {name}: tensor id {i} out of range");
            }
        }
        g.nodes.push(Node { name, op, inputs, output });
    }
    g.validate().context("network JSON failed validation")?;
    Ok(g)
}

/// Serialise a graph to pretty JSON.
pub fn graph_to_json(g: &Graph) -> Result<String> {
    let tensors: Vec<Json> = g
        .tensors
        .iter()
        .map(|t| {
            Json::obj(vec![
                ("name", Json::str(&t.name)),
                ("shape", Json::Arr(t.shape.iter().map(|&d| Json::int(d)).collect())),
                ("dtype", Json::str(t.dtype.name())),
                ("kind", Json::str(kind_name(t.kind))),
            ])
        })
        .collect();
    let nodes: Vec<Json> = g
        .nodes
        .iter()
        .map(|n| {
            let mut obj = op_to_json(&n.op);
            if let Json::Obj(m) = &mut obj {
                m.insert("name".into(), Json::str(&n.name));
                m.insert("inputs".into(), Json::Arr(n.inputs.iter().map(|&i| Json::int(i)).collect()));
                m.insert("output".into(), Json::int(n.output));
            }
            obj
        })
        .collect();
    Ok(Json::obj(vec![("tensors", Json::Arr(tensors)), ("nodes", Json::Arr(nodes))]).pretty())
}

/// Load a graph from a file path.
pub fn graph_from_file(path: &std::path::Path) -> Result<Graph> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
    graph_from_json(&text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::{vit_mlp, vit_mlp_block};
    use crate::ir::DType;

    #[test]
    fn json_roundtrip() {
        let g = vit_mlp(197, 768, 3072, DType::Int8);
        let text = graph_to_json(&g).unwrap();
        let g2 = graph_from_json(&text).unwrap();
        assert_eq!(g.tensors.len(), g2.tensors.len());
        assert_eq!(g.nodes.len(), g2.nodes.len());
        for (a, b) in g.tensors.iter().zip(&g2.tensors) {
            assert_eq!(a, b);
        }
        for (a, b) in g.nodes.iter().zip(&g2.nodes) {
            assert_eq!(a.op, b.op);
            assert_eq!(a.inputs, b.inputs);
            assert_eq!(a.output, b.output);
        }
    }

    #[test]
    fn roundtrip_all_ops() {
        let g = vit_mlp_block(16, 32, 64, DType::F32);
        let text = graph_to_json(&g).unwrap();
        let g2 = graph_from_json(&text).unwrap();
        g2.validate().unwrap();
        assert_eq!(g.nodes.len(), g2.nodes.len());
    }

    #[test]
    fn invalid_json_rejected() {
        assert!(graph_from_json("{").is_err());
        // valid JSON, invalid graph (node uses undefined tensor id)
        let bad = r#"{"tensors":[],"nodes":[{"name":"n","op":"add","inputs":[0,1],"output":2}]}"#;
        assert!(graph_from_json(bad).is_err());
        // unknown op
        let bad = r#"{"tensors":[{"name":"x","shape":[1],"dtype":"int8","kind":"input"}],
                      "nodes":[{"name":"n","op":"warp","inputs":[0],"output":0}]}"#;
        assert!(graph_from_json(bad).is_err());
    }
}
