//! Lock-free, log-bucketed latency histograms (HDR-style).
//!
//! A [`Histogram`] covers the full `u64` range (we record microseconds,
//! but nothing assumes a unit) with a fixed 496-slot bucket table:
//!
//! - values `0..8` get one exact bucket each;
//! - every power-of-two decade `[2^e, 2^(e+1))` above that is split into
//!   `SUB = 8` equal sub-buckets.
//!
//! A bucket at exponent `e` spans `2^(e-3)` values starting at
//! `(8 + sub) << (e - 3)`, so the half-width of any bucket is at most
//! `1/16` of its lower bound and the midpoint we report is within
//! **1/8 relative error** of any value that landed in it (see
//! [`Histogram::MAX_RELATIVE_ERROR_DEN`]; the bound is exercised by a
//! property test in `tests/latency.rs`).
//!
//! The hot path is integer-only and lock-free: `record` is one
//! `leading_zeros` + two shifts to find the bucket, then three relaxed
//! atomic RMWs (bucket slot, count, sum) plus `fetch_max`/`fetch_min`
//! for the exact extremes. Cumulative fields saturate via
//! [`Counter`](super::Counter) so a long-lived replica cannot wrap.
//! Quantile reads walk the table without stopping writers; a snapshot
//! taken while writers are active is a *consistent-enough* telemetry
//! view, not a linearisable cut.

#![forbid(unsafe_code)]

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::json::Json;

use super::Counter;

/// Sub-bucket bits per power-of-two decade: each decade `[2^e, 2^(e+1))`
/// splits into `2^SUB_BITS` equal buckets.
const SUB_BITS: u32 = 3;
/// Sub-buckets per decade.
const SUB: u64 = 1 << SUB_BITS;
/// Total bucket slots: indices 0..=495 cover `0..=u64::MAX`.
const BUCKETS: usize = ((64 - SUB_BITS as usize) << SUB_BITS as usize) + SUB as usize;

/// Bucket index for a value. Exact for `v < 8`; logarithmic above.
#[inline]
fn bucket_index(v: u64) -> usize {
    if v < SUB {
        v as usize
    } else {
        let msb = 63 - v.leading_zeros(); // >= SUB_BITS
        let shift = msb - SUB_BITS;
        let decade = (shift + 1) as usize;
        (decade << SUB_BITS as usize) | ((v >> shift) as usize & (SUB as usize - 1))
    }
}

/// Inclusive lower bound and width of bucket `i` (width 1 for exact
/// buckets). `lo + width - 1` is the inclusive upper bound.
#[inline]
fn bucket_bounds(i: usize) -> (u64, u64) {
    let sub_mask = SUB as usize - 1;
    if i < SUB as usize {
        (i as u64, 1)
    } else {
        let decade = (i >> SUB_BITS as usize) as u32; // >= 1
        let sub = (i & sub_mask) as u64;
        let shift = decade - 1;
        ((SUB + sub) << shift, 1u64 << shift)
    }
}

/// Representative value reported for bucket `i`: the bucket midpoint,
/// which halves the worst-case error vs. reporting an edge.
#[inline]
fn bucket_mid(i: usize) -> u64 {
    let (lo, width) = bucket_bounds(i);
    lo + (width - 1) / 2
}

/// A lock-free log-bucketed histogram. See the module docs for layout
/// and error bounds.
pub struct Histogram {
    buckets: Box<[AtomicU64]>,
    count: Counter,
    sum: Counter,
    max: AtomicU64,
    min: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Denominator of the documented worst-case relative error: the
    /// midpoint of the bucket a value lands in differs from the value by
    /// at most `value / 8`.
    pub const MAX_RELATIVE_ERROR_DEN: u64 = SUB;

    /// New empty histogram.
    pub fn new() -> Self {
        Self {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: Counter::new(0),
            sum: Counter::new(0),
            max: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
        }
    }

    /// Record one value. Integer-only, lock-free, wait-free on every
    /// architecture with native fetch_add.
    pub fn record(&self, v: u64) {
        let i = bucket_index(v);
        // Bucket slots wrap only after 2^64 samples in ONE bucket; the
        // aggregate `count`/`sum` saturate via `Counter`.
        self.buckets[i].fetch_add(1, Ordering::Relaxed);
        self.count.inc();
        self.sum.add(v);
        self.max.fetch_max(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
    }

    /// Record a duration in whole microseconds (saturating at `u64::MAX`).
    pub fn record_duration(&self, d: Duration) {
        self.record(u64::try_from(d.as_micros()).unwrap_or(u64::MAX));
    }

    /// Fold another histogram's samples into this one.
    pub fn merge(&self, other: &Histogram) {
        for (i, b) in other.buckets.iter().enumerate() {
            let n = b.load(Ordering::Relaxed);
            if n > 0 {
                self.buckets[i].fetch_add(n, Ordering::Relaxed);
            }
        }
        self.count.add(other.count.get());
        self.sum.add(other.sum.get());
        self.max.fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
        self.min.fetch_min(other.min.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count.get()
    }

    /// Saturating sum of recorded values.
    pub fn sum(&self) -> u64 {
        self.sum.get()
    }

    /// Exact maximum recorded value (0 when empty).
    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Exact minimum recorded value (0 when empty).
    pub fn min(&self) -> u64 {
        if self.count() == 0 { 0 } else { self.min.load(Ordering::Relaxed) }
    }

    /// Value at quantile `q` in `[0, 1]`: the midpoint of the bucket
    /// holding the sample of rank `clamp(ceil(q * count), 1, count)`
    /// (rank 1 = smallest). Returns 0 for an empty histogram; `q >= 1`
    /// returns the *bucket* of the largest sample — use [`max`] for the
    /// exact extreme.
    ///
    /// [`max`]: Histogram::max
    pub fn quantile(&self, q: f64) -> u64 {
        let count = self.count();
        if count == 0 {
            return 0;
        }
        let rank = ((q * count as f64).ceil() as u64).clamp(1, count);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen = seen.saturating_add(b.load(Ordering::Relaxed));
            if seen >= rank {
                return bucket_mid(i);
            }
        }
        // Racing writers can make `count` run ahead of the bucket walk;
        // fall back to the exact max.
        self.max()
    }

    /// Immutable snapshot of the full bucket table plus aggregates.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.count(),
            sum: self.sum(),
            max: self.max(),
            min: self.min(),
        }
    }

    /// JSON summary: count/sum/min/max, p50/p90/p99, and the sparse
    /// non-empty bucket table as `[index, midpoint, count]` triples.
    /// Values are emitted as `f64` (saturated counters can exceed
    /// `i64::MAX`, which the strict `Json::int` helper rejects).
    pub fn to_json(&self) -> Json {
        let jnum = |v: u64| Json::Num(v as f64);
        let buckets: Vec<Json> = self
            .buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let n = b.load(Ordering::Relaxed);
                (n > 0).then(|| Json::Arr(vec![jnum(i as u64), jnum(bucket_mid(i)), jnum(n)]))
            })
            .collect();
        Json::obj(vec![
            ("count", jnum(self.count())),
            ("sum", jnum(self.sum())),
            ("min", jnum(self.min())),
            ("max", jnum(self.max())),
            ("p50", jnum(self.quantile(0.50))),
            ("p90", jnum(self.quantile(0.90))),
            ("p99", jnum(self.quantile(0.99))),
            ("buckets", Json::Arr(buckets)),
        ])
    }

    /// One-line `p50/p90/p99/max` summary for logs, e.g. `p50=12us`.
    pub fn summary_line(&self, unit: &str) -> String {
        format!(
            "p50={}{unit} p90={}{unit} p99={}{unit} max={}{unit} n={}",
            self.quantile(0.50),
            self.quantile(0.90),
            self.quantile(0.99),
            self.max(),
            self.count()
        )
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Histogram")
            .field("count", &self.count())
            .field("p50", &self.quantile(0.5))
            .field("max", &self.max())
            .finish()
    }
}

/// Plain-data snapshot of a [`Histogram`]; comparable with `==`, which
/// the per-lane-merge invariant test relies on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistogramSnapshot {
    /// Per-bucket sample counts (dense, `BUCKETS` entries).
    pub buckets: Vec<u64>,
    /// Samples recorded.
    pub count: u64,
    /// Saturating sum of values.
    pub sum: u64,
    /// Exact max (0 when empty).
    pub max: u64,
    /// Exact min (0 when empty).
    pub min: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_exact_below_eight() {
        for v in 0..8u64 {
            assert_eq!(bucket_index(v), v as usize);
            let (lo, width) = bucket_bounds(v as usize);
            assert_eq!((lo, width), (v, 1));
        }
    }

    #[test]
    fn bucket_index_is_contiguous_and_bounds_cover() {
        // Walking v upward never skips an index, and every v falls inside
        // its bucket's [lo, lo+width) range.
        let mut prev = 0usize;
        for v in [
            0u64, 1, 7, 8, 9, 15, 16, 17, 31, 32, 63, 64, 100, 1000, 4096, 65535, 1 << 20,
            (1 << 40) + 12345, u64::MAX / 2, u64::MAX - 1, u64::MAX,
        ] {
            let i = bucket_index(v);
            assert!(i >= prev, "index must be monotone in v (v={v}, i={i}, prev={prev})");
            assert!(i < BUCKETS, "index {i} out of table for v={v}");
            let (lo, width) = bucket_bounds(i);
            assert!(lo <= v, "v={v} below bucket lo={lo}");
            assert!(v - lo < width, "v={v} past bucket [{}..{}]", lo, lo + (width - 1));
            prev = i;
        }
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn exhaustive_small_range_roundtrip() {
        for v in 0..100_000u64 {
            let i = bucket_index(v);
            let (lo, width) = bucket_bounds(i);
            assert!(lo <= v && v - lo < width, "v={v} i={i} lo={lo} width={width}");
            let mid = bucket_mid(i);
            let err = mid.abs_diff(v);
            assert!(
                err.saturating_mul(Histogram::MAX_RELATIVE_ERROR_DEN) <= v,
                "relative error bound broken: v={v} mid={mid}"
            );
        }
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.min(), 0);
    }

    #[test]
    fn single_value_quantiles() {
        let h = Histogram::new();
        h.record(42);
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile(q), 42, "q={q}");
        }
        assert_eq!(h.max(), 42);
        assert_eq!(h.min(), 42);
        assert_eq!(h.sum(), 42);
    }

    #[test]
    fn quantiles_order_and_max_is_exact() {
        let h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v * 3 + 1);
        }
        let (p50, p90, p99) = (h.quantile(0.5), h.quantile(0.9), h.quantile(0.99));
        assert!(p50 <= p90 && p90 <= p99, "quantiles must be monotone: {p50} {p90} {p99}");
        assert_eq!(h.max(), 3001, "max is tracked exactly, not bucketed");
        assert_eq!(h.min(), 4);
        assert_eq!(h.count(), 1000);
    }

    #[test]
    fn merge_accumulates_counts_and_extremes() {
        let (a, b) = (Histogram::new(), Histogram::new());
        for v in [1u64, 2, 3] {
            a.record(v);
        }
        for v in [1000u64, 2000] {
            b.record(v);
        }
        let c = Histogram::new();
        c.merge(&a);
        c.merge(&b);
        assert_eq!(c.count(), 5);
        assert_eq!(c.sum(), 3006);
        assert_eq!(c.max(), 2000);
        assert_eq!(c.min(), 1);
        // Merge is bucket-exact: snapshots compose additively.
        let mut want = a.snapshot();
        let bs = b.snapshot();
        for (w, x) in want.buckets.iter_mut().zip(bs.buckets.iter()) {
            *w += x;
        }
        want.count += bs.count;
        want.sum += bs.sum;
        want.max = want.max.max(bs.max);
        want.min = want.min.min(bs.min);
        assert_eq!(c.snapshot(), want);
    }

    #[test]
    fn to_json_has_summary_and_sparse_buckets() {
        let h = Histogram::new();
        h.record(5);
        h.record(5);
        h.record(100);
        let j = h.to_json();
        assert_eq!(j.get("count").unwrap().as_u64().unwrap(), 3);
        assert_eq!(j.get("max").unwrap().as_u64().unwrap(), 100);
        assert_eq!(j.get("p50").unwrap().as_u64().unwrap(), 5);
        let buckets = j.get("buckets").unwrap().as_arr().unwrap();
        assert_eq!(buckets.len(), 2, "only non-empty buckets are listed");
    }

    #[test]
    fn record_duration_uses_micros() {
        let h = Histogram::new();
        h.record_duration(Duration::from_millis(2));
        assert_eq!(h.min(), h.max());
        let v = h.max();
        assert_eq!(v, 2000);
    }

    #[test]
    fn concurrent_records_all_land() {
        use std::sync::Arc;
        let h = Arc::new(Histogram::new());
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..1000u64 {
                        h.record(t * 1000 + i);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 8000);
        assert_eq!(h.max(), 7999);
        assert_eq!(h.min(), 0);
    }
}
