//! Saturating cumulative counters.
//!
//! Every long-lived counter in the serving stack (cache hits, lane sheds,
//! solver node counts, …) is monotone and only ever *reported*, never used
//! for arithmetic that must round-trip. A bare `fetch_add` wraps on
//! overflow (and `+=` panics in debug builds), which for a replica that
//! runs for months means a counter can silently lap `u64::MAX` and report
//! garbage. [`Counter`] pins such counters at `u64::MAX` instead: once
//! saturated they stay saturated, which a scraper can at least recognise.
//!
//! The hot path stays a single `fetch_add`; saturation is detected from
//! the returned previous value and repaired with a plain store, so there
//! is no CAS loop to contend on. A concurrent reader may observe one
//! wrapped intermediate value in the instant between the wrap and the
//! repair — acceptable for telemetry, and the counter converges to
//! `u64::MAX` immediately after.

#![forbid(unsafe_code)]

use std::sync::atomic::{AtomicU64, Ordering};

/// A monotone, saturating `u64` counter for telemetry.
///
/// Like `AtomicU64` but `add` saturates at `u64::MAX` instead of
/// wrapping. All operations use relaxed ordering: counters are
/// independent statistics, not synchronisation edges.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// New counter starting at `value`.
    pub const fn new(value: u64) -> Self {
        Self(AtomicU64::new(value))
    }

    /// Add `n`, saturating at `u64::MAX`.
    pub fn add(&self, n: u64) {
        if n == 0 {
            return;
        }
        let prev = self.0.fetch_add(n, Ordering::Relaxed);
        if prev > u64::MAX - n {
            // The fetch_add wrapped; pin at the ceiling. Concurrent adds
            // racing here all store the same value, so the repair is
            // idempotent.
            self.0.store(u64::MAX, Ordering::Relaxed);
        }
    }

    /// Add one, saturating.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Raise the stored value to at least `v` (for high-water marks).
    pub fn fetch_max(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// Overwrite the value (for counters restored from a snapshot).
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }
}

impl From<u64> for Counter {
    fn from(v: u64) -> Self {
        Self::new(v)
    }
}

impl Clone for Counter {
    fn clone(&self) -> Self {
        Self::new(self.get())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_inc_accumulate() {
        let c = Counter::new(0);
        c.inc();
        c.add(41);
        assert_eq!(c.get(), 42);
    }

    #[test]
    fn add_saturates_at_max() {
        let c = Counter::new(u64::MAX - 1);
        c.add(5);
        assert_eq!(c.get(), u64::MAX, "overflowing add must pin at u64::MAX");
        c.inc();
        assert_eq!(c.get(), u64::MAX, "saturated counter must stay saturated");
        c.add(u64::MAX);
        assert_eq!(c.get(), u64::MAX);
    }

    #[test]
    fn exact_boundary_is_not_saturation() {
        let c = Counter::new(u64::MAX - 5);
        c.add(5); // lands exactly on MAX without wrapping
        assert_eq!(c.get(), u64::MAX);
    }

    #[test]
    fn zero_add_is_a_noop() {
        let c = Counter::new(7);
        c.add(0);
        assert_eq!(c.get(), 7);
    }

    #[test]
    fn fetch_max_keeps_high_water_mark() {
        let c = Counter::new(3);
        c.fetch_max(10);
        c.fetch_max(4);
        assert_eq!(c.get(), 10);
    }

    #[test]
    fn saturates_under_concurrent_adds() {
        use std::sync::Arc;
        let c = Arc::new(Counter::new(u64::MAX - 64));
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..64 {
                        c.add(3);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(c.get(), u64::MAX);
    }
}
