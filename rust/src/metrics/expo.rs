//! Prometheus-style text exposition for counters and histograms.
//!
//! The `METRICS` protocol command renders every cumulative counter and
//! latency histogram as one sample per line:
//!
//! ```text
//! ftl_batch_lanes_default_served 42
//! ftl_latency_us{lane="default",temp="warm",quantile="0.5"} 13
//! # EOF
//! ```
//!
//! The grammar is the useful subset of the Prometheus text format —
//! `name{label="value",…} value` with `#` comment lines — terminated by
//! a `# EOF` marker (OpenMetrics-style) so a line-oriented client knows
//! when the multi-line response ends. [`parse`] is the matching strict
//! reader; the serve self-test and CI round-trip every exposition
//! through it so the format cannot silently drift.
//!
//! Counters come out of the nested `stats_json` tree by flattening
//! object paths with `_` ([`flatten`]); histograms are emitted with
//! proper labels ([`hist_samples`]) rather than path-mangled names.

#![forbid(unsafe_code)]

use anyhow::{bail, Result};

use crate::util::json::Json;

use super::hist::Histogram;

/// One exposition sample: `name{labels} value`.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    /// Metric name (already sanitised).
    pub name: String,
    /// Label pairs, possibly empty.
    pub labels: Vec<(String, String)>,
    /// Sample value.
    pub value: f64,
}

impl Sample {
    /// Unlabelled sample.
    pub fn new(name: impl Into<String>, value: f64) -> Self {
        Self { name: sanitize(&name.into()), labels: Vec::new(), value }
    }

    /// Labelled sample.
    pub fn labelled(name: &str, labels: &[(&str, &str)], value: f64) -> Self {
        Self {
            name: sanitize(name),
            labels: labels.iter().map(|&(k, v)| (sanitize(k), v.to_string())).collect(),
            value: value_or_zero(value),
        }
    }

    /// Render as one exposition line.
    pub fn line(&self) -> String {
        let mut s = self.name.clone();
        if !self.labels.is_empty() {
            s.push('{');
            for (i, (k, v)) in self.labels.iter().enumerate() {
                if i > 0 {
                    s.push(',');
                }
                s.push_str(k);
                s.push_str("=\"");
                s.push_str(&v.replace('\\', "\\\\").replace('"', "\\\""));
                s.push('"');
            }
            s.push('}');
        }
        s.push(' ');
        if self.value.fract() == 0.0 && self.value.abs() < 2f64.powi(53) {
            s.push_str(&format!("{}", self.value as i64));
        } else {
            s.push_str(&format!("{}", self.value));
        }
        s
    }
}

fn value_or_zero(v: f64) -> f64 {
    if v.is_finite() { v } else { 0.0 }
}

/// Clamp a name to the exposition charset `[a-zA-Z0-9_:]` (leading
/// digits get a `_` prefix; every other invalid char becomes `_`).
pub fn sanitize(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    for (i, c) in name.chars().enumerate() {
        let ok = c.is_ascii_alphabetic() || c == '_' || c == ':' || (i > 0 && c.is_ascii_digit());
        if i == 0 && c.is_ascii_digit() {
            out.push('_');
            out.push(c);
        } else if ok {
            out.push(c);
        } else {
            out.push('_');
        }
    }
    if out.is_empty() {
        out.push('_');
    }
    out
}

/// Flatten the numeric and boolean leaves of a JSON tree into samples,
/// joining object paths with `_` under `prefix`. Arrays, strings and
/// nulls are skipped (they are not scrapeable scalars); so is any
/// object key listed in `skip_keys` — the caller uses that to keep
/// histogram subtrees out of the flat namespace and emit them labelled
/// via [`hist_samples`] instead.
pub fn flatten(prefix: &str, v: &Json, skip_keys: &[&str]) -> Vec<Sample> {
    let mut out = Vec::new();
    flatten_into(prefix, v, skip_keys, &mut out);
    out
}

fn flatten_into(path: &str, v: &Json, skip_keys: &[&str], out: &mut Vec<Sample>) {
    match v {
        Json::Num(n) => out.push(Sample { name: sanitize(path), labels: Vec::new(), value: value_or_zero(*n) }),
        Json::Bool(b) => out.push(Sample { name: sanitize(path), labels: Vec::new(), value: f64::from(*b) }),
        Json::Obj(m) => {
            for (k, child) in m {
                if skip_keys.contains(&k.as_str()) {
                    continue;
                }
                flatten_into(&format!("{path}_{k}"), child, skip_keys, out);
            }
        }
        Json::Null | Json::Str(_) | Json::Arr(_) => {}
    }
}

/// Samples for one histogram: `<name>_count`, `<name>_sum`, `<name>_min`,
/// `<name>_max` plus `quantile`-labelled p50/p90/p99 lines, all carrying
/// `labels`.
pub fn hist_samples(name: &str, labels: &[(&str, &str)], h: &Histogram) -> Vec<Sample> {
    let with = |extra: Option<(&str, &str)>, suffix: &str, value: f64| {
        let mut all: Vec<(&str, &str)> = labels.to_vec();
        if let Some(kv) = extra {
            all.push(kv);
        }
        Sample::labelled(&format!("{name}{suffix}"), &all, value)
    };
    vec![
        with(None, "_count", h.count() as f64),
        with(None, "_sum", h.sum() as f64),
        with(None, "_min", h.min() as f64),
        with(None, "_max", h.max() as f64),
        with(Some(("quantile", "0.5")), "", h.quantile(0.50) as f64),
        with(Some(("quantile", "0.9")), "", h.quantile(0.90) as f64),
        with(Some(("quantile", "0.99")), "", h.quantile(0.99) as f64),
    ]
}

/// Render samples as exposition text, terminated by `# EOF`.
pub fn render(samples: &[Sample]) -> String {
    let mut s = String::new();
    for sample in samples {
        s.push_str(&sample.line());
        s.push('\n');
    }
    s.push_str("# EOF\n");
    s
}

/// Strict parser for the exposition format: every non-comment line must
/// be `name{label="value",…} value`. Returns the samples, or the first
/// offending line. This is the round-trip validator used by the serve
/// self-test and the CI metrics smoke step.
pub fn parse(text: &str) -> Result<Vec<Sample>> {
    let mut samples = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        samples.push(parse_line(line).map_err(|e| e.context(format!("line {}: {raw:?}", lineno + 1)))?);
    }
    Ok(samples)
}

fn parse_line(line: &str) -> Result<Sample> {
    let name_end = line
        .char_indices()
        .find(|&(i, c)| {
            !(c.is_ascii_alphanumeric() || c == '_' || c == ':') || (i == 0 && c.is_ascii_digit())
        })
        .map(|(i, _)| i)
        .unwrap_or(line.len());
    if name_end == 0 {
        bail!("metric name must start with [a-zA-Z_:]");
    }
    let name = &line[..name_end];
    let mut rest = &line[name_end..];
    let mut labels = Vec::new();
    if let Some(stripped) = rest.strip_prefix('{') {
        let close = stripped.find('}').ok_or_else(|| anyhow::anyhow!("unterminated label set"))?;
        let body = &stripped[..close];
        rest = &stripped[close + 1..];
        for pair in body.split(',').filter(|p| !p.is_empty()) {
            let (k, v) = pair.split_once('=').ok_or_else(|| anyhow::anyhow!("label without '='"))?;
            let v = v
                .strip_prefix('"')
                .and_then(|v| v.strip_suffix('"'))
                .ok_or_else(|| anyhow::anyhow!("label value must be quoted"))?;
            if k.is_empty() || !k.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
                bail!("bad label name {k:?}");
            }
            labels.push((k.to_string(), v.replace("\\\"", "\"").replace("\\\\", "\\")));
        }
    }
    let value_text = rest.trim();
    if value_text.is_empty() {
        bail!("missing sample value");
    }
    let value: f64 = value_text.parse().map_err(|_| anyhow::anyhow!("bad sample value {value_text:?}"))?;
    Ok(Sample { name: name.to_string(), labels, value })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sanitize_clamps_charset() {
        assert_eq!(sanitize("batch.lanes-default"), "batch_lanes_default");
        assert_eq!(sanitize("9lives"), "_9lives");
        assert_eq!(sanitize("ok_name:x9"), "ok_name:x9");
        assert_eq!(sanitize(""), "_");
    }

    #[test]
    fn flatten_walks_objects_and_skips_non_scalars() {
        let v = crate::util::json::parse(
            r#"{"cache":{"hits":3,"tags":["a"]},"name":"x","deep":{"latency":{"p50":9},"ok":true}}"#,
        )
        .unwrap();
        let samples = flatten("ftl", &v, &["latency"]);
        let names: Vec<&str> = samples.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["ftl_cache_hits", "ftl_deep_ok"]);
        assert_eq!(samples[0].value, 3.0);
        assert_eq!(samples[1].value, 1.0);
    }

    #[test]
    fn sample_line_renders_labels() {
        let s = Sample::labelled("ftl_latency_us", &[("lane", "gold"), ("temp", "warm")], 12.0);
        assert_eq!(s.line(), r#"ftl_latency_us{lane="gold",temp="warm"} 12"#);
        assert_eq!(Sample::new("x", 1.5).line(), "x 1.5");
    }

    #[test]
    fn render_parse_roundtrip() {
        let h = Histogram::new();
        for v in [10u64, 20, 30, 1000] {
            h.record(v);
        }
        let mut samples = flatten(
            "ftl",
            &crate::util::json::parse(r#"{"batch":{"served":7}}"#).unwrap(),
            &[],
        );
        samples.extend(hist_samples("ftl_latency_us", &[("lane", "default"), ("temp", "warm")], &h));
        let text = render(&samples);
        assert!(text.ends_with("# EOF\n"));
        let back = parse(&text).unwrap();
        assert_eq!(back.len(), samples.len());
        assert_eq!(back[0].name, "ftl_batch_served");
        assert_eq!(back[0].value, 7.0);
        let q50 = back
            .iter()
            .find(|s| s.labels.iter().any(|(k, v)| k == "quantile" && v == "0.5"))
            .expect("labelled quantile sample");
        assert_eq!(q50.name, "ftl_latency_us");
        assert!(q50.labels.contains(&("lane".to_string(), "default".to_string())));
    }

    #[test]
    fn parse_rejects_malformed_lines() {
        assert!(parse("ok 1\nbad{x=nope} 2").is_err());
        assert!(parse("{\"json\": 1}").is_err());
        assert!(parse("name_only").is_err());
        assert!(parse("name twelve").is_err());
        assert!(parse("name{k=\"v\" 3").is_err());
        assert!(parse("# comment only\n\n").unwrap().is_empty());
    }

    #[test]
    fn parse_handles_escaped_label_values() {
        let s = Sample::labelled("m", &[("k", "a\"b")], 1.0);
        let back = parse(&s.line()).unwrap();
        assert_eq!(back[0].labels[0].1, "a\"b");
    }
}
