//! Report formatting and telemetry primitives: human tables,
//! machine-readable JSON for every benchmark/deploy run (consumed by
//! EXPERIMENTS.md and the bench harnesses), plus the observability
//! building blocks of the serving stack — saturating [`Counter`]s,
//! lock-free log-bucketed [`Histogram`]s ([`hist`]) and the
//! Prometheus-style text exposition used by the `METRICS` protocol
//! command ([`expo`]).

#![forbid(unsafe_code)]

pub mod counter;
pub mod expo;
pub mod hist;

pub use counter::Counter;
pub use hist::{Histogram, HistogramSnapshot};

use crate::dma::DmaStats;
use crate::memory::Level;
use crate::sim::SimReport;
use crate::soc::SocConfig;
use crate::util::json::Json;

/// Simple fixed-width table writer.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with column headers.
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (must match the header width).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Render a simulation report as a human-readable phase table.
pub fn sim_table(rep: &SimReport, soc: &SocConfig) -> String {
    let mut t = Table::new(&["phase", "cycles", "ms", "cluster%", "npu%", "dmaL2%", "dmaL3%", "bound"]);
    for p in &rep.phases {
        let pct = |busy: u64| if p.cycles == 0 { 0.0 } else { 100.0 * busy as f64 / p.cycles as f64 };
        t.row(&[
            p.name.clone(),
            p.cycles.to_string(),
            format!("{:.3}", soc.cycles_to_ms(p.cycles)),
            format!("{:.1}", pct(p.cluster_busy)),
            format!("{:.1}", pct(p.npu_busy)),
            format!("{:.1}", pct(p.dma_l2_busy)),
            format!("{:.1}", pct(p.dma_l3_busy)),
            p.bound.to_string(),
        ]);
    }
    t.row(&[
        "TOTAL".into(),
        rep.total_cycles.to_string(),
        format!("{:.3}", rep.ms(soc)),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
    ]);
    t.render()
}

/// DMA stats as a table.
pub fn dma_table(d: &DmaStats) -> String {
    let mut t = Table::new(&["channel", "transfers", "KiB"]);
    for lvl in [Level::L2, Level::L3] {
        t.row(&[
            format!("{}-DMA", lvl),
            d.transfers_at(lvl).to_string(),
            format!("{:.1}", d.bytes_at(lvl) as f64 / 1024.0),
        ]);
    }
    t.row(&[
        "TOTAL".into(),
        d.total_transfers().to_string(),
        format!("{:.1}", d.total_bytes() as f64 / 1024.0),
    ]);
    t.render()
}

/// Simulation report as JSON (for the bench harness / EXPERIMENTS.md).
pub fn sim_json(rep: &SimReport, soc: &SocConfig) -> Json {
    let phases: Vec<Json> = rep
        .phases
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("name", Json::str(&p.name)),
                ("cycles", Json::int(p.cycles as usize)),
                ("cluster_busy", Json::int(p.cluster_busy as usize)),
                ("npu_busy", Json::int(p.npu_busy as usize)),
                ("dma_l2_busy", Json::int(p.dma_l2_busy as usize)),
                ("dma_l3_busy", Json::int(p.dma_l3_busy as usize)),
                ("bound", Json::str(p.bound.to_string())),
            ])
        })
        .collect();
    Json::obj(vec![
        ("soc", Json::str(&soc.name)),
        ("total_cycles", Json::int(rep.total_cycles as usize)),
        ("total_ms", Json::Num(rep.ms(soc))),
        ("dma_transfers", Json::int(rep.dma.total_transfers() as usize)),
        ("dma_bytes", Json::int(rep.dma.total_bytes() as usize)),
        ("phases", Json::Arr(phases)),
    ])
}

/// Counters for the serve-layer plan cache (filled by
/// [`crate::serve::PlanCache`], rendered in `STATS` responses and the
/// `ftl serve` self-test).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups that found a cached plan.
    pub hits: u64,
    /// Lookups that missed.
    pub misses: u64,
    /// Entries evicted to stay within capacity.
    pub evictions: u64,
    /// Plans inserted.
    pub inserts: u64,
    /// Current cached-plan count.
    pub entries: usize,
    /// Configured capacity.
    pub capacity: usize,
}

impl CacheStats {
    /// Hit fraction in `[0, 1]` (0 when no lookups yet).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// JSON rendering (embedded in the serve stats snapshot).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("hits", Json::int(self.hits as usize)),
            ("misses", Json::int(self.misses as usize)),
            ("evictions", Json::int(self.evictions as usize)),
            ("inserts", Json::int(self.inserts as usize)),
            ("entries", Json::int(self.entries)),
            ("capacity", Json::int(self.capacity)),
            ("hit_rate", Json::Num(self.hit_rate())),
        ])
    }

    /// Human-readable one-table rendering.
    pub fn table(&self) -> String {
        let mut t = Table::new(&["hits", "misses", "hit%", "evictions", "entries", "capacity"]);
        t.row(&[
            self.hits.to_string(),
            self.misses.to_string(),
            format!("{:.1}", 100.0 * self.hit_rate()),
            self.evictions.to_string(),
            self.entries.to_string(),
            self.capacity.to_string(),
        ]);
        t.render()
    }
}

/// Per-lane counters of the batching scheduler's priority lanes
/// (filled by [`crate::serve::BatchScheduler`] from
/// [`crate::serve::lanes::LaneCounters`], rendered in `STATS` responses
/// under `batch.lanes.<name>.*`).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LaneStats {
    /// Lane name (`default` for the implicit lane).
    pub name: String,
    /// WFQ weight (≥ 1).
    pub weight: u64,
    /// Bounded-queue capacity (0 admits nothing).
    pub capacity: usize,
    /// Requests currently queued in this lane.
    pub queue_depth: usize,
    /// Batches (WFQ quanta) dispatched from this lane.
    pub batches: u64,
    /// Requests dispatched through this lane's batches.
    pub batched_requests: u64,
    /// Largest single batch dispatched from this lane.
    pub max_batch_size: u64,
    /// Requests shed by admission control at this lane.
    pub shed: u64,
    /// Requests whose deadline expired while owned by this lane.
    pub timeouts: u64,
    /// Requests answered with a served reply from this lane's batches.
    pub served: u64,
    /// Cold-work units charged to this lane (one per branch-and-bound
    /// solve + one per simulator run its batches performed) — the
    /// quantity weighted fairness is defined over.
    pub cold_work: u64,
    /// The lane's WFQ virtual finish tag in milli-cost-units
    /// (monotonically non-decreasing; saturated lanes' tags advance at
    /// the same rate).
    pub vtime_milli: u64,
}

impl LaneStats {
    /// JSON rendering (one entry of `batch.lanes` in the stats snapshot).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("weight", Json::int(self.weight as usize)),
            ("capacity", Json::int(self.capacity)),
            ("queue_depth", Json::int(self.queue_depth)),
            ("batches", Json::int(self.batches as usize)),
            ("batched_requests", Json::int(self.batched_requests as usize)),
            ("max_batch_size", Json::int(self.max_batch_size as usize)),
            ("shed", Json::int(self.shed as usize)),
            ("timeouts", Json::int(self.timeouts as usize)),
            ("served", Json::int(self.served as usize)),
            ("cold_work", Json::int(self.cold_work as usize)),
            ("vtime_milli", Json::int(self.vtime_milli as usize)),
        ])
    }
}

/// Counters for the serve-layer batching scheduler (filled by
/// [`crate::serve::BatchScheduler`], rendered in `STATS` responses and
/// the `ftl serve` self-test). The scheduler-wide totals are sums over
/// `lanes` (`sum(lanes.*) == batch.*` — invariant-tested).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BatchStats {
    /// Batches dispatched.
    pub batches: u64,
    /// Requests that went through a batch (admitted, not shed).
    pub batched_requests: u64,
    /// Largest batch dispatched so far.
    pub max_batch_size: u64,
    /// Requests rejected by admission control (full queue, shed policy —
    /// or any request at all on a zero-capacity queue/lane).
    pub shed: u64,
    /// Requests whose deadline expired before dispatch.
    pub timeouts: u64,
    /// Requests currently waiting across all lanes.
    pub queue_depth: usize,
    /// Total configured capacity across all lanes.
    pub queue_capacity: usize,
    /// Per-lane breakdown, in lane-index order.
    pub lanes: Vec<LaneStats>,
}

impl BatchStats {
    /// Mean requests per dispatched batch (0 when nothing dispatched).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches as f64
        }
    }

    /// JSON rendering (embedded in the serve stats snapshot).
    pub fn to_json(&self) -> Json {
        let lanes = Json::Obj(self.lanes.iter().map(|l| (l.name.clone(), l.to_json())).collect());
        Json::obj(vec![
            ("batches", Json::int(self.batches as usize)),
            ("batched_requests", Json::int(self.batched_requests as usize)),
            ("max_batch_size", Json::int(self.max_batch_size as usize)),
            ("mean_batch_size", Json::Num(self.mean_batch_size())),
            ("shed", Json::int(self.shed as usize)),
            ("timeouts", Json::int(self.timeouts as usize)),
            ("queue_depth", Json::int(self.queue_depth)),
            ("queue_capacity", Json::int(self.queue_capacity)),
            ("lanes", lanes),
        ])
    }

    /// Human-readable one-table rendering (scheduler-wide totals).
    pub fn table(&self) -> String {
        let mut t = Table::new(&["batches", "requests", "max", "mean", "shed", "timeouts", "depth", "cap"]);
        t.row(&[
            self.batches.to_string(),
            self.batched_requests.to_string(),
            self.max_batch_size.to_string(),
            format!("{:.1}", self.mean_batch_size()),
            self.shed.to_string(),
            self.timeouts.to_string(),
            self.queue_depth.to_string(),
            self.queue_capacity.to_string(),
        ]);
        t.render()
    }

    /// Human-readable per-lane rendering (one row per priority lane).
    pub fn lanes_table(&self) -> String {
        let mut t = Table::new(&[
            "lane", "weight", "cap", "depth", "batches", "requests", "shed", "timeouts", "served", "cold_work",
        ]);
        for l in &self.lanes {
            t.row(&[
                l.name.clone(),
                l.weight.to_string(),
                l.capacity.to_string(),
                l.queue_depth.to_string(),
                l.batches.to_string(),
                l.batched_requests.to_string(),
                l.shed.to_string(),
                l.timeouts.to_string(),
                l.served.to_string(),
                l.cold_work.to_string(),
            ]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_stats_mean_and_rendering() {
        let s = BatchStats {
            batches: 2,
            batched_requests: 7,
            max_batch_size: 5,
            shed: 1,
            timeouts: 0,
            queue_depth: 0,
            queue_capacity: 16,
            lanes: vec![
                LaneStats {
                    name: "default".into(),
                    weight: 1,
                    capacity: 16,
                    batches: 2,
                    batched_requests: 7,
                    shed: 1,
                    served: 7,
                    cold_work: 3,
                    ..LaneStats::default()
                },
                LaneStats { name: "gold".into(), weight: 3, capacity: 8, ..LaneStats::default() },
            ],
        };
        assert!((s.mean_batch_size() - 3.5).abs() < 1e-12);
        assert_eq!(BatchStats::default().mean_batch_size(), 0.0);
        let j = s.to_json();
        assert_eq!(j.get("shed").unwrap().as_usize().unwrap(), 1);
        assert_eq!(j.get("batched_requests").unwrap().as_usize().unwrap(), 7);
        let lanes = j.get("lanes").unwrap();
        assert_eq!(lanes.get("default").unwrap().get("cold_work").unwrap().as_usize().unwrap(), 3);
        assert_eq!(lanes.get("gold").unwrap().get("weight").unwrap().as_usize().unwrap(), 3);
        assert!(s.table().contains("3.5"));
        let lt = s.lanes_table();
        assert!(lt.contains("gold") && lt.contains("cold_work"));
    }

    #[test]
    fn cache_stats_rates_and_rendering() {
        let s = CacheStats { hits: 3, misses: 1, evictions: 0, inserts: 1, entries: 1, capacity: 8 };
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
        let j = s.to_json();
        assert_eq!(j.get("hits").unwrap().as_usize().unwrap(), 3);
        assert!(s.table().contains("75.0"));
    }

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["a", "bbbb"]);
        t.row(&["xxx".into(), "y".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("a    bbbb"));
        assert!(lines[2].starts_with("xxx  y"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new(&["a"]);
        t.row(&["x".into(), "y".into()]);
    }

    #[test]
    fn dma_table_renders() {
        let d = DmaStats::default();
        let s = dma_table(&d);
        assert!(s.contains("L2-DMA"));
        assert!(s.contains("TOTAL"));
    }
}
