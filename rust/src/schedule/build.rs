//! Schedule construction from a solved tiling.

#![forbid(unsafe_code)]

use anyhow::{anyhow, Result};

use crate::dma::Transfer;
use crate::ir::Graph;
use crate::memory::{ArenaPlan, Level, TileBuffer};
use crate::soc::{ComputeUnit, KernelCostModel, SocConfig};
use crate::tiling::solver_dma_legs as dma_legs;
use crate::tiling::{GroupSolution, TilingSolution};
use crate::util::bincode::{BinReader, BinWriter};
use crate::util::json::Json;

/// One kernel invocation on a concrete tile.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelInvocation {
    /// Node name (e.g. `"fc1"`).
    pub name: String,
    /// Unit it runs on.
    pub unit: ComputeUnit,
    /// Cycles charged by the cost model for this exact tile.
    pub cycles: u64,
    /// Output-tile shape (for traces and the runtime executor).
    pub out_shape: Vec<usize>,
}

/// One tile-loop iteration: loads, kernels, stores.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TileStep {
    /// Inbound transfers issued before the kernels.
    pub dma_in: Vec<Transfer>,
    /// Kernel invocations (group order).
    pub kernels: Vec<KernelInvocation>,
    /// Outbound transfers issued after the kernels.
    pub dma_out: Vec<Transfer>,
}

impl TileStep {
    /// Total payload bytes moved by this step.
    pub fn dma_bytes(&self) -> usize {
        self.dma_in.iter().chain(&self.dma_out).map(Transfer::bytes).sum()
    }

    /// Total kernel cycles of this step.
    pub fn kernel_cycles(&self) -> u64 {
        self.kernels.iter().map(|k| k.cycles).sum()
    }
}

/// One fusion group's tiled execution.
#[derive(Debug, Clone, PartialEq)]
pub struct Phase {
    /// Display name, e.g. `"fc1+gelu"`.
    pub name: String,
    /// Tile iterations in loop order.
    pub steps: Vec<TileStep>,
    /// Whether streamed buffers ping/pong.
    pub double_buffered: bool,
    /// L1 arena layout backing the steps.
    pub arena: ArenaPlan,
}

impl Phase {
    /// Total number of DMA commands in the phase.
    pub fn dma_count(&self) -> usize {
        self.steps.iter().map(|s| s.dma_in.len() + s.dma_out.len()).sum()
    }

    /// Total payload bytes.
    pub fn dma_bytes(&self) -> usize {
        self.steps.iter().map(TileStep::dma_bytes).sum()
    }
}

/// The full network schedule (phases run back-to-back).
#[derive(Debug, Clone, PartialEq)]
pub struct Schedule {
    /// Phases in execution order.
    pub phases: Vec<Phase>,
}

impl Schedule {
    /// Total DMA command count.
    pub fn dma_count(&self) -> usize {
        self.phases.iter().map(Phase::dma_count).sum()
    }

    /// Total DMA payload bytes.
    pub fn dma_bytes(&self) -> usize {
        self.phases.iter().map(Phase::dma_bytes).sum()
    }

    /// Total kernel cycles (no overlap accounting — see [`crate::sim`]).
    pub fn kernel_cycles(&self) -> u64 {
        self.phases.iter().flat_map(|p| &p.steps).map(TileStep::kernel_cycles).sum()
    }

    /// Canonical JSON encoding (the snapshot codec — see
    /// [`crate::serve::persist`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![("phases", Json::Arr(self.phases.iter().map(Phase::to_json).collect()))])
    }

    /// Decode the canonical JSON encoding.
    pub fn from_json(v: &Json) -> Result<Self> {
        Ok(Self { phases: v.get("phases")?.as_arr()?.iter().map(Phase::from_json).collect::<Result<_>>()? })
    }

    /// Canonical binary encoding (`ftl-bin-v1`).
    pub fn to_bin(&self, w: &mut BinWriter) {
        w.seq(&self.phases, |w, p| p.to_bin(w));
    }

    /// Decode the canonical binary encoding.
    pub fn from_bin(r: &mut BinReader) -> Result<Self> {
        Ok(Self { phases: r.seq(Phase::from_bin)? })
    }
}

impl Phase {
    /// Canonical JSON encoding.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("steps", Json::Arr(self.steps.iter().map(TileStep::to_json).collect())),
            ("double_buffered", Json::Bool(self.double_buffered)),
            ("arena", self.arena.to_json()),
        ])
    }

    /// Decode the canonical JSON encoding.
    pub fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            name: v.get("name")?.as_str()?.to_string(),
            steps: v.get("steps")?.as_arr()?.iter().map(TileStep::from_json).collect::<Result<_>>()?,
            double_buffered: v.get("double_buffered")?.as_bool()?,
            arena: ArenaPlan::from_json(v.get("arena")?)?,
        })
    }

    /// Canonical binary encoding (`ftl-bin-v1`).
    pub fn to_bin(&self, w: &mut BinWriter) {
        w.str(&self.name);
        w.seq(&self.steps, |w, s| s.to_bin(w));
        w.bool(self.double_buffered);
        self.arena.to_bin(w);
    }

    /// Decode the canonical binary encoding.
    pub fn from_bin(r: &mut BinReader) -> Result<Self> {
        Ok(Self {
            name: r.str()?,
            steps: r.seq(TileStep::from_bin)?,
            double_buffered: r.bool()?,
            arena: ArenaPlan::from_bin(r)?,
        })
    }
}

impl TileStep {
    /// Canonical JSON encoding.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dma_in", Json::Arr(self.dma_in.iter().map(Transfer::to_json).collect())),
            ("kernels", Json::Arr(self.kernels.iter().map(KernelInvocation::to_json).collect())),
            ("dma_out", Json::Arr(self.dma_out.iter().map(Transfer::to_json).collect())),
        ])
    }

    /// Decode the canonical JSON encoding.
    pub fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            dma_in: v.get("dma_in")?.as_arr()?.iter().map(Transfer::from_json).collect::<Result<_>>()?,
            kernels: v.get("kernels")?.as_arr()?.iter().map(KernelInvocation::from_json).collect::<Result<_>>()?,
            dma_out: v.get("dma_out")?.as_arr()?.iter().map(Transfer::from_json).collect::<Result<_>>()?,
        })
    }

    /// Canonical binary encoding (`ftl-bin-v1`).
    pub fn to_bin(&self, w: &mut BinWriter) {
        w.seq(&self.dma_in, |w, t| t.to_bin(w));
        w.seq(&self.kernels, |w, k| k.to_bin(w));
        w.seq(&self.dma_out, |w, t| t.to_bin(w));
    }

    /// Decode the canonical binary encoding.
    pub fn from_bin(r: &mut BinReader) -> Result<Self> {
        Ok(Self {
            dma_in: r.seq(Transfer::from_bin)?,
            kernels: r.seq(KernelInvocation::from_bin)?,
            dma_out: r.seq(Transfer::from_bin)?,
        })
    }
}

impl KernelInvocation {
    /// Canonical JSON encoding.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("unit", Json::str(self.unit.name())),
            ("cycles", Json::int(self.cycles as usize)),
            ("out_shape", Json::ints(&self.out_shape)),
        ])
    }

    /// Decode the canonical JSON encoding.
    pub fn from_json(v: &Json) -> Result<Self> {
        let unit = v.get("unit")?.as_str()?;
        Ok(Self {
            name: v.get("name")?.as_str()?.to_string(),
            unit: ComputeUnit::parse(unit).ok_or_else(|| anyhow!("unknown compute unit '{unit}'"))?,
            cycles: v.get("cycles")?.as_u64()?,
            out_shape: v.get("out_shape")?.as_usize_arr()?,
        })
    }

    /// Canonical binary encoding (`ftl-bin-v1`).
    pub fn to_bin(&self, w: &mut BinWriter) {
        w.str(&self.name);
        w.str(self.unit.name());
        w.u64(self.cycles);
        w.usize_seq(&self.out_shape);
    }

    /// Decode the canonical binary encoding.
    pub fn from_bin(r: &mut BinReader) -> Result<Self> {
        let name = r.str()?;
        let unit = r.str()?;
        Ok(Self {
            name,
            unit: ComputeUnit::parse(&unit).ok_or_else(|| anyhow!("unknown compute unit '{unit}'"))?,
            cycles: r.u64()?,
            out_shape: r.usize_seq()?,
        })
    }
}

/// Generate the executable schedule for a solved tiling.
pub fn build_schedule(graph: &Graph, soc: &SocConfig, solution: &TilingSolution) -> Result<Schedule> {
    let phases = solution.groups.iter().map(|g| build_phase(graph, soc, g)).collect::<Result<Vec<_>>>()?;
    Ok(Schedule { phases })
}

fn build_phase(graph: &Graph, soc: &SocConfig, g: &GroupSolution) -> Result<Phase> {
    let name = g.nodes.iter().map(|n| n.name.as_str()).collect::<Vec<_>>().join("+");

    // L1 arena: steady-state tile sizes; loop-invariant (depth-0) buffers
    // are not ping/pong-duplicated even when double buffering is on.
    let tiles: Vec<TileBuffer> = g
        .buffers
        .iter()
        .map(|b| TileBuffer { name: b.name.clone(), role: b.role, bytes: b.steady_bytes(&g.loops) })
        .collect();
    let copies: Vec<usize> = g
        .buffers
        .iter()
        .map(|b| if g.double_buffered && b.is_streamed() && b.fetch_depth > 0 { 2 } else { 1 })
        .collect();
    let arena = ArenaPlan::layout_explicit(
        tiles,
        &copies,
        soc.mem.capacity(Level::L1),
        soc.mem.spec(Level::L1).alignment,
        g.double_buffered,
    )?;

    let iters = g.iterations();
    let mut steps = Vec::with_capacity(iters.len());
    for (i, state) in iters.iter().enumerate() {
        let changed = g.changed_depth(iters.get(i.wrapping_sub(1)).filter(|_| i > 0).map(|v| v.as_slice()), state);
        let next_changed = iters.get(i + 1).map(|nx| g.changed_depth(Some(state.as_slice()), nx));

        let mut step = TileStep::default();

        // Loads: a buffer is (re-)fetched when a loop it depends on
        // advanced — i.e. changed depth < fetch_depth — or on iteration 0.
        for b in &g.buffers {
            let inbound = matches!(b.role, crate::memory::BufferRole::Input | crate::memory::BufferRole::Weight);
            if !inbound {
                continue;
            }
            let Some(home) = b.home else { continue };
            let refetch = i == 0 || changed < b.fetch_depth;
            if refetch {
                let shape = b.shape_at(state);
                let rows: usize = shape[..shape.len() - 1].iter().product::<usize>().max(1);
                let row_bytes = shape.last().copied().unwrap_or(1) * b.elem_bytes;
                step.dma_in.extend(dma_legs(home, true, rows, row_bytes));
            }
        }

        // Kernels, with exact (remainder-clamped) tile shapes.
        for n in &g.nodes {
            let in_shapes: Vec<Vec<usize>> = n.input_bufs.iter().map(|&bi| g.buffers[bi].shape_at(state)).collect();
            let in_refs: Vec<&[usize]> = in_shapes.iter().map(|s| s.as_slice()).collect();
            let out_shape = g.buffers[n.output_buf].shape_at(state);
            let cycles = KernelCostModel::tile_cycles(soc, &n.op, n.unit, &in_refs, &out_shape);
            step.kernels.push(KernelInvocation { name: n.name.clone(), unit: n.unit, cycles, out_shape });
        }

        // Stores: exactly once per output tile — at the last iteration of
        // the loops deeper than the buffer's fetch depth.
        for b in &g.buffers {
            if b.role != crate::memory::BufferRole::Output {
                continue;
            }
            let Some(home) = b.home else { continue };
            let store_now = match next_changed {
                None => true, // last iteration of the phase
                Some(nc) => nc < b.fetch_depth,
            };
            if store_now {
                let shape = b.shape_at(state);
                let rows: usize = shape[..shape.len() - 1].iter().product::<usize>().max(1);
                let row_bytes = shape.last().copied().unwrap_or(1) * b.elem_bytes;
                step.dma_out.extend(dma_legs(home, false, rows, row_bytes));
            }
        }

        steps.push(step);
    }

    // Silence unused-variable warning path: graph reserved for future
    // per-node attribute lookups.
    let _ = graph;

    Ok(Phase { name, steps, double_buffered: g.double_buffered, arena })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::vit_mlp;
    use crate::ir::DType;
    use crate::memory::BufferRole;
    use crate::soc::{siracusa_reduced, siracusa_reduced_cluster_only};
    use crate::tiling::{fuse_groups, solve_graph, FusionPolicy, SolverOptions, Strategy};

    fn deploy(strategy: Strategy, npu: bool, dbuf: bool) -> (crate::ir::Graph, SocConfig, Schedule) {
        let g = vit_mlp(197, 768, 3072, DType::Int8);
        let soc = if npu { siracusa_reduced() } else { siracusa_reduced_cluster_only() };
        let groups = fuse_groups(&g, strategy, FusionPolicy::default());
        let (_, sol) = solve_graph(&g, &soc, groups, &SolverOptions::default(), dbuf).unwrap();
        let sched = build_schedule(&g, &soc, &sol).unwrap();
        (g, soc, sched)
    }

    #[test]
    fn baseline_has_three_phases() {
        let (_, _, s) = deploy(Strategy::LayerPerLayer, false, false);
        assert_eq!(s.phases.len(), 3);
    }

    #[test]
    fn ftl_has_two_phases() {
        let (_, _, s) = deploy(Strategy::Ftl, false, false);
        assert_eq!(s.phases.len(), 2);
        assert!(s.phases[0].name.contains('+'), "fused phase named {}", s.phases[0].name);
    }

    #[test]
    fn ftl_moves_fewer_bytes_and_commands() {
        let (_, _, base) = deploy(Strategy::LayerPerLayer, false, false);
        let (_, _, ftl) = deploy(Strategy::Ftl, false, false);
        assert!(ftl.dma_bytes() < base.dma_bytes(), "ftl {} vs base {}", ftl.dma_bytes(), base.dma_bytes());
        assert!(ftl.dma_count() < base.dma_count());
    }

    #[test]
    fn output_stored_exactly_once() {
        // Sum of all outbound payload bytes for the graph output must be
        // >= tensor size and each output tile stored exactly once ⇒ total
        // payload == tensor bytes × legs.
        let (g, _, s) = deploy(Strategy::Ftl, false, false);
        let out_id = g.outputs()[0];
        let out_bytes = g.tensors[out_id].size_bytes();
        let stored: usize = s.phases.last().unwrap().steps.iter().flat_map(|st| &st.dma_out).map(Transfer::bytes).sum();
        // final phase's output is the graph output; home L2 ⇒ 1 leg.
        assert_eq!(stored, out_bytes);
    }

    #[test]
    fn fused_intermediate_generates_no_dma() {
        let (g, soc, _) = deploy(Strategy::Ftl, false, false);
        let groups = fuse_groups(&g, Strategy::Ftl, FusionPolicy::default());
        let (_, sol) = solve_graph(&g, &soc, groups, &SolverOptions::default(), false).unwrap();
        let fused = &sol.groups[0];
        let inter = fused.buffers.iter().find(|b| b.role == BufferRole::Intermediate).unwrap();
        assert!(inter.home.is_none());
        // Total bytes of the fused phase must not include the intermediate.
        let sched = build_schedule(&g, &soc, &sol).unwrap();
        let h_bytes = g.tensor_by_name("fc1_1").unwrap().1.size_bytes();
        let base = deploy(Strategy::LayerPerLayer, false, false).2;
        // Baseline moves H at least twice (store+load), FTL zero times.
        assert!(base.dma_bytes() >= sched.dma_bytes() + 2 * h_bytes);
    }

    #[test]
    fn weights_fetched_once_with_hoisting() {
        // In the best loop order for fc1, X (or W1) is loop-invariant at
        // some depth; the total inbound payload for W1 must be exactly its
        // size × number of refetches implied by its fetch depth.
        let (g, _, s) = deploy(Strategy::LayerPerLayer, false, false);
        let w1_bytes = g.tensor_by_name("fc1.w").unwrap().1.size_bytes();
        let fc1_in: usize = s.phases[0].steps.iter().flat_map(|st| &st.dma_in).map(Transfer::bytes).sum();
        // X + W1 + bias inbound; W1 dominates. Inbound must be at least
        // W1 once, and the solver should avoid re-streaming W1 many times.
        assert!(fc1_in >= w1_bytes);
        assert!(fc1_in < 3 * w1_bytes, "W1 re-streamed too often: {fc1_in} vs {w1_bytes}");
    }

    #[test]
    fn double_buffer_arena_has_pong_copies() {
        let (_, _, s) = deploy(Strategy::Ftl, true, true);
        let phase = &s.phases[0];
        assert!(phase.double_buffered);
        let has_pong = phase.arena.offsets.iter().any(|o| o.len() == 2);
        assert!(has_pong, "at least one streamed buffer must be duplicated");
    }

    #[test]
    fn npu_schedule_places_gemm_on_npu() {
        let (_, _, s) = deploy(Strategy::Ftl, true, false);
        let units: Vec<ComputeUnit> = s.phases[0].steps[0].kernels.iter().map(|k| k.unit).collect();
        assert!(units.contains(&ComputeUnit::Npu));
        assert!(units.contains(&ComputeUnit::Cluster)); // gelu stays on cluster
    }

    #[test]
    fn json_roundtrip_full_schedule() {
        for (strategy, npu, dbuf) in
            [(Strategy::LayerPerLayer, false, false), (Strategy::Ftl, true, true), (Strategy::Ftl, false, false)]
        {
            let (_, _, s) = deploy(strategy, npu, dbuf);
            let back = Schedule::from_json(&s.to_json()).unwrap();
            assert_eq!(back, s, "schedule must round-trip ({strategy:?}, npu={npu}, dbuf={dbuf})");
        }
    }

    #[test]
    fn steps_cover_all_iterations() {
        let (g, soc, _) = deploy(Strategy::Ftl, false, false);
        let groups = fuse_groups(&g, Strategy::Ftl, FusionPolicy::default());
        let (_, sol) = solve_graph(&g, &soc, groups, &SolverOptions::default(), false).unwrap();
        let sched = build_schedule(&g, &soc, &sol).unwrap();
        for (p, gr) in sched.phases.iter().zip(&sol.groups) {
            assert_eq!(p.steps.len(), gr.total_iterations());
        }
    }
}
