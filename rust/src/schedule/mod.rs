//! Tiled execution-schedule generation.
//!
//! Turns a [`TilingSolution`] into the concrete, remainder-exact sequence
//! of DMA commands and kernel invocations the SoC executes — one
//! [`Phase`] per fusion group, one [`TileStep`] per tile-loop iteration.
//! Loop-invariant buffers are fetched once; outputs are stored exactly
//! once per output tile; fused intermediates generate no DMA at all.
//!
//! The schedule is consumed by two backends:
//! * [`crate::sim`] — the event-driven SoC simulator (cycles, DMA stats);
//! * [`crate::runtime`] — the PJRT tile executor (numerics validation).

#![forbid(unsafe_code)]

mod build;

pub use build::{build_schedule, KernelInvocation, Phase, Schedule, TileStep};
