//! Mutation testing for the plan verifier — the checker's own
//! false-negative test.
//!
//! Each mutator takes a *valid* [`Deployment`], applies one seeded
//! corruption (shift an arena offset, widen a transfer, swap two phases,
//! drop a buffer, …) and records which rules the verifier then fires.
//! A mutation is **caught** iff the intended rule appears among the
//! error-severity findings; [`run_mutations`] fails fast if the base
//! plan is not clean or a mutator finds no applicable target — both
//! would silently weaken the harness.

#![forbid(unsafe_code)]

use anyhow::{bail, Result};

use crate::coordinator::Deployment;
use crate::memory::BufferRole;
use crate::soc::SocConfig;
use crate::tiling::DimSpec;

use super::{check_deployment, Rule};

/// One mutator's result.
#[derive(Debug, Clone)]
pub struct MutationOutcome {
    /// Mutator name (stable, used in reports and CI assertions).
    pub name: &'static str,
    /// The rule that must catch this corruption.
    pub intended: Rule,
    /// Whether the intended rule fired at error severity.
    pub caught: bool,
    /// All error-severity rules the verifier fired on the mutant.
    pub rules_hit: Vec<Rule>,
}

type Mutator = fn(&mut Deployment) -> Result<()>;

/// The mutator catalog: (name, intended rule, mutation).
fn catalog() -> Vec<(&'static str, Rule, Mutator)> {
    vec![
        ("shift-offset", Rule::ArenaOverlap, shift_offset),
        ("misalign-offset", Rule::ArenaAlign, misalign_offset),
        ("blow-capacity", Rule::ArenaCapacity, blow_capacity),
        ("drop-buffer", Rule::ArenaShape, drop_buffer),
        ("collapse-copies", Rule::DmaRace, collapse_copies),
        ("widen-transfer", Rule::TransferBounds, widen_transfer),
        ("drop-transfer", Rule::TransferShape, drop_transfer),
        ("shrink-output", Rule::CoverageGap, shrink_output),
        ("halo-output", Rule::CoverageOverlap, halo_output),
        ("swap-phases", Rule::PhaseOrder, swap_phases),
        ("use-before-def", Rule::DefBeforeUse, use_before_def),
        ("corrupt-trip", Rule::TripCount, corrupt_trip),
        ("inflate-cycles", Rule::KernelShape, inflate_cycles),
    ]
}

/// Apply every mutator to (a clone of) `dep` and verify each mutant.
///
/// Errors if the base plan itself fails verification or any mutator has
/// no applicable target in this plan.
pub fn run_mutations(dep: &Deployment, soc: &SocConfig) -> Result<Vec<MutationOutcome>> {
    let base = check_deployment(dep, Some(soc));
    if !base.findings.is_empty() {
        bail!("mutation harness needs a clean base plan, got:\n{}", base.render());
    }
    let mut out = Vec::new();
    for (name, intended, mutate) in catalog() {
        let mut mutant = dep.clone();
        mutate(&mut mutant)?;
        let report = check_deployment(&mutant, Some(soc));
        let rules_hit: Vec<Rule> = report.error_rules().into_iter().collect();
        let caught = rules_hit.contains(&intended);
        out.push(MutationOutcome { name, intended, caught, rules_hit });
    }
    Ok(out)
}

/// Render the mutator → rule table plus the tally line CI parses.
pub fn render_outcomes(outcomes: &[MutationOutcome]) -> String {
    let mut s = String::new();
    for o in outcomes {
        let mark = if o.caught { "caught" } else { "MISSED" };
        let hits: Vec<&str> = o.rules_hit.iter().map(|r| r.name()).collect();
        s.push_str(&format!("{:<16} -> {:<17} {mark:<7} (fired: {})\n", o.name, o.intended.name(), hits.join(", ")));
    }
    let caught = outcomes.iter().filter(|o| o.caught).count();
    s.push_str(&format!("mutations={} caught={caught}\n", outcomes.len()));
    s
}

// ------------------------------------------------------------- mutators

fn shift_offset(dep: &mut Deployment) -> Result<()> {
    for phase in &mut dep.schedule.phases {
        let sized: Vec<usize> = (0..phase.arena.buffers.len())
            .filter(|&i| phase.arena.buffers[i].bytes > 0 && !phase.arena.offsets[i].is_empty())
            .collect();
        if let [i, j, ..] = sized[..] {
            phase.arena.offsets[j][0] = phase.arena.offsets[i][0];
            return Ok(());
        }
    }
    bail!("shift-offset: no phase with two sized buffers")
}

fn misalign_offset(dep: &mut Deployment) -> Result<()> {
    for phase in &mut dep.schedule.phases {
        if let Some(offs) = phase.arena.offsets.first_mut() {
            if let Some(o) = offs.first_mut() {
                *o += 1;
                return Ok(());
            }
        }
    }
    bail!("misalign-offset: no arena offset to perturb")
}

fn blow_capacity(dep: &mut Deployment) -> Result<()> {
    // Push a sized buffer far past any plausible L1; the re-derived span
    // then ends beyond capacity.
    for phase in &mut dep.schedule.phases {
        for i in 0..phase.arena.buffers.len() {
            if phase.arena.buffers[i].bytes > 0 && !phase.arena.offsets[i].is_empty() {
                phase.arena.offsets[i][0] = 1 << 28;
                return Ok(());
            }
        }
    }
    bail!("blow-capacity: no sized buffer")
}

fn drop_buffer(dep: &mut Deployment) -> Result<()> {
    for phase in &mut dep.schedule.phases {
        if !phase.arena.buffers.is_empty() {
            phase.arena.buffers.pop();
            phase.arena.offsets.pop();
            return Ok(());
        }
    }
    bail!("drop-buffer: no arena buffer")
}

fn collapse_copies(dep: &mut Deployment) -> Result<()> {
    for (gi, g) in dep.solution.groups.iter().enumerate() {
        if !g.double_buffered {
            continue;
        }
        for (bi, b) in g.buffers.iter().enumerate() {
            let inbound = matches!(b.role, BufferRole::Input | BufferRole::Weight);
            // The buffer must actually be refetched at some step ≥ 1:
            // some loop above its fetch depth advances at least once.
            let refetched = g.loops[..b.fetch_depth].iter().any(|l| l.trips() >= 2);
            let read = g.nodes.iter().any(|n| n.input_bufs.contains(&bi));
            if inbound && b.home.is_some() && refetched && read {
                let offs = &mut dep.schedule.phases[gi].arena.offsets[bi];
                if offs.len() == 2 {
                    offs.pop();
                    return Ok(());
                }
            }
        }
    }
    bail!("collapse-copies: no refetched double-buffered input")
}

fn widen_transfer(dep: &mut Deployment) -> Result<()> {
    for (gi, g) in dep.solution.groups.iter().enumerate() {
        // Step 0 fetches every streamed input in buffer order, so the
        // first inbound transfer belongs to the first such buffer.
        let Some(b) = g
            .buffers
            .iter()
            .find(|b| matches!(b.role, BufferRole::Input | BufferRole::Weight) && b.home.is_some())
        else {
            continue;
        };
        let full_last = b.dims.last().map_or(1, |d| d.full);
        let step = &mut dep.schedule.phases[gi].steps[0];
        if let Some(t) = step.dma_in.first_mut() {
            t.row_bytes = (full_last + 1) * b.elem_bytes;
            return Ok(());
        }
    }
    bail!("widen-transfer: no inbound transfer at step 0")
}

fn drop_transfer(dep: &mut Deployment) -> Result<()> {
    for phase in &mut dep.schedule.phases {
        if let Some(step) = phase.steps.first_mut() {
            if !step.dma_in.is_empty() {
                step.dma_in.pop();
                return Ok(());
            }
        }
    }
    bail!("drop-transfer: no inbound transfer at step 0")
}

fn shrink_output(dep: &mut Deployment) -> Result<()> {
    for g in &mut dep.solution.groups {
        for b in &mut g.buffers {
            if b.role != BufferRole::Output || b.home.is_none() {
                continue;
            }
            for d in &mut b.dims {
                if d.loop_idx.is_some() && d.full >= 2 {
                    *d = DimSpec { full: d.full, loop_idx: None, a: 0, b: d.full - 1 };
                    return Ok(());
                }
            }
        }
    }
    bail!("shrink-output: no looped output dimension")
}

fn halo_output(dep: &mut Deployment) -> Result<()> {
    for g in &mut dep.solution.groups {
        let loops = g.loops.clone();
        for b in &mut g.buffers {
            if b.role != BufferRole::Output || b.home.is_none() {
                continue;
            }
            for d in &mut b.dims {
                let trips = d.loop_idx.map(|l| loops[l].trips()).unwrap_or(0);
                if trips >= 2 && d.a >= 1 {
                    d.b += 1;
                    return Ok(());
                }
            }
        }
    }
    bail!("halo-output: no multi-trip output dimension")
}

fn swap_phases(dep: &mut Deployment) -> Result<()> {
    if dep.schedule.phases.len() >= 2 && dep.schedule.phases[0].name != dep.schedule.phases[1].name {
        dep.schedule.phases.swap(0, 1);
        return Ok(());
    }
    bail!("swap-phases: need two distinct phases")
}

fn use_before_def(dep: &mut Deployment) -> Result<()> {
    for g in &mut dep.solution.groups {
        if let Some(n) = g.nodes.first_mut() {
            if let Some(ib) = n.input_bufs.first_mut() {
                *ib = n.output_buf;
                return Ok(());
            }
        }
    }
    bail!("use-before-def: no node with inputs")
}

fn corrupt_trip(dep: &mut Deployment) -> Result<()> {
    for g in &mut dep.solution.groups {
        if let Some(l) = g.loops.first_mut() {
            l.full += l.tile;
            return Ok(());
        }
    }
    bail!("corrupt-trip: no loop to corrupt")
}

fn inflate_cycles(dep: &mut Deployment) -> Result<()> {
    for phase in &mut dep.schedule.phases {
        if let Some(step) = phase.steps.first_mut() {
            if let Some(k) = step.kernels.first_mut() {
                k.cycles += 1;
                return Ok(());
            }
        }
    }
    bail!("inflate-cycles: no kernel at step 0")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeployConfig;
    use crate::coordinator::Deployer;
    use crate::ir::builder::vit_mlp;
    use crate::ir::DType;
    use crate::tiling::Strategy;

    #[test]
    fn all_mutations_caught_by_intended_rule() {
        let g = vit_mlp(197, 768, 3072, DType::Int8);
        let mut cfg = DeployConfig::preset("siracusa", Strategy::Ftl).unwrap();
        cfg.double_buffer = true;
        let dep = Deployer::new(g, cfg.clone()).plan().unwrap();
        let outcomes = run_mutations(&dep, &cfg.soc).unwrap();
        assert_eq!(outcomes.len(), catalog().len());
        for o in &outcomes {
            assert!(o.caught, "{} not caught by {} — fired {:?}\n{}", o.name, o.intended.name(), o.rules_hit, render_outcomes(&outcomes));
        }
        let text = render_outcomes(&outcomes);
        assert!(text.contains(&format!("mutations={} caught={}", outcomes.len(), outcomes.len())));
    }
}
