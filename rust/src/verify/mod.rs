//! Independent static verification of solved plans.
//!
//! On a software-managed memory hierarchy there is no MMU and no hardware
//! coherence: a plan that overlaps two live arena buffers, races a DMA
//! against the kernel consuming its destination, or leaves a gap in tile
//! coverage silently corrupts activations. This module is the line of
//! defense: [`check_deployment`] re-derives every safety invariant of a
//! [`Deployment`] **from the artifact alone** — it never trusts the
//! solver's bookkeeping (footprints, byte counts, copy counts are all
//! recomputed from the tile expressions) — and reports typed findings.
//!
//! The pass runs wherever a plan crosses a trust boundary:
//!
//! * `ftl verify <workload>` — CLI gate (nonzero exit on error findings);
//! * `ftl serve --verify-plans` — fresh solves are checked before cache
//!   insertion, snapshot-loaded entries are checked (and rejected) at
//!   warm-start ([`crate::serve`], `verify.*` counters);
//! * the mutation harness ([`mutate`]) — seeded plan corruptions that the
//!   matching rule must catch, the checker's own false-negative test.
//!
//! Rule groups:
//!
//! * **arena safety** — no two live L1 spans overlap, placements aligned
//!   and within L1 capacity, ping/pong copies disjoint, declared arena
//!   layout consistent with the re-derived tile footprints;
//! * **schedule hazards** — a happens-before pass over
//!   [`Phase`]/`TileStep` spans: in a double-buffered phase, step *i*'s
//!   prefetch DMA overlaps step *i−1*'s kernels, so their byte spans must
//!   be disjoint (RAW/WAR/WAW);
//! * **transfer bounds & coverage** — every DMA transfer matches the
//!   tile expression it was derived from and stays within the tensor
//!   extent; output tiles exactly tile the tensor domain (no gaps, no
//!   double-writes; halo'd *reads* may overlap);
//! * **structural** — phase ordering matches the solution, buffers are
//!   defined before use, trip counts are consistent with the loop nest.
//!
//! A corrupt artifact must never panic the verifier: every index is
//! validated before use, arithmetic is checked, and absurd magnitudes
//! are reported as [`Rule::Malformed`] instead of being enumerated.

#![forbid(unsafe_code)]

pub mod mutate;

use std::collections::{BTreeSet, HashMap, HashSet};

use anyhow::{anyhow, Result};

use crate::coordinator::Deployment;
use crate::dma::Transfer;
use crate::memory::{AllocRequest, Allocation, BufferRole, Level, PlacementViolation, StaticAllocator};
use crate::schedule::Phase;
use crate::soc::{KernelCostModel, SocConfig};
use crate::tiling::{solver_dma_legs as dma_legs, FusionGroup, GroupSolution};
use crate::util::json::Json;

/// Finding severity. Only `Error` findings fail a plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Severity {
    /// Suspicious but not a proven safety violation (e.g. a nest too
    /// large to enumerate — verified structurally only).
    Warning,
    /// A proven invariant violation; the plan must not be executed.
    Error,
}

impl Severity {
    /// Canonical name.
    pub const fn name(self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }

    /// Parse a canonical name back.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "warning" => Severity::Warning,
            "error" => Severity::Error,
            _ => return None,
        })
    }
}

/// The invariant a finding violates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Rule {
    /// Two time-live arena spans overlap in space.
    ArenaOverlap,
    /// An arena offset is not aligned to the L1 alignment.
    ArenaAlign,
    /// An arena span ends past the L1 capacity.
    ArenaCapacity,
    /// The declared arena layout disagrees with the re-derived tile
    /// buffers (count, bytes, role, or ping/pong copy count).
    ArenaShape,
    /// A DMA span and a concurrently running kernel span intersect
    /// (RAW/WAR/WAW in a double-buffered phase).
    DmaRace,
    /// A transfer reaches outside its tensor's extent.
    TransferBounds,
    /// A step's transfers disagree with the tile expressions (count,
    /// legs, or geometry) without leaving the tensor extent.
    TransferShape,
    /// Output tiles leave part of the tensor unwritten.
    CoverageGap,
    /// Two output tiles write the same region (double-write).
    CoverageOverlap,
    /// Phase order/name or group membership disagrees with the solution.
    PhaseOrder,
    /// A node reads a buffer no earlier node has produced.
    DefBeforeUse,
    /// Step count disagrees with the loop nest's trip counts.
    TripCount,
    /// A kernel invocation disagrees with its node (name, unit, shape,
    /// or cost-model cycles).
    KernelShape,
    /// The artifact is structurally invalid (indices out of range,
    /// absurd magnitudes) — deeper checks were skipped.
    Malformed,
}

impl Rule {
    /// Every rule, in severity-ordering of the catalog.
    pub const ALL: [Rule; 14] = [
        Rule::ArenaOverlap,
        Rule::ArenaAlign,
        Rule::ArenaCapacity,
        Rule::ArenaShape,
        Rule::DmaRace,
        Rule::TransferBounds,
        Rule::TransferShape,
        Rule::CoverageGap,
        Rule::CoverageOverlap,
        Rule::PhaseOrder,
        Rule::DefBeforeUse,
        Rule::TripCount,
        Rule::KernelShape,
        Rule::Malformed,
    ];

    /// Canonical kebab-case name.
    pub const fn name(self) -> &'static str {
        match self {
            Rule::ArenaOverlap => "arena-overlap",
            Rule::ArenaAlign => "arena-align",
            Rule::ArenaCapacity => "arena-capacity",
            Rule::ArenaShape => "arena-shape",
            Rule::DmaRace => "dma-race",
            Rule::TransferBounds => "transfer-bounds",
            Rule::TransferShape => "transfer-shape",
            Rule::CoverageGap => "coverage-gap",
            Rule::CoverageOverlap => "coverage-overlap",
            Rule::PhaseOrder => "phase-order",
            Rule::DefBeforeUse => "def-before-use",
            Rule::TripCount => "trip-count",
            Rule::KernelShape => "kernel-shape",
            Rule::Malformed => "malformed",
        }
    }

    /// Parse a canonical name back.
    pub fn parse(s: &str) -> Option<Self> {
        Rule::ALL.iter().copied().find(|r| r.name() == s)
    }
}

/// One diagnostic produced by the verifier.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Violated invariant.
    pub rule: Rule,
    /// Severity (only errors fail the plan).
    pub severity: Severity,
    /// Phase (= group) index the finding is anchored to, if any.
    pub phase: Option<usize>,
    /// Human-readable specifics.
    pub detail: String,
}

impl Finding {
    /// One-line text rendering, e.g. `[ERROR] arena-overlap phase 0: …`.
    pub fn render(&self) -> String {
        let sev = match self.severity {
            Severity::Warning => "WARN ",
            Severity::Error => "ERROR",
        };
        match self.phase {
            Some(p) => format!("[{sev}] {} phase {p}: {}", self.rule.name(), self.detail),
            None => format!("[{sev}] {}: {}", self.rule.name(), self.detail),
        }
    }

    /// Canonical JSON encoding.
    pub fn to_json(&self) -> Json {
        let phase = match self.phase {
            None => Json::Null,
            Some(p) => Json::int(p),
        };
        Json::obj(vec![
            ("rule", Json::str(self.rule.name())),
            ("severity", Json::str(self.severity.name())),
            ("phase", phase),
            ("detail", Json::str(&self.detail)),
        ])
    }

    /// Decode the canonical JSON encoding.
    pub fn from_json(v: &Json) -> Result<Self> {
        let rule = v.get("rule")?.as_str()?;
        let severity = v.get("severity")?.as_str()?;
        let phase = match v.get("phase")? {
            Json::Null => None,
            other => Some(other.as_usize()?),
        };
        Ok(Self {
            rule: Rule::parse(rule).ok_or_else(|| anyhow!("unknown verify rule '{rule}'"))?,
            severity: Severity::parse(severity).ok_or_else(|| anyhow!("unknown severity '{severity}'"))?,
            phase,
            detail: v.get("detail")?.as_str()?.to_string(),
        })
    }
}

/// Outcome of [`check_deployment`]: the findings, worst first.
#[derive(Debug, Clone, Default)]
pub struct Report {
    /// All findings (errors sorted before warnings).
    pub findings: Vec<Finding>,
}

impl Report {
    /// True iff the plan carries no error-severity finding.
    pub fn ok(&self) -> bool {
        self.errors() == 0
    }

    /// Number of error-severity findings.
    pub fn errors(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Error).count()
    }

    /// Number of warning-severity findings.
    pub fn warnings(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Warning).count()
    }

    /// The distinct rules violated at error severity.
    pub fn error_rules(&self) -> BTreeSet<Rule> {
        self.findings.iter().filter(|f| f.severity == Severity::Error).map(|f| f.rule).collect()
    }

    /// Short one-line summary (used in serve rejection messages).
    pub fn summary(&self) -> String {
        let rules: Vec<&str> = self.error_rules().iter().map(|r| r.name()).collect();
        format!("{} error(s), {} warning(s) [{}]", self.errors(), self.warnings(), rules.join(", "))
    }

    /// Multi-line text rendering.
    pub fn render(&self) -> String {
        if self.findings.is_empty() {
            return "verify: ok (0 findings)\n".to_string();
        }
        let mut s = String::new();
        for f in &self.findings {
            s.push_str(&f.render());
            s.push('\n');
        }
        s.push_str(&format!("verify: {}\n", self.summary()));
        s
    }

    /// Canonical JSON encoding.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ok", Json::Bool(self.ok())),
            ("errors", Json::int(self.errors())),
            ("warnings", Json::int(self.warnings())),
            ("findings", Json::Arr(self.findings.iter().map(Finding::to_json).collect())),
        ])
    }
}

/// Scalar sanity cap: any loop extent, tile size, dimension term, offset
/// or element size beyond this is treated as artifact corruption.
const SCALAR_CAP: usize = 1 << 31;
/// Derived per-buffer tile bytes beyond this are implausible for any L1.
const BYTES_CAP: u128 = 1 << 40;
/// Nests with more iterations than this get a structural-only check.
const ITER_CAP: u128 = 1 << 22;
/// Per-dimension coverage enumeration cap.
const COVERAGE_TRIP_CAP: usize = 1 << 20;
/// Findings kept per group before suppression (keeps corrupt artifacts
/// from producing megabytes of diagnostics).
const MAX_GROUP_FINDINGS: usize = 24;

/// Statically verify a solved plan.
///
/// When `soc` is `None` (e.g. a snapshot loaded before any request bound
/// a SoC to it), the capacity-, alignment- and cost-model-dependent
/// checks are skipped; overlap, hazard, coverage and structural checks
/// still run in full.
pub fn check_deployment(dep: &Deployment, soc: Option<&SocConfig>) -> Report {
    let mut findings = Vec::new();
    let (ng, ns, np) = (dep.groups.len(), dep.solution.groups.len(), dep.schedule.phases.len());
    if ng != ns || ng != np {
        findings.push(Finding {
            rule: Rule::Malformed,
            severity: Severity::Error,
            phase: None,
            detail: format!("{ng} fusion groups, {ns} solved groups, {np} phases — counts must match"),
        });
    }
    for gi in 0..ng.min(ns).min(np) {
        let mut checker = GroupChecker {
            gi,
            group: &dep.groups[gi],
            sol: &dep.solution.groups[gi],
            phase: &dep.schedule.phases[gi],
            soc,
            findings: Vec::new(),
            suppressed: false,
        };
        checker.run();
        findings.extend(checker.findings);
    }
    findings.sort_by(|a, b| b.severity.cmp(&a.severity).then_with(|| a.phase.cmp(&b.phase)));
    Report { findings }
}

/// Per-group verification state.
struct GroupChecker<'a> {
    gi: usize,
    group: &'a FusionGroup,
    sol: &'a GroupSolution,
    phase: &'a Phase,
    soc: Option<&'a SocConfig>,
    findings: Vec<Finding>,
    suppressed: bool,
}

impl GroupChecker<'_> {
    fn push(&mut self, rule: Rule, severity: Severity, detail: String) {
        if self.findings.len() >= MAX_GROUP_FINDINGS {
            if !self.suppressed {
                self.suppressed = true;
                self.findings.push(Finding {
                    rule: Rule::Malformed,
                    severity: Severity::Warning,
                    phase: Some(self.gi),
                    detail: "further findings suppressed".to_string(),
                });
            }
            return;
        }
        self.findings.push(Finding { rule, severity, phase: Some(self.gi), detail });
    }

    fn error(&mut self, rule: Rule, detail: String) {
        self.push(rule, Severity::Error, detail);
    }

    fn warn(&mut self, rule: Rule, detail: String) {
        self.push(rule, Severity::Warning, detail);
    }

    fn run(&mut self) {
        if !self.structural() {
            return;
        }
        let Some(bytes) = self.derive_bytes() else { return };
        self.arena(&bytes);
        self.ordering();
        self.coverage();
        self.steps(&bytes);
    }

    /// Index/magnitude validation. Returns false (skipping all deeper
    /// passes) if the artifact cannot be walked safely.
    fn structural(&mut self) -> bool {
        let before = self.findings.len();
        let nl = self.sol.loops.len();
        for (li, l) in self.sol.loops.iter().enumerate() {
            if l.tile == 0 || l.full == 0 {
                self.error(Rule::TripCount, format!("loop {li} ('{}') has zero tile or extent", l.name));
            } else if l.tile > SCALAR_CAP || l.full > SCALAR_CAP {
                self.error(Rule::Malformed, format!("loop {li} ('{}') has implausible magnitude", l.name));
            }
        }
        for b in &self.sol.buffers {
            if b.elem_bytes == 0 || b.elem_bytes > SCALAR_CAP {
                self.error(Rule::Malformed, format!("buffer '{}' has element size {}", b.name, b.elem_bytes));
            }
            if b.fetch_depth > nl {
                self.error(Rule::Malformed, format!("buffer '{}' fetch depth {} exceeds {nl} loops", b.name, b.fetch_depth));
            }
            if b.home.is_some() && b.dims.is_empty() {
                self.error(Rule::Malformed, format!("streamed buffer '{}' has no dimensions", b.name));
            }
            for (di, d) in b.dims.iter().enumerate() {
                if d.full > SCALAR_CAP || d.a > SCALAR_CAP || d.b > SCALAR_CAP {
                    self.error(Rule::Malformed, format!("buffer '{}' dim {di} has implausible magnitude", b.name));
                }
                if let Some(l) = d.loop_idx {
                    if l >= nl {
                        self.error(Rule::Malformed, format!("buffer '{}' dim {di} follows loop {l} of {nl}", b.name));
                    }
                }
            }
        }
        let nb = self.sol.buffers.len();
        for (ni, n) in self.sol.nodes.iter().enumerate() {
            if n.output_buf >= nb || n.input_bufs.iter().any(|&i| i >= nb) {
                self.error(Rule::Malformed, format!("node {ni} ('{}') references a buffer out of range", n.name));
            }
        }
        let arena = &self.phase.arena;
        if arena.offsets.len() != arena.buffers.len() {
            self.error(
                Rule::Malformed,
                format!("arena has {} buffers but {} offset lists", arena.buffers.len(), arena.offsets.len()),
            );
        } else {
            for (i, offs) in arena.offsets.iter().enumerate() {
                if offs.is_empty() {
                    self.error(Rule::Malformed, format!("arena buffer {i} has no copies"));
                } else if offs.iter().any(|&o| o > SCALAR_CAP) {
                    self.error(Rule::Malformed, format!("arena buffer {i} has an implausible offset"));
                }
            }
            for (i, tb) in arena.buffers.iter().enumerate() {
                if tb.bytes > SCALAR_CAP {
                    self.error(Rule::Malformed, format!("arena buffer {i} ('{}') has implausible size", tb.name));
                }
            }
        }
        self.findings.len() == before
    }

    /// Re-derive each buffer's steady-state tile bytes from the tile
    /// expressions (never trusting the arena's declared sizes).
    fn derive_bytes(&mut self) -> Option<Vec<usize>> {
        let mut out = Vec::with_capacity(self.sol.buffers.len());
        for b in &self.sol.buffers {
            let mut total = b.elem_bytes as u128;
            for d in &b.dims {
                total = total.saturating_mul(d.steady(&self.sol.loops) as u128);
            }
            if total > BYTES_CAP {
                self.error(Rule::Malformed, format!("buffer '{}' derives {total} steady tile bytes", b.name));
                return None;
            }
            out.push(total as usize);
        }
        Some(out)
    }

    /// Arena safety: layout consistency, alignment, capacity, overlap.
    fn arena(&mut self, bytes: &[usize]) {
        let arena = &self.phase.arena;
        if arena.buffers.len() != self.sol.buffers.len() {
            self.error(
                Rule::ArenaShape,
                format!("arena holds {} buffers, solution has {}", arena.buffers.len(), self.sol.buffers.len()),
            );
        }
        if self.phase.double_buffered != self.sol.double_buffered
            || arena.double_buffered != self.sol.double_buffered
        {
            self.error(
                Rule::ArenaShape,
                format!(
                    "double-buffer flags disagree (phase={}, arena={}, solution={})",
                    self.phase.double_buffered, arena.double_buffered, self.sol.double_buffered
                ),
            );
        }
        let n = arena.buffers.len().min(self.sol.buffers.len());
        for i in 0..n {
            let tb = &arena.buffers[i];
            let b = &self.sol.buffers[i];
            if tb.role != b.role {
                self.error(
                    Rule::ArenaShape,
                    format!("arena buffer '{}' has role {}, solution says {}", tb.name, tb.role.name(), b.role.name()),
                );
            }
            if tb.bytes != bytes[i] {
                self.error(
                    Rule::ArenaShape,
                    format!("arena buffer '{}' declares {} bytes, tile expressions derive {}", tb.name, tb.bytes, bytes[i]),
                );
            }
            let expected =
                if self.sol.double_buffered && b.is_streamed() && b.fetch_depth > 0 { 2 } else { 1 };
            if arena.offsets[i].len() != expected {
                self.error(
                    Rule::ArenaShape,
                    format!("buffer '{}' has {} copies, expected {expected}", tb.name, arena.offsets[i].len()),
                );
            }
        }
        // Placement check through the shared allocator verifier: one
        // allocation per (buffer, copy), all simultaneously live — every
        // copy of every buffer coexists within the phase, so this also
        // proves ping/pong pair disjointness.
        let (capacity, alignment) = match self.soc {
            Some(s) => (s.mem.capacity(Level::L1), s.mem.spec(Level::L1).alignment),
            None => (usize::MAX, 1),
        };
        let mut allocs = Vec::new();
        let mut labels = Vec::new();
        for i in 0..n {
            for (ci, &off) in arena.offsets[i].iter().enumerate() {
                allocs.push(Allocation {
                    request: AllocRequest::new(allocs.len(), bytes[i], 0, 0),
                    offset: off,
                });
                labels.push(format!("{}[{ci}]", arena.buffers[i].name));
            }
        }
        let allocator = StaticAllocator::new(capacity, alignment);
        for v in allocator.violations(&allocs) {
            match v {
                PlacementViolation::Misaligned { index, offset, alignment } => self.error(
                    Rule::ArenaAlign,
                    format!("buffer {} at offset {offset} is not {alignment}-byte aligned", labels[index]),
                ),
                PlacementViolation::OutOfBounds { index, end, capacity } => self.error(
                    Rule::ArenaCapacity,
                    format!("buffer {} ends at byte {end}, beyond the L1 capacity of {capacity}", labels[index]),
                ),
                PlacementViolation::Overlap { a, b } => self.error(
                    Rule::ArenaOverlap,
                    format!("buffers {} and {} overlap in L1", labels[a], labels[b]),
                ),
            }
        }
    }

    /// Phase ordering, group membership, defs-before-uses.
    fn ordering(&mut self) {
        let expected = self.sol.nodes.iter().map(|n| n.name.as_str()).collect::<Vec<_>>().join("+");
        if self.phase.name != expected {
            self.error(
                Rule::PhaseOrder,
                format!("phase named '{}' but schedule position solves '{expected}'", self.phase.name),
            );
        }
        let sol_nodes: Vec<usize> = self.sol.nodes.iter().map(|n| n.node).collect();
        if self.group.nodes != sol_nodes {
            self.error(
                Rule::PhaseOrder,
                format!("fusion group lists nodes {:?}, solution solves {:?}", self.group.nodes, sol_nodes),
            );
        }
        let mut producers: HashMap<usize, usize> = HashMap::new();
        for (k, n) in self.sol.nodes.iter().enumerate() {
            for &ib in &n.input_bufs {
                let role = self.sol.buffers[ib].role;
                let ok = match producers.get(&ib) {
                    Some(&p) => p < k,
                    None => matches!(role, BufferRole::Input | BufferRole::Weight | BufferRole::Scratch),
                };
                if !ok {
                    self.error(
                        Rule::DefBeforeUse,
                        format!("node '{}' reads buffer '{}' before any node produced it", n.name, self.sol.buffers[ib].name),
                    );
                }
            }
            producers.entry(n.output_buf).or_insert(k);
        }
    }

    /// Output tiles must exactly tile the tensor domain, per dimension.
    fn coverage(&mut self) {
        for b in &self.sol.buffers {
            if b.role != BufferRole::Output || b.home.is_none() {
                continue;
            }
            for (di, d) in b.dims.iter().enumerate() {
                let Some(l) = d.loop_idx else {
                    let covered = d.b.min(d.full);
                    if covered != d.full {
                        self.error(
                            Rule::CoverageGap,
                            format!("output '{}' dim {di}: fixed tile writes {covered} of {} elements", b.name, d.full),
                        );
                    }
                    continue;
                };
                let lp = &self.sol.loops[l];
                if lp.trips() > COVERAGE_TRIP_CAP {
                    self.warn(
                        Rule::CoverageGap,
                        format!("output '{}' dim {di}: {} trips, too many to enumerate coverage", b.name, lp.trips()),
                    );
                    continue;
                }
                let mut intervals: BTreeSet<(usize, usize)> = BTreeSet::new();
                let mut off = 0usize;
                while off < lp.full {
                    let cur = lp.tile.min(lp.full - off);
                    let o = (d.a * off).min(d.full.saturating_sub(1));
                    let t = (d.a * cur + d.b).min(d.full - o);
                    intervals.insert((o, o + t));
                    off += lp.tile;
                }
                let mut cursor = 0usize;
                let mut flagged = false;
                for &(s, e) in &intervals {
                    if s > cursor {
                        self.error(
                            Rule::CoverageGap,
                            format!("output '{}' dim {di}: elements [{cursor}, {s}) are never written", b.name),
                        );
                        flagged = true;
                        break;
                    }
                    if s < cursor {
                        self.error(
                            Rule::CoverageOverlap,
                            format!("output '{}' dim {di}: tiles [{s}, {e}) double-write elements below {cursor}", b.name),
                        );
                        flagged = true;
                        break;
                    }
                    cursor = e;
                }
                if !flagged && cursor != d.full {
                    self.error(
                        Rule::CoverageGap,
                        format!("output '{}' dim {di}: tiles cover [0, {cursor}) of {} elements", b.name, d.full),
                    );
                }
            }
        }
    }

    /// Per-iteration pass: trip counts, transfers, kernels, DMA hazards.
    fn steps(&mut self, bytes: &[usize]) {
        let total = self.sol.loops.iter().fold(1u128, |acc, l| acc.saturating_mul(l.trips() as u128));
        if total > ITER_CAP {
            self.warn(Rule::TripCount, format!("nest has {total} iterations, too many to verify per-iteration"));
            return;
        }
        let total = total as usize;
        if self.phase.steps.len() != total {
            self.error(
                Rule::TripCount,
                format!("schedule has {} steps, the loop nest implies {total}", self.phase.steps.len()),
            );
            return;
        }

        let loops = &self.sol.loops;
        let nl = loops.len();
        let mut state: Vec<(usize, usize)> = loops.iter().map(|l| (0, l.tile.min(l.full))).collect();
        let mut changed = 0usize;
        let kernel_reads: Vec<usize> = {
            let mut s: Vec<usize> = self.sol.nodes.iter().flat_map(|n| n.input_bufs.iter().copied()).collect();
            s.sort_unstable();
            s.dedup();
            s
        };
        let kernel_writes: Vec<usize> = {
            let mut s: Vec<usize> = self.sol.nodes.iter().map(|n| n.output_buf).collect();
            s.sort_unstable();
            s.dedup();
            s
        };
        let mut prev_stored: Vec<usize> = Vec::new();
        let mut race_seen: HashSet<(usize, usize, u8)> = HashSet::new();

        for i in 0..total {
            let next_pos = (0..nl).rev().find(|&k| state[k].0 + loops[k].tile < loops[k].full);
            let next_changed = next_pos;
            let step = &self.phase.steps[i];

            // -------- inbound transfers + the prefetch span set
            let mut expect_in: Vec<(usize, Transfer)> = Vec::new();
            let mut fetched: Vec<usize> = Vec::new();
            for (bi, b) in self.sol.buffers.iter().enumerate() {
                if !matches!(b.role, BufferRole::Input | BufferRole::Weight) {
                    continue;
                }
                let Some(home) = b.home else { continue };
                if i == 0 || changed < b.fetch_depth {
                    fetched.push(bi);
                    let shape = b.shape_at(&state);
                    let rows: usize = shape[..shape.len() - 1].iter().product::<usize>().max(1);
                    let row_bytes = shape.last().copied().unwrap_or(1) * b.elem_bytes;
                    for leg in dma_legs(home, true, rows, row_bytes) {
                        expect_in.push((bi, leg));
                    }
                }
            }
            self.check_transfers(i, "inbound", &step.dma_in, &expect_in);

            // -------- kernels
            if step.kernels.len() != self.sol.nodes.len() {
                self.error(
                    Rule::KernelShape,
                    format!("step {i}: {} kernels, group has {} nodes", step.kernels.len(), self.sol.nodes.len()),
                );
            } else {
                for (k, n) in step.kernels.iter().zip(&self.sol.nodes) {
                    let out_shape = self.sol.buffers[n.output_buf].shape_at(&state);
                    if k.name != n.name || k.unit != n.unit {
                        self.error(
                            Rule::KernelShape,
                            format!("step {i}: kernel '{}' on {} but node is '{}' on {}", k.name, k.unit.name(), n.name, n.unit.name()),
                        );
                    } else if k.out_shape != out_shape {
                        self.error(
                            Rule::KernelShape,
                            format!("step {i}: kernel '{}' output {:?} but tile expressions derive {:?}", k.name, k.out_shape, out_shape),
                        );
                    } else if let Some(soc) = self.soc {
                        let in_shapes: Vec<Vec<usize>> =
                            n.input_bufs.iter().map(|&bi| self.sol.buffers[bi].shape_at(&state)).collect();
                        let in_refs: Vec<&[usize]> = in_shapes.iter().map(|s| s.as_slice()).collect();
                        let cycles = KernelCostModel::tile_cycles(soc, &n.op, n.unit, &in_refs, &out_shape);
                        if k.cycles != cycles {
                            self.error(
                                Rule::KernelShape,
                                format!("step {i}: kernel '{}' claims {} cycles, cost model derives {cycles}", k.name, k.cycles),
                            );
                        }
                    }
                }
            }

            // -------- outbound transfers + the store span set
            let mut expect_out: Vec<(usize, Transfer)> = Vec::new();
            let mut stored: Vec<usize> = Vec::new();
            for (bi, b) in self.sol.buffers.iter().enumerate() {
                if b.role != BufferRole::Output {
                    continue;
                }
                let Some(home) = b.home else { continue };
                let store_now = match next_changed {
                    None => true,
                    Some(nc) => nc < b.fetch_depth,
                };
                if store_now {
                    stored.push(bi);
                    let shape = b.shape_at(&state);
                    let rows: usize = shape[..shape.len() - 1].iter().product::<usize>().max(1);
                    let row_bytes = shape.last().copied().unwrap_or(1) * b.elem_bytes;
                    for leg in dma_legs(home, false, rows, row_bytes) {
                        expect_out.push((bi, leg));
                    }
                }
            }
            self.check_transfers(i, "outbound", &step.dma_out, &expect_out);

            // -------- hazards: in a double-buffered phase step i's DMA
            // overlaps step i−1's kernels, so their L1 spans must be
            // disjoint. Single-buffered phases serialize DMA and compute.
            if self.phase.double_buffered && i > 0 {
                for &wb in &fetched {
                    let Some(ws) = self.span(wb, i, bytes) else { continue };
                    for &rb in &kernel_reads {
                        if let Some(rs) = self.span(rb, i - 1, bytes) {
                            if crate::memory::spans_overlap(ws, rs) && race_seen.insert((wb, rb, 0)) {
                                self.race(i, "WAR", wb, rb, "prefetch into", "kernel read of");
                            }
                        }
                    }
                    for &ob in &kernel_writes {
                        if let Some(os) = self.span(ob, i - 1, bytes) {
                            if crate::memory::spans_overlap(ws, os) && race_seen.insert((wb, ob, 1)) {
                                self.race(i, "WAW", wb, ob, "prefetch into", "kernel write of");
                            }
                        }
                    }
                }
                for &sb in &prev_stored {
                    let Some(ss) = self.span(sb, i - 1, bytes) else { continue };
                    for &ob in &kernel_writes {
                        if let Some(os) = self.span(ob, i, bytes) {
                            if crate::memory::spans_overlap(os, ss) && race_seen.insert((ob, sb, 2)) {
                                self.race(i, "RAW", ob, sb, "kernel write to", "in-flight store of");
                            }
                        }
                    }
                }
            }
            prev_stored = stored;

            // -------- advance the odometer
            if let Some(k) = next_pos {
                let noff = state[k].0 + loops[k].tile;
                state[k] = (noff, loops[k].tile.min(loops[k].full - noff));
                for j in k + 1..nl {
                    state[j] = (0, loops[j].tile.min(loops[j].full));
                }
                changed = k;
            }
        }
    }

    /// L1 byte span of buffer `bi`'s copy used at step `i` (None for
    /// zero-size buffers or indices the — possibly corrupt — arena lacks).
    fn span(&self, bi: usize, i: usize, bytes: &[usize]) -> Option<(usize, usize)> {
        let offs = self.phase.arena.offsets.get(bi)?;
        let size = *bytes.get(bi)?;
        if offs.is_empty() || size == 0 {
            return None;
        }
        let o = offs[i % offs.len()];
        Some((o, o + size))
    }

    fn race(&mut self, i: usize, kind: &str, a: usize, b: usize, verb_a: &str, verb_b: &str) {
        let name = |bi: usize| {
            self.sol.buffers.get(bi).map(|b| b.name.clone()).unwrap_or_else(|| format!("#{bi}"))
        };
        let (na, nb) = (name(a), name(b));
        self.error(
            Rule::DmaRace,
            format!("{kind} hazard at step {i}: {verb_a} '{na}' overlaps step {}'s {verb_b} '{nb}'", i - 1),
        );
    }

    /// Compare a step's actual transfer list against the re-derived one.
    fn check_transfers(&mut self, i: usize, dir: &str, actual: &[Transfer], expected: &[(usize, Transfer)]) {
        if actual.len() != expected.len() {
            self.error(
                Rule::TransferShape,
                format!("step {i}: {} {dir} transfers, tile expressions derive {}", actual.len(), expected.len()),
            );
            return;
        }
        for (act, (bi, exp)) in actual.iter().zip(expected) {
            if act == exp {
                continue;
            }
            let b = &self.sol.buffers[*bi];
            // Out-of-extent geometry is a bounds violation; anything else
            // (wrong legs, wrong tile geometry within extent) is a shape
            // disagreement with the tile expressions.
            let full_last = b.dims.last().map_or(1, |d| d.full) as u128;
            let other_full: u128 = if b.dims.len() > 1 {
                b.dims[..b.dims.len() - 1].iter().fold(1u128, |acc, d| acc.saturating_mul(d.full as u128))
            } else {
                1
            };
            let out_of_extent = (act.row_bytes as u128) > full_last.saturating_mul(b.elem_bytes as u128)
                || (act.planes as u128).saturating_mul(act.rows as u128) > other_full;
            if out_of_extent {
                self.error(
                    Rule::TransferBounds,
                    format!(
                        "step {i}: {dir} transfer for '{}' ({}×{}×{}B) exceeds the tensor extent",
                        b.name, act.planes, act.rows, act.row_bytes
                    ),
                );
            } else {
                self.error(
                    Rule::TransferShape,
                    format!(
                        "step {i}: {dir} transfer for '{}' is {}→{} {}×{}×{}B, expected {}→{} {}×{}×{}B",
                        b.name,
                        act.from.name(),
                        act.to.name(),
                        act.planes,
                        act.rows,
                        act.row_bytes,
                        exp.from.name(),
                        exp.to.name(),
                        exp.planes,
                        exp.rows,
                        exp.row_bytes
                    ),
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeployConfig;
    use crate::coordinator::Deployer;
    use crate::ir::builder::vit_mlp;
    use crate::ir::DType;
    use crate::tiling::Strategy;

    fn plan(soc: &str, strategy: Strategy, dbuf: bool) -> (Deployment, DeployConfig) {
        let g = vit_mlp(197, 768, 3072, DType::Int8);
        let mut cfg = DeployConfig::preset(soc, strategy).unwrap();
        cfg.double_buffer = dbuf;
        (Deployer::new(g, cfg.clone()).plan().unwrap(), cfg)
    }

    #[test]
    fn valid_plans_have_zero_findings() {
        for soc in ["siracusa", "cluster-only"] {
            for strategy in [Strategy::Ftl, Strategy::LayerPerLayer] {
                for dbuf in [false, true] {
                    let (d, cfg) = plan(soc, strategy, dbuf);
                    let report = check_deployment(&d, Some(&cfg.soc));
                    assert!(
                        report.findings.is_empty(),
                        "{soc}/{strategy:?}/dbuf={dbuf}:\n{}",
                        report.render()
                    );
                }
            }
        }
    }

    #[test]
    fn soc_free_check_passes_valid_plans() {
        let (d, _) = plan("siracusa", Strategy::Ftl, true);
        let report = check_deployment(&d, None);
        assert!(report.findings.is_empty(), "{}", report.render());
    }

    #[test]
    fn group_count_mismatch_is_malformed() {
        let (mut d, cfg) = plan("siracusa", Strategy::Ftl, false);
        d.schedule.phases.pop();
        let report = check_deployment(&d, Some(&cfg.soc));
        assert!(!report.ok());
        assert!(report.error_rules().contains(&Rule::Malformed));
    }

    #[test]
    fn corrupt_indices_never_panic() {
        let (mut d, cfg) = plan("siracusa", Strategy::Ftl, true);
        d.solution.groups[0].nodes[0].output_buf = 999;
        d.solution.groups[0].buffers[0].fetch_depth = 99;
        d.solution.groups[0].loops[0].tile = 0;
        let report = check_deployment(&d, Some(&cfg.soc));
        assert!(!report.ok());
    }

    #[test]
    fn rule_names_roundtrip() {
        for r in Rule::ALL {
            assert_eq!(Rule::parse(r.name()), Some(r), "{r:?}");
        }
        assert_eq!(Rule::parse("nope"), None);
        for s in [Severity::Warning, Severity::Error] {
            assert_eq!(Severity::parse(s.name()), Some(s));
        }
    }

    #[test]
    fn finding_json_roundtrip() {
        for (rule, severity, phase) in [
            (Rule::ArenaOverlap, Severity::Error, Some(3)),
            (Rule::TripCount, Severity::Warning, None),
        ] {
            let f = Finding { rule, severity, phase, detail: "details \"quoted\"".to_string() };
            let back = Finding::from_json(&f.to_json()).unwrap();
            assert_eq!(back, f);
        }
    }

    #[test]
    fn report_renders_and_serializes() {
        let (mut d, cfg) = plan("siracusa", Strategy::Ftl, true);
        // Collide two arena offsets.
        let offs = &mut d.schedule.phases[0].arena.offsets;
        let o0 = offs[0][0];
        offs[1][0] = o0;
        let report = check_deployment(&d, Some(&cfg.soc));
        assert!(!report.ok());
        assert!(report.error_rules().contains(&Rule::ArenaOverlap));
        assert!(report.render().contains("arena-overlap"));
        let j = report.to_json();
        assert!(!j.get("ok").unwrap().as_bool().unwrap());
        assert!(j.get("errors").unwrap().as_usize().unwrap() >= 1);
    }
}
