//! Transfer accounting — the source of the paper's "−47.1 % DMA
//! transfers" metric.

#![forbid(unsafe_code)]

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::memory::Level;
use crate::util::bincode::{BinReader, BinWriter};
use crate::util::json::Json;

use super::{DmaDirection, Transfer};

/// Aggregated DMA statistics for one run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DmaStats {
    /// Number of transfer commands issued, per channel level.
    pub transfers: BTreeMap<Level, u64>,
    /// Payload bytes moved, per channel level.
    pub bytes: BTreeMap<Level, u64>,
    /// Cycles spent by each DMA channel (busy time, not wall time).
    pub busy_cycles: BTreeMap<Level, u64>,
    /// In/out split of payload bytes.
    pub bytes_in: u64,
    /// Bytes moved away from compute.
    pub bytes_out: u64,
}

impl DmaStats {
    /// Record one transfer taking `cycles` on its channel.
    pub fn record(&mut self, t: &Transfer, cycles: u64) {
        let ch = t.channel_level();
        *self.transfers.entry(ch).or_default() += 1;
        *self.bytes.entry(ch).or_default() += t.bytes() as u64;
        *self.busy_cycles.entry(ch).or_default() += cycles;
        match t.direction() {
            DmaDirection::In => self.bytes_in += t.bytes() as u64,
            DmaDirection::Out => self.bytes_out += t.bytes() as u64,
        }
    }

    /// Total transfer commands across channels.
    pub fn total_transfers(&self) -> u64 {
        self.transfers.values().sum()
    }

    /// Total payload bytes across channels.
    pub fn total_bytes(&self) -> u64 {
        self.bytes.values().sum()
    }

    /// Bytes on a specific channel.
    pub fn bytes_at(&self, level: Level) -> u64 {
        self.bytes.get(&level).copied().unwrap_or(0)
    }

    /// Transfers on a specific channel.
    pub fn transfers_at(&self, level: Level) -> u64 {
        self.transfers.get(&level).copied().unwrap_or(0)
    }

    /// Merge another stats block into this one.
    pub fn merge(&mut self, other: &DmaStats) {
        for (k, v) in &other.transfers {
            *self.transfers.entry(*k).or_default() += v;
        }
        for (k, v) in &other.bytes {
            *self.bytes.entry(*k).or_default() += v;
        }
        for (k, v) in &other.busy_cycles {
            *self.busy_cycles.entry(*k).or_default() += v;
        }
        self.bytes_in += other.bytes_in;
        self.bytes_out += other.bytes_out;
    }

    /// Percentage reduction of transfer count vs a baseline
    /// (`100 * (base - self) / base`).
    pub fn transfer_reduction_vs(&self, baseline: &DmaStats) -> f64 {
        let b = baseline.total_transfers() as f64;
        if b == 0.0 {
            return 0.0;
        }
        100.0 * (b - self.total_transfers() as f64) / b
    }

    /// Percentage reduction of byte volume vs a baseline.
    pub fn byte_reduction_vs(&self, baseline: &DmaStats) -> f64 {
        let b = baseline.total_bytes() as f64;
        if b == 0.0 {
            return 0.0;
        }
        100.0 * (b - self.total_bytes() as f64) / b
    }

    /// Canonical JSON encoding (the snapshot codec — see
    /// [`crate::serve::persist`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("transfers", level_map_to_json(&self.transfers)),
            ("bytes", level_map_to_json(&self.bytes)),
            ("busy_cycles", level_map_to_json(&self.busy_cycles)),
            ("bytes_in", Json::int(self.bytes_in as usize)),
            ("bytes_out", Json::int(self.bytes_out as usize)),
        ])
    }

    /// Decode the canonical JSON encoding.
    pub fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            transfers: level_map_from_json(v.get("transfers")?)?,
            bytes: level_map_from_json(v.get("bytes")?)?,
            busy_cycles: level_map_from_json(v.get("busy_cycles")?)?,
            bytes_in: v.get("bytes_in")?.as_u64()?,
            bytes_out: v.get("bytes_out")?.as_u64()?,
        })
    }

    /// Canonical binary encoding (`ftl-bin-v1`).
    pub fn to_bin(&self, w: &mut BinWriter) {
        level_map_to_bin(&self.transfers, w);
        level_map_to_bin(&self.bytes, w);
        level_map_to_bin(&self.busy_cycles, w);
        w.u64(self.bytes_in);
        w.u64(self.bytes_out);
    }

    /// Decode the canonical binary encoding.
    pub fn from_bin(r: &mut BinReader) -> Result<Self> {
        Ok(Self {
            transfers: level_map_from_bin(r)?,
            bytes: level_map_from_bin(r)?,
            busy_cycles: level_map_from_bin(r)?,
            bytes_in: r.u64()?,
            bytes_out: r.u64()?,
        })
    }
}

fn level_map_to_bin(m: &BTreeMap<Level, u64>, w: &mut BinWriter) {
    let entries: Vec<(Level, u64)> = m.iter().map(|(l, &v)| (*l, v)).collect();
    w.seq(&entries, |w, (l, v)| {
        w.str(l.name());
        w.u64(*v);
    });
}

fn level_map_from_bin(r: &mut BinReader) -> Result<BTreeMap<Level, u64>> {
    let entries = r.seq(|r| {
        let name = r.str()?;
        let level = Level::parse(&name).ok_or_else(|| anyhow!("unknown memory level '{name}'"))?;
        Ok((level, r.u64()?))
    })?;
    Ok(entries.into_iter().collect())
}

fn level_map_to_json(m: &BTreeMap<Level, u64>) -> Json {
    Json::Obj(m.iter().map(|(l, &v)| (l.name().to_string(), Json::int(v as usize))).collect())
}

fn level_map_from_json(v: &Json) -> Result<BTreeMap<Level, u64>> {
    let Json::Obj(m) = v else { bail!("expected an object of per-level counters") };
    m.iter()
        .map(|(k, v)| {
            let level = Level::parse(k).ok_or_else(|| anyhow!("unknown memory level '{k}'"))?;
            Ok((level, v.as_u64()?))
        })
        .collect()
}

/// Optional per-transfer log (used by `--trace` and the test suite).
#[derive(Debug, Clone, Default)]
pub struct TransferLog {
    /// (issue-cycle, transfer, duration) triples in issue order.
    pub entries: Vec<(u64, Transfer, u64)>,
}

impl TransferLog {
    /// Append an entry.
    pub fn push(&mut self, at: u64, t: Transfer, cycles: u64) {
        self.entries.push((at, t, cycles));
    }

    /// Number of logged transfers.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t_l2l1(bytes: usize) -> Transfer {
        Transfer::d1(Level::L2, Level::L1, bytes)
    }

    #[test]
    fn record_and_totals() {
        let mut s = DmaStats::default();
        s.record(&t_l2l1(100), 40);
        s.record(&Transfer::d1(Level::L1, Level::L2, 50), 20);
        s.record(&Transfer::d1(Level::L3, Level::L2, 200), 700);
        assert_eq!(s.total_transfers(), 3);
        assert_eq!(s.total_bytes(), 350);
        assert_eq!(s.bytes_at(Level::L2), 150);
        assert_eq!(s.bytes_at(Level::L3), 200);
        assert_eq!(s.bytes_in, 300);
        assert_eq!(s.bytes_out, 50);
    }

    #[test]
    fn reduction_math() {
        let mut base = DmaStats::default();
        for _ in 0..100 {
            base.record(&t_l2l1(10), 5);
        }
        let mut fused = DmaStats::default();
        for _ in 0..53 {
            fused.record(&t_l2l1(10), 5);
        }
        let red = fused.transfer_reduction_vs(&base);
        assert!((red - 47.0).abs() < 1e-9);
    }

    #[test]
    fn merge_adds() {
        let mut a = DmaStats::default();
        a.record(&t_l2l1(10), 5);
        let mut b = DmaStats::default();
        b.record(&t_l2l1(30), 8);
        a.merge(&b);
        assert_eq!(a.total_transfers(), 2);
        assert_eq!(a.total_bytes(), 40);
    }

    #[test]
    fn json_roundtrip() {
        let mut s = DmaStats::default();
        s.record(&t_l2l1(100), 40);
        s.record(&Transfer::d1(Level::L1, Level::L2, 50), 20);
        s.record(&Transfer::d1(Level::L3, Level::L2, 200), 700);
        let back = DmaStats::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
        // Empty stats round-trip too (fresh maps).
        assert_eq!(DmaStats::from_json(&DmaStats::default().to_json()).unwrap(), DmaStats::default());
    }

    #[test]
    fn empty_baseline_reduction_is_zero() {
        let s = DmaStats::default();
        assert_eq!(s.transfer_reduction_vs(&DmaStats::default()), 0.0);
    }
}
