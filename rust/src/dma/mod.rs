//! DMA engine model.
//!
//! RISC-V SoCs in the Siracusa family move tiles with autonomous DMA
//! engines (MCHAN-class for L2↔L1, a HyperBus/IO DMA for L3↔L2) that
//! support strided 1-D/2-D/3-D transfers. A tile of a row-major tensor is
//! a 2-D (or 3-D) transfer: `rows` contiguous runs of `row_bytes`,
//! separated by `src_stride`/`dst_stride`.
//!
//! The cost model mirrors GVSoC's: a fixed per-command setup latency plus
//! bandwidth-limited streaming, with an extra per-row beat charge for
//! strided transfers (2-D descriptors re-arm per row).

#![forbid(unsafe_code)]

mod stats;
mod transfer;

pub use stats::{DmaStats, TransferLog};
pub use transfer::{DmaCostModel, DmaDirection, Transfer};
