//! Transfer descriptors and the DMA cost model.

#![forbid(unsafe_code)]

use anyhow::{anyhow, Result};

use crate::memory::Level;
use crate::util::bincode::{BinReader, BinWriter};
use crate::util::json::Json;

/// Direction of a transfer between two adjacent levels.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DmaDirection {
    /// Toward compute (e.g. L2→L1 tile load).
    In,
    /// Away from compute (e.g. L1→L2 tile store).
    Out,
}

/// A (possibly strided) DMA transfer between two memory levels.
///
/// `rows` runs of `row_bytes` contiguous bytes each. A fully contiguous
/// transfer has `rows == 1`. 3-D transfers are expressed as `planes`
/// repetitions of the 2-D pattern (the MCHAN 3-D extension the paper
/// relies on).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Transfer {
    /// Source level.
    pub from: Level,
    /// Destination level.
    pub to: Level,
    /// Number of 2-D planes (1 for 1-D/2-D transfers).
    pub planes: usize,
    /// Rows per plane.
    pub rows: usize,
    /// Contiguous bytes per row.
    pub row_bytes: usize,
}

impl Transfer {
    /// Contiguous 1-D transfer.
    pub fn d1(from: Level, to: Level, bytes: usize) -> Self {
        Self { from, to, planes: 1, rows: 1, row_bytes: bytes }
    }

    /// Strided 2-D transfer (`rows` × `row_bytes`).
    pub fn d2(from: Level, to: Level, rows: usize, row_bytes: usize) -> Self {
        Self { from, to, planes: 1, rows, row_bytes }
    }

    /// 3-D transfer.
    pub fn d3(from: Level, to: Level, planes: usize, rows: usize, row_bytes: usize) -> Self {
        Self { from, to, planes, rows, row_bytes }
    }

    /// Total payload bytes.
    pub fn bytes(&self) -> usize {
        self.planes * self.rows * self.row_bytes
    }

    /// Direction relative to compute (L1).
    pub fn direction(&self) -> DmaDirection {
        if self.to < self.from {
            DmaDirection::In
        } else {
            DmaDirection::Out
        }
    }

    /// The *outer* of the two levels — identifies which DMA engine/channel
    /// services this transfer (L2↔L1 → cluster DMA; L3↔L2 → IO DMA).
    pub fn channel_level(&self) -> Level {
        self.from.max(self.to)
    }

    /// Canonical JSON encoding (the snapshot codec — see
    /// [`crate::serve::persist`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("from", Json::str(self.from.name())),
            ("to", Json::str(self.to.name())),
            ("planes", Json::int(self.planes)),
            ("rows", Json::int(self.rows)),
            ("row_bytes", Json::int(self.row_bytes)),
        ])
    }

    /// Decode the canonical JSON encoding.
    pub fn from_json(v: &Json) -> Result<Self> {
        let level = |key: &str| -> Result<Level> {
            let name = v.get(key)?.as_str()?;
            Level::parse(name).ok_or_else(|| anyhow!("unknown memory level '{name}'"))
        };
        Ok(Self {
            from: level("from")?,
            to: level("to")?,
            planes: v.get("planes")?.as_usize()?,
            rows: v.get("rows")?.as_usize()?,
            row_bytes: v.get("row_bytes")?.as_usize()?,
        })
    }

    /// Canonical binary encoding (`ftl-bin-v1`).
    pub fn to_bin(&self, w: &mut BinWriter) {
        w.str(self.from.name());
        w.str(self.to.name());
        w.usize(self.planes);
        w.usize(self.rows);
        w.usize(self.row_bytes);
    }

    /// Decode the canonical binary encoding.
    pub fn from_bin(r: &mut BinReader) -> Result<Self> {
        let level = |r: &mut BinReader| -> Result<Level> {
            let name = r.str()?;
            Level::parse(&name).ok_or_else(|| anyhow!("unknown memory level '{name}'"))
        };
        Ok(Self {
            from: level(r)?,
            to: level(r)?,
            planes: r.usize()?,
            rows: r.usize()?,
            row_bytes: r.usize()?,
        })
    }
}

/// Cost model for one DMA engine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DmaCostModel {
    /// Fixed cycles to program + launch one transfer command.
    pub setup_cycles: u64,
    /// Extra cycles charged per row beyond the first (descriptor re-arm
    /// for strided transfers).
    pub per_row_cycles: u64,
    /// Streaming bandwidth in bytes per cycle (may be fractional, e.g.
    /// 0.5 B/cycle for a HyperRAM link at cluster clock).
    pub bytes_per_cycle: f64,
}

impl DmaCostModel {
    /// Cycles to complete `t` on this engine.
    pub fn cycles(&self, t: &Transfer) -> u64 {
        let stream = (t.bytes() as f64 / self.bytes_per_cycle).ceil() as u64;
        let rows = (t.planes * t.rows) as u64;
        self.setup_cycles + self.per_row_cycles * rows.saturating_sub(1) + stream
    }

    /// Cycles for a burst of identical transfers issued back-to-back.
    pub fn burst_cycles(&self, t: &Transfer, n: usize) -> u64 {
        self.cycles(t) * n as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const M: DmaCostModel = DmaCostModel { setup_cycles: 30, per_row_cycles: 2, bytes_per_cycle: 8.0 };

    #[test]
    fn payload_bytes() {
        assert_eq!(Transfer::d1(Level::L2, Level::L1, 100).bytes(), 100);
        assert_eq!(Transfer::d2(Level::L2, Level::L1, 16, 64).bytes(), 1024);
        assert_eq!(Transfer::d3(Level::L3, Level::L2, 4, 16, 64).bytes(), 4096);
    }

    #[test]
    fn direction_and_channel() {
        let load = Transfer::d1(Level::L2, Level::L1, 8);
        assert_eq!(load.direction(), DmaDirection::In);
        assert_eq!(load.channel_level(), Level::L2);
        let store = Transfer::d1(Level::L1, Level::L2, 8);
        assert_eq!(store.direction(), DmaDirection::Out);
        let spill = Transfer::d1(Level::L2, Level::L3, 8);
        assert_eq!(spill.channel_level(), Level::L3);
    }

    #[test]
    fn cost_1d() {
        let t = Transfer::d1(Level::L2, Level::L1, 800);
        assert_eq!(M.cycles(&t), 30 + 100);
    }

    #[test]
    fn cost_2d_charges_rows() {
        let contiguous = Transfer::d1(Level::L2, Level::L1, 1024);
        let strided = Transfer::d2(Level::L2, Level::L1, 16, 64);
        assert_eq!(strided.bytes(), contiguous.bytes());
        assert!(M.cycles(&strided) > M.cycles(&contiguous));
        assert_eq!(M.cycles(&strided) - M.cycles(&contiguous), 2 * 15);
    }

    #[test]
    fn fractional_bandwidth() {
        let slow = DmaCostModel { setup_cycles: 300, per_row_cycles: 8, bytes_per_cycle: 0.5 };
        let t = Transfer::d1(Level::L3, Level::L2, 100);
        assert_eq!(slow.cycles(&t), 300 + 200);
    }

    #[test]
    fn json_roundtrip() {
        for t in [
            Transfer::d1(Level::L2, Level::L1, 100),
            Transfer::d2(Level::L1, Level::L2, 16, 64),
            Transfer::d3(Level::L3, Level::L2, 4, 16, 64),
        ] {
            assert_eq!(Transfer::from_json(&t.to_json()).unwrap(), t);
        }
        let bad = crate::util::json::parse(r#"{"from":"L9","to":"L1","planes":1,"rows":1,"row_bytes":8}"#).unwrap();
        assert!(Transfer::from_json(&bad).is_err());
    }

    #[test]
    fn burst_is_linear() {
        let t = Transfer::d2(Level::L2, Level::L1, 4, 32);
        assert_eq!(M.burst_cycles(&t, 10), M.cycles(&t) * 10);
    }
}
