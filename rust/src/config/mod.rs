//! Deployment configuration: what the CLI / launcher feeds the
//! [`crate::coordinator::Deployer`], plus JSON (de)serialisation for
//! config files.

#![forbid(unsafe_code)]

use anyhow::{bail, Context, Result};

use crate::dma::DmaCostModel;
use crate::memory::{LevelSpec, MemoryHierarchy};
use crate::soc::{ClusterSpec, NpuSpec, SocConfig, SocPreset};
use crate::tiling::{HomesPolicy, SolverOptions, Strategy};
use crate::util::json::{parse, Json};

/// Alias kept for API continuity — the strategy enum lives in [`crate::tiling`].
pub type StrategyKind = Strategy;

/// Everything needed to deploy one network on one SoC.
#[derive(Debug, Clone)]
pub struct DeployConfig {
    /// Target SoC.
    pub soc: SocConfig,
    /// Tiling strategy.
    pub strategy: Strategy,
    /// Double-buffer streamed tiles (ping/pong) to overlap DMA & compute.
    pub double_buffer: bool,
    /// FTL solver options.
    pub solver: SolverOptions,
    /// L2 home-assignment policy.
    pub homes: HomesPolicy,
}

impl DeployConfig {
    /// Config from a preset name + strategy, with default solver options.
    pub fn preset(soc: &str, strategy: Strategy) -> Result<Self> {
        let preset = SocPreset::parse(soc)
            .with_context(|| format!("unknown SoC preset '{soc}' (try: siracusa, cluster-only)"))?;
        Ok(Self {
            soc: preset.config(),
            strategy,
            double_buffer: false,
            solver: SolverOptions::default(),
            homes: HomesPolicy::Resident,
        })
    }

    /// Load from a JSON file.
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
        let cfg = Self::from_json(&text)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Parse from JSON text.
    pub fn from_json(text: &str) -> Result<Self> {
        let v = parse(text).context("parsing deploy config JSON")?;
        let soc = soc_from_json(v.get("soc")?)?;
        let strategy = Strategy::parse(v.get("strategy")?.as_str()?)
            .context("strategy must be 'ftl' or 'layer-per-layer'")?;
        let double_buffer = v.get_opt("double_buffer").map(|b| b.as_bool()).transpose()?.unwrap_or(false);
        let solver = match v.get_opt("solver") {
            Some(s) => SolverOptions {
                use_perf_constraints: s
                    .get_opt("use_perf_constraints")
                    .map(|b| b.as_bool())
                    .transpose()?
                    .unwrap_or(true),
                max_candidates: s.get_opt("max_candidates").map(|n| n.as_usize()).transpose()?.unwrap_or(64),
                l1_budget_fraction: s
                    .get_opt("l1_budget_fraction")
                    .map(|n| n.as_f64())
                    .transpose()?
                    .unwrap_or(1.0),
            },
            None => SolverOptions::default(),
        };
        let homes = match v.get_opt("homes_policy").map(|h| h.as_str()).transpose()? {
            None | Some("resident") => HomesPolicy::Resident,
            Some("lifetime") => HomesPolicy::Lifetime,
            Some(other) => bail!("unknown homes_policy '{other}' (resident|lifetime)"),
        };
        Ok(Self { soc, strategy, double_buffer, solver, homes })
    }

    /// Serialise to pretty JSON.
    pub fn to_json(&self) -> String {
        Json::obj(vec![
            ("soc", soc_to_json(&self.soc)),
            ("strategy", Json::str(self.strategy.name())),
            (
                "homes_policy",
                Json::str(match self.homes {
                    HomesPolicy::Resident => "resident",
                    HomesPolicy::Lifetime => "lifetime",
                }),
            ),
            ("double_buffer", Json::Bool(self.double_buffer)),
            (
                "solver",
                Json::obj(vec![
                    ("use_perf_constraints", Json::Bool(self.solver.use_perf_constraints)),
                    ("max_candidates", Json::int(self.solver.max_candidates)),
                    ("l1_budget_fraction", Json::Num(self.solver.l1_budget_fraction)),
                ]),
            ),
        ])
        .pretty()
    }

    /// Sanity checks.
    pub fn validate(&self) -> Result<()> {
        if self.soc.mem.l1.capacity == 0 || self.soc.mem.l2.capacity == 0 {
            bail!("SoC memory levels must have non-zero capacity");
        }
        if self.soc.cluster.cores == 0 {
            bail!("cluster must have at least one core");
        }
        if self.soc.dma_cluster.bytes_per_cycle <= 0.0 || self.soc.dma_io.bytes_per_cycle <= 0.0 {
            bail!("DMA bandwidths must be positive");
        }
        Ok(())
    }
}

fn level_to_json(l: &LevelSpec) -> Json {
    Json::obj(vec![("capacity", Json::int(l.capacity)), ("alignment", Json::int(l.alignment))])
}

fn level_from_json(v: &Json) -> Result<LevelSpec> {
    Ok(LevelSpec::new(v.get("capacity")?.as_usize()?, v.get("alignment")?.as_usize()?))
}

fn dma_to_json(d: &DmaCostModel) -> Json {
    Json::obj(vec![
        ("setup_cycles", Json::int(d.setup_cycles as usize)),
        ("per_row_cycles", Json::int(d.per_row_cycles as usize)),
        ("bytes_per_cycle", Json::Num(d.bytes_per_cycle)),
    ])
}

fn dma_from_json(v: &Json) -> Result<DmaCostModel> {
    Ok(DmaCostModel {
        setup_cycles: v.get("setup_cycles")?.as_usize()? as u64,
        per_row_cycles: v.get("per_row_cycles")?.as_usize()? as u64,
        bytes_per_cycle: v.get("bytes_per_cycle")?.as_f64()?,
    })
}

/// SoC config → JSON.
pub fn soc_to_json(s: &SocConfig) -> Json {
    let npu = match &s.npu {
        None => Json::Null,
        Some(n) => Json::obj(vec![
            ("macs_per_cycle", Json::Num(n.macs_per_cycle)),
            ("efficiency", Json::Num(n.efficiency)),
            ("job_setup_cycles", Json::int(n.job_setup_cycles as usize)),
        ]),
    };
    Json::obj(vec![
        ("name", Json::str(&s.name)),
        ("freq_mhz", Json::Num(s.freq_mhz)),
        (
            "mem",
            Json::obj(vec![
                ("l1", level_to_json(&s.mem.l1)),
                ("l2", level_to_json(&s.mem.l2)),
                ("l3", level_to_json(&s.mem.l3)),
            ]),
        ),
        (
            "cluster",
            Json::obj(vec![
                ("cores", Json::int(s.cluster.cores)),
                ("macs_per_core_cycle", Json::Num(s.cluster.macs_per_core_cycle)),
                ("gemm_efficiency", Json::Num(s.cluster.gemm_efficiency)),
                ("eltwise_per_core_cycle", Json::Num(s.cluster.eltwise_per_core_cycle)),
                ("kernel_setup_cycles", Json::int(s.cluster.kernel_setup_cycles as usize)),
            ]),
        ),
        ("npu", npu),
        ("dma_cluster", dma_to_json(&s.dma_cluster)),
        ("dma_io", dma_to_json(&s.dma_io)),
    ])
}

/// JSON → SoC config.
pub fn soc_from_json(v: &Json) -> Result<SocConfig> {
    let mem = v.get("mem")?;
    let cl = v.get("cluster")?;
    let npu = match v.get_opt("npu") {
        None | Some(Json::Null) => None,
        Some(n) => Some(NpuSpec {
            macs_per_cycle: n.get("macs_per_cycle")?.as_f64()?,
            efficiency: n.get("efficiency")?.as_f64()?,
            job_setup_cycles: n.get("job_setup_cycles")?.as_usize()? as u64,
        }),
    };
    Ok(SocConfig {
        name: v.get("name")?.as_str()?.to_string(),
        freq_mhz: v.get("freq_mhz")?.as_f64()?,
        mem: MemoryHierarchy {
            l1: level_from_json(mem.get("l1")?)?,
            l2: level_from_json(mem.get("l2")?)?,
            l3: level_from_json(mem.get("l3")?)?,
        },
        cluster: ClusterSpec {
            cores: cl.get("cores")?.as_usize()?,
            macs_per_core_cycle: cl.get("macs_per_core_cycle")?.as_f64()?,
            gemm_efficiency: cl.get("gemm_efficiency")?.as_f64()?,
            eltwise_per_core_cycle: cl.get("eltwise_per_core_cycle")?.as_f64()?,
            kernel_setup_cycles: cl.get("kernel_setup_cycles")?.as_usize()? as u64,
        },
        npu,
        dma_cluster: dma_from_json(v.get("dma_cluster")?)?,
        dma_io: dma_from_json(v.get("dma_io")?)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_parse() {
        assert_eq!(Strategy::parse("ftl"), Some(Strategy::Ftl));
        assert_eq!(Strategy::parse("baseline"), Some(Strategy::LayerPerLayer));
        assert_eq!(Strategy::parse("magic"), None);
    }

    #[test]
    fn preset_config_valid() {
        let cfg = DeployConfig::preset("siracusa", Strategy::Ftl).unwrap();
        cfg.validate().unwrap();
        assert!(cfg.soc.has_npu());
        let cfg = DeployConfig::preset("cluster-only", Strategy::LayerPerLayer).unwrap();
        assert!(!cfg.soc.has_npu());
        assert!(DeployConfig::preset("bogus", Strategy::Ftl).is_err());
    }

    #[test]
    fn json_roundtrip() {
        let cfg = DeployConfig::preset("siracusa", Strategy::Ftl).unwrap();
        let text = cfg.to_json();
        let back = DeployConfig::from_json(&text).unwrap();
        assert_eq!(back.strategy, Strategy::Ftl);
        assert_eq!(back.soc, cfg.soc);
        assert_eq!(back.solver, cfg.solver);
        assert_eq!(back.double_buffer, cfg.double_buffer);
    }

    #[test]
    fn npu_null_roundtrip() {
        let cfg = DeployConfig::preset("cluster-only", Strategy::LayerPerLayer).unwrap();
        let back = DeployConfig::from_json(&cfg.to_json()).unwrap();
        assert!(back.soc.npu.is_none());
    }
}
