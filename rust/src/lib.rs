//! # FTL — Fused-Tiled Layers
//!
//! A deployment framework for DNNs on SoCs with **software-managed memory
//! hierarchies** (scratchpads + DMA, no hardware caches), reproducing the
//! paper *"Fused-Tiled Layers: Minimizing Data Movement on RISC-V SoCs with
//! Software-Managed Caches"* (Jung, Burrello, Conti, Benini — CS.AR 2025).
//!
//! The core contribution is the [`tiling`] engine: each layer's tiling is
//! expressed as a constraint-optimisation problem over its tensor-dimension
//! variables; **fusion** of consecutive tiled layers is obtained by *binding*
//! the dimension variables of their shared tensor, so that a single solve
//! yields tile sizes valid for the whole fused group and the intermediate
//! tensor never materialises above L1.
//!
//! ## Pipeline
//!
//! ```text
//!  ir::Graph ──► tiling::fusion (group + bind vars)
//!            ──► tiling::solver (branch & bound, L1-capacity pruned)
//!            ──► memory::alloc  (static lifetime allocation, ping-pong)
//!            ──► schedule::{baseline,fused} (tiled DMA/kernel schedule)
//!            ──► sim::Engine    (event-driven runtime + DMA stats)
//!            ──► runtime::TileExecutor (PJRT numerics validation)
//!
//!  serving  (long-running planner service, `ftl serve`):
//!  request ──► serve::BatchScheduler (admission control: per-lane bounded
//!          │    queues, shed/block, deadlines; weighted-fair priority
//!          │    lanes (serve::lanes + serve::wfq, `lane=` protocol
//!          │    field); SoC-grouped batching + fan-out)
//!          ──► serve::fingerprint (stable content hash of graph+config)
//!          ──► serve::PlanCache   (sharded LRU of Arc<Deployment>) ── hit ─► ...
//!          ──► serve::SingleFlight (coalesce concurrent identical solves)
//!          ──► coordinator::Deployer::plan  (solve once, cache, share)
//!          ──► serve::SimCache    (sharded LRU of Arc<SimReport>) ── hit ─► reply
//!          ──► serve::persist     (versioned on-disk snapshots: warm-start
//!                                  both caches across restarts, --cache-dir)
//! ```
//!
//! ## Layers
//!
//! * **L3 (this crate)** — coordinator: IR, FTL solver, allocator, schedule
//!   generation, event-driven SoC simulator, PJRT runtime, CLI.
//! * **L2 (JAX, `python/compile/model.py`)** — ViT-MLP forward lowered AOT
//!   to HLO text artifacts.
//! * **L1 (Pallas, `python/compile/kernels/`)** — tiled GEMM / GeLU / fused
//!   GEMM+GeLU kernels (`interpret=True`), verified against `ref.py`.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured record.

pub mod config;
pub mod coordinator;
pub mod dma;
pub mod ir;
pub mod memory;
pub mod metrics;
pub mod runtime;
pub mod schedule;
pub mod serve;
pub mod sim;
pub mod soak;
pub mod soc;
pub mod tiling;
pub mod util;
pub mod verify;

pub use coordinator::{DeployReport, Deployer, Deployment};
pub use ir::{Graph, Op, Tensor};
pub use serve::{PlanService, ServeOptions};
pub use soc::SocConfig;
pub use tiling::{Strategy, TilingSolution};

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
