//! The deployment coordinator — the L3 entry point tying the whole flow
//! together:
//!
//! ```text
//! Graph ─► fuse_groups ─► assign_homes ─► solve_graph ─► build_schedule
//!       ─► sim::simulate (cycles, DMA)  and/or  runtime::TileExecutor
//! ```
//!
//! [`Deployer`] is the one-stop API used by the CLI, the examples and the
//! benches; [`experiments`] hosts the paper-reproduction drivers (Fig. 3,
//! DMA reduction, sweeps).

#![forbid(unsafe_code)]

pub mod experiments;

use anyhow::{anyhow, Context, Result};

use crate::config::DeployConfig;
use crate::ir::Graph;
use crate::memory::Level;
use crate::metrics;
use crate::runtime::{tile_key, HostTensor, KernelBackend, TileExecutor};
use crate::schedule::{build_schedule, Schedule};
use crate::sim::{simulate, simulate_with, SimReport};
use crate::tiling::{assign_homes_with, fuse_groups, solve_graph_with, FusionGroup, FusionPolicy, TilingSolution};
use crate::util::bincode::{BinReader, BinWriter};
use crate::util::json::Json;

/// A fully planned deployment (before simulation/execution).
///
/// Planning is deterministic and expensive; a `Deployment` is therefore a
/// cacheable artifact. The serve layer ([`crate::serve`]) shares plans as
/// `Arc<Deployment>` — prefer passing `&Deployment`/`Arc<Deployment>`
/// over cloning (the `Clone` impl exists for tooling that genuinely needs
/// an owned copy, e.g. mutation-based ablations).
#[derive(Debug, Clone, PartialEq)]
pub struct Deployment {
    /// Final fusion groups (after solver fallbacks).
    pub groups: Vec<FusionGroup>,
    /// Home level of each tensor (`None` = fused intermediate).
    pub homes: Vec<Option<Level>>,
    /// Solved tiling.
    pub solution: TilingSolution,
    /// Executable tiled schedule.
    pub schedule: Schedule,
}

impl Deployment {
    /// All distinct kernel-tile signatures this deployment invokes —
    /// consumed by `ftl emit-tiles` so `python/compile/aot.py` can AOT
    /// exactly the executables the runtime will need.
    pub fn tile_signatures(&self, graph: &Graph) -> Vec<(String, Vec<Vec<usize>>, Vec<usize>)> {
        let mut seen = std::collections::BTreeMap::new();
        for g in &self.solution.groups {
            for state in g.iterations() {
                for n in &g.nodes {
                    let in_shapes: Vec<Vec<usize>> =
                        n.input_bufs.iter().map(|&bi| g.buffers[bi].shape_at(&state)).collect();
                    let out_shape = g.buffers[n.output_buf].shape_at(&state);
                    let refs: Vec<&[usize]> = in_shapes.iter().map(|s| s.as_slice()).collect();
                    if let Some(key) = tile_key(&n.op, &refs, &out_shape) {
                        seen.entry(key).or_insert((in_shapes, out_shape));
                    }
                }
            }
        }
        let _ = graph;
        seen.into_iter().map(|(k, (i, o))| (k, i, o)).collect()
    }

    /// Simulate this plan on the config's SoC and assemble the standard
    /// per-request report. Planning is the expensive step — this is the
    /// cheap per-request half, so a cached plan (see [`crate::serve`])
    /// can be re-reported under any workload label without re-solving.
    pub fn report(&self, workload: &str, config: &DeployConfig) -> Result<DeployReport> {
        Ok(self.report_with_sim(workload, config, self.simulate(config)?))
    }

    /// Run the event-driven simulator over this plan's schedule.
    /// Deterministic for a fixed (schedule, SoC) — which is exactly why
    /// the serve layer can cache the resulting [`SimReport`] by plan
    /// fingerprint (see [`crate::serve`]).
    pub fn simulate(&self, config: &DeployConfig) -> Result<SimReport> {
        simulate(&self.schedule, &config.soc)
    }

    /// [`Self::simulate`], invoking `on_phase(index, total, report)` as
    /// each phase finishes — the serve layer streams these as partial
    /// `sim` reply events while the engine is still running.
    pub fn simulate_streamed(
        &self,
        config: &DeployConfig,
        on_phase: impl FnMut(usize, usize, &crate::sim::PhaseReport),
    ) -> Result<SimReport> {
        simulate_with(&self.schedule, &config.soc, on_phase)
    }

    /// Canonical JSON encoding of the whole compiled plan — the snapshot
    /// codec behind [`crate::serve::persist`]. Self-contained: everything
    /// needed to re-serve the plan (fusion groups, homes, solved tiling,
    /// executable schedule) is included; the source graph is not (the
    /// cache key, a content fingerprint of the request, already binds it).
    pub fn to_json(&self) -> Json {
        let homes: Vec<Json> = self
            .homes
            .iter()
            .map(|h| match h {
                None => Json::Null,
                Some(l) => Json::str(l.name()),
            })
            .collect();
        Json::obj(vec![
            ("groups", Json::Arr(self.groups.iter().map(|g| Json::ints(&g.nodes)).collect())),
            ("homes", Json::Arr(homes)),
            ("solution", self.solution.to_json()),
            ("schedule", self.schedule.to_json()),
        ])
    }

    /// Decode the canonical JSON encoding (inverse of
    /// [`Deployment::to_json`]).
    pub fn from_json(v: &Json) -> Result<Self> {
        let groups: Vec<FusionGroup> = v
            .get("groups")?
            .as_arr()?
            .iter()
            .map(|g| Ok(FusionGroup { nodes: g.as_usize_arr()? }))
            .collect::<Result<_>>()?;
        let homes: Vec<Option<Level>> = v
            .get("homes")?
            .as_arr()?
            .iter()
            .map(|h| match h {
                Json::Null => Ok(None),
                other => {
                    let name = other.as_str()?;
                    Level::parse(name).map(Some).ok_or_else(|| anyhow!("unknown memory level '{name}'"))
                }
            })
            .collect::<Result<_>>()?;
        Ok(Self {
            groups,
            homes,
            solution: TilingSolution::from_json(v.get("solution")?)?,
            schedule: crate::schedule::Schedule::from_json(v.get("schedule")?)?,
        })
    }

    /// Canonical binary encoding of the whole compiled plan — the
    /// `ftl-bin-v1` counterpart of [`Deployment::to_json`], used by the
    /// segment snapshot format ([`crate::serve::persist`]).
    pub fn to_bin(&self, w: &mut BinWriter) {
        w.seq(&self.groups, |w, g| w.usize_seq(&g.nodes));
        w.seq(&self.homes, |w, h| w.opt(h.as_ref(), |w, l| w.str(l.name())));
        self.solution.to_bin(w);
        self.schedule.to_bin(w);
    }

    /// Decode the canonical binary encoding (inverse of
    /// [`Deployment::to_bin`]).
    pub fn from_bin(r: &mut BinReader) -> Result<Self> {
        let groups: Vec<FusionGroup> = r.seq(|r| Ok(FusionGroup { nodes: r.usize_seq()? }))?;
        let homes: Vec<Option<Level>> = r.seq(|r| {
            r.opt(|r| {
                let name = r.str()?;
                Level::parse(&name).ok_or_else(|| anyhow!("unknown memory level '{name}'"))
            })
        })?;
        let solution = TilingSolution::from_bin(r)?;
        let schedule = crate::schedule::Schedule::from_bin(r)?;
        Ok(Self { groups, homes, solution, schedule })
    }

    /// Assemble the standard per-request report around an
    /// already-computed simulation (fresh or cache-shared). Everything
    /// except the workload label and the sim is derived from the plan.
    pub fn report_with_sim(&self, workload: &str, config: &DeployConfig, sim: SimReport) -> DeployReport {
        DeployReport {
            strategy: config.strategy.name().to_string(),
            soc: config.soc.name.clone(),
            workload: workload.to_string(),
            phases: self.schedule.phases.len(),
            peak_l1: self.solution.peak_l1(),
            dma_commands: self.schedule.dma_count(),
            dma_bytes: self.schedule.dma_bytes(),
            sim,
        }
    }
}

/// Per-deployment report: plan stats + simulation outcome.
#[derive(Debug, Clone)]
pub struct DeployReport {
    /// Strategy name.
    pub strategy: String,
    /// SoC name.
    pub soc: String,
    /// Workload name.
    pub workload: String,
    /// Number of fusion groups (phases).
    pub phases: usize,
    /// Peak L1 arena bytes.
    pub peak_l1: usize,
    /// Total DMA command count (planned).
    pub dma_commands: usize,
    /// Total DMA payload bytes (planned).
    pub dma_bytes: usize,
    /// Simulation outcome.
    pub sim: SimReport,
}

impl DeployReport {
    /// Human-readable report.
    pub fn render(&self, soc: &crate::soc::SocConfig) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "workload={} soc={} strategy={} phases={} peak_l1={}B dma_cmds={} dma_bytes={}\n",
            self.workload, self.soc, self.strategy, self.phases, self.peak_l1, self.dma_commands, self.dma_bytes
        ));
        s.push_str(&metrics::sim_table(&self.sim, soc));
        s.push_str(&metrics::dma_table(&self.sim.dma));
        s
    }

    /// Machine-readable report.
    pub fn to_json(&self, soc: &crate::soc::SocConfig) -> Json {
        Json::obj(vec![
            ("workload", Json::str(&self.workload)),
            ("strategy", Json::str(&self.strategy)),
            ("phases", Json::int(self.phases)),
            ("peak_l1", Json::int(self.peak_l1)),
            ("dma_commands", Json::int(self.dma_commands)),
            ("dma_bytes", Json::int(self.dma_bytes)),
            ("sim", metrics::sim_json(&self.sim, soc)),
        ])
    }
}

/// The deployment pipeline.
pub struct Deployer {
    graph: Graph,
    config: DeployConfig,
    policy: FusionPolicy,
    workload: String,
}

impl Deployer {
    /// New deployer for a graph + config.
    pub fn new(graph: Graph, config: DeployConfig) -> Self {
        Self { graph, config, policy: FusionPolicy::default(), workload: "custom".into() }
    }

    /// Set the workload name used in reports.
    pub fn with_workload_name(mut self, name: impl Into<String>) -> Self {
        self.workload = name.into();
        self
    }

    /// Override the fusion policy.
    pub fn with_policy(mut self, policy: FusionPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// The graph being deployed.
    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// The active config.
    pub fn config(&self) -> &DeployConfig {
        &self.config
    }

    /// Run the planning pipeline (steps ①–④ + allocation + schedule).
    pub fn plan(&self) -> Result<Deployment> {
        self.graph.validate()?;
        let groups = fuse_groups(&self.graph, self.config.strategy, self.policy);
        let (groups, solution) = solve_graph_with(
            &self.graph,
            &self.config.soc,
            groups,
            &self.config.solver,
            self.config.double_buffer,
            self.config.homes,
        )
        .context("tiling solve failed")?;
        let homes = assign_homes_with(&self.graph, &groups, &self.config.soc, self.config.homes);
        let schedule = build_schedule(&self.graph, &self.config.soc, &solution)?;
        Ok(Deployment { groups, homes, solution, schedule })
    }

    /// Plan + simulate.
    pub fn deploy(&self) -> Result<(Deployment, DeployReport)> {
        let d = self.plan()?;
        let report = d.report(&self.workload, &self.config)?;
        Ok((d, report))
    }

    /// Plan + execute numerically against the un-tiled oracle; returns
    /// the max output deviation.
    pub fn validate_numerics<B: KernelBackend>(&self, backend: B, seed: u64) -> Result<f32> {
        let d = self.plan()?;
        let bindings = crate::runtime::reference::random_bindings(&self.graph, seed);
        let oracle = crate::runtime::reference::run_graph(&self.graph, &bindings)?;
        let mut exec = TileExecutor::new(backend);
        let env = exec.run(&self.graph, &d.solution, &bindings)?;
        let mut worst = 0.0f32;
        for &out in &self.graph.outputs() {
            worst = worst.max(env[&out].max_abs_diff(&oracle[&out]));
        }
        Ok(worst)
    }

    /// Async-style request loop helper: deploy many graphs sequentially
    /// on a std::thread, reporting through a channel. (The coordinator is
    /// CPU-bound; a thread pool is the right tool without an async
    /// runtime dependency.)
    pub fn deploy_batch(
        requests: Vec<(String, Graph, DeployConfig)>,
    ) -> Vec<(String, Result<DeployReport>)> {
        let (tx, rx) = std::sync::mpsc::channel();
        let handles: Vec<_> = requests
            .into_iter()
            .map(|(name, graph, config)| {
                let tx = tx.clone();
                std::thread::spawn(move || {
                    let dep = Deployer::new(graph, config).with_workload_name(name.clone());
                    let out = dep.deploy().map(|(_, r)| r);
                    tx.send((name, out)).ok();
                })
            })
            .collect();
        drop(tx);
        let mut results: Vec<(String, Result<DeployReport>)> = rx.into_iter().collect();
        for h in handles {
            h.join().ok();
        }
        results.sort_by(|a, b| a.0.cmp(&b.0));
        results
    }
}

/// Binding helper re-exported for examples.
pub fn random_bindings(graph: &Graph, seed: u64) -> std::collections::HashMap<usize, HostTensor> {
    crate::runtime::reference::random_bindings(graph, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeployConfig;
    use crate::ir::builder::vit_mlp;
    use crate::ir::DType;
    use crate::runtime::NativeBackend;
    use crate::tiling::Strategy;

    #[test]
    fn full_pipeline_ftl() {
        let g = vit_mlp(197, 768, 3072, DType::Int8);
        let cfg = DeployConfig::preset("siracusa", Strategy::Ftl).unwrap();
        let dep = Deployer::new(g, cfg).with_workload_name("vit-base-mlp");
        let (d, report) = dep.deploy().unwrap();
        assert_eq!(report.phases, 2);
        assert!(report.sim.total_cycles > 0);
        assert!(d.solution.peak_l1() > 0);
        let rendered = report.render(&dep.config().soc);
        assert!(rendered.contains("fc1+gelu"));
    }

    #[test]
    fn numerics_validation_small() {
        let g = vit_mlp(16, 24, 48, DType::F32);
        let cfg = DeployConfig::preset("cluster-only", Strategy::Ftl).unwrap();
        let dep = Deployer::new(g, cfg);
        let worst = dep.validate_numerics(NativeBackend, 3).unwrap();
        assert!(worst < 1e-3, "deviation {worst}");
    }

    #[test]
    fn tile_signatures_nonempty_and_stable() {
        let g = vit_mlp(64, 32, 96, DType::F32);
        let cfg = DeployConfig::preset("siracusa", Strategy::Ftl).unwrap();
        let dep = Deployer::new(g, cfg);
        let d = dep.plan().unwrap();
        let sigs = d.tile_signatures(dep.graph());
        assert!(!sigs.is_empty());
        assert!(sigs.iter().any(|(k, _, _)| k.starts_with("gemm")));
        // deterministic ordering (BTreeMap)
        let sigs2 = d.tile_signatures(dep.graph());
        assert_eq!(
            sigs.iter().map(|s| &s.0).collect::<Vec<_>>(),
            sigs2.iter().map(|s| &s.0).collect::<Vec<_>>()
        );
    }

    #[test]
    fn deployment_json_roundtrip() {
        for (soc, strategy, dbuf) in [
            ("siracusa", Strategy::Ftl, false),
            ("cluster-only", Strategy::LayerPerLayer, false),
            ("siracusa", Strategy::Ftl, true),
        ] {
            let g = vit_mlp(64, 32, 96, DType::Int8);
            let mut cfg = DeployConfig::preset(soc, strategy).unwrap();
            cfg.double_buffer = dbuf;
            let d = Deployer::new(g, cfg).plan().unwrap();
            let back = Deployment::from_json(&d.to_json()).unwrap();
            assert_eq!(back, d, "deployment must round-trip ({soc}, {strategy:?}, dbuf={dbuf})");
            // And the decoded plan is still *servable*: its report matches.
            let cfg2 = {
                let mut c = DeployConfig::preset(soc, strategy).unwrap();
                c.double_buffer = dbuf;
                c
            };
            let sim_a = d.simulate(&cfg2).unwrap();
            let sim_b = back.simulate(&cfg2).unwrap();
            assert_eq!(sim_a.total_cycles, sim_b.total_cycles);
        }
    }

    #[test]
    fn deploy_batch_parallel() {
        let reqs = vec![
            (
                "a".to_string(),
                vit_mlp(32, 32, 64, DType::Int8),
                DeployConfig::preset("siracusa", Strategy::Ftl).unwrap(),
            ),
            (
                "b".to_string(),
                vit_mlp(32, 32, 64, DType::Int8),
                DeployConfig::preset("cluster-only", Strategy::LayerPerLayer).unwrap(),
            ),
        ];
        let results = Deployer::deploy_batch(reqs);
        assert_eq!(results.len(), 2);
        assert!(results.iter().all(|(_, r)| r.is_ok()));
    }
}
