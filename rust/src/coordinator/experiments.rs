//! Paper-reproduction experiment drivers.
//!
//! Every table/figure of the paper (and each extension ablation from
//! DESIGN.md) has a function here returning structured rows; the CLI and
//! the bench binaries print them. See EXPERIMENTS.md for paper-vs-measured.

#![forbid(unsafe_code)]

use anyhow::Result;

use crate::config::DeployConfig;
use crate::ir::builder::vit_mlp;
use crate::ir::{DType, Graph};
use crate::metrics::Table;
use crate::tiling::Strategy;

use super::{DeployReport, Deployer};

/// The paper's benchmark workload: the ViT MLP *stage* — GEMM(d→h)+bias
/// followed by GeLU (the fusion pair Fig. 3 measures).
pub fn vit_mlp_stage(seq: usize, d: usize, h: usize) -> Graph {
    use crate::ir::{ActKind, GraphBuilder};
    let mut b = GraphBuilder::new(DType::Int8);
    let x = b.input("x", &[seq, d]);
    let fc1 = b.linear("fc1", x, h, true);
    let act = b.act("gelu", ActKind::Gelu, fc1);
    b.finish(act).expect("vit_mlp_stage is valid by construction")
}

/// One Fig. 3 bar.
#[derive(Debug, Clone)]
pub struct Fig3Row {
    /// `cluster` or `cluster+npu`.
    pub config: String,
    /// `layer-per-layer` or `ftl`.
    pub strategy: String,
    /// Simulated runtime in cycles.
    pub cycles: u64,
    /// Runtime in ms at the SoC clock.
    pub ms: f64,
    /// Reduction vs the same config's baseline (% — 0 for the baseline).
    pub reduction_pct: f64,
    /// Full report for drill-down.
    pub report: DeployReport,
}

/// Reproduce **Fig. 3**: ViT MLP-stage runtime, baseline vs FTL, with and
/// without the NPU. `double_buffer=false` is the headline configuration
/// (see DESIGN.md §Calibration); the Ext-B ablation flips it.
pub fn fig3(seq: usize, d: usize, h: usize, double_buffer: bool) -> Result<Vec<Fig3Row>> {
    let mut rows = Vec::new();
    for (config_name, soc_preset) in [("cluster", "cluster-only"), ("cluster+npu", "siracusa")] {
        let mut base_cycles = 0u64;
        for strategy in [Strategy::LayerPerLayer, Strategy::Ftl] {
            let graph = vit_mlp_stage(seq, d, h);
            let mut cfg = DeployConfig::preset(soc_preset, strategy)?;
            cfg.double_buffer = double_buffer;
            let soc = cfg.soc.clone();
            let dep = Deployer::new(graph, cfg).with_workload_name(format!("vit-mlp-stage-{seq}x{d}x{h}"));
            let (_, report) = dep.deploy()?;
            let cycles = report.sim.total_cycles;
            let reduction = if strategy == Strategy::LayerPerLayer {
                base_cycles = cycles;
                0.0
            } else {
                100.0 * (base_cycles as f64 - cycles as f64) / base_cycles as f64
            };
            rows.push(Fig3Row {
                config: config_name.to_string(),
                strategy: strategy.name().to_string(),
                cycles,
                ms: soc.cycles_to_ms(cycles),
                reduction_pct: reduction,
                report,
            });
        }
    }
    Ok(rows)
}

/// Render Fig. 3 rows as a table.
pub fn fig3_table(rows: &[Fig3Row]) -> String {
    let mut t = Table::new(&["config", "strategy", "cycles", "ms", "runtime reduction"]);
    for r in rows {
        t.row(&[
            r.config.clone(),
            r.strategy.clone(),
            r.cycles.to_string(),
            format!("{:.3}", r.ms),
            if r.reduction_pct == 0.0 { "—".into() } else { format!("-{:.1}%", r.reduction_pct) },
        ]);
    }
    t.render()
}

/// The paper's inline metric: DMA reduction (count and bytes) of FTL vs
/// baseline on the MLP stage.
#[derive(Debug, Clone)]
pub struct DmaReduction {
    /// Baseline transfer commands.
    pub base_transfers: u64,
    /// FTL transfer commands.
    pub ftl_transfers: u64,
    /// Baseline payload bytes.
    pub base_bytes: u64,
    /// FTL payload bytes.
    pub ftl_bytes: u64,
    /// Command-count reduction %.
    pub transfer_reduction_pct: f64,
    /// Byte-volume reduction %.
    pub byte_reduction_pct: f64,
}

/// Reproduce the **−47.1 % DMA** claim (§Results).
pub fn dma_reduction(seq: usize, d: usize, h: usize, soc_preset: &str) -> Result<DmaReduction> {
    let run = |strategy| -> Result<DeployReport> {
        let graph = vit_mlp_stage(seq, d, h);
        let cfg = DeployConfig::preset(soc_preset, strategy)?;
        Ok(Deployer::new(graph, cfg).deploy()?.1)
    };
    let base = run(Strategy::LayerPerLayer)?;
    let ftl = run(Strategy::Ftl)?;
    Ok(DmaReduction {
        base_transfers: base.sim.dma.total_transfers(),
        ftl_transfers: ftl.sim.dma.total_transfers(),
        base_bytes: base.sim.dma.total_bytes(),
        ftl_bytes: ftl.sim.dma.total_bytes(),
        transfer_reduction_pct: ftl.sim.dma.transfer_reduction_vs(&base.sim.dma),
        byte_reduction_pct: ftl.sim.dma.byte_reduction_vs(&base.sim.dma),
    })
}

/// Ext-A: hidden-dimension sweep — shows the L2-overflow crossover where
/// FTL's advantage jumps (the paper's mechanism, swept).
pub fn hidden_sweep(seq: usize, d: usize, hs: &[usize], soc_preset: &str) -> Result<Vec<(usize, u64, u64, f64)>> {
    let mut out = Vec::new();
    for &h in hs {
        let run = |strategy| -> Result<u64> {
            let graph = vit_mlp_stage(seq, d, h);
            let cfg = DeployConfig::preset(soc_preset, strategy)?;
            Ok(Deployer::new(graph, cfg).deploy()?.1.sim.total_cycles)
        };
        let base = run(Strategy::LayerPerLayer)?;
        let ftl = run(Strategy::Ftl)?;
        out.push((h, base, ftl, 100.0 * (base as f64 - ftl as f64) / base as f64));
    }
    Ok(out)
}

/// Ext-B: double-buffering ablation on one config. Returns
/// `(single_base, single_ftl, double_base, double_ftl)` cycles.
pub fn dbuf_ablation(seq: usize, d: usize, h: usize, soc_preset: &str) -> Result<(u64, u64, u64, u64)> {
    let run = |strategy, dbuf| -> Result<u64> {
        let graph = vit_mlp_stage(seq, d, h);
        let mut cfg = DeployConfig::preset(soc_preset, strategy)?;
        cfg.double_buffer = dbuf;
        Ok(Deployer::new(graph, cfg).deploy()?.1.sim.total_cycles)
    };
    Ok((
        run(Strategy::LayerPerLayer, false)?,
        run(Strategy::Ftl, false)?,
        run(Strategy::LayerPerLayer, true)?,
        run(Strategy::Ftl, true)?,
    ))
}

/// Ext-C: performance-constraint ablation — solver quality with and
/// without the paper's third constraint class. Returns
/// `(with_perf_cycles, without_perf_cycles)`.
pub fn perf_constraint_ablation(seq: usize, d: usize, h: usize, soc_preset: &str) -> Result<(u64, u64)> {
    let run = |use_perf| -> Result<u64> {
        let graph = vit_mlp_stage(seq, d, h);
        let mut cfg = DeployConfig::preset(soc_preset, Strategy::Ftl)?;
        cfg.solver.use_perf_constraints = use_perf;
        Ok(Deployer::new(graph, cfg).deploy()?.1.sim.total_cycles)
    };
    Ok((run(true)?, run(false)?))
}

/// Ext-D: full MLP (GEMM→GeLU→GEMM) — beyond the paper's stage benchmark.
pub fn full_mlp(seq: usize, d: usize, h: usize, soc_preset: &str) -> Result<(u64, u64, f64)> {
    let run = |strategy| -> Result<u64> {
        let graph = vit_mlp(seq, d, h, DType::Int8);
        let cfg = DeployConfig::preset(soc_preset, strategy)?;
        Ok(Deployer::new(graph, cfg).deploy()?.1.sim.total_cycles)
    };
    let base = run(Strategy::LayerPerLayer)?;
    let ftl = run(Strategy::Ftl)?;
    Ok((base, ftl, 100.0 * (base as f64 - ftl as f64) / base as f64))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's headline numbers, at the paper's workload size. The
    /// calibration targets ±6 pp of the published reductions — same
    /// winner, same ordering, same mechanism (see DESIGN.md).
    #[test]
    fn fig3_reproduces_paper_shape() {
        let rows = fig3(197, 768, 3072, false).unwrap();
        assert_eq!(rows.len(), 4);
        let cluster_ftl = rows.iter().find(|r| r.config == "cluster" && r.strategy == "ftl").unwrap();
        let npu_ftl = rows.iter().find(|r| r.config == "cluster+npu" && r.strategy == "ftl").unwrap();
        assert!(
            (cluster_ftl.reduction_pct - 28.8).abs() < 6.0,
            "cluster reduction {:.1}% vs paper 28.8%",
            cluster_ftl.reduction_pct
        );
        assert!(
            (npu_ftl.reduction_pct - 60.1).abs() < 6.0,
            "npu reduction {:.1}% vs paper 60.1%",
            npu_ftl.reduction_pct
        );
        assert!(npu_ftl.reduction_pct > cluster_ftl.reduction_pct);
    }

    #[test]
    fn dma_reduction_near_paper() {
        let r = dma_reduction(197, 768, 3072, "cluster-only").unwrap();
        assert!(r.ftl_transfers < r.base_transfers);
        assert!(
            (r.byte_reduction_pct - 47.1).abs() < 12.0,
            "byte reduction {:.1}% vs paper 47.1%",
            r.byte_reduction_pct
        );
    }

    #[test]
    fn hidden_sweep_monotone_benefit_at_overflow() {
        let rows = hidden_sweep(197, 768, &[512, 1024, 3072], "siracusa").unwrap();
        assert_eq!(rows.len(), 3);
        // At h=3072 the intermediate overflows L2 → big reduction.
        assert!(rows[2].3 > rows[0].3);
    }

    #[test]
    fn table_renders() {
        let rows = fig3(64, 64, 128, false).unwrap();
        let t = fig3_table(&rows);
        assert!(t.contains("cluster"));
        assert!(t.contains("ftl"));
    }
}
