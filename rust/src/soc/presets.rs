//! SoC presets.
//!
//! `siracusa-reduced` is *calibrated*, not measured: the constants are
//! chosen so the deployment flow reproduces the paper's Fig. 3 ratios
//! (−28.8 % runtime cluster-only, −60.1 % with NPU, ≈−47 % DMA volume)
//! through the same *mechanism* the paper describes — the MLP intermediate
//! tensor overflows L2 and round-trips through slow external L3 unless FTL
//! fuses the producer/consumer pair. See EXPERIMENTS.md §Calibration.
//!
//! Derivation of the key constants (ViT-Base MLP stage, int8,
//! X[197,768] · W1[768,3072] → GeLU):
//!
//! * cluster GEMM: 8 cores × 4 MAC/cyc (XpulpV2 `pv.sdotsp.b`) × 0.5
//!   efficiency = 16 MAC/cyc → 464.8 M MAC ≈ 29 M cycles — compute-bound.
//! * NPU: 96 MAC/cyc × 0.65 = 62.4 MAC/cyc → ≈ 7.5 M cycles.
//! * L3 link: 0.1 B/cyc → one 605 KiB pass of the intermediate ≈ 6.1 M
//!   cycles; the baseline pays the round trip twice (store + load).
//! * L2 = 3.25 MiB: holds X + W1 + output (≈2.97 MiB) but *not* also the
//!   605 KiB intermediate — exactly the paper's overflow condition.

#![forbid(unsafe_code)]

use crate::dma::DmaCostModel;
use crate::memory::{LevelSpec, MemoryHierarchy};

use super::{ClusterSpec, NpuSpec, SocConfig};

/// Named preset selector (CLI `--soc`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SocPreset {
    /// Reduced Siracusa, cluster + NPU (the paper's right-hand Fig. 3 bars).
    SiracusaReduced,
    /// Reduced Siracusa, cluster only (left-hand bars).
    SiracusaClusterOnly,
}

impl SocPreset {
    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "siracusa" | "siracusa-reduced" | "npu" => SocPreset::SiracusaReduced,
            "siracusa-cluster" | "cluster" | "cluster-only" => SocPreset::SiracusaClusterOnly,
            _ => return None,
        })
    }

    /// Materialise the configuration.
    pub fn config(self) -> SocConfig {
        match self {
            SocPreset::SiracusaReduced => siracusa_reduced(),
            SocPreset::SiracusaClusterOnly => siracusa_reduced_cluster_only(),
        }
    }
}

fn base() -> SocConfig {
    SocConfig {
        name: "siracusa-reduced".into(),
        freq_mhz: 360.0,
        mem: MemoryHierarchy {
            // 256 KiB TCDM minus 16 KiB runtime reservation.
            l1: LevelSpec::new(240 << 10, 4),
            // Reduced Siracusa L2: 3.25 MiB usable.
            l2: LevelSpec::new((3 << 20) + (256 << 10), 4),
            // External HyperRAM-class L3.
            l3: LevelSpec::new(64 << 20, 4),
        },
        cluster: ClusterSpec {
            cores: 8,
            macs_per_core_cycle: 4.0,
            gemm_efficiency: 0.5,
            eltwise_per_core_cycle: 1.0,
            kernel_setup_cycles: 400,
        },
        npu: Some(NpuSpec { macs_per_cycle: 96.0, efficiency: 0.65, job_setup_cycles: 600 }),
        // Cluster DMA (MCHAN-class): 64-bit port to L2, cheap commands.
        dma_cluster: DmaCostModel { setup_cycles: 30, per_row_cycles: 2, bytes_per_cycle: 8.0 },
        // IO DMA over HyperBus-class link, expressed at cluster clock.
        dma_io: DmaCostModel { setup_cycles: 300, per_row_cycles: 8, bytes_per_cycle: 0.1 },
    }
}

/// Reduced Siracusa with the NPU enabled.
pub fn siracusa_reduced() -> SocConfig {
    base()
}

/// Reduced Siracusa with the NPU fused off (cluster-only evaluation).
pub fn siracusa_reduced_cluster_only() -> SocConfig {
    let mut soc = base();
    soc.name = "siracusa-reduced-cluster".into();
    soc.npu = None;
    soc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::Level;

    #[test]
    fn preset_parse() {
        assert_eq!(SocPreset::parse("siracusa"), Some(SocPreset::SiracusaReduced));
        assert_eq!(SocPreset::parse("cluster-only"), Some(SocPreset::SiracusaClusterOnly));
        assert_eq!(SocPreset::parse("zx81"), None);
    }

    #[test]
    fn overflow_condition_holds() {
        // The calibration invariant behind the whole reproduction: for
        // ViT-Base MLP-stage tensors, L2 holds {X, W1, bias, OUT} but not
        // also the intermediate.
        let soc = siracusa_reduced();
        let x = 197 * 768;
        let w1 = 768 * 3072;
        let b1 = 3072 * 4; // int32 bias
        let inter = 197 * 3072;
        let out = 197 * 3072;
        let without = x + w1 + b1 + out;
        let with = without + inter;
        assert!(without <= soc.mem.capacity(Level::L2), "resident set must fit L2");
        assert!(with > soc.mem.capacity(Level::L2), "adding the intermediate must overflow L2");
    }

    #[test]
    fn l3_much_slower_than_l2() {
        let soc = siracusa_reduced();
        assert!(soc.dma_cluster.bytes_per_cycle / soc.dma_io.bytes_per_cycle >= 16.0);
    }

    #[test]
    fn npu_faster_than_cluster_but_not_free() {
        let soc = siracusa_reduced();
        let npu = soc.npu.unwrap().effective_macs_per_cycle();
        let cl = soc.cluster.gemm_macs_per_cycle();
        assert!(npu > 2.0 * cl);
        assert!(npu < 16.0 * cl);
    }
}
