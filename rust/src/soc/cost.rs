//! Analytic kernel cost models.
//!
//! GVSoC-style event simulation charges each kernel invocation an analytic
//! cycle count derived from the unit's throughput. Constants are
//! calibrated so that the *ratios* of the paper's Fig. 3 reproduce (see
//! `presets.rs` and EXPERIMENTS.md); absolute cycle counts are not claims
//! about 16 nm silicon.

#![forbid(unsafe_code)]

use crate::ir::{ActKind, Op};

use super::{ComputeUnit, SocConfig};

/// Cost of one kernel invocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelCost {
    /// Unit the kernel runs on.
    pub unit: ComputeUnit,
    /// Cycles charged.
    pub cycles: u64,
}

/// Stateless cost evaluator.
#[derive(Debug, Clone, Copy, Default)]
pub struct KernelCostModel;

impl KernelCostModel {
    /// Cycles for executing `op` on a tile with the given input/output
    /// shapes, on `unit`.
    pub fn tile_cycles(soc: &SocConfig, op: &Op, unit: ComputeUnit, inputs: &[&[usize]], output: &[usize]) -> u64 {
        let (setup, work) = Self::tile_setup_work(soc, op, unit, inputs, output);
        setup + work.ceil() as u64
    }

    /// The two components of [`KernelCostModel::tile_cycles`]: the fixed
    /// per-invocation setup and the pre-ceil streaming work (cycles as a
    /// linear function of the tile's MAC/element volume). The tiling
    /// solver's branch-and-bound lower bound uses the work term directly
    /// on *covered* (trips × extent) shapes, where the per-tile ceil would
    /// not be admissible.
    pub fn tile_setup_work(
        soc: &SocConfig,
        op: &Op,
        unit: ComputeUnit,
        inputs: &[&[usize]],
        output: &[usize],
    ) -> (u64, f64) {
        let macs = op.macs(inputs, output) as f64;
        let elems = output.iter().product::<usize>() as f64;
        match unit {
            ComputeUnit::Npu => {
                let npu = soc.npu.expect("NPU kernel scheduled on NPU-less SoC");
                let compute = match op {
                    Op::Gemm { .. } | Op::Conv2d { .. } => macs / npu.effective_macs_per_cycle(),
                    // The NPU only runs GEMM/conv; anything else falling
                    // here is a placement bug — make it expensive and
                    // visible rather than silently wrong.
                    _ => unreachable!("op {} cannot run on the NPU", op.name()),
                };
                (npu.job_setup_cycles, compute)
            }
            ComputeUnit::Cluster => {
                let c = soc.cluster;
                let compute = match op {
                    Op::Gemm { .. } | Op::Conv2d { .. } => macs / c.gemm_macs_per_cycle(),
                    Op::Act(kind) => elems / (c.eltwise_per_cycle() * Self::act_rate(*kind)),
                    Op::Add | Op::Requant => elems / (c.eltwise_per_cycle() * 2.0),
                    Op::LayerNorm { .. } => elems / (c.eltwise_per_cycle() * 0.25),
                    Op::Softmax => elems / (c.eltwise_per_cycle() / 3.0),
                    Op::Transpose => elems / c.eltwise_per_cycle(),
                };
                (c.kernel_setup_cycles, compute)
            }
        }
    }

    /// Relative elementwise throughput of each activation (vs the
    /// cluster's base `eltwise_per_core_cycle`): int8 GeLU is a 256-entry
    /// LUT (1 elem/cycle/core), ReLU is a SIMD max (4×), sigmoid an LUT
    /// with interpolation (0.5×).
    fn act_rate(kind: ActKind) -> f64 {
        match kind {
            ActKind::Gelu => 1.0,
            ActKind::Relu => 4.0,
            ActKind::Sigmoid => 0.5,
            ActKind::Identity => 8.0,
        }
    }

    /// Convenience: cycles for the op on its *placed* unit.
    pub fn placed_cycles(soc: &SocConfig, op: &Op, inputs: &[&[usize]], output: &[usize]) -> KernelCost {
        let unit = soc.place(op);
        KernelCost { unit, cycles: Self::tile_cycles(soc, op, unit, inputs, output) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::{siracusa_reduced, siracusa_reduced_cluster_only};

    #[test]
    fn gemm_cluster_vs_npu() {
        let soc = siracusa_reduced();
        let op = Op::Gemm { transpose_b: false, has_bias: false };
        let ins: Vec<&[usize]> = vec![&[64, 256], &[256, 64]];
        let out = [64usize, 64];
        let cl = KernelCostModel::tile_cycles(&soc, &op, ComputeUnit::Cluster, &ins, &out);
        let np = KernelCostModel::tile_cycles(&soc, &op, ComputeUnit::Npu, &ins, &out);
        assert!(np < cl, "NPU ({np}) should beat cluster ({cl}) on GEMM");
    }

    #[test]
    fn placement_in_placed_cycles() {
        let soc = siracusa_reduced_cluster_only();
        let op = Op::Gemm { transpose_b: false, has_bias: false };
        let ins: Vec<&[usize]> = vec![&[8, 8], &[8, 8]];
        let kc = KernelCostModel::placed_cycles(&soc, &op, &ins, &[8, 8]);
        assert_eq!(kc.unit, ComputeUnit::Cluster);
    }

    #[test]
    fn gelu_scales_with_elems() {
        let soc = siracusa_reduced();
        let op = Op::Act(ActKind::Gelu);
        let small: Vec<&[usize]> = vec![&[16, 64]];
        let large: Vec<&[usize]> = vec![&[64, 64]];
        let s = KernelCostModel::tile_cycles(&soc, &op, ComputeUnit::Cluster, &small, &[16, 64]);
        let l = KernelCostModel::tile_cycles(&soc, &op, ComputeUnit::Cluster, &large, &[64, 64]);
        assert!(l > s);
        let setup = soc.cluster.kernel_setup_cycles;
        assert_eq!((l - setup), (s - setup) * 4);
    }

    #[test]
    fn relu_faster_than_gelu() {
        let soc = siracusa_reduced();
        let shape: Vec<&[usize]> = vec![&[128, 128]];
        let tile = [128usize, 128];
        let gelu = KernelCostModel::tile_cycles(&soc, &Op::Act(ActKind::Gelu), ComputeUnit::Cluster, &shape, &tile);
        let relu = KernelCostModel::tile_cycles(&soc, &Op::Act(ActKind::Relu), ComputeUnit::Cluster, &shape, &tile);
        assert!(relu < gelu);
    }

    #[test]
    #[should_panic(expected = "cannot run on the NPU")]
    fn gelu_on_npu_panics() {
        let soc = siracusa_reduced();
        let shape: Vec<&[usize]> = vec![&[8, 8]];
        KernelCostModel::tile_cycles(&soc, &Op::Act(ActKind::Gelu), ComputeUnit::Npu, &shape, &[8, 8]);
    }
}
