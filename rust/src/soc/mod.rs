//! SoC model: compute units, kernel cost models, and presets.
//!
//! The paper evaluates on a *reduced* Siracusa SoC: an 8-core RV32
//! (XpulpV2) DSP cluster plus an NE16-class NPU, both reading from L1
//! TCDM, fed by a cluster DMA (L2↔L1) and an IO DMA to external RAM
//! (L3↔L2). We model each compute unit with a MAC-throughput cost model
//! calibrated to reproduce the paper's runtime *ratios* (GVSoC-style
//! event simulation does the same — cycle counts come from analytic
//! kernel models, not RTL).

#![forbid(unsafe_code)]

mod cost;
mod presets;
mod units;

pub use cost::{KernelCost, KernelCostModel};
pub use presets::{siracusa_reduced, siracusa_reduced_cluster_only, SocPreset};
pub use units::{ClusterSpec, ComputeUnit, NpuSpec};


use crate::dma::DmaCostModel;
use crate::memory::{Level, MemoryHierarchy};

/// Full SoC configuration — everything the simulator and the FTL solver
/// need to know about the target.
#[derive(Debug, Clone, PartialEq)]
pub struct SocConfig {
    /// Human-readable name (shows up in reports).
    pub name: String,
    /// Cluster clock in MHz (cycles → wall-clock conversion only).
    pub freq_mhz: f64,
    /// Memory hierarchy capacities.
    pub mem: MemoryHierarchy,
    /// The RISC-V DSP cluster.
    pub cluster: ClusterSpec,
    /// Optional NPU (GEMM/conv offload).
    pub npu: Option<NpuSpec>,
    /// Cluster DMA (L2↔L1).
    pub dma_cluster: DmaCostModel,
    /// IO DMA / HyperBus (L3↔L2).
    pub dma_io: DmaCostModel,
}

impl SocConfig {
    /// DMA cost model for transfers whose outer level is `level`.
    pub fn dma_for(&self, level: Level) -> DmaCostModel {
        match level {
            Level::L3 => self.dma_io,
            _ => self.dma_cluster,
        }
    }

    /// The compute unit a given op runs on (NPU takes GEMM/conv when
    /// present, everything else runs on the cluster — the paper's
    /// placement).
    pub fn place(&self, op: &crate::ir::Op) -> ComputeUnit {
        use crate::ir::Op;
        match op {
            Op::Gemm { .. } | Op::Conv2d { .. } if self.npu.is_some() => ComputeUnit::Npu,
            _ => ComputeUnit::Cluster,
        }
    }

    /// Convert cycles to milliseconds at the configured clock.
    pub fn cycles_to_ms(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.freq_mhz * 1e3)
    }

    /// Whether the SoC has an NPU.
    pub fn has_npu(&self) -> bool {
        self.npu.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ActKind, Op};

    #[test]
    fn placement_follows_npu_presence() {
        let with = siracusa_reduced();
        let without = siracusa_reduced_cluster_only();
        let gemm = Op::Gemm { transpose_b: false, has_bias: true };
        let gelu = Op::Act(ActKind::Gelu);
        assert_eq!(with.place(&gemm), ComputeUnit::Npu);
        assert_eq!(with.place(&gelu), ComputeUnit::Cluster);
        assert_eq!(without.place(&gemm), ComputeUnit::Cluster);
    }

    #[test]
    fn dma_selection() {
        let soc = siracusa_reduced();
        assert_eq!(soc.dma_for(Level::L2), soc.dma_cluster);
        assert_eq!(soc.dma_for(Level::L3), soc.dma_io);
    }

    #[test]
    fn cycles_to_ms() {
        let soc = siracusa_reduced();
        let ms = soc.cycles_to_ms((soc.freq_mhz * 1e3) as u64);
        assert!((ms - 1.0).abs() < 1e-9);
    }
}
