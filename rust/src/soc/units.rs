//! Compute-unit specifications.

#![forbid(unsafe_code)]


/// Which engine executes a kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ComputeUnit {
    /// The 8-core RV32 XpulpV2 DSP cluster.
    Cluster,
    /// The NE16-class neural processing unit.
    Npu,
}

impl ComputeUnit {
    /// Display name.
    pub const fn name(self) -> &'static str {
        match self {
            ComputeUnit::Cluster => "cluster",
            ComputeUnit::Npu => "npu",
        }
    }

    /// Parse a display name back (the snapshot codec's inverse of
    /// [`ComputeUnit::name`]).
    pub fn parse(s: &str) -> Option<ComputeUnit> {
        Some(match s {
            "cluster" => ComputeUnit::Cluster,
            "npu" => ComputeUnit::Npu,
            _ => return None,
        })
    }
}

impl std::fmt::Display for ComputeUnit {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// RISC-V DSP cluster parameters (XpulpV2: hardware loops, post-increment
/// load/store, 4×int8 SIMD dot-product).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClusterSpec {
    /// Number of cores.
    pub cores: usize,
    /// Peak int8 MACs per core per cycle (SIMD sdotp: 4).
    pub macs_per_core_cycle: f64,
    /// Achieved fraction of peak for GEMM inner loops (loop overhead,
    /// bank conflicts, barriers).
    pub gemm_efficiency: f64,
    /// Elementwise ops (e.g. LUT GeLU) per core per cycle.
    pub eltwise_per_core_cycle: f64,
    /// Fixed cycles per kernel launch (fork/join + loop setup).
    pub kernel_setup_cycles: u64,
}

impl ClusterSpec {
    /// Effective GEMM MACs/cycle for the whole cluster.
    pub fn gemm_macs_per_cycle(&self) -> f64 {
        self.cores as f64 * self.macs_per_core_cycle * self.gemm_efficiency
    }

    /// Effective elementwise throughput (elements/cycle) for the cluster.
    pub fn eltwise_per_cycle(&self) -> f64 {
        self.cores as f64 * self.eltwise_per_core_cycle
    }
}

/// NPU parameters (NE16-class: int8 GEMM/conv engine reading L1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NpuSpec {
    /// Peak int8 MACs per cycle.
    pub macs_per_cycle: f64,
    /// Achieved fraction of peak (tiling edge effects, pipeline fill).
    pub efficiency: f64,
    /// Fixed cycles per job launch (configuration over the peripheral
    /// interconnect).
    pub job_setup_cycles: u64,
}

impl NpuSpec {
    /// Effective MACs/cycle.
    pub fn effective_macs_per_cycle(&self) -> f64 {
        self.macs_per_cycle * self.efficiency
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_throughput() {
        let c = ClusterSpec {
            cores: 8,
            macs_per_core_cycle: 4.0,
            gemm_efficiency: 0.5,
            eltwise_per_core_cycle: 1.0,
            kernel_setup_cycles: 400,
        };
        assert!((c.gemm_macs_per_cycle() - 16.0).abs() < 1e-12);
        assert!((c.eltwise_per_cycle() - 8.0).abs() < 1e-12);
    }

    #[test]
    fn npu_throughput() {
        let n = NpuSpec { macs_per_cycle: 256.0, efficiency: 0.75, job_setup_cycles: 600 };
        assert!((n.effective_macs_per_cycle() - 192.0).abs() < 1e-12);
    }
}
