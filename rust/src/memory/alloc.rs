//! Static lifetime-interval allocator.
//!
//! Deeploy-style: tensor lifetimes are intervals over the (topologically
//! ordered) node index; two tensors may share memory iff their intervals
//! are disjoint. We run a greedy best-fit over requests sorted by size
//! (largest first), which is the classic offline strip-packing heuristic
//! used by TFLM/Deeploy memory planners.

#![forbid(unsafe_code)]

use anyhow::{bail, Result};

/// Half-open byte-span intersection test — the one overlap primitive
/// shared by the placement verifier below and the plan verifier
/// ([`crate::verify`]).
pub fn spans_overlap(a: (usize, usize), b: (usize, usize)) -> bool {
    a.0 < b.1 && b.0 < a.1
}

/// One allocation request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AllocRequest {
    /// Caller-chosen identifier (e.g. tensor id).
    pub id: usize,
    /// Size in bytes.
    pub size: usize,
    /// First node index (inclusive) at which the buffer must be live.
    pub birth: usize,
    /// Last node index (inclusive) at which the buffer must be live.
    pub death: usize,
}

impl AllocRequest {
    /// New request; `birth <= death` is required.
    pub fn new(id: usize, size: usize, birth: usize, death: usize) -> Self {
        assert!(birth <= death, "birth {birth} > death {death}");
        Self { id, size, birth, death }
    }

    fn overlaps(&self, other: &AllocRequest) -> bool {
        self.birth <= other.death && other.birth <= self.death
    }
}

/// A placed buffer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Allocation {
    /// The request this placement answers.
    pub request: AllocRequest,
    /// Byte offset within the memory pool.
    pub offset: usize,
}

impl Allocation {
    /// One-past-the-end offset.
    pub fn end(&self) -> usize {
        self.offset + self.request.size
    }
}

/// Greedy best-fit static allocator for one memory pool.
#[derive(Debug, Clone)]
pub struct StaticAllocator {
    capacity: usize,
    alignment: usize,
}

impl StaticAllocator {
    /// Allocator for a pool of `capacity` bytes with `alignment`-byte
    /// alignment (must be a power of two).
    pub fn new(capacity: usize, alignment: usize) -> Self {
        assert!(alignment.is_power_of_two(), "alignment must be a power of two");
        Self { capacity, alignment }
    }

    fn align(&self, x: usize) -> usize {
        (x + self.alignment - 1) & !(self.alignment - 1)
    }

    /// Place all requests; errors if the peak footprint exceeds capacity.
    ///
    /// Strategy: sort by (size desc, birth asc); for each request, scan
    /// already-placed *overlapping-in-time* buffers and take the lowest
    /// gap that fits (best-fit on offset).
    pub fn solve(&self, requests: &[AllocRequest]) -> Result<Vec<Allocation>> {
        let mut order: Vec<usize> = (0..requests.len()).collect();
        order.sort_by(|&a, &b| {
            requests[b]
                .size
                .cmp(&requests[a].size)
                .then(requests[a].birth.cmp(&requests[b].birth))
                .then(requests[a].id.cmp(&requests[b].id))
        });

        // §Perf: keep placements sorted by offset (binary-search insert)
        // so the per-request best-fit scan needs no re-sort — ~2x faster
        // on the 512-request benchmark than sort-per-request.
        let mut placed: Vec<Allocation> = Vec::with_capacity(requests.len());
        let mut by_offset: Vec<usize> = Vec::with_capacity(requests.len());
        for &ri in &order {
            let req = &requests[ri];
            if req.size == 0 {
                placed.push(Allocation { request: req.clone(), offset: 0 });
                continue;
            }
            // Best-fit: smallest gap that fits, else first gap scan, over
            // live-range-overlapping placements in offset order.
            let mut best: Option<(usize, usize)> = None; // (offset, slack)
            let mut cursor = 0usize;
            for &pi in &by_offset {
                let a = &placed[pi];
                if !a.request.overlaps(req) {
                    continue;
                }
                if a.offset > cursor {
                    let gap = a.offset - cursor;
                    let start = self.align(cursor);
                    if start + req.size <= a.offset {
                        let slack = gap - req.size;
                        if best.map_or(true, |(_, s)| slack < s) {
                            best = Some((start, slack));
                        }
                    }
                }
                cursor = cursor.max(a.end());
            }
            let offset = match best {
                Some((o, _)) => o,
                None => self.align(cursor),
            };
            if offset + req.size > self.capacity {
                bail!(
                    "static allocation overflow: request id={} size={} needs offset {} but capacity is {}",
                    req.id,
                    req.size,
                    offset,
                    self.capacity
                );
            }
            placed.push(Allocation { request: req.clone(), offset });
            let pos = by_offset
                .binary_search_by_key(&offset, |&pi| placed[pi].offset)
                .unwrap_or_else(|p| p);
            by_offset.insert(pos, placed.len() - 1);
        }
        placed.sort_by_key(|a| a.request.id);
        Ok(placed)
    }

    /// Peak footprint of a placement (max end offset).
    pub fn peak(allocations: &[Allocation]) -> usize {
        allocations.iter().map(Allocation::end).max().unwrap_or(0)
    }

    /// Try to place one more request into an existing placement (best-fit
    /// against live-range-overlapping buffers). Returns the offset and
    /// appends on success; leaves `placed` untouched and returns `None`
    /// if the request cannot fit. Used by the lifetime-based L2 home
    /// assigner, where tensors that don't fit spill to L3 one by one.
    pub fn place_incremental(&self, placed: &mut Vec<Allocation>, req: AllocRequest) -> Option<usize> {
        if req.size == 0 {
            placed.push(Allocation { request: req, offset: 0 });
            return Some(0);
        }
        let mut live: Vec<&Allocation> =
            placed.iter().filter(|a| a.request.overlaps(&req) && a.request.size > 0).collect();
        live.sort_by_key(|a| a.offset);
        let mut best: Option<(usize, usize)> = None;
        let mut cursor = 0usize;
        for a in &live {
            if a.offset > cursor {
                let start = self.align(cursor);
                if start + req.size <= a.offset {
                    let slack = a.offset - cursor - req.size;
                    if best.map_or(true, |(_, s)| slack < s) {
                        best = Some((start, slack));
                    }
                }
            }
            cursor = cursor.max(a.end());
        }
        let offset = best.map(|(o, _)| o).unwrap_or_else(|| self.align(cursor));
        if offset + req.size > self.capacity {
            return None;
        }
        placed.push(Allocation { request: req, offset });
        Some(offset)
    }

    /// Structured placement check: every violated invariant, in order.
    ///
    /// Zero-size allocations follow the allocator's own placement rule
    /// (pinned, aligned, in-bounds): alignment and capacity are checked
    /// for them too; only spatial overlap is vacuous at size 0. This is
    /// the engine behind [`StaticAllocator::verify`] and the arena pass
    /// of [`crate::verify::check_deployment`].
    pub fn violations(&self, allocations: &[Allocation]) -> Vec<PlacementViolation> {
        let mut out = Vec::new();
        for (i, a) in allocations.iter().enumerate() {
            if a.offset % self.alignment != 0 {
                out.push(PlacementViolation::Misaligned { index: i, offset: a.offset, alignment: self.alignment });
            }
            if a.end() > self.capacity {
                out.push(PlacementViolation::OutOfBounds { index: i, end: a.end(), capacity: self.capacity });
            }
        }
        for (i, a) in allocations.iter().enumerate() {
            if a.request.size == 0 {
                continue;
            }
            for (dj, b) in allocations[i + 1..].iter().enumerate() {
                if b.request.size == 0 || !a.request.overlaps(&b.request) {
                    continue;
                }
                if spans_overlap((a.offset, a.end()), (b.offset, b.end())) {
                    out.push(PlacementViolation::Overlap { a: i, b: i + 1 + dj });
                }
            }
        }
        out
    }

    /// Verify a placement: no two live-range-overlapping buffers overlap in
    /// space, everything aligned and within capacity. Used by tests and the
    /// property-based suite.
    pub fn verify(&self, allocations: &[Allocation]) -> Result<()> {
        match self.violations(allocations).into_iter().next() {
            None => Ok(()),
            Some(PlacementViolation::Misaligned { index, offset, alignment }) => {
                bail!("allocation id={} offset {offset} not {alignment}-aligned", allocations[index].request.id)
            }
            Some(PlacementViolation::OutOfBounds { index, end, capacity }) => {
                bail!("allocation id={} end {end} exceeds capacity {capacity}", allocations[index].request.id)
            }
            Some(PlacementViolation::Overlap { a, b }) => {
                let (a, b) = (&allocations[a], &allocations[b]);
                bail!(
                    "allocations id={} [{},{}) and id={} [{},{}) overlap in space and time",
                    a.request.id,
                    a.offset,
                    a.end(),
                    b.request.id,
                    b.offset,
                    b.end()
                )
            }
        }
    }
}

/// A violated placement invariant (see [`StaticAllocator::violations`]).
/// Indices refer to the `allocations` slice passed in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementViolation {
    /// `allocations[index]` does not respect the pool alignment.
    Misaligned {
        /// Offending allocation.
        index: usize,
        /// Its offset.
        offset: usize,
        /// The required alignment.
        alignment: usize,
    },
    /// `allocations[index]` ends past the pool capacity.
    OutOfBounds {
        /// Offending allocation.
        index: usize,
        /// One-past-the-end offset.
        end: usize,
        /// The pool capacity.
        capacity: usize,
    },
    /// Two allocations live at the same time overlap in space.
    Overlap {
        /// First allocation.
        a: usize,
        /// Second allocation.
        b: usize,
    },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disjoint_lifetimes_share_space() {
        let alloc = StaticAllocator::new(100, 4);
        let reqs =
            vec![AllocRequest::new(0, 60, 0, 1), AllocRequest::new(1, 60, 2, 3), AllocRequest::new(2, 40, 1, 2)];
        let placed = alloc.solve(&reqs).unwrap();
        alloc.verify(&placed).unwrap();
        // 0 and 1 don't overlap in time → may share offset 0; peak must be
        // ≤ 100 even though total sizes are 160.
        assert!(StaticAllocator::peak(&placed) <= 100);
    }

    #[test]
    fn overflow_detected() {
        let alloc = StaticAllocator::new(100, 4);
        let reqs = vec![AllocRequest::new(0, 60, 0, 2), AllocRequest::new(1, 60, 1, 3)];
        assert!(alloc.solve(&reqs).is_err());
    }

    #[test]
    fn alignment_respected() {
        let alloc = StaticAllocator::new(1 << 10, 16);
        let reqs = vec![
            AllocRequest::new(0, 7, 0, 5),
            AllocRequest::new(1, 9, 0, 5),
            AllocRequest::new(2, 3, 0, 5),
        ];
        let placed = alloc.solve(&reqs).unwrap();
        alloc.verify(&placed).unwrap();
        for a in &placed {
            assert_eq!(a.offset % 16, 0);
        }
    }

    #[test]
    fn zero_sized_ok() {
        let alloc = StaticAllocator::new(16, 4);
        let placed = alloc.solve(&[AllocRequest::new(0, 0, 0, 0)]).unwrap();
        alloc.verify(&placed).unwrap();
    }

    #[test]
    fn best_fit_uses_gap() {
        let alloc = StaticAllocator::new(200, 1);
        // Two long-lived buffers with a gap between them, then a short one
        // that fits in the gap.
        let reqs = vec![
            AllocRequest::new(0, 50, 0, 9),
            AllocRequest::new(1, 100, 0, 9),
            AllocRequest::new(2, 30, 0, 9),
        ];
        let placed = alloc.solve(&reqs).unwrap();
        alloc.verify(&placed).unwrap();
        assert!(StaticAllocator::peak(&placed) <= 180);
    }

    #[test]
    fn place_incremental_fits_then_rejects() {
        let alloc = StaticAllocator::new(100, 4);
        let mut placed = Vec::new();
        assert!(alloc.place_incremental(&mut placed, AllocRequest::new(0, 60, 0, 2)).is_some());
        // Overlapping lifetime, doesn't fit next to the first.
        assert!(alloc.place_incremental(&mut placed, AllocRequest::new(1, 60, 1, 3)).is_none());
        assert_eq!(placed.len(), 1, "rejected request must not be appended");
        // Disjoint lifetime reuses the space.
        let off = alloc.place_incremental(&mut placed, AllocRequest::new(2, 60, 3, 4)).unwrap();
        assert_eq!(off, 0);
        alloc.verify(&placed).unwrap();
    }

    #[test]
    fn place_incremental_uses_gaps() {
        let alloc = StaticAllocator::new(100, 1);
        let mut placed = vec![
            Allocation { request: AllocRequest::new(0, 20, 0, 9), offset: 0 },
            Allocation { request: AllocRequest::new(1, 20, 0, 9), offset: 60 },
        ];
        let off = alloc.place_incremental(&mut placed, AllocRequest::new(2, 30, 0, 9)).unwrap();
        assert_eq!(off, 20, "best-fit should use the interior gap");
        alloc.verify(&placed).unwrap();
    }

    #[test]
    fn spans_overlap_is_half_open() {
        assert!(spans_overlap((0, 4), (3, 8)));
        assert!(spans_overlap((3, 8), (0, 4)));
        assert!(!spans_overlap((0, 4), (4, 8)));
        assert!(!spans_overlap((4, 8), (0, 4)));
    }

    #[test]
    fn violations_are_structured() {
        let alloc = StaticAllocator::new(100, 4);
        let mk = |id, size, off| Allocation { request: AllocRequest::new(id, size, 0, 9), offset: off };
        let vs = alloc.violations(&[mk(0, 8, 0), mk(1, 8, 4)]);
        assert_eq!(vs, vec![PlacementViolation::Overlap { a: 0, b: 1 }]);
        assert!(alloc.verify(&[mk(0, 8, 0), mk(1, 8, 4)]).is_err());
        assert!(alloc.violations(&[mk(0, 8, 0), mk(1, 8, 8)]).is_empty());
    }

    #[test]
    fn zero_size_follows_placement_rule() {
        // The allocator pins zero-size requests at offset 0 — aligned and
        // in bounds. The verifier holds zero-size placements to the same
        // rule (alignment + bounds) while exempting them from overlap.
        let alloc = StaticAllocator::new(100, 4);
        let mk = |id, size, off| Allocation { request: AllocRequest::new(id, size, 0, 9), offset: off };
        let vs = alloc.violations(&[mk(0, 0, 3), mk(1, 0, 200), mk(2, 0, 0), mk(3, 0, 0)]);
        assert_eq!(
            vs,
            vec![
                PlacementViolation::Misaligned { index: 0, offset: 3, alignment: 4 },
                PlacementViolation::OutOfBounds { index: 1, end: 200, capacity: 100 },
            ]
        );
        assert!(alloc.verify(&[mk(0, 0, 3)]).is_err());
    }

    #[test]
    fn results_sorted_by_id() {
        let alloc = StaticAllocator::new(1000, 4);
        let reqs: Vec<_> = (0..10).map(|i| AllocRequest::new(i, 10 + i, 0, 1)).collect();
        let placed = alloc.solve(&reqs).unwrap();
        for (i, a) in placed.iter().enumerate() {
            assert_eq!(a.request.id, i);
        }
    }
}
