//! L1 tile-buffer arena layout.
//!
//! For a tiled (or fused-tiled) execution, L1 holds one buffer per operand
//! tile; with double buffering every *streamed* buffer is duplicated
//! (ping/pong) so the DMA can fill buffer `k+1` while the kernel consumes
//! buffer `k`. The [`ArenaPlan`] computes concrete offsets and checks the
//! L1 capacity constraint that the FTL solver promised to satisfy.

#![forbid(unsafe_code)]

use anyhow::{anyhow, ensure, Result};

use crate::util::bincode::{BinReader, BinWriter};
use crate::util::json::Json;

/// Role of a tile buffer inside L1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BufferRole {
    /// Streamed input-activation tile (double-buffered).
    Input,
    /// Streamed weight tile (double-buffered).
    Weight,
    /// Streamed output tile (double-buffered).
    Output,
    /// Intermediate tile of a fused group — lives only in L1, single copy.
    Intermediate,
    /// Kernel scratch (im2col buffers, accumulators), single copy.
    Scratch,
}

impl BufferRole {
    /// Whether this buffer is duplicated under double buffering.
    pub fn is_streamed(self) -> bool {
        matches!(self, BufferRole::Input | BufferRole::Weight | BufferRole::Output)
    }

    /// Canonical name (the snapshot codec's tag).
    pub const fn name(self) -> &'static str {
        match self {
            BufferRole::Input => "input",
            BufferRole::Weight => "weight",
            BufferRole::Output => "output",
            BufferRole::Intermediate => "intermediate",
            BufferRole::Scratch => "scratch",
        }
    }

    /// Parse a canonical name back.
    pub fn parse(s: &str) -> Option<BufferRole> {
        Some(match s {
            "input" => BufferRole::Input,
            "weight" => BufferRole::Weight,
            "output" => BufferRole::Output,
            "intermediate" => BufferRole::Intermediate,
            "scratch" => BufferRole::Scratch,
            _ => return None,
        })
    }
}

/// One logical tile buffer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TileBuffer {
    /// Display name, e.g. `"fc1.in[x]"`.
    pub name: String,
    /// Role (decides ping/pong duplication).
    pub role: BufferRole,
    /// Bytes per copy.
    pub bytes: usize,
}

/// A concrete L1 layout: every buffer (and its pong copy, if any) gets an
/// offset.
#[derive(Debug, Clone, PartialEq)]
pub struct ArenaPlan {
    /// The logical buffers.
    pub buffers: Vec<TileBuffer>,
    /// Offsets: `offsets[i]` has one entry per copy of `buffers[i]`.
    pub offsets: Vec<Vec<usize>>,
    /// Total bytes used.
    pub total: usize,
    /// Whether double buffering was applied.
    pub double_buffered: bool,
}

impl ArenaPlan {
    /// Lay out `buffers` sequentially (aligned), duplicating streamed
    /// buffers when `double_buffered`. Errors if the total exceeds
    /// `capacity`.
    pub fn layout(
        buffers: Vec<TileBuffer>,
        capacity: usize,
        alignment: usize,
        double_buffered: bool,
    ) -> Result<Self> {
        let copies: Vec<usize> = buffers
            .iter()
            .map(|b| if double_buffered && b.role.is_streamed() { 2 } else { 1 })
            .collect();
        Self::layout_explicit(buffers, &copies, capacity, alignment, double_buffered)
    }

    /// Like [`ArenaPlan::layout`] but with an explicit per-buffer copy
    /// count (the schedule generator exempts loop-invariant buffers from
    /// ping/pong duplication even when double buffering is on).
    pub fn layout_explicit(
        buffers: Vec<TileBuffer>,
        copies: &[usize],
        capacity: usize,
        alignment: usize,
        double_buffered: bool,
    ) -> Result<Self> {
        assert!(alignment.is_power_of_two());
        assert_eq!(copies.len(), buffers.len());
        let align = |x: usize| (x + alignment - 1) & !(alignment - 1);
        let mut cursor = 0usize;
        let mut offsets = Vec::with_capacity(buffers.len());
        for (b, &n) in buffers.iter().zip(copies) {
            assert!(n >= 1, "buffer {} needs at least one copy", b.name);
            let mut offs = Vec::with_capacity(n);
            for _ in 0..n {
                cursor = align(cursor);
                offs.push(cursor);
                cursor += b.bytes;
            }
            offsets.push(offs);
        }
        ensure!(
            cursor <= capacity,
            "L1 arena overflow: need {} bytes, capacity {} (double_buffered={})",
            cursor,
            capacity,
            double_buffered
        );
        Ok(Self { buffers, offsets, total: cursor, double_buffered })
    }

    /// Bytes that the layout would take (without building it) — the
    /// capacity expression used inside the FTL solver.
    pub fn footprint(buffers: &[TileBuffer], alignment: usize, double_buffered: bool) -> usize {
        let align = |x: usize| (x + alignment - 1) & !(alignment - 1);
        let mut cursor = 0usize;
        for b in buffers {
            let copies = if double_buffered && b.role.is_streamed() { 2 } else { 1 };
            for _ in 0..copies {
                cursor = align(cursor) + b.bytes;
            }
        }
        cursor
    }

    /// Offset of copy `phase % copies` of buffer `i` — the ping/pong
    /// address used by tile iteration `phase`.
    pub fn offset(&self, i: usize, phase: usize) -> usize {
        let offs = &self.offsets[i];
        offs[phase % offs.len()]
    }

    /// Canonical JSON encoding (the snapshot codec — see
    /// [`crate::serve::persist`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("buffers", Json::Arr(self.buffers.iter().map(TileBuffer::to_json).collect())),
            ("offsets", Json::Arr(self.offsets.iter().map(|o| Json::ints(o.as_slice())).collect())),
            ("total", Json::int(self.total)),
            ("double_buffered", Json::Bool(self.double_buffered)),
        ])
    }

    /// Decode the canonical JSON encoding.
    pub fn from_json(v: &Json) -> Result<Self> {
        let buffers: Vec<TileBuffer> =
            v.get("buffers")?.as_arr()?.iter().map(TileBuffer::from_json).collect::<Result<_>>()?;
        let offsets: Vec<Vec<usize>> =
            v.get("offsets")?.as_arr()?.iter().map(Json::as_usize_arr).collect::<Result<_>>()?;
        ensure!(offsets.len() == buffers.len(), "arena plan: offsets/buffers length mismatch");
        Ok(Self {
            buffers,
            offsets,
            total: v.get("total")?.as_usize()?,
            double_buffered: v.get("double_buffered")?.as_bool()?,
        })
    }

    /// Canonical binary encoding (`ftl-bin-v1`).
    pub fn to_bin(&self, w: &mut BinWriter) {
        w.seq(&self.buffers, |w, b| b.to_bin(w));
        w.seq(&self.offsets, |w, o| w.usize_seq(o));
        w.usize(self.total);
        w.bool(self.double_buffered);
    }

    /// Decode the canonical binary encoding.
    pub fn from_bin(r: &mut BinReader) -> Result<Self> {
        let buffers: Vec<TileBuffer> = r.seq(TileBuffer::from_bin)?;
        let offsets: Vec<Vec<usize>> = r.seq(|r| r.usize_seq())?;
        ensure!(offsets.len() == buffers.len(), "arena plan: offsets/buffers length mismatch");
        Ok(Self { buffers, offsets, total: r.usize()?, double_buffered: r.bool()? })
    }
}

impl TileBuffer {
    /// Canonical JSON encoding.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("role", Json::str(self.role.name())),
            ("bytes", Json::int(self.bytes)),
        ])
    }

    /// Decode the canonical JSON encoding.
    pub fn from_json(v: &Json) -> Result<Self> {
        let role = v.get("role")?.as_str()?;
        Ok(Self {
            name: v.get("name")?.as_str()?.to_string(),
            role: BufferRole::parse(role).ok_or_else(|| anyhow!("unknown buffer role '{role}'"))?,
            bytes: v.get("bytes")?.as_usize()?,
        })
    }

    /// Canonical binary encoding (`ftl-bin-v1`).
    pub fn to_bin(&self, w: &mut BinWriter) {
        w.str(&self.name);
        w.str(self.role.name());
        w.usize(self.bytes);
    }

    /// Decode the canonical binary encoding.
    pub fn from_bin(r: &mut BinReader) -> Result<Self> {
        let name = r.str()?;
        let role = r.str()?;
        Ok(Self {
            name,
            role: BufferRole::parse(&role).ok_or_else(|| anyhow!("unknown buffer role '{role}'"))?,
            bytes: r.usize()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bufs() -> Vec<TileBuffer> {
        vec![
            TileBuffer { name: "in".into(), role: BufferRole::Input, bytes: 100 },
            TileBuffer { name: "w".into(), role: BufferRole::Weight, bytes: 200 },
            TileBuffer { name: "mid".into(), role: BufferRole::Intermediate, bytes: 50 },
            TileBuffer { name: "out".into(), role: BufferRole::Output, bytes: 80 },
        ]
    }

    #[test]
    fn single_buffered_layout() {
        let plan = ArenaPlan::layout(bufs(), 1 << 10, 4, false).unwrap();
        assert_eq!(plan.total, 100 + 200 + 52 + 80); // mid aligned 50→52 start ok
        for o in &plan.offsets {
            assert_eq!(o.len(), 1);
        }
    }

    #[test]
    fn double_buffered_duplicates_streams_only() {
        let plan = ArenaPlan::layout(bufs(), 1 << 10, 4, true).unwrap();
        assert_eq!(plan.offsets[0].len(), 2); // input
        assert_eq!(plan.offsets[1].len(), 2); // weight
        assert_eq!(plan.offsets[2].len(), 1); // intermediate: single copy
        assert_eq!(plan.offsets[3].len(), 2); // output
        // ping/pong alternation
        assert_eq!(plan.offset(0, 0), plan.offsets[0][0]);
        assert_eq!(plan.offset(0, 1), plan.offsets[0][1]);
        assert_eq!(plan.offset(0, 2), plan.offsets[0][0]);
        // intermediate is phase-invariant
        assert_eq!(plan.offset(2, 0), plan.offset(2, 7));
    }

    #[test]
    fn footprint_matches_layout() {
        for db in [false, true] {
            let plan = ArenaPlan::layout(bufs(), 1 << 20, 8, db).unwrap();
            assert_eq!(plan.total, ArenaPlan::footprint(&bufs(), 8, db));
        }
    }

    #[test]
    fn overflow_rejected() {
        assert!(ArenaPlan::layout(bufs(), 300, 4, true).is_err());
    }

    #[test]
    fn json_roundtrip() {
        for db in [false, true] {
            let plan = ArenaPlan::layout(bufs(), 1 << 10, 4, db).unwrap();
            let back = ArenaPlan::from_json(&plan.to_json()).unwrap();
            assert_eq!(back, plan);
        }
        assert!(ArenaPlan::from_json(&crate::util::json::parse(r#"{"total":1}"#).unwrap()).is_err());
    }

    #[test]
    fn buffer_role_names_roundtrip() {
        for r in
            [BufferRole::Input, BufferRole::Weight, BufferRole::Output, BufferRole::Intermediate, BufferRole::Scratch]
        {
            assert_eq!(BufferRole::parse(r.name()), Some(r));
        }
        assert_eq!(BufferRole::parse("nope"), None);
    }

    #[test]
    fn offsets_disjoint() {
        let plan = ArenaPlan::layout(bufs(), 1 << 10, 4, true).unwrap();
        let mut spans: Vec<(usize, usize)> = Vec::new();
        for (i, b) in plan.buffers.iter().enumerate() {
            for &o in &plan.offsets[i] {
                spans.push((o, o + b.bytes));
            }
        }
        spans.sort();
        for w in spans.windows(2) {
            assert!(w[0].1 <= w[1].0, "overlapping spans {:?}", w);
        }
    }
}
