//! Software-managed memory hierarchy model and static allocation.
//!
//! SoCs like Siracusa have no hardware caches on the accelerator path:
//! every byte in L1 TCDM was put there by an explicit DMA transfer, and the
//! deployment flow must *statically* decide, at compile time, where every
//! tensor (and every tile double-buffer) lives. This module provides:
//!
//! * [`Level`] / [`LevelSpec`] — the three-level hierarchy (L1 TCDM, L2
//!   SRAM, L3 external RAM) with capacities.
//! * [`StaticAllocator`] — Deeploy-style lifetime-interval allocation:
//!   tensors with disjoint live ranges share offsets (greedy best-fit).
//! * [`ArenaPlan`] — the L1 tile-buffer layout for a tiled schedule,
//!   including ping-pong duplication for double buffering.

#![forbid(unsafe_code)]

mod alloc;
mod arena;
mod hierarchy;

pub use alloc::{spans_overlap, AllocRequest, Allocation, PlacementViolation, StaticAllocator};
pub use arena::{ArenaPlan, BufferRole, TileBuffer};
pub use hierarchy::{Level, LevelSpec, MemoryHierarchy};
