//! The three-level software-managed hierarchy.

#![forbid(unsafe_code)]


/// A memory level in the hierarchy. Lower number = closer to compute.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    /// L1 TCDM — multi-banked scratchpad shared by the cluster cores and
    /// the NPU; the only level kernels read from.
    L1,
    /// L2 — on-chip SRAM, holds tensors between layers.
    L2,
    /// L3 — external RAM (HyperRAM-class); costly to reach.
    L3,
}

impl Level {
    /// All levels, closest first.
    pub const ALL: [Level; 3] = [Level::L1, Level::L2, Level::L3];

    /// The next level further from compute, if any.
    pub fn outer(self) -> Option<Level> {
        match self {
            Level::L1 => Some(Level::L2),
            Level::L2 => Some(Level::L3),
            Level::L3 => None,
        }
    }

    /// Short display name.
    pub const fn name(self) -> &'static str {
        match self {
            Level::L1 => "L1",
            Level::L2 => "L2",
            Level::L3 => "L3",
        }
    }

    /// Parse a display name back (the snapshot codec's inverse of
    /// [`Level::name`]).
    pub fn parse(s: &str) -> Option<Level> {
        Some(match s {
            "L1" => Level::L1,
            "L2" => Level::L2,
            "L3" => Level::L3,
            _ => return None,
        })
    }
}

impl std::fmt::Display for Level {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Static properties of one memory level.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LevelSpec {
    /// Usable capacity in bytes (after runtime/stack reservations).
    pub capacity: usize,
    /// Required allocation alignment in bytes.
    pub alignment: usize,
}

impl LevelSpec {
    /// New spec.
    pub const fn new(capacity: usize, alignment: usize) -> Self {
        Self { capacity, alignment }
    }
}

/// Capacities of the whole hierarchy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemoryHierarchy {
    /// L1 TCDM spec.
    pub l1: LevelSpec,
    /// L2 SRAM spec.
    pub l2: LevelSpec,
    /// L3 external RAM spec.
    pub l3: LevelSpec,
}

impl MemoryHierarchy {
    /// Spec of a given level.
    pub fn spec(&self, level: Level) -> LevelSpec {
        match level {
            Level::L1 => self.l1,
            Level::L2 => self.l2,
            Level::L3 => self.l3,
        }
    }

    /// Capacity of a given level in bytes.
    pub fn capacity(&self, level: Level) -> usize {
        self.spec(level).capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_parse_roundtrip() {
        for l in Level::ALL {
            assert_eq!(Level::parse(l.name()), Some(l));
        }
        assert_eq!(Level::parse("L4"), None);
    }

    #[test]
    fn outer_chain() {
        assert_eq!(Level::L1.outer(), Some(Level::L2));
        assert_eq!(Level::L2.outer(), Some(Level::L3));
        assert_eq!(Level::L3.outer(), None);
    }

    #[test]
    fn ordering_closest_first() {
        assert!(Level::L1 < Level::L2);
        assert!(Level::L2 < Level::L3);
    }

    #[test]
    fn hierarchy_lookup() {
        let h = MemoryHierarchy {
            l1: LevelSpec::new(256 << 10, 4),
            l2: LevelSpec::new(512 << 10, 4),
            l3: LevelSpec::new(64 << 20, 4),
        };
        assert_eq!(h.capacity(Level::L1), 256 << 10);
        assert_eq!(h.spec(Level::L3).capacity, 64 << 20);
    }
}
