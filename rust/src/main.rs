//! `ftl` — the deployment-framework CLI.
//!
//! ```text
//! ftl deploy     --workload vit-base-stage --soc siracusa --strategy ftl [--double-buffer] [--json]
//! ftl serve      [--addr 127.0.0.1:7117] [--workers 4] [--cache-cap 64] [--sim-cache-cap 256]
//!                [--queue-cap 256] [--batch-window-ms 2] [--max-batch 64] [--shed]
//!                [--lane name:weight:cap[:shed|:block][:deadline-ms]]...  (repeatable WFQ lanes)
//!                [--cache-dir DIR] [--snapshot-interval-ms 1000] [--cache-max-entries 0]
//!                [--snapshot-format bin|json] [--trace-cap 512] [--slowlog-ms 250]
//!                [--write-queue-cap 4194304] [--verify-plans] [--self-test]
//!                (line protocol, see PROTOCOL.md: DEPLOY | STATS | PING | METRICS | TRACE [n] |
//!                SLOW [n], either bare (legacy v0, one JSON reply per line, in order) or framed
//!                `FTL1 <id> <command...>` — multiplexed ids, streamed plan/sim/done events,
//!                out-of-order completion; every request is traced end to end, `--trace-cap 0`
//!                disables tracing entirely)
//!
//! Every command also takes `--solver-threads N` (or the
//! `FTL_SOLVER_THREADS` env var): the branch-and-bound tiling solver's
//! worker budget. Deterministic — any thread count compiles bit-identical
//! plans (the serve self-test prints a greppable `plan_digest=` line that
//! CI compares across thread counts).
//! ftl soak       [--seed 1] [--waves 4] [--requests 24] [--cache-dir DIR] [--out BENCH_soak.json]
//!                (seeded soak/chaos run against a live `ftl serve` child it owns: mixed v0/v1
//!                traffic waves, SIGKILL + warm restarts, snapshot corruption, lane saturation,
//!                slow readers, oversized frames — asserting the cross-counter invariants over
//!                the wire after every wave; `FTL_SOAK_SMOKE=1` shrinks volumes for CI)
//! ftl verify     [<workload>] [--soc siracusa --strategy ftl --double-buffer] [--json]
//!                [--all | --mutate]   (static plan verification; nonzero exit on errors)
//! ftl snapshot   compact|inspect --cache-dir DIR [--cache-max-entries 0] [--json]
//!                (offline segment compaction / JSON→segment migration, or a read-only
//!                breakdown of a snapshot directory)
//! ftl fig3       [--seq 197 --dim 768 --hidden 3072] [--double-buffer]
//! ftl dma        [--soc cluster-only]
//! ftl emit-tiles --out artifacts/tiles.json
//! ftl run        --artifacts artifacts [--workload vit-base-stage] [--strategy ftl]
//! ftl export     --workload vit-base --out net.json
//! ```
//!
//! Argument parsing is hand-rolled (the build is fully offline — no clap).

#![forbid(unsafe_code)]

use std::collections::HashMap;
use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Context, Result};

use ftl::config::DeployConfig;
use ftl::coordinator::{experiments, Deployer};
use ftl::ir::builder::{attention_head, deep_mlp, vit_mlp_block, vit_mlp_preset};
use ftl::ir::{graph_from_json, graph_to_json, DType, Graph};
use ftl::runtime::{KernelBackend, NativeBackend, PjrtBackend};
use ftl::serve::{
    checksum, handle_command, handle_line, normalize_specs, resolve_workload, AdmissionPolicy,
    BatchOptions, BatchScheduler, Frontend, FrontendOptions, LaneSpec, PersistOptions, PlanService,
    ServeOptions, SnapshotFormat, Snapshotter, TraceOptions,
};
use ftl::tiling::Strategy;
use ftl::util::json::Json;

struct Args {
    cmd: String,
    /// Bare (non-flag) tokens after the command. Only `verify` (the
    /// workload name) and `snapshot` (the subcommand) accept one; every
    /// other command rejects them in [`dispatch`], preserving the old
    /// strictness.
    pos: Vec<String>,
    /// Flag values in arrival order — most flags use the last value,
    /// repeatable flags (`--lane`) consume all of them.
    flags: HashMap<String, Vec<String>>,
}

impl Args {
    fn parse() -> Result<Self> {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".into());
        let mut pos = Vec::new();
        let mut flags: HashMap<String, Vec<String>> = HashMap::new();
        while let Some(a) = it.next() {
            let Some(name) = a.strip_prefix("--") else {
                pos.push(a);
                continue;
            };
            // boolean flags take no value; value flags consume the next token
            match name {
                "double-buffer" | "json" | "no-perf-constraints" | "verbose" | "self-test" | "shed"
                | "verify-plans" | "all" | "mutate" => {
                    flags.entry(name.to_string()).or_default().push("true".into());
                }
                _ => {
                    let v = it.next().ok_or_else(|| anyhow!("flag --{name} needs a value"))?;
                    flags.entry(name.to_string()).or_default().push(v);
                }
            }
        }
        Ok(Self { cmd, pos, flags })
    }

    fn get_opt(&self, name: &str) -> Option<&str> {
        self.flags.get(name).and_then(|v| v.last()).map(String::as_str)
    }

    fn get<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get_opt(name).unwrap_or(default)
    }

    /// Every value a repeatable flag was given (empty when absent).
    fn get_all(&self, name: &str) -> &[String] {
        self.flags.get(name).map(Vec::as_slice).unwrap_or(&[])
    }

    fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get_opt(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} must be an integer")),
        }
    }

    fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
}

/// Resolve a workload name (or `--network file.json`) to a graph.
fn load_workload(args: &Args) -> Result<(String, Graph)> {
    if let Some(path) = args.get_opt("network") {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        return Ok((path.to_string(), graph_from_json(&text)?));
    }
    let name = args.get("workload", "vit-base-stage");
    let seq = args.get_usize("seq", 197)?;
    let graph = match name {
        "vit-base-stage" => experiments::vit_mlp_stage(seq, 768, 3072),
        "vit-tiny-stage" => experiments::vit_mlp_stage(seq, 192, 768),
        "mlp-stage" => {
            experiments::vit_mlp_stage(seq, args.get_usize("dim", 768)?, args.get_usize("hidden", 3072)?)
        }
        "vit-base-block" => vit_mlp_block(seq, 768, 3072, DType::Int8),
        "deep-mlp" => deep_mlp(seq, args.get_usize("dim", 512)?, args.get_usize("layers", 4)?, DType::Int8),
        "attention" => attention_head(seq, args.get_usize("dim", 768)?, args.get_usize("head", 64)?, DType::Int8),
        other => vit_mlp_preset(other).ok_or_else(|| {
            anyhow!("unknown workload '{other}' (try vit-base-stage, vit-base, vit-tiny, mlp-stage, deep-mlp)")
        })?,
    };
    Ok((name.to_string(), graph))
}

fn make_config(args: &Args) -> Result<DeployConfig> {
    let strategy = Strategy::parse(args.get("strategy", "ftl"))
        .ok_or_else(|| anyhow!("--strategy must be 'ftl' or 'baseline'"))?;
    let mut cfg = match args.get_opt("config") {
        Some(path) => DeployConfig::from_file(std::path::Path::new(path))?,
        None => DeployConfig::preset(args.get("soc", "siracusa"), strategy)?,
    };
    cfg.strategy = strategy;
    cfg.double_buffer = args.has("double-buffer");
    if args.has("no-perf-constraints") {
        cfg.solver.use_perf_constraints = false;
    }
    cfg.homes = match args.get("homes", "resident") {
        "resident" => ftl::tiling::HomesPolicy::Resident,
        "lifetime" => ftl::tiling::HomesPolicy::Lifetime,
        other => bail!("--homes must be resident|lifetime, got '{other}'"),
    };
    Ok(cfg)
}

fn cmd_deploy(args: &Args) -> Result<()> {
    let (name, graph) = load_workload(args)?;
    let cfg = make_config(args)?;
    let dep = Deployer::new(graph, cfg).with_workload_name(&name);
    let (plan, report) = dep.deploy()?;
    let soc = &dep.config().soc;
    if args.has("json") {
        println!("{}", report.to_json(soc).pretty());
    } else {
        println!("{}", report.render(soc));
        println!("fusion groups:");
        for (g, sol) in plan.groups.iter().zip(&plan.solution.groups) {
            let names: Vec<&str> = g.nodes.iter().map(|&n| dep.graph().nodes[n].name.as_str()).collect();
            let loops: Vec<String> =
                sol.loops.iter().map(|l| format!("{}={}({}/{})", l.name, l.tile, l.trips(), l.full)).collect();
            println!(
                "  [{}] loops: {} footprint: {} B iterations: {}",
                names.join("+"),
                loops.join(" "),
                sol.footprint,
                sol.total_iterations()
            );
        }
    }
    Ok(())
}

/// `ftl serve` — run the batch-aware deployment service
/// ([`ftl::serve::BatchScheduler`] over [`ftl::serve::PlanService`])
/// behind the line protocol `DEPLOY <workload> <soc> <strategy>
/// [deadline-ms] [lane=<name>]` | `STATS` | `PING` (one JSON response
/// per line). `--queue-cap`, `--batch-window-ms` and `--shed` tune
/// admission control; `--lane name:weight:cap[:shed|:block][:deadline-ms]`
/// (repeatable) declares weighted-fair priority lanes — saturated lanes
/// split cold work in proportion to their weights, requests select
/// a lane with the protocol's `lane=` field (unknown/absent names use
/// the default lane), and a lane's trailing `deadline-ms` applies to
/// every request in it that carries no deadline of its own;
/// `--cache-dir` persists the plan + sim caches across restarts
/// (write-behind every `--snapshot-interval-ms`, lane-ordered warm
/// start on boot; `--snapshot-format` picks the on-disk codec —
/// `bin` (default) appends binary segment files, `json` keeps one
/// envelope per entry; reads always accept both — and
/// `--cache-max-entries` caps the directory: segment compaction
/// keeping the heaviest lane hints under `bin`, an mtime-LRU sweep
/// under `json`);
/// `--trace-cap`/`--slowlog-ms` size the per-request trace journal and
/// slowlog (`--trace-cap 0` disables tracing; `METRICS`, `TRACE [n]` and
/// `SLOW [n]` expose the results over the protocol);
/// `--verify-plans` runs the static plan verifier on every fresh solve
/// before it enters the cache and on every snapshot-loaded entry at
/// warm-start (rejections surface as `verify.*` in STATS/METRICS);
/// `--self-test` exercises the full service in process (cache hits,
/// single-flight coalescing, warm-vs-cold speedup, batch fan-out,
/// shedding, deadlines, latency-histogram invariants — or, with
/// `--cache-dir`, the snapshot/warm-start path) and exits.
fn cmd_serve(args: &Args) -> Result<()> {
    let opts = ServeOptions {
        cache_capacity: args.get_usize("cache-cap", 64)?,
        sim_cache_capacity: args.get_usize("sim-cache-cap", 256)?,
        cache_shards: args.get_usize("cache-shards", 8)?,
        workers: args.get_usize("workers", 4)?,
        verify_plans: args.has("verify-plans"),
    };
    let queue_cap = args.get_usize("queue-cap", 256)?;
    // Repeatable: --lane name:weight:capacity[:shed|:block]. Validated
    // (and the default lane guaranteed) up front so a bad spec is a CLI
    // error, not a scheduler panic.
    let mut lane_specs = Vec::new();
    for spec in args.get_all("lane") {
        lane_specs.push(LaneSpec::parse(spec)?);
    }
    let lane_specs = normalize_specs(lane_specs, queue_cap)?;
    // --trace-cap 0 removes the tracer entirely (the zero-overhead
    // baseline); any other value sizes the TRACE span journal.
    let trace_cap = args.get_usize("trace-cap", 512)?;
    let trace = TraceOptions {
        enabled: trace_cap > 0,
        journal_cap: trace_cap.max(1),
        slowlog_ms: args.get_usize("slowlog-ms", 250)? as u64,
        ..TraceOptions::default()
    };
    let batch_opts = BatchOptions {
        queue_capacity: queue_cap,
        batch_window: std::time::Duration::from_millis(args.get_usize("batch-window-ms", 2)? as u64),
        max_batch: args.get_usize("max-batch", 64)?,
        policy: if args.has("shed") { AdmissionPolicy::Shed } else { AdmissionPolicy::Block },
        lanes: lane_specs,
        trace,
    };
    let cache_dir = args.get_opt("cache-dir").map(str::to_string);
    // `ftl serve` defaults to binary segments (restart-to-warm at memory
    // speed); `--snapshot-format json` keeps writing per-entry
    // envelopes. Reading is always format-agnostic, so either setting
    // loads whatever the directory already holds.
    let format_arg = args.get("snapshot-format", "bin");
    let snapshot_format = SnapshotFormat::parse(format_arg)
        .ok_or_else(|| anyhow!("--snapshot-format must be 'json' or 'bin', got '{format_arg}'"))?;
    let persist_opts = PersistOptions {
        interval: std::time::Duration::from_millis(args.get_usize("snapshot-interval-ms", 1000)? as u64),
        max_entries: args.get_usize("cache-max-entries", 0)?,
        format: snapshot_format,
        ..PersistOptions::default()
    };
    if args.has("self-test") {
        return match cache_dir {
            Some(dir) => serve_warm_start_self_test(opts, batch_opts, &dir, persist_opts),
            None => serve_self_test(opts, batch_opts),
        };
    }
    let service = Arc::new(PlanService::new(opts));
    // Held for the process lifetime: warm-starts the caches now, then
    // write-behinds new entries until shutdown.
    let _snapshotter = match &cache_dir {
        Some(dir) => {
            let snap = Snapshotter::attach(service.clone(), dir, persist_opts)?;
            println!(
                "[ftl-serve] snapshot dir {dir}: loaded {} entries (skipped {} corrupt, {} version)",
                snap.counters().loaded(),
                snap.counters().skipped_corrupt(),
                snap.counters().skipped_version()
            );
            Some(snap)
        }
        None => None,
    };
    let scheduler = Arc::new(BatchScheduler::new(service, batch_opts));
    let addr = args.get("addr", "127.0.0.1:7117");
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    println!(
        "[ftl-serve] listening on {addr} \
         (DEPLOY <workload> <soc> <strategy> [deadline-ms] [lane=<name>] | STATS | METRICS \
         | TRACE [n] | SLOW [n] | PING; multiplexed v1 framing: FTL1 <id> <command...> — see PROTOCOL.md)"
    );
    // All connections are served by the async front door: one
    // readiness-polled event loop, many in-flight ids per connection,
    // streamed partial replies for v1 frames, serialized legacy replies
    // for bare v0 lines (ftl::serve::Frontend). `--write-queue-cap`
    // bounds each connection's unread-response backlog in bytes — past
    // it the client is shed as a slow reader.
    let frontend_opts = FrontendOptions {
        write_queue_cap: args.get_usize("write-queue-cap", 4 * 1024 * 1024)?,
        ..FrontendOptions::default()
    };
    let handle = Frontend::new(scheduler, frontend_opts).serve(listener)?;
    handle.join();
    Ok(())
}

/// In-process exercise of the serve layer — run by tier-1 via the
/// `serve` integration test so the service is covered without TCP.
fn serve_self_test(opts: ServeOptions, batch_opts: BatchOptions) -> Result<()> {
    println!("[ftl-serve] self-test");
    let service = PlanService::new(opts);
    let graph = resolve_workload("vit-base-stage")?;
    let cfg = DeployConfig::preset("siracusa", Strategy::Ftl)?;

    // 1. Cold plan: must consult the solver exactly once.
    let t_cold = Instant::now();
    let cold = service.plan(&graph, &cfg)?;
    let cold_time = t_cold.elapsed();
    ensure!(!cold.cached, "first request cannot be a cache hit");
    ensure!(service.stats().solves == 1, "cold plan must run exactly one solve");

    // 2. Warm plan: served from cache, sharing the same Arc, no solve.
    // Timing is best-of-100 so a scheduler hiccup can't flake the bound.
    let warm = service.plan(&graph, &cfg)?;
    ensure!(warm.cached, "second request must hit the cache");
    ensure!(Arc::ptr_eq(&cold.plan, &warm.plan), "cache must share the plan, not copy it");
    let mut warm_time = std::time::Duration::MAX;
    for _ in 0..100 {
        let t = Instant::now();
        let hit = service.plan(&graph, &cfg)?;
        warm_time = warm_time.min(t.elapsed());
        ensure!(hit.cached, "warm requests must keep hitting the cache");
    }
    ensure!(service.stats().solves == 1, "warm requests must skip the solver");
    let speedup = cold_time.as_nanos() as f64 / warm_time.as_nanos().max(1) as f64;
    println!(
        "[ftl-serve] cold plan {:.2?} vs warm hit {:.2?} ({speedup:.0}x)",
        cold_time, warm_time
    );
    ensure!(speedup >= 10.0, "warm cache hit must be >=10x faster than a cold solve (got {speedup:.1}x)");

    // 3. Concurrent identical DEPLOYs: coalesce, agree, and add no solves.
    let mut cycles: Vec<u64> = Vec::new();
    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::new();
        for _ in 0..6 {
            handles.push(s.spawn(|| {
                service.deploy("vit-base-stage", &graph, &cfg).map(|r| r.report.sim.total_cycles)
            }));
        }
        for h in handles {
            cycles.push(h.join().map_err(|_| anyhow!("self-test thread panicked"))??);
        }
        Ok(())
    })?;
    ensure!(cycles.windows(2).all(|w| w[0] == w[1]), "coalesced requests must agree on cycles");
    ensure!(service.stats().solves == 1, "identical concurrent requests must not re-solve");

    // 4. A structurally different request discriminates and solves anew.
    let baseline_cfg = DeployConfig::preset("cluster-only", Strategy::LayerPerLayer)?;
    let other = service.deploy("vit-base-stage", &graph, &baseline_cfg)?;
    ensure!(!other.cached, "different config must miss the cache");
    ensure!(other.fingerprint != cold.fingerprint, "fingerprint must discriminate configs");
    ensure!(service.stats().solves == 2, "new config must trigger exactly one more solve");
    ensure!(
        other.report.sim.total_cycles > cycles[0],
        "FTL on siracusa must beat the cluster-only baseline"
    );

    // 5. Sim-report cache: a warm DEPLOY must skip the engine entirely.
    // (Repeat the *most recent* key — older keys may legitimately have
    // been evicted under a tiny --cache-cap.)
    let sims_before = service.stats().sims;
    let warm_deploy = service.deploy("vit-base-stage", &graph, &baseline_cfg)?;
    ensure!(warm_deploy.cached && warm_deploy.sim_cached, "warm deploy must hit both caches");
    ensure!(service.stats().sims == sims_before, "warm deploy must not re-run the simulator");

    // 6. Batching scheduler: a concurrent mixed-SoC burst over a fresh
    // service must perform exactly one solve + one simulation per
    // distinct fingerprint, fanning each result out to all its waiters.
    // Fixed cache sizing: the burst's 3 keys must never evict each other
    // even under an adversarial --cache-cap.
    let burst_service = Arc::new(PlanService::new(ServeOptions {
        cache_capacity: 32,
        sim_cache_capacity: 64,
        cache_shards: 4,
        ..opts
    }));
    let burst_opts = BatchOptions {
        queue_capacity: 32,
        max_batch: 32,
        batch_window: batch_opts.batch_window.max(std::time::Duration::from_millis(50)),
        policy: batch_opts.policy,
        lanes: Vec::new(),
        trace: TraceOptions::default(),
    };
    let scheduler = BatchScheduler::new(burst_service.clone(), burst_opts.clone());
    let mix = [
        ("vit-base-stage", "siracusa", Strategy::Ftl),
        ("vit-base-stage", "cluster-only", Strategy::Ftl),
        ("vit-base-stage", "cluster-only", Strategy::LayerPerLayer),
    ];
    let mut burst: Vec<(usize, u64)> = Vec::new(); // (mix index, cycles)
    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::new();
        for round in 0..3 {
            for (i, (workload, soc, strategy)) in mix.iter().enumerate() {
                let scheduler = &scheduler;
                let graph = graph.clone();
                handles.push(s.spawn(move || -> Result<(usize, u64)> {
                    let cfg = DeployConfig::preset(soc, *strategy)?;
                    let outcome = scheduler.deploy(&format!("{workload}-{round}"), graph, cfg)?;
                    let reply = outcome
                        .served()
                        .ok_or_else(|| anyhow!("burst request must be served, not shed/timed out"))?;
                    Ok((i, reply.report.sim.total_cycles))
                }));
            }
        }
        for h in handles {
            burst.push(h.join().map_err(|_| anyhow!("burst thread panicked"))??);
        }
        Ok(())
    })?;
    ensure!(burst.len() == 9, "expected 9 burst replies");
    for i in 0..mix.len() {
        let cycles: Vec<u64> = burst.iter().filter(|(j, _)| *j == i).map(|(_, c)| *c).collect();
        ensure!(cycles.len() == 3, "each fingerprint must serve 3 requests");
        ensure!(cycles.windows(2).all(|w| w[0] == w[1]), "fan-out replies must agree on cycles");
    }
    let burst_stats = burst_service.stats();
    ensure!(burst_stats.solves == 3, "burst must solve once per distinct fingerprint (got {})", burst_stats.solves);
    ensure!(burst_stats.sims == 3, "burst must simulate once per distinct fingerprint (got {})", burst_stats.sims);
    let batch_stats = scheduler.stats();
    // Each distinct fingerprint's first (cold) request must be batched;
    // stragglers may take the warm fast path once their key is cached.
    ensure!(
        (3..=9).contains(&batch_stats.batched_requests),
        "burst batched_requests out of range: {}",
        batch_stats.batched_requests
    );
    ensure!(batch_stats.shed == 0 && batch_stats.timeouts == 0, "burst must not shed or time out");

    // 7. Admission control: a zero-capacity queue sheds, an expired
    // deadline times out — neither touches the solver. (Use a config the
    // burst did NOT warm, so the cache fast path can't serve it.)
    let gate = BatchScheduler::new(
        burst_service.clone(),
        BatchOptions { queue_capacity: 0, policy: AdmissionPolicy::Shed, ..burst_opts },
    );
    let mut cold_cfg = cfg.clone();
    cold_cfg.double_buffer = true;
    let shed = gate.deploy("overload", graph.clone(), cold_cfg)?;
    ensure!(shed.kind() == "SHED", "zero-capacity queue must shed");
    let late = scheduler.deploy_with_deadline(
        "late",
        graph.clone(),
        cfg.clone(),
        Some(std::time::Duration::ZERO),
    )?;
    ensure!(late.kind() == "TIMEOUT", "expired deadline must time out");
    ensure!(gate.stats().shed == 1 && scheduler.stats().timeouts == 1, "admission counters must record");
    ensure!(burst_service.stats().solves == 3, "shed/timed-out requests must not reach the solver");

    // 8. Determinism digest: a stable content hash over the three burst
    // plans, printed greppably so CI can assert that FTL_SOLVER_THREADS=1
    // and multi-threaded runs compile bit-identical plans.
    let mut plan_text = String::new();
    for (_, soc, strategy) in mix {
        let cfg = DeployConfig::preset(soc, strategy)?;
        let outcome = burst_service.plan(&graph, &cfg)?;
        ensure!(outcome.cached, "digest step must reuse the burst's cached plans");
        plan_text.push_str(&outcome.plan.to_json().to_string());
    }
    println!("[ftl-serve] plan_digest={}", checksum(plan_text.as_bytes()).hex());

    // 9. Priority lanes, deterministic core: saturate the scheduler's
    // own LaneSet under a virtual clock (shared `serve::wave` driver,
    // unit-cost quanta). Pure integer WFQ — the printed shares are
    // identical at any FTL_SOLVER_THREADS (the CI fairness smoke
    // asserts exactly that), and a 3:1 weight split must yield exactly
    // 12/4 cold-work units over 16 quanta.
    let shares = ftl::serve::wave::saturated_shares(&[("gold", 3), ("free", 1)], 16);
    println!("[ftl-serve] lane_shares quanta=16 gold={} free={} (weights 3:1)", shares[0], shares[1]);
    ensure!(shares == [12, 4], "3:1 WFQ must split 16 unit quanta 12/4 (got {shares:?})");

    // 10. Lane wiring over the protocol: lane= routes to the named lane,
    // unknown lanes fall back to default, per-lane counters ride in
    // STATS, and the scheduler-wide totals are the lane sums.
    let lane_sched = BatchScheduler::new(
        burst_service.clone(),
        BatchOptions {
            batch_window: std::time::Duration::ZERO,
            lanes: vec![LaneSpec::new("gold", 3, 32)],
            ..BatchOptions::default()
        },
    );
    let j = handle_line(&lane_sched, "DEPLOY vit-tiny-stage cluster-only ftl lane=gold");
    ensure!(j.get_opt("error").is_none(), "lane deploy failed: {j}");
    ensure!(j.get("lane")?.as_str()? == "gold", "lane= must route to the named lane");
    let j2 = handle_line(&lane_sched, "DEPLOY vit-tiny-stage cluster-only ftl lane=no-such-lane");
    ensure!(j2.get_opt("error").is_none(), "unknown lane must be served, not rejected: {j2}");
    ensure!(j2.get("lane")?.as_str()? == "default", "unknown lane must fall back to default");
    let lane_stats = lane_sched.stats();
    let gold_lane = lane_stats.lanes.iter().find(|l| l.name == "gold").expect("gold lane in stats");
    ensure!(gold_lane.batched_requests == 1, "the cold lane=gold deploy must be batched in gold");
    ensure!(gold_lane.cold_work >= 1, "gold's cold deploy must be charged as cold work");
    ensure!(
        lane_stats.lanes.iter().map(|l| l.batched_requests).sum::<u64>() == lane_stats.batched_requests
            && lane_stats.lanes.iter().map(|l| l.shed).sum::<u64>() == lane_stats.shed
            && lane_stats.lanes.iter().map(|l| l.timeouts).sum::<u64>() == lane_stats.timeouts,
        "batch.* totals must equal the per-lane sums"
    );
    println!("{}", lane_stats.lanes_table());

    // 11. Observability: a seeded mixed-lane wave over a traced
    // scheduler, then the tracing invariants — the merge of the per-lane
    // warm/cold histograms must equal the independently recorded
    // scheduler-wide histogram bucket-for-bucket, METRICS must
    // round-trip the strict exposition parser, and TRACE/SLOW must dump
    // parseable JSON lines with monotone stage offsets.
    let traced = ftl::serve::wave::mixed_lane_wave(7, 24)?;
    let tracer = traced.tracer().ok_or_else(|| anyhow!("tracing must be on by default"))?;
    ensure!(
        tracer.merged_lanes().snapshot() == tracer.overall().snapshot(),
        "per-lane latency histograms must merge to the scheduler-wide histogram"
    );
    ensure!(tracer.overall().count() == 25, "every served wave request must record a latency sample");
    let (warm_hist, cold_hist) = (ftl::metrics::Histogram::new(), ftl::metrics::Histogram::new());
    // Three lanes: the wave's gold/free plus the always-present default.
    for i in 0..traced.stats().lanes.len() {
        warm_hist.merge(tracer.warm_hist(i));
        cold_hist.merge(tracer.cold_hist(i));
    }
    println!(
        "[ftl-serve] latency warm_p50={}us warm_p99={}us cold_p50={}us cold_p99={}us queue_p50={}us n={}",
        warm_hist.quantile(0.5),
        warm_hist.quantile(0.99),
        cold_hist.quantile(0.5),
        cold_hist.quantile(0.99),
        tracer.queue_hist().quantile(0.5),
        tracer.overall().count()
    );
    let metrics = traced.metrics_text();
    let samples = ftl::metrics::expo::parse(&metrics)
        .map_err(|e| e.context("METRICS must round-trip the exposition parser"))?;
    ensure!(
        samples.iter().any(|s| s.name == "ftl_latency_us_count"),
        "METRICS must expose per-lane latency histograms"
    );
    println!("[ftl-serve] metrics lines={}", samples.len());
    for cmd in ["TRACE 8", "SLOW"] {
        let dump = handle_command(&traced, cmd);
        let mut lines = dump.lines();
        let header = ftl::util::json::parse(lines.next().ok_or_else(|| anyhow!("{cmd}: empty dump"))?)?;
        let spans = header.get("spans")?.as_usize()?;
        if cmd.starts_with("TRACE") {
            ensure!(spans >= 1, "TRACE must hold spans after the wave");
        }
        for line in lines {
            let span = ftl::util::json::parse(line)?;
            let mut prev = 0u64;
            for key in ["queued_us", "picked_us", "solved_us", "simmed_us", "total_us"] {
                if let Some(v) = span.get_opt(key) {
                    let v = v.as_u64()?;
                    ensure!(v >= prev, "{cmd}: span stages must be monotone ({key}={v} < {prev})");
                    prev = v;
                }
            }
        }
    }
    let bench = Json::obj(vec![
        ("name", Json::str("serve_latency_selftest")),
        ("requests", Json::Num(tracer.overall().count() as f64)),
        ("warm_p50_us", Json::Num(warm_hist.quantile(0.5) as f64)),
        ("warm_p99_us", Json::Num(warm_hist.quantile(0.99) as f64)),
        ("cold_p50_us", Json::Num(cold_hist.quantile(0.5) as f64)),
        ("cold_p99_us", Json::Num(cold_hist.quantile(0.99) as f64)),
        ("queue_p50_us", Json::Num(tracer.queue_hist().quantile(0.5) as f64)),
    ]);
    std::fs::write("BENCH_serve_latency.json", format!("{}\n", bench.pretty()))?;
    println!("[ftl-serve] wrote BENCH_serve_latency.json");

    // 12. The async front door over real TCP: a cold v1 deploy streams
    // plan strictly before done with per-phase sim events between, a
    // warm repeat collapses to one frame, a cold+warm pair completes
    // out of order on one connection, and bare v0 lines keep their
    // legacy single-line replies in request order (shared probes in
    // ftl::serve::wave, also run by examples/deploy_server.rs).
    let door_service = Arc::new(PlanService::new(ServeOptions {
        cache_capacity: 32,
        sim_cache_capacity: 64,
        cache_shards: 4,
        ..opts
    }));
    let door_sched = Arc::new(BatchScheduler::new(
        door_service,
        BatchOptions { batch_window: std::time::Duration::ZERO, ..BatchOptions::default() },
    ));
    let door = Frontend::new(door_sched, FrontendOptions::default())
        .serve(TcpListener::bind("127.0.0.1:0").context("binding the self-test front door")?)?;
    let door_addr = door.addr().to_string();
    let probe = ftl::serve::wave::streaming_probe(&door_addr)?;
    println!(
        "[ftl-serve] stream_events plan={} sim={} done={} out_of_order={}",
        probe.plan_events, probe.sim_events, probe.done_events, probe.out_of_order
    );
    ensure!(probe.plan_events == 2 && probe.done_events == 4, "front-door probe event counts off");
    let v0_replies = ftl::serve::wave::v0_probe(&door_addr)?;
    println!("[ftl-serve] v0_compat replies={v0_replies} (legacy lines, ordered, no v1 fields)");
    ensure!(door.counters().protocol_errors.get() == 0, "clean probes must not count protocol errors");
    door.join();

    let stats = service.stats();
    println!("{}", stats.cache.table());
    println!("{}", scheduler.stats().table());
    println!("{}", scheduler.stats_json().pretty());
    println!(
        "[ftl-serve] served {} requests with {} solves / {} sims (+ batch burst: 9 requests, 3 solves); self-test OK",
        stats.requests, stats.solves, stats.sims
    );
    Ok(())
}

/// Warm-start self-test (`ftl serve --self-test --cache-dir <dir>`):
/// attach the snapshotter (loading whatever the directory holds), serve
/// a fixed mixed workload set through the batch scheduler, flush the
/// snapshot, and report counters in a stable greppable format. Run once
/// against an empty directory it populates the snapshot (3 solves); run
/// again against the same directory every request must come out of the
/// loaded caches — `solves=0 sims=0` (asserted in-process and by the CI
/// warm-start smoke step).
fn serve_warm_start_self_test(
    opts: ServeOptions,
    batch_opts: BatchOptions,
    dir: &str,
    persist_opts: PersistOptions,
) -> Result<()> {
    println!("[ftl-serve] warm-start self-test (cache-dir: {dir})");
    let service = Arc::new(PlanService::new(opts));
    let snapshotter = Snapshotter::attach(service.clone(), dir, persist_opts)?;
    let loaded = snapshotter.counters().loaded();
    let scheduler = BatchScheduler::new(service.clone(), batch_opts);
    let mix = [
        ("vit-base-stage", "siracusa", Strategy::Ftl),
        ("vit-base-stage", "cluster-only", Strategy::Ftl),
        ("vit-tiny-stage", "cluster-only", Strategy::LayerPerLayer),
    ];
    for (workload, soc, strategy) in mix {
        let graph = resolve_workload(workload)?;
        let cfg = DeployConfig::preset(soc, strategy)?;
        let outcome = scheduler.deploy(workload, graph, cfg)?;
        ensure!(outcome.kind() == "OK", "warm-start request '{workload}' must be served");
    }
    // Drain anything the background pass hasn't written yet, then assert
    // on the cumulative counter (a background flush may already have run).
    snapshotter.flush();
    let written = snapshotter.counters().entries_written();
    ensure!(snapshotter.counters().write_errors() == 0, "snapshot writes must succeed in the self-test");
    let stats = service.stats();
    // Each mix entry contributes one plan + one sim snapshot entry.
    let full_snapshot = (2 * mix.len()) as u64;
    if loaded >= full_snapshot {
        ensure!(
            stats.solves == 0 && stats.sims == 0,
            "a populated snapshot must serve with zero solves/sims (got {}/{})",
            stats.solves,
            stats.sims
        );
        ensure!(written == 0, "a fully warm run has nothing new to snapshot");
    } else if loaded == 0 {
        ensure!(stats.solves == mix.len() as u64, "cold run must solve once per distinct request");
        ensure!(written == full_snapshot, "cold run must snapshot every new entry");
    }
    ensure!(
        service.stats_json().get("persist").is_ok(),
        "stats_json must expose persist counters when a snapshotter is attached"
    );
    println!(
        "[ftl-serve] warm-start: loaded={loaded} solves={} sims={} written={written} \
         skipped_corrupt={} skipped_version={}",
        stats.solves,
        stats.sims,
        snapshotter.counters().skipped_corrupt(),
        snapshotter.counters().skipped_version()
    );
    println!("[ftl-serve] warm-start self-test OK");
    Ok(())
}

/// `ftl verify [<workload>]` — plan a workload and run the static plan
/// verifier ([`ftl::verify::check_deployment`]) over the result:
/// arena-overlap/alignment/capacity, DMA-vs-kernel hazards, transfer
/// bounds, output-tile coverage and structural consistency, re-derived
/// from the plan artifact alone. Nonzero exit on any error-severity
/// finding. `--json` prints the machine-readable report; `--all` sweeps
/// the builtin workloads across SoCs, strategies and buffering modes;
/// `--mutate` runs the mutation-testing harness (each seeded plan
/// corruption must be caught by its intended rule).
fn cmd_verify(args: &Args) -> Result<()> {
    if args.has("mutate") {
        return cmd_verify_mutate(args);
    }
    if args.has("all") {
        return cmd_verify_all(args);
    }
    let (name, graph) = match args.pos.first() {
        // Positional names use the serve vocabulary (vit-base-stage,
        // stage-<seq>x<dim>x<hidden>, ...) so the CLI can verify exactly
        // what the wire serves.
        Some(name) => (name.clone(), resolve_workload(name)?),
        None => load_workload(args)?,
    };
    let cfg = make_config(args)?;
    let dep = Deployer::new(graph, cfg.clone()).plan().with_context(|| format!("planning '{name}'"))?;
    let report = ftl::verify::check_deployment(&dep, Some(&cfg.soc));
    if args.has("json") {
        println!("{}", report.to_json().pretty());
    } else {
        print!("{}", report.render());
    }
    if !report.ok() {
        bail!("plan verification failed for '{name}': {}", report.summary());
    }
    Ok(())
}

/// `ftl verify --all`: sweep the builtin serve workloads across both SoC
/// presets, both strategies and both buffering modes; any error-severity
/// finding (or plan failure) fails the sweep.
fn cmd_verify_all(args: &Args) -> Result<()> {
    let workloads = ["vit-base-stage", "vit-tiny-stage", "stage-64x96x192"];
    let mut rows: Vec<Json> = Vec::new();
    let mut failed = 0usize;
    let mut plans = 0usize;
    for workload in workloads {
        let graph = resolve_workload(workload)?;
        for soc in ["siracusa", "cluster-only"] {
            for strategy in [Strategy::Ftl, Strategy::LayerPerLayer] {
                for dbuf in [false, true] {
                    let mut cfg = DeployConfig::preset(soc, strategy)?;
                    cfg.double_buffer = dbuf;
                    let dep = Deployer::new(graph.clone(), cfg.clone())
                        .plan()
                        .with_context(|| format!("planning {workload} on {soc}/{strategy:?}/dbuf={dbuf}"))?;
                    let report = ftl::verify::check_deployment(&dep, Some(&cfg.soc));
                    plans += 1;
                    if !report.ok() {
                        failed += 1;
                    }
                    if args.has("json") {
                        rows.push(Json::obj(vec![
                            ("workload", Json::str(workload)),
                            ("soc", Json::str(soc)),
                            ("strategy", Json::str(format!("{strategy:?}"))),
                            ("double_buffer", Json::Bool(dbuf)),
                            ("report", report.to_json()),
                        ]));
                    } else {
                        let status = if report.ok() { "ok" } else { "FAIL" };
                        println!(
                            "{workload:<18} {soc:<14} {strategy:<14?} dbuf={dbuf:<5} findings={:<3} {status}",
                            report.findings.len()
                        );
                        if !report.ok() {
                            print!("{}", report.render());
                        }
                    }
                }
            }
        }
    }
    if args.has("json") {
        println!("{}", Json::Arr(rows).pretty());
    } else {
        println!("verify --all: {plans} plans checked, {failed} failed");
    }
    if failed > 0 {
        bail!("{failed} of {plans} plans failed verification");
    }
    Ok(())
}

/// `ftl verify --mutate`: the verifier's own false-negative test. Seeded
/// corruptions of a valid double-buffered plan, each of which must be
/// caught by its intended rule ([`ftl::verify::mutate`]); prints the
/// mutator → rule table and the `mutations=N caught=N` tally CI asserts.
fn cmd_verify_mutate(args: &Args) -> Result<()> {
    // Default to the full ViT-Base MLP: the mutators need a plan with two
    // phases and refetched double-buffered inputs to have targets.
    let name = args.pos.first().map(String::as_str).unwrap_or("vit-base");
    let graph = resolve_workload(name)?;
    let strategy = Strategy::parse(args.get("strategy", "ftl"))
        .ok_or_else(|| anyhow!("--strategy must be 'ftl' or 'baseline'"))?;
    let mut cfg = DeployConfig::preset(args.get("soc", "siracusa"), strategy)?;
    cfg.double_buffer = true;
    let dep = Deployer::new(graph, cfg.clone()).plan().with_context(|| format!("planning '{name}'"))?;
    let outcomes = ftl::verify::mutate::run_mutations(&dep, &cfg.soc)?;
    print!("{}", ftl::verify::mutate::render_outcomes(&outcomes));
    let missed = outcomes.iter().filter(|o| !o.caught).count();
    if missed > 0 {
        bail!("{missed} mutation(s) escaped the verifier");
    }
    Ok(())
}

/// `ftl snapshot compact|inspect --cache-dir DIR` — offline maintenance
/// for a snapshot directory, running against the same codec the server
/// uses. `compact` folds every segment **and** every legacy per-entry
/// JSON envelope into one freshly fsync'd segment (migrating JSON dirs
/// in place — source files are removed only after the new segment is
/// durable), evicting the lightest-lane-hint entries beyond
/// `--cache-max-entries` (0 = unbounded); `inspect` prints a JSON
/// breakdown of segments, live/dead bytes and stray JSON entries
/// without touching anything.
fn cmd_snapshot(args: &Args) -> Result<()> {
    let sub = args.pos.first().map(String::as_str).unwrap_or("");
    if let Some(extra) = args.pos.get(1) {
        bail!("unexpected argument '{extra}'");
    }
    let dir = PathBuf::from(
        args.get_opt("cache-dir").ok_or_else(|| anyhow!("ftl snapshot {sub} needs --cache-dir DIR"))?,
    );
    ensure!(dir.is_dir(), "snapshot directory {} does not exist", dir.display());
    match sub {
        "compact" => {
            let max_entries = args.get_usize("cache-max-entries", 0)?;
            let report = ftl::serve::compact_dir(&dir, max_entries)?;
            if args.has("json") {
                println!("{}", report.to_json().pretty());
            } else {
                println!(
                    "[ftl-snapshot] compacted {}: segments {} -> {} json_migrated={} live={} evicted={} \
                     skipped_corrupt={} skipped_version={} bytes={}",
                    dir.display(),
                    report.segments_before,
                    report.segments_after,
                    report.json_migrated,
                    report.live,
                    report.evicted,
                    report.skipped_corrupt,
                    report.skipped_version,
                    report.bytes
                );
            }
            Ok(())
        }
        "inspect" => {
            println!("{}", ftl::serve::inspect_dir(&dir)?.pretty());
            Ok(())
        }
        "" => bail!("ftl snapshot needs a subcommand: 'compact' or 'inspect'"),
        other => bail!("unknown snapshot subcommand '{other}' (expected 'compact' or 'inspect')"),
    }
}

fn cmd_fig3(args: &Args) -> Result<()> {
    let seq = args.get_usize("seq", 197)?;
    let d = args.get_usize("dim", 768)?;
    let h = args.get_usize("hidden", 3072)?;
    let rows = experiments::fig3(seq, d, h, args.has("double-buffer"))?;
    if args.has("json") {
        let arr: Vec<Json> = rows
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("config", Json::str(&r.config)),
                    ("strategy", Json::str(&r.strategy)),
                    ("cycles", Json::int(r.cycles as usize)),
                    ("ms", Json::Num(r.ms)),
                    ("reduction_pct", Json::Num(r.reduction_pct)),
                ])
            })
            .collect();
        println!("{}", Json::Arr(arr).pretty());
    } else {
        println!("Fig. 3 — ViT MLP stage ({seq}x{d}->{h}); paper: -28.8% (cluster), -60.1% (cluster+npu)\n");
        println!("{}", experiments::fig3_table(&rows));
    }
    Ok(())
}

fn cmd_dma(args: &Args) -> Result<()> {
    let seq = args.get_usize("seq", 197)?;
    let d = args.get_usize("dim", 768)?;
    let h = args.get_usize("hidden", 3072)?;
    let r = experiments::dma_reduction(seq, d, h, args.get("soc", "cluster-only"))?;
    println!("DMA reduction (paper: -47.1%)");
    println!(
        "  transfers: {} -> {} ({:.1}% reduction)",
        r.base_transfers, r.ftl_transfers, r.transfer_reduction_pct
    );
    println!("  bytes:     {} -> {} ({:.1}% reduction)", r.base_bytes, r.ftl_bytes, r.byte_reduction_pct);
    Ok(())
}

/// Emit the tile signatures needed by the AOT compiler (two-pass build):
/// every (op, exact tile shape) the planned deployments will invoke.
fn cmd_emit_tiles(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.get("out", "artifacts/tiles.json"));
    let seq = args.get_usize("seq", 197)?;
    let d = args.get_usize("dim", 768)?;
    let h = args.get_usize("hidden", 3072)?;
    let mut sigs: std::collections::BTreeMap<String, (String, Vec<Vec<usize>>, Vec<usize>)> =
        std::collections::BTreeMap::new();
    for strategy in [Strategy::LayerPerLayer, Strategy::Ftl] {
        for soc in ["cluster-only", "siracusa"] {
            let graph = experiments::vit_mlp_stage(seq, d, h);
            let cfg = DeployConfig::preset(soc, strategy)?;
            let dep = Deployer::new(graph, cfg);
            let plan = dep.plan()?;
            for (key, ins, outs) in plan.tile_signatures(dep.graph()) {
                let kind = key.split('_').next().unwrap_or("?").to_string();
                sigs.entry(key).or_insert((kind, ins, outs));
            }
        }
    }
    let entries: Vec<Json> = sigs
        .iter()
        .map(|(key, (kind, ins, outs))| {
            Json::obj(vec![
                ("name", Json::str(key)),
                ("kind", Json::str(kind)),
                (
                    "in_shapes",
                    Json::Arr(ins.iter().map(|s| Json::Arr(s.iter().map(|&v| Json::int(v)).collect())).collect()),
                ),
                ("out_shape", Json::Arr(outs.iter().map(|&v| Json::int(v)).collect())),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("workload", Json::obj(vec![("seq", Json::int(seq)), ("dim", Json::int(d)), ("hidden", Json::int(h))])),
        ("entries", Json::Arr(entries)),
    ]);
    if let Some(parent) = out.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(&out, doc.pretty())?;
    println!("wrote {} tile signatures to {}", sigs.len(), out.display());
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let (name, graph) = load_workload(args)?;
    let cfg = make_config(args)?;
    let dep = Deployer::new(graph, cfg).with_workload_name(&name);
    let seed = args.get_usize("seed", 42)? as u64;
    let artifacts = args.get("artifacts", "artifacts");
    let worst = if std::path::Path::new(artifacts).join("manifest.json").exists() {
        let backend = PjrtBackend::new(std::path::Path::new(artifacts))?;
        println!("backend: {} (artifacts: {artifacts})", KernelBackend::name(&backend));
        if KernelBackend::name(&backend) == "pjrt-stub" {
            println!(
                "warning: built without the `xla` feature — artifacts are NOT executed; \
                 kernels fall back to the native reference, so this validates the tiling \
                 transformation only, not the AOT artifacts"
            );
        }
        dep.validate_numerics(backend, seed)?
    } else {
        println!("backend: native (no manifest at {artifacts}/manifest.json)");
        dep.validate_numerics(NativeBackend, seed)?
    };
    println!("workload {name}: max |tiled - oracle| = {worst:.2e}");
    if worst > 1e-3 {
        bail!("numerics validation FAILED (deviation {worst})");
    }
    println!("numerics validation OK");
    Ok(())
}

fn cmd_export(args: &Args) -> Result<()> {
    let (name, graph) = load_workload(args)?;
    let out = args.get("out", "network.json");
    std::fs::write(out, graph_to_json(&graph)?)?;
    println!("exported workload '{name}' to {out}");
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let seq = args.get_usize("seq", 197)?;
    let d = args.get_usize("dim", 768)?;
    let hs = [256, 512, 1024, 1536, 2048, 3072, 4096];
    let rows = experiments::hidden_sweep(seq, d, &hs, args.get("soc", "siracusa"))?;
    let mut t = ftl::metrics::Table::new(&["hidden", "baseline cycles", "ftl cycles", "reduction"]);
    for (h, base, f, red) in rows {
        t.row(&[h.to_string(), base.to_string(), f.to_string(), format!("-{red:.1}%")]);
    }
    println!("{}", t.render());
    Ok(())
}

/// `ftl soak` — seeded soak/chaos run against a live `ftl serve` child
/// ([`ftl::soak`]). `--seed` fixes the traffic/fault schedule, `--waves`
/// the length (minimum 3: mixed, warm replay, post-corruption replay),
/// `--requests` the per-wave volume, `--out` the trajectory report
/// path. `--cache-dir` pins the snapshot directory and keeps it
/// afterwards; by default a temp directory is used and removed after a
/// clean run. `FTL_SOAK_SMOKE=1` shrinks volumes for CI smoke.
fn cmd_soak(args: &Args) -> Result<()> {
    let smoke = std::env::var("FTL_SOAK_SMOKE").is_ok_and(|v| v == "1");
    let seed = args.get_usize("seed", 1)? as u64;
    let (cache_dir, keep_dir) = match args.get_opt("cache-dir") {
        Some(dir) => (PathBuf::from(dir), true),
        None => (std::env::temp_dir().join(format!("ftl-soak-{seed}-{}", std::process::id())), false),
    };
    let opts = ftl::soak::SoakOptions {
        seed,
        waves: args.get_usize("waves", 4)?,
        requests_per_wave: args.get_usize("requests", if smoke { 8 } else { 24 })?,
        server_bin: std::env::current_exe().context("locating the ftl binary")?,
        cache_dir: cache_dir.clone(),
        out_path: PathBuf::from(args.get("out", "BENCH_soak.json")),
        smoke,
    };
    ftl::soak::run(&opts)?;
    if !keep_dir {
        let _ = std::fs::remove_dir_all(&cache_dir);
    }
    Ok(())
}

fn help() {
    println!(
        "ftl — Fused-Tiled Layers deployment framework (paper reproduction)

USAGE: ftl <command> [flags]

COMMANDS:
  deploy       plan + simulate one deployment     (--workload --soc --strategy [--double-buffer] [--json])
  serve        batch-aware deployment service     ([--addr 127.0.0.1:7117] [--workers 4] [--cache-cap 64]
               (DEPLOY/STATS/PING plus METRICS/    [--sim-cache-cap 256] [--cache-shards 8] [--queue-cap 256]
               TRACE [n]/SLOW [n] line protocol,   [--batch-window-ms 2] [--max-batch 64] [--shed]
               bare v0 or multiplexed+streaming    [--lane name:weight:cap[:shed|:block][:deadline-ms]]...
               FTL1 framing — see PROTOCOL.md)     [--cache-dir DIR] [--snapshot-interval-ms 1000]
                                                   [--cache-max-entries 0] [--snapshot-format bin|json]
                                                   [--trace-cap 512] (0 = tracing off)
                                                   [--slowlog-ms 250] [--write-queue-cap 4194304]
                                                   [--verify-plans] [--self-test])
  soak         seeded soak/chaos harness          ([--seed 1] [--waves 4] [--requests 24]
               (owns a live serve child: traffic   [--cache-dir DIR] [--out BENCH_soak.json];
               waves, SIGKILL + warm restarts,     FTL_SOAK_SMOKE=1 shrinks volumes for CI;
               snapshot corruption, lane bursts,   wire-level counter invariants asserted
               slow readers, oversized frames)     after every wave)
  snapshot     snapshot-dir maintenance           (snapshot compact|inspect --cache-dir DIR
               (compact segments + migrate JSON    [--cache-max-entries 0] [--json]; compaction keeps
               entries in place, or inspect)       the heaviest lane hints when over the cap)
  verify       static plan verification           (verify [<workload>] [--soc --strategy --double-buffer]
               (arena overlap/align/capacity,      [--json] | verify --all | verify --mutate;
               DMA hazards, transfer bounds,       nonzero exit on any error-severity finding)
               tile coverage, structure)
  fig3         reproduce the paper's Fig. 3       ([--seq --dim --hidden] [--double-buffer] [--json])
  dma          reproduce the -47.1% DMA metric    ([--soc])
  sweep        hidden-dim sweep (Ext-A)           ([--soc])
  emit-tiles   export tile signatures for AOT     (--out artifacts/tiles.json)
  run          numerics validation vs oracle      (--artifacts artifacts [--workload] [--strategy])
  export       write a workload as network JSON   (--workload --out)
  help         this text

WORKLOADS: vit-base-stage (default, the paper's), vit-tiny-stage, mlp-stage
           (--dim/--hidden), vit-base-block, deep-mlp, attention, vit-tiny|small|base|large
SOCS:      siracusa (cluster+NPU), cluster-only
STRATEGY:  ftl (default), baseline
GLOBAL:    --solver-threads N (default: FTL_SOLVER_THREADS or auto) — tiling-solver worker budget;
           deterministic, any value compiles bit-identical plans"
    );
}

/// Apply the global solver-concurrency knob: `--solver-threads N`
/// (any command) overrides the `FTL_SOLVER_THREADS` env default; `0`
/// restores auto-detection. Thread count never changes solver output
/// (deterministic branch-and-bound — see `ftl::tiling::SolverPool`), so
/// this is a pure throughput knob.
fn apply_solver_threads(args: &Args) -> Result<()> {
    if args.has("solver-threads") {
        ftl::tiling::SolverPool::global().set_threads(args.get_usize("solver-threads", 0)?);
    }
    Ok(())
}

fn dispatch(args: &Args) -> Result<()> {
    apply_solver_threads(args)?;
    // `verify` takes a positional workload, `snapshot` a subcommand;
    // every other command keeps the old strictness.
    if args.cmd != "verify" && args.cmd != "snapshot" {
        if let Some(extra) = args.pos.first() {
            bail!("unexpected argument '{extra}'");
        }
    }
    match args.cmd.as_str() {
        "deploy" => cmd_deploy(args),
        "serve" => cmd_serve(args),
        "soak" => cmd_soak(args),
        "snapshot" => cmd_snapshot(args),
        "verify" => cmd_verify(args),
        "fig3" => cmd_fig3(args),
        "dma" => cmd_dma(args),
        "sweep" => cmd_sweep(args),
        "emit-tiles" => cmd_emit_tiles(args),
        "run" => cmd_run(args),
        "export" => cmd_export(args),
        "help" | "--help" | "-h" => {
            help();
            Ok(())
        }
        other => {
            help();
            Err(anyhow!("unknown command '{other}'"))
        }
    }
}

fn main() {
    let code = match Args::parse().and_then(|args| dispatch(&args)) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}
