//! Schedule → task graph translation and report collection.

#![forbid(unsafe_code)]

use anyhow::{anyhow, Result};

use crate::dma::DmaStats;
use crate::memory::Level;
use crate::schedule::{Phase, Schedule};
use crate::soc::{ComputeUnit, SocConfig};
use crate::util::bincode::{BinReader, BinWriter};
use crate::util::json::Json;

use super::engine::{Engine, Resource, TaskId, TaskSpec};

/// What limits a phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Boundedness {
    /// Kernels dominate (the paper's cluster-only GEMM case).
    Compute,
    /// DMA dominates (the paper's NPU case — where FTL pays off most).
    Dma,
    /// Neither clearly dominates (< 20 % apart).
    Balanced,
}

impl Boundedness {
    /// Canonical name (shared by [`std::fmt::Display`] and the snapshot
    /// codec).
    pub const fn name(self) -> &'static str {
        match self {
            Boundedness::Compute => "compute-bound",
            Boundedness::Dma => "dma-bound",
            Boundedness::Balanced => "balanced",
        }
    }

    /// Parse a canonical name back.
    pub fn parse(s: &str) -> Option<Boundedness> {
        Some(match s {
            "compute-bound" => Boundedness::Compute,
            "dma-bound" => Boundedness::Dma,
            "balanced" => Boundedness::Balanced,
            _ => return None,
        })
    }
}

impl std::fmt::Display for Boundedness {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Per-phase simulation outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseReport {
    /// Phase name (node names joined with '+').
    pub name: String,
    /// Phase makespan in cycles.
    pub cycles: u64,
    /// Busy cycles: cluster.
    pub cluster_busy: u64,
    /// Busy cycles: NPU.
    pub npu_busy: u64,
    /// Busy cycles: cluster DMA (L2↔L1).
    pub dma_l2_busy: u64,
    /// Busy cycles: IO DMA (L3↔L2).
    pub dma_l3_busy: u64,
    /// What limits the phase.
    pub bound: Boundedness,
    /// DMA statistics of the phase.
    pub dma: DmaStats,
}

/// Whole-network simulation outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct SimReport {
    /// Total cycles (phases are barriers, so the sum of phase makespans).
    pub total_cycles: u64,
    /// Per-phase breakdown.
    pub phases: Vec<PhaseReport>,
    /// Aggregated DMA statistics.
    pub dma: DmaStats,
}

impl SimReport {
    /// Wall-clock milliseconds at the SoC clock.
    pub fn ms(&self, soc: &SocConfig) -> f64 {
        soc.cycles_to_ms(self.total_cycles)
    }

    /// Percentage runtime reduction vs a baseline report.
    pub fn runtime_reduction_vs(&self, baseline: &SimReport) -> f64 {
        if baseline.total_cycles == 0 {
            return 0.0;
        }
        100.0 * (baseline.total_cycles as f64 - self.total_cycles as f64) / baseline.total_cycles as f64
    }

    /// Canonical JSON encoding (the snapshot codec — see
    /// [`crate::serve::persist`]; distinct from [`crate::metrics::sim_json`],
    /// which renders for reports and is not decodable).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("total_cycles", Json::int(self.total_cycles as usize)),
            ("phases", Json::Arr(self.phases.iter().map(PhaseReport::to_json).collect())),
            ("dma", self.dma.to_json()),
        ])
    }

    /// Decode the canonical JSON encoding.
    pub fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            total_cycles: v.get("total_cycles")?.as_u64()?,
            phases: v.get("phases")?.as_arr()?.iter().map(PhaseReport::from_json).collect::<Result<_>>()?,
            dma: DmaStats::from_json(v.get("dma")?)?,
        })
    }

    /// Canonical binary encoding (`ftl-bin-v1`).
    pub fn to_bin(&self, w: &mut BinWriter) {
        w.u64(self.total_cycles);
        w.seq(&self.phases, |w, p| p.to_bin(w));
        self.dma.to_bin(w);
    }

    /// Decode the canonical binary encoding.
    pub fn from_bin(r: &mut BinReader) -> Result<Self> {
        Ok(Self {
            total_cycles: r.u64()?,
            phases: r.seq(PhaseReport::from_bin)?,
            dma: DmaStats::from_bin(r)?,
        })
    }
}

impl PhaseReport {
    /// Canonical JSON encoding.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("cycles", Json::int(self.cycles as usize)),
            ("cluster_busy", Json::int(self.cluster_busy as usize)),
            ("npu_busy", Json::int(self.npu_busy as usize)),
            ("dma_l2_busy", Json::int(self.dma_l2_busy as usize)),
            ("dma_l3_busy", Json::int(self.dma_l3_busy as usize)),
            ("bound", Json::str(self.bound.name())),
            ("dma", self.dma.to_json()),
        ])
    }

    /// Decode the canonical JSON encoding.
    pub fn from_json(v: &Json) -> Result<Self> {
        let bound = v.get("bound")?.as_str()?;
        Ok(Self {
            name: v.get("name")?.as_str()?.to_string(),
            cycles: v.get("cycles")?.as_u64()?,
            cluster_busy: v.get("cluster_busy")?.as_u64()?,
            npu_busy: v.get("npu_busy")?.as_u64()?,
            dma_l2_busy: v.get("dma_l2_busy")?.as_u64()?,
            dma_l3_busy: v.get("dma_l3_busy")?.as_u64()?,
            bound: Boundedness::parse(bound).ok_or_else(|| anyhow!("unknown boundedness '{bound}'"))?,
            dma: DmaStats::from_json(v.get("dma")?)?,
        })
    }

    /// Canonical binary encoding (`ftl-bin-v1`).
    pub fn to_bin(&self, w: &mut BinWriter) {
        w.str(&self.name);
        w.u64(self.cycles);
        w.u64(self.cluster_busy);
        w.u64(self.npu_busy);
        w.u64(self.dma_l2_busy);
        w.u64(self.dma_l3_busy);
        w.str(self.bound.name());
        self.dma.to_bin(w);
    }

    /// Decode the canonical binary encoding.
    pub fn from_bin(r: &mut BinReader) -> Result<Self> {
        let name = r.str()?;
        let cycles = r.u64()?;
        let cluster_busy = r.u64()?;
        let npu_busy = r.u64()?;
        let dma_l2_busy = r.u64()?;
        let dma_l3_busy = r.u64()?;
        let bound = r.str()?;
        let bound = Boundedness::parse(&bound).ok_or_else(|| anyhow!("unknown boundedness '{bound}'"))?;
        Ok(Self { name, cycles, cluster_busy, npu_busy, dma_l2_busy, dma_l3_busy, bound, dma: DmaStats::from_bin(r)? })
    }
}

/// Simulate a schedule on a SoC.
pub fn simulate(schedule: &Schedule, soc: &SocConfig) -> Result<SimReport> {
    simulate_with(schedule, soc, |_, _, _| {})
}

/// [`simulate`], reporting each finished phase to `on_phase(index,
/// total, report)` in schedule order before the full [`SimReport`] is
/// assembled — the hook behind streamed `sim` events on the serve wire
/// (one event per phase while the engine is still working).
pub fn simulate_with(
    schedule: &Schedule,
    soc: &SocConfig,
    mut on_phase: impl FnMut(usize, usize, &PhaseReport),
) -> Result<SimReport> {
    let total_phases = schedule.phases.len();
    let mut phases = Vec::with_capacity(total_phases);
    let mut dma = DmaStats::default();
    let mut total = 0u64;
    for (i, phase) in schedule.phases.iter().enumerate() {
        let rep = simulate_phase(phase, soc)?;
        total += rep.cycles;
        dma.merge(&rep.dma);
        on_phase(i, total_phases, &rep);
        phases.push(rep);
    }
    Ok(SimReport { total_cycles: total, phases, dma })
}

fn simulate_phase(phase: &Phase, soc: &SocConfig) -> Result<PhaseReport> {
    let mut e = Engine::new();
    let mut stats = DmaStats::default();

    // Per-step task ids for pipeline dependencies.
    let mut step_dma_in: Vec<Vec<TaskId>> = Vec::with_capacity(phase.steps.len());
    let mut step_kernels: Vec<Vec<TaskId>> = Vec::with_capacity(phase.steps.len());
    let mut step_dma_out: Vec<Vec<TaskId>> = Vec::with_capacity(phase.steps.len());
    // In single-buffered mode everything chains onto the previous task.
    let mut prev_task: Option<TaskId> = None;

    for (i, step) in phase.steps.iter().enumerate() {
        let mut dma_in_ids = Vec::with_capacity(step.dma_in.len());
        // Ping/pong: buffers are reused from step i−2, so loads (and the
        // kernels overwriting output buffers) must wait for that step.
        let two_back_kernels: Vec<TaskId> =
            if i >= 2 { step_kernels[i - 2].clone() } else { Vec::new() };
        let two_back_stores: Vec<TaskId> =
            if i >= 2 { step_dma_out[i - 2].clone() } else { Vec::new() };

        let mut prev_leg: Option<TaskId> = None;
        for t in &step.dma_in {
            let cycles = soc.dma_for(t.channel_level()).cycles(t);
            stats.record(t, cycles);
            let mut deps: Vec<TaskId> = Vec::new();
            if phase.double_buffered {
                deps.extend(two_back_kernels.iter().copied());
                // Multi-leg transfers (L3→L2→L1) chain leg to leg.
                if t.to == Level::L1 {
                    if let Some(p) = prev_leg {
                        deps.push(p);
                    }
                }
            } else if let Some(p) = prev_task {
                deps.push(p);
            }
            let id = e.submit(TaskSpec { resource: Resource::Dma(t.channel_level()), duration: cycles, deps });
            prev_leg = Some(id);
            prev_task = Some(id);
            dma_in_ids.push(id);
        }

        let mut kernel_ids = Vec::with_capacity(step.kernels.len());
        let mut prev_kernel: Option<TaskId> = None;
        for k in &step.kernels {
            let mut deps: Vec<TaskId> = Vec::new();
            if phase.double_buffered {
                deps.extend(dma_in_ids.iter().copied());
                deps.extend(two_back_stores.iter().copied());
                if let Some(p) = prev_kernel {
                    deps.push(p); // data dependency within the fused chain
                }
            } else if let Some(p) = prev_task {
                deps.push(p);
            }
            let id = e.submit(TaskSpec { resource: Resource::Unit(k.unit), duration: k.cycles, deps });
            prev_kernel = Some(id);
            prev_task = Some(id);
            kernel_ids.push(id);
        }

        let mut dma_out_ids = Vec::with_capacity(step.dma_out.len());
        let mut prev_leg: Option<TaskId> = None;
        for t in &step.dma_out {
            let cycles = soc.dma_for(t.channel_level()).cycles(t);
            stats.record(t, cycles);
            let mut deps: Vec<TaskId> = Vec::new();
            if phase.double_buffered {
                deps.extend(kernel_ids.iter().copied());
                if let Some(p) = prev_leg {
                    deps.push(p); // L1→L2 before L2→L3
                }
            } else if let Some(p) = prev_task {
                deps.push(p);
            }
            let id = e.submit(TaskSpec { resource: Resource::Dma(t.channel_level()), duration: cycles, deps });
            prev_leg = Some(id);
            prev_task = Some(id);
            dma_out_ids.push(id);
        }

        step_dma_in.push(dma_in_ids);
        step_kernels.push(kernel_ids);
        step_dma_out.push(dma_out_ids);
    }

    let run = e.run()?;
    let cluster_busy = run.busy_of(Resource::Unit(ComputeUnit::Cluster));
    let npu_busy = run.busy_of(Resource::Unit(ComputeUnit::Npu));
    let dma_l2_busy = run.busy_of(Resource::Dma(Level::L2));
    let dma_l3_busy = run.busy_of(Resource::Dma(Level::L3));
    let compute = cluster_busy + npu_busy;
    let dma_busy = dma_l2_busy + dma_l3_busy;
    let bound = if dma_busy as f64 > 1.2 * compute as f64 {
        Boundedness::Dma
    } else if compute as f64 > 1.2 * dma_busy as f64 {
        Boundedness::Compute
    } else {
        Boundedness::Balanced
    };

    Ok(PhaseReport {
        name: phase.name.clone(),
        cycles: run.makespan,
        cluster_busy,
        npu_busy,
        dma_l2_busy,
        dma_l3_busy,
        bound,
        dma: stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::vit_mlp;
    use crate::ir::DType;
    use crate::schedule::build_schedule;
    use crate::soc::{siracusa_reduced, siracusa_reduced_cluster_only};
    use crate::tiling::{fuse_groups, solve_graph, FusionPolicy, SolverOptions, Strategy};

    fn run(strategy: Strategy, npu: bool, dbuf: bool) -> SimReport {
        let g = vit_mlp(197, 768, 3072, DType::Int8);
        let soc = if npu { siracusa_reduced() } else { siracusa_reduced_cluster_only() };
        let groups = fuse_groups(&g, strategy, FusionPolicy::default());
        let (_, sol) = solve_graph(&g, &soc, groups, &SolverOptions::default(), dbuf).unwrap();
        let sched = build_schedule(&g, &soc, &sol).unwrap();
        simulate(&sched, &soc).unwrap()
    }

    #[test]
    fn ftl_faster_than_baseline_cluster() {
        let base = run(Strategy::LayerPerLayer, false, false);
        let ftl = run(Strategy::Ftl, false, false);
        let red = ftl.runtime_reduction_vs(&base);
        assert!(red > 10.0, "cluster-only FTL reduction too small: {red:.1}%");
        assert!(red < 60.0, "cluster-only FTL reduction implausibly large: {red:.1}%");
    }

    #[test]
    fn ftl_faster_than_baseline_npu() {
        let base = run(Strategy::LayerPerLayer, true, false);
        let ftl = run(Strategy::Ftl, true, false);
        let red = ftl.runtime_reduction_vs(&base);
        assert!(red > 40.0, "NPU FTL reduction too small: {red:.1}%");
        assert!(red < 85.0, "NPU FTL reduction implausibly large: {red:.1}%");
    }

    #[test]
    fn npu_case_reduction_larger_than_cluster() {
        let base_c = run(Strategy::LayerPerLayer, false, false);
        let ftl_c = run(Strategy::Ftl, false, false);
        let base_n = run(Strategy::LayerPerLayer, true, false);
        let ftl_n = run(Strategy::Ftl, true, false);
        assert!(
            ftl_n.runtime_reduction_vs(&base_n) > ftl_c.runtime_reduction_vs(&base_c),
            "the paper's key shape: NPU case benefits more from FTL"
        );
    }

    #[test]
    fn dma_transfer_reduction_large() {
        let base = run(Strategy::LayerPerLayer, false, false);
        let ftl = run(Strategy::Ftl, false, false);
        let red = ftl.dma.byte_reduction_vs(&base.dma);
        assert!(red > 25.0, "DMA byte reduction too small: {red:.1}%");
    }

    #[test]
    fn double_buffer_helps_or_equal() {
        for npu in [false, true] {
            let single = run(Strategy::Ftl, npu, false);
            let double = run(Strategy::Ftl, npu, true);
            assert!(
                double.total_cycles <= single.total_cycles,
                "double buffering must not slow down (npu={npu}): {} vs {}",
                double.total_cycles,
                single.total_cycles
            );
        }
    }

    #[test]
    fn npu_only_busy_when_present() {
        let no_npu = run(Strategy::Ftl, false, false);
        assert!(no_npu.phases.iter().all(|p| p.npu_busy == 0));
        let with_npu = run(Strategy::Ftl, true, false);
        assert!(with_npu.phases.iter().any(|p| p.npu_busy > 0));
    }

    #[test]
    fn sim_report_json_roundtrip() {
        for (npu, dbuf) in [(false, false), (true, true)] {
            let rep = run(Strategy::Ftl, npu, dbuf);
            let back = SimReport::from_json(&rep.to_json()).unwrap();
            assert_eq!(back, rep, "sim report must round-trip (npu={npu}, dbuf={dbuf})");
        }
        for b in [Boundedness::Compute, Boundedness::Dma, Boundedness::Balanced] {
            assert_eq!(Boundedness::parse(b.name()), Some(b));
        }
    }

    #[test]
    fn phase_cycles_sum_to_total() {
        let rep = run(Strategy::Ftl, true, true);
        let sum: u64 = rep.phases.iter().map(|p| p.cycles).sum();
        assert_eq!(sum, rep.total_cycles);
    }

    #[test]
    fn baseline_gelu_phase_is_dma_bound() {
        // The paper's mechanism: the standalone GeLU layer round-trips the
        // L3-spilled intermediate; its phase must be DMA-bound.
        let base = run(Strategy::LayerPerLayer, false, false);
        let gelu = base.phases.iter().find(|p| p.name == "gelu").expect("gelu phase");
        assert_eq!(gelu.bound, Boundedness::Dma);
        assert!(gelu.dma_l3_busy > 0, "gelu must touch the IO DMA (L3 spill)");
    }
}
