//! GVSoC-style event-driven SoC simulation.
//!
//! The paper measures runtime with GVSoC, an event-based simulator whose
//! cycle counts come from analytic per-engine models. We reproduce that
//! abstraction: a discrete-event [`engine`] schedules *tasks* (DMA
//! transfers, kernel invocations) on *serial resources* (the cluster, the
//! NPU, one DMA channel per outer memory level) honouring explicit
//! dependencies; [`executor`] translates a [`crate::schedule::Schedule`]
//! into the task graph — sequential within a single-buffered phase,
//! software-pipelined (ping/pong) within a double-buffered one — and
//! collects runtime, per-resource utilisation and DMA statistics.
//!
//! # Determinism (the sim-cache contract)
//!
//! [`simulate`] is a pure function of (schedule, SoC): no randomness, no
//! wall-clock, no global state — ties in the event queue break by task
//! id, which is assigned deterministically from the schedule order. The
//! serve layer depends on this to cache [`SimReport`]s by plan
//! fingerprint ([`crate::serve::SimCache`]); anything that would make two
//! runs of the same schedule diverge (e.g. randomized tie-breaking or
//! time-based scheduling) must also invalidate that cache's key scheme.

#![forbid(unsafe_code)]

mod engine;
mod executor;

pub use engine::{Engine, Resource, TaskId, TaskSpec};
pub use executor::{simulate, simulate_with, Boundedness, PhaseReport, SimReport};
