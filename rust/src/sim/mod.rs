//! GVSoC-style event-driven SoC simulation.
//!
//! The paper measures runtime with GVSoC, an event-based simulator whose
//! cycle counts come from analytic per-engine models. We reproduce that
//! abstraction: a discrete-event [`engine`] schedules *tasks* (DMA
//! transfers, kernel invocations) on *serial resources* (the cluster, the
//! NPU, one DMA channel per outer memory level) honouring explicit
//! dependencies; [`executor`] translates a [`crate::schedule::Schedule`]
//! into the task graph — sequential within a single-buffered phase,
//! software-pipelined (ping/pong) within a double-buffered one — and
//! collects runtime, per-resource utilisation and DMA statistics.

mod engine;
mod executor;

pub use engine::{Engine, Resource, TaskId, TaskSpec};
pub use executor::{simulate, Boundedness, PhaseReport, SimReport};
