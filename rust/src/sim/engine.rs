//! The discrete-event core: tasks, serial resources, dependency-driven
//! list scheduling with an event heap.
//!
//! Each task occupies exactly one resource for `duration` cycles and may
//! depend on any set of earlier tasks. A task starts at
//! `max(max(dep.finish), resource.free)`; the engine processes a ready
//! heap ordered by earliest feasible start, which for serial resources is
//! equivalent to full event-driven simulation.

#![forbid(unsafe_code)]

use std::collections::BinaryHeap;

use anyhow::{ensure, Result};

use crate::memory::Level;
use crate::soc::ComputeUnit;

/// A serial hardware resource.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Resource {
    /// A compute unit (cluster or NPU).
    Unit(ComputeUnit),
    /// The DMA channel whose outer endpoint is this level
    /// (`L2` = cluster DMA, `L3` = IO DMA).
    Dma(Level),
}

impl Resource {
    /// All resources of a SoC (NPU slot exists even if unused).
    pub const ALL: [Resource; 4] = [
        Resource::Unit(ComputeUnit::Cluster),
        Resource::Unit(ComputeUnit::Npu),
        Resource::Dma(Level::L2),
        Resource::Dma(Level::L3),
    ];

    fn index(self) -> usize {
        match self {
            Resource::Unit(ComputeUnit::Cluster) => 0,
            Resource::Unit(ComputeUnit::Npu) => 1,
            Resource::Dma(Level::L2) => 2,
            Resource::Dma(Level::L3) => 3,
            Resource::Dma(Level::L1) => unreachable!("no DMA channel terminates at L1's inner side"),
        }
    }
}

/// Handle to a submitted task.
pub type TaskId = usize;

/// A task to simulate.
#[derive(Debug, Clone)]
pub struct TaskSpec {
    /// Resource it occupies.
    pub resource: Resource,
    /// Busy cycles.
    pub duration: u64,
    /// Task ids that must finish first.
    pub deps: Vec<TaskId>,
}

#[derive(Debug, Clone, Copy)]
struct Done {
    finish: u64,
}

/// Dependency-driven event engine.
#[derive(Debug, Default)]
pub struct Engine {
    tasks: Vec<TaskSpec>,
}

/// Simulation result.
#[derive(Debug, Clone)]
pub struct RunResult {
    /// Finish time of every task.
    pub finish: Vec<u64>,
    /// Start time of every task.
    pub start: Vec<u64>,
    /// Makespan (max finish).
    pub makespan: u64,
    /// Busy cycles per resource (indexed like `Resource::ALL`).
    pub busy: [u64; 4],
}

impl RunResult {
    /// Busy cycles of one resource.
    pub fn busy_of(&self, r: Resource) -> u64 {
        self.busy[r.index()]
    }

    /// Utilisation (busy / makespan) of one resource.
    pub fn utilisation(&self, r: Resource) -> f64 {
        if self.makespan == 0 {
            0.0
        } else {
            self.busy_of(r) as f64 / self.makespan as f64
        }
    }
}

impl Engine {
    /// Fresh engine.
    pub fn new() -> Self {
        Self::default()
    }

    /// Submit a task; returns its id. Dependencies must already exist
    /// (task graph is a DAG by construction).
    pub fn submit(&mut self, spec: TaskSpec) -> TaskId {
        debug_assert!(spec.deps.iter().all(|&d| d < self.tasks.len()), "deps must be earlier tasks");
        self.tasks.push(spec);
        self.tasks.len() - 1
    }

    /// Number of submitted tasks.
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    /// True if no tasks were submitted.
    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Run the event simulation.
    pub fn run(&self) -> Result<RunResult> {
        let n = self.tasks.len();
        let mut indeg = vec![0usize; n];
        let mut dependents: Vec<Vec<TaskId>> = vec![Vec::new(); n];
        for (i, t) in self.tasks.iter().enumerate() {
            indeg[i] = t.deps.len();
            for &d in &t.deps {
                ensure!(d < i, "task {i} depends on later/self task {d}");
                dependents[d].push(i);
            }
        }

        // Ready heap: (Reverse(earliest_start), task). Earliest start =
        // max over dep finishes; actual start also waits for the resource.
        let mut ready: BinaryHeap<std::cmp::Reverse<(u64, usize)>> = BinaryHeap::new();
        let mut earliest = vec![0u64; n];
        for i in 0..n {
            if indeg[i] == 0 {
                ready.push(std::cmp::Reverse((0, i)));
            }
        }

        let mut res_free = [0u64; 4];
        let mut busy = [0u64; 4];
        let mut done: Vec<Option<Done>> = vec![None; n];
        let mut start = vec![0u64; n];
        let mut completed = 0usize;

        while let Some(std::cmp::Reverse((est, i))) = ready.pop() {
            let t = &self.tasks[i];
            let r = t.resource.index();
            let s = est.max(res_free[r]);
            let f = s + t.duration;
            res_free[r] = f;
            busy[r] += t.duration;
            start[i] = s;
            done[i] = Some(Done { finish: f });
            completed += 1;
            for &dep in &dependents[i] {
                earliest[dep] = earliest[dep].max(f);
                indeg[dep] -= 1;
                if indeg[dep] == 0 {
                    ready.push(std::cmp::Reverse((earliest[dep], dep)));
                }
            }
        }
        ensure!(completed == n, "dependency cycle: only {completed}/{n} tasks ran");

        let finish: Vec<u64> = done.into_iter().map(|d| d.unwrap().finish).collect();
        let makespan = finish.iter().copied().max().unwrap_or(0);
        Ok(RunResult { finish, start, makespan, busy })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CL: Resource = Resource::Unit(ComputeUnit::Cluster);
    const NPU: Resource = Resource::Unit(ComputeUnit::Npu);
    const DMA: Resource = Resource::Dma(Level::L2);

    #[test]
    fn serial_on_same_resource() {
        let mut e = Engine::new();
        e.submit(TaskSpec { resource: CL, duration: 10, deps: vec![] });
        e.submit(TaskSpec { resource: CL, duration: 5, deps: vec![] });
        let r = e.run().unwrap();
        assert_eq!(r.makespan, 15);
        assert_eq!(r.busy_of(CL), 15);
    }

    #[test]
    fn parallel_on_different_resources() {
        let mut e = Engine::new();
        e.submit(TaskSpec { resource: CL, duration: 10, deps: vec![] });
        e.submit(TaskSpec { resource: NPU, duration: 7, deps: vec![] });
        let r = e.run().unwrap();
        assert_eq!(r.makespan, 10);
        assert!((r.utilisation(NPU) - 0.7).abs() < 1e-12);
    }

    #[test]
    fn dependency_chain() {
        let mut e = Engine::new();
        let a = e.submit(TaskSpec { resource: DMA, duration: 4, deps: vec![] });
        let b = e.submit(TaskSpec { resource: CL, duration: 6, deps: vec![a] });
        let c = e.submit(TaskSpec { resource: DMA, duration: 3, deps: vec![b] });
        let r = e.run().unwrap();
        assert_eq!(r.start[b], 4);
        assert_eq!(r.finish[c], 13);
    }

    #[test]
    fn pipeline_overlap() {
        // Classic double-buffer pipeline: dma(i) overlaps kernel(i-1).
        let mut e = Engine::new();
        let mut prev_kernel: Option<TaskId> = None;
        let mut last = 0;
        for _ in 0..4 {
            let mut deps = vec![];
            if let Some(k) = prev_kernel {
                // Keep ping/pong ordering: dma i can start while kernel
                // i−1 runs, so dma depends only on the kernel two steps
                // back (not modelled here: 4 steps, no conflict).
                let _ = k;
            }
            let d = e.submit(TaskSpec { resource: DMA, duration: 10, deps: std::mem::take(&mut deps) });
            let k = e.submit(TaskSpec { resource: CL, duration: 10, deps: vec![d] });
            prev_kernel = Some(k);
            last = k;
        }
        let r = e.run().unwrap();
        // DMA is the serial bottleneck: 4×10, last kernel finishes +10.
        assert_eq!(r.finish[last], 50);
    }

    #[test]
    fn cycle_detected_via_debug_assert_or_error() {
        // deps must reference earlier ids; a forward dep is a builder bug
        // caught by run()'s ensure.
        let e = Engine { tasks: vec![TaskSpec { resource: CL, duration: 1, deps: vec![0] }] };
        assert!(e.run().is_err());
    }

    #[test]
    fn empty_engine() {
        let e = Engine::new();
        let r = e.run().unwrap();
        assert_eq!(r.makespan, 0);
        assert!(e.is_empty());
    }
}
