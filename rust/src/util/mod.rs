//! Small in-crate utilities.
//!
//! This workspace builds fully offline; instead of pulling `serde_json`,
//! [`json`] provides a compact JSON value model with a strict parser and a
//! pretty printer — enough for the network interchange format, deploy
//! configs, and machine-readable reports. [`prop`] is a tiny
//! property-testing harness (xorshift PRNG + shrink-free case generation)
//! used by the test suite in place of `proptest`.

#![forbid(unsafe_code)]

pub mod bench;
pub mod bincode;
pub mod json;
pub mod prop;
