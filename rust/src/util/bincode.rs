//! Minimal length-prefixed binary (de)serialisation — the `ftl-bin-v1`
//! wire under the snapshot segment format ([`crate::serve::persist`]).
//!
//! The same offline constraint that produced [`super::json`] applies
//! here: no `serde`/`bincode` crates, so this module hand-rolls the two
//! primitives every compact codec needs — **LEB128 varints** for
//! unsigned integers (one byte for values < 128, which covers almost
//! every length, index and dimension in a plan) and **length-prefixed
//! byte strings**. Everything else is built from those:
//!
//! * `bool` — one byte (`0`/`1`, any other value is corruption)
//! * `u64`/`usize` — varint
//! * `u128` — fixed 16 bytes little-endian (fingerprints, checksums)
//! * `f64`/`f32` — IEEE-754 bits, fixed-width little-endian (bit-exact
//!   round-trip; the JSON codec's float printing is shortest-roundtrip,
//!   so both codecs preserve values exactly)
//! * `str` — varint byte length + UTF-8 bytes
//! * `Option<T>` — presence byte + value
//! * sequences — varint count + elements ([`BinWriter::seq`] /
//!   [`BinReader::seq`])
//!
//! Decoding is **total**: every read returns `Result`, truncated input
//! or a malformed varint is an error, never a panic — the snapshot
//! loader turns any decode error into a counted skip. Sequence counts
//! are validated against the remaining input length before allocating,
//! so a corrupted count cannot balloon memory.

#![forbid(unsafe_code)]

use anyhow::{bail, Result};

/// Append-only binary encoder (see module docs for the wire forms).
#[derive(Debug, Default)]
pub struct BinWriter {
    buf: Vec<u8>,
}

impl BinWriter {
    /// Fresh empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// The encoded bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Bytes written so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when nothing has been written.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// One raw byte.
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// One presence/flag byte.
    pub fn bool(&mut self, v: bool) {
        self.buf.push(u8::from(v));
    }

    /// Unsigned LEB128 varint (7 bits per byte, high bit = continuation).
    pub fn u64(&mut self, mut v: u64) {
        loop {
            let byte = (v & 0x7f) as u8;
            v >>= 7;
            if v == 0 {
                self.buf.push(byte);
                return;
            }
            self.buf.push(byte | 0x80);
        }
    }

    /// `usize` as a varint.
    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    /// Fixed 16-byte little-endian `u128` (fingerprints/checksums — the
    /// fixed width keeps them greppable in hexdumps).
    pub fn u128(&mut self, v: u128) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// IEEE-754 bits, fixed 8 bytes little-endian.
    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }

    /// IEEE-754 bits, fixed 4 bytes little-endian.
    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }

    /// Raw bytes with **no** length prefix — for fixed-width file magics
    /// whose length is part of the format, not the data.
    pub fn raw(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }

    /// Length-prefixed raw bytes.
    pub fn bytes(&mut self, v: &[u8]) {
        self.usize(v.len());
        self.buf.extend_from_slice(v);
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }

    /// Presence byte + value.
    pub fn opt<T>(&mut self, v: Option<&T>, f: impl FnOnce(&mut Self, &T)) {
        match v {
            None => self.bool(false),
            Some(x) => {
                self.bool(true);
                f(self, x);
            }
        }
    }

    /// Varint count + elements.
    pub fn seq<T>(&mut self, items: &[T], mut f: impl FnMut(&mut Self, &T)) {
        self.usize(items.len());
        for item in items {
            f(self, item);
        }
    }

    /// Varint count + varint elements (the common `Vec<usize>` case).
    pub fn usize_seq(&mut self, items: &[usize]) {
        self.seq(items, |w, &v| w.usize(v));
    }
}

/// Cursor-based binary decoder over a byte slice. Every read validates
/// the remaining input; errors are `anyhow` (the snapshot loader maps
/// them to counted skips).
#[derive(Debug)]
pub struct BinReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> BinReader<'a> {
    /// Reader over `buf`, cursor at the start.
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Current cursor offset.
    pub fn position(&self) -> usize {
        self.pos
    }

    /// True when the whole input has been consumed (strict decoders
    /// check this to reject trailing garbage).
    pub fn is_done(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!("binary input truncated: wanted {n} bytes, {} remain", self.remaining());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// One raw byte.
    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// One flag byte; anything but `0`/`1` is corruption.
    pub fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => bail!("bad bool byte {b:#04x}"),
        }
    }

    /// Unsigned LEB128 varint (at most 10 bytes for a `u64`).
    pub fn u64(&mut self) -> Result<u64> {
        let mut v: u64 = 0;
        for shift in (0..64).step_by(7) {
            let byte = self.u8()?;
            let bits = u64::from(byte & 0x7f);
            if shift == 63 && bits > 1 {
                bail!("varint overflows u64");
            }
            v |= bits << shift;
            if byte & 0x80 == 0 {
                return Ok(v);
            }
        }
        bail!("varint longer than 10 bytes")
    }

    /// Varint as `usize`.
    pub fn usize(&mut self) -> Result<usize> {
        usize::try_from(self.u64()?).map_err(|_| anyhow::anyhow!("varint overflows usize"))
    }

    /// Fixed 16-byte little-endian `u128`.
    pub fn u128(&mut self) -> Result<u128> {
        let b: [u8; 16] = self.take(16)?.try_into().expect("take(16) returns 16 bytes");
        Ok(u128::from_le_bytes(b))
    }

    /// Fixed 8-byte IEEE-754 bits.
    pub fn f64(&mut self) -> Result<f64> {
        let b: [u8; 8] = self.take(8)?.try_into().expect("take(8) returns 8 bytes");
        Ok(f64::from_bits(u64::from_le_bytes(b)))
    }

    /// Fixed 4-byte IEEE-754 bits.
    pub fn f32(&mut self) -> Result<f32> {
        let b: [u8; 4] = self.take(4)?.try_into().expect("take(4) returns 4 bytes");
        Ok(f32::from_bits(u32::from_le_bytes(b)))
    }

    /// Length-prefixed raw bytes (borrowed from the input).
    pub fn bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.usize()?;
        self.take(n)
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self) -> Result<String> {
        let b = self.bytes()?;
        Ok(std::str::from_utf8(b).map_err(|_| anyhow::anyhow!("string is not UTF-8"))?.to_string())
    }

    /// Presence byte + value.
    pub fn opt<T>(&mut self, f: impl FnOnce(&mut Self) -> Result<T>) -> Result<Option<T>> {
        if self.bool()? {
            Ok(Some(f(self)?))
        } else {
            Ok(None)
        }
    }

    /// Varint count + elements. The count is bounded by the remaining
    /// input (every element is at least one byte), so a corrupted count
    /// errors instead of triggering a huge allocation.
    pub fn seq<T>(&mut self, mut f: impl FnMut(&mut Self) -> Result<T>) -> Result<Vec<T>> {
        let n = self.usize()?;
        if n > self.remaining() {
            bail!("sequence count {n} exceeds {} remaining bytes", self.remaining());
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(f(self)?);
        }
        Ok(out)
    }

    /// Varint count + varint elements.
    pub fn usize_seq(&mut self) -> Result<Vec<usize>> {
        self.seq(|r| r.usize())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_round_trip() {
        let mut w = BinWriter::new();
        w.u8(7);
        w.bool(true);
        w.bool(false);
        w.u64(0);
        w.u64(127);
        w.u64(128);
        w.u64(u64::MAX);
        w.u128(0xdead_beef_dead_beef_dead_beef_dead_beef);
        w.f64(-0.125);
        w.f32(1e-5);
        w.str("tile φ");
        w.opt(Some(&42usize), |w, &v| w.usize(v));
        w.opt::<usize>(None, |w, &v| w.usize(v));
        w.usize_seq(&[1, 2, 300]);
        let bytes = w.into_bytes();
        let mut r = BinReader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert!(r.bool().unwrap());
        assert!(!r.bool().unwrap());
        assert_eq!(r.u64().unwrap(), 0);
        assert_eq!(r.u64().unwrap(), 127);
        assert_eq!(r.u64().unwrap(), 128);
        assert_eq!(r.u64().unwrap(), u64::MAX);
        assert_eq!(r.u128().unwrap(), 0xdead_beef_dead_beef_dead_beef_dead_beef);
        assert_eq!(r.f64().unwrap(), -0.125);
        assert_eq!(r.f32().unwrap(), 1e-5);
        assert_eq!(r.str().unwrap(), "tile φ");
        assert_eq!(r.opt(|r| r.usize()).unwrap(), Some(42));
        assert_eq!(r.opt(|r| r.usize()).unwrap(), None);
        assert_eq!(r.usize_seq().unwrap(), vec![1, 2, 300]);
        assert!(r.is_done());
    }

    #[test]
    fn varint_boundaries_are_minimal_and_exact() {
        for (v, len) in [(0u64, 1), (127, 1), (128, 2), (16383, 2), (16384, 3), (u64::MAX, 10)] {
            let mut w = BinWriter::new();
            w.u64(v);
            let bytes = w.into_bytes();
            assert_eq!(bytes.len(), len, "varint({v}) must be {len} bytes");
            assert_eq!(BinReader::new(&bytes).u64().unwrap(), v);
        }
    }

    #[test]
    fn truncated_input_errors_instead_of_panicking() {
        let mut w = BinWriter::new();
        w.str("snapshot");
        let bytes = w.into_bytes();
        for cut in 0..bytes.len() {
            let mut r = BinReader::new(&bytes[..cut]);
            assert!(r.str().is_err(), "truncation at {cut} must be a decode error");
        }
    }

    #[test]
    fn corrupt_counts_and_flags_error() {
        // A sequence count far beyond the remaining bytes must be
        // rejected before allocation.
        let mut w = BinWriter::new();
        w.u64(1 << 40);
        let bytes = w.into_bytes();
        assert!(BinReader::new(&bytes).seq(|r| r.u8()).is_err());
        // A bool byte outside {0,1} is corruption, not "truthy".
        assert!(BinReader::new(&[2]).bool().is_err());
        // An 11-byte varint is malformed.
        let long = [0x80u8; 11];
        assert!(BinReader::new(&long).u64().is_err());
    }

    #[test]
    fn floats_round_trip_bit_exactly() {
        for v in [0.0f64, -0.0, 1.5e-300, f64::MAX, f64::MIN_POSITIVE] {
            let mut w = BinWriter::new();
            w.f64(v);
            let b = w.into_bytes();
            assert_eq!(BinReader::new(&b).f64().unwrap().to_bits(), v.to_bits());
        }
        let mut w = BinWriter::new();
        w.f64(f64::NAN);
        let b = w.into_bytes();
        assert!(BinReader::new(&b).f64().unwrap().is_nan());
    }
}
