//! Minimal JSON: value model, strict recursive-descent parser, printer.
//!
//! Supports the full JSON grammar (RFC 8259) except that numbers are held
//! as `f64` (integers round-trip exactly up to 2⁵³ — far beyond any tensor
//! dimension or cycle count we serialise).

#![forbid(unsafe_code)]

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number.
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (sorted keys for deterministic output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// String value helper.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Integer helper.
    pub fn int(v: impl TryInto<i64>) -> Json {
        Json::Num(v.try_into().map_err(|_| ()).expect("int out of range") as f64)
    }

    /// Field access on objects.
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing field '{key}'")),
            _ => bail!("expected object while reading '{key}'"),
        }
    }

    /// Optional field access.
    pub fn get_opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As string.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {}", other.kind()),
        }
    }

    /// As f64.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {}", other.kind()),
        }
    }

    /// As usize (must be a non-negative integer).
    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 || n > 2f64.powi(53) {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    /// As u64 (must be a non-negative integer ≤ 2⁵³).
    pub fn as_u64(&self) -> Result<u64> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 || n > 2f64.powi(53) {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as u64)
    }

    /// As a vector of non-negative integers.
    pub fn as_usize_arr(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(Json::as_usize).collect()
    }

    /// Integer-array builder (the codec layer's shape/id lists).
    pub fn ints(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&v| Json::int(v)).collect())
    }

    /// As bool.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {}", other.kind()),
        }
    }

    /// As array.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => bail!("expected array, got {}", other.kind()),
        }
    }

    fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    /// Pretty serialisation (2-space indent).
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => ("\n".to_string(), " ".repeat(w * depth), " ".repeat(w * (depth + 1))),
            None => (String::new(), String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(&nl);
                    out.push_str(&pad_in);
                    v.write(out, indent, depth + 1);
                }
                out.push_str(&nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(&nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(&nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

/// Compact serialisation (`to_string()` via the blanket `ToString`).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse JSON text.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", b as char, self.pos)
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => bail!("unexpected character at byte {}", self.pos),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => bail!("bad escape at byte {}", self.pos),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for t in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = parse(t).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn nested_structures() {
        let v = parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let back = parse(&v.to_string()).unwrap();
        assert_eq!(back, v);
        let back2 = parse(&v.pretty()).unwrap();
        assert_eq!(back2, v);
    }

    #[test]
    fn integers_exact() {
        let v = parse("1234567890123").unwrap();
        assert_eq!(v.as_usize().unwrap(), 1234567890123);
        assert_eq!(v.to_string(), "1234567890123");
    }

    #[test]
    fn errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        assert!(Json::Num(1.5).as_usize().is_err());
        assert!(Json::Null.get("x").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse(r#""A\t\"ü""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "A\t\"ü");
        let s = Json::str("a\"b\\c\nd");
        assert_eq!(parse(&s.to_string()).unwrap(), s);
    }

    #[test]
    fn control_characters_always_escaped() {
        // Regression: every control character below U+0020 must come out
        // as a valid JSON escape (`\u00XX` or a short form), never raw —
        // a raw 0x01 in a protocol response or persisted snapshot is
        // invalid JSON and corrupts the whole document.
        for cp in 0u32..0x20 {
            let c = char::from_u32(cp).unwrap();
            let s = Json::str(format!("a{c}b"));
            let text = s.to_string();
            assert!(text.bytes().all(|b| b >= 0x20), "control char U+{cp:04X} emitted raw in {text:?}");
            assert_eq!(parse(&text).unwrap(), s, "U+{cp:04X} must round-trip");
        }
        // Exact encodings: short escapes for the common ones, \u00XX else.
        assert_eq!(Json::str("\n\r\t").to_string(), "\"\\n\\r\\t\"");
        assert_eq!(Json::str("\u{1}").to_string(), "\"\\u0001\"");
        assert_eq!(Json::str("\u{8}\u{c}").to_string(), "\"\\u0008\\u000c\"");
        assert_eq!(Json::str("\u{1f}").to_string(), "\"\\u001f\"");
    }

    #[test]
    fn u64_and_usize_arrays() {
        let v = parse("[3,1,2]").unwrap();
        assert_eq!(v.as_usize_arr().unwrap(), vec![3, 1, 2]);
        assert_eq!(Json::ints(&[3, 1, 2]).to_string(), "[3,1,2]");
        assert_eq!(parse("42").unwrap().as_u64().unwrap(), 42);
        assert!(parse("-1").unwrap().as_u64().is_err());
        assert!(parse("1.5").unwrap().as_u64().is_err());
        assert!(parse("[1,true]").unwrap().as_usize_arr().is_err());
    }

    #[test]
    fn obj_builder() {
        let v = Json::obj(vec![("x", Json::int(3usize)), ("y", Json::str("z"))]);
        assert_eq!(v.to_string(), r#"{"x":3,"y":"z"}"#);
        assert!(v.get_opt("nope").is_none());
    }
}
