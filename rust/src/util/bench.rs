//! Micro-benchmark harness (in place of `criterion`, offline).
//!
//! Plain wall-clock timing with warmup, N samples, and a criterion-style
//! one-line summary (median ± IQR). Bench binaries are `harness = false`
//! and call [`bench`] directly; `cargo bench` runs them all.

#![forbid(unsafe_code)]

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Median sample time.
    pub median: Duration,
    /// 25th percentile.
    pub p25: Duration,
    /// 75th percentile.
    pub p75: Duration,
    /// Samples taken.
    pub samples: usize,
}

impl BenchResult {
    /// Criterion-style one-liner.
    pub fn line(&self) -> String {
        format!(
            "{:<44} time: [{} {} {}]  ({} samples)",
            self.name,
            fmt_dur(self.p25),
            fmt_dur(self.median),
            fmt_dur(self.p75),
            self.samples
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.2} s", ns as f64 / 1e9)
    }
}

/// Run `f` with warmup and sampling; prints and returns the result.
///
/// `target_time` bounds total sampling wall-clock (like criterion's
/// measurement_time); at least 10 samples are always taken.
pub fn bench(name: &str, target_time: Duration, mut f: impl FnMut()) -> BenchResult {
    // Warmup: run until 10% of target or 3 iterations.
    let warm_start = Instant::now();
    let mut warm_iters = 0u32;
    while warm_iters < 3 || warm_start.elapsed() < target_time / 10 {
        f();
        warm_iters += 1;
        if warm_iters > 1000 {
            break;
        }
    }

    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < 10 || (start.elapsed() < target_time && samples.len() < 200) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort();
    let result = BenchResult {
        name: name.to_string(),
        median: samples[samples.len() / 2],
        p25: samples[samples.len() / 4],
        p75: samples[samples.len() * 3 / 4],
        samples: samples.len(),
    };
    println!("{}", result.line());
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut count = 0u64;
        let r = bench("noop", Duration::from_millis(20), || {
            count += 1;
        });
        assert!(r.samples >= 10);
        assert!(count as usize >= r.samples);
        assert!(r.p25 <= r.median && r.median <= r.p75);
        assert!(r.line().contains("noop"));
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500 ns");
        assert!(fmt_dur(Duration::from_micros(5)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains("s"));
    }
}
