//! Tiny property-testing harness (in place of `proptest`, offline).
//!
//! Deterministic xorshift64* PRNG + a `cases` driver: run a closure over N
//! seeded cases and report the failing seed so a failure reproduces with
//! `Rng::new(seed)`.

#![forbid(unsafe_code)]

/// xorshift64* PRNG — deterministic, seedable, no dependencies.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    /// Seeded generator (seed 0 is remapped — xorshift state must be ≠ 0).
    pub fn new(seed: u64) -> Self {
        Rng(if seed == 0 { 0x9E3779B97F4A7C15 } else { seed })
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + (self.next_u64() as usize) % (hi - lo + 1)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Bernoulli.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len() - 1)]
    }
}

/// Run `f` over `n` seeded cases; panics with the seed on failure.
pub fn cases(n: u64, f: impl Fn(&mut Rng)) {
    for seed in 1..=n {
        let mut rng = Rng::new(seed.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed at case {seed}/{n}");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.range(5, 9);
            assert!((5..=9).contains(&v));
        }
        assert_eq!(r.range(4, 4), 4);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn cases_runs_all() {
        let mut count = 0u64;
        // `cases` takes Fn, so count via a Cell.
        let cell = std::cell::Cell::new(0u64);
        cases(25, |_| cell.set(cell.get() + 1));
        count += cell.get();
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "always fails")]
    fn cases_propagates_failure() {
        cases(10, |_| panic!("always fails"));
    }
}
