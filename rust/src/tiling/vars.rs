//! Step ① — variable attribution.
//!
//! Every tensor dimension of every operator in a (possibly fused) group is
//! given a *tile-size variable*. A variable's domain is `1..=full` where
//! `full` is the dimension's extent; the solver assigns each variable the
//! tile size used in L1.

#![forbid(unsafe_code)]


/// Handle to a [`DimVar`] inside a [`VarTable`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VarId(pub usize);

/// One tile-size variable.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DimVar {
    /// Debug name, e.g. `"fc1.M"`.
    pub name: String,
    /// Full extent of the dimension.
    pub full: usize,
}

/// Arena of variables for one tiling problem.
#[derive(Debug, Clone, Default)]
pub struct VarTable {
    vars: Vec<DimVar>,
}

impl VarTable {
    /// Empty table.
    pub fn new() -> Self {
        Self::default()
    }

    /// Create a fresh variable.
    pub fn fresh(&mut self, name: impl Into<String>, full: usize) -> VarId {
        assert!(full > 0, "dimension extent must be positive");
        self.vars.push(DimVar { name: name.into(), full });
        VarId(self.vars.len() - 1)
    }

    /// Look up a variable.
    pub fn get(&self, id: VarId) -> &DimVar {
        &self.vars[id.0]
    }

    /// Number of variables.
    pub fn len(&self) -> usize {
        self.vars.len()
    }

    /// True if no variables were attributed yet.
    pub fn is_empty(&self) -> bool {
        self.vars.is_empty()
    }

    /// Iterate over `(id, var)`.
    pub fn iter(&self) -> impl Iterator<Item = (VarId, &DimVar)> {
        self.vars.iter().enumerate().map(|(i, v)| (VarId(i), v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_and_get() {
        let mut t = VarTable::new();
        let m = t.fresh("fc1.M", 197);
        let n = t.fresh("fc1.N", 3072);
        assert_eq!(t.get(m).full, 197);
        assert_eq!(t.get(n).name, "fc1.N");
        assert_eq!(t.len(), 2);
        assert!(!t.is_empty());
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_extent_panics() {
        VarTable::new().fresh("bad", 0);
    }

    #[test]
    fn iter_order() {
        let mut t = VarTable::new();
        let ids: Vec<VarId> = (0..5).map(|i| t.fresh(format!("v{i}"), i + 1)).collect();
        let seen: Vec<VarId> = t.iter().map(|(id, _)| id).collect();
        assert_eq!(ids, seen);
    }
}
