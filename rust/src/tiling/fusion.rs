//! Step ③ — fusion-group selection.
//!
//! FTL fuses *consecutive* layers: a producer and the consumer(s) of its
//! output tensor, chained while the policy allows. The shared tensor's
//! dimension variables are bound during [`super::GroupProblem::build`];
//! this module only decides *which* nodes go together. If a group later
//! turns out to be unsolvable (the bound problem cannot fit L1), the
//! solver shrinks it from the tail — fusion in FTL is an optimisation, not
//! an obligation.

#![forbid(unsafe_code)]


use crate::ir::{Graph, NodeId, TensorKind};

use super::problem::Strategy;

/// A set of consecutive nodes tiled as one problem.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FusionGroup {
    /// Node ids in topological (execution) order.
    pub nodes: Vec<NodeId>,
}

impl FusionGroup {
    /// Single-node group.
    pub fn solo(n: NodeId) -> Self {
        Self { nodes: vec![n] }
    }

    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// Always false — groups are non-empty by construction.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }
}

/// Which consumers may be pulled into a producer's group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FusionPolicy {
    /// Maximum nodes per group.
    pub max_len: usize,
    /// Only chain *elementwise* consumers (the safe default: their tile
    /// dims bind 1:1 to the producer's). When false, any consumer is
    /// attempted (e.g. GEMM→GEMM) and the solver's capacity check decides.
    pub elementwise_only: bool,
}

impl Default for FusionPolicy {
    fn default() -> Self {
        Self { max_len: 4, elementwise_only: true }
    }
}

/// Partition the graph into fusion groups.
///
/// * [`Strategy::LayerPerLayer`] — every node is its own group.
/// * [`Strategy::Ftl`] — greedy maximal chains: extend a group while the
///   tail node's output has a *single* consumer, is not a graph output,
///   and the consumer satisfies the policy.
pub fn fuse_groups(graph: &Graph, strategy: Strategy, policy: FusionPolicy) -> Vec<FusionGroup> {
    match strategy {
        Strategy::LayerPerLayer => (0..graph.nodes.len()).map(FusionGroup::solo).collect(),
        Strategy::Ftl => {
            let consumers = graph.consumers();
            let mut groups: Vec<FusionGroup> = Vec::new();
            let mut taken = vec![false; graph.nodes.len()];
            for start in 0..graph.nodes.len() {
                if taken[start] {
                    continue;
                }
                let mut group = FusionGroup::solo(start);
                taken[start] = true;
                let mut tail = start;
                while group.len() < policy.max_len {
                    let out = graph.nodes[tail].output;
                    if graph.tensors[out].kind == TensorKind::Output {
                        break;
                    }
                    let cons = &consumers[out];
                    if cons.len() != 1 {
                        break;
                    }
                    let next = cons[0];
                    if taken[next] {
                        break;
                    }
                    // The consumer must directly follow in topo order *as a
                    // chain*: all its other inputs must come from outside
                    // the not-yet-executed region (they do, since the graph
                    // is topologically ordered and produced tensors are
                    // either in-group or earlier).
                    if policy.elementwise_only && !graph.nodes[next].op.is_elementwise() {
                        break;
                    }
                    group.nodes.push(next);
                    taken[next] = true;
                    tail = next;
                }
                groups.push(group);
            }
            groups
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::{deep_mlp, vit_mlp, vit_mlp_block};
    use crate::ir::DType;

    #[test]
    fn layer_per_layer_is_solo() {
        let g = vit_mlp(197, 768, 3072, DType::Int8);
        let groups = fuse_groups(&g, Strategy::LayerPerLayer, FusionPolicy::default());
        assert_eq!(groups.len(), 3);
        assert!(groups.iter().all(|gr| gr.len() == 1));
    }

    #[test]
    fn ftl_fuses_gemm_gelu() {
        let g = vit_mlp(197, 768, 3072, DType::Int8);
        let groups = fuse_groups(&g, Strategy::Ftl, FusionPolicy::default());
        // {fc1, gelu}, {fc2}
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].nodes, vec![0, 1]);
        assert_eq!(groups[1].nodes, vec![2]);
    }

    #[test]
    fn aggressive_policy_chains_gemms() {
        let g = vit_mlp(197, 768, 3072, DType::Int8);
        let groups = fuse_groups(&g, Strategy::Ftl, FusionPolicy { max_len: 8, elementwise_only: false });
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].nodes, vec![0, 1, 2]);
    }

    #[test]
    fn max_len_respected() {
        let g = deep_mlp(32, 64, 4, DType::Int8); // 8 nodes: fc,act ×4
        let groups = fuse_groups(&g, Strategy::Ftl, FusionPolicy { max_len: 2, elementwise_only: true });
        assert_eq!(groups.len(), 4);
        assert!(groups.iter().all(|gr| gr.len() == 2));
    }

    #[test]
    fn multi_consumer_breaks_chain() {
        // In vit_mlp_block, x feeds both LN and the residual Add → the LN
        // group can't swallow x's consumers; Add has two inputs and fuses
        // onto fc2 only if fc2's output has a single consumer (it does).
        let g = vit_mlp_block(16, 32, 64, DType::Int8);
        let groups = fuse_groups(&g, Strategy::Ftl, FusionPolicy::default());
        // ln solo (fc1 is not elementwise), {fc1, gelu}, {fc2, add}
        assert_eq!(groups.len(), 3);
        let names: Vec<Vec<&str>> = groups
            .iter()
            .map(|gr| gr.nodes.iter().map(|&n| g.nodes[n].name.as_str()).collect())
            .collect();
        assert_eq!(names[0], vec!["ln"]);
        assert_eq!(names[1], vec!["fc1", "gelu"]);
        assert_eq!(names[2], vec!["fc2", "residual"]);
    }

    #[test]
    fn groups_cover_all_nodes_once() {
        let g = deep_mlp(16, 32, 5, DType::Int8);
        for strat in [Strategy::LayerPerLayer, Strategy::Ftl] {
            let groups = fuse_groups(&g, strat, FusionPolicy::default());
            let mut seen = vec![false; g.nodes.len()];
            for gr in &groups {
                for &n in &gr.nodes {
                    assert!(!seen[n], "node {n} appears twice");
                    seen[n] = true;
                }
            }
            assert!(seen.iter().all(|&s| s));
        }
    }
}
