//! Step ④ output — the solved tiling of each fusion group.
//!
//! A [`GroupSolution`] is self-contained: the loop nest (free variables in
//! loop order with chosen steady-state tile sizes), every L1 buffer with
//! its per-dimension affine tile expressions, and the node list. The
//! schedule generator and the PJRT tile executor both walk
//! [`GroupSolution::iterations`] to enumerate concrete (remainder-exact)
//! tiles.

#![forbid(unsafe_code)]


use anyhow::{anyhow, Result};

use crate::ir::{op_from_bin, op_from_json, op_to_bin, op_to_json, NodeId, Op, TensorId};
use crate::memory::{BufferRole, Level};
use crate::soc::ComputeUnit;
use crate::util::bincode::{BinReader, BinWriter};
use crate::util::json::Json;

/// One free tile variable, placed at a loop level.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FreeVarChoice {
    /// Debug name (from the representative dimension variable).
    pub name: String,
    /// Full extent to cover.
    pub full: usize,
    /// Chosen steady-state tile size.
    pub tile: usize,
}

impl FreeVarChoice {
    /// Number of iterations of this loop.
    pub fn trips(&self) -> usize {
        self.full.div_ceil(self.tile)
    }
}

/// Affine tile expression of one buffer dimension:
/// `tile = min(full − offset, a·t + b)` where `t` is the current extent of
/// loop `loop_idx` (`None` ⇒ fixed dim, `tile = b`), and the offset along
/// the dim is `a · loop_offset`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DimSpec {
    /// Full extent of the underlying tensor dimension.
    pub full: usize,
    /// Loop this dim follows, if any (index into the loop order).
    pub loop_idx: Option<usize>,
    /// Multiplier on the loop variable.
    pub a: usize,
    /// Offset (halo) term; for fixed dims this *is* the tile size.
    pub b: usize,
}

impl DimSpec {
    /// Concrete (offset, extent) of this dim at the given loop state.
    /// `state[l] = (offset, cur_tile)` for loop `l`.
    pub fn at(&self, state: &[(usize, usize)]) -> (usize, usize) {
        match self.loop_idx {
            None => (0, self.b.min(self.full)),
            Some(l) => {
                let (off, cur) = state[l];
                let o = (self.a * off).min(self.full.saturating_sub(1));
                let t = (self.a * cur + self.b).min(self.full - o);
                (o, t)
            }
        }
    }

    /// Steady-state tile extent (no remainder clamping).
    pub fn steady(&self, loops: &[FreeVarChoice]) -> usize {
        match self.loop_idx {
            None => self.b.min(self.full),
            Some(l) => (self.a * loops[l].tile + self.b).min(self.full),
        }
    }
}

/// One L1 tile buffer of a group.
#[derive(Debug, Clone, PartialEq)]
pub struct GroupBuffer {
    /// Backing tensor.
    pub tensor: TensorId,
    /// Tensor name (for reports).
    pub name: String,
    /// Role in L1 (decides streaming/double-buffering).
    pub role: BufferRole,
    /// Element size in bytes.
    pub elem_bytes: usize,
    /// Per-dimension tile expressions.
    pub dims: Vec<DimSpec>,
    /// Home memory level of the tensor (`None` for fused intermediates
    /// that exist only in L1).
    pub home: Option<Level>,
    /// Re-fetched every iteration of loops `0..fetch_depth`
    /// (`0` ⇒ fetched once before the nest).
    pub fetch_depth: usize,
}

impl GroupBuffer {
    /// Steady-state tile bytes.
    pub fn steady_bytes(&self, loops: &[FreeVarChoice]) -> usize {
        self.dims.iter().map(|d| d.steady(loops)).product::<usize>() * self.elem_bytes
    }

    /// Concrete tile shape at a loop state.
    pub fn shape_at(&self, state: &[(usize, usize)]) -> Vec<usize> {
        self.dims.iter().map(|d| d.at(state).1).collect()
    }

    /// Concrete element offsets at a loop state.
    pub fn offsets_at(&self, state: &[(usize, usize)]) -> Vec<usize> {
        self.dims.iter().map(|d| d.at(state).0).collect()
    }

    /// Number of times this buffer is (re-)fetched over the whole nest.
    pub fn trips(&self, loops: &[FreeVarChoice]) -> usize {
        loops[..self.fetch_depth].iter().map(FreeVarChoice::trips).product()
    }

    /// True if this buffer is moved by DMA at all.
    pub fn is_streamed(&self) -> bool {
        self.home.is_some()
    }
}

/// One node of the group with its kernel placement.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeTile {
    /// Graph node id.
    pub node: NodeId,
    /// Node name.
    pub name: String,
    /// Operator (copied out of the graph for self-containedness).
    pub op: Op,
    /// Compute unit the kernel runs on.
    pub unit: ComputeUnit,
    /// Indices into [`GroupSolution::buffers`] for the inputs, in op order.
    pub input_bufs: Vec<usize>,
    /// Index of the output buffer.
    pub output_buf: usize,
}

/// Solved tiling for one fusion group.
#[derive(Debug, Clone, PartialEq)]
pub struct GroupSolution {
    /// Nodes in execution order.
    pub nodes: Vec<NodeTile>,
    /// Loop nest, outermost first.
    pub loops: Vec<FreeVarChoice>,
    /// All L1 buffers (deduplicated per tensor).
    pub buffers: Vec<GroupBuffer>,
    /// Steady-state L1 footprint in bytes (with double-buffer copies as
    /// solved).
    pub footprint: usize,
    /// Whether streamed buffers are double-buffered.
    pub double_buffered: bool,
    /// Analytic runtime estimate used as the solver objective.
    pub estimated_cycles: u64,
}

impl GroupSolution {
    /// Total tile iterations of the nest.
    pub fn total_iterations(&self) -> usize {
        self.loops.iter().map(FreeVarChoice::trips).product()
    }

    /// Enumerate the loop nest: yields, for every iteration, the loop
    /// state `[(offset, cur_tile); n_loops]` in row-major (outer-first)
    /// order, plus the multi-index.
    pub fn iterations(&self) -> Vec<Vec<(usize, usize)>> {
        let mut states = vec![Vec::new()];
        for l in &self.loops {
            let mut next = Vec::with_capacity(states.len() * l.trips());
            for s in &states {
                let mut off = 0;
                while off < l.full {
                    let cur = l.tile.min(l.full - off);
                    let mut s2 = s.clone();
                    s2.push((off, cur));
                    next.push(s2);
                    off += l.tile;
                }
            }
            states = next;
        }
        states
    }

    /// Which loops advanced between consecutive iterations `i-1` and `i`
    /// (outermost changed level); iteration 0 returns 0 (everything fresh).
    pub fn changed_depth(&self, prev: Option<&[(usize, usize)]>, cur: &[(usize, usize)]) -> usize {
        match prev {
            None => 0,
            Some(p) => {
                for (l, (a, b)) in p.iter().zip(cur).enumerate() {
                    if a != b {
                        return l;
                    }
                }
                cur.len()
            }
        }
    }
}

/// The full-graph solution.
#[derive(Debug, Clone, PartialEq)]
pub struct TilingSolution {
    /// Per-group solutions, in execution order.
    pub groups: Vec<GroupSolution>,
}

impl TilingSolution {
    /// Sum of analytic estimates (used for solver regression tests; the
    /// simulator provides the real number).
    pub fn estimated_cycles(&self) -> u64 {
        self.groups.iter().map(|g| g.estimated_cycles).sum()
    }

    /// Max L1 footprint over groups.
    pub fn peak_l1(&self) -> usize {
        self.groups.iter().map(|g| g.footprint).max().unwrap_or(0)
    }

    /// Canonical JSON encoding (the snapshot codec — see
    /// [`crate::serve::persist`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![("groups", Json::Arr(self.groups.iter().map(GroupSolution::to_json).collect()))])
    }

    /// Decode the canonical JSON encoding.
    pub fn from_json(v: &Json) -> Result<Self> {
        Ok(Self { groups: v.get("groups")?.as_arr()?.iter().map(GroupSolution::from_json).collect::<Result<_>>()? })
    }

    /// Canonical binary encoding (`ftl-bin-v1`).
    pub fn to_bin(&self, w: &mut BinWriter) {
        w.seq(&self.groups, |w, g| g.to_bin(w));
    }

    /// Decode the canonical binary encoding.
    pub fn from_bin(r: &mut BinReader) -> Result<Self> {
        Ok(Self { groups: r.seq(GroupSolution::from_bin)? })
    }
}

// ---------------------------------------------------------- snapshot codec

impl FreeVarChoice {
    /// Canonical JSON encoding.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("full", Json::int(self.full)),
            ("tile", Json::int(self.tile)),
        ])
    }

    /// Decode the canonical JSON encoding.
    pub fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            name: v.get("name")?.as_str()?.to_string(),
            full: v.get("full")?.as_usize()?,
            tile: v.get("tile")?.as_usize()?,
        })
    }

    /// Canonical binary encoding (`ftl-bin-v1`).
    pub fn to_bin(&self, w: &mut BinWriter) {
        w.str(&self.name);
        w.usize(self.full);
        w.usize(self.tile);
    }

    /// Decode the canonical binary encoding.
    pub fn from_bin(r: &mut BinReader) -> Result<Self> {
        Ok(Self { name: r.str()?, full: r.usize()?, tile: r.usize()? })
    }
}

impl DimSpec {
    /// Canonical JSON encoding (`"loop": null` encodes a fixed dim).
    pub fn to_json(&self) -> Json {
        let loop_idx = match self.loop_idx {
            None => Json::Null,
            Some(l) => Json::int(l),
        };
        Json::obj(vec![
            ("full", Json::int(self.full)),
            ("loop", loop_idx),
            ("a", Json::int(self.a)),
            ("b", Json::int(self.b)),
        ])
    }

    /// Decode the canonical JSON encoding.
    pub fn from_json(v: &Json) -> Result<Self> {
        let loop_idx = match v.get("loop")? {
            Json::Null => None,
            other => Some(other.as_usize()?),
        };
        Ok(Self {
            full: v.get("full")?.as_usize()?,
            loop_idx,
            a: v.get("a")?.as_usize()?,
            b: v.get("b")?.as_usize()?,
        })
    }

    /// Canonical binary encoding (`ftl-bin-v1`; absent presence byte
    /// encodes a fixed dim).
    pub fn to_bin(&self, w: &mut BinWriter) {
        w.usize(self.full);
        w.opt(self.loop_idx.as_ref(), |w, &l| w.usize(l));
        w.usize(self.a);
        w.usize(self.b);
    }

    /// Decode the canonical binary encoding.
    pub fn from_bin(r: &mut BinReader) -> Result<Self> {
        Ok(Self { full: r.usize()?, loop_idx: r.opt(|r| r.usize())?, a: r.usize()?, b: r.usize()? })
    }
}

impl GroupBuffer {
    /// Canonical JSON encoding (`"home": null` encodes a fused
    /// intermediate that never leaves L1).
    pub fn to_json(&self) -> Json {
        let home = match self.home {
            None => Json::Null,
            Some(l) => Json::str(l.name()),
        };
        Json::obj(vec![
            ("tensor", Json::int(self.tensor)),
            ("name", Json::str(&self.name)),
            ("role", Json::str(self.role.name())),
            ("elem_bytes", Json::int(self.elem_bytes)),
            ("dims", Json::Arr(self.dims.iter().map(DimSpec::to_json).collect())),
            ("home", home),
            ("fetch_depth", Json::int(self.fetch_depth)),
        ])
    }

    /// Decode the canonical JSON encoding.
    pub fn from_json(v: &Json) -> Result<Self> {
        let role = v.get("role")?.as_str()?;
        let home = match v.get("home")? {
            Json::Null => None,
            other => {
                let name = other.as_str()?;
                Some(Level::parse(name).ok_or_else(|| anyhow!("unknown memory level '{name}'"))?)
            }
        };
        Ok(Self {
            tensor: v.get("tensor")?.as_usize()?,
            name: v.get("name")?.as_str()?.to_string(),
            role: BufferRole::parse(role).ok_or_else(|| anyhow!("unknown buffer role '{role}'"))?,
            elem_bytes: v.get("elem_bytes")?.as_usize()?,
            dims: v.get("dims")?.as_arr()?.iter().map(DimSpec::from_json).collect::<Result<_>>()?,
            home,
            fetch_depth: v.get("fetch_depth")?.as_usize()?,
        })
    }

    /// Canonical binary encoding (`ftl-bin-v1`).
    pub fn to_bin(&self, w: &mut BinWriter) {
        w.usize(self.tensor);
        w.str(&self.name);
        w.str(self.role.name());
        w.usize(self.elem_bytes);
        w.seq(&self.dims, |w, d| d.to_bin(w));
        w.opt(self.home.as_ref(), |w, l| w.str(l.name()));
        w.usize(self.fetch_depth);
    }

    /// Decode the canonical binary encoding.
    pub fn from_bin(r: &mut BinReader) -> Result<Self> {
        let tensor = r.usize()?;
        let name = r.str()?;
        let role = r.str()?;
        let role = BufferRole::parse(&role).ok_or_else(|| anyhow!("unknown buffer role '{role}'"))?;
        let elem_bytes = r.usize()?;
        let dims = r.seq(DimSpec::from_bin)?;
        let home = r.opt(|r| {
            let name = r.str()?;
            Level::parse(&name).ok_or_else(|| anyhow!("unknown memory level '{name}'"))
        })?;
        Ok(Self { tensor, name, role, elem_bytes, dims, home, fetch_depth: r.usize()? })
    }
}

impl NodeTile {
    /// Canonical JSON encoding (the operator nests as the interchange
    /// format's `{"op", "attrs"}` object).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("node", Json::int(self.node)),
            ("name", Json::str(&self.name)),
            ("op", op_to_json(&self.op)),
            ("unit", Json::str(self.unit.name())),
            ("input_bufs", Json::ints(&self.input_bufs)),
            ("output_buf", Json::int(self.output_buf)),
        ])
    }

    /// Decode the canonical JSON encoding.
    pub fn from_json(v: &Json) -> Result<Self> {
        let unit = v.get("unit")?.as_str()?;
        Ok(Self {
            node: v.get("node")?.as_usize()?,
            name: v.get("name")?.as_str()?.to_string(),
            op: op_from_json(v.get("op")?)?,
            unit: ComputeUnit::parse(unit).ok_or_else(|| anyhow!("unknown compute unit '{unit}'"))?,
            input_bufs: v.get("input_bufs")?.as_usize_arr()?,
            output_buf: v.get("output_buf")?.as_usize()?,
        })
    }

    /// Canonical binary encoding (`ftl-bin-v1`).
    pub fn to_bin(&self, w: &mut BinWriter) {
        w.usize(self.node);
        w.str(&self.name);
        op_to_bin(&self.op, w);
        w.str(self.unit.name());
        w.usize_seq(&self.input_bufs);
        w.usize(self.output_buf);
    }

    /// Decode the canonical binary encoding.
    pub fn from_bin(r: &mut BinReader) -> Result<Self> {
        let node = r.usize()?;
        let name = r.str()?;
        let op = op_from_bin(r)?;
        let unit = r.str()?;
        let unit = ComputeUnit::parse(&unit).ok_or_else(|| anyhow!("unknown compute unit '{unit}'"))?;
        Ok(Self { node, name, op, unit, input_bufs: r.usize_seq()?, output_buf: r.usize()? })
    }
}

impl GroupSolution {
    /// Canonical JSON encoding.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("nodes", Json::Arr(self.nodes.iter().map(NodeTile::to_json).collect())),
            ("loops", Json::Arr(self.loops.iter().map(FreeVarChoice::to_json).collect())),
            ("buffers", Json::Arr(self.buffers.iter().map(GroupBuffer::to_json).collect())),
            ("footprint", Json::int(self.footprint)),
            ("double_buffered", Json::Bool(self.double_buffered)),
            ("estimated_cycles", Json::int(self.estimated_cycles as usize)),
        ])
    }

    /// Decode the canonical JSON encoding.
    pub fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            nodes: v.get("nodes")?.as_arr()?.iter().map(NodeTile::from_json).collect::<Result<_>>()?,
            loops: v.get("loops")?.as_arr()?.iter().map(FreeVarChoice::from_json).collect::<Result<_>>()?,
            buffers: v.get("buffers")?.as_arr()?.iter().map(GroupBuffer::from_json).collect::<Result<_>>()?,
            footprint: v.get("footprint")?.as_usize()?,
            double_buffered: v.get("double_buffered")?.as_bool()?,
            estimated_cycles: v.get("estimated_cycles")?.as_u64()?,
        })
    }

    /// Canonical binary encoding (`ftl-bin-v1`).
    pub fn to_bin(&self, w: &mut BinWriter) {
        w.seq(&self.nodes, |w, n| n.to_bin(w));
        w.seq(&self.loops, |w, l| l.to_bin(w));
        w.seq(&self.buffers, |w, b| b.to_bin(w));
        w.usize(self.footprint);
        w.bool(self.double_buffered);
        w.u64(self.estimated_cycles);
    }

    /// Decode the canonical binary encoding.
    pub fn from_bin(r: &mut BinReader) -> Result<Self> {
        Ok(Self {
            nodes: r.seq(NodeTile::from_bin)?,
            loops: r.seq(FreeVarChoice::from_bin)?,
            buffers: r.seq(GroupBuffer::from_bin)?,
            footprint: r.usize()?,
            double_buffered: r.bool()?,
            estimated_cycles: r.u64()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loops() -> Vec<FreeVarChoice> {
        vec![
            FreeVarChoice { name: "M".into(), full: 10, tile: 4 },
            FreeVarChoice { name: "N".into(), full: 6, tile: 3 },
        ]
    }

    fn sol(loops: Vec<FreeVarChoice>) -> GroupSolution {
        GroupSolution {
            nodes: vec![],
            loops,
            buffers: vec![],
            footprint: 0,
            double_buffered: false,
            estimated_cycles: 0,
        }
    }

    #[test]
    fn trips_and_iterations() {
        let s = sol(loops());
        assert_eq!(s.total_iterations(), 3 * 2);
        let iters = s.iterations();
        assert_eq!(iters.len(), 6);
        // first iteration full tiles
        assert_eq!(iters[0], vec![(0, 4), (0, 3)]);
        // last iteration: M remainder 2, N offset 3
        assert_eq!(iters[5], vec![(8, 2), (3, 3)]);
    }

    #[test]
    fn remainder_tiles_cover_exactly() {
        let s = sol(vec![FreeVarChoice { name: "X".into(), full: 197, tile: 32 }]);
        let iters = s.iterations();
        let covered: usize = iters.iter().map(|st| st[0].1).sum();
        assert_eq!(covered, 197);
        assert_eq!(iters.len(), 7);
        assert_eq!(iters.last().unwrap()[0], (192, 5));
    }

    #[test]
    fn dimspec_fixed_and_looped() {
        let st = vec![(8, 2), (3, 3)];
        let fixed = DimSpec { full: 768, loop_idx: None, a: 0, b: 768 };
        assert_eq!(fixed.at(&st), (0, 768));
        let m = DimSpec { full: 10, loop_idx: Some(0), a: 1, b: 0 };
        assert_eq!(m.at(&st), (8, 2));
        // halo'd (conv-like): in = 2*out + 2
        let halo = DimSpec { full: 23, loop_idx: Some(1), a: 2, b: 2 };
        assert_eq!(halo.at(&st), (6, 8));
    }

    #[test]
    fn buffer_trips_hoisting() {
        let ls = loops(); // trips: 3 (M), 2 (N)
        let mk = |depth| GroupBuffer {
            tensor: 0,
            name: "b".into(),
            role: BufferRole::Input,
            elem_bytes: 1,
            dims: vec![],
            home: Some(Level::L2),
            fetch_depth: depth,
        };
        assert_eq!(mk(0).trips(&ls), 1); // loop-invariant: fetched once
        assert_eq!(mk(1).trips(&ls), 3); // per M tile
        assert_eq!(mk(2).trips(&ls), 6); // per (M,N) tile
    }

    #[test]
    fn changed_depth_detection() {
        let s = sol(loops());
        let iters = s.iterations();
        assert_eq!(s.changed_depth(None, &iters[0]), 0);
        // iter 0→1: N advanced (depth 1)
        assert_eq!(s.changed_depth(Some(&iters[0]), &iters[1]), 1);
        // iter 1→2: M advanced (depth 0)
        assert_eq!(s.changed_depth(Some(&iters[1]), &iters[2]), 0);
    }

    #[test]
    fn json_roundtrip() {
        let sol = TilingSolution {
            groups: vec![GroupSolution {
                nodes: vec![NodeTile {
                    node: 0,
                    name: "fc1".into(),
                    op: Op::Gemm { transpose_b: false, has_bias: true },
                    unit: ComputeUnit::Cluster,
                    input_bufs: vec![0, 1],
                    output_buf: 2,
                }],
                loops: loops(),
                buffers: vec![GroupBuffer {
                    tensor: 3,
                    name: "x".into(),
                    role: BufferRole::Input,
                    elem_bytes: 1,
                    dims: vec![
                        DimSpec { full: 10, loop_idx: Some(0), a: 1, b: 0 },
                        DimSpec { full: 768, loop_idx: None, a: 0, b: 768 },
                    ],
                    home: Some(Level::L2),
                    fetch_depth: 1,
                }],
                footprint: 4096,
                double_buffered: true,
                estimated_cycles: 123_456,
            }],
        };
        let back = TilingSolution::from_json(&sol.to_json()).unwrap();
        assert_eq!(back, sol);
        // A fused-intermediate buffer (home: null) round-trips too.
        let mut nul = sol.clone();
        nul.groups[0].buffers[0].home = None;
        assert_eq!(TilingSolution::from_json(&nul.to_json()).unwrap(), nul);
    }

    #[test]
    fn steady_bytes() {
        let ls = loops();
        let b = GroupBuffer {
            tensor: 0,
            name: "a".into(),
            role: BufferRole::Input,
            elem_bytes: 2,
            dims: vec![
                DimSpec { full: 10, loop_idx: Some(0), a: 1, b: 0 },
                DimSpec { full: 768, loop_idx: None, a: 0, b: 768 },
            ],
            home: Some(Level::L2),
            fetch_depth: 1,
        };
        assert_eq!(b.steady_bytes(&ls), 4 * 768 * 2);
        assert_eq!(b.shape_at(&[(8, 2), (0, 3)]), vec![2, 768]);
    }
}
