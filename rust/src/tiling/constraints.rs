//! Step ② — constraint formulation.
//!
//! Three constraint classes, exactly as the paper defines them:
//!
//! * **Geometric** — data dependencies between output- and input-tensor
//!   dimensions, expressed as linear transformations `in = a·out + b`
//!   ([`Constraint::Link`]; plain equality is `a=1, b=0`). For GEMM the
//!   output tile `[m, n]` needs input tiles `A[m, k]`, `B[k, n]`; for a
//!   convolution the input-height tile is `stride·h_out + (kh − 1)`.
//! * **Kernel policy** — dataflow requirements of the kernel library:
//!   the int8 GEMM reduction dim is never tiled ([`Constraint::Full`],
//!   requantisation needs the complete accumulation), normalisation ops
//!   need whole rows.
//! * **Performance** — flexible utilisation boosters: tile sizes that are
//!   multiples of the SIMD width / NPU PE-array width
//!   ([`Constraint::Multiple`]) and minimum tile sizes
//!   ([`Constraint::Min`]). These bind only the *steady-state* tile; edge
//!   (remainder) tiles may be smaller.

#![forbid(unsafe_code)]

use anyhow::{bail, Result};

use crate::ir::{Graph, Node, Op};
use crate::soc::SocConfig;

use super::problem::{NodeTiling, OperandRef};
use super::vars::{VarId, VarTable};

/// A tiling constraint over [`VarId`]s.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Constraint {
    /// Geometric: `dst = a · src + b` over *tile sizes*.
    Link {
        /// Dependent (input-side) variable.
        dst: VarId,
        /// Independent variable.
        src: VarId,
        /// Multiplier.
        a: usize,
        /// Offset.
        b: usize,
    },
    /// Kernel policy: the dimension is not tiled (`tile == full`).
    Full(VarId),
    /// Performance: steady-state tile size must be a multiple of `.1`.
    Multiple(VarId, usize),
    /// Performance: steady-state tile size must be at least `.1`.
    Min(VarId, usize),
}

impl Constraint {
    /// Equality binding (used by fusion, step ③).
    pub fn eq(dst: VarId, src: VarId) -> Self {
        Constraint::Link { dst, src, a: 1, b: 0 }
    }

    /// True for the performance class (droppable under `--no-perf-constraints`).
    pub fn is_performance(&self) -> bool {
        matches!(self, Constraint::Multiple(..) | Constraint::Min(..))
    }
}

/// Emit variables, operand descriptors and constraints for one node.
///
/// `out_vars`, if given, are the *pre-bound* variables for the node's
/// output dimensions (used when the node's output feeds a later op in the
/// same solve — not the usual path; fusion binds on the *input* side).
/// Returns the node tiling descriptor plus its constraints.
pub fn emit_node(
    graph: &Graph,
    soc: &SocConfig,
    node_id: usize,
    vars: &mut VarTable,
) -> Result<(NodeTiling, Vec<Constraint>)> {
    let node: &Node = &graph.nodes[node_id];
    let nname = &node.name;
    let out_shape = &graph.tensors[node.output].shape;
    let mut cons = Vec::new();

    // Attribute output variables (step ① for the output tensor).
    let out_vars: Vec<VarId> = out_shape
        .iter()
        .enumerate()
        .map(|(i, &d)| vars.fresh(format!("{nname}.out{i}"), d))
        .collect();

    let in_shapes: Vec<&Vec<usize>> = node.inputs.iter().map(|&t| &graph.tensors[t].shape).collect();

    // Per-op geometric / policy / performance constraints.
    let in_vars: Vec<Vec<VarId>> = match &node.op {
        Op::Gemm { transpose_b, has_bias } => {
            let (m, n) = (out_vars[0], out_vars[1]);
            let k_full = in_shapes[0][1];
            let k = vars.fresh(format!("{nname}.K"), k_full);
            // Kernel policy: int8 GEMM accumulates the whole K per tile.
            cons.push(Constraint::Full(k));
            // Performance: SIMD width on N (cluster sdotp) / PE width (NPU).
            let width = if soc.has_npu() { 16 } else { 4 };
            cons.push(Constraint::Multiple(n, width));
            let b = if *transpose_b { vec![n, k] } else { vec![k, n] };
            let mut ins = vec![vec![m, k], b];
            if *has_bias {
                ins.push(vec![n]);
            }
            ins
        }
        Op::Act(_) | Op::Requant => {
            // Elementwise: input tile dims ≡ output tile dims.
            vec![out_vars.clone()]
        }
        Op::Add => vec![out_vars.clone(), out_vars.clone()],
        Op::LayerNorm { .. } => {
            // Kernel policy: normalisation needs whole rows — last dim full.
            let c = *out_vars.last().unwrap();
            cons.push(Constraint::Full(c));
            vec![out_vars.clone(), vec![c], vec![c]]
        }
        Op::Softmax => {
            let c = *out_vars.last().unwrap();
            cons.push(Constraint::Full(c));
            vec![out_vars.clone()]
        }
        Op::Transpose => {
            // Geometric: input dims are the output dims swapped.
            vec![vec![out_vars[1], out_vars[0]]]
        }
        Op::Conv2d { kh, kw, stride, pad } => {
            let (nb, ho, wo, f) = (out_vars[0], out_vars[1], out_vars[2], out_vars[3]);
            // Geometric links with halo: hi = stride·ho + (kh − 1).
            let hi = vars.fresh(format!("{nname}.Hin"), in_shapes[0][1]);
            let wi = vars.fresh(format!("{nname}.Win"), in_shapes[0][2]);
            cons.push(Constraint::Link { dst: hi, src: ho, a: *stride, b: kh - 1 });
            cons.push(Constraint::Link { dst: wi, src: wo, a: *stride, b: kw - 1 });
            // Kernel policy: padded convolutions are not spatially tiled —
            // the affine tile-offset model (`in_off = stride·out_off`)
            // cannot express the −pad shift, so interior tiles would read
            // the wrong halo. Zero-pad convs tile freely.
            if *pad > 0 {
                cons.push(Constraint::Full(ho));
                cons.push(Constraint::Full(wo));
            }
            // Kernel policy: full input-channel reduction per tile.
            let c = vars.fresh(format!("{nname}.Cin"), in_shapes[0][3]);
            cons.push(Constraint::Full(c));
            // Weights are never spatially tiled.
            let kh_v = vars.fresh(format!("{nname}.kh"), *kh);
            let kw_v = vars.fresh(format!("{nname}.kw"), *kw);
            cons.push(Constraint::Full(kh_v));
            cons.push(Constraint::Full(kw_v));
            let width = if soc.has_npu() { 16 } else { 4 };
            cons.push(Constraint::Multiple(f, width));
            vec![vec![nb, hi, wi, c], vec![kh_v, kw_v, c, f]]
        }
    };

    if in_vars.len() != node.inputs.len() {
        bail!("internal: operand/var count mismatch for node {nname}");
    }

    let operands: Vec<OperandRef> = node
        .inputs
        .iter()
        .zip(&in_vars)
        .map(|(&t, dims)| OperandRef { tensor: t, dims: dims.clone(), is_output: false })
        .chain(std::iter::once(OperandRef { tensor: node.output, dims: out_vars.clone(), is_output: true }))
        .collect();

    Ok((NodeTiling { node: node_id, out_vars, operands }, cons))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::vit_mlp;
    use crate::ir::DType;
    use crate::soc::{siracusa_reduced, siracusa_reduced_cluster_only};

    #[test]
    fn gemm_emits_full_k_and_simd_multiple() {
        let g = vit_mlp(197, 768, 3072, DType::Int8);
        let soc = siracusa_reduced_cluster_only();
        let mut vars = VarTable::new();
        let (nt, cons) = emit_node(&g, &soc, 0, &mut vars).unwrap();
        // fc1: A, B, bias, out = 4 operands.
        assert_eq!(nt.operands.len(), 4);
        let fulls: Vec<_> = cons.iter().filter(|c| matches!(c, Constraint::Full(_))).collect();
        assert_eq!(fulls.len(), 1, "exactly one Full (the K dim)");
        assert!(cons.iter().any(|c| matches!(c, Constraint::Multiple(_, 4))));
    }

    #[test]
    fn npu_widens_simd_multiple() {
        let g = vit_mlp(197, 768, 3072, DType::Int8);
        let soc = siracusa_reduced();
        let mut vars = VarTable::new();
        let (_, cons) = emit_node(&g, &soc, 0, &mut vars).unwrap();
        assert!(cons.iter().any(|c| matches!(c, Constraint::Multiple(_, 16))));
    }

    #[test]
    fn act_shares_output_vars() {
        let g = vit_mlp(197, 768, 3072, DType::Int8);
        let soc = siracusa_reduced();
        let mut vars = VarTable::new();
        let (nt, cons) = emit_node(&g, &soc, 1, &mut vars).unwrap();
        assert!(cons.is_empty());
        // gelu input dims are literally the output vars.
        assert_eq!(nt.operands[0].dims, nt.operands[1].dims);
    }

    #[test]
    fn performance_class_detection() {
        let v = VarId(0);
        assert!(Constraint::Multiple(v, 4).is_performance());
        assert!(Constraint::Min(v, 8).is_performance());
        assert!(!Constraint::Full(v).is_performance());
        assert!(!Constraint::eq(v, VarId(1)).is_performance());
    }

    #[test]
    fn conv_emits_halo_links() {
        use crate::ir::{Graph, Tensor, TensorKind};
        let mut g = Graph::new();
        let x = g.add_tensor(Tensor::new("x", vec![1, 32, 32, 16], DType::Int8, TensorKind::Input)).unwrap();
        let w = g.add_tensor(Tensor::new("w", vec![3, 3, 16, 64], DType::Int8, TensorKind::Weight)).unwrap();
        g.add_node("conv", Op::Conv2d { kh: 3, kw: 3, stride: 1, pad: 1 }, vec![x, w], "y", TensorKind::Output)
            .unwrap();
        let soc = siracusa_reduced_cluster_only();
        let mut vars = VarTable::new();
        let (_, cons) = emit_node(&g, &soc, 0, &mut vars).unwrap();
        let halos: Vec<_> = cons
            .iter()
            .filter(|c| matches!(c, Constraint::Link { a: 1, b: 2, .. }))
            .collect();
        assert_eq!(halos.len(), 2, "H and W halo links");
    }
}
