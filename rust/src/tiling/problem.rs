//! The merged constraint-optimisation problem for one fusion group, and
//! the affine variable resolution that turns the constraint set into a
//! small number of *free* tile variables.

#![forbid(unsafe_code)]

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::ir::{Graph, NodeId, TensorId};
use crate::soc::SocConfig;

use super::constraints::{emit_node, Constraint};
use super::fusion::FusionGroup;
use super::vars::{VarId, VarTable};

/// Tiling strategy: the baseline vs the paper's contribution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Layer-per-layer tiling — each node is its own group (baseline).
    LayerPerLayer,
    /// Fused-Tiled Layers — consecutive layers merged per the fusion
    /// policy, shared-tensor variables bound.
    Ftl,
}

impl Strategy {
    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "baseline" | "layer-per-layer" | "lpl" => Strategy::LayerPerLayer,
            "ftl" | "fused" | "fused-tiled" => Strategy::Ftl,
            _ => return None,
        })
    }

    /// Display name used in reports.
    pub const fn name(self) -> &'static str {
        match self {
            Strategy::LayerPerLayer => "layer-per-layer",
            Strategy::Ftl => "ftl",
        }
    }
}

impl std::fmt::Display for Strategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One operand (input or output) of a node, with its tile-dim variables.
#[derive(Debug, Clone)]
pub struct OperandRef {
    /// The tensor this operand reads/writes.
    pub tensor: TensorId,
    /// Tile-size variable per dimension.
    pub dims: Vec<VarId>,
    /// True if this operand is the node's output.
    pub is_output: bool,
}

/// Tiling view of one node.
#[derive(Debug, Clone)]
pub struct NodeTiling {
    /// Node id in the graph.
    pub node: NodeId,
    /// Variables of the output dims.
    pub out_vars: Vec<VarId>,
    /// All operands (inputs in op order, then the output).
    pub operands: Vec<OperandRef>,
}

/// The merged problem for a fusion group (paper steps ①–③ materialised).
#[derive(Debug, Clone)]
pub struct GroupProblem {
    /// Per-node tiling descriptors, in group order.
    pub nodes: Vec<NodeTiling>,
    /// All variables.
    pub vars: VarTable,
    /// All constraints (geometric + kernel policy + performance + fusion
    /// bindings).
    pub constraints: Vec<Constraint>,
}

impl GroupProblem {
    /// Build the problem: emit per-node variables/constraints (steps ①–②)
    /// and bind shared-tensor variables across the group (step ③).
    pub fn build(graph: &Graph, soc: &SocConfig, group: &FusionGroup) -> Result<Self> {
        let mut vars = VarTable::new();
        let mut constraints = Vec::new();
        let mut nodes = Vec::with_capacity(group.nodes.len());

        // producer-output vars per tensor, for binding.
        let mut produced: HashMap<TensorId, Vec<VarId>> = HashMap::new();

        for &nid in &group.nodes {
            let (nt, cons) = emit_node(graph, soc, nid, &mut vars)?;
            constraints.extend(cons);
            // Step ③: bind this node's input vars to the in-group
            // producer's output vars, dimension by dimension.
            for op_ref in nt.operands.iter().filter(|o| !o.is_output) {
                if let Some(src_vars) = produced.get(&op_ref.tensor) {
                    if src_vars.len() != op_ref.dims.len() {
                        bail!("fusion binding rank mismatch on tensor {}", graph.tensors[op_ref.tensor].name);
                    }
                    for (&dst, &src) in op_ref.dims.iter().zip(src_vars) {
                        constraints.push(Constraint::eq(dst, src));
                    }
                }
            }
            produced.insert(graph.nodes[nid].output, nt.out_vars.clone());
            nodes.push(nt);
        }
        Ok(Self { nodes, vars, constraints })
    }

    /// Resolve the affine link structure: every variable becomes
    /// `a · root + b` for some root variable; `Full` roots get fixed
    /// values. Returns the reduced problem the solver enumerates over.
    ///
    /// `use_perf` — include performance constraints (the paper's third
    /// class); disabled by the `--no-perf-constraints` ablation.
    pub fn resolve(&self, use_perf: bool) -> Result<ResolvedVars> {
        let n = self.vars.len();
        // link[dst] = (src, a, b)
        let mut link: Vec<Option<(usize, usize, usize)>> = vec![None; n];
        for c in &self.constraints {
            if let Constraint::Link { dst, src, a, b } = *c {
                if dst == src {
                    if a == 1 && b == 0 {
                        continue;
                    }
                    bail!("inconsistent self-link on {}", self.vars.get(dst).name);
                }
                match link[dst.0] {
                    None => link[dst.0] = Some((src.0, a, b)),
                    Some(existing) if existing == (src.0, a, b) => {}
                    Some(_) => {
                        // Two different links into the same var: keep the
                        // first as the definition and record the second as
                        // an equality on roots later. For this IR the only
                        // multi-link case is a diamond (Add of two fused
                        // branches), which shares vars by construction.
                        bail!("conflicting links into {}", self.vars.get(dst).name)
                    }
                }
            }
        }

        // Resolve each var to (root, a, b) with cycle detection.
        let mut expr: Vec<Option<(usize, usize, usize)>> = vec![None; n];
        fn resolve_one(
            i: usize,
            link: &[Option<(usize, usize, usize)>],
            expr: &mut Vec<Option<(usize, usize, usize)>>,
            depth: usize,
        ) -> Result<(usize, usize, usize)> {
            if depth > link.len() {
                bail!("cycle in link constraints");
            }
            if let Some(e) = expr[i] {
                return Ok(e);
            }
            let e = match link[i] {
                None => (i, 1, 0),
                Some((src, a, b)) => {
                    let (root, a2, b2) = resolve_one(src, link, expr, depth + 1)?;
                    (root, a * a2, a * b2 + b)
                }
            };
            expr[i] = Some(e);
            Ok(e)
        }
        for i in 0..n {
            resolve_one(i, &link, &mut expr, 0)?;
        }
        let expr: Vec<(usize, usize, usize)> = expr.into_iter().map(Option::unwrap).collect();

        // Roots and their effective full extents (tightest bound over all
        // vars mapping to the root: a·root + b ≤ full ⇒ root ≤ (full−b)/a).
        let mut root_full: HashMap<usize, usize> = HashMap::new();
        for (i, &(root, a, b)) in expr.iter().enumerate() {
            let full = self.vars.get(VarId(i)).full;
            if full < b + a {
                bail!("dimension {} too small for link offsets", self.vars.get(VarId(i)).name);
            }
            let bound = (full - b) / a;
            let e = root_full.entry(root).or_insert(bound);
            *e = (*e).min(bound);
        }

        // Fixed roots from Full constraints.
        let mut fixed: HashMap<usize, usize> = HashMap::new();
        for c in &self.constraints {
            if let Constraint::Full(v) = *c {
                let (root, a, b) = expr[v.0];
                let full = self.vars.get(v).full;
                if (full - b) % a != 0 {
                    bail!("Full constraint on {} not satisfiable via link", self.vars.get(v).name);
                }
                let val = (full - b) / a;
                if let Some(prev) = fixed.insert(root, val) {
                    if prev != val {
                        bail!("conflicting Full constraints on root of {}", self.vars.get(v).name);
                    }
                }
            }
        }

        // Performance constraints, projected onto roots (identity exprs only —
        // halo'd dims get their preference via the objective instead).
        let mut multiple: HashMap<usize, usize> = HashMap::new();
        let mut min: HashMap<usize, usize> = HashMap::new();
        if use_perf {
            for c in &self.constraints {
                match *c {
                    Constraint::Multiple(v, m) if expr[v.0].1 == 1 && expr[v.0].2 == 0 => {
                        let r = expr[v.0].0;
                        let e = multiple.entry(r).or_insert(1);
                        *e = lcm(*e, m);
                    }
                    Constraint::Min(v, lo) if expr[v.0].1 == 1 && expr[v.0].2 == 0 => {
                        let r = expr[v.0].0;
                        let e = min.entry(r).or_insert(1);
                        *e = (*e).max(lo);
                    }
                    _ => {}
                }
            }
        }

        let mut free: Vec<usize> = root_full.keys().copied().filter(|r| !fixed.contains_key(r)).collect();
        free.sort_unstable();
        Ok(ResolvedVars { expr, root_full, fixed, multiple, min, free })
    }
}

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

fn lcm(a: usize, b: usize) -> usize {
    a / gcd(a, b) * b
}

/// The reduced (affine-resolved) problem.
#[derive(Debug, Clone)]
pub struct ResolvedVars {
    /// Per var: `(root_index, a, b)` meaning `tile(var) = a·tile(root)+b`.
    pub expr: Vec<(usize, usize, usize)>,
    /// Effective domain upper bound of each root.
    pub root_full: HashMap<usize, usize>,
    /// Roots with policy-fixed values (`Full` dims).
    pub fixed: HashMap<usize, usize>,
    /// Multiplicity preferences per root.
    pub multiple: HashMap<usize, usize>,
    /// Minimum tile per root.
    pub min: HashMap<usize, usize>,
    /// Free roots, sorted — the solver's search dimensions.
    pub free: Vec<usize>,
}

impl ResolvedVars {
    /// Tile size of `var` under an assignment of the free roots
    /// (`assign[i]` is the value of `free[i]`), clamped to the var's full
    /// extent.
    pub fn tile_of(&self, var: VarId, full: usize, assign: &[usize]) -> usize {
        let (root, a, b) = self.expr[var.0];
        let rv = self.root_value(root, assign);
        (a * rv + b).min(full)
    }

    /// Value of a root under an assignment.
    pub fn root_value(&self, root: usize, assign: &[usize]) -> usize {
        if let Some(&v) = self.fixed.get(&root) {
            v
        } else {
            let idx = self.free.binary_search(&root).expect("root must be free or fixed");
            assign[idx]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::vit_mlp;
    use crate::ir::DType;
    use crate::soc::siracusa_reduced_cluster_only;
    use crate::tiling::fusion::FusionGroup;

    fn problem(nodes: Vec<usize>) -> GroupProblem {
        let g = vit_mlp(197, 768, 3072, DType::Int8);
        let soc = siracusa_reduced_cluster_only();
        GroupProblem::build(&g, &soc, &FusionGroup { nodes }).unwrap()
    }

    #[test]
    fn single_gemm_two_free_vars() {
        let p = problem(vec![0]);
        let r = p.resolve(true).unwrap();
        // GEMM: M and N free, K fixed by policy.
        assert_eq!(r.free.len(), 2);
        assert_eq!(r.fixed.len(), 1);
        assert!(r.fixed.values().any(|&v| v == 768));
    }

    #[test]
    fn fused_gemm_gelu_binds_vars() {
        let p = problem(vec![0, 1]);
        let r = p.resolve(true).unwrap();
        // Fusion must NOT add free vars: gelu's dims are bound to gemm's
        // output dims.
        assert_eq!(r.free.len(), 2, "fused group still has exactly M and N free");
        // Binding: gelu operand vars resolve to the same roots as gemm out vars.
        let gemm_out = &p.nodes[0].out_vars;
        let gelu_in = &p.nodes[1].operands[0].dims;
        for (a, b) in gemm_out.iter().zip(gelu_in) {
            assert_eq!(r.expr[a.0].0, r.expr[b.0].0, "bound vars share a root");
        }
    }

    #[test]
    fn perf_constraints_projected() {
        let p = problem(vec![0]);
        let with = p.resolve(true).unwrap();
        let without = p.resolve(false).unwrap();
        assert!(!with.multiple.is_empty());
        assert!(without.multiple.is_empty());
    }

    #[test]
    fn tile_of_clamps() {
        let p = problem(vec![0]);
        let r = p.resolve(true).unwrap();
        // Assign huge values; tiles must clamp to fulls.
        let assign: Vec<usize> = r.free.iter().map(|_| 100_000).collect();
        for (vid, v) in p.vars.iter() {
            assert!(r.tile_of(vid, v.full, &assign) <= v.full);
        }
    }

    #[test]
    fn full_mlp_group_fused_chain() {
        // fc1 → gelu → fc2: fc2's input K is Full → binds gelu's N (and
        // thus gemm1's N) to full 3072.
        let p = problem(vec![0, 1, 2]);
        let r = p.resolve(true).unwrap();
        // free vars: M (shared), fc2.N — gemm1.N is forced to 3072 by the
        // chain through fc2's Full(K).
        assert_eq!(r.free.len(), 2);
        let gemm1_n = p.nodes[0].out_vars[1];
        let (root, a, b) = r.expr[gemm1_n.0];
        assert_eq!((a, b), (1, 0));
        assert_eq!(r.fixed.get(&root), Some(&3072));
    }
}
