//! The shared solver pool: a process-wide worker budget + search counters
//! for the branch-and-bound tiling solver (see [`super::solver`]).
//!
//! The pool does **not** own threads — branch-and-bound workers are
//! short-lived `std::thread::scope` threads spawned by whoever is
//! solving. What the pool owns is the *budget*: a global cap on how many
//! extra workers may run concurrently, shared by every caller
//! ([`crate::tiling::solve_graph`]'s per-group fan-out, the per-group
//! candidate fan-out inside `solve_group`, and
//! [`crate::serve::BatchScheduler`]'s dispatch lanes), so nested
//! parallelism degrades to fewer workers per solve instead of
//! oversubscribing the host. A caller's own thread never needs a permit;
//! only *extra* workers do, so every solve always makes progress even
//! with zero permits available.
//!
//! Thread count resolution: an explicit [`SolverPool::set_threads`] /
//! [`SolverPool::new`] value wins; `0` means auto. The global pool's
//! auto default reads `FTL_SOLVER_THREADS`, falling back to
//! [`std::thread::available_parallelism`]. **Thread count never changes
//! solver output** — the search is deterministic by construction
//! (enforced by property test + CI) — which is why it is *not* part of
//! the request fingerprint ([`crate::serve::fingerprint`]).
//!
//! The pool also aggregates the `solver.*` search counters surfaced in
//! the serve layer's `stats_json`: per completed solve, how many search
//! points were actually scored vs pruned away by the capacity bound or
//! the best-so-far cost bound. Counters are saturating
//! ([`crate::metrics::Counter`]) so a long-lived replica pins at
//! `u64::MAX` instead of wrapping; a [`crate::metrics::Histogram`] of
//! per-group solve wall time (`group_solve_us`) rides along for the
//! observability layer.

#![forbid(unsafe_code)]

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::metrics::{Counter, Histogram};
use crate::util::json::Json;

/// Snapshot of the search counters (see [`SearchCounters`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SearchStats {
    /// Completed group solves.
    pub solves: u64,
    /// Total enumerable points across those solves
    /// (`Σ orders × Π candidates`).
    pub space: u64,
    /// Points actually scored (full feasibility + cost evaluation).
    pub scored: u64,
    /// Points discarded because the L1-capacity lower bound of their
    /// prefix (or their own footprint) exceeded the budget.
    pub capacity_pruned: u64,
    /// Points discarded because the cost lower bound of their prefix
    /// exceeded the best solution found so far.
    pub bound_pruned: u64,
    /// Prune events (a cut subtree of any size counts once).
    pub subtrees_cut: u64,
}

impl SearchStats {
    /// Points eliminated without scoring.
    pub fn pruned(&self) -> u64 {
        self.capacity_pruned + self.bound_pruned
    }

    /// JSON rendering (embedded in the serve stats snapshot).
    /// `Json::Num`, not `Json::int`: a saturated counter (`u64::MAX`)
    /// must render, not panic on the i64 conversion.
    pub fn to_json(&self) -> Json {
        let n = |v: u64| Json::Num(v as f64);
        Json::obj(vec![
            ("solves", n(self.solves)),
            ("space", n(self.space)),
            ("scored", n(self.scored)),
            ("capacity_pruned", n(self.capacity_pruned)),
            ("bound_pruned", n(self.bound_pruned)),
            ("subtrees_cut", n(self.subtrees_cut)),
        ])
    }
}

/// Atomic accumulator behind [`SearchStats`]. One instance lives in each
/// [`SolverPool`]; solves merge their whole local tally at completion, so
/// `scored + capacity_pruned + bound_pruned == space` holds on any
/// quiesced pool (asserted by the search-space accounting property test).
#[derive(Debug, Default)]
pub struct SearchCounters {
    solves: Counter,
    space: Counter,
    scored: Counter,
    capacity_pruned: Counter,
    bound_pruned: Counter,
    subtrees_cut: Counter,
}

impl SearchCounters {
    /// Merge one solve's local tally.
    pub fn merge(&self, s: &SearchStats) {
        self.solves.add(s.solves);
        self.space.add(s.space);
        self.scored.add(s.scored);
        self.capacity_pruned.add(s.capacity_pruned);
        self.bound_pruned.add(s.bound_pruned);
        self.subtrees_cut.add(s.subtrees_cut);
    }

    /// Current totals.
    pub fn snapshot(&self) -> SearchStats {
        SearchStats {
            solves: self.solves.get(),
            space: self.space.get(),
            scored: self.scored.get(),
            capacity_pruned: self.capacity_pruned.get(),
            bound_pruned: self.bound_pruned.get(),
            subtrees_cut: self.subtrees_cut.get(),
        }
    }
}

/// The shared worker budget + counters (see module docs).
pub struct SolverPool {
    /// Configured thread cap; 0 = auto.
    threads: AtomicUsize,
    /// Extra workers currently running (the budget is `threads() - 1`
    /// extras — the calling thread itself is always worker zero).
    extras_in_use: AtomicUsize,
    counters: SearchCounters,
    /// Wall time per completed group solve, in µs (see
    /// [`SolverPool::group_solve_us`]).
    group_solve_us: Histogram,
}

impl SolverPool {
    /// Pool with an explicit thread cap (`0` = auto-detect).
    pub fn new(threads: usize) -> Self {
        Self {
            threads: AtomicUsize::new(threads),
            extras_in_use: AtomicUsize::new(0),
            counters: SearchCounters::default(),
            group_solve_us: Histogram::new(),
        }
    }

    /// The process-wide pool. Auto thread count honours
    /// `FTL_SOLVER_THREADS` (read once, at first use); CLI flags override
    /// it via [`SolverPool::set_threads`].
    pub fn global() -> &'static SolverPool {
        static GLOBAL: OnceLock<SolverPool> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let env = std::env::var("FTL_SOLVER_THREADS").ok().and_then(|v| v.parse::<usize>().ok());
            SolverPool::new(env.unwrap_or(0))
        })
    }

    /// Override the thread cap (`0` = auto). Call before serving traffic;
    /// permits already granted are unaffected.
    pub fn set_threads(&self, n: usize) {
        self.threads.store(n, Ordering::Relaxed);
    }

    /// Resolved thread cap (≥ 1).
    pub fn threads(&self) -> usize {
        match self.threads.load(Ordering::Relaxed) {
            0 => std::thread::available_parallelism().map(std::num::NonZeroUsize::get).unwrap_or(1),
            n => n,
        }
    }

    /// Search counters (solves merge local tallies here).
    pub fn counters(&self) -> &SearchCounters {
        &self.counters
    }

    /// Wall-time histogram of per-group branch-and-bound solves, in µs
    /// ([`crate::tiling::solve_group_in`] records one sample per solve).
    pub fn group_solve_us(&self) -> &Histogram {
        &self.group_solve_us
    }

    /// Counter snapshot.
    pub fn stats(&self) -> SearchStats {
        self.counters.snapshot()
    }

    /// The `stats_json` rendering: thread cap + search counters + the
    /// per-group solve-time histogram.
    pub fn stats_json(&self) -> Json {
        let mut j = self.stats().to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("threads".into(), Json::int(self.threads()));
            m.insert("group_solve_us".into(), self.group_solve_us.to_json());
        }
        j
    }

    /// Try to reserve up to `want` extra-worker permits without blocking;
    /// returns how many were granted (possibly 0). Pair with
    /// [`SolverPool::release`].
    pub fn try_acquire(&self, want: usize) -> usize {
        if want == 0 {
            return 0;
        }
        let budget = self.threads().saturating_sub(1);
        loop {
            let cur = self.extras_in_use.load(Ordering::Relaxed);
            let grant = want.min(budget.saturating_sub(cur));
            if grant == 0 {
                return 0;
            }
            if self
                .extras_in_use
                .compare_exchange(cur, cur + grant, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                return grant;
            }
        }
    }

    /// Return permits taken by [`SolverPool::try_acquire`].
    pub fn release(&self, n: usize) {
        if n > 0 {
            self.extras_in_use.fetch_sub(n, Ordering::Relaxed);
        }
    }

    /// [`SolverPool::try_acquire`] behind an RAII guard: the permits are
    /// returned when the guard drops, so a panicking worker cannot leak
    /// the global budget (which would silently force every later solve
    /// single-threaded for the life of the process).
    pub fn acquire_up_to(&self, want: usize) -> Permits<'_> {
        Permits { pool: self, n: self.try_acquire(want) }
    }

    /// Run `f` over `items`, fanning across the caller's thread plus up
    /// to `threads() - 1` pool-budgeted scoped workers (strided split, so
    /// results keep item order). Falls back to a plain sequential map
    /// when the pool has no spare budget or there is nothing to fan out.
    /// `f` must be safe to call concurrently for distinct items.
    pub fn map<T: Send, R: Send>(&self, items: Vec<T>, f: impl Fn(T) -> R + Sync) -> Vec<R> {
        let want_extras = self.threads().min(items.len()).saturating_sub(1);
        let permits = self.acquire_up_to(want_extras);
        let extras = permits.count();
        if extras == 0 || items.len() < 2 {
            return items.into_iter().map(f).collect();
        }
        let workers = extras + 1;
        let n = items.len();
        let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
        // Hand each worker a strided set of items: worker w gets items
        // w, w+workers, … (keeps early/late heavy items balanced).
        let mut per_worker: Vec<Vec<(usize, T)>> = (0..workers).map(|_| Vec::new()).collect();
        for (i, item) in items.into_iter().enumerate() {
            per_worker[i % workers].push((i, item));
        }
        std::thread::scope(|s| {
            let f = &f;
            let slots = &slots;
            let mut own = None;
            for (w, chunk) in per_worker.into_iter().enumerate() {
                if w == 0 {
                    own = Some(chunk);
                    continue;
                }
                s.spawn(move || {
                    for (i, item) in chunk {
                        *slots[i].lock().expect("solver pool slot poisoned") = Some(f(item));
                    }
                });
            }
            for (i, item) in own.expect("worker zero chunk") {
                *slots[i].lock().expect("solver pool slot poisoned") = Some(f(item));
            }
        });
        slots
            .into_iter()
            .map(|s| s.into_inner().expect("solver pool slot poisoned").expect("worker filled slot"))
            .collect()
    }
}

/// RAII extra-worker permits (see [`SolverPool::acquire_up_to`]).
pub struct Permits<'p> {
    pool: &'p SolverPool,
    n: usize,
}

impl Permits<'_> {
    /// How many extra-worker permits were actually granted.
    pub fn count(&self) -> usize {
        self.n
    }
}

impl Drop for Permits<'_> {
    fn drop(&mut self) {
        self.pool.release(self.n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn permits_are_bounded_and_returned() {
        let pool = SolverPool::new(4);
        assert_eq!(pool.threads(), 4);
        let a = pool.try_acquire(10);
        assert_eq!(a, 3, "budget is threads - 1");
        assert_eq!(pool.try_acquire(1), 0, "budget exhausted");
        pool.release(a);
        assert_eq!(pool.try_acquire(2), 2);
        pool.release(2);
    }

    #[test]
    fn single_thread_pool_grants_nothing() {
        let pool = SolverPool::new(1);
        assert_eq!(pool.try_acquire(4), 0);
    }

    #[test]
    fn permits_survive_worker_panics() {
        let pool = SolverPool::new(4);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _permits = pool.acquire_up_to(3);
            panic!("worker died mid-solve");
        }));
        assert!(result.is_err());
        assert_eq!(pool.try_acquire(3), 3, "RAII guard must return permits across a panic");
        pool.release(3);
    }

    #[test]
    fn map_preserves_order_any_thread_count() {
        for threads in [1, 2, 4, 8] {
            let pool = SolverPool::new(threads);
            let out = pool.map((0..37).collect::<Vec<usize>>(), |x| x * 2);
            assert_eq!(out, (0..37).map(|x| x * 2).collect::<Vec<usize>>());
        }
    }

    #[test]
    fn counters_merge_and_snapshot() {
        let pool = SolverPool::new(2);
        pool.counters().merge(&SearchStats {
            solves: 1,
            space: 100,
            scored: 10,
            capacity_pruned: 40,
            bound_pruned: 50,
            subtrees_cut: 7,
        });
        let s = pool.stats();
        assert_eq!(s.scored + s.pruned(), s.space);
        let j = pool.stats_json();
        assert_eq!(j.get("threads").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("space").unwrap().as_usize().unwrap(), 100);
    }

    #[test]
    fn counters_saturate_instead_of_wrapping() {
        let pool = SolverPool::new(2);
        pool.counters().merge(&SearchStats { space: u64::MAX - 1, ..Default::default() });
        pool.counters().merge(&SearchStats { space: 5, ..Default::default() });
        assert_eq!(pool.stats().space, u64::MAX, "merge past u64::MAX must pin, not wrap");
        // A saturated counter must still render (to_json would panic if
        // it forced the value through i64).
        let j = pool.stats_json();
        assert!(j.get("space").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn group_solve_hist_records_and_renders() {
        let pool = SolverPool::new(1);
        pool.group_solve_us().record(120);
        pool.group_solve_us().record(480);
        let j = pool.stats_json();
        let h = j.get("group_solve_us").unwrap();
        assert_eq!(h.get("count").unwrap().as_u64().unwrap(), 2);
    }

    #[test]
    fn auto_threads_resolves_positive() {
        let pool = SolverPool::new(0);
        assert!(pool.threads() >= 1);
    }
}
