//! Step ④ — solving the merged constraint-optimisation problem.
//!
//! After affine resolution the group has a handful of *free* tile
//! variables. The solver searches candidate tile sizes per free variable
//! (divisor-spaced, rounded to the performance multiples) and loop
//! orders, minimising an analytic runtime estimate: DMA cost (with
//! loop-invariant operand hoisting) plus kernel cost over the tile loop
//! nest — single- or double-buffered.
//!
//! The search is a **parallel branch-and-bound** (§Perf), not a flat
//! sweep: variables are assigned along the loop order, and every partial
//! assignment is bounded by two admissible lower bounds — a monotone
//! L1-footprint bound (unassigned variables at their smallest candidate)
//! and a cost bound built on covered-volume conservation (`trips ×
//! extent ≥` the covered minimum per dimension, total MAC volume per
//! kernel, per-transfer/per-tile setup at minimum trip counts). Subtrees
//! whose bound exceeds the budget or the best solution so far are cut
//! without scoring a single leaf; candidates are scanned largest-first
//! so capacity cuts land early and the near-optimal large tiles
//! establish a tight cost bound immediately. The outermost variable's
//! candidates fan out across `std::thread::scope` workers budgeted by
//! the shared [`SolverPool`], sharing the best-so-far bound through an
//! `AtomicU64`. The winner is **bit-identical to the serial exhaustive
//! reference** for any thread count: pruning only ever discards points
//! strictly worse than the optimum, and ties resolve by the
//! deterministic `(cycles, iters, order, assign)` lexicographic key
//! (property-tested against [`solve_group_exhaustive`], enforced again
//! in CI via plan digests).
//!
//! If a fused group cannot fit L1 at any candidate point (e.g. an
//! aggressive GEMM→GEMM fusion whose binding forces a full-width
//! intermediate), [`solve_graph`] *shrinks the group from the tail* and
//! re-solves — fusion in FTL is opportunistic.

#![forbid(unsafe_code)]

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::dma::Transfer;
use crate::ir::{Graph, TensorId, TensorKind};
use crate::memory::{BufferRole, Level};
use crate::soc::{ComputeUnit, KernelCostModel, SocConfig};

use super::fusion::FusionGroup;
use super::pool::{SearchStats, SolverPool};
use super::problem::{GroupProblem, ResolvedVars};
use super::solution::{DimSpec, FreeVarChoice, GroupBuffer, GroupSolution, NodeTile, TilingSolution};

/// Solver knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SolverOptions {
    /// Include the paper's *performance* constraint class (SIMD/PE-width
    /// multiples). Disabled by the `--no-perf-constraints` ablation.
    pub use_perf_constraints: bool,
    /// Max candidate tile sizes per free variable.
    pub max_candidates: usize,
    /// Fraction of L1 the tile arena may use (headroom for stack/runtime).
    pub l1_budget_fraction: f64,
}

impl Default for SolverOptions {
    fn default() -> Self {
        Self { use_perf_constraints: true, max_candidates: 64, l1_budget_fraction: 1.0 }
    }
}

/// How materialised tensors are packed into L2 (overflow → L3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum HomesPolicy {
    /// Every tensor occupies L2 for the whole inference (the calibrated
    /// default — conservative, matches SoCs that keep I/O staging and
    /// weights resident).
    #[default]
    Resident,
    /// Deeploy-style lifetime-interval allocation: activations only
    /// occupy L2 while live (weights stay resident for the whole
    /// inference — they cannot be re-fetched for free). Tensors that
    /// don't fit spill to L3 one by one. See `bench ablation_homes`.
    Lifetime,
}

/// Assign a *home* memory level to every materialised tensor.
///
/// Intra-group intermediates of fused groups never materialise (they live
/// only in L1 tiles) and get `None`. Everything else is packed into L2 in
/// priority order — graph inputs/outputs first, then weights, then
/// inter-group intermediates — and spills to L3 once L2 is full. This is
/// exactly the paper's overflow mechanism: for the ViT MLP stage the
/// baseline's intermediate does not fit and round-trips through L3
/// (under *both* policies — lifetime allocation can't save it because
/// the intermediate's live range overlaps the resident weights).
pub fn assign_homes(graph: &Graph, groups: &[FusionGroup], soc: &SocConfig) -> Vec<Option<Level>> {
    assign_homes_with(graph, groups, soc, HomesPolicy::Resident)
}

/// [`assign_homes`] with an explicit packing policy.
pub fn assign_homes_with(
    graph: &Graph,
    groups: &[FusionGroup],
    soc: &SocConfig,
    policy: HomesPolicy,
) -> Vec<Option<Level>> {
    let mut materialised = vec![true; graph.tensors.len()];
    let consumers = graph.consumers();
    for g in groups {
        for (i, &nid) in g.nodes.iter().enumerate() {
            let out = graph.nodes[nid].output;
            let in_group = |c: &usize| g.nodes[i + 1..].contains(c);
            if graph.tensors[out].kind == TensorKind::Intermediate && consumers[out].iter().all(|c| in_group(c)) {
                materialised[out] = false;
            }
        }
    }

    let mut homes: Vec<Option<Level>> = vec![None; graph.tensors.len()];
    let priority = |t: &crate::ir::Tensor| match t.kind {
        TensorKind::Input | TensorKind::Output => 0usize,
        TensorKind::Weight => 1,
        TensorKind::Intermediate => 2,
    };
    let mut order: Vec<TensorId> = (0..graph.tensors.len()).filter(|&t| materialised[t]).collect();
    order.sort_by_key(|&t| (priority(&graph.tensors[t]), t));

    match policy {
        HomesPolicy::Resident => {
            let mut l2_left = soc.mem.capacity(Level::L2);
            for t in order {
                let sz = graph.tensors[t].size_bytes();
                if sz <= l2_left {
                    homes[t] = Some(Level::L2);
                    l2_left -= sz;
                } else {
                    homes[t] = Some(Level::L3);
                }
            }
        }
        HomesPolicy::Lifetime => {
            let producers = graph.producers();
            let end = graph.nodes.len();
            let lifetime = |t: TensorId| -> (usize, usize) {
                let tensor = &graph.tensors[t];
                match tensor.kind {
                    // Weights are persistent — freeing their slot would
                    // mean re-fetching them from L3 every inference.
                    TensorKind::Weight => (0, end),
                    TensorKind::Input => (0, consumers[t].iter().copied().max().unwrap_or(0)),
                    TensorKind::Output => (producers[t].unwrap_or(0), end),
                    TensorKind::Intermediate => (
                        producers[t].unwrap_or(0),
                        consumers[t].iter().copied().max().unwrap_or(end),
                    ),
                }
            };
            let spec = soc.mem.spec(Level::L2);
            let alloc = crate::memory::StaticAllocator::new(spec.capacity, spec.alignment);
            let mut placed = Vec::new();
            for t in order {
                let (birth, death) = lifetime(t);
                let req = crate::memory::AllocRequest::new(t, graph.tensors[t].size_bytes(), birth, death);
                homes[t] = if alloc.place_incremental(&mut placed, req).is_some() {
                    Some(Level::L2)
                } else {
                    Some(Level::L3)
                };
            }
        }
    }
    homes
}

/// Internal buffer template before loop-order placement.
struct BufTemplate {
    tensor: TensorId,
    name: String,
    role: BufferRole,
    elem_bytes: usize,
    /// Per dim: (full, free_ref, a, b); `free_ref` indexes `resolved.free`.
    dims: Vec<(usize, Option<usize>, usize, usize)>,
    home: Option<Level>,
}

/// Solve one fusion group with the global [`SolverPool`]. Errors if no
/// candidate point fits L1.
pub fn solve_group(
    graph: &Graph,
    soc: &SocConfig,
    group: &FusionGroup,
    homes: &[Option<Level>],
    opts: &SolverOptions,
    double_buffer: bool,
) -> Result<GroupSolution> {
    solve_group_in(graph, soc, group, homes, opts, double_buffer, SolverPool::global())
}

/// [`solve_group`] against an explicit pool (thread budget + counters).
#[allow(clippy::too_many_arguments)]
pub fn solve_group_in(
    graph: &Graph,
    soc: &SocConfig,
    group: &FusionGroup,
    homes: &[Option<Level>],
    opts: &SolverOptions,
    double_buffer: bool,
    pool: &SolverPool,
) -> Result<GroupSolution> {
    let space = GroupSpace::build(graph, soc, group, homes, opts, double_buffer)?;
    let solve_start = Instant::now();
    let (best, tally) = space.branch_and_bound(pool);
    pool.group_solve_us().record_duration(solve_start.elapsed());
    pool.counters().merge(&tally);
    space.materialise(graph, group, best)
}

/// Serial exhaustive reference sweep over the full search space — the
/// branch-and-bound's correctness oracle (property tests assert the
/// pruned/parallel winner is bit-identical to this) and the §Perf
/// "before" baseline in `benches/hotpath.rs` / `benches/ablation_solver`.
pub fn solve_group_exhaustive(
    graph: &Graph,
    soc: &SocConfig,
    group: &FusionGroup,
    homes: &[Option<Level>],
    opts: &SolverOptions,
    double_buffer: bool,
) -> Result<GroupSolution> {
    let space = GroupSpace::build(graph, soc, group, homes, opts, double_buffer)?;
    let best = space.exhaustive();
    space.materialise(graph, group, best)
}

// ------------------------------------------------------------------ search

/// Partial-assignment state of one free variable during the search.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum VarMode {
    /// Unassigned: bounds relax it over its whole candidate list.
    Free,
    /// Being scanned at this level: bounds relax it over the candidate
    /// *suffix* starting at this index (the list is descending, so the
    /// suffix is "this size and smaller").
    Scan(usize),
    /// Assigned to candidate `.1` with value `.0`.
    Exact(usize, usize),
}

/// One candidate point the search considers best so far.
#[derive(Debug, Clone)]
struct BestPoint {
    cycles: u64,
    iters: usize,
    order_idx: usize,
    assign: Vec<usize>,
}

/// Everything the branch-and-bound needs about one group, precomputed
/// once per solve: templates, per-variable candidate lists (descending),
/// loop orders, and the suffix tables behind the admissible bounds.
struct GroupSpace<'a> {
    soc: &'a SocConfig,
    bufs: Vec<BufTemplate>,
    /// (node id, input buf indices, output buf index).
    node_tiles: Vec<(usize, Vec<usize>, usize)>,
    node_ops: Vec<(crate::ir::Op, ComputeUnit)>,
    resolved: ResolvedVars,
    budget: usize,
    double_buffer: bool,
    /// Full extent per free variable.
    fulls: Vec<usize>,
    /// Candidate tile sizes per free variable, largest first.
    cands: Vec<Vec<usize>>,
    /// Smallest candidate per free variable (the extent relaxation).
    min_cand: Vec<usize>,
    /// Loop orders to search.
    orders: Vec<Vec<usize>>,
    /// Per order: hoisted position/fetch-depth tables (§Perf: computed
    /// once per order instead of per scored leaf).
    order_ctx: Vec<OrderCtx>,
    /// Per buffer, per dim: covered-volume suffix table (empty for fixed
    /// dims): `cov[i] = min over candidates x at index ≥ i of
    /// ceil(full/x) · min(a·x + b, dim_full)` — the least volume any
    /// completion can move through that dimension.
    cov: Vec<Vec<Vec<u64>>>,
    /// Per node: fixed kernel setup + input shapes at minimum extents,
    /// for the compute lower bound.
    node_bound: Vec<NodeBoundMeta>,
    /// Total enumerable points: `orders × Π candidates`.
    total_points: u64,
}

/// Per-order hoisted tables.
struct OrderCtx {
    /// Loop order: position → free-variable index.
    order: Vec<usize>,
    /// Inverse permutation: free-variable index → position.
    pos_of: Vec<usize>,
    /// Per buffer: re-fetched every iteration of loops `0..fetch_depth`.
    fetch_depth: Vec<usize>,
}

struct NodeBoundMeta {
    setup: u64,
    in_min: Vec<Vec<usize>>,
}

/// Below this many total points a solve stays on the calling thread —
/// worker spawn overhead would dominate tiny searches.
const PARALLEL_MIN_POINTS: u64 = 256;

/// Safety margin subtracted from the (partly float) cost lower bound so
/// rounding can never make it exceed the exact integer cost of a
/// completion.
const FLOAT_SLACK: u64 = 8;

impl<'a> GroupSpace<'a> {
    fn build(
        graph: &'a Graph,
        soc: &'a SocConfig,
        group: &FusionGroup,
        homes: &[Option<Level>],
        opts: &SolverOptions,
        double_buffer: bool,
    ) -> Result<GroupSpace<'a>> {
        let problem = GroupProblem::build(graph, soc, group)?;
        let resolved = problem.resolve(opts.use_perf_constraints)?;
        let budget = (soc.mem.capacity(Level::L1) as f64 * opts.l1_budget_fraction) as usize;

        // --- Buffer templates, deduplicated per tensor -------------------
        let produced: Vec<TensorId> = group.nodes.iter().map(|&n| graph.nodes[n].output).collect();
        let consumers = graph.consumers();
        let mut buf_index: HashMap<TensorId, usize> = HashMap::new();
        let mut bufs: Vec<BufTemplate> = Vec::new();
        let mut node_tiles: Vec<(usize, Vec<usize>, usize)> = Vec::new();

        for nt in &problem.nodes {
            let mut input_bufs = Vec::new();
            let mut output_buf = usize::MAX;
            for op_ref in &nt.operands {
                let t = op_ref.tensor;
                let idx = *buf_index.entry(t).or_insert_with(|| {
                    let tensor = &graph.tensors[t];
                    let role = if tensor.kind == TensorKind::Weight {
                        BufferRole::Weight
                    } else if produced.contains(&t) {
                        let escapes = tensor.kind == TensorKind::Output
                            || consumers[t].iter().any(|c| !group.nodes.contains(c));
                        if escapes {
                            BufferRole::Output
                        } else {
                            BufferRole::Intermediate
                        }
                    } else {
                        BufferRole::Input
                    };
                    let dims = op_ref
                        .dims
                        .iter()
                        .enumerate()
                        .map(|(d, &v)| {
                            let (root, a, b) = resolved.expr[v.0];
                            let full = tensor.shape[d];
                            match resolved.fixed.get(&root) {
                                Some(&fv) => (full, None, 0usize, (a * fv + b).min(full)),
                                None => {
                                    let fi = resolved.free.binary_search(&root).expect("free root");
                                    (full, Some(fi), a, b)
                                }
                            }
                        })
                        .collect();
                    let home = if role == BufferRole::Intermediate { None } else { homes[t] };
                    bufs.push(BufTemplate {
                        tensor: t,
                        name: tensor.name.clone(),
                        role,
                        elem_bytes: tensor.dtype.size_bytes(),
                        dims,
                        home,
                    });
                    bufs.len() - 1
                });
                if op_ref.is_output {
                    output_buf = idx;
                } else {
                    input_bufs.push(idx);
                }
            }
            node_tiles.push((nt.node, input_bufs, output_buf));
        }

        // --- Candidate tile sizes per free variable ----------------------
        let free = &resolved.free;
        let n = free.len();
        debug_assert!(n <= 64, "free-variable bitmask assumes ≤64 variables");
        let fulls: Vec<usize> = free.iter().map(|root| resolved.root_full[root]).collect();
        let cands: Vec<Vec<usize>> = free
            .iter()
            .map(|root| {
                let full = resolved.root_full[root];
                let step = resolved.multiple.get(root).copied().unwrap_or(1);
                let minv = resolved.min.get(root).copied().unwrap_or(1).max(1);
                candidate_tiles(full, step, minv, opts.max_candidates)
            })
            .collect();
        let min_cand: Vec<usize> = cands.iter().map(|c| *c.last().expect("non-empty candidates")).collect();

        // --- Loop orders + per-order hoisted tables ----------------------
        let orders = search_orders(n, &bufs);
        let order_ctx: Vec<OrderCtx> = orders
            .iter()
            .map(|order| {
                let mut pos_of = vec![0usize; n];
                for (pos, &fi) in order.iter().enumerate() {
                    pos_of[fi] = pos;
                }
                let fetch_depth = bufs
                    .iter()
                    .map(|b| {
                        b.dims.iter().filter_map(|&(_, fr, _, _)| fr).map(|fi| pos_of[fi] + 1).max().unwrap_or(0)
                    })
                    .collect();
                OrderCtx { order: order.clone(), pos_of, fetch_depth }
            })
            .collect();

        // --- Covered-volume suffix tables --------------------------------
        let cov: Vec<Vec<Vec<u64>>> = bufs
            .iter()
            .map(|b| {
                b.dims
                    .iter()
                    .map(|&(full, fr, a, bb)| match fr {
                        None => Vec::new(),
                        Some(fi) => {
                            let list = &cands[fi];
                            let root_full = fulls[fi];
                            let mut suf = vec![0u64; list.len()];
                            let mut best = u64::MAX;
                            for (i, &x) in list.iter().enumerate().rev() {
                                let covered =
                                    (root_full.div_ceil(x) as u64) * ((a * x + bb).min(full) as u64);
                                best = best.min(covered);
                                suf[i] = best;
                            }
                            suf
                        }
                    })
                    .collect()
            })
            .collect();

        // --- Per-node bound metadata -------------------------------------
        let node_ops: Vec<(crate::ir::Op, ComputeUnit)> = node_tiles
            .iter()
            .map(|(nid, _, _)| {
                let op = graph.nodes[*nid].op.clone();
                let unit = soc.place(&op);
                (op, unit)
            })
            .collect();
        let min_shape = |bi: usize| -> Vec<usize> {
            bufs[bi]
                .dims
                .iter()
                .map(|&(full, fr, a, bb)| match fr {
                    None => bb.min(full),
                    Some(fi) => (a * min_cand[fi] + bb).min(full),
                })
                .collect()
        };
        let node_bound: Vec<NodeBoundMeta> = node_tiles
            .iter()
            .zip(&node_ops)
            .map(|((_, ins, out), (op, unit))| {
                let in_min: Vec<Vec<usize>> = ins.iter().map(|&bi| min_shape(bi)).collect();
                let out_min = min_shape(*out);
                let in_refs: Vec<&[usize]> = in_min.iter().map(|s| s.as_slice()).collect();
                let (setup, _) = KernelCostModel::tile_setup_work(soc, op, *unit, &in_refs, &out_min);
                NodeBoundMeta { setup, in_min }
            })
            .collect();

        let mut total_points = orders.len() as u64;
        for c in &cands {
            total_points = total_points.saturating_mul(c.len() as u64);
        }

        Ok(GroupSpace {
            soc,
            bufs,
            node_tiles,
            node_ops,
            resolved,
            budget,
            double_buffer,
            fulls,
            cands,
            min_cand,
            orders,
            order_ctx,
            cov,
            node_bound,
            total_points,
        })
    }

    /// Number of leaves under one node at `depth` (product of deeper
    /// candidate-list lengths).
    fn leaves_below(&self, octx: &OrderCtx, depth: usize) -> u64 {
        octx.order[depth + 1..].iter().map(|&fi| self.cands[fi].len() as u64).product()
    }

    /// Minimum trip count of `fi`'s loop over every completion of its
    /// current [`VarMode`].
    fn var_trips_lb(&self, st: &[VarMode], fi: usize) -> u64 {
        let full = self.fulls[fi];
        let tile = match st[fi] {
            VarMode::Exact(v, _) => v.min(full),
            VarMode::Scan(i) => self.cands[fi][i],
            VarMode::Free => self.cands[fi][0],
        };
        full.div_ceil(tile) as u64
    }

    /// Minimum steady extent of a dim driven by `fi`.
    fn var_ext_lb(&self, st: &[VarMode], fi: usize, a: usize, b: usize, dim_full: usize) -> usize {
        let v = match st[fi] {
            VarMode::Exact(v, _) => v.min(self.fulls[fi]),
            _ => self.min_cand[fi],
        };
        (a * v + b).min(dim_full)
    }

    /// Minimum covered volume (`trips × extent`) of a dim driven by `fi`.
    fn var_cov_lb(&self, st: &[VarMode], fi: usize, a: usize, b: usize, dim_full: usize, suf: &[u64]) -> u64 {
        match st[fi] {
            VarMode::Exact(v, _) => {
                let v = v.min(self.fulls[fi]);
                (self.fulls[fi].div_ceil(v) as u64) * ((a * v + b).min(dim_full) as u64)
            }
            VarMode::Scan(i) => suf[i],
            VarMode::Free => suf[0],
        }
    }

    /// Admissible lower bounds over every completion of the partial
    /// assignment `st`: `(L1 footprint, cycles)`.
    ///
    /// Footprint: every extent is nondecreasing in its variable's tile
    /// size, so unassigned variables at their smallest candidate bound
    /// every completion from below. Cycles relaxes term-wise: each DMA
    /// channel is charged `setup × min-trips + min-volume / bandwidth`,
    /// pairing each loop with one dependent buffer dim through the
    /// covered-volume table (the per-row term is dropped — admissible);
    /// each kernel is charged `setup × min-iters + covered MAC volume /
    /// throughput` (the per-tile ceil is dropped — admissible). A small
    /// constant absorbs float-floor slack.
    fn lower_bound(&self, octx: &OrderCtx, st: &[VarMode]) -> (usize, u64) {
        let n = self.fulls.len();
        let mut footprint = 0usize;
        let (mut vol_l2, mut vol_l3) = (0f64, 0f64);
        let (mut setup_l2, mut setup_l3) = (0u64, 0u64);
        for (bi, b) in self.bufs.iter().enumerate() {
            let fd = octx.fetch_depth[bi];
            let mut bytes = b.elem_bytes;
            for &(full, fr, a, bb) in &b.dims {
                let ext = match fr {
                    None => bb.min(full),
                    Some(fi) => self.var_ext_lb(st, fi, a, bb, full),
                };
                bytes *= ext;
            }
            let copies = if self.double_buffer && b.home.is_some() && fd > 0 { 2 } else { 1 };
            footprint += align4(bytes) * copies;
            let Some(home) = b.home else { continue };
            if home == Level::L1 {
                continue;
            }
            // Minimum volume: pair each loop with its first dependent dim
            // (covered = trips × extent conserved), remaining dims at
            // minimum extent, loops below the fetch depth that drive no
            // dim of this buffer at minimum trips.
            let mut vol = b.elem_bytes as f64;
            let mut paired = 0u64;
            for (di, &(full, fr, a, bb)) in b.dims.iter().enumerate() {
                match fr {
                    None => vol *= bb.min(full) as f64,
                    Some(fi) if paired & (1 << fi) == 0 => {
                        paired |= 1 << fi;
                        vol *= self.var_cov_lb(st, fi, a, bb, full, &self.cov[bi][di]) as f64;
                    }
                    Some(fi) => vol *= self.var_ext_lb(st, fi, a, bb, full) as f64,
                }
            }
            let mut unpaired_trips = 1u64;
            let mut all_trips = 1u64;
            for &fi in &octx.order[..fd] {
                let t = self.var_trips_lb(st, fi);
                all_trips = all_trips.saturating_mul(t);
                if paired & (1 << fi) == 0 {
                    unpaired_trips = unpaired_trips.saturating_mul(t);
                }
            }
            let vol_total = vol * unpaired_trips as f64;
            vol_l2 += vol_total;
            setup_l2 = setup_l2.saturating_add(self.soc.dma_cluster.setup_cycles.saturating_mul(all_trips));
            if home == Level::L3 {
                vol_l3 += vol_total;
                setup_l3 = setup_l3.saturating_add(self.soc.dma_io.setup_cycles.saturating_mul(all_trips));
            }
        }
        let dma_l2 = setup_l2.saturating_add((vol_l2 / self.soc.dma_cluster.bytes_per_cycle) as u64);
        let dma_l3 = setup_l3.saturating_add((vol_l3 / self.soc.dma_io.bytes_per_cycle) as u64);

        let mut iters_lb = 1u64;
        for fi in 0..n {
            iters_lb = iters_lb.saturating_mul(self.var_trips_lb(st, fi));
        }
        let mut compute = 0u64;
        for (ni, ((_, _, out_buf), (op, unit))) in self.node_tiles.iter().zip(&self.node_ops).enumerate() {
            let nb = &self.node_bound[ni];
            let ob = &self.bufs[*out_buf];
            let mut paired = 0u64;
            let mut out_shape: Vec<usize> = Vec::with_capacity(ob.dims.len());
            for (di, &(full, fr, a, bb)) in ob.dims.iter().enumerate() {
                let v = match fr {
                    None => bb.min(full),
                    Some(fi) if paired & (1 << fi) == 0 => {
                        paired |= 1 << fi;
                        self.var_cov_lb(st, fi, a, bb, full, &self.cov[*out_buf][di]) as usize
                    }
                    Some(fi) => self.var_ext_lb(st, fi, a, bb, full),
                };
                out_shape.push(v);
            }
            let in_refs: Vec<&[usize]> = nb.in_min.iter().map(|s| s.as_slice()).collect();
            let (_, work) = KernelCostModel::tile_setup_work(self.soc, op, *unit, &in_refs, &out_shape);
            let mut extra = 1u64;
            for fi in 0..n {
                if paired & (1 << fi) == 0 {
                    extra = extra.saturating_mul(self.var_trips_lb(st, fi));
                }
            }
            compute = compute
                .saturating_add(nb.setup.saturating_mul(iters_lb))
                .saturating_add((work * extra as f64).max(0.0) as u64);
        }

        let cycles = if self.double_buffer {
            dma_l2.max(dma_l3).max(compute)
        } else {
            dma_l2.saturating_add(dma_l3).saturating_add(compute)
        };
        (footprint, cycles.saturating_sub(FLOAT_SLACK))
    }

    /// Allocation-free exact feasibility + cost scoring of one candidate
    /// point. Mirrors [`build_candidate`] + [`estimate_cycles`] exactly
    /// (asserted by `tests::score_matches_build`).
    fn score_leaf(&self, octx: &OrderCtx, assign: &[usize], s: &mut ScoreScratch) -> Option<(u64, usize)> {
        s.loops.clear();
        for &fi in &octx.order {
            let full = self.fulls[fi];
            s.loops.push((full, assign[fi].min(full)));
        }
        let mut total_iters = 1usize;
        for &(full, tile) in &s.loops {
            total_iters *= full.div_ceil(tile);
        }

        // Steady tile extents + footprint.
        s.steady.clear();
        s.steady_off.clear();
        let mut footprint = 0usize;
        for (bi, b) in self.bufs.iter().enumerate() {
            s.steady_off.push(s.steady.len());
            let mut bytes = b.elem_bytes;
            for &(full, fr, a, bb) in &b.dims {
                let ext = match fr {
                    None => bb.min(full),
                    Some(fi) => (a * s.loops[octx.pos_of[fi]].1 + bb).min(full),
                };
                s.steady.push(ext);
                bytes *= ext;
            }
            let copies = if self.double_buffer && b.home.is_some() && octx.fetch_depth[bi] > 0 { 2 } else { 1 };
            footprint += align4(bytes) * copies;
            if footprint > self.budget {
                return None;
            }
        }
        s.steady_off.push(s.steady.len());

        // DMA per channel (loop-invariant hoisting via fetch depth).
        let mut dma_l2 = 0u64;
        let mut dma_l3 = 0u64;
        for (bi, b) in self.bufs.iter().enumerate() {
            let Some(home) = b.home else { continue };
            let dims = &s.steady[s.steady_off[bi]..s.steady_off[bi + 1]];
            let rows: usize = dims[..dims.len() - 1].iter().product::<usize>().max(1);
            let row_bytes = dims.last().copied().unwrap_or(1) * b.elem_bytes;
            let trips: u64 = s.loops[..octx.fetch_depth[bi]]
                .iter()
                .map(|&(full, tile)| full.div_ceil(tile) as u64)
                .product();
            let inbound = matches!(b.role, BufferRole::Input | BufferRole::Weight);
            for leg in dma_legs(home, inbound, rows, row_bytes) {
                let cycles = self.soc.dma_for(leg.channel_level()).cycles(&leg) * trips;
                match leg.channel_level() {
                    Level::L3 => dma_l3 += cycles,
                    _ => dma_l2 += cycles,
                }
            }
        }

        // Compute.
        let mut compute = 0u64;
        for ((_, input_bufs, output_buf), (op, unit)) in self.node_tiles.iter().zip(&self.node_ops) {
            let in_shapes: Vec<&[usize]> = input_bufs
                .iter()
                .map(|&bi| &s.steady[s.steady_off[bi]..s.steady_off[bi + 1]])
                .collect();
            let out_shape = &s.steady[s.steady_off[*output_buf]..s.steady_off[*output_buf + 1]];
            compute +=
                KernelCostModel::tile_cycles(self.soc, op, *unit, &in_shapes, out_shape) * total_iters as u64;
        }

        let dma_total = dma_l2 + dma_l3;
        let cycles = if self.double_buffer {
            let bottleneck = dma_l2.max(dma_l3).max(compute);
            let fill = if total_iters > 0 { dma_total / total_iters as u64 } else { 0 };
            bottleneck + fill
        } else {
            dma_total + compute
        };
        Some((cycles, total_iters))
    }

    /// Deterministic tie-break key: `(cycles, iters, order, assign)`
    /// lexicographic — the global winner is independent of search order
    /// and thread count.
    fn key<'s>(&'s self, p: &'s BestPoint) -> (u64, usize, &'s [usize], &'s [usize]) {
        (p.cycles, p.iters, self.orders[p.order_idx].as_slice(), p.assign.as_slice())
    }

    /// Run the branch-and-bound; returns the winner (if any point is
    /// feasible) plus this solve's fully-accounted search tally
    /// (`scored + capacity_pruned + bound_pruned == space`).
    fn branch_and_bound(&self, pool: &SolverPool) -> (Option<BestPoint>, SearchStats) {
        let n = self.fulls.len();
        let mut tally = SearchStats { solves: 1, space: self.total_points, ..Default::default() };
        if n == 0 {
            let mut scratch = ScoreScratch::new(0, self.bufs.len());
            return match self.score_leaf(&self.order_ctx[0], &[], &mut scratch) {
                None => {
                    tally.capacity_pruned += 1;
                    (None, tally)
                }
                Some((cycles, iters)) => {
                    tally.scored += 1;
                    (Some(BestPoint { cycles, iters, order_idx: 0, assign: Vec::new() }), tally)
                }
            };
        }

        // Work items: the outermost variable's candidates, per order.
        let mut items: Vec<(usize, usize)> = Vec::new();
        for (oi, order) in self.orders.iter().enumerate() {
            for ci in 0..self.cands[order[0]].len() {
                items.push((oi, ci));
            }
        }
        let shared = AtomicU64::new(u64::MAX);
        let threads = pool.threads().min(items.len()).max(1);
        // RAII permits: returned on drop even if a worker panics, so a
        // poisoned solve can't shrink the global budget forever.
        let want = if threads <= 1 || self.total_points < PARALLEL_MIN_POINTS { 0 } else { threads - 1 };
        let permits = pool.acquire_up_to(want);
        let extras = permits.count();

        let results: Vec<(Option<BestPoint>, SearchStats)> = if extras == 0 {
            vec![self.search_range(&items, &shared)]
        } else {
            let workers = extras + 1;
            let mut chunks: Vec<Vec<(usize, usize)>> = (0..workers).map(|_| Vec::new()).collect();
            for (i, item) in items.into_iter().enumerate() {
                chunks[i % workers].push(item);
            }
            std::thread::scope(|s| {
                let shared = &shared;
                let mut own = None;
                let mut handles = Vec::new();
                for (w, chunk) in chunks.into_iter().enumerate() {
                    if w == 0 {
                        own = Some(chunk);
                        continue;
                    }
                    handles.push(s.spawn(move || self.search_range(&chunk, shared)));
                }
                let mut out = vec![self.search_range(&own.expect("worker zero chunk"), shared)];
                for h in handles {
                    out.push(h.join().expect("solver worker panicked"));
                }
                out
            })
        };
        drop(permits);

        let mut best: Option<BestPoint> = None;
        for (b, t) in results {
            tally.scored += t.scored;
            tally.capacity_pruned += t.capacity_pruned;
            tally.bound_pruned += t.bound_pruned;
            tally.subtrees_cut += t.subtrees_cut;
            if let Some(p) = b {
                let better = match &best {
                    None => true,
                    Some(cur) => self.key(&p) < self.key(cur),
                };
                if better {
                    best = Some(p);
                }
            }
        }
        (best, tally)
    }

    /// One worker: search the given `(order, outermost candidate)` items.
    fn search_range(&self, items: &[(usize, usize)], shared: &AtomicU64) -> (Option<BestPoint>, SearchStats) {
        let n = self.fulls.len();
        let mut w = Walker {
            space: self,
            shared,
            st: vec![VarMode::Free; n],
            assign: vec![0; n],
            scratch: ScoreScratch::new(n, self.bufs.len()),
            best: None,
            tally: SearchStats::default(),
        };
        let mut dead = vec![false; self.orders.len()];
        for &(oi, ci) in items {
            let octx = &self.order_ctx[oi];
            let below = self.leaves_below(octx, 0);
            if dead[oi] {
                // A suffix-range cut at a previous item of this order
                // already covers everything smaller.
                w.tally.bound_pruned += below;
                continue;
            }
            let fi = octx.order[0];
            let v = self.cands[fi][ci];
            w.assign[fi] = v;
            w.st[fi] = VarMode::Exact(v, ci);
            if n == 1 {
                w.leaf(octx, oi);
            } else {
                let (fp, cl) = self.lower_bound(octx, &w.st);
                if fp > self.budget {
                    w.tally.capacity_pruned += below;
                    w.tally.subtrees_cut += 1;
                } else if cl > shared.load(Ordering::Relaxed) {
                    w.tally.bound_pruned += below;
                    w.tally.subtrees_cut += 1;
                } else {
                    w.dfs(octx, oi, 1);
                }
            }
            // Range cut: can any smaller outermost candidate still win?
            if ci + 1 < self.cands[fi].len() && shared.load(Ordering::Relaxed) != u64::MAX {
                w.st[fi] = VarMode::Scan(ci + 1);
                let (_, cl) = self.lower_bound(octx, &w.st);
                if cl > shared.load(Ordering::Relaxed) {
                    dead[oi] = true;
                    w.tally.subtrees_cut += 1;
                }
            }
            w.st[fi] = VarMode::Free;
        }
        (w.best, w.tally)
    }

    /// Serial exhaustive sweep (the oracle/baseline — no pruning).
    fn exhaustive(&self) -> Option<BestPoint> {
        let n = self.fulls.len();
        let mut scratch = ScoreScratch::new(n, self.bufs.len());
        let mut best: Option<BestPoint> = None;
        let mut assign = vec![0usize; n];
        for (oi, octx) in self.order_ctx.iter().enumerate() {
            if n == 0 {
                if let Some((cycles, iters)) = self.score_leaf(octx, &assign, &mut scratch) {
                    let better = match &best {
                        None => true,
                        Some(b) => (cycles, iters, octx.order.as_slice(), assign.as_slice()) < self.key(b),
                    };
                    if better {
                        best = Some(BestPoint { cycles, iters, order_idx: oi, assign: assign.clone() });
                    }
                }
                continue;
            }
            let mut idx = vec![0usize; n];
            'points: loop {
                for f in 0..n {
                    assign[f] = self.cands[f][idx[f]];
                }
                if let Some((cycles, iters)) = self.score_leaf(octx, &assign, &mut scratch) {
                    let better = match &best {
                        None => true,
                        Some(b) => (cycles, iters, octx.order.as_slice(), assign.as_slice()) < self.key(b),
                    };
                    if better {
                        best = Some(BestPoint { cycles, iters, order_idx: oi, assign: assign.clone() });
                    }
                }
                let mut d = n;
                while d > 0 {
                    d -= 1;
                    idx[d] += 1;
                    if idx[d] < self.cands[d].len() {
                        continue 'points;
                    }
                    idx[d] = 0;
                }
                break;
            }
        }
        best
    }

    /// Turn the winning point into a [`GroupSolution`] (or the standard
    /// infeasibility error).
    fn materialise(
        &self,
        graph: &Graph,
        group: &FusionGroup,
        best: Option<BestPoint>,
    ) -> Result<GroupSolution> {
        let p = best.with_context(|| {
            format!(
                "no feasible tiling for group [{}] within L1 budget {} B",
                group.nodes.iter().map(|&n| graph.nodes[n].name.as_str()).collect::<Vec<_>>().join(", "),
                self.budget
            )
        })?;
        let order = &self.orders[p.order_idx];
        let sol = build_candidate(
            graph,
            self.soc,
            &self.bufs,
            &self.node_tiles,
            &self.resolved,
            order,
            &p.assign,
            self.double_buffer,
            self.budget,
        )
        .expect("winning candidate must rebuild");
        Ok(sol)
    }
}

/// Per-worker DFS state below the fanned-out top level.
struct Walker<'s, 'a> {
    space: &'s GroupSpace<'a>,
    shared: &'s AtomicU64,
    st: Vec<VarMode>,
    assign: Vec<usize>,
    scratch: ScoreScratch,
    best: Option<BestPoint>,
    tally: SearchStats,
}

impl Walker<'_, '_> {
    /// Score one fully-assigned point and fold it into the local best +
    /// the shared bound.
    fn leaf(&mut self, octx: &OrderCtx, oi: usize) {
        match self.space.score_leaf(octx, &self.assign, &mut self.scratch) {
            None => self.tally.capacity_pruned += 1,
            Some((cycles, iters)) => {
                self.tally.scored += 1;
                let better = match &self.best {
                    None => true,
                    Some(b) => {
                        (cycles, iters, octx.order.as_slice(), self.assign.as_slice()) < self.space.key(b)
                    }
                };
                if better {
                    self.best = Some(BestPoint { cycles, iters, order_idx: oi, assign: self.assign.clone() });
                    self.shared.fetch_min(cycles, Ordering::Relaxed);
                }
            }
        }
    }

    /// Assign the variable at `depth` (1-based below the fanned-out top
    /// level), pruning by the capacity/cost bounds and cutting the whole
    /// remaining candidate suffix when even its relaxation cannot beat
    /// the best so far.
    fn dfs(&mut self, octx: &OrderCtx, oi: usize, depth: usize) {
        let fi = octx.order[depth];
        let ncand = self.space.cands[fi].len();
        let below = self.space.leaves_below(octx, depth);
        let last = depth + 1 == octx.order.len();
        for i in 0..ncand {
            let v = self.space.cands[fi][i];
            self.assign[fi] = v;
            self.st[fi] = VarMode::Exact(v, i);
            if last {
                self.leaf(octx, oi);
            } else {
                let (fp, cl) = self.space.lower_bound(octx, &self.st);
                if fp > self.space.budget {
                    self.tally.capacity_pruned += below;
                    self.tally.subtrees_cut += 1;
                } else if cl > self.shared.load(Ordering::Relaxed) {
                    self.tally.bound_pruned += below;
                    self.tally.subtrees_cut += 1;
                } else {
                    self.dfs(octx, oi, depth + 1);
                }
            }
            if i + 1 < ncand && self.shared.load(Ordering::Relaxed) != u64::MAX {
                self.st[fi] = VarMode::Scan(i + 1);
                let (_, cl) = self.space.lower_bound(octx, &self.st);
                if cl > self.shared.load(Ordering::Relaxed) {
                    self.tally.bound_pruned += (ncand - i - 1) as u64 * below;
                    self.tally.subtrees_cut += 1;
                    self.st[fi] = VarMode::Free;
                    return;
                }
            }
            self.st[fi] = VarMode::Free;
        }
        self.st[fi] = VarMode::Free;
    }
}

/// Reusable scratch for [`GroupSpace::score_leaf`].
struct ScoreScratch {
    /// (full, tile) per loop position.
    loops: Vec<(usize, usize)>,
    /// Steady tile extents, all buffer dims flattened.
    steady: Vec<usize>,
    /// Start index of each buffer's dims in `steady`.
    steady_off: Vec<usize>,
}

impl ScoreScratch {
    fn new(n_free: usize, n_bufs: usize) -> Self {
        Self {
            loops: Vec::with_capacity(n_free),
            steady: Vec::with_capacity(n_bufs * 4),
            steady_off: Vec::with_capacity(n_bufs + 1),
        }
    }
}

/// Solve all groups; shrinks unsolvable fused groups from the tail.
/// Returns the (possibly re-split) groups alongside the solution.
pub fn solve_graph(
    graph: &Graph,
    soc: &SocConfig,
    groups: Vec<FusionGroup>,
    opts: &SolverOptions,
    double_buffer: bool,
) -> Result<(Vec<FusionGroup>, TilingSolution)> {
    solve_graph_with(graph, soc, groups, opts, double_buffer, HomesPolicy::Resident)
}

/// [`solve_graph`] with an explicit L2-packing policy.
pub fn solve_graph_with(
    graph: &Graph,
    soc: &SocConfig,
    groups: Vec<FusionGroup>,
    opts: &SolverOptions,
    double_buffer: bool,
    policy: HomesPolicy,
) -> Result<(Vec<FusionGroup>, TilingSolution)> {
    solve_graph_in(graph, soc, groups, opts, double_buffer, policy, SolverPool::global())
}

/// [`solve_graph_with`] against an explicit pool. Distinct groups solve
/// concurrently on the pool's budget (each group search additionally
/// fans its own candidates out) — results are position-stable, so the
/// outcome is identical to the serial loop.
#[allow(clippy::too_many_arguments)]
pub fn solve_graph_in(
    graph: &Graph,
    soc: &SocConfig,
    groups: Vec<FusionGroup>,
    opts: &SolverOptions,
    double_buffer: bool,
    policy: HomesPolicy,
    pool: &SolverPool,
) -> Result<(Vec<FusionGroup>, TilingSolution)> {
    let mut groups = groups;
    loop {
        let homes = assign_homes_with(graph, &groups, soc, policy);
        let results: Vec<Result<GroupSolution>> = pool.map((0..groups.len()).collect(), |gi| {
            solve_group_in(graph, soc, &groups[gi], &homes, opts, double_buffer, pool)
        });
        let mut out = Vec::with_capacity(groups.len());
        let mut resplit: Option<(usize, anyhow::Error)> = None;
        for (gi, r) in results.into_iter().enumerate() {
            match r {
                Ok(s) => out.push(s),
                Err(e) => {
                    resplit = Some((gi, e));
                    break;
                }
            }
        }
        match resplit {
            None => return Ok((groups, TilingSolution { groups: out })),
            Some((gi, e)) => {
                if groups[gi].len() == 1 {
                    let name = &graph.nodes[groups[gi].nodes[0]].name;
                    return Err(e.context(format!("unsolvable single-node group '{name}'")));
                }
                // Drop the tail node into its own group and retry (homes
                // change: the split tensor now materialises).
                let tail = groups[gi].nodes.pop().expect("non-empty");
                groups.insert(gi + 1, FusionGroup::solo(tail));
            }
        }
    }
}

/// Divisor-spaced candidate tile sizes, rounded up to `step`, at least
/// `minv`, largest first.
fn candidate_tiles(full: usize, step: usize, minv: usize, max_candidates: usize) -> Vec<usize> {
    let round_up = |x: usize| ((x + step - 1) / step * step).min(full);
    let mut c: Vec<usize> = Vec::new();
    c.push(full);
    for i in 1..=max_candidates.min(full) {
        c.push(round_up(full.div_ceil(i)));
    }
    // Small powers-of-two ladder of the step, for tight-memory corners.
    let mut t = step;
    while t < full {
        c.push(round_up(t));
        t *= 2;
    }
    c.retain(|&t| t >= minv.min(full) && t >= 1);
    c.sort_unstable_by(|a, b| b.cmp(a));
    c.dedup();
    // Cap the list while keeping the whole size *spread*: plain truncation
    // would drop all small tiles and make tight-L1 problems infeasible at
    // low candidate budgets. Evenly subsample, always keeping the largest
    // and the smallest candidate.
    let cap = max_candidates.max(4);
    if c.len() > cap {
        let last = c.len() - 1;
        let picked: Vec<usize> = (0..cap).map(|i| c[(i * last) / (cap - 1)]).collect();
        c = picked;
        c.dedup();
    }
    c
}

fn permutations(n: usize) -> Vec<Vec<usize>> {
    fn rec(rest: &mut Vec<usize>, cur: &mut Vec<usize>, out: &mut Vec<Vec<usize>>) {
        if rest.is_empty() {
            out.push(cur.clone());
            return;
        }
        for i in 0..rest.len() {
            let v = rest.remove(i);
            cur.push(v);
            rec(rest, cur, out);
            cur.pop();
            rest.insert(i, v);
        }
    }
    let mut out = Vec::new();
    rec(&mut (0..n).collect(), &mut Vec::new(), &mut out);
    if out.is_empty() {
        out.push(Vec::new());
    }
    out
}

/// Loop orders to search. Up to 3 free variables: every permutation
/// (regression-tested — small groups keep the exhaustive order space).
/// Above that, a small heuristic set built on operand reuse: the
/// variable with the largest cross-iteration reuse — the smallest
/// dependent streamed footprint — goes innermost, so the big operands
/// hoist out of the hot loop; plus its reverse and the identity orders,
/// deduplicated. All orders feed the same deterministic tie-break.
fn search_orders(n: usize, bufs: &[BufTemplate]) -> Vec<Vec<usize>> {
    if n <= 3 {
        return permutations(n);
    }
    let mut weight = vec![0u128; n];
    for b in bufs {
        if b.home.is_none() {
            continue;
        }
        let full_bytes = b.elem_bytes as u128 * b.dims.iter().map(|d| d.0 as u128).product::<u128>();
        let mut seen = 0u64;
        for &(_, fr, _, _) in &b.dims {
            if let Some(fi) = fr {
                if seen & (1 << fi) == 0 {
                    seen |= 1 << fi;
                    weight[fi] += full_bytes;
                }
            }
        }
    }
    // Outermost = heaviest dependent footprint (fetched fewest times);
    // innermost = lightest = most reuse across inner iterations.
    let mut h: Vec<usize> = (0..n).collect();
    h.sort_by(|&x, &y| weight[y].cmp(&weight[x]).then(x.cmp(&y)));
    let all = [
        h.clone(),
        h.iter().rev().copied().collect::<Vec<usize>>(),
        (0..n).collect::<Vec<usize>>(),
        (0..n).rev().collect::<Vec<usize>>(),
    ];
    let mut out: Vec<Vec<usize>> = Vec::new();
    for o in all {
        if !out.contains(&o) {
            out.push(o);
        }
    }
    out
}

/// Materialise a candidate (order, assignment) into a GroupSolution if it
/// fits the L1 budget; returns None otherwise.
#[allow(clippy::too_many_arguments)]
fn build_candidate(
    graph: &Graph,
    soc: &SocConfig,
    bufs: &[BufTemplate],
    node_tiles: &[(usize, Vec<usize>, usize)],
    resolved: &ResolvedVars,
    order: &[usize],
    assign: &[usize],
    double_buffer: bool,
    budget: usize,
) -> Option<GroupSolution> {
    // Loop nest in the chosen order.
    let loops: Vec<FreeVarChoice> = order
        .iter()
        .map(|&fi| {
            let root = resolved.free[fi];
            FreeVarChoice {
                name: format!("t{root}"),
                full: resolved.root_full[&root],
                tile: assign[fi].min(resolved.root_full[&root]),
            }
        })
        .collect();
    // free-ref → loop position
    let pos_of: Vec<usize> = {
        let mut p = vec![0; order.len()];
        for (pos, &fi) in order.iter().enumerate() {
            p[fi] = pos;
        }
        p
    };

    let buffers: Vec<GroupBuffer> = bufs
        .iter()
        .map(|b| {
            let dims: Vec<DimSpec> = b
                .dims
                .iter()
                .map(|&(full, fr, a, bb)| DimSpec { full, loop_idx: fr.map(|f| pos_of[f]), a, b: bb })
                .collect();
            let fetch_depth = dims.iter().filter_map(|d| d.loop_idx).map(|l| l + 1).max().unwrap_or(0);
            GroupBuffer {
                tensor: b.tensor,
                name: b.name.clone(),
                role: b.role,
                elem_bytes: b.elem_bytes,
                dims,
                home: b.home,
                fetch_depth,
            }
        })
        .collect();

    // Footprint check (steady-state tiles, ping/pong copies).
    let footprint: usize = buffers
        .iter()
        .map(|b| {
            let one = align4(b.steady_bytes(&loops));
            let copies = if double_buffer && b.is_streamed() && b.fetch_depth > 0 { 2 } else { 1 };
            one * copies
        })
        .sum();
    if footprint > budget {
        return None;
    }

    let nodes: Vec<NodeTile> = node_tiles
        .iter()
        .map(|(nid, ins, out)| {
            let op = graph.nodes[*nid].op.clone();
            let unit = soc.place(&op);
            NodeTile {
                node: *nid,
                name: graph.nodes[*nid].name.clone(),
                op,
                unit,
                input_bufs: ins.clone(),
                output_buf: *out,
            }
        })
        .collect();

    let estimated_cycles = estimate_cycles(soc, &nodes, &buffers, &loops, double_buffer);
    Some(GroupSolution { nodes, loops, buffers, footprint, double_buffered: double_buffer, estimated_cycles })
}

fn align4(x: usize) -> usize {
    (x + 3) & !3
}

/// DMA legs for one fetch of a buffer from its home level to L1 (or back).
pub fn dma_legs(home: Level, inbound: bool, rows: usize, row_bytes: usize) -> Vec<Transfer> {
    match (home, inbound) {
        (Level::L1, _) => vec![],
        (Level::L2, true) => vec![Transfer::d2(Level::L2, Level::L1, rows, row_bytes)],
        (Level::L2, false) => vec![Transfer::d2(Level::L1, Level::L2, rows, row_bytes)],
        (Level::L3, true) => vec![
            Transfer::d2(Level::L3, Level::L2, rows, row_bytes),
            Transfer::d2(Level::L2, Level::L1, rows, row_bytes),
        ],
        (Level::L3, false) => vec![
            Transfer::d2(Level::L1, Level::L2, rows, row_bytes),
            Transfer::d2(Level::L2, Level::L3, rows, row_bytes),
        ],
    }
}

/// Analytic runtime estimate for a candidate point — the solver objective.
pub fn estimate_cycles(
    soc: &SocConfig,
    nodes: &[NodeTile],
    buffers: &[GroupBuffer],
    loops: &[FreeVarChoice],
    double_buffer: bool,
) -> u64 {
    let total_iters: usize = loops.iter().map(FreeVarChoice::trips).product();

    // DMA per channel.
    let mut dma: HashMap<Level, u64> = HashMap::new();
    for b in buffers {
        let Some(home) = b.home else { continue };
        let shape: Vec<usize> = b.dims.iter().map(|d| d.steady(loops)).collect();
        let rows: usize = shape[..shape.len() - 1].iter().product::<usize>().max(1);
        let row_bytes = shape.last().copied().unwrap_or(1) * b.elem_bytes;
        let trips = b.trips(loops) as u64;
        let inbound = matches!(b.role, BufferRole::Input | BufferRole::Weight);
        for leg in dma_legs(home, inbound, rows, row_bytes) {
            let model = soc.dma_for(leg.channel_level());
            *dma.entry(leg.channel_level()).or_default() += model.cycles(&leg) * trips;
        }
    }

    // Compute.
    let mut compute: u64 = 0;
    for n in nodes {
        let in_shapes: Vec<Vec<usize>> =
            n.input_bufs.iter().map(|&bi| buffers[bi].dims.iter().map(|d| d.steady(loops)).collect()).collect();
        let in_refs: Vec<&[usize]> = in_shapes.iter().map(|s| s.as_slice()).collect();
        let out_shape: Vec<usize> = buffers[n.output_buf].dims.iter().map(|d| d.steady(loops)).collect();
        compute += KernelCostModel::tile_cycles(soc, &n.op, n.unit, &in_refs, &out_shape) * total_iters as u64;
    }

    let dma_total: u64 = dma.values().sum();
    if double_buffer {
        // Pipelined: bound by the slowest resource, plus a first-tile fill.
        let bottleneck = dma.values().copied().max().unwrap_or(0).max(compute);
        let fill = if total_iters > 0 { dma_total / total_iters as u64 } else { 0 };
        bottleneck + fill
    } else {
        dma_total + compute
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::vit_mlp;
    use crate::ir::DType;
    use crate::soc::{siracusa_reduced, siracusa_reduced_cluster_only};
    use crate::tiling::fusion::{fuse_groups, FusionPolicy};
    use crate::tiling::problem::Strategy;

    fn setup(strategy: Strategy, npu: bool) -> (Graph, SocConfig, Vec<FusionGroup>) {
        let g = vit_mlp(197, 768, 3072, DType::Int8);
        let soc = if npu { siracusa_reduced() } else { siracusa_reduced_cluster_only() };
        let groups = fuse_groups(&g, strategy, FusionPolicy::default());
        (g, soc, groups)
    }

    #[test]
    fn candidate_tiles_properties() {
        let c = candidate_tiles(3072, 16, 1, 64);
        assert!(c.contains(&3072));
        assert!(c.windows(2).all(|w| w[0] > w[1]), "sorted desc, unique");
        assert!(c.iter().all(|&t| t == 3072 || t % 16 == 0));
        let c = candidate_tiles(197, 1, 1, 64);
        assert!(c.contains(&197));
        assert!(c.iter().all(|&t| (1..=197).contains(&t)));
    }

    #[test]
    fn permutations_count() {
        assert_eq!(permutations(0).len(), 1);
        assert_eq!(permutations(2).len(), 2);
        assert_eq!(permutations(3).len(), 6);
    }

    #[test]
    fn small_groups_enumerate_all_permutations() {
        // Regression for the order heuristic: ≤3 free variables must keep
        // the full permutation space regardless of buffer shapes.
        for n in 0..=3 {
            assert_eq!(search_orders(n, &[]), permutations(n));
        }
    }

    #[test]
    fn heuristic_orders_for_many_vars() {
        // 4 free vars; streamed buffers make var 0 the heaviest (largest
        // dependent footprint → outermost) and var 3 the lightest
        // (innermost in the heuristic order).
        let buf = |dims: Vec<(usize, Option<usize>, usize, usize)>, home| BufTemplate {
            tensor: 0,
            name: "b".into(),
            role: BufferRole::Input,
            elem_bytes: 1,
            dims,
            home,
        };
        let bufs = vec![
            buf(vec![(4096, Some(2), 1, 0), (64, Some(0), 1, 0)], Some(Level::L2)),
            buf(vec![(64, Some(1), 1, 0), (8, Some(3), 1, 0)], Some(Level::L2)),
            buf(vec![(512, Some(0), 1, 0), (64, Some(1), 1, 0)], Some(Level::L3)),
            // A fused intermediate must not influence the heuristic.
            buf(vec![(1 << 20, Some(3), 1, 0)], None),
        ];
        let orders = search_orders(4, &bufs);
        assert!(orders.len() <= 4, "heuristic set stays small");
        assert!(orders.len() >= 2, "at least heuristic + reverse");
        for o in &orders {
            let mut sorted = o.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, vec![0, 1, 2, 3], "every order is a permutation");
        }
        assert_eq!(orders[0][0], 0, "heaviest var outermost");
        assert_eq!(*orders[0].last().unwrap(), 3, "lightest var innermost");
    }

    #[test]
    fn baseline_solves_and_fits() {
        let (g, soc, groups) = setup(Strategy::LayerPerLayer, false);
        let homes = assign_homes(&g, &groups, &soc);
        for gr in &groups {
            let s = solve_group(&g, &soc, gr, &homes, &SolverOptions::default(), false).unwrap();
            assert!(s.footprint <= soc.mem.capacity(Level::L1));
            assert!(s.total_iterations() >= 1);
        }
    }

    #[test]
    fn ftl_solves_fused_group() {
        let (g, soc, groups) = setup(Strategy::Ftl, true);
        let homes = assign_homes(&g, &groups, &soc);
        let s = solve_group(&g, &soc, &groups[0], &homes, &SolverOptions::default(), false).unwrap();
        // Fused group: gemm + gelu share the intermediate buffer in L1.
        assert_eq!(s.nodes.len(), 2);
        let inter: Vec<_> = s.buffers.iter().filter(|b| b.role == BufferRole::Intermediate).collect();
        assert_eq!(inter.len(), 1);
        assert!(inter[0].home.is_none(), "fused intermediate has no home level");
    }

    #[test]
    fn bnb_matches_exhaustive_any_thread_count() {
        // The heart of the PR: the pruned/parallel search returns the
        // bit-identical winner of the exhaustive serial sweep, for every
        // strategy × SoC × buffering combination and any thread count.
        for (strategy, npu, dbuf) in [
            (Strategy::Ftl, true, false),
            (Strategy::Ftl, false, true),
            (Strategy::LayerPerLayer, true, false),
            (Strategy::LayerPerLayer, false, true),
        ] {
            let (g, soc, groups) = setup(strategy, npu);
            let homes = assign_homes(&g, &groups, &soc);
            for gr in &groups {
                let oracle =
                    solve_group_exhaustive(&g, &soc, gr, &homes, &SolverOptions::default(), dbuf).unwrap();
                for threads in [1usize, 2, 8] {
                    let pool = SolverPool::new(threads);
                    let sol =
                        solve_group_in(&g, &soc, gr, &homes, &SolverOptions::default(), dbuf, &pool).unwrap();
                    assert_eq!(
                        sol, oracle,
                        "B&B winner must be bit-identical to exhaustive \
                         ({strategy:?}, npu={npu}, dbuf={dbuf}, threads={threads})"
                    );
                }
            }
        }
    }

    #[test]
    fn search_space_fully_accounted() {
        // Every enumerable point is either scored or pruned, never lost:
        // scored + capacity_pruned + bound_pruned == space.
        for threads in [1usize, 4] {
            let pool = SolverPool::new(threads);
            let (g, soc, groups) = setup(Strategy::Ftl, true);
            let homes = assign_homes(&g, &groups, &soc);
            for gr in &groups {
                solve_group_in(&g, &soc, gr, &homes, &SolverOptions::default(), false, &pool).unwrap();
            }
            let s = pool.stats();
            assert!(s.space > 0 && s.scored > 0);
            assert_eq!(
                s.scored + s.capacity_pruned + s.bound_pruned,
                s.space,
                "accounting must cover the whole space (threads={threads}): {s:?}"
            );
            assert!(s.pruned() > s.scored, "pruning must carry the search");
        }
    }

    #[test]
    fn homes_spill_intermediate_in_baseline() {
        // The paper's benchmark graph is the MLP *stage* (GEMM+GeLU): the
        // resident set {X, W1, b1, OUT} fits L2, the intermediate doesn't.
        use crate::ir::{ActKind, GraphBuilder};
        let mut b = GraphBuilder::new(DType::Int8);
        let x = b.input("x", &[197, 768]);
        let fc1 = b.linear("fc1", x, 3072, true);
        let act = b.act("gelu", ActKind::Gelu, fc1);
        let g = b.finish(act).unwrap();
        let soc = siracusa_reduced_cluster_only();
        let groups = fuse_groups(&g, Strategy::LayerPerLayer, FusionPolicy::default());
        let homes = assign_homes(&g, &groups, &soc);
        let (h, _) = g.tensor_by_name("fc1_1").unwrap();
        assert_eq!(homes[h], Some(Level::L3), "baseline intermediate spills to L3");
        let (x, _) = g.tensor_by_name("x").unwrap();
        assert_eq!(homes[x], Some(Level::L2));
    }

    #[test]
    fn homes_none_for_fused_intermediate() {
        let (g, soc, groups) = setup(Strategy::Ftl, false);
        let homes = assign_homes(&g, &groups, &soc);
        let (h, _) = g.tensor_by_name("fc1_1").unwrap();
        assert_eq!(homes[h], None, "fused intermediate never materialises");
    }

    #[test]
    fn solve_graph_ftl_beats_baseline_estimate() {
        let (g, soc, base_groups) = setup(Strategy::LayerPerLayer, true);
        let (_, base) = solve_graph(&g, &soc, base_groups, &SolverOptions::default(), false).unwrap();
        let (g2, soc2, ftl_groups) = setup(Strategy::Ftl, true);
        let (_, ftl) = solve_graph(&g2, &soc2, ftl_groups, &SolverOptions::default(), false).unwrap();
        assert!(
            ftl.estimated_cycles() < base.estimated_cycles(),
            "FTL estimate {} must beat baseline {}",
            ftl.estimated_cycles(),
            base.estimated_cycles()
        );
    }

    #[test]
    fn aggressive_fusion_falls_back() {
        // GEMM→GeLU→GEMM fully fused forces gemm1.N = 3072 (full) via
        // fc2's Full(K); W1 tile becomes 768×3072 = 2.3 MiB > L1, so the
        // solver must shrink the group and still succeed.
        let g = vit_mlp(197, 768, 3072, DType::Int8);
        let soc = siracusa_reduced();
        let groups = fuse_groups(&g, Strategy::Ftl, FusionPolicy { max_len: 8, elementwise_only: false });
        assert_eq!(groups.len(), 1);
        let (final_groups, sol) = solve_graph(&g, &soc, groups, &SolverOptions::default(), false).unwrap();
        assert!(final_groups.len() >= 2, "unsolvable 3-node fusion must split");
        assert_eq!(final_groups.iter().map(FusionGroup::len).sum::<usize>(), 3);
        assert_eq!(sol.groups.len(), final_groups.len());
    }

    #[test]
    fn double_buffer_footprint_grows() {
        let (g, soc, groups) = setup(Strategy::Ftl, true);
        let homes = assign_homes(&g, &groups, &soc);
        let _single = solve_group(&g, &soc, &groups[0], &homes, &SolverOptions::default(), false).unwrap();
        let double = solve_group(&g, &soc, &groups[0], &homes, &SolverOptions::default(), true).unwrap();
        assert!(double.double_buffered);
        // Same tiles would double the streamed part; the solver may pick
        // smaller tiles instead, but the footprint must stay within L1.
        assert!(double.footprint <= soc.mem.capacity(Level::L1));
    }

    #[test]
    fn score_matches_build() {
        // The allocation-free scorer must agree with the materialising
        // path on every feasible point it accepts — checked by comparing
        // the winner's (cycles, iterations) against its rebuilt solution.
        for npu in [false, true] {
            for dbuf in [false, true] {
                let (g, soc, groups) = setup(Strategy::Ftl, npu);
                let homes = assign_homes(&g, &groups, &soc);
                let sol = solve_group(&g, &soc, &groups[0], &homes, &SolverOptions::default(), dbuf).unwrap();
                let rebuilt = estimate_cycles(&soc, &sol.nodes, &sol.buffers, &sol.loops, dbuf);
                assert_eq!(
                    sol.estimated_cycles, rebuilt,
                    "stored estimate must equal recomputed estimate (npu={npu}, dbuf={dbuf})"
                );
            }
        }
    }

    #[test]
    fn perf_constraint_ablation_changes_tiles() {
        let (g, soc, groups) = setup(Strategy::LayerPerLayer, false);
        let homes = assign_homes(&g, &groups, &soc);
        let with = solve_group(&g, &soc, &groups[0], &homes, &SolverOptions::default(), false).unwrap();
        // With perf constraints, the N tile is a multiple of 4.
        let n_loop = with.loops.iter().find(|l| l.full == 3072).unwrap();
        assert_eq!(n_loop.tile % 4, 0);
    }
}
