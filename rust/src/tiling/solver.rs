//! Step ④ — solving the merged constraint-optimisation problem.
//!
//! After affine resolution the group has a handful of *free* tile
//! variables. The solver enumerates candidate tile sizes per free
//! variable (divisor-spaced, rounded to the performance multiples) and
//! loop orders, prunes by the L1-capacity constraint, and minimises an
//! analytic runtime estimate: DMA cost (with loop-invariant operand
//! hoisting) plus kernel cost over the tile loop nest — single- or
//! double-buffered.
//!
//! If a fused group cannot fit L1 at any candidate point (e.g. an
//! aggressive GEMM→GEMM fusion whose binding forces a full-width
//! intermediate), [`solve_graph`] *shrinks the group from the tail* and
//! re-solves — fusion in FTL is opportunistic.

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::dma::Transfer;
use crate::ir::{Graph, TensorId, TensorKind};
use crate::memory::{BufferRole, Level};
use crate::soc::{ComputeUnit, KernelCostModel, SocConfig};

use super::fusion::FusionGroup;
use super::problem::{GroupProblem, ResolvedVars};
use super::solution::{DimSpec, FreeVarChoice, GroupBuffer, GroupSolution, NodeTile, TilingSolution};

/// Solver knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SolverOptions {
    /// Include the paper's *performance* constraint class (SIMD/PE-width
    /// multiples). Disabled by the `--no-perf-constraints` ablation.
    pub use_perf_constraints: bool,
    /// Max candidate tile sizes per free variable.
    pub max_candidates: usize,
    /// Fraction of L1 the tile arena may use (headroom for stack/runtime).
    pub l1_budget_fraction: f64,
}

impl Default for SolverOptions {
    fn default() -> Self {
        Self { use_perf_constraints: true, max_candidates: 64, l1_budget_fraction: 1.0 }
    }
}

/// How materialised tensors are packed into L2 (overflow → L3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum HomesPolicy {
    /// Every tensor occupies L2 for the whole inference (the calibrated
    /// default — conservative, matches SoCs that keep I/O staging and
    /// weights resident).
    #[default]
    Resident,
    /// Deeploy-style lifetime-interval allocation: activations only
    /// occupy L2 while live (weights stay resident for the whole
    /// inference — they cannot be re-fetched for free). Tensors that
    /// don't fit spill to L3 one by one. See `bench ablation_homes`.
    Lifetime,
}

/// Assign a *home* memory level to every materialised tensor.
///
/// Intra-group intermediates of fused groups never materialise (they live
/// only in L1 tiles) and get `None`. Everything else is packed into L2 in
/// priority order — graph inputs/outputs first, then weights, then
/// inter-group intermediates — and spills to L3 once L2 is full. This is
/// exactly the paper's overflow mechanism: for the ViT MLP stage the
/// baseline's intermediate does not fit and round-trips through L3
/// (under *both* policies — lifetime allocation can't save it because
/// the intermediate's live range overlaps the resident weights).
pub fn assign_homes(graph: &Graph, groups: &[FusionGroup], soc: &SocConfig) -> Vec<Option<Level>> {
    assign_homes_with(graph, groups, soc, HomesPolicy::Resident)
}

/// [`assign_homes`] with an explicit packing policy.
pub fn assign_homes_with(
    graph: &Graph,
    groups: &[FusionGroup],
    soc: &SocConfig,
    policy: HomesPolicy,
) -> Vec<Option<Level>> {
    let mut materialised = vec![true; graph.tensors.len()];
    let consumers = graph.consumers();
    for g in groups {
        for (i, &nid) in g.nodes.iter().enumerate() {
            let out = graph.nodes[nid].output;
            let in_group = |c: &usize| g.nodes[i + 1..].contains(c);
            if graph.tensors[out].kind == TensorKind::Intermediate && consumers[out].iter().all(|c| in_group(c)) {
                materialised[out] = false;
            }
        }
    }

    let mut homes: Vec<Option<Level>> = vec![None; graph.tensors.len()];
    let priority = |t: &crate::ir::Tensor| match t.kind {
        TensorKind::Input | TensorKind::Output => 0usize,
        TensorKind::Weight => 1,
        TensorKind::Intermediate => 2,
    };
    let mut order: Vec<TensorId> = (0..graph.tensors.len()).filter(|&t| materialised[t]).collect();
    order.sort_by_key(|&t| (priority(&graph.tensors[t]), t));

    match policy {
        HomesPolicy::Resident => {
            let mut l2_left = soc.mem.capacity(Level::L2);
            for t in order {
                let sz = graph.tensors[t].size_bytes();
                if sz <= l2_left {
                    homes[t] = Some(Level::L2);
                    l2_left -= sz;
                } else {
                    homes[t] = Some(Level::L3);
                }
            }
        }
        HomesPolicy::Lifetime => {
            let producers = graph.producers();
            let end = graph.nodes.len();
            let lifetime = |t: TensorId| -> (usize, usize) {
                let tensor = &graph.tensors[t];
                match tensor.kind {
                    // Weights are persistent — freeing their slot would
                    // mean re-fetching them from L3 every inference.
                    TensorKind::Weight => (0, end),
                    TensorKind::Input => (0, consumers[t].iter().copied().max().unwrap_or(0)),
                    TensorKind::Output => (producers[t].unwrap_or(0), end),
                    TensorKind::Intermediate => (
                        producers[t].unwrap_or(0),
                        consumers[t].iter().copied().max().unwrap_or(end),
                    ),
                }
            };
            let spec = soc.mem.spec(Level::L2);
            let alloc = crate::memory::StaticAllocator::new(spec.capacity, spec.alignment);
            let mut placed = Vec::new();
            for t in order {
                let (birth, death) = lifetime(t);
                let req = crate::memory::AllocRequest::new(t, graph.tensors[t].size_bytes(), birth, death);
                homes[t] = if alloc.place_incremental(&mut placed, req).is_some() {
                    Some(Level::L2)
                } else {
                    Some(Level::L3)
                };
            }
        }
    }
    homes
}

/// Internal buffer template before loop-order placement.
struct BufTemplate {
    tensor: TensorId,
    name: String,
    role: BufferRole,
    elem_bytes: usize,
    /// Per dim: (full, free_ref, a, b); `free_ref` indexes `resolved.free`.
    dims: Vec<(usize, Option<usize>, usize, usize)>,
    home: Option<Level>,
}

/// Solve one fusion group. Errors if no candidate point fits L1.
pub fn solve_group(
    graph: &Graph,
    soc: &SocConfig,
    group: &FusionGroup,
    homes: &[Option<Level>],
    opts: &SolverOptions,
    double_buffer: bool,
) -> Result<GroupSolution> {
    let problem = GroupProblem::build(graph, soc, group)?;
    let resolved = problem.resolve(opts.use_perf_constraints)?;
    let budget = (soc.mem.capacity(Level::L1) as f64 * opts.l1_budget_fraction) as usize;

    // --- Buffer templates, deduplicated per tensor -----------------------
    let produced: Vec<TensorId> = group.nodes.iter().map(|&n| graph.nodes[n].output).collect();
    let consumers = graph.consumers();
    let mut buf_index: HashMap<TensorId, usize> = HashMap::new();
    let mut bufs: Vec<BufTemplate> = Vec::new();
    let mut node_tiles: Vec<(usize, Vec<usize>, usize)> = Vec::new(); // (node, input buf idx, output buf idx)

    for nt in &problem.nodes {
        let mut input_bufs = Vec::new();
        let mut output_buf = usize::MAX;
        for op_ref in &nt.operands {
            let t = op_ref.tensor;
            let idx = *buf_index.entry(t).or_insert_with(|| {
                let tensor = &graph.tensors[t];
                let role = if tensor.kind == TensorKind::Weight {
                    BufferRole::Weight
                } else if produced.contains(&t) {
                    let escapes = tensor.kind == TensorKind::Output
                        || consumers[t].iter().any(|c| !group.nodes.contains(c));
                    if escapes {
                        BufferRole::Output
                    } else {
                        BufferRole::Intermediate
                    }
                } else {
                    BufferRole::Input
                };
                let dims = op_ref
                    .dims
                    .iter()
                    .enumerate()
                    .map(|(d, &v)| {
                        let (root, a, b) = resolved.expr[v.0];
                        let full = tensor.shape[d];
                        match resolved.fixed.get(&root) {
                            Some(&fv) => (full, None, 0usize, (a * fv + b).min(full)),
                            None => {
                                let fi = resolved.free.binary_search(&root).expect("free root");
                                (full, Some(fi), a, b)
                            }
                        }
                    })
                    .collect();
                let home = if role == BufferRole::Intermediate { None } else { homes[t] };
                bufs.push(BufTemplate {
                    tensor: t,
                    name: tensor.name.clone(),
                    role,
                    elem_bytes: tensor.dtype.size_bytes(),
                    dims,
                    home,
                });
                bufs.len() - 1
            });
            if op_ref.is_output {
                output_buf = idx;
            } else {
                input_bufs.push(idx);
            }
        }
        node_tiles.push((nt.node, input_bufs, output_buf));
    }

    // --- Candidate tile sizes per free variable ---------------------------
    let free = &resolved.free;
    let candidates: Vec<Vec<usize>> = free
        .iter()
        .map(|root| {
            let full = resolved.root_full[root];
            let step = resolved.multiple.get(root).copied().unwrap_or(1);
            let minv = resolved.min.get(root).copied().unwrap_or(1).max(1);
            candidate_tiles(full, step, minv, opts.max_candidates)
        })
        .collect();

    // --- Loop orders -------------------------------------------------------
    let orders: Vec<Vec<usize>> = if free.len() <= 3 {
        permutations(free.len())
    } else {
        vec![(0..free.len()).collect(), (0..free.len()).rev().collect()]
    };

    // --- Enumerate ---------------------------------------------------------
    // Hot loop (§Perf): candidates × orders can reach tens of thousands of
    // points per group, so scoring is allocation-free (scratch buffers
    // reused across points); the full GroupSolution is materialised once,
    // for the winner only.
    let node_ops: Vec<(crate::ir::Op, ComputeUnit)> = node_tiles
        .iter()
        .map(|(nid, _, _)| {
            let op = graph.nodes[*nid].op.clone();
            let unit = soc.place(&op);
            (op, unit)
        })
        .collect();
    let mut best: Option<(u64, usize, Vec<usize>, Vec<usize>)> = None; // (cycles, iters, order, assign)
    let mut assign = vec![0usize; free.len()];
    let mut scratch = ScoreScratch::new(free.len(), bufs.len());
    for order in &orders {
        enumerate(&candidates, 0, &mut assign, &mut |assign| {
            let Some((cycles, iters)) = score_candidate(
                soc, &bufs, &node_tiles, &node_ops, &resolved, order, assign, double_buffer, budget,
                &mut scratch,
            ) else {
                return;
            };
            let better = match &best {
                None => true,
                Some((c, i, _, _)) => (cycles, iters) < (*c, *i),
            };
            if better {
                best = Some((cycles, iters, order.clone(), assign.to_vec()));
            }
        });
    }

    let (_, _, order, assign) = best.with_context(|| {
        format!(
            "no feasible tiling for group [{}] within L1 budget {budget} B",
            group.nodes.iter().map(|&n| graph.nodes[n].name.as_str()).collect::<Vec<_>>().join(", ")
        )
    })?;
    let sol = build_candidate(graph, soc, &bufs, &node_tiles, &resolved, &order, &assign, double_buffer, budget)
        .expect("winning candidate must rebuild");
    Ok(sol)
}

/// Reusable scratch for [`score_candidate`].
struct ScoreScratch {
    /// (full, tile) per loop position.
    loops: Vec<(usize, usize)>,
    /// Steady tile extents, all buffer dims flattened.
    steady: Vec<usize>,
    /// Start index of each buffer's dims in `steady`.
    steady_off: Vec<usize>,
}

impl ScoreScratch {
    fn new(n_free: usize, n_bufs: usize) -> Self {
        Self {
            loops: Vec::with_capacity(n_free),
            steady: Vec::with_capacity(n_bufs * 4),
            steady_off: Vec::with_capacity(n_bufs + 1),
        }
    }
}

/// Allocation-free feasibility + cost scoring of one candidate point.
/// Mirrors [`build_candidate`] + [`estimate_cycles`] exactly (asserted by
/// `tests::score_matches_build`).
#[allow(clippy::too_many_arguments)]
fn score_candidate(
    soc: &SocConfig,
    bufs: &[BufTemplate],
    node_tiles: &[(usize, Vec<usize>, usize)],
    node_ops: &[(crate::ir::Op, ComputeUnit)],
    resolved: &ResolvedVars,
    order: &[usize],
    assign: &[usize],
    double_buffer: bool,
    budget: usize,
    s: &mut ScoreScratch,
) -> Option<(u64, usize)> {
    // Loop nest (full, tile) per position; pos_of[free_ref] = position.
    s.loops.clear();
    for &fi in order {
        let root = resolved.free[fi];
        let full = resolved.root_full[&root];
        s.loops.push((full, assign[fi].min(full)));
    }
    let pos_of = |fi: usize| order.iter().position(|&o| o == fi).unwrap();

    // Steady tile extents + footprint + fetch depths.
    s.steady.clear();
    s.steady_off.clear();
    let mut footprint = 0usize;
    let mut total_iters = 1usize;
    for &(full, tile) in &s.loops {
        total_iters *= full.div_ceil(tile);
    }
    for b in bufs {
        s.steady_off.push(s.steady.len());
        let mut bytes = b.elem_bytes;
        let mut fetch_depth = 0usize;
        for &(full, fr, a, bb) in &b.dims {
            let ext = match fr {
                None => bb.min(full),
                Some(fi) => {
                    let pos = pos_of(fi);
                    fetch_depth = fetch_depth.max(pos + 1);
                    (a * s.loops[pos].1 + bb).min(full)
                }
            };
            s.steady.push(ext);
            bytes *= ext;
        }
        let copies = if double_buffer && b.home.is_some() && fetch_depth > 0 { 2 } else { 1 };
        footprint += align4(bytes) * copies;
        if footprint > budget {
            s.steady_off.push(s.steady.len()); // keep offsets consistent
            return None;
        }
    }
    s.steady_off.push(s.steady.len());

    // DMA per channel (loop-invariant hoisting via fetch depth).
    let mut dma_l2 = 0u64;
    let mut dma_l3 = 0u64;
    for (bi, b) in bufs.iter().enumerate() {
        let Some(home) = b.home else { continue };
        let dims = &s.steady[s.steady_off[bi]..s.steady_off[bi + 1]];
        let rows: usize = dims[..dims.len() - 1].iter().product::<usize>().max(1);
        let row_bytes = dims.last().copied().unwrap_or(1) * b.elem_bytes;
        // trips = product of loop trip counts outside the innermost
        // dependent loop (same formula as GroupBuffer::trips).
        let mut fetch_depth = 0usize;
        for &(_, fr, _, _) in &b.dims {
            if let Some(fi) = fr {
                fetch_depth = fetch_depth.max(pos_of(fi) + 1);
            }
        }
        let trips: u64 =
            s.loops[..fetch_depth].iter().map(|&(full, tile)| full.div_ceil(tile) as u64).product();
        let inbound = matches!(b.role, BufferRole::Input | BufferRole::Weight);
        for leg in dma_legs(home, inbound, rows, row_bytes) {
            let cycles = soc.dma_for(leg.channel_level()).cycles(&leg) * trips;
            match leg.channel_level() {
                Level::L3 => dma_l3 += cycles,
                _ => dma_l2 += cycles,
            }
        }
    }

    // Compute.
    let mut compute = 0u64;
    for ((_, input_bufs, output_buf), (op, unit)) in node_tiles.iter().zip(node_ops) {
        let in_shapes: Vec<&[usize]> = input_bufs
            .iter()
            .map(|&bi| &s.steady[s.steady_off[bi]..s.steady_off[bi + 1]])
            .collect();
        let out_shape = &s.steady[s.steady_off[*output_buf]..s.steady_off[*output_buf + 1]];
        compute += KernelCostModel::tile_cycles(soc, op, *unit, &in_shapes, out_shape) * total_iters as u64;
    }

    let dma_total = dma_l2 + dma_l3;
    let cycles = if double_buffer {
        let bottleneck = dma_l2.max(dma_l3).max(compute);
        let fill = if total_iters > 0 { dma_total / total_iters as u64 } else { 0 };
        bottleneck + fill
    } else {
        dma_total + compute
    };
    Some((cycles, total_iters))
}

/// Solve all groups; shrinks unsolvable fused groups from the tail.
/// Returns the (possibly re-split) groups alongside the solution.
pub fn solve_graph(
    graph: &Graph,
    soc: &SocConfig,
    groups: Vec<FusionGroup>,
    opts: &SolverOptions,
    double_buffer: bool,
) -> Result<(Vec<FusionGroup>, TilingSolution)> {
    solve_graph_with(graph, soc, groups, opts, double_buffer, HomesPolicy::Resident)
}

/// [`solve_graph`] with an explicit L2-packing policy.
pub fn solve_graph_with(
    graph: &Graph,
    soc: &SocConfig,
    groups: Vec<FusionGroup>,
    opts: &SolverOptions,
    double_buffer: bool,
    policy: HomesPolicy,
) -> Result<(Vec<FusionGroup>, TilingSolution)> {
    let mut groups = groups;
    loop {
        let homes = assign_homes_with(graph, &groups, soc, policy);
        let mut out = Vec::with_capacity(groups.len());
        let mut resplit: Option<usize> = None;
        for (gi, g) in groups.iter().enumerate() {
            match solve_group(graph, soc, g, &homes, opts, double_buffer) {
                Ok(s) => out.push(s),
                Err(e) => {
                    if g.len() == 1 {
                        let name = &graph.nodes[g.nodes[0]].name;
                        return Err(e.context(format!("unsolvable single-node group '{name}'")));
                    }
                    resplit = Some(gi);
                    break;
                }
            }
        }
        match resplit {
            None => return Ok((groups, TilingSolution { groups: out })),
            Some(gi) => {
                // Drop the tail node into its own group and retry (homes
                // change: the split tensor now materialises).
                let tail = groups[gi].nodes.pop().expect("non-empty");
                groups.insert(gi + 1, FusionGroup::solo(tail));
            }
        }
    }
}

/// Divisor-spaced candidate tile sizes, rounded up to `step`, at least
/// `minv`, largest first.
fn candidate_tiles(full: usize, step: usize, minv: usize, max_candidates: usize) -> Vec<usize> {
    let round_up = |x: usize| ((x + step - 1) / step * step).min(full);
    let mut c: Vec<usize> = Vec::new();
    c.push(full);
    for i in 1..=max_candidates.min(full) {
        c.push(round_up(full.div_ceil(i)));
    }
    // Small powers-of-two ladder of the step, for tight-memory corners.
    let mut t = step;
    while t < full {
        c.push(round_up(t));
        t *= 2;
    }
    c.retain(|&t| t >= minv.min(full) && t >= 1);
    c.sort_unstable_by(|a, b| b.cmp(a));
    c.dedup();
    // Cap the list while keeping the whole size *spread*: plain truncation
    // would drop all small tiles and make tight-L1 problems infeasible at
    // low candidate budgets. Evenly subsample, always keeping the largest
    // and the smallest candidate.
    let cap = max_candidates.max(4);
    if c.len() > cap {
        let last = c.len() - 1;
        let picked: Vec<usize> = (0..cap).map(|i| c[(i * last) / (cap - 1)]).collect();
        c = picked;
        c.dedup();
    }
    c
}

fn permutations(n: usize) -> Vec<Vec<usize>> {
    fn rec(rest: &mut Vec<usize>, cur: &mut Vec<usize>, out: &mut Vec<Vec<usize>>) {
        if rest.is_empty() {
            out.push(cur.clone());
            return;
        }
        for i in 0..rest.len() {
            let v = rest.remove(i);
            cur.push(v);
            rec(rest, cur, out);
            cur.pop();
            rest.insert(i, v);
        }
    }
    let mut out = Vec::new();
    rec(&mut (0..n).collect(), &mut Vec::new(), &mut out);
    if out.is_empty() {
        out.push(Vec::new());
    }
    out
}

fn enumerate(cands: &[Vec<usize>], i: usize, assign: &mut Vec<usize>, f: &mut impl FnMut(&[usize])) {
    if i == cands.len() {
        f(assign);
        return;
    }
    for &v in &cands[i] {
        assign[i] = v;
        enumerate(cands, i + 1, assign, f);
    }
}

/// Materialise a candidate (order, assignment) into a GroupSolution if it
/// fits the L1 budget; returns None otherwise.
#[allow(clippy::too_many_arguments)]
fn build_candidate(
    graph: &Graph,
    soc: &SocConfig,
    bufs: &[BufTemplate],
    node_tiles: &[(usize, Vec<usize>, usize)],
    resolved: &ResolvedVars,
    order: &[usize],
    assign: &[usize],
    double_buffer: bool,
    budget: usize,
) -> Option<GroupSolution> {
    // Loop nest in the chosen order.
    let loops: Vec<FreeVarChoice> = order
        .iter()
        .map(|&fi| {
            let root = resolved.free[fi];
            FreeVarChoice {
                name: format!("t{root}"),
                full: resolved.root_full[&root],
                tile: assign[fi].min(resolved.root_full[&root]),
            }
        })
        .collect();
    // free-ref → loop position
    let pos_of: Vec<usize> = {
        let mut p = vec![0; order.len()];
        for (pos, &fi) in order.iter().enumerate() {
            p[fi] = pos;
        }
        p
    };

    let buffers: Vec<GroupBuffer> = bufs
        .iter()
        .map(|b| {
            let dims: Vec<DimSpec> = b
                .dims
                .iter()
                .map(|&(full, fr, a, bb)| DimSpec { full, loop_idx: fr.map(|f| pos_of[f]), a, b: bb })
                .collect();
            let fetch_depth = dims.iter().filter_map(|d| d.loop_idx).map(|l| l + 1).max().unwrap_or(0);
            GroupBuffer {
                tensor: b.tensor,
                name: b.name.clone(),
                role: b.role,
                elem_bytes: b.elem_bytes,
                dims,
                home: b.home,
                fetch_depth,
            }
        })
        .collect();

    // Footprint check (steady-state tiles, ping/pong copies).
    let footprint: usize = buffers
        .iter()
        .map(|b| {
            let one = align4(b.steady_bytes(&loops));
            let copies = if double_buffer && b.is_streamed() && b.fetch_depth > 0 { 2 } else { 1 };
            one * copies
        })
        .sum();
    if footprint > budget {
        return None;
    }

    let nodes: Vec<NodeTile> = node_tiles
        .iter()
        .map(|(nid, ins, out)| {
            let op = graph.nodes[*nid].op.clone();
            let unit = soc.place(&op);
            NodeTile {
                node: *nid,
                name: graph.nodes[*nid].name.clone(),
                op,
                unit,
                input_bufs: ins.clone(),
                output_buf: *out,
            }
        })
        .collect();

    let estimated_cycles = estimate_cycles(soc, &nodes, &buffers, &loops, double_buffer);
    Some(GroupSolution { nodes, loops, buffers, footprint, double_buffered: double_buffer, estimated_cycles })
}

fn align4(x: usize) -> usize {
    (x + 3) & !3
}

/// DMA legs for one fetch of a buffer from its home level to L1 (or back).
pub fn dma_legs(home: Level, inbound: bool, rows: usize, row_bytes: usize) -> Vec<Transfer> {
    match (home, inbound) {
        (Level::L1, _) => vec![],
        (Level::L2, true) => vec![Transfer::d2(Level::L2, Level::L1, rows, row_bytes)],
        (Level::L2, false) => vec![Transfer::d2(Level::L1, Level::L2, rows, row_bytes)],
        (Level::L3, true) => vec![
            Transfer::d2(Level::L3, Level::L2, rows, row_bytes),
            Transfer::d2(Level::L2, Level::L1, rows, row_bytes),
        ],
        (Level::L3, false) => vec![
            Transfer::d2(Level::L1, Level::L2, rows, row_bytes),
            Transfer::d2(Level::L2, Level::L3, rows, row_bytes),
        ],
    }
}

/// Analytic runtime estimate for a candidate point — the solver objective.
pub fn estimate_cycles(
    soc: &SocConfig,
    nodes: &[NodeTile],
    buffers: &[GroupBuffer],
    loops: &[FreeVarChoice],
    double_buffer: bool,
) -> u64 {
    let total_iters: usize = loops.iter().map(FreeVarChoice::trips).product();

    // DMA per channel.
    let mut dma: HashMap<Level, u64> = HashMap::new();
    for b in buffers {
        let Some(home) = b.home else { continue };
        let shape: Vec<usize> = b.dims.iter().map(|d| d.steady(loops)).collect();
        let rows: usize = shape[..shape.len() - 1].iter().product::<usize>().max(1);
        let row_bytes = shape.last().copied().unwrap_or(1) * b.elem_bytes;
        let trips = b.trips(loops) as u64;
        let inbound = matches!(b.role, BufferRole::Input | BufferRole::Weight);
        for leg in dma_legs(home, inbound, rows, row_bytes) {
            let model = soc.dma_for(leg.channel_level());
            *dma.entry(leg.channel_level()).or_default() += model.cycles(&leg) * trips;
        }
    }

    // Compute.
    let mut compute: u64 = 0;
    for n in nodes {
        let in_shapes: Vec<Vec<usize>> =
            n.input_bufs.iter().map(|&bi| buffers[bi].dims.iter().map(|d| d.steady(loops)).collect()).collect();
        let in_refs: Vec<&[usize]> = in_shapes.iter().map(|s| s.as_slice()).collect();
        let out_shape: Vec<usize> = buffers[n.output_buf].dims.iter().map(|d| d.steady(loops)).collect();
        compute += KernelCostModel::tile_cycles(soc, &n.op, n.unit, &in_refs, &out_shape) * total_iters as u64;
    }

    let dma_total: u64 = dma.values().sum();
    if double_buffer {
        // Pipelined: bound by the slowest resource, plus a first-tile fill.
        let bottleneck = dma.values().copied().max().unwrap_or(0).max(compute);
        let fill = if total_iters > 0 { dma_total / total_iters as u64 } else { 0 };
        bottleneck + fill
    } else {
        dma_total + compute
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::vit_mlp;
    use crate::ir::DType;
    use crate::soc::{siracusa_reduced, siracusa_reduced_cluster_only};
    use crate::tiling::fusion::{fuse_groups, FusionPolicy};
    use crate::tiling::problem::Strategy;

    fn setup(strategy: Strategy, npu: bool) -> (Graph, SocConfig, Vec<FusionGroup>) {
        let g = vit_mlp(197, 768, 3072, DType::Int8);
        let soc = if npu { siracusa_reduced() } else { siracusa_reduced_cluster_only() };
        let groups = fuse_groups(&g, strategy, FusionPolicy::default());
        (g, soc, groups)
    }

    #[test]
    fn candidate_tiles_properties() {
        let c = candidate_tiles(3072, 16, 1, 64);
        assert!(c.contains(&3072));
        assert!(c.windows(2).all(|w| w[0] > w[1]), "sorted desc, unique");
        assert!(c.iter().all(|&t| t == 3072 || t % 16 == 0));
        let c = candidate_tiles(197, 1, 1, 64);
        assert!(c.contains(&197));
        assert!(c.iter().all(|&t| (1..=197).contains(&t)));
    }

    #[test]
    fn permutations_count() {
        assert_eq!(permutations(0).len(), 1);
        assert_eq!(permutations(2).len(), 2);
        assert_eq!(permutations(3).len(), 6);
    }

    #[test]
    fn baseline_solves_and_fits() {
        let (g, soc, groups) = setup(Strategy::LayerPerLayer, false);
        let homes = assign_homes(&g, &groups, &soc);
        for gr in &groups {
            let s = solve_group(&g, &soc, gr, &homes, &SolverOptions::default(), false).unwrap();
            assert!(s.footprint <= soc.mem.capacity(Level::L1));
            assert!(s.total_iterations() >= 1);
        }
    }

    #[test]
    fn ftl_solves_fused_group() {
        let (g, soc, groups) = setup(Strategy::Ftl, true);
        let homes = assign_homes(&g, &groups, &soc);
        let s = solve_group(&g, &soc, &groups[0], &homes, &SolverOptions::default(), false).unwrap();
        // Fused group: gemm + gelu share the intermediate buffer in L1.
        assert_eq!(s.nodes.len(), 2);
        let inter: Vec<_> = s.buffers.iter().filter(|b| b.role == BufferRole::Intermediate).collect();
        assert_eq!(inter.len(), 1);
        assert!(inter[0].home.is_none(), "fused intermediate has no home level");
    }

    #[test]
    fn homes_spill_intermediate_in_baseline() {
        // The paper's benchmark graph is the MLP *stage* (GEMM+GeLU): the
        // resident set {X, W1, b1, OUT} fits L2, the intermediate doesn't.
        use crate::ir::{ActKind, GraphBuilder};
        let mut b = GraphBuilder::new(DType::Int8);
        let x = b.input("x", &[197, 768]);
        let fc1 = b.linear("fc1", x, 3072, true);
        let act = b.act("gelu", ActKind::Gelu, fc1);
        let g = b.finish(act).unwrap();
        let soc = siracusa_reduced_cluster_only();
        let groups = fuse_groups(&g, Strategy::LayerPerLayer, FusionPolicy::default());
        let homes = assign_homes(&g, &groups, &soc);
        let (h, _) = g.tensor_by_name("fc1_1").unwrap();
        assert_eq!(homes[h], Some(Level::L3), "baseline intermediate spills to L3");
        let (x, _) = g.tensor_by_name("x").unwrap();
        assert_eq!(homes[x], Some(Level::L2));
    }

    #[test]
    fn homes_none_for_fused_intermediate() {
        let (g, soc, groups) = setup(Strategy::Ftl, false);
        let homes = assign_homes(&g, &groups, &soc);
        let (h, _) = g.tensor_by_name("fc1_1").unwrap();
        assert_eq!(homes[h], None, "fused intermediate never materialises");
    }

    #[test]
    fn solve_graph_ftl_beats_baseline_estimate() {
        let (g, soc, base_groups) = setup(Strategy::LayerPerLayer, true);
        let (_, base) = solve_graph(&g, &soc, base_groups, &SolverOptions::default(), false).unwrap();
        let (g2, soc2, ftl_groups) = setup(Strategy::Ftl, true);
        let (_, ftl) = solve_graph(&g2, &soc2, ftl_groups, &SolverOptions::default(), false).unwrap();
        assert!(
            ftl.estimated_cycles() < base.estimated_cycles(),
            "FTL estimate {} must beat baseline {}",
            ftl.estimated_cycles(),
            base.estimated_cycles()
        );
    }

    #[test]
    fn aggressive_fusion_falls_back() {
        // GEMM→GeLU→GEMM fully fused forces gemm1.N = 3072 (full) via
        // fc2's Full(K); W1 tile becomes 768×3072 = 2.3 MiB > L1, so the
        // solver must shrink the group and still succeed.
        let g = vit_mlp(197, 768, 3072, DType::Int8);
        let soc = siracusa_reduced();
        let groups = fuse_groups(&g, Strategy::Ftl, FusionPolicy { max_len: 8, elementwise_only: false });
        assert_eq!(groups.len(), 1);
        let (final_groups, sol) = solve_graph(&g, &soc, groups, &SolverOptions::default(), false).unwrap();
        assert!(final_groups.len() >= 2, "unsolvable 3-node fusion must split");
        assert_eq!(final_groups.iter().map(FusionGroup::len).sum::<usize>(), 3);
        assert_eq!(sol.groups.len(), final_groups.len());
    }

    #[test]
    fn double_buffer_footprint_grows() {
        let (g, soc, groups) = setup(Strategy::Ftl, true);
        let homes = assign_homes(&g, &groups, &soc);
        let _single = solve_group(&g, &soc, &groups[0], &homes, &SolverOptions::default(), false).unwrap();
        let double = solve_group(&g, &soc, &groups[0], &homes, &SolverOptions::default(), true).unwrap();
        assert!(double.double_buffered);
        // Same tiles would double the streamed part; the solver may pick
        // smaller tiles instead, but the footprint must stay within L1.
        assert!(double.footprint <= soc.mem.capacity(Level::L1));
    }

    #[test]
    fn score_matches_build() {
        // The allocation-free scorer must agree with the materialising
        // path on every feasible point it accepts — checked by comparing
        // the winner's (cycles, iterations) against its rebuilt solution.
        for npu in [false, true] {
            for dbuf in [false, true] {
                let (g, soc, groups) = setup(Strategy::Ftl, npu);
                let homes = assign_homes(&g, &groups, &soc);
                let sol = solve_group(&g, &soc, &groups[0], &homes, &SolverOptions::default(), dbuf).unwrap();
                let rebuilt = estimate_cycles(&soc, &sol.nodes, &sol.buffers, &sol.loops, dbuf);
                assert_eq!(
                    sol.estimated_cycles, rebuilt,
                    "stored estimate must equal recomputed estimate (npu={npu}, dbuf={dbuf})"
                );
            }
        }
    }

    #[test]
    fn perf_constraint_ablation_changes_tiles() {
        let (g, soc, groups) = setup(Strategy::LayerPerLayer, false);
        let homes = assign_homes(&g, &groups, &soc);
        let with = solve_group(&g, &soc, &groups[0], &homes, &SolverOptions::default(), false).unwrap();
        // With perf constraints, the N tile is a multiple of 4.
        let n_loop = with.loops.iter().find(|l| l.full == 3072).unwrap();
        assert_eq!(n_loop.tile % 4, 0);
    }
}
