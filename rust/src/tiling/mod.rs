//! The FTL tiling engine — the paper's core contribution.
//!
//! FTL formulates tiling as a constraint-optimisation problem (paper
//! Fig. 1, steps ①–④):
//!
//! 1. **Variable attribution** ([`vars`]): every dimension of every tensor
//!    touched by an operator gets a tile-size variable.
//! 2. **Constraint formulation** ([`constraints`]): three constraint
//!    classes per operator —
//!    *geometric* (output dims linked to input dims via linear
//!    transformations, `in = a·out + b`), *kernel policy* (dataflow
//!    requirements, e.g. the int8 GEMM reduction dimension is never tiled
//!    because requantisation needs the full accumulation), and
//!    *performance* (SIMD-width multiples, minimum tile sizes, to keep
//!    hardware utilisation up).
//! 3. **Fusion** ([`fusion`]): consecutive layers are selected and the
//!    variables of their *shared* tensor's dimensions are **bound**
//!    (equality-linked), merging the per-layer problems into one.
//! 4. **Solve** ([`solver`]): a parallel branch-and-bound search over
//!    candidate tile sizes and loop orders — partial assignments are cut
//!    by admissible L1-capacity and cost lower bounds, the outermost
//!    variable fans out across [`SolverPool`]-budgeted workers sharing
//!    the best-so-far bound, and the winner is bit-identical to the
//!    serial exhaustive sweep ([`solve_group_exhaustive`]) for any
//!    thread count. The objective is an analytic runtime estimate (DMA +
//!    kernel cost over the tile loop nest, with loop-invariant operand
//!    hoisting).
//!
//! The output is a [`TilingSolution`]: per fused group, a loop nest with
//! concrete tile sizes, per-operand L1 buffers and fetch depths — from
//! which [`crate::schedule`] emits the executable tiled schedule.

#![forbid(unsafe_code)]

mod constraints;
mod fusion;
mod pool;
mod problem;
mod solution;
mod solver;
mod vars;

pub use constraints::{emit_node, Constraint};
pub use fusion::{fuse_groups, FusionGroup, FusionPolicy};
pub use pool::{Permits, SearchCounters, SearchStats, SolverPool};
pub use problem::{GroupProblem, OperandRef, Strategy};
pub use solution::{DimSpec, FreeVarChoice, GroupBuffer, GroupSolution, NodeTile, TilingSolution};
pub use solver::{
    assign_homes, assign_homes_with, dma_legs as solver_dma_legs, estimate_cycles, solve_graph, solve_graph_in,
    solve_graph_with, solve_group, solve_group_exhaustive, solve_group_in, HomesPolicy, SolverOptions,
};
pub use vars::{DimVar, VarId, VarTable};
