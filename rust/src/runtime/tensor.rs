//! Host-side tensors and N-D tile gather/scatter.

#![forbid(unsafe_code)]

use anyhow::{ensure, Result};

/// A row-major f32 tensor on the host.
///
/// The deployment target computes in int8, but the numerics-validation
/// path runs the f32 Pallas/XLA kernels — the *transformation* under test
/// (tiling + fusion) is dtype-agnostic.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    /// Shape, row-major.
    pub shape: Vec<usize>,
    /// Elements, `shape.iter().product()` of them.
    pub data: Vec<f32>,
}

impl HostTensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    /// Tensor from data (checked).
    pub fn new(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        ensure!(
            data.len() == shape.iter().product::<usize>(),
            "data length {} does not match shape {:?}",
            data.len(),
            shape
        );
        Ok(Self { shape: shape.to_vec(), data })
    }

    /// Deterministic pseudo-random tensor in [-1, 1] (xorshift-seeded).
    pub fn random(shape: &[usize], seed: u64) -> Self {
        let mut rng = crate::util::prop::Rng::new(seed);
        let n = shape.iter().product();
        let data = (0..n).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
        Self { shape: shape.to_vec(), data }
    }

    /// Number of elements.
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Row-major strides in elements.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    /// Gather a tile `[offsets, offsets+tile_shape)` into a fresh tensor.
    /// Out-of-range parts are zero-filled (conv halo support).
    pub fn gather(&self, offsets: &[usize], tile_shape: &[usize]) -> HostTensor {
        assert_eq!(offsets.len(), self.shape.len());
        assert_eq!(tile_shape.len(), self.shape.len());
        let mut out = HostTensor::zeros(tile_shape);
        let src_strides = self.strides();
        let dst_strides = out.strides();
        let rank = self.shape.len();
        // Iterate all rows (all dims except the last) of the tile.
        let row_len = tile_shape[rank - 1];
        let rows: usize = tile_shape[..rank - 1].iter().product::<usize>().max(1);
        let mut idx = vec![0usize; rank - 1];
        for _ in 0..rows {
            // In-range row?
            let mut src_off = 0usize;
            let mut in_range = true;
            for (d, &i) in idx.iter().enumerate() {
                let src_i = offsets[d] + i;
                if src_i >= self.shape[d] {
                    in_range = false;
                    break;
                }
                src_off += src_i * src_strides[d];
            }
            if in_range {
                let col0 = offsets[rank - 1];
                let n = row_len.min(self.shape[rank - 1].saturating_sub(col0));
                let src_start = src_off + col0 * src_strides[rank - 1];
                let mut dst_off = 0usize;
                for (d, &i) in idx.iter().enumerate() {
                    dst_off += i * dst_strides[d];
                }
                out.data[dst_off..dst_off + n].copy_from_slice(&self.data[src_start..src_start + n]);
            }
            // advance multi-index
            for d in (0..rank - 1).rev() {
                idx[d] += 1;
                if idx[d] < tile_shape[d] {
                    break;
                }
                idx[d] = 0;
            }
        }
        out
    }

    /// Scatter `tile` into `self` at `offsets` (clipped to bounds).
    pub fn scatter(&mut self, offsets: &[usize], tile: &HostTensor) {
        assert_eq!(offsets.len(), self.shape.len());
        assert_eq!(tile.shape.len(), self.shape.len());
        let dst_strides = self.strides();
        let src_strides = tile.strides();
        let rank = self.shape.len();
        let row_len = tile.shape[rank - 1];
        let rows: usize = tile.shape[..rank - 1].iter().product::<usize>().max(1);
        let mut idx = vec![0usize; rank - 1];
        for _ in 0..rows {
            let mut dst_off = 0usize;
            let mut in_range = true;
            for (d, &i) in idx.iter().enumerate() {
                let dst_i = offsets[d] + i;
                if dst_i >= self.shape[d] {
                    in_range = false;
                    break;
                }
                dst_off += dst_i * dst_strides[d];
            }
            if in_range {
                let col0 = offsets[rank - 1];
                let n = row_len.min(self.shape[rank - 1].saturating_sub(col0));
                let mut src_off = 0usize;
                for (d, &i) in idx.iter().enumerate() {
                    src_off += i * src_strides[d];
                }
                let dst_start = dst_off + col0 * dst_strides[rank - 1];
                self.data[dst_start..dst_start + n].copy_from_slice(&tile.data[src_off..src_off + n]);
            }
            for d in (0..rank - 1).rev() {
                idx[d] += 1;
                if idx[d] < tile.shape[d] {
                    break;
                }
                idx[d] = 0;
            }
        }
    }

    /// Max absolute difference against another tensor of the same shape.
    pub fn max_abs_diff(&self, other: &HostTensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data.iter().zip(&other.data).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(shape: &[usize]) -> HostTensor {
        let n: usize = shape.iter().product();
        HostTensor::new(shape, (0..n).map(|i| i as f32).collect()).unwrap()
    }

    #[test]
    fn gather_interior_2d() {
        let t = seq(&[4, 5]);
        let tile = t.gather(&[1, 2], &[2, 2]);
        assert_eq!(tile.data, vec![7.0, 8.0, 12.0, 13.0]);
    }

    #[test]
    fn gather_edge_zero_fills() {
        let t = seq(&[3, 3]);
        let tile = t.gather(&[2, 2], &[2, 2]);
        assert_eq!(tile.data, vec![8.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn scatter_roundtrip() {
        let src = seq(&[6, 7]);
        let mut dst = HostTensor::zeros(&[6, 7]);
        // copy via 2x3 tiles
        for r in (0..6).step_by(2) {
            for c in (0..7).step_by(3) {
                let th = 2.min(6 - r);
                let tw = 3.min(7 - c);
                let tile = src.gather(&[r, c], &[th, tw]);
                dst.scatter(&[r, c], &tile);
            }
        }
        assert_eq!(src.data, dst.data);
    }

    #[test]
    fn gather_1d_and_3d() {
        let t = seq(&[6]);
        assert_eq!(t.gather(&[4], &[3]).data, vec![4.0, 5.0, 0.0]);
        let t3 = seq(&[2, 3, 4]);
        let tile = t3.gather(&[1, 1, 2], &[1, 2, 2]);
        assert_eq!(tile.data, vec![18.0, 19.0, 22.0, 23.0]);
    }

    #[test]
    fn random_deterministic() {
        let a = HostTensor::random(&[4, 4], 42);
        let b = HostTensor::random(&[4, 4], 42);
        assert_eq!(a, b);
        assert!(a.data.iter().all(|v| (-1.0..=1.0).contains(v)));
    }

    #[test]
    fn max_abs_diff() {
        let a = seq(&[2, 2]);
        let mut b = a.clone();
        b.data[3] += 0.5;
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-7);
    }

    #[test]
    fn new_checks_length() {
        assert!(HostTensor::new(&[2, 2], vec![0.0; 3]).is_err());
    }
}
