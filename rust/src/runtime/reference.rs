//! Pure-Rust reference kernels (f32) and whole-graph oracle execution.
//!
//! These mirror `python/compile/kernels/ref.py` exactly — in particular
//! GeLU uses the tanh approximation, the same one `jax.nn.gelu` defaults
//! to — so native results, PJRT artifact results and the Python oracle
//! all agree to float tolerance.

#![forbid(unsafe_code)]

use std::collections::HashMap;

use anyhow::{bail, ensure, Result};

use crate::ir::{ActKind, Graph, Op, TensorId, TensorKind};

use super::HostTensor;

/// `gemm`: `A [M,K] · B [K,N] (+bias)` with optional transposed B.
pub fn gemm(a: &HostTensor, b: &HostTensor, bias: Option<&HostTensor>, transpose_b: bool) -> Result<HostTensor> {
    ensure!(a.shape.len() == 2 && b.shape.len() == 2, "gemm expects rank-2 inputs");
    let (m, k) = (a.shape[0], a.shape[1]);
    let (bk, n) = if transpose_b { (b.shape[1], b.shape[0]) } else { (b.shape[0], b.shape[1]) };
    ensure!(k == bk, "gemm K mismatch: {k} vs {bk}");
    let mut out = HostTensor::zeros(&[m, n]);
    if transpose_b {
        // B is [N, K]: row-dot-row is already contiguous.
        for i in 0..m {
            let arow = &a.data[i * k..(i + 1) * k];
            for j in 0..n {
                let brow = &b.data[j * k..(j + 1) * k];
                let acc: f32 = arow.iter().zip(brow).map(|(x, y)| x * y).sum();
                out.data[i * n + j] = acc;
            }
        }
    } else {
        // §Perf: ikj order — the inner loop updates a contiguous output
        // row with a contiguous B row (auto-vectorises; ~4x over the
        // naive ijk with strided B access on the executor benchmark).
        for i in 0..m {
            let orow = &mut out.data[i * n..(i + 1) * n];
            for p in 0..k {
                let a_ip = a.data[i * k + p];
                let brow = &b.data[p * n..(p + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += a_ip * bv;
                }
            }
        }
    }
    if let Some(bias) = bias {
        for i in 0..m {
            for (o, &bv) in out.data[i * n..(i + 1) * n].iter_mut().zip(&bias.data) {
                *o += bv;
            }
        }
    }
    Ok(out)
}

/// GeLU, tanh approximation (matches `jax.nn.gelu(approximate=True)`).
pub fn gelu_scalar(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044_715 * x * x * x)).tanh())
}

/// Elementwise activation.
pub fn act(kind: ActKind, x: &HostTensor) -> HostTensor {
    let f = |v: f32| match kind {
        ActKind::Gelu => gelu_scalar(v),
        ActKind::Relu => v.max(0.0),
        ActKind::Sigmoid => 1.0 / (1.0 + (-v).exp()),
        ActKind::Identity => v,
    };
    HostTensor { shape: x.shape.clone(), data: x.data.iter().map(|&v| f(v)).collect() }
}

/// Elementwise addition.
pub fn add(a: &HostTensor, b: &HostTensor) -> Result<HostTensor> {
    ensure!(a.shape == b.shape, "add shape mismatch");
    Ok(HostTensor {
        shape: a.shape.clone(),
        data: a.data.iter().zip(&b.data).map(|(x, y)| x + y).collect(),
    })
}

/// Layer normalisation over the last axis.
pub fn layernorm(x: &HostTensor, gamma: &HostTensor, beta: &HostTensor, eps: f32) -> HostTensor {
    let c = *x.shape.last().unwrap();
    let rows = x.numel() / c;
    let mut out = HostTensor::zeros(&x.shape);
    for r in 0..rows {
        let row = &x.data[r * c..(r + 1) * c];
        let mean = row.iter().sum::<f32>() / c as f32;
        let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / c as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for j in 0..c {
            out.data[r * c + j] = (row[j] - mean) * inv * gamma.data[j] + beta.data[j];
        }
    }
    out
}

/// Softmax over the last axis.
pub fn softmax(x: &HostTensor) -> HostTensor {
    let c = *x.shape.last().unwrap();
    let rows = x.numel() / c;
    let mut out = HostTensor::zeros(&x.shape);
    for r in 0..rows {
        let row = &x.data[r * c..(r + 1) * c];
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = row.iter().map(|v| (v - m).exp()).collect();
        let s: f32 = exps.iter().sum();
        for j in 0..c {
            out.data[r * c + j] = exps[j] / s;
        }
    }
    out
}

/// 2-D matrix transpose.
pub fn transpose(x: &HostTensor) -> HostTensor {
    let (m, n) = (x.shape[0], x.shape[1]);
    let mut out = HostTensor::zeros(&[n, m]);
    for i in 0..m {
        for j in 0..n {
            out.data[j * m + i] = x.data[i * n + j];
        }
    }
    out
}

/// NHWC conv2d (naive; used only for oracle validation of conv tilings).
pub fn conv2d(x: &HostTensor, w: &HostTensor, kh: usize, kw: usize, stride: usize, pad: usize) -> HostTensor {
    let (n, h, wi, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let f = w.shape[3];
    let ho = (h + 2 * pad - kh) / stride + 1;
    let wo = (wi + 2 * pad - kw) / stride + 1;
    let mut out = HostTensor::zeros(&[n, ho, wo, f]);
    for b in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                for of in 0..f {
                    let mut acc = 0.0f32;
                    for ky in 0..kh {
                        for kx in 0..kw {
                            let iy = oy * stride + ky;
                            let ix = ox * stride + kx;
                            if iy < pad || ix < pad || iy - pad >= h || ix - pad >= wi {
                                continue;
                            }
                            let (iy, ix) = (iy - pad, ix - pad);
                            for ic in 0..c {
                                let xv = x.data[((b * h + iy) * wi + ix) * c + ic];
                                let wv = w.data[((ky * kw + kx) * c + ic) * f + of];
                                acc += xv * wv;
                            }
                        }
                    }
                    out.data[((b * ho + oy) * wo + ox) * f + of] = acc;
                }
            }
        }
    }
    out
}

/// Execute one op on full tensors.
pub fn run_op(op: &Op, inputs: &[&HostTensor]) -> Result<HostTensor> {
    Ok(match op {
        Op::Gemm { transpose_b, has_bias } => {
            let bias = if *has_bias { Some(inputs[2]) } else { None };
            gemm(inputs[0], inputs[1], bias, *transpose_b)?
        }
        Op::Act(kind) => act(*kind, inputs[0]),
        Op::Add => add(inputs[0], inputs[1])?,
        Op::LayerNorm { eps } => layernorm(inputs[0], inputs[1], inputs[2], *eps),
        Op::Softmax => softmax(inputs[0]),
        Op::Transpose => transpose(inputs[0]),
        Op::Conv2d { kh, kw, stride, pad } => conv2d(inputs[0], inputs[1], *kh, *kw, *stride, *pad),
        Op::Requant => inputs[0].clone(), // numerics identity in the f32 path
    })
}

/// Run the whole graph on full tensors — the un-tiled oracle.
///
/// `bindings` must provide every Input and Weight tensor; returns a map
/// with all tensors (including intermediates and outputs) materialised.
pub fn run_graph(graph: &Graph, bindings: &HashMap<TensorId, HostTensor>) -> Result<HashMap<TensorId, HostTensor>> {
    let mut env = bindings.clone();
    for (id, t) in graph.tensors.iter().enumerate() {
        if matches!(t.kind, TensorKind::Input | TensorKind::Weight) && !env.contains_key(&id) {
            bail!("missing binding for {}", t.name);
        }
    }
    for node in &graph.nodes {
        let inputs: Vec<&HostTensor> = node
            .inputs
            .iter()
            .map(|i| env.get(i).expect("topological order guarantees inputs"))
            .collect();
        let out = run_op(&node.op, &inputs)?;
        ensure!(out.shape == graph.tensors[node.output].shape, "node {} produced wrong shape", node.name);
        env.insert(node.output, out);
    }
    Ok(env)
}

/// Deterministic random bindings for all graph inputs + weights.
pub fn random_bindings(graph: &Graph, seed: u64) -> HashMap<TensorId, HostTensor> {
    let mut env = HashMap::new();
    for (id, t) in graph.tensors.iter().enumerate() {
        if matches!(t.kind, TensorKind::Input | TensorKind::Weight) {
            env.insert(id, HostTensor::random(&t.shape, seed ^ (id as u64 + 1).wrapping_mul(0x9E3779B9)));
        }
    }
    env
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::vit_mlp;
    use crate::ir::DType;

    #[test]
    fn gemm_known_values() {
        let a = HostTensor::new(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = HostTensor::new(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let c = gemm(&a, &b, None, false).unwrap();
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
        let bias = HostTensor::new(&[2], vec![10.0, 20.0]).unwrap();
        let c = gemm(&a, &b, Some(&bias), false).unwrap();
        assert_eq!(c.data, vec![13.0, 23.0, 17.0, 27.0]);
    }

    #[test]
    fn gemm_transpose_b() {
        let a = HostTensor::new(&[1, 3], vec![1.0, 2.0, 3.0]).unwrap();
        let bt = HostTensor::new(&[2, 3], vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0]).unwrap();
        let c = gemm(&a, &bt, None, true).unwrap();
        assert_eq!(c.data, vec![1.0, 2.0]);
    }

    #[test]
    fn gelu_values() {
        assert!((gelu_scalar(0.0)).abs() < 1e-7);
        assert!((gelu_scalar(10.0) - 10.0).abs() < 1e-3);
        assert!(gelu_scalar(-10.0).abs() < 1e-3);
        // gelu(1) ≈ 0.8412
        assert!((gelu_scalar(1.0) - 0.8412).abs() < 1e-3);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = HostTensor::random(&[3, 7], 5);
        let s = softmax(&x);
        for r in 0..3 {
            let sum: f32 = s.data[r * 7..(r + 1) * 7].iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn layernorm_normalises() {
        let x = HostTensor::random(&[4, 16], 9);
        let gamma = HostTensor::new(&[16], vec![1.0; 16]).unwrap();
        let beta = HostTensor::zeros(&[16]);
        let y = layernorm(&x, &gamma, &beta, 1e-5);
        for r in 0..4 {
            let row = &y.data[r * 16..(r + 1) * 16];
            let mean: f32 = row.iter().sum::<f32>() / 16.0;
            assert!(mean.abs() < 1e-5);
        }
    }

    #[test]
    fn transpose_involution() {
        let x = HostTensor::random(&[5, 3], 2);
        assert_eq!(transpose(&transpose(&x)).data, x.data);
    }

    #[test]
    fn run_graph_mlp() {
        let g = vit_mlp(8, 16, 32, DType::F32);
        let bind = random_bindings(&g, 7);
        let env = run_graph(&g, &bind).unwrap();
        let out = g.outputs()[0];
        assert_eq!(env[&out].shape, vec![8, 16]);
        // Output is a composition of finite ops on [-1,1] inputs: finite.
        assert!(env[&out].data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn conv2d_identity_kernel() {
        let x = HostTensor::random(&[1, 4, 4, 1], 3);
        // 1x1 kernel of weight 1.0 = identity
        let w = HostTensor::new(&[1, 1, 1, 1], vec![1.0]).unwrap();
        let y = conv2d(&x, &w, 1, 1, 1, 0);
        assert_eq!(y.data, x.data);
    }
}
