//! Numerics runtime: execute the tiled/fused schedules on real data.
//!
//! Two backends implement [`KernelBackend`]:
//!
//! * [`NativeBackend`] — pure-Rust reference kernels (always available;
//!   used by `cargo test` so the tiling/fusion *transformation* is
//!   validated without artifacts);
//! * [`PjrtBackend`] — loads the AOT-compiled HLO tile executables
//!   produced by `python/compile/aot.py` (see `artifacts/manifest.json`)
//!   and runs them on the PJRT CPU client via the `xla` crate. Python is
//!   never on this path — artifacts are compiled once at build time.
//!
//! [`TileExecutor`] walks a [`crate::tiling::TilingSolution`] exactly like
//! the schedule generator does — same loop nests, same remainder tiles —
//! slicing input tiles out of the full tensors, invoking one kernel per
//! node per iteration, and scattering output tiles back. Fused
//! intermediates live only in the executor's "L1" scratch, mirroring the
//! hardware behaviour. Comparing the result against the un-tiled oracle
//! ([`reference::run_graph`]) proves FTL is numerics-preserving.

#![forbid(unsafe_code)]

mod backend;
mod executor;
mod pjrt;
pub mod reference;
mod tensor;

pub use backend::{KernelBackend, NativeBackend};
pub use executor::TileExecutor;
pub use pjrt::{fused_gemm_gelu_key, tile_key, Manifest, ManifestEntry, PjrtBackend};
pub use tensor::HostTensor;
