//! The tile executor: run a solved tiling on real data, tile by tile.

#![forbid(unsafe_code)]

use std::collections::HashMap;

use anyhow::{ensure, Context, Result};

use crate::ir::{Graph, TensorId};
use crate::memory::BufferRole;
use crate::tiling::{GroupSolution, TilingSolution};

use super::backend::KernelBackend;
use super::HostTensor;

/// Executes a [`TilingSolution`] with a [`KernelBackend`].
///
/// The executor walks the exact loop nests of the solution (including
/// remainder tiles), gathers input/weight tiles from the materialised
/// tensors, runs each node's kernel on the tile, keeps fused
/// intermediates in per-iteration scratch (the L1 analogue — they never
/// touch the full-tensor environment), and scatters output tiles back.
pub struct TileExecutor<B: KernelBackend> {
    backend: B,
    /// Tiles executed (for reports).
    pub tiles_run: u64,
    /// Kernels invoked.
    pub kernels_run: u64,
}

impl<B: KernelBackend> TileExecutor<B> {
    /// New executor over a backend.
    pub fn new(backend: B) -> Self {
        Self { backend, tiles_run: 0, kernels_run: 0 }
    }

    /// Access the backend (e.g. to read PJRT stats).
    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Execute the full solution. `bindings` provides inputs + weights;
    /// returns the environment with outputs (and inter-group
    /// intermediates) materialised. Fused intra-group intermediates are
    /// *not* in the result — exactly like on the SoC.
    pub fn run(
        &mut self,
        graph: &Graph,
        solution: &TilingSolution,
        bindings: &HashMap<TensorId, HostTensor>,
    ) -> Result<HashMap<TensorId, HostTensor>> {
        let mut env = bindings.clone();
        for group in &solution.groups {
            self.run_group(graph, group, &mut env)
                .with_context(|| format!("executing group [{}]", group_name(group)))?;
        }
        Ok(env)
    }

    fn run_group(
        &mut self,
        graph: &Graph,
        g: &GroupSolution,
        env: &mut HashMap<TensorId, HostTensor>,
    ) -> Result<()> {
        // Materialise output tensors.
        for b in &g.buffers {
            if b.role == BufferRole::Output && !env.contains_key(&b.tensor) {
                env.insert(b.tensor, HostTensor::zeros(&graph.tensors[b.tensor].shape));
            }
        }

        for state in g.iterations() {
            // Per-iteration L1 scratch: buffer index → tile.
            let mut scratch: HashMap<usize, HostTensor> = HashMap::new();

            for node in &g.nodes {
                let mut inputs: Vec<HostTensor> = Vec::with_capacity(node.input_bufs.len());
                for &bi in &node.input_bufs {
                    let b = &g.buffers[bi];
                    let tile = match scratch.get(&bi) {
                        Some(t) => t.clone(),
                        None => {
                            let full = env
                                .get(&b.tensor)
                                .with_context(|| format!("tensor {} not materialised", b.name))?;
                            full.gather(&b.offsets_at(&state), &b.shape_at(&state))
                        }
                    };
                    inputs.push(tile);
                }
                let in_refs: Vec<&HostTensor> = inputs.iter().collect();
                let out = self.backend.exec(&node.op, &in_refs)?;
                let ob = &g.buffers[node.output_buf];
                ensure!(
                    out.shape == ob.shape_at(&state),
                    "node {}: kernel produced {:?}, expected tile {:?}",
                    node.name,
                    out.shape,
                    ob.shape_at(&state)
                );
                scratch.insert(node.output_buf, out);
                self.kernels_run += 1;
            }

            // Scatter output tiles into the materialised tensors.
            for (bi, b) in g.buffers.iter().enumerate() {
                if b.role != BufferRole::Output {
                    continue;
                }
                if let Some(tile) = scratch.get(&bi) {
                    let full = env.get_mut(&b.tensor).expect("materialised above");
                    full.scatter(&b.offsets_at(&state), tile);
                }
            }
            self.tiles_run += 1;
        }
        Ok(())
    }
}

fn group_name(g: &GroupSolution) -> String {
    g.nodes.iter().map(|n| n.name.as_str()).collect::<Vec<_>>().join("+")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::{deep_mlp, vit_mlp, vit_mlp_block};
    use crate::ir::DType;
    use crate::runtime::reference::{random_bindings, run_graph};
    use crate::runtime::NativeBackend;
    use crate::soc::{siracusa_reduced, siracusa_reduced_cluster_only};
    use crate::tiling::{fuse_groups, solve_graph, FusionPolicy, SolverOptions, Strategy};

    fn check_numerics(graph: &crate::ir::Graph, strategy: Strategy, npu: bool, dbuf: bool) {
        let soc = if npu { siracusa_reduced() } else { siracusa_reduced_cluster_only() };
        let groups = fuse_groups(graph, strategy, FusionPolicy::default());
        let (final_groups, sol) = solve_graph(graph, &soc, groups, &SolverOptions::default(), dbuf).unwrap();
        let bindings = random_bindings(graph, 42);
        let oracle = run_graph(graph, &bindings).unwrap();
        let mut exec = TileExecutor::new(NativeBackend);
        let env = exec.run(graph, &sol, &bindings).unwrap();
        for &out in &graph.outputs() {
            let diff = env[&out].max_abs_diff(&oracle[&out]);
            assert!(
                diff < 1e-3,
                "{} tiled output differs from oracle by {diff} (strategy {strategy:?})",
                graph.tensors[out].name
            );
        }
        // Fused intermediates must NOT be materialised.
        if strategy == Strategy::Ftl {
            let homes = crate::tiling::assign_homes(graph, &final_groups, &soc);
            for (t, h) in homes.iter().enumerate() {
                if h.is_none() && graph.tensors[t].kind == crate::ir::TensorKind::Intermediate {
                    assert!(!env.contains_key(&t), "fused intermediate {} leaked", graph.tensors[t].name);
                }
            }
        }
    }

    #[test]
    fn small_mlp_baseline_matches_oracle() {
        let g = vit_mlp(16, 24, 48, DType::F32);
        check_numerics(&g, Strategy::LayerPerLayer, false, false);
    }

    #[test]
    fn small_mlp_ftl_matches_oracle() {
        let g = vit_mlp(16, 24, 48, DType::F32);
        check_numerics(&g, Strategy::Ftl, false, false);
    }

    #[test]
    fn vit_base_ftl_matches_oracle() {
        // The paper's actual workload size — heavier test (~1 s native).
        let g = vit_mlp(197, 768, 3072, DType::Int8);
        check_numerics(&g, Strategy::Ftl, true, false);
    }

    #[test]
    fn deep_mlp_both_strategies() {
        let g = deep_mlp(24, 32, 3, DType::F32);
        check_numerics(&g, Strategy::LayerPerLayer, false, false);
        check_numerics(&g, Strategy::Ftl, false, true);
    }

    #[test]
    fn residual_block_ftl_matches_oracle() {
        // Exercises LayerNorm (Full last dim), the Add diamond, and
        // multi-group execution.
        let g = vit_mlp_block(16, 32, 64, DType::F32);
        check_numerics(&g, Strategy::Ftl, false, false);
        check_numerics(&g, Strategy::LayerPerLayer, true, false);
    }

    #[test]
    fn executor_counts_tiles() {
        let g = vit_mlp(16, 24, 48, DType::F32);
        let soc = siracusa_reduced_cluster_only();
        let groups = fuse_groups(&g, Strategy::Ftl, FusionPolicy::default());
        let (_, sol) = solve_graph(&g, &soc, groups, &SolverOptions::default(), false).unwrap();
        let mut exec = TileExecutor::new(NativeBackend);
        exec.run(&g, &sol, &random_bindings(&g, 1)).unwrap();
        let expect: u64 = sol.groups.iter().map(|gr| gr.total_iterations() as u64).sum();
        assert_eq!(exec.tiles_run, expect);
        assert!(exec.kernels_run >= exec.tiles_run);
    }
}
