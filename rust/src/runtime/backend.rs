//! Kernel backend abstraction.

#![forbid(unsafe_code)]

use anyhow::Result;

use crate::ir::Op;

use super::{reference, HostTensor};

/// Executes one kernel on concrete tile tensors.
///
/// The tile executor is generic over this: `cargo test` uses
/// [`NativeBackend`]; the end-to-end example uses
/// [`super::PjrtBackend`] with the AOT artifacts.
pub trait KernelBackend {
    /// Execute `op` on `inputs`, returning the output tile.
    fn exec(&mut self, op: &Op, inputs: &[&HostTensor]) -> Result<HostTensor>;

    /// Backend name for reports.
    fn name(&self) -> &'static str;
}

/// Pure-Rust reference backend.
#[derive(Debug, Default, Clone)]
pub struct NativeBackend;

impl KernelBackend for NativeBackend {
    fn exec(&mut self, op: &Op, inputs: &[&HostTensor]) -> Result<HostTensor> {
        reference::run_op(op, inputs)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ActKind;

    #[test]
    fn native_backend_runs_ops() {
        let mut b = NativeBackend;
        let x = HostTensor::random(&[3, 4], 1);
        let y = b.exec(&Op::Act(ActKind::Relu), &[&x]).unwrap();
        assert!(y.data.iter().all(|&v| v >= 0.0));
        assert_eq!(b.name(), "native");
    }
}
