//! PJRT backend: load AOT-compiled HLO tile executables and run them.
//!
//! The interchange format is **HLO text** (see `python/compile/aot.py`):
//! jax ≥ 0.5 serialises `HloModuleProto`s with 64-bit instruction ids
//! which the crate's XLA (xla_extension 0.5.1) rejects; the text parser
//! reassigns ids and round-trips cleanly.

#![forbid(unsafe_code)]

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::ir::{ActKind, Op};
use crate::util::json::{parse, Json};

use super::backend::KernelBackend;
use super::HostTensor;

/// Canonical artifact key for an (op, tile-shapes) pair. Must match the
/// naming scheme in `python/compile/aot.py`.
///
/// Examples: `gemm_b_m197_k768_n256`, `gelu_197x256`,
/// `gemm_gelu_b_m197_k768_n256` (the fused Pallas kernel).
pub fn tile_key(op: &Op, in_shapes: &[&[usize]], out_shape: &[usize]) -> Option<String> {
    match op {
        Op::Gemm { transpose_b: false, has_bias } => {
            let m = out_shape[0];
            let n = out_shape[1];
            let k = in_shapes[0][1];
            let b = if *has_bias { "_b" } else { "" };
            Some(format!("gemm{b}_m{m}_k{k}_n{n}"))
        }
        Op::Act(ActKind::Gelu) => {
            let dims: Vec<String> = out_shape.iter().map(|d| d.to_string()).collect();
            Some(format!("gelu_{}", dims.join("x")))
        }
        Op::Act(ActKind::Relu) => {
            let dims: Vec<String> = out_shape.iter().map(|d| d.to_string()).collect();
            Some(format!("relu_{}", dims.join("x")))
        }
        Op::Add => {
            let dims: Vec<String> = out_shape.iter().map(|d| d.to_string()).collect();
            Some(format!("add_{}", dims.join("x")))
        }
        // Other ops fall back to the native backend.
        _ => None,
    }
}

/// Key for the fused GEMM+GeLU Pallas kernel artifact.
pub fn fused_gemm_gelu_key(m: usize, k: usize, n: usize, bias: bool) -> String {
    let b = if bias { "_b" } else { "" };
    format!("gemm_gelu{b}_m{m}_k{k}_n{n}")
}

/// One artifact entry from `manifest.json`.
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    /// Canonical key (see [`tile_key`]).
    pub name: String,
    /// HLO text file, relative to the manifest directory.
    pub file: String,
    /// Expected input shapes.
    pub in_shapes: Vec<Vec<usize>>,
    /// Expected output shape.
    pub out_shape: Vec<usize>,
}

/// Parsed artifact manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Entries keyed by canonical name.
    pub entries: HashMap<String, ManifestEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| format!("reading {}", path.display()))?;
        let v = parse(&text)?;
        let mut entries = HashMap::new();
        for e in v.get("entries")?.as_arr()? {
            let name = e.get("name")?.as_str()?.to_string();
            let file = e.get("file")?.as_str()?.to_string();
            let in_shapes = e
                .get("in_shapes")?
                .as_arr()?
                .iter()
                .map(|s| s.as_arr()?.iter().map(Json::as_usize).collect::<Result<Vec<_>>>())
                .collect::<Result<Vec<_>>>()?;
            let out_shape = e.get("out_shape")?.as_arr()?.iter().map(Json::as_usize).collect::<Result<Vec<_>>>()?;
            entries.insert(name.clone(), ManifestEntry { name, file, in_shapes, out_shape });
        }
        Ok(Self { dir: dir.to_path_buf(), entries })
    }

    /// True if an artifact with this key exists.
    pub fn has(&self, key: &str) -> bool {
        self.entries.contains_key(key)
    }
}

/// PJRT CPU backend with lazily compiled executables.
#[cfg(feature = "xla")]
pub struct PjrtBackend {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Kernel invocations served (for reports).
    pub invocations: u64,
}

#[cfg(feature = "xla")]
impl PjrtBackend {
    /// Create from an artifact directory containing `manifest.json`.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Self { client, manifest, compiled: HashMap::new(), invocations: 0 })
    }

    /// The manifest in use.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn executable(&mut self, key: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.compiled.contains_key(key) {
            let entry = self
                .manifest
                .entries
                .get(key)
                .ok_or_else(|| anyhow!("artifact '{key}' not in manifest ({} entries)", self.manifest.entries.len()))?;
            let path = self.manifest.dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
                .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(|e| anyhow!("compiling {key}: {e:?}"))?;
            self.compiled.insert(key.to_string(), exe);
        }
        Ok(&self.compiled[key])
    }

    /// Run an artifact by key on concrete tensors.
    pub fn run(&mut self, key: &str, inputs: &[&HostTensor]) -> Result<HostTensor> {
        // Validate shapes against the manifest before the FFI boundary.
        let entry =
            self.manifest.entries.get(key).ok_or_else(|| anyhow!("artifact '{key}' not in manifest"))?.clone();
        if entry.in_shapes.len() != inputs.len() {
            bail!("artifact {key}: expected {} inputs, got {}", entry.in_shapes.len(), inputs.len());
        }
        for (i, (t, exp)) in inputs.iter().zip(&entry.in_shapes).enumerate() {
            if &t.shape != exp {
                bail!("artifact {key}: input {i} shape {:?} != expected {:?}", t.shape, exp);
            }
        }
        let exe = self.executable(key)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<usize> = t.shape.clone();
                let lit = xla::Literal::vec1(&t.data);
                lit.reshape(&dims.iter().map(|&d| d as i64).collect::<Vec<_>>())
                    .map_err(|e| anyhow!("reshape literal: {e:?}"))
            })
            .collect::<Result<Vec<_>>>()?;
        let result = exe.execute::<xla::Literal>(&lits).map_err(|e| anyhow!("executing {key}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let data = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        self.invocations += 1;
        HostTensor::new(&entry.out_shape, data)
    }
}

#[cfg(feature = "xla")]
impl KernelBackend for PjrtBackend {
    fn exec(&mut self, op: &Op, inputs: &[&HostTensor]) -> Result<HostTensor> {
        let shapes: Vec<&[usize]> = inputs.iter().map(|t| t.shape.as_slice()).collect();
        // Output shape from IR shape inference on the tile shapes.
        let out_shape = op.infer_shape(&shapes)?;
        match tile_key(op, &shapes, &out_shape) {
            Some(key) if self.manifest.has(&key) => self.run(&key, inputs),
            // No artifact for this (op, shape): fall back to the native
            // reference so mixed graphs still validate end-to-end.
            _ => super::reference::run_op(op, inputs),
        }
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// Offline stub compiled when the `xla` feature is off (the default — the
/// build has no network access and `xla_extension` ships native XLA
/// libraries). It still loads and validates the artifact manifest so the
/// tooling flow (`ftl emit-tiles` → `aot.py` → `ftl run`) stays
/// exercisable; kernel execution falls back to the native reference
/// backend, and direct artifact invocation ([`PjrtBackend::run`]) reports
/// a clear error. Build with `--features xla` (after adding the `xla`
/// dependency) for real PJRT execution.
#[cfg(not(feature = "xla"))]
pub struct PjrtBackend {
    manifest: Manifest,
    /// Kernel invocations served via real artifacts (always 0 in the stub).
    pub invocations: u64,
}

#[cfg(not(feature = "xla"))]
impl PjrtBackend {
    /// Create from an artifact directory containing `manifest.json`.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        Ok(Self { manifest: Manifest::load(artifact_dir)?, invocations: 0 })
    }

    /// The manifest in use.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Direct artifact execution is unavailable without the `xla` feature.
    pub fn run(&mut self, key: &str, _inputs: &[&HostTensor]) -> Result<HostTensor> {
        if !self.manifest.has(key) {
            bail!("artifact '{key}' not in manifest ({} entries)", self.manifest.entries.len());
        }
        bail!(
            "artifact '{key}': ftl was built without the `xla` feature — rebuild with `--features xla` \
             to execute PJRT artifacts"
        )
    }
}

#[cfg(not(feature = "xla"))]
impl KernelBackend for PjrtBackend {
    fn exec(&mut self, op: &Op, inputs: &[&HostTensor]) -> Result<HostTensor> {
        // Native fallback keeps `ftl run --artifacts ...` usable offline.
        super::reference::run_op(op, inputs)
    }

    fn name(&self) -> &'static str {
        "pjrt-stub"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_key_format() {
        let op = Op::Gemm { transpose_b: false, has_bias: true };
        let key = tile_key(&op, &[&[197, 768], &[768, 256], &[256]], &[197, 256]).unwrap();
        assert_eq!(key, "gemm_b_m197_k768_n256");
        let op = Op::Act(ActKind::Gelu);
        assert_eq!(tile_key(&op, &[&[197, 256]], &[197, 256]).unwrap(), "gelu_197x256");
        assert_eq!(fused_gemm_gelu_key(197, 768, 256, true), "gemm_gelu_b_m197_k768_n256");
        // Unsupported ops yield None (native fallback).
        assert!(tile_key(&Op::Softmax, &[&[4, 4]], &[4, 4]).is_none());
    }

    #[test]
    fn manifest_parse() {
        let dir = std::env::temp_dir().join(format!("ftl_manifest_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"entries":[{"name":"gelu_4x4","file":"gelu_4x4.hlo.txt",
                "in_shapes":[[4,4]],"out_shape":[4,4]}]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.has("gelu_4x4"));
        assert_eq!(m.entries["gelu_4x4"].out_shape, vec![4, 4]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_missing_dir_errors() {
        assert!(Manifest::load(Path::new("/nonexistent/ftl")).is_err());
    }
}
