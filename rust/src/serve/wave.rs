//! Shared fairness-exercise drivers for the priority lanes.
//!
//! One source of truth for the two harness shapes run by
//! `examples/deploy_server.rs --self-test`, `ftl serve --self-test` and
//! `rust/benches/lane_contention.rs` — examples, the binary and benches
//! are separate compilation targets, so the only way they can share a
//! driver is through the library. These are demo/verification
//! harnesses, not part of the serving API proper:
//!
//! * [`saturated_shares`] — the deterministic virtual-clock core:
//!   unit-cost quanta over permanently backlogged lanes. Pure integer
//!   WFQ, identical output on any host at any thread count (the CI
//!   fairness smoke greps it).
//! * [`two_tenant_wave`] — the threaded 3:1 wave over a real
//!   [`BatchScheduler`] with distinct cold solves.
//! * [`mixed_lane_wave`] — a seeded random mix of lanes and warm/cold
//!   requests over a traced scheduler, drained to quiescence — the
//!   driver behind the latency-histogram merge-invariant checks
//!   (property test and self-tests).
//! * [`streaming_probe`] / [`v0_probe`] — over-the-wire clients for the
//!   async front door ([`super::frontend`]): the v1 streaming contract
//!   (plan strictly before done, out-of-order ids) and bare legacy-line
//!   compatibility, run against a real TCP address.
//! * [`WireClient`] / [`seeded_wire_wave`] — the reusable over-the-wire
//!   traffic generator behind `ftl soak` ([`crate::soak`]): a seeded
//!   mix of warm repeats and parametric cold solves across lanes,
//!   deadlines and both protocol framings, multiplexed on real TCP.
//!
//! The threaded wave's early-share measurement deliberately reads the
//! dispatcher's own per-lane `batches` counters (sampled by a monitor
//! thread the first time the total crosses the window) rather than
//! requester-thread completion order: a waiter that was served in
//! quantum *k* can be descheduled by the OS and wake after waiters
//! served later, so completion order on an oversubscribed host is
//! noise — the scheduler's counters are the serve order as the
//! scheduler made it.

#![forbid(unsafe_code)]

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Result};

use crate::config::DeployConfig;
use crate::coordinator::experiments;
use crate::metrics::BatchStats;
use crate::tiling::Strategy;

use super::batch::{AdmissionPolicy, BatchOptions, BatchScheduler};
use super::lanes::{LaneSet, LaneSpec};
use super::proto;
use super::service::{PlanService, ServeOptions};
use super::trace::TraceOptions;

/// Saturated run on the deterministic scheduling core: `quanta`
/// unit-cost quanta over the named `(name, weight)` lanes, every lane
/// kept permanently backlogged. Returns the per-lane served-quantum
/// counts, index-aligned with the input. Under WFQ these track the
/// weight shares within one quantum — e.g. `[("gold", 3), ("free", 1)]`
/// over 16 quanta is exactly `[12, 4]`.
pub fn saturated_shares(lanes_spec: &[(&str, u64)], quanta: u64) -> Vec<u64> {
    let specs: Vec<LaneSpec> = lanes_spec.iter().map(|&(n, w)| LaneSpec::new(n, w, 64)).collect();
    let mut lanes: LaneSet<u64> = LaneSet::new(specs);
    let idx: Vec<usize> = lanes_spec.iter().map(|&(n, _)| lanes.resolve(Some(n))).collect();
    let mut served = vec![0u64; lanes_spec.len()];
    for tick in 0..quanta {
        for &l in &idx {
            // Top up; a bounce off the queue cap still leaves a backlog.
            let _ = lanes.try_push(l, tick);
        }
        let lane = lanes.pick().expect("every lane is backlogged");
        lanes.drain(lane, 1);
        lanes.charge(lane, 1);
        served[idx.iter().position(|&x| x == lane).expect("only named lanes are picked")] += 1;
    }
    served
}

/// Outcome of [`two_tenant_wave`].
pub struct WaveReport {
    /// Quanta dispatched from the `gold` lane at the sample point.
    pub gold_early: u64,
    /// Total quanta dispatched at the sample point (≥ the requested
    /// window; normally window or window + 1 — each quantum is a full
    /// solve + simulation, far slower than the monitor's poll).
    pub total_early: u64,
    /// Final scheduler stats after the wave drained.
    pub stats: BatchStats,
}

/// Drive a fresh scheduler with two lanes — `gold` (weight 3) and
/// `free` (weight 1) — and `per_lane` *distinct* cold requests per lane
/// released at the same instant (barrier), one request per WFQ quantum
/// (`max_batch: 1`). Blocks until the wave fully drains; a failing
/// request surfaces as an `Err`, never as a hang (all fallible setup
/// happens before the threads spawn, and the monitor is released when
/// the requesters finish, whether or not the window was reached).
///
/// Asserts the invariants that must hold regardless of scheduling
/// noise: every request served, nothing shed or timed out, each lane
/// charged exactly one solve + one sim of cold work per request, and
/// the scheduler totals equal to the lane sums. The *fairness* judgment
/// on `gold_early / total_early` (≈ 3/4 under WFQ) is left to the
/// caller, which knows its tolerance.
pub fn two_tenant_wave(per_lane: usize, window: u64) -> Result<WaveReport> {
    ensure!(per_lane >= 1, "wave needs at least one request per lane");
    ensure!(
        (1..=2 * per_lane as u64).contains(&window),
        "window must lie within the wave's {} total quanta",
        2 * per_lane
    );
    let service = Arc::new(PlanService::new(ServeOptions::default()));
    let sched = BatchScheduler::new(
        service,
        BatchOptions {
            queue_capacity: 64,
            batch_window: Duration::from_millis(1),
            // One request per quantum: fairness at request granularity.
            max_batch: 1,
            policy: AdmissionPolicy::Block,
            lanes: vec![LaneSpec::new("gold", 3, 64), LaneSpec::new("free", 1, 64)],
            trace: TraceOptions::default(),
        },
    );
    // Build every request up front: nothing fallible runs between spawn
    // and the barrier, so the barrier always completes.
    let mut requests: Vec<(String, &'static str, crate::ir::Graph, DeployConfig)> = Vec::new();
    for (lane, is_gold) in [("gold", true), ("free", false)] {
        for i in 0..per_lane {
            // Distinct shape per request (gold even seq lengths, free
            // odd — disjoint for any per_lane): every request is a cold
            // solve, so fairness is measured in real cold work, not
            // cache hits.
            let seq_len = if is_gold { 16 + 8 * i } else { 17 + 8 * i };
            let graph = experiments::vit_mlp_stage(seq_len, 24, 48);
            let cfg = DeployConfig::preset("cluster-only", Strategy::Ftl)?;
            requests.push((format!("{lane}-{i}"), lane, graph, cfg));
        }
    }
    let barrier = Barrier::new(requests.len());
    let requesters_done = AtomicBool::new(false);
    let mut early: Option<(u64, u64)> = None;
    let mut first_error: Option<anyhow::Error> = None;
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (workload, lane, graph, cfg) in requests {
            let (sched, barrier) = (&sched, &barrier);
            handles.push(s.spawn(move || -> Result<()> {
                barrier.wait();
                let outcome = sched.deploy_in_lane(&workload, graph, cfg, Some(lane), None)?;
                ensure!(outcome.kind() == "OK", "wave request {workload} must be served");
                Ok(())
            }));
        }
        // Monitor: first snapshot of the dispatcher's own counters at or
        // after the window — or at whatever the requesters reached, if
        // they finished (possibly by failing) without crossing it.
        let monitor = {
            let (sched, done) = (&sched, &requesters_done);
            s.spawn(move || loop {
                let st = sched.stats();
                if st.batches >= window || done.load(Ordering::Acquire) {
                    let gold = st.lanes.iter().find(|l| l.name == "gold").map_or(0, |l| l.batches);
                    return (gold, st.batches);
                }
                std::thread::sleep(Duration::from_micros(200));
            })
        };
        // Collect every requester before releasing the monitor, so a
        // failed request can never leave the monitor spinning.
        for h in handles {
            let result = h.join().unwrap_or_else(|_| Err(anyhow!("wave thread panicked")));
            if let Err(e) = result {
                first_error.get_or_insert(e);
            }
        }
        requesters_done.store(true, Ordering::Release);
        match monitor.join() {
            Ok(sample) => early = Some(sample),
            Err(_) => {
                if first_error.is_none() {
                    first_error = Some(anyhow!("wave monitor panicked"));
                }
            }
        }
    });
    if let Some(e) = first_error {
        return Err(e.context("two-tenant wave request failed"));
    }
    let (gold_early, total_early) = early.expect("monitor joined above");
    let stats = sched.stats();
    let by = |name: &str| stats.lanes.iter().find(|l| l.name == name).cloned().unwrap_or_default();
    let (gold, free) = (by("gold"), by("free"));
    ensure!(gold.served == per_lane as u64 && free.served == per_lane as u64, "every request must drain");
    ensure!(stats.shed == 0 && stats.timeouts == 0, "nothing may shed or time out in the wave");
    // Every request is a distinct cold fingerprint: one solve + one sim
    // each, charged to its lane.
    ensure!(
        gold.cold_work == 2 * per_lane as u64 && free.cold_work == 2 * per_lane as u64,
        "each lane's drained cold work is one solve + one sim per request (got {} / {})",
        gold.cold_work,
        free.cold_work
    );
    ensure!(
        stats.lanes.iter().map(|l| l.batched_requests).sum::<u64>() == stats.batched_requests
            && stats.lanes.iter().map(|l| l.shed).sum::<u64>() == stats.shed
            && stats.lanes.iter().map(|l| l.timeouts).sum::<u64>() == stats.timeouts,
        "scheduler totals must equal the per-lane sums"
    );
    Ok(WaveReport { gold_early, total_early, stats })
}

/// Randomized mixed-lane wave for the latency invariants: `total`
/// requests split across the `gold`/`free`/`default` lanes by a
/// deterministic LCG over `seed`, mixing warm fast-path repeats (one
/// fingerprint is pre-warmed before the wave) with distinct cold
/// solves, all released at one barrier. Blocks until every request is
/// served, then returns the (traced, quiescent) scheduler so the caller
/// can assert tracer invariants — per-lane histogram merge equals the
/// scheduler-wide histogram, journal/slowlog contents, span counts.
pub fn mixed_lane_wave(seed: u64, total: usize) -> Result<BatchScheduler> {
    ensure!(total >= 1, "wave needs at least one request");
    let cap = 64usize.max(total);
    let service = Arc::new(PlanService::new(ServeOptions::default()));
    let sched = BatchScheduler::new(
        service,
        BatchOptions {
            queue_capacity: cap,
            batch_window: Duration::from_millis(1),
            max_batch: 4,
            policy: AdmissionPolicy::Block,
            lanes: vec![LaneSpec::new("gold", 3, cap), LaneSpec::new("free", 1, cap)],
            trace: TraceOptions::default(),
        },
    );
    // Pre-warm one fingerprint so the wave mixes true warm fast-path
    // hits with cold solves in every lane.
    let warm_cfg = DeployConfig::preset("cluster-only", Strategy::Ftl)?;
    let outcome = sched.deploy("prewarm", experiments::vit_mlp_stage(16, 24, 48), warm_cfg)?;
    ensure!(outcome.kind() == "OK", "pre-warm request must be served");
    // Deterministic LCG: lane and warm/cold draws reproduce per seed.
    let mut state = seed.wrapping_mul(2).wrapping_add(1);
    let mut next = move || {
        state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        state >> 33
    };
    let lane_names = [Some("gold"), Some("free"), None];
    let mut requests: Vec<(String, Option<&'static str>, crate::ir::Graph, DeployConfig)> = Vec::new();
    for i in 0..total {
        let lane = lane_names[(next() % 3) as usize];
        // Half the draws (on average) repeat the pre-warmed shape; the
        // rest are distinct cold solves (24 + 8i never collides with 16).
        let seq_len = if next() % 2 == 0 { 16 } else { 24 + 8 * i };
        let graph = experiments::vit_mlp_stage(seq_len, 24, 48);
        let cfg = DeployConfig::preset("cluster-only", Strategy::Ftl)?;
        requests.push((format!("mix-{i}"), lane, graph, cfg));
    }
    let barrier = Barrier::new(requests.len());
    let mut first_error: Option<anyhow::Error> = None;
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (workload, lane, graph, cfg) in requests {
            let (sched, barrier) = (&sched, &barrier);
            handles.push(s.spawn(move || -> Result<()> {
                barrier.wait();
                let outcome = sched.deploy_in_lane(&workload, graph, cfg, lane, None)?;
                ensure!(outcome.kind() == "OK", "wave request {workload} must be served");
                Ok(())
            }));
        }
        for h in handles {
            let result = h.join().unwrap_or_else(|_| Err(anyhow!("wave thread panicked")));
            if let Err(e) = result {
                first_error.get_or_insert(e);
            }
        }
    });
    if let Some(e) = first_error {
        return Err(e.context("mixed-lane wave request failed"));
    }
    Ok(sched)
}

/// Report from [`streaming_probe`] — the shared over-the-wire exercise
/// of the v1 front door (`ftl serve --self-test` and
/// `examples/deploy_server.rs` both run it against their own server).
pub struct StreamProbe {
    pub plan_events: usize,
    pub sim_events: usize,
    pub done_events: usize,
    /// The interleaved warm request's terminal frame arrived before the
    /// cold one's — out-of-order completion on one connection.
    pub out_of_order: bool,
}

/// Read one newline-terminated JSON reply off the probe connection.
fn read_reply(reader: &mut std::io::BufReader<std::net::TcpStream>) -> Result<crate::util::json::Json> {
    use std::io::BufRead;
    let mut line = String::new();
    let n = reader.read_line(&mut line)?;
    ensure!(n > 0, "server closed the connection mid-probe");
    crate::util::json::parse(line.trim())
}

/// Drive the async front door at `addr` over real TCP and assert the
/// streaming contract: a cold v1 `DEPLOY` answers `plan` strictly
/// before `done` with at least one per-phase `sim` event between, a
/// warm repeat collapses to a single terminal frame, and a cold + warm
/// pair written back to back completes out of order (warm terminal
/// first), each frame tagged with its own request id.
pub fn streaming_probe(addr: &str) -> Result<StreamProbe> {
    use std::io::Write;
    let mut stream = std::net::TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(60)))?;
    let mut reader = std::io::BufReader::new(stream.try_clone()?);
    let (mut plan_events, mut sim_events, mut done_events) = (0usize, 0usize, 0usize);

    // Cold deploy on id 1: plan → sim* → done, all on id 1.
    stream.write_all(b"FTL1 1 DEPLOY stage-16x24x48 cluster-only ftl\n")?;
    let mut kinds: Vec<String> = Vec::new();
    loop {
        let j = read_reply(&mut reader)?;
        ensure!(j.get("id")?.as_u64()? == 1, "cold deploy events must carry id 1: {j}");
        ensure!(j.get("v")?.as_u64()? == 1, "v1 events must carry the protocol version: {j}");
        let kind = j.get("event")?.as_str()?.to_string();
        let terminal = kind == "done" || kind == "error";
        kinds.push(kind);
        if terminal {
            break;
        }
    }
    ensure!(kinds.first().map(String::as_str) == Some("plan"), "cold deploy must stream plan first ({kinds:?})");
    ensure!(kinds.last().map(String::as_str) == Some("done"), "cold deploy must end with done ({kinds:?})");
    let sims = kinds.iter().filter(|k| k.as_str() == "sim").count();
    ensure!(sims >= 1, "cold deploy must stream at least one sim event ({kinds:?})");
    ensure!(kinds.len() == sims + 2, "cold deploy stream must be exactly plan, sim*, done ({kinds:?})");
    plan_events += 1;
    sim_events += sims;
    done_events += 1;

    // Warm repeat on id 2: both caches hit, single terminal frame.
    stream.write_all(b"FTL1 2 DEPLOY stage-16x24x48 cluster-only ftl\n")?;
    let j = read_reply(&mut reader)?;
    ensure!(
        j.get("id")?.as_u64()? == 2 && j.get("event")?.as_str()? == "done",
        "warm deploy must collapse to one done frame: {j}"
    );
    ensure!(j.get("cached")?.as_bool()? && j.get("sim_cached")?.as_bool()?, "warm repeat must hit both caches: {j}");
    done_events += 1;

    // Interleave: cold id 3 and warm id 4 written back to back. The
    // warm hit resolves inline while the cold solve is still running,
    // so its terminal frame must overtake.
    stream.write_all(
        b"FTL1 3 DEPLOY stage-24x24x48 cluster-only ftl\nFTL1 4 DEPLOY stage-16x24x48 cluster-only ftl\n",
    )?;
    let mut terminal_order: Vec<u64> = Vec::new();
    while terminal_order.len() < 2 {
        let j = read_reply(&mut reader)?;
        let id = j.get("id")?.as_u64()?;
        match j.get("event")?.as_str()? {
            "done" => terminal_order.push(id),
            "error" => bail!("interleaved deploy {id} failed: {j}"),
            "plan" => {
                ensure!(id == 3, "only the cold deploy streams partials: {j}");
                plan_events += 1;
            }
            "sim" => {
                ensure!(id == 3, "only the cold deploy streams partials: {j}");
                sim_events += 1;
            }
            other => bail!("unexpected event '{other}': {j}"),
        }
    }
    done_events += 2;
    ensure!(
        terminal_order == [4, 3],
        "warm id 4 must complete before cold id 3 (terminal order {terminal_order:?})"
    );
    Ok(StreamProbe { plan_events, sim_events, done_events, out_of_order: true })
}

/// Drive the front door at `addr` with bare legacy (v0) lines written
/// back to back and assert full compatibility: one legacy-shaped JSON
/// reply per request, in request order, with no v1 protocol fields.
/// Returns the number of replies verified.
pub fn v0_probe(addr: &str) -> Result<usize> {
    use std::io::Write;
    let mut stream = std::net::TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(60)))?;
    let mut reader = std::io::BufReader::new(stream.try_clone()?);
    stream.write_all(b"PING\nDEPLOY stage-16x24x48 cluster-only ftl\nSTATS\n")?;
    let pong = read_reply(&mut reader)?;
    ensure!(pong.get("pong")?.as_bool()?, "v0 PING must answer pong first: {pong}");
    let deploy = read_reply(&mut reader)?;
    ensure!(deploy.get("outcome")?.as_str()? == "OK", "v0 DEPLOY must be served second: {deploy}");
    let stats = read_reply(&mut reader)?;
    ensure!(stats.get_opt("batch").is_some(), "v0 STATS must answer last with the stats object: {stats}");
    for (name, j) in [("PING", &pong), ("DEPLOY", &deploy), ("STATS", &stats)] {
        ensure!(
            j.get_opt("v").is_none() && j.get_opt("event").is_none() && j.get_opt("id").is_none(),
            "v0 {name} reply must not grow v1 protocol fields: {j}"
        );
    }
    Ok(3)
}

/// Thin newline-framed client for the front door: one TCP connection,
/// command lines out, JSON lines back, with a read timeout so a hung
/// server surfaces as an error instead of a wedged harness. Speaks both
/// framings — callers write bare v0 lines or `FTL1 <id> ...` frames
/// through the same [`send_line`](WireClient::send_line).
pub struct WireClient {
    stream: std::net::TcpStream,
    reader: std::io::BufReader<std::net::TcpStream>,
}

impl WireClient {
    /// Connect with a 60 s read timeout — long enough for any cold
    /// solve, short enough that a dead server fails the harness instead
    /// of wedging it.
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = std::net::TcpStream::connect(addr)?;
        stream.set_read_timeout(Some(Duration::from_secs(60)))?;
        stream.set_nodelay(true)?;
        let reader = std::io::BufReader::new(stream.try_clone()?);
        Ok(WireClient { stream, reader })
    }

    /// Write one request line; the newline terminator is added here.
    pub fn send_line(&mut self, line: &str) -> Result<()> {
        use std::io::Write;
        self.stream.write_all(line.as_bytes())?;
        self.stream.write_all(b"\n")?;
        Ok(())
    }

    /// Read the next newline-framed JSON reply.
    pub fn read_json(&mut self) -> Result<crate::util::json::Json> {
        read_reply(&mut self.reader)
    }

    /// Read raw reply lines up to and including the one equal to
    /// `marker` — for the multi-line commands (`METRICS` ends with
    /// `# EOF`).
    pub fn read_until(&mut self, marker: &str) -> Result<Vec<String>> {
        use std::io::BufRead;
        let mut lines = Vec::new();
        loop {
            let mut line = String::new();
            let n = self.reader.read_line(&mut line)?;
            ensure!(n > 0, "server closed the connection mid-reply");
            let line = line.trim_end().to_string();
            let done = line == marker;
            lines.push(line);
            if done {
                return Ok(lines);
            }
        }
    }

    /// One serial round trip: write `line`, read its single JSON reply.
    pub fn roundtrip(&mut self, line: &str) -> Result<crate::util::json::Json> {
        self.send_line(line)?;
        self.read_json()
    }
}

/// Traffic-mix knobs for [`seeded_wire_wave`]. Each percentage is drawn
/// independently per request from the caller's rng, so the schedule is
/// a pure function of the rng state.
#[derive(Debug, Clone)]
pub struct WireMix {
    /// Requests in the wave.
    pub total: usize,
    /// Percent of requests repeating an already-pooled workload (warm
    /// fast-path candidates). Ignored while the pool is empty.
    pub warm_pct: usize,
    /// Percent sent as bare v0 lines on a second, serial connection.
    pub v0_pct: usize,
    /// Percent of *cold* requests given a 1 ms deadline — queued behind
    /// a batch window they cannot beat, exercising TIMEOUT.
    pub tight_deadline_pct: usize,
}

impl Default for WireMix {
    fn default() -> Self {
        WireMix { total: 24, warm_pct: 40, v0_pct: 25, tight_deadline_pct: 8 }
    }
}

/// One request's terminal outcome in a [`seeded_wire_wave`].
#[derive(Debug, Clone)]
pub struct WireOutcome {
    /// The `stage-<seq>x<dim>x<hidden>` workload spec.
    pub workload: String,
    /// Requested lane (`None` = default).
    pub lane: Option<String>,
    /// `OK` | `SHED` | `TIMEOUT`.
    pub outcome: String,
    /// Plan-cache hit (meaningful on `OK`; false otherwise).
    pub cached: bool,
    /// Sim-cache hit (meaningful on `OK`; false otherwise).
    pub sim_cached: bool,
    /// Plan fingerprint hex (`OK` replies only).
    pub fingerprint: Option<String>,
    /// Send-to-terminal wall latency.
    pub latency_us: u64,
    /// Sent as a bare v0 line (serial) rather than a v1 frame.
    pub v0: bool,
}

/// Aggregate result of [`seeded_wire_wave`].
pub struct WireWaveReport {
    /// Per-request terminal outcomes, in schedule order.
    pub outcomes: Vec<WireOutcome>,
    /// Streamed v1 `plan` partial events observed.
    pub plan_events: usize,
    /// Streamed v1 `sim` partial events observed.
    pub sim_events: usize,
}

impl WireWaveReport {
    /// Outcomes matching `kind` (`OK`/`SHED`/`TIMEOUT`).
    pub fn count(&self, kind: &str) -> usize {
        self.outcomes.iter().filter(|o| o.outcome == kind).count()
    }
}

/// One scheduled request of a seeded wire wave.
struct WireRequest {
    workload: String,
    lane: Option<&'static str>,
    deadline_ms: Option<u64>,
    v0: bool,
}

/// A v1 request in flight: where its outcome lands and when it left.
struct PendingWire {
    idx: usize,
    started: Instant,
}

/// Decode a terminal reply body into a [`WireOutcome`].
fn wire_outcome(
    j: &crate::util::json::Json,
    workload: &str,
    lane: Option<&'static str>,
    latency: Duration,
    v0: bool,
) -> Result<WireOutcome> {
    let outcome = j.get("outcome")?.as_str()?.to_string();
    let cached = j.get_opt("cached").map(|v| v.as_bool()).transpose()?.unwrap_or(false);
    let sim_cached = j.get_opt("sim_cached").map(|v| v.as_bool()).transpose()?.unwrap_or(false);
    let fingerprint = j.get_opt("fingerprint").map(|v| v.as_str().map(str::to_string)).transpose()?;
    Ok(WireOutcome {
        workload: workload.to_string(),
        lane: lane.map(str::to_string),
        outcome,
        cached,
        sim_cached,
        fingerprint,
        latency_us: latency.as_micros() as u64,
        v0,
    })
}

/// Drain one v1 frame off `client`; a terminal fills its slot in
/// `outcomes`, partials are only counted.
fn drain_wire_event(
    client: &mut WireClient,
    pending: &mut std::collections::HashMap<u64, PendingWire>,
    reqs: &[WireRequest],
    outcomes: &mut [Option<WireOutcome>],
    plan_events: &mut usize,
    sim_events: &mut usize,
) -> Result<()> {
    let j = client.read_json()?;
    let id = j.get("id")?.as_u64()?;
    match j.get("event")?.as_str()? {
        "plan" => *plan_events += 1,
        "sim" => *sim_events += 1,
        "done" => {
            let p = pending.remove(&id).ok_or_else(|| anyhow!("terminal for unknown id {id}: {j}"))?;
            let req = &reqs[p.idx];
            outcomes[p.idx] = Some(wire_outcome(&j, &req.workload, req.lane, p.started.elapsed(), false)?);
        }
        "error" => bail!("v1 request {id} failed: {j}"),
        other => bail!("unexpected v1 event '{other}': {j}"),
    }
    Ok(())
}

/// Seeded, realistic mixed traffic over the real wire: `mix.total`
/// deploys against the front door at `addr`, mixing warm repeats from
/// `pool` with fresh parametric `stage-<seq>x<dim>x<hidden>` cold
/// solves, random lanes (`gold`/`free`/default), occasional tight
/// deadlines on cold requests and a v0 fraction on its own serial
/// connection. v1 requests are multiplexed on one connection with a
/// bounded in-flight window. Fresh cold specs are pushed onto `pool` as
/// they are scheduled, so successive waves over the same pool trend
/// warmer. The request *schedule* is a pure function of the rng;
/// latencies and cache flags depend on server state. Fails on any
/// `error` event — the mix only sends well-formed frames.
pub fn seeded_wire_wave(
    addr: &str,
    rng: &mut crate::util::prop::Rng,
    mix: &WireMix,
    pool: &mut Vec<String>,
) -> Result<WireWaveReport> {
    ensure!(mix.total >= 1, "wave needs at least one request");
    // Draw the whole schedule first, in a fixed order: determinism
    // lives here, not in wire timing.
    let lane_names: [Option<&'static str>; 3] = [None, Some("gold"), Some("free")];
    let mut reqs: Vec<WireRequest> = Vec::with_capacity(mix.total);
    for _ in 0..mix.total {
        let warm = !pool.is_empty() && rng.range(1, 100) <= mix.warm_pct;
        let workload = if warm {
            pool[rng.range(0, pool.len() - 1)].clone()
        } else {
            let dim = *rng.pick(&[16usize, 24, 32]);
            let seq = rng.range(2, 64) * 4;
            let w = format!("stage-{seq}x{dim}x{}", 2 * dim);
            pool.push(w.clone());
            w
        };
        let lane = *rng.pick(&lane_names);
        let deadline_ms = if !warm && rng.range(1, 100) <= mix.tight_deadline_pct { Some(1u64) } else { None };
        let v0 = rng.range(1, 100) <= mix.v0_pct;
        reqs.push(WireRequest { workload, lane, deadline_ms, v0 });
    }
    let mut v1 = WireClient::connect(addr)?;
    let mut v0 = WireClient::connect(addr)?;
    let mut outcomes: Vec<Option<WireOutcome>> = reqs.iter().map(|_| None).collect();
    let mut pending: std::collections::HashMap<u64, PendingWire> = std::collections::HashMap::new();
    let (mut plan_events, mut sim_events) = (0usize, 0usize);
    let mut next_id = 1u64;
    for i in 0..reqs.len() {
        let req = &reqs[i];
        let mut cmd = format!("DEPLOY {} cluster-only ftl", req.workload);
        if let Some(d) = req.deadline_ms {
            cmd.push_str(&format!(" {d}"));
        }
        if let Some(lane) = req.lane {
            cmd.push_str(&format!(" lane={lane}"));
        }
        if req.v0 {
            // Bare lines have no ids: strictly serial round trips.
            let started = Instant::now();
            let j = v0.roundtrip(&cmd)?;
            outcomes[i] = Some(wire_outcome(&j, &req.workload, req.lane, started.elapsed(), true)?);
        } else {
            // Keep in-flight ids well under the front door's
            // per-connection cap so the loop never stops reading us.
            while pending.len() >= 64 {
                drain_wire_event(&mut v1, &mut pending, &reqs, &mut outcomes, &mut plan_events, &mut sim_events)?;
            }
            let id = next_id;
            next_id += 1;
            v1.send_line(&format!("{} {id} {cmd}", proto::V1_TAG))?;
            pending.insert(id, PendingWire { idx: i, started: Instant::now() });
        }
    }
    while !pending.is_empty() {
        drain_wire_event(&mut v1, &mut pending, &reqs, &mut outcomes, &mut plan_events, &mut sim_events)?;
    }
    let outcomes: Vec<WireOutcome> = outcomes
        .into_iter()
        .enumerate()
        .map(|(i, o)| o.ok_or_else(|| anyhow!("request {i} never reached a terminal outcome")))
        .collect::<Result<_>>()?;
    Ok(WireWaveReport { outcomes, plan_events, sim_events })
}
