//! `ftl::serve::persist` — the warm-start snapshot layer.
//!
//! The whole serve stack rests on one fact: planning is a pure function
//! of the request, and requests are identified by *process-stable*
//! content fingerprints ([`super::fingerprint`] deliberately avoids
//! `std::hash` so keys survive restarts). This module cashes that
//! promise in: cached `Arc<Deployment>`s and `Arc<SimReport>`s are
//! serialised through the canonical codec layer
//! ([`Deployment::to_json`], [`SimReport::to_json`]) into a snapshot
//! directory, and a restarted service loads them back before taking
//! traffic — a previously-seen request is then served with **zero**
//! branch-and-bound solves and **zero** simulator runs.
//!
//! Two on-disk encodings exist behind one loader ([`SnapshotFormat`]):
//! per-entry JSON envelopes (the original format, kept readable
//! forever) and binary **segment files** ([`super::segment`]) — batched
//! `ftl-bin-v1` entries with a footer index, which turn warm-start from
//! ~10⁵ `open`+parse calls into a few sequential reads plus in-memory
//! decodes fanned out across the [`crate::tiling::SolverPool`].
//! Warm-start always reads *both* from the same directory, newest
//! segment occurrence first; the configured format only selects what new
//! flushes write.
//!
//! # Lane-ordered warm-start
//!
//! Every cache entry carries a lane-weight hint — the WFQ weight of the
//! heaviest lane that ever hit it ([`PlanService::note_lane_hit`]) —
//! persisted in the segment index. A restarted replica decodes and
//! imports entries heaviest-hint-first, so premium tenants' plans are
//! warm before best-effort traffic's, and entries beyond the cache
//! capacity are never decoded at all (lightest hints are the ones left
//! on disk). The hints ratchet and survive round trips, so the priority
//! ordering compounds across restarts.
//!
//! # JSON snapshot format (`ftl-snapshot-v1`)
//!
//! One file per cache entry, named `plan-<fingerprint>.json` /
//! `sim-<fingerprint>.json` (32 lowercase hex digits). Each file is a
//! self-validating envelope:
//!
//! ```json
//! {
//!   "format": "ftl-snapshot-v1",         // version tag — bump on any codec change
//!   "kind": "plan" | "sim",
//!   "fingerprint": "<32 hex digits>",     // the cache key
//!   "checksum": "<32 hex digits>",        // FNV-1a/128 over "<kind>\n<fingerprint>\n<payload>"
//!   "payload": { ... canonical encoding ... }
//! }
//! ```
//!
//! The checksum covers the kind and fingerprint as well as the compact
//! payload text, so a corrupted cache key cannot smuggle a valid payload
//! in under the wrong fingerprint. Writes are atomic: the envelope is
//! written to a `.tmp-<pid>` sibling and `rename`d into place, so a
//! crash mid-write can never leave a half-written entry under a final
//! name (stale tmp files from a crashed writer are deleted at the next
//! load). Loading is **never fatal**: a file that fails to parse, fails
//! its checksum, or decodes to garbage is skipped and counted
//! (`persist.skipped_corrupt`); an entry written by a different format
//! version is skipped and counted separately (`persist.skipped_version`).
//! When the service runs with `--verify-plans`, a plan entry that decodes
//! cleanly (valid checksum, valid codec) may still be refused by the
//! static plan verifier at import — it is then neither cached nor counted
//! as `loaded`, and surfaces under the service's `verify.rejected`
//! instead.
//! Writing is never fatal either: an entry that cannot be written is
//! counted (`persist.write_errors`) and retried on the next pass, and
//! the rest of the pass continues. Only an unreadable/uncreatable
//! snapshot *directory* errors the attach.
//!
//! # Write-behind
//!
//! [`Snapshotter::attach`] spawns a background thread that wakes every
//! `PersistOptions::interval` and writes any cache entry not yet on disk
//! (entries are immutable once cached — a fingerprint's plan never
//! changes — so "not yet written" is the only dirty state). A zero
//! interval disables the thread; [`Snapshotter::flush`] runs the same
//! pass synchronously, and shutdown/drop performs a final flush so
//! admitted work is not lost.
//!
//! # Garbage collection
//!
//! By default the directory grows with every distinct fingerprint.
//! [`PersistOptions::max_entries`] (`ftl serve --cache-max-entries`)
//! bounds it, in the format's idiom. JSON: each snapshot pass ends with
//! an mtime-LRU sweep that removes the oldest entry files beyond the cap
//! (entries are immutable, so write time is the only recency signal on
//! disk). Segments: the cap triggers a **compaction** ([`compact_dir`],
//! also `ftl snapshot compact`) — the live set minus the
//! lightest-lane-hint overflow is rewritten into one fresh segment and
//! the sources are removed only after it fsyncs. Compaction doubles as
//! the in-place JSON→segment migration. Either way evictions are
//! counted (`persist.evicted`), never re-written within the process, and
//! only shrink the warm-start set a restart can load.
//!
//! Counters surface in `stats_json` under `"persist"`: `loaded`,
//! `skipped_corrupt`, `skipped_version`, `snapshots`, `entries_written`,
//! `bytes_written`, `write_errors`, `evicted`, `write_us`/`load_us`
//! wall-time histograms, and the segment gauges `segments` /
//! `live_bytes` / `dead_bytes`.

#![forbid(unsafe_code)]

use std::cmp::Reverse;
use std::collections::{BTreeMap, HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::Deployment;
use crate::metrics::{Counter, Histogram};
use crate::sim::SimReport;
use crate::util::bincode::{BinReader, BinWriter};
use crate::util::json::{parse, Json};

use super::fingerprint::{checksum, Fingerprint};
use super::segment::{self, IndexEntry, SegmentEntry, SegmentError, SegmentView};
use super::service::PlanService;

/// JSON snapshot format version tag (per-entry envelope files). Bump
/// whenever the canonical encoding of any persisted type changes
/// incompatibly — old entries are then skipped (counted as
/// `skipped_version`) instead of mis-decoded. The binary segment format
/// carries its own tag ([`segment::SEGMENT_FORMAT`]).
pub const SNAPSHOT_FORMAT: &str = "ftl-snapshot-v1";

/// On-disk snapshot encoding a [`Snapshotter`] *writes*. Reading is
/// format-agnostic: warm-start always loads segment files **and**
/// per-entry JSON envelopes from the same directory, so a JSON cache dir
/// stays readable forever and `ftl snapshot compact` can migrate it to
/// segments at leisure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SnapshotFormat {
    /// One self-validating JSON envelope file per entry (`ftl-snapshot-v1`).
    Json,
    /// Batched binary segments with a footer index (`ftl-bin-v1`).
    Bin,
}

impl SnapshotFormat {
    /// CLI spelling (`--snapshot-format {json,bin}`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "json" => Some(Self::Json),
            "bin" => Some(Self::Bin),
            _ => None,
        }
    }

    /// The canonical spelling.
    pub fn name(self) -> &'static str {
        match self {
            Self::Json => "json",
            Self::Bin => "bin",
        }
    }
}

/// Tunables for a [`Snapshotter`].
#[derive(Debug, Clone, Copy)]
pub struct PersistOptions {
    /// Write-behind pass interval. `Duration::ZERO` disables the
    /// background thread (snapshots then happen only on explicit
    /// [`Snapshotter::flush`] calls and at shutdown).
    pub interval: Duration,
    /// Snapshot-directory size cap (`ftl serve --cache-max-entries`).
    /// `0` disables garbage collection. In JSON mode each snapshot pass
    /// ends with an mtime-LRU sweep removing the oldest entries beyond
    /// the cap; in segment mode the cap triggers a **compaction** that
    /// rewrites the live set minus the lightest-lane-hint entries
    /// (lane-aware GC). Either way evictions are counted
    /// (`persist.evicted`) and evicted entries are *not* re-written
    /// while the process lives (entries are immutable; the cap bounds
    /// the warm-start set a restart can load, nothing else).
    pub max_entries: usize,
    /// Which encoding new snapshot writes use. Defaults to
    /// [`SnapshotFormat::Json`] for library callers (existing dirs keep
    /// their shape); `ftl serve` defaults to `bin` (restart-to-warm at
    /// memory speed).
    pub format: SnapshotFormat,
    /// How many **deferred** segment compactions one
    /// [`Snapshotter::flush`] (or one background pass) may run after its
    /// write pass completes. A cap trip during the write pass only marks
    /// a compaction pending (counted as `persist.compactions_deferred`);
    /// the rewrite itself happens outside the write-behind critical
    /// section, at most this many times per flush. `0` means flushes
    /// never compact — the cap is then only enforced by
    /// [`Snapshotter::compact_now`], attach, and shutdown.
    pub compaction_budget: usize,
}

impl Default for PersistOptions {
    fn default() -> Self {
        Self {
            interval: Duration::from_millis(1000),
            max_entries: 0,
            format: SnapshotFormat::Json,
            compaction_budget: 1,
        }
    }
}

impl PersistOptions {
    /// Manual-flush-only options (no background thread).
    pub fn manual() -> Self {
        Self { interval: Duration::ZERO, ..Self::default() }
    }

    /// The same options with a different write format.
    pub fn with_format(self, format: SnapshotFormat) -> Self {
        Self { format, ..self }
    }
}

/// Live persistence counters, shared with [`PlanService`] so they appear
/// in `stats_json` under `"persist"`. All counters are saturating
/// ([`Counter`]) — a long-lived replica pins at `u64::MAX` instead of
/// wrapping — and envelope write wall time feeds a [`Histogram`]
/// (`write_us`).
#[derive(Debug, Default)]
pub struct PersistCounters {
    loaded: Counter,
    skipped_corrupt: Counter,
    skipped_version: Counter,
    snapshots: Counter,
    entries_written: Counter,
    bytes_written: Counter,
    write_errors: Counter,
    evicted: Counter,
    compactions: Counter,
    compactions_deferred: Counter,
    write_us: Histogram,
    load_us: Histogram,
    /// Gauges (set, not accumulated): segment files on disk, live entry
    /// bytes inside them, and bytes a compaction could reclaim.
    segments: Counter,
    live_bytes: Counter,
    dead_bytes: Counter,
}

impl PersistCounters {
    /// Entries loaded into the caches at attach time.
    pub fn loaded(&self) -> u64 {
        self.loaded.get()
    }

    /// Entries skipped because they were unreadable, unparseable, failed
    /// their checksum, or failed payload decoding.
    pub fn skipped_corrupt(&self) -> u64 {
        self.skipped_corrupt.get()
    }

    /// Entries skipped because they carry a different format version.
    pub fn skipped_version(&self) -> u64 {
        self.skipped_version.get()
    }

    /// Completed snapshot passes (background + manual + shutdown).
    pub fn snapshots(&self) -> u64 {
        self.snapshots.get()
    }

    /// Entries written to disk over the snapshotter's lifetime.
    pub fn entries_written(&self) -> u64 {
        self.entries_written.get()
    }

    /// Envelope bytes written to disk over the snapshotter's lifetime.
    pub fn bytes_written(&self) -> u64 {
        self.bytes_written.get()
    }

    /// Entries that failed to write (skipped for the pass, retried on
    /// the next one).
    pub fn write_errors(&self) -> u64 {
        self.write_errors.get()
    }

    /// Entries removed by the mtime-LRU size-cap sweep
    /// (`--cache-max-entries`).
    pub fn evicted(&self) -> u64 {
        self.evicted.get()
    }

    /// Segment compactions that completed (attach-time sweep, deferred
    /// post-flush steps, [`Snapshotter::compact_now`]).
    pub fn compactions(&self) -> u64 {
        self.compactions.get()
    }

    /// Write passes that tripped the size cap and *deferred* the
    /// compaction instead of rewriting the directory inline (the rewrite
    /// then runs as its own budgeted step — see
    /// [`PersistOptions::compaction_budget`]).
    pub fn compactions_deferred(&self) -> u64 {
        self.compactions_deferred.get()
    }

    /// Wall-time histogram of successful envelope/segment writes, in µs.
    pub fn write_us(&self) -> &Histogram {
        &self.write_us
    }

    /// Wall-time histogram of warm-start load passes, in µs (one sample
    /// per attach — the restart-to-warm number the segment format buys
    /// down).
    pub fn load_us(&self) -> &Histogram {
        &self.load_us
    }

    /// Segment files currently on disk (gauge; 0 when the directory is
    /// JSON-only).
    pub fn segments(&self) -> u64 {
        self.segments.get()
    }

    /// Bytes of live (newest-occurrence) entries inside segments (gauge).
    pub fn live_bytes(&self) -> u64 {
        self.live_bytes.get()
    }

    /// Segment bytes a compaction could reclaim — superseded duplicates,
    /// torn tails and framing for dead entries (gauge).
    pub fn dead_bytes(&self) -> u64 {
        self.dead_bytes.get()
    }

    /// The `stats_json` rendering (`"persist": {...}`). `Json::Num`, not
    /// `Json::int`: a saturated counter must render, not panic on the
    /// i64 conversion.
    pub fn to_json(&self) -> Json {
        let n = |v: u64| Json::Num(v as f64);
        Json::obj(vec![
            ("loaded", n(self.loaded())),
            ("skipped_corrupt", n(self.skipped_corrupt())),
            ("skipped_version", n(self.skipped_version())),
            ("snapshots", n(self.snapshots())),
            ("entries_written", n(self.entries_written())),
            ("bytes_written", n(self.bytes_written())),
            ("write_errors", n(self.write_errors())),
            ("evicted", n(self.evicted())),
            ("compactions", n(self.compactions())),
            ("compactions_deferred", n(self.compactions_deferred())),
            ("write_us", self.write_us.to_json()),
            ("load_us", self.load_us.to_json()),
            ("segments", n(self.segments())),
            ("live_bytes", n(self.live_bytes())),
            ("dead_bytes", n(self.dead_bytes())),
        ])
    }
}

const KIND_PLAN: u8 = 0;
const KIND_SIM: u8 = 1;

/// The write-behind snapshotter (see module docs). Attach one to a
/// [`PlanService`] and point it at a snapshot directory; existing
/// entries warm-start the caches immediately, new entries are persisted
/// in the background (or on [`Snapshotter::flush`]).
pub struct Snapshotter {
    inner: Arc<SnapInner>,
    writer: Mutex<Option<JoinHandle<()>>>,
}

struct SnapInner {
    service: Arc<PlanService>,
    dir: PathBuf,
    counters: Arc<PersistCounters>,
    /// Keys already on disk (seeded at load) — entries are immutable, so
    /// this is the entire dirty-tracking state. Keys evicted by the size
    /// cap stay in the set: eviction bounds the warm-start directory,
    /// it does not mark the entry dirty again (that would make every
    /// pass re-write and re-evict the same overflow).
    written: Mutex<HashSet<(u8, u128)>>,
    /// Entries believed live on disk (segment live set + JSON files),
    /// maintained so segment-mode GC only pays for a compaction when the
    /// cap is actually exceeded.
    live_on_disk: Mutex<usize>,
    /// Directory size cap (0 = no GC) — see [`PersistOptions::max_entries`].
    max_entries: usize,
    /// Encoding for new writes (reads are always format-agnostic).
    format: SnapshotFormat,
    /// Set by a write pass whose cap trip was deferred; consumed by
    /// [`SnapInner::run_deferred_compactions`] outside the write path.
    compact_pending: std::sync::atomic::AtomicBool,
    /// Max deferred compactions run after one flush — see
    /// [`PersistOptions::compaction_budget`].
    compaction_budget: usize,
    stop: Mutex<bool>,
    wake: Condvar,
}

impl Snapshotter {
    /// Warm-start `service` from `dir` (creating it if absent), register
    /// the `persist.*` counters with the service, and start the
    /// write-behind thread (unless `opts.interval` is zero). Corrupt or
    /// version-mismatched entries are skipped and counted, never fatal.
    pub fn attach(service: Arc<PlanService>, dir: impl Into<PathBuf>, opts: PersistOptions) -> Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir).with_context(|| format!("creating snapshot directory {}", dir.display()))?;
        let counters = Arc::new(PersistCounters::default());
        service.set_persist_counters(counters.clone());
        let mut written = HashSet::new();
        let live_on_disk = load_dir(&service, &dir, &counters, &mut written)?;
        let inner = Arc::new(SnapInner {
            service,
            dir,
            counters,
            written: Mutex::new(written),
            live_on_disk: Mutex::new(live_on_disk),
            max_entries: opts.max_entries,
            format: opts.format,
            compact_pending: std::sync::atomic::AtomicBool::new(false),
            compaction_budget: opts.compaction_budget,
            stop: Mutex::new(false),
            wake: Condvar::new(),
        });
        if opts.max_entries > 0 {
            // A restart may bring a smaller cap than the directory it
            // inherits — sweep/compact once up front.
            inner.enforce_cap();
        }
        let writer = if opts.interval.is_zero() {
            None
        } else {
            let worker = inner.clone();
            let interval = opts.interval;
            let handle = std::thread::Builder::new()
                .name("ftl-snapshotter".into())
                .spawn(move || {
                    let mut stopped = worker.stop.lock().expect("snapshotter stop flag poisoned");
                    loop {
                        if *stopped {
                            break;
                        }
                        let (guard, _) =
                            worker.wake.wait_timeout(stopped, interval).expect("snapshotter stop flag poisoned");
                        stopped = guard;
                        if *stopped {
                            break;
                        }
                        drop(stopped);
                        worker.flush();
                        // Compaction is its own step, after the write
                        // pass has released the `written` lock — a cap
                        // trip never stalls the write-behind pass.
                        worker.run_deferred_compactions(worker.compaction_budget);
                        stopped = worker.stop.lock().expect("snapshotter stop flag poisoned");
                    }
                })
                .expect("spawn snapshotter thread");
            Some(handle)
        };
        Ok(Self { inner, writer: Mutex::new(writer) })
    }

    /// Run one write-behind pass now; returns how many new entries were
    /// written. Never fails: an entry that cannot be written is counted
    /// (`write_errors`) and retried on the next pass. Safe to call
    /// concurrently with the background thread. If the pass tripped the
    /// size cap, up to [`PersistOptions::compaction_budget`] deferred
    /// compactions run afterwards, outside the write pass.
    pub fn flush(&self) -> usize {
        let wrote = self.inner.flush();
        self.inner.run_deferred_compactions(self.inner.compaction_budget);
        wrote
    }

    /// Run any pending deferred cap compaction now, ignoring the
    /// per-flush budget. Returns whether a compaction actually ran.
    pub fn compact_now(&self) -> bool {
        self.inner.run_deferred_compactions(usize::MAX) > 0
    }

    /// The snapshot directory.
    pub fn dir(&self) -> &Path {
        &self.inner.dir
    }

    /// Live counters (shared with the service's `stats_json`).
    pub fn counters(&self) -> &PersistCounters {
        &self.inner.counters
    }

    /// Stop the background thread and run a final flush so every cached
    /// entry reaches disk (also runs on drop). The final flush is the
    /// last chance an entry has to be persisted, so unlike a periodic
    /// pass its failures are summarised loudly (they are also counted in
    /// `persist.write_errors` like any other write failure) instead of
    /// being silently swallowed by drop.
    pub fn shutdown(&self) {
        {
            let mut stopped = self.inner.stop.lock().expect("snapshotter stop flag poisoned");
            *stopped = true;
        }
        self.inner.wake.notify_all();
        if let Some(handle) = self.writer.lock().expect("snapshotter writer poisoned").take() {
            handle.join().ok();
        }
        let errors_before = self.inner.counters.write_errors();
        self.inner.flush();
        // The cap is part of the on-disk contract a restart inherits:
        // never exit with a deferred compaction still pending.
        self.inner.run_deferred_compactions(usize::MAX);
        let failed = self.inner.counters.write_errors().saturating_sub(errors_before);
        if failed > 0 {
            eprintln!(
                "[ftl-serve] final snapshot flush hit {failed} write error(s); \
                 some cache entries were NOT persisted (see persist.write_errors)"
            );
        }
    }
}

impl Drop for Snapshotter {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl SnapInner {
    /// One write-behind pass in the configured format. Write failures
    /// are counted and retried next pass — a failed write must not
    /// starve the rest (mirror of the load side's skip-and-count
    /// policy).
    fn flush(&self) -> usize {
        match self.format {
            SnapshotFormat::Json => self.flush_json(),
            SnapshotFormat::Bin => self.flush_bin(),
        }
    }

    /// JSON pass: one envelope file per new cache entry. The flush holds
    /// the `written` set for its whole duration — only snapshotter
    /// threads touch it, and there is at most one background thread, so
    /// this serialises concurrent manual flushes.
    fn flush_json(&self) -> usize {
        let mut written = self.written.lock().expect("snapshotter written-set poisoned");
        let mut wrote = 0usize;
        let mut bytes = 0u64;
        // The `written` check comes before serialization: in steady state
        // (everything on disk) a pass must not rebuild a single Json tree.
        for (key, plan) in self.service.export_plans() {
            if written.contains(&(KIND_PLAN, key.0)) {
                continue;
            }
            if self.persist_one("plan", key, plan.to_json(), &mut wrote, &mut bytes) {
                written.insert((KIND_PLAN, key.0));
            }
        }
        for (key, sim) in self.service.export_sims() {
            if written.contains(&(KIND_SIM, key.0)) {
                continue;
            }
            if self.persist_one("sim", key, sim.to_json(), &mut wrote, &mut bytes) {
                written.insert((KIND_SIM, key.0));
            }
        }
        self.counters.snapshots.inc();
        self.counters.entries_written.add(wrote as u64);
        self.counters.bytes_written.add(bytes);
        // Only a pass that wrote something can have grown the directory
        // (evicted keys are never re-written), so an idle server must not
        // re-scan it every interval; attach runs one unconditional sweep
        // to enforce a lowered cap over a pre-existing directory.
        if wrote > 0 {
            *self.live_on_disk.lock().expect("snapshotter live count poisoned") += wrote;
            if self.max_entries > 0 {
                self.gc();
            }
        }
        wrote
    }

    /// Segment pass: every new cache entry is encoded through the
    /// `ftl-bin-v1` codec and the batch is sealed into **one** fresh
    /// segment file (atomic tmp+fsync+rename). In steady state this is
    /// a no-op with zero serialisation work, exactly like the JSON path.
    fn flush_bin(&self) -> usize {
        let mut written = self.written.lock().expect("snapshotter written-set poisoned");
        let mut entries: Vec<SegmentEntry> = Vec::new();
        for (key, plan, hint) in self.service.export_plans_hinted() {
            if written.contains(&(KIND_PLAN, key.0)) {
                continue;
            }
            let mut w = BinWriter::new();
            plan.to_bin(&mut w);
            entries.push(SegmentEntry { kind: KIND_PLAN, key, hint, payload: w.into_bytes() });
        }
        for (key, sim, hint) in self.service.export_sims_hinted() {
            if written.contains(&(KIND_SIM, key.0)) {
                continue;
            }
            let mut w = BinWriter::new();
            sim.to_bin(&mut w);
            entries.push(SegmentEntry { kind: KIND_SIM, key, hint, payload: w.into_bytes() });
        }
        let mut wrote = 0usize;
        if !entries.is_empty() {
            // Heaviest lanes first *inside* the segment too: a reader
            // that lost the footer and recovers sequentially still sees
            // premium entries before best-effort ones.
            entries.sort_by_key(|e| (Reverse(e.hint), e.kind, e.key.0));
            let write_start = Instant::now();
            match segment::write_segment(&self.dir, &entries) {
                Ok((_, bytes)) => {
                    self.counters.write_us.record_duration(write_start.elapsed());
                    for e in &entries {
                        written.insert((e.kind, e.key.0));
                    }
                    wrote = entries.len();
                    self.counters.bytes_written.add(bytes);
                    self.counters.segments.add(1);
                    self.counters.live_bytes.add(bytes);
                    *self.live_on_disk.lock().expect("snapshotter live count poisoned") += wrote;
                }
                Err(e) => {
                    // One failed segment = one error, however many
                    // entries it carried; all of them stay dirty and are
                    // retried next pass.
                    self.counters.write_errors.inc();
                    eprintln!("[ftl-serve] snapshot segment write failed ({} entries): {e:#}", entries.len());
                }
            }
        }
        self.counters.snapshots.inc();
        self.counters.entries_written.add(wrote as u64);
        // The write pass never compacts inline: rewriting the whole live
        // set here would stall the write-behind pass (and every manual
        // flush serialised behind the `written` lock) for the duration
        // of a directory rewrite. A cap trip only marks the compaction
        // pending; it runs as its own budgeted step once this pass has
        // released the lock (background loop, Snapshotter::flush,
        // compact_now, shutdown).
        if self.max_entries > 0 && wrote > 0 {
            let live = *self.live_on_disk.lock().expect("snapshotter live count poisoned");
            if live > self.max_entries {
                self.compact_pending.store(true, std::sync::atomic::Ordering::SeqCst);
                self.counters.compactions_deferred.inc();
            }
        }
        wrote
    }

    /// Apply the `max_entries` cap in the format's idiom: mtime-LRU file
    /// sweep for JSON, lane-aware compaction for segments (only when the
    /// live count actually exceeds the cap — compaction rewrites the
    /// live set, so it must not run on every pass). Only the attach-time
    /// sweep calls this synchronously; flush passes defer instead.
    fn enforce_cap(&self) {
        match self.format {
            SnapshotFormat::Json => self.gc(),
            SnapshotFormat::Bin => {
                let live = *self.live_on_disk.lock().expect("snapshotter live count poisoned");
                if live > self.max_entries {
                    self.compact();
                }
            }
        }
    }

    /// Run at most `budget` compactions deferred by earlier write
    /// passes. Holds neither the `written` lock nor any flush state —
    /// the write-behind pass proceeds unimpeded while the directory is
    /// rewritten. Returns how many compactions ran.
    fn run_deferred_compactions(&self, budget: usize) -> usize {
        let mut ran = 0usize;
        while ran < budget && self.compact_pending.swap(false, std::sync::atomic::Ordering::SeqCst) {
            let live = *self.live_on_disk.lock().expect("snapshotter live count poisoned");
            if self.max_entries == 0 || live <= self.max_entries {
                break;
            }
            self.compact();
            ran += 1;
        }
        ran
    }

    /// Segment-mode GC: rewrite the live set (minus the
    /// lightest-lane-hint overflow) into one fresh segment and drop the
    /// sources. Failures are logged and left for the next pass — the old
    /// segments stay valid until the rewrite lands.
    fn compact(&self) {
        match compact_dir(&self.dir, self.max_entries) {
            Ok(report) => {
                self.counters.compactions.inc();
                self.counters.evicted.add(report.evicted as u64);
                self.counters.segments.set(report.segments_after as u64);
                self.counters.live_bytes.set(report.bytes);
                self.counters.dead_bytes.set(0);
                *self.live_on_disk.lock().expect("snapshotter live count poisoned") = report.live;
            }
            Err(e) => eprintln!("[ftl-serve] snapshot compaction failed: {e:#}"),
        }
    }

    /// mtime-LRU sweep: when the directory holds more than `max_entries`
    /// final entries, remove the oldest (ties broken by file name so the
    /// sweep is deterministic under coarse mtimes). Best-effort — an
    /// entry that cannot be statted or removed is simply left for the
    /// next pass.
    fn gc(&self) {
        let Ok(entries) = std::fs::read_dir(&self.dir) else { return };
        let mut finals: Vec<(std::time::SystemTime, String, PathBuf)> = Vec::new();
        for entry in entries.flatten() {
            let path = entry.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
            if name.contains(".tmp-")
                || !name.ends_with(".json")
                || !(name.starts_with("plan-") || name.starts_with("sim-"))
            {
                continue;
            }
            let Ok(mtime) = entry.metadata().and_then(|m| m.modified()) else { continue };
            finals.push((mtime, name.to_string(), path));
        }
        if finals.len() <= self.max_entries {
            return;
        }
        finals.sort_by(|a, b| (a.0, &a.1).cmp(&(b.0, &b.1)));
        let excess = finals.len() - self.max_entries;
        let mut evicted = 0u64;
        for (_, _, path) in finals.into_iter().take(excess) {
            if std::fs::remove_file(&path).is_ok() {
                evicted += 1;
            }
        }
        self.counters.evicted.add(evicted);
    }

    /// Write one envelope, counting failures instead of propagating them
    /// (a failed entry is retried on the next pass). Returns whether the
    /// entry reached disk.
    fn persist_one(&self, tag: &str, key: Fingerprint, payload: Json, wrote: &mut usize, bytes: &mut u64) -> bool {
        let write_start = Instant::now();
        match write_entry(&self.dir, tag, key, payload) {
            Ok(b) => {
                self.counters.write_us.record_duration(write_start.elapsed());
                *wrote += 1;
                *bytes += b;
                true
            }
            Err(e) => {
                self.counters.write_errors.inc();
                eprintln!("[ftl-serve] snapshot write failed for {tag}-{}: {e:#}", key.hex());
                false
            }
        }
    }
}

/// The checksummed byte string of one envelope: kind + fingerprint +
/// compact payload text, so corruption of the cache key is caught just
/// like corruption of the payload.
fn checksum_input(kind: &str, key: Fingerprint, payload_text: &str) -> String {
    format!("{kind}\n{}\n{payload_text}", key.hex())
}

/// Atomically write one envelope; returns its size in bytes.
fn write_entry(dir: &Path, kind: &str, key: Fingerprint, payload: Json) -> Result<u64> {
    let payload_text = payload.to_string();
    let sum = checksum(checksum_input(kind, key, &payload_text).as_bytes());
    let doc = Json::obj(vec![
        ("format", Json::str(SNAPSHOT_FORMAT)),
        ("kind", Json::str(kind)),
        ("fingerprint", Json::str(key.hex())),
        ("checksum", Json::str(sum.hex())),
        ("payload", payload),
    ]);
    let text = doc.to_string();
    let final_path = dir.join(format!("{kind}-{}.json", key.hex()));
    let tmp_path = dir.join(format!("{kind}-{}.json.tmp-{}", key.hex(), std::process::id()));
    std::fs::write(&tmp_path, &text).with_context(|| format!("writing {}", tmp_path.display()))?;
    std::fs::rename(&tmp_path, &final_path).with_context(|| format!("renaming {} into place", tmp_path.display()))?;
    Ok(text.len() as u64)
}

/// A decoded snapshot entry.
enum Loaded {
    Plan(Fingerprint, Deployment),
    Sim(Fingerprint, SimReport),
}

/// Why an entry was skipped.
enum Skip {
    Version,
    Corrupt,
}

/// Newest-occurrence live set across a directory's segments.
type SegLive = HashMap<(u8, u128), (Arc<SegmentView>, IndexEntry)>;

/// One unit of warm-start decode work, shipped to a [`SolverPool`]
/// worker.
enum Work {
    /// A live segment entry (shared view + its index record).
    Seg { view: Arc<SegmentView>, ie: IndexEntry },
    /// A legacy per-entry JSON envelope file.
    Json { path: PathBuf },
}

/// A decoded unit of warm-start work, imported sequentially in lane
/// order.
enum DecodeOut {
    Plan(Fingerprint, u64, Deployment),
    Sim(Fingerprint, u64, SimReport),
    SkipVersion,
    SkipCorrupt,
}

/// `(kind, fingerprint)` from a well-formed envelope file name
/// (`plan-<32 hex>.json`) — used to dedup JSON files against the
/// segment live set *without* reading them. `None` for nonstandard
/// names, which still load under whatever fingerprint their content
/// declares (the envelope, not the name, is authoritative).
fn parse_entry_name(name: &str) -> Option<(u8, u128)> {
    let rest = name.strip_suffix(".json")?;
    let (kind, hex) = if let Some(h) = rest.strip_prefix("plan-") {
        (KIND_PLAN, h)
    } else if let Some(h) = rest.strip_prefix("sim-") {
        (KIND_SIM, h)
    } else {
        return None;
    };
    u128::from_str_radix(hex, 16).ok().map(|v| (kind, v))
}

/// Decode one unit of warm-start work (runs on a solver-pool worker).
fn decode_work(work: Work) -> DecodeOut {
    match work {
        Work::Seg { view, ie } => match segment::decode_entry(&view.data, &ie) {
            Ok(payload) => decode_bin_payload(ie.kind, ie.key, ie.hint, payload),
            Err(_) => DecodeOut::SkipCorrupt,
        },
        Work::Json { path } => match load_entry(&path) {
            Ok(Loaded::Plan(key, plan)) => DecodeOut::Plan(key, 0, plan),
            Ok(Loaded::Sim(key, sim)) => DecodeOut::Sim(key, 0, sim),
            Err(Skip::Version) => DecodeOut::SkipVersion,
            Err(Skip::Corrupt) => DecodeOut::SkipCorrupt,
        },
    }
}

/// Strictly decode a checksum-validated `ftl-bin-v1` payload (trailing
/// bytes are corruption, same policy as the JSON envelope).
fn decode_bin_payload(kind: u8, key: Fingerprint, hint: u64, payload: &[u8]) -> DecodeOut {
    let mut r = BinReader::new(payload);
    match kind {
        KIND_PLAN => match Deployment::from_bin(&mut r) {
            Ok(plan) if r.is_done() => DecodeOut::Plan(key, hint, plan),
            _ => DecodeOut::SkipCorrupt,
        },
        KIND_SIM => match SimReport::from_bin(&mut r) {
            Ok(sim) if r.is_done() => DecodeOut::Sim(key, hint, sim),
            _ => DecodeOut::SkipCorrupt,
        },
        _ => DecodeOut::SkipCorrupt,
    }
}

/// Warm-start `service` from everything `dir` holds — segment files
/// *and* legacy per-entry JSON envelopes — and return the number of
/// entries believed live on disk. Per-entry failures are counted, never
/// propagated.
///
/// The load is structured for restart-to-warm speed:
///
/// 1. **Sequential reads.** Each segment is read front-to-back once;
///    its footer index locates every entry without touching payloads.
/// 2. **Dedup before decode.** Newest segment occurrence wins per
///    `(kind, fingerprint)`; JSON files already covered by a segment
///    are skipped by *name*, unread.
/// 3. **Lane order.** Work is sorted heaviest-lane-hint first, so the
///    entries premium lanes hit go warm first, and truncated to the
///    cache capacities (an entry the LRU would immediately evict is not
///    worth decoding — it stays on disk, unloaded and unmarked).
/// 4. **Parallel decode.** Payload decoding — the dominant cost — fans
///    out across the global [`crate::tiling::SolverPool`]; imports then
///    run sequentially in lane order.
fn load_dir(
    service: &PlanService,
    dir: &Path,
    counters: &PersistCounters,
    written: &mut HashSet<(u8, u128)>,
) -> Result<usize> {
    let load_start = Instant::now();

    // ---- segments: sequential read + footer index, newest wins.
    let mut seg_live: SegLive = HashMap::new();
    let mut seg_count = 0usize;
    let mut total_bytes = 0u64;
    for path in segment::segment_paths(dir) {
        seg_count += 1;
        match segment::read_segment(&path) {
            Ok(view) => {
                total_bytes += view.data.len() as u64;
                if view.torn_tail {
                    // The undecodable tail of a truncated segment is one
                    // counted skip; everything before the tear loads.
                    counters.skipped_corrupt.inc();
                }
                let view = Arc::new(view);
                for ie in &view.entries {
                    seg_live.insert((ie.kind, ie.key.0), (view.clone(), *ie));
                }
            }
            Err(SegmentError::Version) => counters.skipped_version.inc(),
            Err(SegmentError::Corrupt) => counters.skipped_corrupt.inc(),
        }
    }
    let live_bytes: u64 = seg_live.values().map(|(_, ie)| ie.len as u64).sum();
    counters.segments.set(seg_count as u64);
    counters.live_bytes.set(live_bytes);
    counters.dead_bytes.set(total_bytes.saturating_sub(live_bytes));

    // ---- JSON envelopes (the format-compat path) + stale-tmp reaping.
    let mut items: Vec<(u8, u128, u64, Work)> = Vec::new();
    let entries = std::fs::read_dir(dir).with_context(|| format!("reading snapshot directory {}", dir.display()))?;
    for entry in entries {
        let Ok(entry) = entry else { continue };
        let path = entry.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
        // Stale `.tmp-<pid>` files from a crashed writer are dead weight,
        // but another *live* replica sharing this directory may be
        // mid-write right now — only reap tmp files old enough that no
        // in-flight rename can still want them (best-effort).
        if name.contains(".tmp-") {
            let stale = std::fs::metadata(&path)
                .and_then(|m| m.modified())
                .ok()
                .and_then(|t| t.elapsed().ok())
                .is_some_and(|age| age > Duration::from_secs(60));
            if stale {
                let _ = std::fs::remove_file(&path);
            }
            continue;
        }
        // Final JSON entries only (segments were listed above).
        if !name.ends_with(".json") || !(name.starts_with("plan-") || name.starts_with("sim-")) {
            continue;
        }
        let named = parse_entry_name(name);
        if let Some(key) = named {
            // Entries are immutable per fingerprint: a JSON file already
            // covered by a segment is the same entry — skip it unread.
            if seg_live.contains_key(&key) {
                continue;
            }
        }
        let kind = if name.starts_with("plan-") { KIND_PLAN } else { KIND_SIM };
        let key = named.map_or(0, |(_, k)| k);
        items.push((kind, key, 0, Work::Json { path }));
    }
    let json_files = items.len();

    for (&(kind, key), (view, ie)) in &seg_live {
        items.push((kind, key, ie.hint, Work::Seg { view: view.clone(), ie: *ie }));
    }

    // ---- lane order + capacity cut.
    items.sort_by_key(|&(kind, key, hint, _)| (Reverse(hint), kind, key));
    let cap = |c: usize| if c == 0 { usize::MAX } else { c };
    let (plan_cap, sim_cap) = { (cap(service.stats().cache.capacity), cap(service.stats().sim_cache.capacity)) };
    let (mut plans_kept, mut sims_kept) = (0usize, 0usize);
    let work: Vec<Work> = items
        .into_iter()
        .filter_map(|(kind, _, _, work)| {
            let kept = if kind == KIND_PLAN { &mut plans_kept } else { &mut sims_kept };
            let limit = if kind == KIND_PLAN { plan_cap } else { sim_cap };
            if *kept >= limit {
                return None;
            }
            *kept += 1;
            Some(work)
        })
        .collect();

    // ---- parallel decode, sequential lane-ordered import.
    let pool = crate::tiling::SolverPool::global();
    let decoded = if work.is_empty() { Vec::new() } else { pool.map(work, decode_work) };
    for out in decoded {
        match out {
            DecodeOut::Plan(key, hint, plan) => {
                // Under `--verify-plans` the service may refuse the entry
                // (error-severity findings, `verify.rejected`). A refused
                // entry is neither loaded nor marked written — it is not
                // in the cache, so flush passes have nothing to re-export
                // for it and the file is simply left to the size-cap GC.
                if service.import_plan_hinted(key, Arc::new(plan), hint) {
                    written.insert((KIND_PLAN, key.0));
                    counters.loaded.inc();
                }
            }
            DecodeOut::Sim(key, hint, sim) => {
                service.import_sim_hinted(key, Arc::new(sim), hint);
                written.insert((KIND_SIM, key.0));
                counters.loaded.inc();
            }
            DecodeOut::SkipVersion => counters.skipped_version.inc(),
            DecodeOut::SkipCorrupt => counters.skipped_corrupt.inc(),
        }
    }
    counters.load_us.record_duration(load_start.elapsed());
    Ok(seg_live.len() + json_files)
}

/// Validate and decode one envelope file.
fn load_entry(path: &Path) -> std::result::Result<Loaded, Skip> {
    let text = std::fs::read_to_string(path).map_err(|_| Skip::Corrupt)?;
    let doc = parse(&text).map_err(|_| Skip::Corrupt)?;
    let format = doc.get("format").and_then(|f| f.as_str()).map_err(|_| Skip::Corrupt)?;
    if format != SNAPSHOT_FORMAT {
        return Err(Skip::Version);
    }
    let kind = doc.get("kind").and_then(|k| k.as_str()).map_err(|_| Skip::Corrupt)?;
    let hex = doc.get("fingerprint").and_then(|f| f.as_str()).map_err(|_| Skip::Corrupt)?;
    let key = Fingerprint(u128::from_str_radix(hex, 16).map_err(|_| Skip::Corrupt)?);
    let declared = doc.get("checksum").and_then(|c| c.as_str()).map_err(|_| Skip::Corrupt)?;
    let payload = doc.get("payload").map_err(|_| Skip::Corrupt)?;
    // Re-serialising the parsed payload through the canonical printer
    // reproduces the exact text the checksum was computed over (the
    // printer is deterministic: sorted keys, shortest-roundtrip floats).
    let canonical = payload.to_string();
    if checksum(checksum_input(kind, key, &canonical).as_bytes()).hex() != declared {
        return Err(Skip::Corrupt);
    }
    match kind {
        "plan" => Ok(Loaded::Plan(key, Deployment::from_json(payload).map_err(|_| Skip::Corrupt)?)),
        "sim" => Ok(Loaded::Sim(key, SimReport::from_json(payload).map_err(|_| Skip::Corrupt)?)),
        _ => Err(Skip::Corrupt),
    }
}

/// What [`compact_dir`] did (also the payload of `ftl snapshot compact`).
#[derive(Debug, Clone, Copy, Default)]
pub struct CompactReport {
    /// Segment files before the rewrite.
    pub segments_before: usize,
    /// Segment files after (1, or 0 when nothing was live).
    pub segments_after: usize,
    /// Per-entry JSON envelopes migrated into the new segment (their
    /// files are removed once the segment is durable).
    pub json_migrated: usize,
    /// Live entries written to the new segment.
    pub live: usize,
    /// Live entries dropped to satisfy the cap (lightest lane hints
    /// first).
    pub evicted: usize,
    /// Unreadable entries/files encountered (left in place when they
    /// are whole files; torn segment tails are unrecoverable).
    pub skipped_corrupt: usize,
    /// Files carrying a different codec version (left in place).
    pub skipped_version: usize,
    /// Size of the new segment in bytes.
    pub bytes: u64,
}

impl CompactReport {
    /// JSON rendering (`ftl snapshot compact --json`).
    pub fn to_json(&self) -> Json {
        let n = |v: usize| Json::Num(v as f64);
        Json::obj(vec![
            ("segments_before", n(self.segments_before)),
            ("segments_after", n(self.segments_after)),
            ("json_migrated", n(self.json_migrated)),
            ("live", n(self.live)),
            ("evicted", n(self.evicted)),
            ("skipped_corrupt", n(self.skipped_corrupt)),
            ("skipped_version", n(self.skipped_version)),
            ("bytes", Json::Num(self.bytes as f64)),
        ])
    }
}

/// Compact a snapshot directory: fold every live entry — newest segment
/// occurrence per `(kind, fingerprint)`, plus every legacy JSON envelope
/// — into **one** fresh segment, then remove the sources. This is both
/// the segment format's GC (`max_entries > 0` evicts the
/// lightest-lane-hint overflow — lane-aware, where the JSON sweep was
/// mtime-LRU) and the in-place JSON→segment migration behind
/// `ftl snapshot compact` (`max_entries == 0` migrates without
/// evicting).
///
/// Durability contract: the new segment is fsync'd before any source
/// file is removed, and sources are removed only when they were fully
/// ingested — a version-mismatched or unreadable file is left in place
/// for the operator. Safe to re-run; idempotent once the directory is a
/// single segment.
pub fn compact_dir(dir: &Path, max_entries: usize) -> Result<CompactReport> {
    let seg_paths = segment::segment_paths(dir);
    let mut report = CompactReport { segments_before: seg_paths.len(), ..CompactReport::default() };
    // (hint, payload) per key; BTreeMap so eviction and output order are
    // deterministic.
    let mut live: BTreeMap<(u8, u128), (u64, Vec<u8>)> = BTreeMap::new();
    let mut ingested: Vec<PathBuf> = Vec::new();
    for path in seg_paths {
        match segment::read_segment(&path) {
            Ok(view) => {
                if view.torn_tail {
                    report.skipped_corrupt += 1;
                }
                for ie in &view.entries {
                    match segment::decode_entry(&view.data, ie) {
                        Ok(payload) => {
                            let slot = live.entry((ie.kind, ie.key.0)).or_default();
                            // Hints only ratchet; the payload is
                            // immutable per key, so newest-wins is a
                            // formality.
                            slot.0 = slot.0.max(ie.hint);
                            slot.1 = payload.to_vec();
                        }
                        Err(_) => report.skipped_corrupt += 1,
                    }
                }
                ingested.push(path);
            }
            Err(SegmentError::Version) => report.skipped_version += 1,
            Err(SegmentError::Corrupt) => report.skipped_corrupt += 1,
        }
    }
    // Legacy JSON envelopes: decode, re-encode through the binary codec.
    let entries = std::fs::read_dir(dir).with_context(|| format!("reading snapshot directory {}", dir.display()))?;
    for entry in entries.flatten() {
        let path = entry.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
        if name.contains(".tmp-")
            || !name.ends_with(".json")
            || !(name.starts_with("plan-") || name.starts_with("sim-"))
        {
            continue;
        }
        let (kind, key, payload) = match load_entry(&path) {
            Ok(Loaded::Plan(key, plan)) => {
                let mut w = BinWriter::new();
                plan.to_bin(&mut w);
                (KIND_PLAN, key, w.into_bytes())
            }
            Ok(Loaded::Sim(key, sim)) => {
                let mut w = BinWriter::new();
                sim.to_bin(&mut w);
                (KIND_SIM, key, w.into_bytes())
            }
            Err(Skip::Version) => {
                report.skipped_version += 1;
                continue;
            }
            Err(Skip::Corrupt) => {
                report.skipped_corrupt += 1;
                continue;
            }
        };
        // A segment copy of the same key is the same immutable entry —
        // the file is migrated (removable) either way.
        live.entry((kind, key.0)).or_insert((0, payload));
        report.json_migrated += 1;
        ingested.push(path);
    }
    // Cap: evict the lightest lane hints first (ties by key, so the
    // sweep is deterministic).
    if max_entries > 0 && live.len() > max_entries {
        let mut order: Vec<(u64, (u8, u128))> = live.iter().map(|(&k, &(hint, _))| (hint, k)).collect();
        order.sort_unstable();
        let excess = live.len() - max_entries;
        for (_, k) in order.into_iter().take(excess) {
            live.remove(&k);
            report.evicted += 1;
        }
    }
    report.live = live.len();
    if !live.is_empty() {
        let mut out: Vec<SegmentEntry> = live
            .into_iter()
            .map(|((kind, key), (hint, payload))| SegmentEntry { kind, key: Fingerprint(key), hint, payload })
            .collect();
        out.sort_by_key(|e| (Reverse(e.hint), e.kind, e.key.0));
        let (_, bytes) = segment::write_segment(dir, &out)?;
        report.bytes = bytes;
        report.segments_after = 1;
    }
    // The new segment is fsync'd and renamed — only now do the sources
    // go away (best-effort; a leftover is re-ingested next time).
    for path in ingested {
        let _ = std::fs::remove_file(&path);
    }
    Ok(report)
}

/// Summarise a snapshot directory without touching the caches
/// (`ftl snapshot inspect`): per-segment entry counts and health, the
/// deduped live set, and how many legacy JSON envelopes remain.
pub fn inspect_dir(dir: &Path) -> Result<Json> {
    let mut seg_rows: Vec<Json> = Vec::new();
    let mut live: HashMap<(u8, u128), usize> = HashMap::new();
    let mut total_bytes = 0u64;
    for path in segment::segment_paths(dir) {
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("?").to_string();
        let row = match segment::read_segment(&path) {
            Ok(view) => {
                total_bytes += view.data.len() as u64;
                let plans = view.entries.iter().filter(|e| e.kind == KIND_PLAN).count();
                for ie in &view.entries {
                    *live.entry((ie.kind, ie.key.0)).or_insert(0) = ie.len;
                }
                Json::obj(vec![
                    ("file", Json::str(name)),
                    ("bytes", Json::Num(view.data.len() as f64)),
                    ("entries", Json::Num(view.entries.len() as f64)),
                    ("plans", Json::Num(plans as f64)),
                    ("sims", Json::Num((view.entries.len() - plans) as f64)),
                    ("recovered", Json::Bool(view.recovered)),
                    ("torn_tail", Json::Bool(view.torn_tail)),
                ])
            }
            Err(e) => Json::obj(vec![
                ("file", Json::str(name)),
                ("error", Json::str(if e == SegmentError::Version { "version" } else { "corrupt" })),
            ]),
        };
        seg_rows.push(row);
    }
    let live_bytes: u64 = live.values().map(|&len| len as u64).sum();
    let json_files = std::fs::read_dir(dir)
        .with_context(|| format!("reading snapshot directory {}", dir.display()))?
        .flatten()
        .filter(|e| {
            e.file_name().to_str().is_some_and(|n| {
                !n.contains(".tmp-") && n.ends_with(".json") && (n.starts_with("plan-") || n.starts_with("sim-"))
            })
        })
        .count();
    Ok(Json::obj(vec![
        ("dir", Json::str(dir.display().to_string())),
        ("segments", Json::Arr(seg_rows)),
        ("live_entries", Json::Num(live.len() as f64)),
        ("live_bytes", Json::Num(live_bytes as f64)),
        ("dead_bytes", Json::Num(total_bytes.saturating_sub(live_bytes) as f64)),
        ("json_entries", Json::Num(json_files as f64)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dma::DmaStats;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("ftl-persist-unit-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn tiny_sim() -> SimReport {
        SimReport { total_cycles: 42, phases: vec![], dma: DmaStats::default() }
    }

    #[test]
    fn envelope_roundtrips_and_rejects_tampering() {
        let dir = tmp_dir("envelope");
        let key = Fingerprint(0xfeed_beef);
        write_entry(&dir, "sim", key, tiny_sim().to_json()).unwrap();
        let path = dir.join(format!("sim-{}.json", key.hex()));
        match load_entry(&path).ok().unwrap() {
            Loaded::Sim(k, sim) => {
                assert_eq!(k, key);
                assert_eq!(sim, tiny_sim());
            }
            Loaded::Plan(..) => panic!("sim entry decoded as plan"),
        }
        // Flip one payload byte: the checksum must catch it.
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, text.replace("\"total_cycles\":42", "\"total_cycles\":43")).unwrap();
        assert!(matches!(load_entry(&path), Err(Skip::Corrupt)));
        // Flip the cache key: the checksum covers it, so a valid payload
        // can never be imported under a corrupted fingerprint.
        std::fs::write(&path, text.replace(&key.hex(), &Fingerprint(0xfeed_beee).hex())).unwrap();
        assert!(matches!(load_entry(&path), Err(Skip::Corrupt)));
        // A different format version is a version skip, not corruption.
        std::fs::write(&path, text.replace(SNAPSHOT_FORMAT, "ftl-snapshot-v0")).unwrap();
        assert!(matches!(load_entry(&path), Err(Skip::Version)));
        // Unparseable text is corruption.
        std::fs::write(&path, "{not json").unwrap();
        assert!(matches!(load_entry(&path), Err(Skip::Corrupt)));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn size_cap_sweeps_oldest_entries() {
        use crate::serve::{PlanService, ServeOptions};
        let dir = tmp_dir("gc");
        let service = Arc::new(PlanService::new(ServeOptions {
            cache_capacity: 8,
            sim_cache_capacity: 8,
            cache_shards: 1,
            workers: 1,
            ..ServeOptions::default()
        }));
        for k in 0..5u128 {
            service.import_sim(Fingerprint(0x1000 + k), Arc::new(tiny_sim()));
        }
        let snap = Snapshotter::attach(
            service,
            dir.clone(),
            PersistOptions { interval: Duration::ZERO, max_entries: 2, ..PersistOptions::default() },
        )
        .unwrap();
        assert_eq!(snap.flush(), 5, "all five entries written before the sweep");
        let count_finals = || {
            std::fs::read_dir(&dir)
                .unwrap()
                .filter_map(|e| e.ok())
                .filter(|e| e.file_name().to_string_lossy().ends_with(".json"))
                .count()
        };
        assert_eq!(count_finals(), 2, "sweep must enforce the cap");
        assert_eq!(snap.counters().evicted(), 3);
        // Evicted keys are not dirty: the next pass writes and evicts
        // nothing (the cap bounds the directory, it doesn't thrash it).
        assert_eq!(snap.flush(), 0);
        assert_eq!(snap.counters().evicted(), 3);
        assert_eq!(count_finals(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn no_tmp_files_survive_a_flush_write() {
        let dir = tmp_dir("atomic");
        write_entry(&dir, "sim", Fingerprint(7), tiny_sim().to_json()).unwrap();
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "atomic write must leave no tmp files");
        let _ = std::fs::remove_dir_all(&dir);
    }

    // ------------------------------------------------- segment snapshots

    fn tiny_service() -> Arc<PlanService> {
        use crate::serve::{PlanService, ServeOptions};
        Arc::new(PlanService::new(ServeOptions {
            cache_capacity: 64,
            sim_cache_capacity: 64,
            cache_shards: 1,
            workers: 1,
            ..ServeOptions::default()
        }))
    }

    fn bin_opts() -> PersistOptions {
        PersistOptions::manual().with_format(SnapshotFormat::Bin)
    }

    #[test]
    fn snapshot_format_parses_cli_spellings() {
        assert_eq!(SnapshotFormat::parse("json"), Some(SnapshotFormat::Json));
        assert_eq!(SnapshotFormat::parse("bin"), Some(SnapshotFormat::Bin));
        assert_eq!(SnapshotFormat::parse("yaml"), None);
        assert_eq!(SnapshotFormat::Bin.name(), "bin");
    }

    #[test]
    fn segment_snapshots_round_trip_with_lane_hints() {
        let dir = tmp_dir("bin-roundtrip");
        {
            let svc = tiny_service();
            let snap = Snapshotter::attach(svc.clone(), dir.clone(), bin_opts()).unwrap();
            svc.import_sim_hinted(Fingerprint(0xA), Arc::new(tiny_sim()), 7);
            svc.import_sim_hinted(Fingerprint(0xB), Arc::new(tiny_sim()), 2);
            assert_eq!(snap.flush(), 2);
            assert_eq!(snap.counters().write_errors(), 0);
            assert_eq!(snap.counters().segments(), 1, "one flush seals one segment");
            assert!(snap.counters().live_bytes() > 0);
            assert_eq!(snap.flush(), 0, "immutable entries are not rewritten");
            assert_eq!(snap.counters().segments(), 1, "a no-op pass must not seal an empty segment");
        }
        assert_eq!(segment::segment_paths(&dir).len(), 1);
        let svc = tiny_service();
        let snap = Snapshotter::attach(svc.clone(), dir.clone(), bin_opts()).unwrap();
        assert_eq!(snap.counters().loaded(), 2, "restart must load both segment entries");
        assert_eq!(snap.counters().skipped_corrupt(), 0);
        assert!(snap.counters().load_us().count() >= 1, "warm-start pass must record load_us");
        let hints: Vec<(Fingerprint, u64)> =
            svc.export_sims_hinted().into_iter().map(|(k, _, h)| (k, h)).collect();
        assert!(hints.contains(&(Fingerprint(0xA), 7)), "lane hints must survive the round trip: {hints:?}");
        assert!(hints.contains(&(Fingerprint(0xB), 2)));
        assert_eq!(snap.flush(), 0, "loaded entries are already on disk");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn json_entries_load_alongside_segments() {
        let dir = tmp_dir("mixed");
        {
            // JSON era.
            let svc = tiny_service();
            let snap = Snapshotter::attach(svc.clone(), dir.clone(), PersistOptions::manual()).unwrap();
            svc.import_sim(Fingerprint(1), Arc::new(tiny_sim()));
            assert_eq!(snap.flush(), 1);
        }
        {
            // Segment era: the JSON entry loads, only the new key is
            // written — into a segment.
            let svc = tiny_service();
            let snap = Snapshotter::attach(svc.clone(), dir.clone(), bin_opts()).unwrap();
            assert_eq!(snap.counters().loaded(), 1);
            svc.import_sim(Fingerprint(2), Arc::new(tiny_sim()));
            assert_eq!(snap.flush(), 1);
        }
        assert_eq!(segment::segment_paths(&dir).len(), 1);
        let svc = tiny_service();
        let snap = Snapshotter::attach(svc.clone(), dir.clone(), bin_opts()).unwrap();
        assert_eq!(snap.counters().loaded(), 2, "segment + legacy JSON entries must both load");
        assert_eq!(snap.counters().skipped_corrupt(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_segment_loads_the_prefix_and_counts_the_tail() {
        let dir = tmp_dir("bin-torn");
        {
            let svc = tiny_service();
            let snap = Snapshotter::attach(svc.clone(), dir.clone(), bin_opts()).unwrap();
            for k in 0..6u64 {
                // Descending hints by key, so the segment's lane order
                // (heaviest first) is keys 0,1,2,...
                svc.import_sim_hinted(Fingerprint(u128::from(k)), Arc::new(tiny_sim()), 6 - k);
            }
            assert_eq!(snap.flush(), 6);
        }
        let path = segment::segment_paths(&dir).pop().unwrap();
        let view = segment::read_segment(&path).unwrap();
        assert_eq!(view.entries.len(), 6);
        // Truncate inside the fourth entry: three entries survive.
        let cut = view.entries[3];
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..cut.offset + cut.len / 2]).unwrap();
        let svc = tiny_service();
        let snap = Snapshotter::attach(svc.clone(), dir.clone(), bin_opts()).unwrap();
        assert_eq!(snap.counters().loaded(), 3, "entries before the tear must load");
        assert_eq!(snap.counters().skipped_corrupt(), 1, "the lost tail is one counted skip");
        let keys: Vec<u128> = svc.export_sims_hinted().into_iter().map(|(k, _, _)| k.0).collect();
        for k in 0..3u128 {
            assert!(keys.contains(&k), "heaviest-hint prefix must survive, missing {k}: {keys:?}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn segment_cap_compacts_lightest_hints_first() {
        let dir = tmp_dir("bin-cap");
        {
            let svc = tiny_service();
            let opts = PersistOptions { max_entries: 2, ..bin_opts() };
            let snap = Snapshotter::attach(svc.clone(), dir.clone(), opts).unwrap();
            for k in 1..=5u64 {
                svc.import_sim_hinted(Fingerprint(u128::from(k)), Arc::new(tiny_sim()), k);
            }
            assert_eq!(snap.flush(), 5);
            assert_eq!(snap.counters().evicted(), 3, "cap must evict the three lightest hints");
            assert_eq!(snap.counters().segments(), 1, "compaction folds everything into one segment");
            assert_eq!(snap.counters().dead_bytes(), 0);
            assert_eq!(snap.counters().compactions_deferred(), 1, "the cap trip was deferred, not inline");
            assert_eq!(snap.counters().compactions(), 1, "…then ran as flush()'s budgeted step");
            assert_eq!(snap.flush(), 0, "evicted keys are not dirty — no rewrite thrash");
            assert_eq!(snap.counters().evicted(), 3);
            assert_eq!(snap.counters().compactions(), 1, "an idle pass must not re-compact");
        }
        assert_eq!(segment::segment_paths(&dir).len(), 1);
        let svc = tiny_service();
        let snap = Snapshotter::attach(svc.clone(), dir.clone(), bin_opts()).unwrap();
        assert_eq!(snap.counters().loaded(), 2);
        let keys: Vec<u128> = svc.export_sims_hinted().into_iter().map(|(k, _, _)| k.0).collect();
        assert!(keys.contains(&4) && keys.contains(&5), "heaviest lanes must survive the cap: {keys:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cap_trip_is_deferred_off_the_write_pass() {
        let dir = tmp_dir("bin-defer");
        let svc = tiny_service();
        let opts = PersistOptions { max_entries: 2, ..bin_opts() };
        let snap = Snapshotter::attach(svc.clone(), dir.clone(), opts).unwrap();
        for k in 1..=5u64 {
            svc.import_sim_hinted(Fingerprint(u128::from(k)), Arc::new(tiny_sim()), k);
        }
        // The write pass alone (what the background thread's flush and
        // every manual flush serialise behind): pre-fix it compacted the
        // directory inline, right there under the `written` lock.
        assert_eq!(snap.inner.flush(), 5);
        assert_eq!(snap.counters().evicted(), 0, "the write pass itself must not compact");
        assert_eq!(snap.counters().compactions(), 0);
        assert_eq!(snap.counters().compactions_deferred(), 1, "…it only records the deferral");
        assert_eq!(segment::segment_paths(&dir).len(), 1, "the sealed segment is untouched");
        // The deferred step — here forced explicitly — does the rewrite.
        assert!(snap.compact_now());
        assert_eq!(snap.counters().compactions(), 1);
        assert_eq!(snap.counters().evicted(), 3);
        assert!(!snap.compact_now(), "nothing left pending");
        // A deferral left behind by a bare write pass is drained at
        // shutdown: a restart must inherit a cap-bounded directory.
        for k in 6..=8u64 {
            svc.import_sim_hinted(Fingerprint(u128::from(k)), Arc::new(tiny_sim()), k);
        }
        assert_eq!(snap.inner.flush(), 3);
        assert_eq!(snap.counters().compactions(), 1, "the bare write pass deferred again");
        snap.shutdown();
        assert_eq!(snap.counters().compactions(), 2, "shutdown must drain the pending compaction");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compact_migrates_json_dirs_in_place() {
        let dir = tmp_dir("migrate");
        {
            let svc = tiny_service();
            let snap = Snapshotter::attach(svc.clone(), dir.clone(), PersistOptions::manual()).unwrap();
            for k in 0..3u128 {
                svc.import_sim(Fingerprint(0x100 + k), Arc::new(tiny_sim()));
            }
            assert_eq!(snap.flush(), 3);
        }
        // A file compaction cannot read stays in place for the operator.
        std::fs::write(dir.join("sim-00000000000000000000000000000bad.json"), "not json").unwrap();
        let report = compact_dir(&dir, 0).unwrap();
        assert_eq!(report.json_migrated, 3);
        assert_eq!(report.live, 3);
        assert_eq!(report.evicted, 0);
        assert_eq!(report.skipped_corrupt, 1);
        assert_eq!(report.segments_after, 1);
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names.iter().filter(|n| n.ends_with(".json")).count(), 1, "only the corrupt file remains");
        assert_eq!(names.iter().filter(|n| n.ends_with(".ftlseg")).count(), 1);
        // Idempotent: a second compaction rewrites the same live set.
        let again = compact_dir(&dir, 0).unwrap();
        assert_eq!(again.live, 3);
        assert_eq!(again.json_migrated, 0);
        let svc = tiny_service();
        let snap = Snapshotter::attach(svc.clone(), dir.clone(), bin_opts()).unwrap();
        assert_eq!(snap.counters().loaded(), 3, "migrated entries must warm-start");
        assert_eq!(snap.counters().skipped_corrupt(), 1);
        let j = inspect_dir(&dir).unwrap();
        assert_eq!(j.get("live_entries").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.get("json_entries").unwrap().as_usize().unwrap(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn final_flush_failures_are_surfaced_not_swallowed() {
        for opts in [PersistOptions::manual(), bin_opts()] {
            let dir = tmp_dir(&format!("drop-flush-{}", opts.format.name()));
            let svc = tiny_service();
            let snap = Snapshotter::attach(svc.clone(), dir.clone(), opts).unwrap();
            svc.import_sim(Fingerprint(0xDEAD), Arc::new(tiny_sim()));
            // Replace the snapshot dir with a regular file: every write
            // from here on fails (ENOTDIR), even for root.
            std::fs::remove_dir_all(&dir).unwrap();
            std::fs::write(&dir, "not a directory").unwrap();
            snap.shutdown();
            assert!(
                snap.counters().write_errors() >= 1,
                "{}: final flush failure must land in write_errors",
                opts.format.name()
            );
            drop(snap);
            let _ = std::fs::remove_file(&dir);
        }
    }
}
