//! `ftl::serve::persist` — the warm-start snapshot layer.
//!
//! The whole serve stack rests on one fact: planning is a pure function
//! of the request, and requests are identified by *process-stable*
//! content fingerprints ([`super::fingerprint`] deliberately avoids
//! `std::hash` so keys survive restarts). This module cashes that
//! promise in: cached `Arc<Deployment>`s and `Arc<SimReport>`s are
//! serialised through the canonical codec layer
//! ([`Deployment::to_json`], [`SimReport::to_json`]) into a snapshot
//! directory, and a restarted service loads them back before taking
//! traffic — a previously-seen request is then served with **zero**
//! branch-and-bound solves and **zero** simulator runs.
//!
//! # Snapshot format
//!
//! One file per cache entry, named `plan-<fingerprint>.json` /
//! `sim-<fingerprint>.json` (32 lowercase hex digits). Each file is a
//! self-validating envelope:
//!
//! ```json
//! {
//!   "format": "ftl-snapshot-v1",         // version tag — bump on any codec change
//!   "kind": "plan" | "sim",
//!   "fingerprint": "<32 hex digits>",     // the cache key
//!   "checksum": "<32 hex digits>",        // FNV-1a/128 over "<kind>\n<fingerprint>\n<payload>"
//!   "payload": { ... canonical encoding ... }
//! }
//! ```
//!
//! The checksum covers the kind and fingerprint as well as the compact
//! payload text, so a corrupted cache key cannot smuggle a valid payload
//! in under the wrong fingerprint. Writes are atomic: the envelope is
//! written to a `.tmp-<pid>` sibling and `rename`d into place, so a
//! crash mid-write can never leave a half-written entry under a final
//! name (stale tmp files from a crashed writer are deleted at the next
//! load). Loading is **never fatal**: a file that fails to parse, fails
//! its checksum, or decodes to garbage is skipped and counted
//! (`persist.skipped_corrupt`); an entry written by a different format
//! version is skipped and counted separately (`persist.skipped_version`).
//! When the service runs with `--verify-plans`, a plan entry that decodes
//! cleanly (valid checksum, valid codec) may still be refused by the
//! static plan verifier at import — it is then neither cached nor counted
//! as `loaded`, and surfaces under the service's `verify.rejected`
//! instead.
//! Writing is never fatal either: an entry that cannot be written is
//! counted (`persist.write_errors`) and retried on the next pass, and
//! the rest of the pass continues. Only an unreadable/uncreatable
//! snapshot *directory* errors the attach.
//!
//! # Write-behind
//!
//! [`Snapshotter::attach`] spawns a background thread that wakes every
//! `PersistOptions::interval` and writes any cache entry not yet on disk
//! (entries are immutable once cached — a fingerprint's plan never
//! changes — so "not yet written" is the only dirty state). A zero
//! interval disables the thread; [`Snapshotter::flush`] runs the same
//! pass synchronously, and shutdown/drop performs a final flush so
//! admitted work is not lost.
//!
//! # Garbage collection
//!
//! By default the directory grows with every distinct fingerprint.
//! [`PersistOptions::max_entries`] (`ftl serve --cache-max-entries`)
//! bounds it: each snapshot pass ends with an mtime-LRU sweep that
//! removes the oldest entries beyond the cap (entries are immutable, so
//! write time is the only recency signal on disk). Evictions are counted
//! (`persist.evicted`), never re-written within the process, and only
//! shrink the warm-start set a restart can load.
//!
//! Counters surface in `stats_json` under `"persist"`: `loaded`,
//! `skipped_corrupt`, `skipped_version`, `snapshots`, `entries_written`,
//! `bytes_written`, `write_errors`, `evicted`, plus a `write_us`
//! histogram of per-envelope write wall time.

#![forbid(unsafe_code)]

use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::Deployment;
use crate::metrics::{Counter, Histogram};
use crate::sim::SimReport;
use crate::util::json::{parse, Json};

use super::fingerprint::{checksum, Fingerprint};
use super::service::PlanService;

/// Snapshot format version tag. Bump whenever the canonical encoding of
/// any persisted type changes incompatibly — old entries are then
/// skipped (counted as `skipped_version`) instead of mis-decoded.
pub const SNAPSHOT_FORMAT: &str = "ftl-snapshot-v1";

/// Tunables for a [`Snapshotter`].
#[derive(Debug, Clone, Copy)]
pub struct PersistOptions {
    /// Write-behind pass interval. `Duration::ZERO` disables the
    /// background thread (snapshots then happen only on explicit
    /// [`Snapshotter::flush`] calls and at shutdown).
    pub interval: Duration,
    /// Snapshot-directory size cap (`ftl serve --cache-max-entries`):
    /// after each snapshot pass, if the directory holds more than this
    /// many entries the oldest (by file mtime, ties by name) are removed
    /// — an mtime-LRU sweep, counted as `persist.evicted`. `0` disables
    /// garbage collection. Evicted entries are *not* re-written while
    /// the process lives (entries are immutable; the cap bounds the
    /// warm-start set a restart can load, nothing else).
    pub max_entries: usize,
}

impl Default for PersistOptions {
    fn default() -> Self {
        Self { interval: Duration::from_millis(1000), max_entries: 0 }
    }
}

impl PersistOptions {
    /// Manual-flush-only options (no background thread).
    pub fn manual() -> Self {
        Self { interval: Duration::ZERO, max_entries: 0 }
    }
}

/// Live persistence counters, shared with [`PlanService`] so they appear
/// in `stats_json` under `"persist"`. All counters are saturating
/// ([`Counter`]) — a long-lived replica pins at `u64::MAX` instead of
/// wrapping — and envelope write wall time feeds a [`Histogram`]
/// (`write_us`).
#[derive(Debug, Default)]
pub struct PersistCounters {
    loaded: Counter,
    skipped_corrupt: Counter,
    skipped_version: Counter,
    snapshots: Counter,
    entries_written: Counter,
    bytes_written: Counter,
    write_errors: Counter,
    evicted: Counter,
    write_us: Histogram,
}

impl PersistCounters {
    /// Entries loaded into the caches at attach time.
    pub fn loaded(&self) -> u64 {
        self.loaded.get()
    }

    /// Entries skipped because they were unreadable, unparseable, failed
    /// their checksum, or failed payload decoding.
    pub fn skipped_corrupt(&self) -> u64 {
        self.skipped_corrupt.get()
    }

    /// Entries skipped because they carry a different format version.
    pub fn skipped_version(&self) -> u64 {
        self.skipped_version.get()
    }

    /// Completed snapshot passes (background + manual + shutdown).
    pub fn snapshots(&self) -> u64 {
        self.snapshots.get()
    }

    /// Entries written to disk over the snapshotter's lifetime.
    pub fn entries_written(&self) -> u64 {
        self.entries_written.get()
    }

    /// Envelope bytes written to disk over the snapshotter's lifetime.
    pub fn bytes_written(&self) -> u64 {
        self.bytes_written.get()
    }

    /// Entries that failed to write (skipped for the pass, retried on
    /// the next one).
    pub fn write_errors(&self) -> u64 {
        self.write_errors.get()
    }

    /// Entries removed by the mtime-LRU size-cap sweep
    /// (`--cache-max-entries`).
    pub fn evicted(&self) -> u64 {
        self.evicted.get()
    }

    /// Wall-time histogram of successful envelope writes, in µs.
    pub fn write_us(&self) -> &Histogram {
        &self.write_us
    }

    /// The `stats_json` rendering (`"persist": {...}`). `Json::Num`, not
    /// `Json::int`: a saturated counter must render, not panic on the
    /// i64 conversion.
    pub fn to_json(&self) -> Json {
        let n = |v: u64| Json::Num(v as f64);
        Json::obj(vec![
            ("loaded", n(self.loaded())),
            ("skipped_corrupt", n(self.skipped_corrupt())),
            ("skipped_version", n(self.skipped_version())),
            ("snapshots", n(self.snapshots())),
            ("entries_written", n(self.entries_written())),
            ("bytes_written", n(self.bytes_written())),
            ("write_errors", n(self.write_errors())),
            ("evicted", n(self.evicted())),
            ("write_us", self.write_us.to_json()),
        ])
    }
}

const KIND_PLAN: u8 = 0;
const KIND_SIM: u8 = 1;

/// The write-behind snapshotter (see module docs). Attach one to a
/// [`PlanService`] and point it at a snapshot directory; existing
/// entries warm-start the caches immediately, new entries are persisted
/// in the background (or on [`Snapshotter::flush`]).
pub struct Snapshotter {
    inner: Arc<SnapInner>,
    writer: Mutex<Option<JoinHandle<()>>>,
}

struct SnapInner {
    service: Arc<PlanService>,
    dir: PathBuf,
    counters: Arc<PersistCounters>,
    /// Keys already on disk (seeded at load) — entries are immutable, so
    /// this is the entire dirty-tracking state. Keys evicted by the size
    /// cap stay in the set: eviction bounds the warm-start directory,
    /// it does not mark the entry dirty again (that would make every
    /// pass re-write and re-evict the same overflow).
    written: Mutex<HashSet<(u8, u128)>>,
    /// Directory size cap (0 = no GC) — see [`PersistOptions::max_entries`].
    max_entries: usize,
    stop: Mutex<bool>,
    wake: Condvar,
}

impl Snapshotter {
    /// Warm-start `service` from `dir` (creating it if absent), register
    /// the `persist.*` counters with the service, and start the
    /// write-behind thread (unless `opts.interval` is zero). Corrupt or
    /// version-mismatched entries are skipped and counted, never fatal.
    pub fn attach(service: Arc<PlanService>, dir: impl Into<PathBuf>, opts: PersistOptions) -> Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir).with_context(|| format!("creating snapshot directory {}", dir.display()))?;
        let counters = Arc::new(PersistCounters::default());
        service.set_persist_counters(counters.clone());
        let mut written = HashSet::new();
        load_dir(&service, &dir, &counters, &mut written)?;
        let inner = Arc::new(SnapInner {
            service,
            dir,
            counters,
            written: Mutex::new(written),
            max_entries: opts.max_entries,
            stop: Mutex::new(false),
            wake: Condvar::new(),
        });
        if opts.max_entries > 0 {
            // A restart may bring a smaller cap than the directory it
            // inherits — sweep once up front.
            inner.gc();
        }
        let writer = if opts.interval.is_zero() {
            None
        } else {
            let worker = inner.clone();
            let interval = opts.interval;
            let handle = std::thread::Builder::new()
                .name("ftl-snapshotter".into())
                .spawn(move || {
                    let mut stopped = worker.stop.lock().expect("snapshotter stop flag poisoned");
                    loop {
                        if *stopped {
                            break;
                        }
                        let (guard, _) =
                            worker.wake.wait_timeout(stopped, interval).expect("snapshotter stop flag poisoned");
                        stopped = guard;
                        if *stopped {
                            break;
                        }
                        drop(stopped);
                        worker.flush();
                        stopped = worker.stop.lock().expect("snapshotter stop flag poisoned");
                    }
                })
                .expect("spawn snapshotter thread");
            Some(handle)
        };
        Ok(Self { inner, writer: Mutex::new(writer) })
    }

    /// Run one write-behind pass now; returns how many new entries were
    /// written. Never fails: an entry that cannot be written is counted
    /// (`write_errors`) and retried on the next pass. Safe to call
    /// concurrently with the background thread.
    pub fn flush(&self) -> usize {
        self.inner.flush()
    }

    /// The snapshot directory.
    pub fn dir(&self) -> &Path {
        &self.inner.dir
    }

    /// Live counters (shared with the service's `stats_json`).
    pub fn counters(&self) -> &PersistCounters {
        &self.inner.counters
    }

    /// Stop the background thread and run a final flush so every cached
    /// entry reaches disk (also runs on drop).
    pub fn shutdown(&self) {
        {
            let mut stopped = self.inner.stop.lock().expect("snapshotter stop flag poisoned");
            *stopped = true;
        }
        self.inner.wake.notify_all();
        if let Some(handle) = self.writer.lock().expect("snapshotter writer poisoned").take() {
            handle.join().ok();
        }
        self.inner.flush();
    }
}

impl Drop for Snapshotter {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl SnapInner {
    /// One write-behind pass: persist every cache entry not yet on disk.
    /// Per-entry write failures are counted and retried next pass — one
    /// unwritable entry must not starve the rest (mirror of the load
    /// side's skip-and-count policy). The flush holds the `written` set
    /// for its whole duration — only snapshotter threads touch it, and
    /// there is at most one background thread, so this serialises
    /// concurrent manual flushes.
    fn flush(&self) -> usize {
        let mut written = self.written.lock().expect("snapshotter written-set poisoned");
        let mut wrote = 0usize;
        let mut bytes = 0u64;
        // The `written` check comes before serialization: in steady state
        // (everything on disk) a pass must not rebuild a single Json tree.
        for (key, plan) in self.service.export_plans() {
            if written.contains(&(KIND_PLAN, key.0)) {
                continue;
            }
            if self.persist_one("plan", key, plan.to_json(), &mut wrote, &mut bytes) {
                written.insert((KIND_PLAN, key.0));
            }
        }
        for (key, sim) in self.service.export_sims() {
            if written.contains(&(KIND_SIM, key.0)) {
                continue;
            }
            if self.persist_one("sim", key, sim.to_json(), &mut wrote, &mut bytes) {
                written.insert((KIND_SIM, key.0));
            }
        }
        self.counters.snapshots.inc();
        self.counters.entries_written.add(wrote as u64);
        self.counters.bytes_written.add(bytes);
        // Only a pass that wrote something can have grown the directory
        // (evicted keys are never re-written), so an idle server must not
        // re-scan it every interval; attach runs one unconditional sweep
        // to enforce a lowered cap over a pre-existing directory.
        if self.max_entries > 0 && wrote > 0 {
            self.gc();
        }
        wrote
    }

    /// mtime-LRU sweep: when the directory holds more than `max_entries`
    /// final entries, remove the oldest (ties broken by file name so the
    /// sweep is deterministic under coarse mtimes). Best-effort — an
    /// entry that cannot be statted or removed is simply left for the
    /// next pass.
    fn gc(&self) {
        let Ok(entries) = std::fs::read_dir(&self.dir) else { return };
        let mut finals: Vec<(std::time::SystemTime, String, PathBuf)> = Vec::new();
        for entry in entries.flatten() {
            let path = entry.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
            if name.contains(".tmp-")
                || !name.ends_with(".json")
                || !(name.starts_with("plan-") || name.starts_with("sim-"))
            {
                continue;
            }
            let Ok(mtime) = entry.metadata().and_then(|m| m.modified()) else { continue };
            finals.push((mtime, name.to_string(), path));
        }
        if finals.len() <= self.max_entries {
            return;
        }
        finals.sort_by(|a, b| (a.0, &a.1).cmp(&(b.0, &b.1)));
        let excess = finals.len() - self.max_entries;
        let mut evicted = 0u64;
        for (_, _, path) in finals.into_iter().take(excess) {
            if std::fs::remove_file(&path).is_ok() {
                evicted += 1;
            }
        }
        self.counters.evicted.add(evicted);
    }

    /// Write one envelope, counting failures instead of propagating them
    /// (a failed entry is retried on the next pass). Returns whether the
    /// entry reached disk.
    fn persist_one(&self, tag: &str, key: Fingerprint, payload: Json, wrote: &mut usize, bytes: &mut u64) -> bool {
        let write_start = Instant::now();
        match write_entry(&self.dir, tag, key, payload) {
            Ok(b) => {
                self.counters.write_us.record_duration(write_start.elapsed());
                *wrote += 1;
                *bytes += b;
                true
            }
            Err(e) => {
                self.counters.write_errors.inc();
                eprintln!("[ftl-serve] snapshot write failed for {tag}-{}: {e:#}", key.hex());
                false
            }
        }
    }
}

/// The checksummed byte string of one envelope: kind + fingerprint +
/// compact payload text, so corruption of the cache key is caught just
/// like corruption of the payload.
fn checksum_input(kind: &str, key: Fingerprint, payload_text: &str) -> String {
    format!("{kind}\n{}\n{payload_text}", key.hex())
}

/// Atomically write one envelope; returns its size in bytes.
fn write_entry(dir: &Path, kind: &str, key: Fingerprint, payload: Json) -> Result<u64> {
    let payload_text = payload.to_string();
    let sum = checksum(checksum_input(kind, key, &payload_text).as_bytes());
    let doc = Json::obj(vec![
        ("format", Json::str(SNAPSHOT_FORMAT)),
        ("kind", Json::str(kind)),
        ("fingerprint", Json::str(key.hex())),
        ("checksum", Json::str(sum.hex())),
        ("payload", payload),
    ]);
    let text = doc.to_string();
    let final_path = dir.join(format!("{kind}-{}.json", key.hex()));
    let tmp_path = dir.join(format!("{kind}-{}.json.tmp-{}", key.hex(), std::process::id()));
    std::fs::write(&tmp_path, &text).with_context(|| format!("writing {}", tmp_path.display()))?;
    std::fs::rename(&tmp_path, &final_path).with_context(|| format!("renaming {} into place", tmp_path.display()))?;
    Ok(text.len() as u64)
}

/// A decoded snapshot entry.
enum Loaded {
    Plan(Fingerprint, Deployment),
    Sim(Fingerprint, SimReport),
}

/// Why an entry was skipped.
enum Skip {
    Version,
    Corrupt,
}

/// Scan `dir` and import every valid entry into the service's caches.
/// Per-entry failures are counted, never propagated.
fn load_dir(
    service: &PlanService,
    dir: &Path,
    counters: &PersistCounters,
    written: &mut HashSet<(u8, u128)>,
) -> Result<()> {
    let entries = std::fs::read_dir(dir).with_context(|| format!("reading snapshot directory {}", dir.display()))?;
    for entry in entries {
        let Ok(entry) = entry else { continue };
        let path = entry.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
        // Stale `.tmp-<pid>` files from a crashed writer are dead weight,
        // but another *live* replica sharing this directory may be
        // mid-write right now — only reap tmp files old enough that no
        // in-flight rename can still want them (best-effort).
        if name.contains(".tmp-") {
            let stale = std::fs::metadata(&path)
                .and_then(|m| m.modified())
                .ok()
                .and_then(|t| t.elapsed().ok())
                .is_some_and(|age| age > Duration::from_secs(60));
            if stale {
                let _ = std::fs::remove_file(&path);
            }
            continue;
        }
        // Final entries only.
        if !name.ends_with(".json") || !(name.starts_with("plan-") || name.starts_with("sim-")) {
            continue;
        }
        match load_entry(&path) {
            Ok(Loaded::Plan(key, plan)) => {
                // Under `--verify-plans` the service may refuse the entry
                // (error-severity findings, `verify.rejected`). A refused
                // entry is neither loaded nor marked written — it is not
                // in the cache, so flush passes have nothing to re-export
                // for it and the file is simply left to the size-cap GC.
                if service.import_plan(key, Arc::new(plan)) {
                    written.insert((KIND_PLAN, key.0));
                    counters.loaded.inc();
                }
            }
            Ok(Loaded::Sim(key, sim)) => {
                service.import_sim(key, Arc::new(sim));
                written.insert((KIND_SIM, key.0));
                counters.loaded.inc();
            }
            Err(Skip::Version) => {
                counters.skipped_version.inc();
            }
            Err(Skip::Corrupt) => {
                counters.skipped_corrupt.inc();
            }
        }
    }
    Ok(())
}

/// Validate and decode one envelope file.
fn load_entry(path: &Path) -> std::result::Result<Loaded, Skip> {
    let text = std::fs::read_to_string(path).map_err(|_| Skip::Corrupt)?;
    let doc = parse(&text).map_err(|_| Skip::Corrupt)?;
    let format = doc.get("format").and_then(|f| f.as_str()).map_err(|_| Skip::Corrupt)?;
    if format != SNAPSHOT_FORMAT {
        return Err(Skip::Version);
    }
    let kind = doc.get("kind").and_then(|k| k.as_str()).map_err(|_| Skip::Corrupt)?;
    let hex = doc.get("fingerprint").and_then(|f| f.as_str()).map_err(|_| Skip::Corrupt)?;
    let key = Fingerprint(u128::from_str_radix(hex, 16).map_err(|_| Skip::Corrupt)?);
    let declared = doc.get("checksum").and_then(|c| c.as_str()).map_err(|_| Skip::Corrupt)?;
    let payload = doc.get("payload").map_err(|_| Skip::Corrupt)?;
    // Re-serialising the parsed payload through the canonical printer
    // reproduces the exact text the checksum was computed over (the
    // printer is deterministic: sorted keys, shortest-roundtrip floats).
    let canonical = payload.to_string();
    if checksum(checksum_input(kind, key, &canonical).as_bytes()).hex() != declared {
        return Err(Skip::Corrupt);
    }
    match kind {
        "plan" => Ok(Loaded::Plan(key, Deployment::from_json(payload).map_err(|_| Skip::Corrupt)?)),
        "sim" => Ok(Loaded::Sim(key, SimReport::from_json(payload).map_err(|_| Skip::Corrupt)?)),
        _ => Err(Skip::Corrupt),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dma::DmaStats;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("ftl-persist-unit-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn tiny_sim() -> SimReport {
        SimReport { total_cycles: 42, phases: vec![], dma: DmaStats::default() }
    }

    #[test]
    fn envelope_roundtrips_and_rejects_tampering() {
        let dir = tmp_dir("envelope");
        let key = Fingerprint(0xfeed_beef);
        write_entry(&dir, "sim", key, tiny_sim().to_json()).unwrap();
        let path = dir.join(format!("sim-{}.json", key.hex()));
        match load_entry(&path).ok().unwrap() {
            Loaded::Sim(k, sim) => {
                assert_eq!(k, key);
                assert_eq!(sim, tiny_sim());
            }
            Loaded::Plan(..) => panic!("sim entry decoded as plan"),
        }
        // Flip one payload byte: the checksum must catch it.
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, text.replace("\"total_cycles\":42", "\"total_cycles\":43")).unwrap();
        assert!(matches!(load_entry(&path), Err(Skip::Corrupt)));
        // Flip the cache key: the checksum covers it, so a valid payload
        // can never be imported under a corrupted fingerprint.
        std::fs::write(&path, text.replace(&key.hex(), &Fingerprint(0xfeed_beee).hex())).unwrap();
        assert!(matches!(load_entry(&path), Err(Skip::Corrupt)));
        // A different format version is a version skip, not corruption.
        std::fs::write(&path, text.replace(SNAPSHOT_FORMAT, "ftl-snapshot-v0")).unwrap();
        assert!(matches!(load_entry(&path), Err(Skip::Version)));
        // Unparseable text is corruption.
        std::fs::write(&path, "{not json").unwrap();
        assert!(matches!(load_entry(&path), Err(Skip::Corrupt)));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn size_cap_sweeps_oldest_entries() {
        use crate::serve::{PlanService, ServeOptions};
        let dir = tmp_dir("gc");
        let service = Arc::new(PlanService::new(ServeOptions {
            cache_capacity: 8,
            sim_cache_capacity: 8,
            cache_shards: 1,
            workers: 1,
            ..ServeOptions::default()
        }));
        for k in 0..5u128 {
            service.import_sim(Fingerprint(0x1000 + k), Arc::new(tiny_sim()));
        }
        let snap = Snapshotter::attach(
            service,
            dir.clone(),
            PersistOptions { interval: Duration::ZERO, max_entries: 2 },
        )
        .unwrap();
        assert_eq!(snap.flush(), 5, "all five entries written before the sweep");
        let count_finals = || {
            std::fs::read_dir(&dir)
                .unwrap()
                .filter_map(|e| e.ok())
                .filter(|e| e.file_name().to_string_lossy().ends_with(".json"))
                .count()
        };
        assert_eq!(count_finals(), 2, "sweep must enforce the cap");
        assert_eq!(snap.counters().evicted(), 3);
        // Evicted keys are not dirty: the next pass writes and evicts
        // nothing (the cap bounds the directory, it doesn't thrash it).
        assert_eq!(snap.flush(), 0);
        assert_eq!(snap.counters().evicted(), 3);
        assert_eq!(count_finals(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn no_tmp_files_survive_a_flush_write() {
        let dir = tmp_dir("atomic");
        write_entry(&dir, "sim", Fingerprint(7), tiny_sim().to_json()).unwrap();
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "atomic write must leave no tmp files");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
