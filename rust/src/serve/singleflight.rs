//! Single-flight request coalescing.
//!
//! The branch-&-bound solve is the expensive step of the pipeline; when N
//! identical requests arrive concurrently (the common pattern behind a
//! load balancer), only the first — the *leader* — runs the computation.
//! The rest park on a condvar and receive a clone of the leader's result,
//! so the solver runs **exactly once per key** regardless of concurrency.
//!
//! Values must be `Clone` (the serve layer uses `Arc<Deployment>`, so a
//! "clone" is a refcount bump). Errors don't generally implement `Clone`,
//! so followers receive the leader's failure re-rendered from its full
//! context chain. The in-flight table only holds keys while a leader is
//! computing; completed flights are removed immediately after the result
//! is published, and the caller is expected to make the result visible
//! (e.g. insert into the plan cache) *inside* the leader closure so no
//! window exists where neither the cache nor the flight table covers the
//! key.

#![forbid(unsafe_code)]

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, Result};

/// One in-flight computation: the slot the leader fills + the condvar
/// followers wait on.
struct Call<T> {
    slot: Mutex<Option<Result<T, String>>>,
    done: Condvar,
}

impl<T> Call<T> {
    fn new() -> Self {
        Self { slot: Mutex::new(None), done: Condvar::new() }
    }
}

/// Who performed the work for a [`SingleFlight::run`] call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// This caller executed the closure.
    Leader,
    /// This caller waited and received the leader's result.
    Follower,
}

/// Coalesces concurrent computations by key (see module docs).
pub struct SingleFlight<T: Clone> {
    calls: Mutex<HashMap<u128, Arc<Call<T>>>>,
    leads: AtomicU64,
    waits: AtomicU64,
}

/// Leader-side cleanup that also runs on unwind: if the leader's closure
/// panics before publishing, followers would otherwise block forever on
/// the condvar and every future request for the key would join the dead
/// flight. On drop this publishes a failure into any still-empty slot,
/// wakes the followers, and removes the flight-table entry. Locks are
/// taken with `if let Ok(..)` — never `expect` — because this drop can
/// run mid-panic and a second panic would abort the process.
struct LeaderGuard<'a, T: Clone> {
    flight: &'a SingleFlight<T>,
    call: Arc<Call<T>>,
    key: u128,
}

impl<T: Clone> Drop for LeaderGuard<'_, T> {
    fn drop(&mut self) {
        if let Ok(mut slot) = self.call.slot.lock() {
            if slot.is_none() {
                *slot = Some(Err("leader panicked before publishing a result".to_string()));
            }
        }
        self.call.done.notify_all();
        if let Ok(mut calls) = self.flight.calls.lock() {
            calls.remove(&self.key);
        }
    }
}

impl<T: Clone> Default for SingleFlight<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Clone> SingleFlight<T> {
    /// Empty flight table.
    pub fn new() -> Self {
        Self { calls: Mutex::new(HashMap::new()), leads: AtomicU64::new(0), waits: AtomicU64::new(0) }
    }

    /// Run `f` for `key`, or wait for the concurrent leader already
    /// running it. Returns the result plus this caller's [`Role`].
    pub fn run(&self, key: u128, f: impl FnOnce() -> Result<T>) -> (Result<T>, Role) {
        let (call, role) = {
            let mut calls = self.calls.lock().expect("single-flight table poisoned");
            match calls.get(&key) {
                Some(existing) => (existing.clone(), Role::Follower),
                None => {
                    let fresh = Arc::new(Call::new());
                    calls.insert(key, fresh.clone());
                    (fresh, Role::Leader)
                }
            }
        };

        match role {
            Role::Leader => {
                self.leads.fetch_add(1, Ordering::Relaxed);
                // The guard publishes + notifies + removes on drop — on
                // the normal path *after* the result is stored below, and
                // on unwind (publishing a failure) if `f` panics.
                let guard = LeaderGuard { flight: self, call: call.clone(), key };
                let result = f();
                let shared: Result<T, String> = match &result {
                    Ok(v) => Ok(v.clone()),
                    // `{:#}` keeps the whole context chain for followers.
                    Err(e) => Err(format!("{e:#}")),
                };
                {
                    let mut slot = call.slot.lock().expect("single-flight slot poisoned");
                    *slot = Some(shared);
                }
                // Drop order: publish happened above, so the guard's drop
                // notifies followers and removes the flight entry — a
                // follower that grabbed the call just before removal
                // finds the slot already filled.
                drop(guard);
                (result, Role::Leader)
            }
            Role::Follower => {
                self.waits.fetch_add(1, Ordering::Relaxed);
                let mut slot = call.slot.lock().expect("single-flight slot poisoned");
                while slot.is_none() {
                    slot = call.done.wait(slot).expect("single-flight wait poisoned");
                }
                let shared = slot.clone().expect("slot filled before notify");
                (shared.map_err(|e| anyhow!("single-flight leader failed: {e}")), Role::Follower)
            }
        }
    }

    /// How many callers executed a closure (led a flight).
    pub fn leads(&self) -> u64 {
        self.leads.load(Ordering::Relaxed)
    }

    /// How many callers coalesced onto another caller's flight.
    pub fn waits(&self) -> u64 {
        self.waits.load(Ordering::Relaxed)
    }

    /// Number of keys currently being computed.
    pub fn in_flight(&self) -> usize {
        self.calls.lock().expect("single-flight table poisoned").len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    #[test]
    fn single_caller_leads() {
        let sf: SingleFlight<u32> = SingleFlight::new();
        let (res, role) = sf.run(1, || Ok(7));
        assert_eq!(res.unwrap(), 7);
        assert_eq!(role, Role::Leader);
        assert_eq!(sf.leads(), 1);
        assert_eq!(sf.waits(), 0);
        assert_eq!(sf.in_flight(), 0, "completed flights must be removed");
    }

    #[test]
    fn concurrent_callers_coalesce_to_one_execution() {
        let sf: SingleFlight<u32> = SingleFlight::new();
        let executions = AtomicUsize::new(0);
        let leaders = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    let (res, role) = sf.run(42, || {
                        executions.fetch_add(1, Ordering::SeqCst);
                        // Hold the flight open until every other thread has
                        // registered as a follower (bounded, so a broken
                        // implementation fails instead of hanging).
                        let start = std::time::Instant::now();
                        while sf.waits() < 7 && start.elapsed() < Duration::from_secs(10) {
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        Ok(99)
                    });
                    assert_eq!(res.unwrap(), 99);
                    if role == Role::Leader {
                        leaders.fetch_add(1, Ordering::SeqCst);
                    }
                });
            }
        });
        assert_eq!(executions.load(Ordering::SeqCst), 1, "exactly one solve per key");
        assert_eq!(leaders.load(Ordering::SeqCst), 1);
        assert_eq!(sf.leads(), 1);
        assert_eq!(sf.waits(), 7);
    }

    #[test]
    fn distinct_keys_run_independently() {
        let sf: SingleFlight<u32> = SingleFlight::new();
        let (a, _) = sf.run(1, || Ok(1));
        let (b, _) = sf.run(2, || Ok(2));
        assert_eq!(a.unwrap() + b.unwrap(), 3);
        assert_eq!(sf.leads(), 2);
    }

    #[test]
    fn leader_error_propagates_to_followers() {
        let sf: SingleFlight<u32> = SingleFlight::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    let (res, _) = sf.run(7, || {
                        std::thread::sleep(Duration::from_millis(50));
                        Err(anyhow::Error::msg("boom").context("solving"))
                    });
                    let msg = format!("{:#}", res.unwrap_err());
                    assert!(msg.contains("boom"), "error chain lost: {msg}");
                });
            }
        });
        assert_eq!(sf.leads() + sf.waits(), 4);
    }

    #[test]
    fn leader_panic_unblocks_follower_and_clears_key() {
        let sf: SingleFlight<u32> = SingleFlight::new();
        let follower_errs = AtomicUsize::new(0);
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for _ in 0..2 {
                handles.push(s.spawn(|| {
                    let (res, role) = sf.run(11, || {
                        // Only the leader runs this: wait for the follower
                        // to park, then die without publishing.
                        let start = std::time::Instant::now();
                        while sf.waits() < 1 && start.elapsed() < Duration::from_secs(10) {
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        panic!("leader dies mid-solve");
                    });
                    // Only the follower reaches here.
                    assert_eq!(role, Role::Follower);
                    let msg = format!("{}", res.unwrap_err());
                    assert!(msg.contains("panicked"), "follower must see the panic: {msg}");
                    follower_errs.fetch_add(1, Ordering::SeqCst);
                }));
            }
            // One handle joins with Err (the panicking leader) — swallow it
            // so the scope doesn't re-panic.
            for h in handles {
                let _ = h.join();
            }
        });
        assert_eq!(follower_errs.load(Ordering::SeqCst), 1);
        assert_eq!(sf.in_flight(), 0, "panicked flight must be removed");
        // The key is immediately reusable.
        let (res, role) = sf.run(11, || Ok(5));
        assert_eq!(res.unwrap(), 5);
        assert_eq!(role, Role::Leader);
    }

    #[test]
    fn key_reusable_after_completion() {
        let sf: SingleFlight<u32> = SingleFlight::new();
        let count = AtomicUsize::new(0);
        for _ in 0..3 {
            let (res, role) = sf.run(5, || {
                count.fetch_add(1, Ordering::SeqCst);
                Ok(1)
            });
            assert_eq!(res.unwrap(), 1);
            assert_eq!(role, Role::Leader, "sequential callers each lead a fresh flight");
        }
        assert_eq!(count.load(Ordering::SeqCst), 3);
    }
}
