//! `ftl::serve` — the plan-cache + single-flight deployment service layer.
//!
//! The FTL pipeline (fuse → branch-&-bound solve → allocate → schedule)
//! is **deterministic** for a given (graph, SoC, strategy, config): a
//! compiled [`crate::coordinator::Deployment`] is a pure function of its
//! request. This layer exploits that to serve heavy traffic: solve each
//! distinct planning problem once, then hand the shared plan to every
//! structurally identical request.
//!
//! ```text
//!            request (graph, DeployConfig)
//!                      │
//!            [fingerprint]  stable 128-bit content hash
//!                      │
//!            [cache]  sharded LRU of Arc<Deployment> ── hit ──► reply
//!                      │ miss
//!            [singleflight]  concurrent misses coalesce; one leader
//!                      │ solves, followers wait on its result
//!            coordinator::Deployer::plan()  (the expensive solve)
//!                      │
//!            cache insert ──► reply (simulation re-runs per request)
//! ```
//!
//! # Cache-key contract
//!
//! Two requests share a plan **iff** their [`Fingerprint`]s are equal.
//! The fingerprint covers, exactly:
//!
//! * **Graph structure** — tensor shapes, dtypes and kinds; node
//!   topology (which tensor indices each node reads/writes); and every
//!   operator attribute (GEMM layout flags, LayerNorm epsilon bits,
//!   Conv2d geometry). Tensor/node **names are excluded**: renaming
//!   layers does not miss the cache. The cached schedule therefore
//!   carries the names of whichever request solved first — names are
//!   cosmetic in reports, never semantic.
//! * **SoC structure** — memory capacities/alignments, cluster and NPU
//!   throughput models, DMA cost models, clock. The preset *name* is
//!   excluded; aliases of the same hardware share plans.
//! * **Planning config** — strategy, double-buffering, all solver
//!   options (bit-exact for floats) and the homes policy.
//!
//! Anything that can change the solver's output must be (and is) part of
//! the key; anything cosmetic must not be. When adding a field to
//! [`crate::config::DeployConfig`] or a new [`crate::ir::Op`] attribute,
//! extend [`fingerprint`] in the same change — a missed field silently
//! serves stale plans.
//!
//! Served plans are shared as `Arc<Deployment>` — the cache never clones
//! a plan, and callers must not mutate one.

mod cache;
mod fingerprint;
mod service;
mod singleflight;

pub use cache::{LruCache, PlanCache};
pub use fingerprint::{fingerprint, Fingerprint};
pub use service::{
    handle_line, resolve_workload, AsyncReply, PlanOutcome, PlanService, ServeOptions, ServeReply,
    ServeStats,
};
pub use singleflight::{Role, SingleFlight};
