//! `ftl::serve` — the traffic-shaped deployment service layer.
//!
//! The FTL pipeline (fuse → branch-&-bound solve → allocate → schedule)
//! is **deterministic** for a given (graph, SoC, strategy, config): a
//! compiled [`crate::coordinator::Deployment`] is a pure function of its
//! request, and so is its simulation report. This layer exploits that to
//! serve heavy traffic: solve and simulate each distinct planning
//! problem once, then hand the shared results to every structurally
//! identical request — with admission control in front so overload sheds
//! instead of stalling.
//!
//! # Serving
//!
//! Request lifecycle (`admit → batch → solve-or-hit → simulate-or-hit →
//! reply`), as driven by `ftl serve` and `examples/deploy_server.rs`:
//!
//! ```text
//!            request (workload, graph, DeployConfig [, deadline] [, lane])
//!                      │
//!            [fast path]  both caches warm? → serve immediately,
//!                      │    skipping the lanes and the batch window
//!                      │    (warm traffic is lane-agnostic)
//!            [admit]   BatchScheduler per-lane bounded queue (lane= name,
//!                      │    unknown → default): full? → shed (SHED) or
//!                      │    block for space; deadline expired (now or
//!                      │    while parked) → TIMEOUT
//!            [schedule] dispatcher holds a window open, then WFQ picks
//!                      │    the lane with the smallest virtual finish tag
//!                      │    and drains one batch (quantum) from it; the
//!                      │    batch's cold work is charged back to the lane,
//!                      │    so saturated lanes split cold work by weight
//!            [batch]   the quantum's batch is grouped by SoC fingerprint
//!                      │    (solver locality) and deduped by full
//!                      │    fingerprint (one solve per run, fan the
//!                      │    result out to every waiter)
//!            [solve-or-hit]     sharded LRU of Arc<Deployment>; misses
//!                      │        coalesce through SingleFlight, one leader
//!                      │        runs coordinator::Deployer::plan()
//!            [simulate-or-hit]  second sharded LRU of Arc<SimReport>
//!                      │        keyed by the plan fingerprint; warm keys
//!                      │        skip sim::engine entirely
//!            [reply]   per-request DeployReport (own workload label) +
//!                      cached / sim_cached flags + fingerprint
//! ```
//!
//! Synchronous callers can still use [`PlanService::plan`] /
//! [`PlanService::deploy`] directly — the caches and single-flight sit
//! below the batching layer, so both entry points stay coherent.
//!
//! # Cache-key contract
//!
//! Two requests share a plan **iff** their [`Fingerprint`]s are equal.
//! The fingerprint covers, exactly:
//!
//! * **Graph structure** — tensor shapes, dtypes and kinds; node
//!   topology (which tensor indices each node reads/writes); and every
//!   operator attribute (GEMM layout flags, LayerNorm epsilon bits,
//!   Conv2d geometry). Tensor/node **names are excluded**: renaming
//!   layers does not miss the cache. The cached schedule therefore
//!   carries the names of whichever request solved first — names are
//!   cosmetic in reports, never semantic.
//! * **SoC structure** — memory capacities/alignments, cluster and NPU
//!   throughput models, DMA cost models, clock. The preset *name* is
//!   excluded; aliases of the same hardware share plans. (The batching
//!   scheduler groups by this component alone — see
//!   [`soc_fingerprint`].)
//! * **Planning config** — strategy, double-buffering, all solver
//!   options (bit-exact for floats) and the homes policy.
//!
//! Simulation reports are cached under the same fingerprint rehashed
//! into a disjoint key space ([`Fingerprint::derive`]): the simulator is
//! deterministic for a fixed (schedule, SoC), both of which the plan
//! fingerprint covers.
//!
//! Anything that can change the solver's output must be (and is) part of
//! the key; anything cosmetic must not be. When adding a field to
//! [`crate::config::DeployConfig`] or a new [`crate::ir::Op`] attribute,
//! extend [`fingerprint`] in the same change — a missed field silently
//! serves stale plans.
//!
//! Served plans are shared as `Arc<Deployment>` — the cache never clones
//! a plan, and callers must not mutate one.
//!
//! # Persistence (warm start)
//!
//! Fingerprints are process-stable by construction, so the caches
//! survive restarts: [`persist::Snapshotter::attach`] points a
//! [`PlanService`] at a snapshot directory (`ftl serve --cache-dir`),
//! loads every valid entry back into the plan + sim caches before the
//! first request, and write-behinds new entries in the background
//! (`--snapshot-interval-ms`). Two on-disk codecs exist behind one
//! loader ([`persist::SnapshotFormat`]): self-validating per-entry JSON
//! envelopes ([`persist::SNAPSHOT_FORMAT`]) and batched binary
//! **segment files** ([`segment`], `ftl serve` default) — `ftl-bin-v1`
//! entries with per-entry FNV-1a/128 checksums and a footer index
//! carrying lane-weight hints, so a restart is a few sequential reads
//! decoded in parallel, heaviest lanes first. Reads always accept both
//! (`ftl snapshot compact` migrates JSON dirs in place); all writes are
//! atomic via tmp-file + fsync + rename. **Corruption policy:** a
//! mangled entry is skipped and counted (`persist.skipped_corrupt`), an
//! entry from another format version likewise (`persist.skipped_version`);
//! neither is ever fatal, and the affected request simply re-solves. A
//! restarted replica pointed at a populated directory serves previously
//! seen requests with zero solves and zero simulator runs.
//!
//! # Verification gate
//!
//! With `ftl serve --verify-plans` ([`ServeOptions::verify_plans`]),
//! every plan is statically verified ([`crate::verify`]) at the two
//! points where one enters the cache: a fresh solve is checked before
//! insertion (a failing plan errors the request instead of poisoning
//! the cache), and a snapshot-loaded entry is checked at warm-start —
//! an envelope that passes the checksum above but whose *payload*
//! violates a safety invariant (overlapping arena spans, a DMA race, a
//! coverage gap, …) is refused, counted under `verify.rejected`, and
//! the affected request simply re-solves. Warm hits never re-verify:
//! the gate adds zero work to the warm path (bench-asserted). The
//! `verify` counter block (`checked`/`rejected`/`findings`) is always
//! present in `STATS` and flattens into `METRICS` as `verify.*`.
//!
//! # Observability
//!
//! Every request is traced end to end ([`trace`]): a monotonic trace id
//! (echoed as `"trace"` in `DEPLOY` replies), stage offsets
//! (queued → picked → solved → simmed, µs since admission), outcome,
//! lane and warm/cold flag. Completed spans land in a fixed-capacity
//! ring journal (`TRACE [n]`, `--trace-cap`) and — when the total
//! latency crosses `--slowlog-ms` — in a bounded slowlog (`SLOW [n]`).
//! Served latencies feed lock-free log-bucketed histograms
//! ([`crate::metrics::Histogram`]) per lane × warm/cold plus a
//! scheduler-wide one; the merge of the per-lane histograms equals the
//! scheduler-wide one bucket-for-bucket (self-test- and property-test-
//! asserted). `STATS` reports the summaries under `latency.*` plus a
//! `server` identity/config block, and `METRICS` renders everything as
//! Prometheus-style text ([`crate::metrics::expo`]). `--trace-cap 0`
//! disables tracing entirely — the warm path then pays zero overhead.
//!
//! # Front door
//!
//! [`Frontend`] is the network face: a single readiness-polled event
//! loop multiplexes many in-flight requests per TCP connection. Clients
//! speak the typed, versioned line protocol in [`proto`] (see
//! `PROTOCOL.md` at the repo root): v1 frames carry a client-chosen
//! request id and receive *streamed* partial replies — a `plan` event as
//! soon as the solve lands, per-phase `sim` events, then a terminal
//! `done`/`error` — with responses free to interleave out of order
//! across ids. Bare legacy (v0) lines keep working unchanged and are
//! answered in order, one JSON line per request. Per-connection write
//! queues are bounded; clients that stop reading are shed rather than
//! allowed to wedge the loop.

mod batch;
mod cache;
mod fingerprint;
mod frontend;
pub mod lanes;
pub mod persist;
pub mod proto;
pub mod segment;
mod service;
mod singleflight;
pub mod trace;
pub mod wave;
pub mod wfq;

pub use batch::{
    handle_command, handle_line, handle_typed, outcome_to_json, AdmissionPolicy, BatchOptions, BatchOutcome,
    BatchScheduler, DeployCompletion, DeployRequest,
};
pub use cache::{LruCache, PlanCache, SimCache};
pub use fingerprint::{checksum, fingerprint, soc_fingerprint, Fingerprint};
pub use frontend::{Frontend, FrontendCounters, FrontendHandle, FrontendOptions};
pub use lanes::{normalize_specs, DEFAULT_LANE, LaneSet, LaneSpec};
pub use persist::{
    compact_dir, inspect_dir, CompactReport, PersistCounters, PersistOptions, SNAPSHOT_FORMAT, SnapshotFormat,
    Snapshotter,
};
pub use service::{
    resolve_workload, AsyncReply, PlanOutcome, PlanService, ServeOptions, ServeReply, ServeStats,
};
pub use singleflight::{Role, SingleFlight};
pub use trace::{ActiveSpan, Span, TraceOptions, Tracer};
