//! [`PlanService`] — the deployment service facade.
//!
//! Ties the serve layer together: fingerprint the request, consult the
//! sharded [`PlanCache`], coalesce concurrent misses through
//! [`SingleFlight`], and only then run the coordinator's planning
//! pipeline. A second sharded LRU (the [`SimCache`]) does the same for
//! simulation reports, so a fully warm request touches neither the
//! solver nor `sim::engine`. Exposes a synchronous API (`plan` /
//! `deploy`) for request-response callers and a fire-and-forget queue
//! (`submit` / `submit_with`) drained by a worker-thread pool for cache
//! warming and async callers. All counters surface in a JSON stats
//! snapshot.

#![forbid(unsafe_code)]

use std::sync::mpsc::{Receiver, Sender};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::config::DeployConfig;
use crate::coordinator::{experiments, DeployReport, Deployer, Deployment};
use crate::ir::builder::vit_mlp_preset;
use crate::ir::Graph;
use crate::metrics::{Counter, Histogram};
use crate::sim::SimReport;
use crate::util::json::Json;

use super::cache::{PlanCache, SimCache};
use super::fingerprint::{checksum, fingerprint, Fingerprint};
use super::persist::PersistCounters;
use super::proto::{Event, EventSink};
use super::singleflight::SingleFlight;
use super::trace::ActiveSpan;

/// Domain tag separating sim-cache keys from plan-cache keys (see
/// [`Fingerprint::derive`]). Bump when the simulator's output changes
/// shape-compatibly but not value-compatibly.
const SIM_KEY_TAG: &str = "ftl-sim-v1";

/// Tunables for a [`PlanService`].
#[derive(Debug, Clone, Copy)]
pub struct ServeOptions {
    /// Max cached plans (total across shards).
    pub cache_capacity: usize,
    /// Max cached simulation reports (total across shards).
    pub sim_cache_capacity: usize,
    /// Number of cache lock shards.
    pub cache_shards: usize,
    /// Worker threads draining the fire-and-forget queue.
    pub workers: usize,
    /// Run [`crate::verify::check_deployment`] on every plan before it
    /// enters the cache (`ftl serve --verify-plans`): fresh solves that
    /// fail verification error the request instead of being cached, and
    /// snapshot-loaded entries that fail are rejected at warm-start.
    /// Checks run only at insertion/import — the warm (cache-hit) path
    /// never pays for them.
    pub verify_plans: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self { cache_capacity: 128, sim_cache_capacity: 256, cache_shards: 8, workers: 4, verify_plans: false }
    }
}

/// `verify.*` counters (the `--verify-plans` gate; all zero when the gate
/// is off).
#[derive(Debug, Default)]
struct VerifyCounters {
    /// Plans checked (fresh solves + snapshot imports).
    checked: Counter,
    /// Plans rejected for error-severity findings (never cached).
    rejected: Counter,
    /// Total error-severity findings across rejected plans.
    findings: Counter,
}

impl VerifyCounters {
    /// `stats_json` rendering (`"verify": {...}`). `Json::Num`, not
    /// `Json::int`: a saturated counter must render, not panic.
    fn to_json(&self) -> Json {
        let n = |v: u64| Json::Num(v as f64);
        Json::obj(vec![
            ("checked", n(self.checked.get())),
            ("rejected", n(self.rejected.get())),
            ("findings", n(self.findings.get())),
        ])
    }
}

/// Outcome of the plan-cache path.
#[derive(Clone)]
pub struct PlanOutcome {
    /// The (shared) compiled plan.
    pub plan: Arc<Deployment>,
    /// The request's cache key.
    pub fingerprint: Fingerprint,
    /// True if the plan came from the cache without consulting the solver
    /// (including coalescing onto a concurrent solve).
    pub cached: bool,
}

/// Full response for one deployment request.
pub struct ServeReply {
    /// The (shared) compiled plan.
    pub plan: Arc<Deployment>,
    /// Plan + simulation report. The report wrapper is rebuilt per request
    /// (it carries the per-request workload name) but the simulation
    /// inside it comes from the sim cache whenever the key is warm.
    pub report: DeployReport,
    /// The request's cache key.
    pub fingerprint: Fingerprint,
    /// True iff *this request* did not run the solver: served from the
    /// plan cache, coalesced onto a concurrent solve (single-flight), or
    /// fanned out from a batch leader's solve.
    pub cached: bool,
    /// True iff *this request* did not run the simulation engine: served
    /// from the sim-report cache, coalesced onto a concurrent
    /// simulation, or fanned out from a batch leader's simulation.
    pub sim_cached: bool,
}

/// Reply sent back on the channel for queued ([`PlanService::submit_with`])
/// requests: the workload name plus the report or error.
pub type AsyncReply = (String, Result<DeployReport>);

struct Job {
    workload: String,
    graph: Graph,
    config: DeployConfig,
    reply: Option<Sender<AsyncReply>>,
}

/// Shared state between the facade and the worker threads.
struct ServiceInner {
    cache: PlanCache,
    sim_cache: SimCache,
    flight: SingleFlight<Arc<Deployment>>,
    sim_flight: SingleFlight<Arc<SimReport>>,
    solves: Counter,
    sims: Counter,
    requests: Counter,
    errors: Counter,
    /// Wall time of actual branch-and-bound solves (cache hits and
    /// coalesced waiters record nothing), in µs.
    solve_us: Histogram,
    /// Wall time of actual `sim::engine` runs, in µs.
    sim_us: Histogram,
    workers: usize,
    /// Verify plans before cache insertion/import (see
    /// [`ServeOptions::verify_plans`]).
    verify_plans: bool,
    verify: VerifyCounters,
    /// Counters of the attached persistence layer, if any (see
    /// [`crate::serve::persist::Snapshotter::attach`]); surfaced in
    /// `stats_json` under `"persist"`.
    persist: Mutex<Option<Arc<PersistCounters>>>,
}

impl ServiceInner {
    /// The cache + single-flight path around the solver.
    fn plan(&self, graph: &Graph, config: &DeployConfig) -> Result<PlanOutcome> {
        self.requests.inc();
        let key = fingerprint(graph, config);
        if let Some(plan) = self.cache.get(key) {
            return Ok(PlanOutcome { plan, fingerprint: key, cached: true });
        }
        // `cached` must reflect whether *this request's* plan came out of
        // the solver, not the flight role: a leader whose double-check
        // below hits the cache did not solve either.
        let solved_here = std::cell::Cell::new(false);
        let (result, _role) = self.flight.run(key.0, || {
            // Double-check inside the flight: this caller may have raced a
            // leader that finished (and populated the cache) between our
            // miss and the flight acquisition. Quiet lookup — the miss was
            // already counted above.
            if let Some(plan) = self.cache.get_quiet(key) {
                return Ok(plan);
            }
            solved_here.set(true);
            self.solves.inc();
            let solve_start = Instant::now();
            let deployment = Deployer::new(graph.clone(), config.clone()).plan()?;
            self.solve_us.record_duration(solve_start.elapsed());
            let plan = Arc::new(deployment);
            // Gate the trust boundary: a plan enters the shared cache only
            // if it verifies. The check runs once per solve, never on the
            // warm path (cache hits returned above).
            if self.verify_plans {
                self.verify.checked.inc();
                let report = crate::verify::check_deployment(&plan, Some(&config.soc));
                if !report.ok() {
                    self.verify.findings.add(report.errors() as u64);
                    self.verify.rejected.inc();
                    return Err(anyhow!("plan verification failed: {}", report.summary()));
                }
            }
            // Publish before the flight closes so no request can observe
            // "no flight and no cache entry" for an already-solved key.
            self.cache.insert(key, plan.clone());
            Ok(plan)
        });
        let plan = match result {
            Ok(plan) => plan,
            Err(e) => {
                self.errors.inc();
                return Err(e);
            }
        };
        Ok(PlanOutcome { plan, fingerprint: key, cached: !solved_here.get() })
    }

    /// The sim-cache + single-flight path around `sim::engine`. Keyed by
    /// the plan fingerprint (which already covers the workload shape, the
    /// SoC and every planning knob) rehashed under [`SIM_KEY_TAG`].
    fn simulate(
        &self,
        key: Fingerprint,
        plan: &Arc<Deployment>,
        config: &DeployConfig,
        sink: Option<&dyn EventSink>,
    ) -> Result<(Arc<SimReport>, bool)> {
        let sim_key = key.derive(SIM_KEY_TAG);
        if let Some(sim) = self.sim_cache.get(sim_key) {
            return Ok((sim, true));
        }
        // Same `cached` semantics as `plan`: true unless *this request*
        // ran the simulation engine.
        let simulated_here = std::cell::Cell::new(false);
        let (result, _role) = self.sim_flight.run(sim_key.0, || {
            // Quiet double-check — the miss was already counted above.
            if let Some(sim) = self.sim_cache.get_quiet(sim_key) {
                return Ok(sim);
            }
            simulated_here.set(true);
            self.sims.inc();
            let sim_start = Instant::now();
            // Only the request that actually runs the engine streams
            // per-phase events; coalesced waiters get a terminal frame.
            let sim = Arc::new(match sink {
                Some(s) => plan.simulate_streamed(config, |index, total, rep| {
                    s.emit(&Event::SimPhase { index, total, name: rep.name.clone(), cycles: rep.cycles });
                })?,
                None => plan.simulate(config)?,
            });
            self.sim_us.record_duration(sim_start.elapsed());
            self.sim_cache.insert(sim_key, sim.clone());
            Ok(sim)
        });
        match result {
            Ok(sim) => Ok((sim, !simulated_here.get())),
            Err(e) => Err(e),
        }
    }

    /// Plan (cached) + simulate (cached) + assemble the standard report.
    fn deploy(&self, workload: &str, graph: &Graph, config: &DeployConfig) -> Result<ServeReply> {
        self.deploy_spanned(workload, graph, config, None)
    }

    /// [`ServiceInner::deploy`] with an optional request-trace span: the
    /// solve and simulate stage boundaries are marked on it as they
    /// complete (warm hits mark immediately — the stage still happened,
    /// it just cost a cache lookup).
    fn deploy_spanned(
        &self,
        workload: &str,
        graph: &Graph,
        config: &DeployConfig,
        span: Option<&ActiveSpan>,
    ) -> Result<ServeReply> {
        self.deploy_observed(workload, graph, config, span, None)
    }

    /// [`ServiceInner::deploy_spanned`] plus an optional [`EventSink`]:
    /// when present, a `plan` event (plan digest + fingerprint) is
    /// emitted as soon as the solve lands and per-phase `sim` events
    /// stream while the engine runs — the partial replies behind the v1
    /// wire protocol. The terminal frame stays the caller's job.
    fn deploy_observed(
        &self,
        workload: &str,
        graph: &Graph,
        config: &DeployConfig,
        span: Option<&ActiveSpan>,
        sink: Option<&dyn EventSink>,
    ) -> Result<ServeReply> {
        let outcome = self.plan(graph, config)?;
        if let Some(s) = span {
            s.mark_solved();
        }
        if let Some(sink) = sink {
            let digest = checksum(outcome.plan.to_json().to_string().as_bytes()).hex();
            sink.emit(&Event::Plan {
                digest,
                fingerprint: outcome.fingerprint.hex(),
                cached: outcome.cached,
            });
            if let Some(s) = span {
                s.mark_streamed();
            }
        }
        let (sim, sim_cached) = match self.simulate(outcome.fingerprint, &outcome.plan, config, sink) {
            Ok(sim) => sim,
            Err(e) => {
                self.errors.inc();
                return Err(e).with_context(|| format!("simulating cached plan for '{workload}'"));
            }
        };
        if let Some(s) = span {
            s.mark_simmed();
        }
        let report = outcome.plan.report_with_sim(workload, config, (*sim).clone());
        Ok(ServeReply {
            plan: outcome.plan,
            report,
            fingerprint: outcome.fingerprint,
            cached: outcome.cached,
            sim_cached,
        })
    }
}

/// The deployment service (see module docs).
pub struct PlanService {
    inner: Arc<ServiceInner>,
    queue: Mutex<Option<Sender<Job>>>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl PlanService {
    /// Start a service with the given tunables (spawns the worker pool).
    pub fn new(opts: ServeOptions) -> Self {
        let inner = Arc::new(ServiceInner {
            cache: PlanCache::new(opts.cache_capacity, opts.cache_shards),
            sim_cache: SimCache::new(opts.sim_cache_capacity, opts.cache_shards),
            flight: SingleFlight::new(),
            sim_flight: SingleFlight::new(),
            solves: Counter::new(0),
            sims: Counter::new(0),
            requests: Counter::new(0),
            errors: Counter::new(0),
            solve_us: Histogram::new(),
            sim_us: Histogram::new(),
            workers: opts.workers,
            verify_plans: opts.verify_plans,
            verify: VerifyCounters::default(),
            persist: Mutex::new(None),
        });
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::new();
        for i in 0..opts.workers.max(1) {
            let rx: Arc<Mutex<Receiver<Job>>> = rx.clone();
            let inner = inner.clone();
            let handle = std::thread::Builder::new()
                .name(format!("ftl-serve-{i}"))
                .spawn(move || loop {
                    // Holding the lock while blocked in recv() is the
                    // standard std-mpsc work-queue pattern: exactly one
                    // idle worker waits in recv, the rest wait on the
                    // mutex, and the lock drops before the job runs.
                    let job = rx.lock().expect("serve queue poisoned").recv();
                    let Ok(job) = job else { break };
                    // Panic isolation: a panicking solve must not kill the
                    // worker (with a small pool, one bad job would
                    // otherwise silently stop the queue forever while
                    // submit() keeps succeeding).
                    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        inner.deploy(&job.workload, &job.graph, &job.config).map(|r| r.report)
                    }))
                    .unwrap_or_else(|_| {
                        Err(anyhow!("serve worker panicked while deploying '{}'", job.workload))
                    });
                    if let Some(reply) = job.reply {
                        reply.send((job.workload, result)).ok();
                    }
                })
                .expect("spawn serve worker");
            handles.push(handle);
        }
        Self { inner, queue: Mutex::new(Some(tx)), workers: Mutex::new(handles) }
    }

    /// Service with default tunables.
    pub fn with_defaults() -> Self {
        Self::new(ServeOptions::default())
    }

    /// Plan-only path (no simulation): fingerprint → cache → single-flight
    /// → solve. Warm keys return the shared `Arc<Deployment>` without
    /// touching the solver.
    pub fn plan(&self, graph: &Graph, config: &DeployConfig) -> Result<PlanOutcome> {
        self.inner.plan(graph, config)
    }

    /// Synchronous request-response deployment: cached plan + cached (or
    /// freshly run) simulation report.
    pub fn deploy(&self, workload: &str, graph: &Graph, config: &DeployConfig) -> Result<ServeReply> {
        self.inner.deploy(workload, graph, config)
    }

    /// [`PlanService::deploy`] with an optional request-trace span (see
    /// [`crate::serve::trace`]): `mark_solved` / `mark_simmed` fire on it
    /// as the stages complete, so the batch scheduler's per-request spans
    /// carry real stage boundaries instead of estimates.
    pub fn deploy_spanned(
        &self,
        workload: &str,
        graph: &Graph,
        config: &DeployConfig,
        span: Option<&ActiveSpan>,
    ) -> Result<ServeReply> {
        self.inner.deploy_spanned(workload, graph, config, span)
    }

    /// [`PlanService::deploy_spanned`] with streaming partial replies:
    /// when `sink` is present, a `plan` event fires as soon as the solve
    /// lands and per-phase `sim` events stream while the engine runs
    /// (cache hits skip straight to the caller's terminal frame).
    pub fn deploy_observed(
        &self,
        workload: &str,
        graph: &Graph,
        config: &DeployConfig,
        span: Option<&ActiveSpan>,
        sink: Option<&dyn EventSink>,
    ) -> Result<ServeReply> {
        self.inner.deploy_observed(workload, graph, config, span, sink)
    }

    /// Serve the request only if both caches are warm: `None` (with no
    /// counter side effects) when either the plan or the sim report is
    /// absent. The batch scheduler uses this as a fast path so fully warm
    /// traffic skips the priority lanes and the batch window entirely —
    /// the fast path is deliberately lane-agnostic, since WFQ fairness is
    /// defined over *cold* work and a cache hit consumes none. Probes are
    /// `contains`-only; the `Some` arm re-runs the normal counted path,
    /// which in the rare eviction race may still solve synchronously.
    pub fn deploy_if_warm(
        &self,
        workload: &str,
        graph: &Graph,
        config: &DeployConfig,
    ) -> Option<Result<ServeReply>> {
        let key = fingerprint(graph, config);
        if !self.inner.cache.contains(key) || !self.inner.sim_cache.contains(key.derive(SIM_KEY_TAG)) {
            return None;
        }
        Some(self.inner.deploy(workload, graph, config))
    }

    /// Fire-and-forget: queue the request for the worker pool (used to
    /// pre-warm the cache). Errors only if the service is shut down.
    pub fn submit(&self, workload: impl Into<String>, graph: Graph, config: DeployConfig) -> Result<()> {
        self.enqueue(Job { workload: workload.into(), graph, config, reply: None })
    }

    /// Queue a request; the worker pool sends `(workload, report)` back on
    /// `reply` when done.
    pub fn submit_with(
        &self,
        workload: impl Into<String>,
        graph: Graph,
        config: DeployConfig,
        reply: Sender<AsyncReply>,
    ) -> Result<()> {
        self.enqueue(Job { workload: workload.into(), graph, config, reply: Some(reply) })
    }

    fn enqueue(&self, job: Job) -> Result<()> {
        let queue = self.queue.lock().expect("serve queue poisoned");
        match queue.as_ref() {
            Some(tx) => tx.send(job).map_err(|_| anyhow!("serve worker pool is shut down")),
            None => Err(anyhow!("serve worker pool is shut down")),
        }
    }

    /// Counter snapshot.
    pub fn stats(&self) -> ServeStats {
        ServeStats {
            cache: self.inner.cache.stats(),
            sim_cache: self.inner.sim_cache.stats(),
            solves: self.inner.solves.get(),
            sims: self.inner.sims.get(),
            requests: self.inner.requests.get(),
            errors: self.inner.errors.get(),
            singleflight_leads: self.inner.flight.leads(),
            singleflight_waits: self.inner.flight.waits(),
            workers: self.inner.workers,
        }
    }

    /// Machine-readable stats snapshot (the protocol's `STATS` response).
    /// Always includes the `"verify"` block (`checked` / `rejected` /
    /// `findings` — all zero unless `--verify-plans` is on), and includes
    /// `"persist"` counters when a
    /// [`crate::serve::persist::Snapshotter`] is attached, and the global
    /// solver pool's `"solver"` search counters (thread cap, points
    /// scored vs capacity-/bound-pruned — see
    /// [`crate::tiling::SolverPool`]).
    pub fn stats_json(&self) -> Json {
        let mut j = self.stats().to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("solver".into(), crate::tiling::SolverPool::global().stats_json());
            m.insert("verify".into(), self.inner.verify.to_json());
            m.insert(
                "plan_latency".into(),
                Json::obj(vec![
                    ("solve_us", self.inner.solve_us.to_json()),
                    ("sim_us", self.inner.sim_us.to_json()),
                ]),
            );
            if let Some(counters) = self.inner.persist.lock().expect("persist counters poisoned").as_ref() {
                m.insert("persist".into(), counters.to_json());
            }
        }
        j
    }

    // ------------------------------------------------ persistence hooks
    // (consumed by `crate::serve::persist` — see its module docs)

    /// Export every cached plan (no counter side effects).
    pub fn export_plans(&self) -> Vec<(Fingerprint, Arc<Deployment>)> {
        self.inner.cache.export()
    }

    /// Export every cached simulation report, keyed by the *derived* sim
    /// fingerprint (no counter side effects).
    pub fn export_sims(&self) -> Vec<(Fingerprint, Arc<SimReport>)> {
        self.inner.sim_cache.export()
    }

    /// [`Self::export_plans`] including each entry's lane-weight hint
    /// (the WFQ weight of the heaviest lane that hit it — see
    /// [`Self::note_lane_hit`]).
    pub fn export_plans_hinted(&self) -> Vec<(Fingerprint, Arc<Deployment>, u64)> {
        self.inner.cache.export_hinted()
    }

    /// [`Self::export_sims`] including lane-weight hints.
    pub fn export_sims_hinted(&self) -> Vec<(Fingerprint, Arc<SimReport>, u64)> {
        self.inner.sim_cache.export_hinted()
    }

    /// Tag the cached plan (and its derived sim entry) with the WFQ
    /// weight of the lane that just hit it. Hints only ratchet upward;
    /// they ride along in the snapshot segment index so a restarted
    /// replica loads the heaviest lanes' entries first. Called by the
    /// batch scheduler on every served request — misses (entry already
    /// evicted) are silently ignored, so this is cheap enough for the
    /// warm path.
    pub fn note_lane_hit(&self, key: Fingerprint, lane_weight: u64) {
        self.inner.cache.raise_hint(key, lane_weight);
        self.inner.sim_cache.raise_hint(key.derive(SIM_KEY_TAG), lane_weight);
    }

    /// Seed the plan cache with a snapshot entry (warm start). Under
    /// `--verify-plans` the entry is verified first — a snapshot is an
    /// even less trusted source than the in-process solver — and a plan
    /// with error-severity findings is rejected (counted as
    /// `verify.rejected`) instead of cached; returns whether the entry
    /// was admitted. The SoC-free check runs here (a snapshot key binds
    /// no SoC) — capacity/cost checks are deferred, overlap, hazard,
    /// coverage and structural checks still apply.
    pub fn import_plan(&self, key: Fingerprint, plan: Arc<Deployment>) -> bool {
        self.import_plan_hinted(key, plan, 0)
    }

    /// [`Self::import_plan`] carrying the lane-weight hint recovered from
    /// the segment index, so the restored entry keeps its warm-up
    /// priority for the *next* restart too.
    pub fn import_plan_hinted(&self, key: Fingerprint, plan: Arc<Deployment>, hint: u64) -> bool {
        if self.inner.verify_plans {
            self.inner.verify.checked.inc();
            let report = crate::verify::check_deployment(&plan, None);
            if !report.ok() {
                self.inner.verify.findings.add(report.errors() as u64);
                self.inner.verify.rejected.inc();
                eprintln!("[ftl-serve] rejecting snapshot plan {}: {}", key.hex(), report.summary());
                return false;
            }
        }
        self.inner.cache.insert_hinted(key, plan, hint);
        true
    }

    /// Seed the sim cache with a snapshot entry; `key` must be the
    /// derived sim fingerprint exactly as exported.
    pub fn import_sim(&self, key: Fingerprint, sim: Arc<SimReport>) {
        self.import_sim_hinted(key, sim, 0);
    }

    /// [`Self::import_sim`] carrying the lane-weight hint from the
    /// segment index.
    pub fn import_sim_hinted(&self, key: Fingerprint, sim: Arc<SimReport>, hint: u64) {
        self.inner.sim_cache.insert_hinted(key, sim, hint);
    }

    /// Register the persistence layer's counters for `stats_json`.
    pub fn set_persist_counters(&self, counters: Arc<PersistCounters>) {
        *self.inner.persist.lock().expect("persist counters poisoned") = Some(counters);
    }

    /// Drain the queue and stop the worker pool (also runs on drop).
    pub fn shutdown(&self) {
        if let Some(tx) = self.queue.lock().expect("serve queue poisoned").take() {
            drop(tx);
        }
        let handles: Vec<JoinHandle<()>> =
            std::mem::take(&mut *self.workers.lock().expect("serve workers poisoned"));
        for h in handles {
            h.join().ok();
        }
    }
}

impl Drop for PlanService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Aggregated service counters.
#[derive(Debug, Clone, Copy)]
pub struct ServeStats {
    /// Plan-cache counters.
    pub cache: crate::metrics::CacheStats,
    /// Sim-report-cache counters.
    pub sim_cache: crate::metrics::CacheStats,
    /// Actual branch-&-bound solves performed.
    pub solves: u64,
    /// Actual `sim::engine` runs performed.
    pub sims: u64,
    /// Plan requests received (sync + queued).
    pub requests: u64,
    /// Requests that returned an error.
    pub errors: u64,
    /// Single-flight leaders (computations run).
    pub singleflight_leads: u64,
    /// Single-flight followers (requests coalesced onto another solve).
    pub singleflight_waits: u64,
    /// Worker-pool size.
    pub workers: usize,
}

impl ServeStats {
    /// JSON rendering. Counters render via `Json::Num`, not `Json::int`:
    /// a saturated counter (`u64::MAX`) must serialise, not panic on the
    /// i64 conversion.
    pub fn to_json(&self) -> Json {
        let n = |v: u64| Json::Num(v as f64);
        Json::obj(vec![
            ("plan_cache", self.cache.to_json()),
            ("sim_cache", self.sim_cache.to_json()),
            ("solves", n(self.solves)),
            ("sims", n(self.sims)),
            ("requests", n(self.requests)),
            ("errors", n(self.errors)),
            ("singleflight_leads", n(self.singleflight_leads)),
            ("singleflight_waits", n(self.singleflight_waits)),
            ("workers", Json::int(self.workers)),
        ])
    }
}

/// Resolve a served workload name to a graph — the vocabulary of the line
/// protocol spoken by `ftl serve` and `examples/deploy_server.rs`.
/// Besides the named presets, `stage-<seq>x<dim>x<hidden>` (each
/// dimension in 1..=4096) builds a parameterized MLP stage, giving wire
/// clients an unbounded supply of distinct cold fingerprints — the
/// connection-scaling bench leans on this.
pub fn resolve_workload(name: &str) -> Result<Graph> {
    match name {
        "vit-base-stage" => Ok(experiments::vit_mlp_stage(197, 768, 3072)),
        "vit-tiny-stage" => Ok(experiments::vit_mlp_stage(197, 192, 768)),
        other => {
            if let Some(dims) = parse_stage_dims(other) {
                let (seq, dim, hidden) = dims;
                return Ok(experiments::vit_mlp_stage(seq, dim, hidden));
            }
            vit_mlp_preset(other).ok_or_else(|| {
                anyhow!(
                    "unknown workload '{other}' (try vit-base-stage, vit-tiny-stage, \
                     stage-<seq>x<dim>x<hidden>, vit-tiny, vit-small, vit-base, vit-large)"
                )
            })
        }
    }
}

fn parse_stage_dims(name: &str) -> Option<(usize, usize, usize)> {
    let dims = name.strip_prefix("stage-")?;
    let mut out = [0usize; 3];
    let mut it = dims.split('x');
    for slot in &mut out {
        let v: usize = it.next()?.parse().ok()?;
        if !(1..=4096).contains(&v) {
            return None;
        }
        *slot = v;
    }
    if it.next().is_some() {
        return None;
    }
    Some((out[0], out[1], out[2]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tiling::Strategy;

    fn small() -> (Graph, DeployConfig) {
        (experiments::vit_mlp_stage(16, 24, 48), DeployConfig::preset("cluster-only", Strategy::Ftl).unwrap())
    }

    fn opts(cache_capacity: usize, cache_shards: usize, workers: usize) -> ServeOptions {
        ServeOptions { cache_capacity, cache_shards, workers, ..ServeOptions::default() }
    }

    #[test]
    fn warm_hit_skips_solver_and_shares_plan() {
        let svc = PlanService::new(opts(8, 2, 1));
        let (g, c) = small();
        let first = svc.plan(&g, &c).unwrap();
        assert!(!first.cached);
        let second = svc.plan(&g, &c).unwrap();
        assert!(second.cached);
        assert!(Arc::ptr_eq(&first.plan, &second.plan), "cache must share, not copy");
        let stats = svc.stats();
        assert_eq!(stats.solves, 1);
        assert_eq!(stats.cache.hits, 1);
        assert_eq!(stats.requests, 2);
    }

    #[test]
    fn deploy_reports_match_uncached_pipeline() {
        let svc = PlanService::with_defaults();
        let (g, c) = small();
        let reply = svc.deploy("unit", &g, &c).unwrap();
        let (_, direct) = Deployer::new(g.clone(), c.clone()).with_workload_name("unit").deploy().unwrap();
        assert_eq!(reply.report.sim.total_cycles, direct.sim.total_cycles);
        assert_eq!(reply.report.phases, direct.phases);
        assert_eq!(reply.report.workload, "unit");
    }

    #[test]
    fn warm_deploy_skips_simulation_engine() {
        let svc = PlanService::with_defaults();
        let (g, c) = small();
        let cold = svc.deploy("a", &g, &c).unwrap();
        assert!(!cold.cached && !cold.sim_cached);
        let warm = svc.deploy("b", &g, &c).unwrap();
        assert!(warm.cached && warm.sim_cached, "second deploy must hit both caches");
        assert_eq!(warm.report.workload, "b", "cached sims must still carry per-request names");
        assert_eq!(warm.report.sim.total_cycles, cold.report.sim.total_cycles);
        let stats = svc.stats();
        assert_eq!(stats.sims, 1, "one engine run for two deploys");
        assert_eq!(stats.sim_cache.hits, 1);
    }

    #[test]
    fn deploy_if_warm_only_serves_fully_cached_keys() {
        let svc = PlanService::with_defaults();
        let (g, c) = small();
        assert!(svc.deploy_if_warm("w", &g, &c).is_none(), "cold key has no warm path");
        assert_eq!(svc.stats().requests, 0, "a declined warm probe must leave counters untouched");
        svc.deploy("seed", &g, &c).unwrap();
        let reply = svc.deploy_if_warm("warm", &g, &c).unwrap().unwrap();
        assert!(reply.cached && reply.sim_cached);
        assert_eq!(reply.report.workload, "warm");
        assert_eq!(svc.stats().solves, 1);
        assert_eq!(svc.stats().sims, 1);
    }

    #[test]
    fn verify_gate_checks_once_per_solve() {
        let svc = PlanService::new(ServeOptions { verify_plans: true, workers: 1, ..ServeOptions::default() });
        let (g, c) = small();
        assert!(!svc.plan(&g, &c).unwrap().cached);
        assert!(svc.plan(&g, &c).unwrap().cached);
        let j = svc.stats_json();
        let v = j.get("verify").unwrap();
        assert_eq!(v.get("checked").unwrap().as_usize().unwrap(), 1, "warm hits must never re-verify");
        assert_eq!(v.get("rejected").unwrap().as_usize().unwrap(), 0);
        assert_eq!(v.get("findings").unwrap().as_usize().unwrap(), 0);
    }

    #[test]
    fn verify_gate_rejects_corrupt_imports() {
        let svc = PlanService::new(ServeOptions { verify_plans: true, workers: 1, ..ServeOptions::default() });
        let (g, c) = small();
        let out = svc.plan(&g, &c).unwrap();
        // Corrupt a clone of the valid plan: collide two sized arena
        // offsets so the verifier's overlap rule must fire.
        let mut bad = (*out.plan).clone();
        let phase = &mut bad.schedule.phases[0];
        let sized: Vec<usize> = (0..phase.arena.buffers.len())
            .filter(|&i| phase.arena.buffers[i].bytes > 0 && !phase.arena.offsets[i].is_empty())
            .collect();
        let (i, j) = (sized[0], sized[1]);
        phase.arena.offsets[j][0] = phase.arena.offsets[i][0];
        let key = out.fingerprint.derive("unit-import");
        assert!(!svc.import_plan(key, Arc::new(bad)), "overlapping plan must be refused");
        assert!(svc.import_plan(key, out.plan.clone()), "valid plan must be admitted");
        let v = svc.stats_json().get("verify").unwrap().clone();
        assert_eq!(v.get("rejected").unwrap().as_usize().unwrap(), 1);
        assert!(v.get("findings").unwrap().as_usize().unwrap() >= 1);
    }

    #[test]
    fn queued_requests_reply_on_channel() {
        let svc = PlanService::new(opts(8, 2, 2));
        let (g, c) = small();
        let (tx, rx) = mpsc::channel();
        svc.submit_with("queued", g.clone(), c.clone(), tx.clone()).unwrap();
        svc.submit_with("queued", g, c, tx).unwrap();
        let mut ok = 0;
        for _ in 0..2 {
            let (name, res) = rx.recv().unwrap();
            assert_eq!(name, "queued");
            res.unwrap();
            ok += 1;
        }
        assert_eq!(ok, 2);
        assert_eq!(svc.stats().solves, 1, "identical queued requests share one solve");
    }

    #[test]
    fn shutdown_rejects_new_work() {
        let svc = PlanService::new(opts(2, 1, 1));
        svc.shutdown();
        let (g, c) = small();
        assert!(svc.submit("late", g, c).is_err());
    }

    #[test]
    fn resolve_workload_names() {
        assert!(resolve_workload("vit-base-stage").is_ok());
        assert!(resolve_workload("vit-tiny-stage").is_ok());
        assert!(resolve_workload("no-such-net").is_err());
    }

    #[test]
    fn resolve_workload_parameterized_stages() {
        assert!(resolve_workload("stage-16x24x48").is_ok());
        assert!(resolve_workload("stage-4096x1x1").is_ok());
        for bad in ["stage-", "stage-16x24", "stage-16x24x48x2", "stage-0x24x48", "stage-5000x24x48", "stage-axbxc"] {
            assert!(resolve_workload(bad).is_err(), "'{bad}' must not resolve");
        }
    }

    #[test]
    fn deploy_observed_streams_plan_then_phases() {
        use std::sync::Mutex as StdMutex;
        struct Rec(StdMutex<Vec<String>>);
        impl EventSink for Rec {
            fn emit(&self, event: &Event) {
                let tag = match event {
                    Event::Plan { .. } => "plan".to_string(),
                    Event::SimPhase { index, .. } => format!("sim{index}"),
                    Event::Done(_) => "done".to_string(),
                    Event::Error { .. } => "error".to_string(),
                };
                self.0.lock().unwrap().push(tag);
            }
        }
        let svc = PlanService::new(opts(8, 2, 1));
        let (g, c) = small();
        let sink = Rec(StdMutex::new(Vec::new()));
        let cold = svc.deploy_observed("cold", &g, &c, None, Some(&sink)).unwrap();
        assert!(!cold.cached && !cold.sim_cached);
        let events = sink.0.lock().unwrap().clone();
        assert!(events.len() >= 2, "cold deploy must stream plan + phases, got {events:?}");
        assert_eq!(events[0], "plan", "plan event must come first: {events:?}");
        assert!(events[1..].iter().enumerate().all(|(i, t)| t == &format!("sim{i}")), "{events:?}");

        let warm_sink = Rec(StdMutex::new(Vec::new()));
        let warm = svc.deploy_observed("warm", &g, &c, None, Some(&warm_sink)).unwrap();
        assert!(warm.cached && warm.sim_cached);
        let events = warm_sink.0.lock().unwrap().clone();
        assert_eq!(events, vec!["plan"], "warm deploys must not stream sim phases");
    }
}
