//! [`PlanService`] — the deployment service facade.
//!
//! Ties the serve layer together: fingerprint the request, consult the
//! sharded [`PlanCache`], coalesce concurrent misses through
//! [`SingleFlight`], and only then run the coordinator's planning
//! pipeline. Exposes a synchronous API (`plan` / `deploy`) for
//! request-response callers and a fire-and-forget queue (`submit` /
//! `submit_with`) drained by a worker-thread pool for cache warming and
//! async callers. All counters surface in a JSON stats snapshot.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::DeployConfig;
use crate::coordinator::{experiments, DeployReport, Deployer, Deployment};
use crate::ir::builder::vit_mlp_preset;
use crate::ir::Graph;
use crate::util::json::Json;

use super::cache::PlanCache;
use super::fingerprint::{fingerprint, Fingerprint};
use super::singleflight::SingleFlight;

/// Tunables for a [`PlanService`].
#[derive(Debug, Clone, Copy)]
pub struct ServeOptions {
    /// Max cached plans (total across shards).
    pub cache_capacity: usize,
    /// Number of cache lock shards.
    pub cache_shards: usize,
    /// Worker threads draining the fire-and-forget queue.
    pub workers: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self { cache_capacity: 128, cache_shards: 8, workers: 4 }
    }
}

/// Outcome of the plan-cache path.
#[derive(Clone)]
pub struct PlanOutcome {
    /// The (shared) compiled plan.
    pub plan: Arc<Deployment>,
    /// The request's cache key.
    pub fingerprint: Fingerprint,
    /// True if the plan came from the cache without consulting the solver
    /// (including coalescing onto a concurrent solve).
    pub cached: bool,
}

/// Full response for one deployment request.
pub struct ServeReply {
    /// The (shared) compiled plan.
    pub plan: Arc<Deployment>,
    /// Plan + simulation report (rebuilt per request — simulation is cheap
    /// next to the solve and carries the per-request workload name).
    pub report: DeployReport,
    /// The request's cache key.
    pub fingerprint: Fingerprint,
    /// Whether the plan was served from the cache.
    pub cached: bool,
}

/// Reply sent back on the channel for queued ([`PlanService::submit_with`])
/// requests: the workload name plus the report or error.
pub type AsyncReply = (String, Result<DeployReport>);

struct Job {
    workload: String,
    graph: Graph,
    config: DeployConfig,
    reply: Option<Sender<AsyncReply>>,
}

/// Shared state between the facade and the worker threads.
struct ServiceInner {
    cache: PlanCache,
    flight: SingleFlight<Arc<Deployment>>,
    solves: AtomicU64,
    requests: AtomicU64,
    errors: AtomicU64,
    workers: usize,
}

impl ServiceInner {
    /// The cache + single-flight path around the solver.
    fn plan(&self, graph: &Graph, config: &DeployConfig) -> Result<PlanOutcome> {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let key = fingerprint(graph, config);
        if let Some(plan) = self.cache.get(key) {
            return Ok(PlanOutcome { plan, fingerprint: key, cached: true });
        }
        // `cached` must reflect whether *this request's* plan came out of
        // the solver, not the flight role: a leader whose double-check
        // below hits the cache did not solve either.
        let solved_here = std::cell::Cell::new(false);
        let (result, _role) = self.flight.run(key.0, || {
            // Double-check inside the flight: this caller may have raced a
            // leader that finished (and populated the cache) between our
            // miss and the flight acquisition.
            if let Some(plan) = self.cache.get(key) {
                return Ok(plan);
            }
            solved_here.set(true);
            self.solves.fetch_add(1, Ordering::Relaxed);
            let deployment = Deployer::new(graph.clone(), config.clone()).plan()?;
            let plan = Arc::new(deployment);
            // Publish before the flight closes so no request can observe
            // "no flight and no cache entry" for an already-solved key.
            self.cache.insert(key, plan.clone());
            Ok(plan)
        });
        let plan = match result {
            Ok(plan) => plan,
            Err(e) => {
                self.errors.fetch_add(1, Ordering::Relaxed);
                return Err(e);
            }
        };
        Ok(PlanOutcome { plan, fingerprint: key, cached: !solved_here.get() })
    }

    /// Plan (cached) + simulate + assemble the standard report.
    fn deploy(&self, workload: &str, graph: &Graph, config: &DeployConfig) -> Result<ServeReply> {
        let outcome = self.plan(graph, config)?;
        let report = match outcome.plan.report(workload, config) {
            Ok(report) => report,
            Err(e) => {
                self.errors.fetch_add(1, Ordering::Relaxed);
                return Err(e).with_context(|| format!("simulating cached plan for '{workload}'"));
            }
        };
        Ok(ServeReply { plan: outcome.plan, report, fingerprint: outcome.fingerprint, cached: outcome.cached })
    }
}

/// The deployment service (see module docs).
pub struct PlanService {
    inner: Arc<ServiceInner>,
    queue: Mutex<Option<Sender<Job>>>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl PlanService {
    /// Start a service with the given tunables (spawns the worker pool).
    pub fn new(opts: ServeOptions) -> Self {
        let inner = Arc::new(ServiceInner {
            cache: PlanCache::new(opts.cache_capacity, opts.cache_shards),
            flight: SingleFlight::new(),
            solves: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            workers: opts.workers,
        });
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::new();
        for i in 0..opts.workers.max(1) {
            let rx: Arc<Mutex<Receiver<Job>>> = rx.clone();
            let inner = inner.clone();
            let handle = std::thread::Builder::new()
                .name(format!("ftl-serve-{i}"))
                .spawn(move || loop {
                    // Holding the lock while blocked in recv() is the
                    // standard std-mpsc work-queue pattern: exactly one
                    // idle worker waits in recv, the rest wait on the
                    // mutex, and the lock drops before the job runs.
                    let job = rx.lock().expect("serve queue poisoned").recv();
                    let Ok(job) = job else { break };
                    // Panic isolation: a panicking solve must not kill the
                    // worker (with a small pool, one bad job would
                    // otherwise silently stop the queue forever while
                    // submit() keeps succeeding).
                    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        inner.deploy(&job.workload, &job.graph, &job.config).map(|r| r.report)
                    }))
                    .unwrap_or_else(|_| {
                        Err(anyhow!("serve worker panicked while deploying '{}'", job.workload))
                    });
                    if let Some(reply) = job.reply {
                        reply.send((job.workload, result)).ok();
                    }
                })
                .expect("spawn serve worker");
            handles.push(handle);
        }
        Self { inner, queue: Mutex::new(Some(tx)), workers: Mutex::new(handles) }
    }

    /// Service with default tunables.
    pub fn with_defaults() -> Self {
        Self::new(ServeOptions::default())
    }

    /// Plan-only path (no simulation): fingerprint → cache → single-flight
    /// → solve. Warm keys return the shared `Arc<Deployment>` without
    /// touching the solver.
    pub fn plan(&self, graph: &Graph, config: &DeployConfig) -> Result<PlanOutcome> {
        self.inner.plan(graph, config)
    }

    /// Synchronous request-response deployment: cached plan + fresh
    /// simulation report.
    pub fn deploy(&self, workload: &str, graph: &Graph, config: &DeployConfig) -> Result<ServeReply> {
        self.inner.deploy(workload, graph, config)
    }

    /// Fire-and-forget: queue the request for the worker pool (used to
    /// pre-warm the cache). Errors only if the service is shut down.
    pub fn submit(&self, workload: impl Into<String>, graph: Graph, config: DeployConfig) -> Result<()> {
        self.enqueue(Job { workload: workload.into(), graph, config, reply: None })
    }

    /// Queue a request; the worker pool sends `(workload, report)` back on
    /// `reply` when done.
    pub fn submit_with(
        &self,
        workload: impl Into<String>,
        graph: Graph,
        config: DeployConfig,
        reply: Sender<AsyncReply>,
    ) -> Result<()> {
        self.enqueue(Job { workload: workload.into(), graph, config, reply: Some(reply) })
    }

    fn enqueue(&self, job: Job) -> Result<()> {
        let queue = self.queue.lock().expect("serve queue poisoned");
        match queue.as_ref() {
            Some(tx) => tx.send(job).map_err(|_| anyhow!("serve worker pool is shut down")),
            None => Err(anyhow!("serve worker pool is shut down")),
        }
    }

    /// Counter snapshot.
    pub fn stats(&self) -> ServeStats {
        ServeStats {
            cache: self.inner.cache.stats(),
            solves: self.inner.solves.load(Ordering::Relaxed),
            requests: self.inner.requests.load(Ordering::Relaxed),
            errors: self.inner.errors.load(Ordering::Relaxed),
            singleflight_leads: self.inner.flight.leads(),
            singleflight_waits: self.inner.flight.waits(),
            workers: self.inner.workers,
        }
    }

    /// Machine-readable stats snapshot (the protocol's `STATS` response).
    pub fn stats_json(&self) -> Json {
        self.stats().to_json()
    }

    /// Drain the queue and stop the worker pool (also runs on drop).
    pub fn shutdown(&self) {
        if let Some(tx) = self.queue.lock().expect("serve queue poisoned").take() {
            drop(tx);
        }
        let handles: Vec<JoinHandle<()>> =
            std::mem::take(&mut *self.workers.lock().expect("serve workers poisoned"));
        for h in handles {
            h.join().ok();
        }
    }
}

impl Drop for PlanService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Aggregated service counters.
#[derive(Debug, Clone, Copy)]
pub struct ServeStats {
    /// Plan-cache counters.
    pub cache: crate::metrics::CacheStats,
    /// Actual branch-&-bound solves performed.
    pub solves: u64,
    /// Plan requests received (sync + queued).
    pub requests: u64,
    /// Requests that returned an error.
    pub errors: u64,
    /// Single-flight leaders (computations run).
    pub singleflight_leads: u64,
    /// Single-flight followers (requests coalesced onto another solve).
    pub singleflight_waits: u64,
    /// Worker-pool size.
    pub workers: usize,
}

impl ServeStats {
    /// JSON rendering.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("plan_cache", self.cache.to_json()),
            ("solves", Json::int(self.solves as usize)),
            ("requests", Json::int(self.requests as usize)),
            ("errors", Json::int(self.errors as usize)),
            ("singleflight_leads", Json::int(self.singleflight_leads as usize)),
            ("singleflight_waits", Json::int(self.singleflight_waits as usize)),
            ("workers", Json::int(self.workers)),
        ])
    }
}

/// Resolve a served workload name to a graph — the vocabulary of the line
/// protocol spoken by `ftl serve` and `examples/deploy_server.rs`.
pub fn resolve_workload(name: &str) -> Result<Graph> {
    match name {
        "vit-base-stage" => Ok(experiments::vit_mlp_stage(197, 768, 3072)),
        "vit-tiny-stage" => Ok(experiments::vit_mlp_stage(197, 192, 768)),
        other => vit_mlp_preset(other).ok_or_else(|| {
            anyhow!("unknown workload '{other}' (try vit-base-stage, vit-tiny-stage, vit-tiny, vit-small, vit-base, vit-large)")
        }),
    }
}

/// Handle one line of the serve protocol — the single implementation
/// behind both `ftl serve` and `examples/deploy_server.rs`:
///
/// ```text
/// DEPLOY <workload> <soc> <strategy>   -> deploy report JSON
///                                         (+ "cached", "fingerprint")
/// STATS                                -> service counter snapshot
/// PING                                 -> {"pong": true}
/// ```
///
/// Errors never escape: they come back as one `{"error": ...}` object so
/// a bad request can't kill a connection handler.
pub fn handle_line(service: &PlanService, line: &str) -> Json {
    match handle_request(service, line) {
        Ok(j) => j,
        Err(e) => Json::obj(vec![("error", Json::str(format!("{e:#}")))]),
    }
}

fn handle_request(service: &PlanService, line: &str) -> Result<Json> {
    let parts: Vec<&str> = line.split_whitespace().collect();
    match parts.as_slice() {
        ["DEPLOY", workload, soc, strategy] => {
            let strategy = crate::tiling::Strategy::parse(strategy)
                .ok_or_else(|| anyhow!("bad strategy '{strategy}'"))?;
            let graph = resolve_workload(workload)?;
            let cfg = DeployConfig::preset(soc, strategy)?;
            let reply = service.deploy(workload, &graph, &cfg)?;
            let mut j = reply.report.to_json(&cfg.soc);
            if let Json::Obj(m) = &mut j {
                m.insert("cached".into(), Json::Bool(reply.cached));
                m.insert("fingerprint".into(), Json::str(reply.fingerprint.hex()));
            }
            Ok(j)
        }
        ["STATS"] => Ok(service.stats_json()),
        ["PING"] => Ok(Json::obj(vec![("pong", Json::Bool(true))])),
        _ => bail!("bad request: '{line}' (expected: DEPLOY <workload> <soc> <strategy> | STATS | PING)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tiling::Strategy;

    fn small() -> (Graph, DeployConfig) {
        (experiments::vit_mlp_stage(16, 24, 48), DeployConfig::preset("cluster-only", Strategy::Ftl).unwrap())
    }

    #[test]
    fn warm_hit_skips_solver_and_shares_plan() {
        let svc = PlanService::new(ServeOptions { cache_capacity: 8, cache_shards: 2, workers: 1 });
        let (g, c) = small();
        let first = svc.plan(&g, &c).unwrap();
        assert!(!first.cached);
        let second = svc.plan(&g, &c).unwrap();
        assert!(second.cached);
        assert!(Arc::ptr_eq(&first.plan, &second.plan), "cache must share, not copy");
        let stats = svc.stats();
        assert_eq!(stats.solves, 1);
        assert_eq!(stats.cache.hits, 1);
        assert_eq!(stats.requests, 2);
    }

    #[test]
    fn deploy_reports_match_uncached_pipeline() {
        let svc = PlanService::with_defaults();
        let (g, c) = small();
        let reply = svc.deploy("unit", &g, &c).unwrap();
        let (_, direct) = Deployer::new(g.clone(), c.clone()).with_workload_name("unit").deploy().unwrap();
        assert_eq!(reply.report.sim.total_cycles, direct.sim.total_cycles);
        assert_eq!(reply.report.phases, direct.phases);
        assert_eq!(reply.report.workload, "unit");
    }

    #[test]
    fn queued_requests_reply_on_channel() {
        let svc = PlanService::new(ServeOptions { cache_capacity: 8, cache_shards: 2, workers: 2 });
        let (g, c) = small();
        let (tx, rx) = mpsc::channel();
        svc.submit_with("queued", g.clone(), c.clone(), tx.clone()).unwrap();
        svc.submit_with("queued", g, c, tx).unwrap();
        let mut ok = 0;
        for _ in 0..2 {
            let (name, res) = rx.recv().unwrap();
            assert_eq!(name, "queued");
            res.unwrap();
            ok += 1;
        }
        assert_eq!(ok, 2);
        assert_eq!(svc.stats().solves, 1, "identical queued requests share one solve");
    }

    #[test]
    fn shutdown_rejects_new_work() {
        let svc = PlanService::new(ServeOptions { cache_capacity: 2, cache_shards: 1, workers: 1 });
        svc.shutdown();
        let (g, c) = small();
        assert!(svc.submit("late", g, c).is_err());
    }

    #[test]
    fn resolve_workload_names() {
        assert!(resolve_workload("vit-base-stage").is_ok());
        assert!(resolve_workload("vit-tiny-stage").is_ok());
        assert!(resolve_workload("no-such-net").is_err());
    }

    #[test]
    fn protocol_errors_become_json_not_panics() {
        let svc = PlanService::new(ServeOptions { cache_capacity: 2, cache_shards: 1, workers: 1 });
        for bad in ["", "DEPLOY", "DEPLOY x", "DEPLOY a b c d e", "NOPE x y z",
                    "DEPLOY no-such-net siracusa ftl", "DEPLOY vit-tiny-stage no-such-soc ftl",
                    "DEPLOY vit-tiny-stage siracusa no-such-strategy"] {
            let j = handle_line(&svc, bad);
            assert!(j.get_opt("error").is_some(), "'{bad}' must yield an error object, got {}", j.to_string());
        }
        let pong = handle_line(&svc, "PING");
        assert!(pong.get("pong").unwrap().as_bool().unwrap());
        let stats = handle_line(&svc, "STATS");
        assert_eq!(stats.get("solves").unwrap().as_usize().unwrap(), 0);
    }
}
