//! Stable content fingerprints for deployment requests.
//!
//! A [`Fingerprint`] identifies the *planning problem*: the graph's
//! structure (topology, shapes, dtypes, operator attributes) plus every
//! [`DeployConfig`] field that influences the fuse → solve → allocate →
//! schedule pipeline. Two requests with equal fingerprints are guaranteed
//! to produce the same [`crate::coordinator::Deployment`], so the serve
//! layer can hand out one cached plan for both.
//!
//! The hash is a hand-rolled 128-bit FNV-1a over a canonical byte
//! encoding — deliberately **not** `std::hash` (whose algorithm is
//! unspecified and, for `RandomState`, randomly seeded per process), so
//! keys are stable across runs and could be persisted or shared between
//! replicas. Every variable-length field is length-prefixed and every
//! section is tagged, so distinct structures cannot collide by
//! concatenation ambiguity.

#![forbid(unsafe_code)]

use crate::config::DeployConfig;
use crate::ir::{Graph, Op, TensorKind};
use crate::soc::SocConfig;
use crate::tiling::{HomesPolicy, Strategy};

/// 128-bit FNV-1a offset basis.
const FNV_OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
/// 128-bit FNV-1a prime.
const FNV_PRIME: u128 = 0x0000000001000000000000000000013b;

/// A stable 128-bit content hash of one planning problem.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Fingerprint(pub u128);

impl Fingerprint {
    /// Hex rendering (32 lowercase hex digits) used in protocol responses.
    pub fn hex(&self) -> String {
        format!("{:032x}", self.0)
    }

    /// Derive a secondary key from this fingerprint by rehashing it under
    /// a domain tag. Used for the sim-report cache: its key space must be
    /// a pure function of the plan fingerprint (plan + SoC + workload
    /// shape are all covered by it) yet never collide with another cache's
    /// use of the same fingerprint.
    pub fn derive(&self, tag: &str) -> Fingerprint {
        let mut h = Fnv::new();
        h.tag(tag);
        h.bytes(&self.0.to_le_bytes());
        Fingerprint(h.state)
    }

    /// Stable shard index in `0..shards` (for the sharded plan cache).
    pub fn shard(&self, shards: usize) -> usize {
        debug_assert!(shards > 0);
        // The low bits feed the cache's HashMap; use the high bits here so
        // shard choice and bucket choice are decorrelated.
        ((self.0 >> 64) as u64 % shards.max(1) as u64) as usize
    }
}

impl std::fmt::Display for Fingerprint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.hex())
    }
}

/// Incremental FNV-1a/128 writer over the canonical encoding.
struct Fnv {
    state: u128,
}

impl Fnv {
    fn new() -> Self {
        Self { state: FNV_OFFSET }
    }

    fn bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= b as u128;
            self.state = self.state.wrapping_mul(FNV_PRIME);
        }
    }

    fn u8(&mut self, v: u8) {
        self.bytes(&[v]);
    }

    fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }

    fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    /// Bit-exact float encoding (plans are invalidated by *any* cost-model
    /// change, including ones that only flip a rounding decision).
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    /// Length-prefixed string (used for op/dtype tags, never user names).
    fn str(&mut self, s: &str) {
        self.usize(s.len());
        self.bytes(s.as_bytes());
    }

    /// Section tag — keeps differently-ordered encoders from colliding.
    fn tag(&mut self, t: &str) {
        self.str(t);
    }
}

/// Stable FNV-1a/128 content checksum of a byte string — used by the
/// snapshot layer ([`crate::serve::persist`]) to detect corrupted entries.
/// Domain-tagged and length-prefixed so checksums can never collide with
/// request fingerprints or with each other by concatenation ambiguity.
pub fn checksum(bytes: &[u8]) -> Fingerprint {
    let mut h = Fnv::new();
    h.tag("ftl-snap-checksum-v1");
    h.usize(bytes.len());
    h.bytes(bytes);
    Fingerprint(h.state)
}

/// Fingerprint one request: graph structure + the full deploy config.
///
/// **Contract** (see also `serve/mod.rs` module docs):
///
/// * tensor/node *names are excluded* — alpha-equivalent graphs share a
///   plan (the cached schedule carries the names of whichever request
///   solved first; names are cosmetic in reports);
/// * tensor shapes, dtypes, kinds and the exact topology (input/output
///   tensor indices per node) are included;
/// * every operator attribute is included (GEMM layout flags, LayerNorm
///   epsilon bits, Conv2d geometry);
/// * the SoC is included *structurally* (memories, compute units, DMA cost
///   models, clock) but not by preset name — two names for the same
///   hardware share plans;
/// * strategy, double-buffering, solver options and the homes policy are
///   included.
pub fn fingerprint(graph: &Graph, config: &DeployConfig) -> Fingerprint {
    let mut h = Fnv::new();
    h.tag("ftl-plan-v1");
    hash_graph(&mut h, graph);
    hash_soc(&mut h, &config.soc);
    hash_config(&mut h, config);
    Fingerprint(h.state)
}

/// Fingerprint of the SoC structure alone — the batching scheduler's
/// grouping key ([`crate::serve::BatchScheduler`]). Requests with equal
/// SoC fingerprints exercise the same memory hierarchy and cost models,
/// so solving them back-to-back keeps the solver's working set warm even
/// when their graphs differ. Same exclusion rules as [`fingerprint`]: the
/// preset *name* is cosmetic, the structure is identity.
pub fn soc_fingerprint(soc: &SocConfig) -> Fingerprint {
    let mut h = Fnv::new();
    h.tag("ftl-soc-v1");
    hash_soc(&mut h, soc);
    Fingerprint(h.state)
}

fn hash_graph(h: &mut Fnv, graph: &Graph) {
    h.tag("graph");
    h.usize(graph.tensors.len());
    for t in &graph.tensors {
        h.u8(match t.kind {
            TensorKind::Input => 0,
            TensorKind::Output => 1,
            TensorKind::Weight => 2,
            TensorKind::Intermediate => 3,
        });
        h.str(t.dtype.name());
        h.usize(t.shape.len());
        for &d in &t.shape {
            h.usize(d);
        }
    }
    h.usize(graph.nodes.len());
    for n in &graph.nodes {
        hash_op(h, &n.op);
        h.usize(n.inputs.len());
        for &i in &n.inputs {
            h.usize(i);
        }
        h.usize(n.output);
    }
}

fn hash_op(h: &mut Fnv, op: &Op) {
    match op {
        Op::Gemm { transpose_b, has_bias } => {
            h.tag("gemm");
            h.u8(u8::from(*transpose_b));
            h.u8(u8::from(*has_bias));
        }
        Op::Act(kind) => {
            h.tag("act");
            h.str(kind.name());
        }
        Op::Add => h.tag("add"),
        Op::LayerNorm { eps } => {
            h.tag("layernorm");
            h.u64(eps.to_bits() as u64);
        }
        Op::Softmax => h.tag("softmax"),
        Op::Transpose => h.tag("transpose"),
        Op::Conv2d { kh, kw, stride, pad } => {
            h.tag("conv2d");
            h.usize(*kh);
            h.usize(*kw);
            h.usize(*stride);
            h.usize(*pad);
        }
        Op::Requant => h.tag("requant"),
    }
}

fn hash_soc(h: &mut Fnv, soc: &SocConfig) {
    h.tag("soc");
    // NOTE: soc.name intentionally excluded — structural identity only.
    h.f64(soc.freq_mhz);
    for level in [&soc.mem.l1, &soc.mem.l2, &soc.mem.l3] {
        h.usize(level.capacity);
        h.usize(level.alignment);
    }
    h.usize(soc.cluster.cores);
    h.f64(soc.cluster.macs_per_core_cycle);
    h.f64(soc.cluster.gemm_efficiency);
    h.f64(soc.cluster.eltwise_per_core_cycle);
    h.u64(soc.cluster.kernel_setup_cycles);
    match &soc.npu {
        None => h.u8(0),
        Some(npu) => {
            h.u8(1);
            h.f64(npu.macs_per_cycle);
            h.f64(npu.efficiency);
            h.u64(npu.job_setup_cycles);
        }
    }
    for dma in [&soc.dma_cluster, &soc.dma_io] {
        h.u64(dma.setup_cycles);
        h.u64(dma.per_row_cycles);
        h.f64(dma.bytes_per_cycle);
    }
}

fn hash_config(h: &mut Fnv, config: &DeployConfig) {
    h.tag("config");
    h.u8(match config.strategy {
        Strategy::LayerPerLayer => 0,
        Strategy::Ftl => 1,
    });
    h.u8(u8::from(config.double_buffer));
    h.u8(u8::from(config.solver.use_perf_constraints));
    h.usize(config.solver.max_candidates);
    h.f64(config.solver.l1_budget_fraction);
    h.u8(match config.homes {
        HomesPolicy::Resident => 0,
        HomesPolicy::Lifetime => 1,
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiments::vit_mlp_stage;

    fn cfg(soc: &str, strategy: Strategy) -> DeployConfig {
        DeployConfig::preset(soc, strategy).unwrap()
    }

    #[test]
    fn deterministic_across_calls() {
        let g = vit_mlp_stage(16, 24, 48);
        let c = cfg("siracusa", Strategy::Ftl);
        assert_eq!(fingerprint(&g, &c), fingerprint(&g, &c));
        // A freshly-built structurally identical graph hashes identically.
        let g2 = vit_mlp_stage(16, 24, 48);
        assert_eq!(fingerprint(&g, &c), fingerprint(&g2, &c));
    }

    #[test]
    fn names_are_cosmetic() {
        let g = vit_mlp_stage(16, 24, 48);
        let mut renamed = g.clone();
        for t in &mut renamed.tensors {
            t.name = format!("renamed_{}", t.name);
        }
        for n in &mut renamed.nodes {
            n.name = format!("renamed_{}", n.name);
        }
        let c = cfg("siracusa", Strategy::Ftl);
        assert_eq!(fingerprint(&g, &c), fingerprint(&renamed, &c));
    }

    #[test]
    fn discriminates_shapes_and_config() {
        let g = vit_mlp_stage(16, 24, 48);
        let c = cfg("siracusa", Strategy::Ftl);
        let base = fingerprint(&g, &c);

        let bigger = vit_mlp_stage(16, 24, 64);
        assert_ne!(base, fingerprint(&bigger, &c));

        assert_ne!(base, fingerprint(&g, &cfg("siracusa", Strategy::LayerPerLayer)));
        assert_ne!(base, fingerprint(&g, &cfg("cluster-only", Strategy::Ftl)));

        let mut dbuf = cfg("siracusa", Strategy::Ftl);
        dbuf.double_buffer = true;
        assert_ne!(base, fingerprint(&g, &dbuf));

        let mut solver = cfg("siracusa", Strategy::Ftl);
        solver.solver.max_candidates += 1;
        assert_ne!(base, fingerprint(&g, &solver));

        let mut homes = cfg("siracusa", Strategy::Ftl);
        homes.homes = HomesPolicy::Lifetime;
        assert_ne!(base, fingerprint(&g, &homes));
    }

    #[test]
    fn soc_fingerprint_groups_by_structure_not_name() {
        let siracusa = cfg("siracusa", Strategy::Ftl);
        let cluster = cfg("cluster-only", Strategy::Ftl);
        assert_ne!(soc_fingerprint(&siracusa.soc), soc_fingerprint(&cluster.soc));
        // The preset name is cosmetic: renaming the SoC keeps the key.
        let mut renamed = siracusa.soc.clone();
        renamed.name = "siracusa-alias".into();
        assert_eq!(soc_fingerprint(&siracusa.soc), soc_fingerprint(&renamed));
        // Strategy is not part of the SoC key (it groups, not discriminates).
        let baseline = cfg("siracusa", Strategy::LayerPerLayer);
        assert_eq!(soc_fingerprint(&siracusa.soc), soc_fingerprint(&baseline.soc));
    }

    #[test]
    fn derived_keys_are_stable_and_tagged() {
        let g = vit_mlp_stage(16, 24, 48);
        let f = fingerprint(&g, &cfg("siracusa", Strategy::Ftl));
        assert_eq!(f.derive("sim-v1"), f.derive("sim-v1"));
        assert_ne!(f.derive("sim-v1"), f.derive("other"));
        assert_ne!(f.derive("sim-v1"), f, "derived keys must not collide with the base key space");
    }

    #[test]
    fn checksum_is_stable_and_content_sensitive() {
        assert_eq!(checksum(b"abc"), checksum(b"abc"));
        assert_ne!(checksum(b"abc"), checksum(b"abd"));
        assert_ne!(checksum(b""), checksum(b"\0"));
        // Checksums live in their own key space: hashing a fingerprint's
        // bytes never reproduces the fingerprint.
        let g = vit_mlp_stage(8, 8, 16);
        let f = fingerprint(&g, &cfg("cluster-only", Strategy::Ftl));
        assert_ne!(checksum(&f.0.to_le_bytes()), f);
    }

    #[test]
    fn hex_is_32_digits() {
        let g = vit_mlp_stage(8, 8, 16);
        let f = fingerprint(&g, &cfg("cluster-only", Strategy::Ftl));
        assert_eq!(f.hex().len(), 32);
        assert_eq!(f.to_string(), f.hex());
    }
}
