//! Typed wire protocol for the serve front door.
//!
//! One frame per line, two framings on the same request vocabulary
//! (see PROTOCOL.md at the repo root for the full reference):
//!
//! ```text
//! v1:  FTL1 <id> <command...>     id'd frame, responses may interleave
//! v0:  <command...>               legacy bare line, served in order
//! ```
//!
//! The command vocabulary is shared: `DEPLOY <workload> <soc> <strategy>
//! [deadline-ms] [lane=<name>]`, `STATS`, `METRICS`, `TRACE [n]`,
//! `SLOW [n]`, `PING`. [`Frame::parse`] is strict — every accepted
//! frame renders back ([`Frame::render`]) to an equivalent line — and
//! malformed input yields an error that the front door answers on the
//! offending id ([`id_hint`]) instead of dropping the connection.
//!
//! v1 responses are [`Event`]s: single-line JSON objects tagged
//! `{"v":1,"id":N,"event":...}`. A cold `DEPLOY` streams `plan`, then
//! per-phase `sim` events, then a terminal `done`; warm requests may
//! collapse to a single `done`. Every other command (and every error)
//! is a single terminal frame. v0 responses keep the exact legacy
//! shapes so pre-PR-7 clients never see a `"v"` field.

#![forbid(unsafe_code)]

use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::util::json::Json;

/// Wire protocol version spoken by `FTL1` frames.
pub const PROTO_VERSION: u32 = 1;

/// Magic first token that marks a v1 frame.
pub const V1_TAG: &str = "FTL1";

/// Hard cap on one request line. Longer lines are answered with an
/// `error` event (on the id when it is recoverable) and discarded up
/// to the next newline — never a disconnect.
pub const MAX_FRAME_BYTES: usize = 64 * 1024;

/// Default count for bare `TRACE`/`SLOW` (kept identical to the legacy
/// handler so v0 behavior is unchanged).
pub const DEFAULT_DUMP_COUNT: usize = 16;

/// Which framing a request arrived in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Version {
    /// Legacy bare line: no id, responses in request order.
    V0,
    /// `FTL1 <id> ...`: id'd, responses may arrive out of order.
    V1,
}

/// One parsed request line: framing, optional client-chosen id, and
/// the typed command.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub version: Version,
    /// Client-chosen request id — always `Some` for v1, `None` for v0.
    pub id: Option<u64>,
    pub request: Request,
}

/// The typed command vocabulary, shared by both framings.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Deploy(DeployCommand),
    Stats,
    Ping,
    Metrics,
    Trace { n: usize },
    Slow { n: usize },
}

/// A parsed `DEPLOY` command, still in wire terms (workload/SoC/strategy
/// names, not resolved graphs) so parsing stays infallible w.r.t. the
/// model registry and resolution errors surface per-request.
#[derive(Debug, Clone, PartialEq)]
pub struct DeployCommand {
    pub workload: String,
    pub soc: String,
    pub strategy: String,
    pub deadline_ms: Option<u64>,
    pub lane: Option<String>,
}

impl DeployCommand {
    /// The client-requested deadline, if any.
    pub fn deadline(&self) -> Option<Duration> {
        self.deadline_ms.map(Duration::from_millis)
    }
}

impl Frame {
    /// Parse one request line, strict. `FTL1 <id> <command...>` is v1;
    /// anything else is tried as a bare v0 command. Error messages for
    /// v0 lines are byte-identical to the pre-typed handler so legacy
    /// clients (and the pinned tests) see the same diagnostics.
    pub fn parse(line: &str) -> Result<Frame> {
        let parts: Vec<&str> = line.split_whitespace().collect();
        if parts.first() == Some(&V1_TAG) {
            let [_, id, rest @ ..] = parts.as_slice() else {
                bail!("bad v1 frame '{line}' (expected: FTL1 <id> <command...>)");
            };
            let id: u64 =
                id.parse().map_err(|_| anyhow!("bad request id '{id}' in '{line}' (expected a non-negative integer)"))?;
            let request = Request::parse_tokens(rest, line)?;
            Ok(Frame { version: Version::V1, id: Some(id), request })
        } else {
            let request = Request::parse_tokens(&parts, line)?;
            Ok(Frame { version: Version::V0, id: None, request })
        }
    }

    /// Render back to a canonical request line. `parse(render(f)) == f`
    /// for every frame `parse` accepts (bare `TRACE`/`SLOW` normalize
    /// to an explicit count, which round-trips stably from then on).
    pub fn render(&self) -> String {
        match (self.version, self.id) {
            (Version::V1, Some(id)) => format!("{V1_TAG} {id} {}", self.request.render()),
            _ => self.request.render(),
        }
    }
}

impl Request {
    fn parse_tokens(parts: &[&str], line: &str) -> Result<Request> {
        match parts {
            ["DEPLOY", workload, soc, strategy, rest @ ..] if rest.len() <= 2 => {
                let mut deadline_ms: Option<u64> = None;
                let mut lane: Option<&str> = None;
                for tok in rest {
                    if let Some(name) = tok.strip_prefix("lane=") {
                        if lane.replace(name).is_some() {
                            bail!("duplicate lane= field in '{line}'");
                        }
                    } else {
                        let ms: u64 = tok
                            .parse()
                            .map_err(|_| anyhow!("bad deadline '{tok}' (expected milliseconds or lane=<name>)"))?;
                        if deadline_ms.replace(ms).is_some() {
                            bail!("duplicate deadline in '{line}'");
                        }
                    }
                }
                Ok(Request::Deploy(DeployCommand {
                    workload: workload.to_string(),
                    soc: soc.to_string(),
                    strategy: strategy.to_string(),
                    deadline_ms,
                    lane: lane.map(str::to_string),
                }))
            }
            ["STATS"] => Ok(Request::Stats),
            ["PING"] => Ok(Request::Ping),
            ["METRICS"] => Ok(Request::Metrics),
            [cmd @ ("TRACE" | "SLOW"), rest @ ..] if rest.len() <= 1 => {
                let n = match rest {
                    [tok] => tok
                        .parse::<usize>()
                        .map_err(|_| anyhow!("bad count '{tok}' in '{line}' (expected a non-negative integer)"))?,
                    _ => DEFAULT_DUMP_COUNT,
                };
                Ok(if *cmd == "TRACE" { Request::Trace { n } } else { Request::Slow { n } })
            }
            _ => bail!(
                "bad request: '{line}' (expected: DEPLOY <workload> <soc> <strategy> [deadline-ms] [lane=<name>] \
                 | STATS | METRICS | TRACE [n] | SLOW [n] | PING)"
            ),
        }
    }

    /// Canonical command text (the part after any `FTL1 <id>` prefix).
    pub fn render(&self) -> String {
        match self {
            Request::Deploy(d) => {
                let mut s = format!("DEPLOY {} {} {}", d.workload, d.soc, d.strategy);
                if let Some(ms) = d.deadline_ms {
                    s.push_str(&format!(" {ms}"));
                }
                if let Some(lane) = &d.lane {
                    s.push_str(&format!(" lane={lane}"));
                }
                s
            }
            Request::Stats => "STATS".to_string(),
            Request::Ping => "PING".to_string(),
            Request::Metrics => "METRICS".to_string(),
            Request::Trace { n } => format!("TRACE {n}"),
            Request::Slow { n } => format!("SLOW {n}"),
        }
    }
}

/// Best-effort id recovery from a malformed line: if it starts with
/// `FTL1 <id>`, the error can be delivered on that id; otherwise the
/// front door answers on id 0 by convention.
pub fn id_hint(line: &str) -> Option<u64> {
    let mut it = line.split_whitespace();
    if it.next() != Some(V1_TAG) {
        return None;
    }
    it.next().and_then(|tok| tok.parse().ok())
}

/// One v1 response frame. Rendered as a single JSON line tagged with
/// the protocol version and the request id it answers.
#[derive(Debug, Clone)]
pub enum Event {
    /// The solve landed: plan digest + request fingerprint, emitted
    /// before simulation starts. `cached` is true on a plan-cache hit.
    Plan { digest: String, fingerprint: String, cached: bool },
    /// One simulated phase, in schedule order (`index` in `0..total`).
    SimPhase { index: usize, total: usize, name: String, cycles: u64 },
    /// Terminal success: the full reply body (same fields as the
    /// legacy single-line response) merged into the event object.
    Done(Json),
    /// Terminal failure on this id. The connection stays open.
    Error { message: String },
}

impl Event {
    /// True for `done`/`error` — the last frame an id will see.
    pub fn is_terminal(&self) -> bool {
        matches!(self, Event::Done(_) | Event::Error { .. })
    }

    /// Render as the single JSON line the client sees for request `id`.
    pub fn render(&self, id: u64) -> String {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("v".to_string(), Json::Num(PROTO_VERSION as f64));
        obj.insert("id".to_string(), Json::Num(id as f64));
        match self {
            Event::Plan { digest, fingerprint, cached } => {
                obj.insert("event".to_string(), Json::str("plan"));
                obj.insert("digest".to_string(), Json::str(digest));
                obj.insert("fingerprint".to_string(), Json::str(fingerprint));
                obj.insert("cached".to_string(), Json::Bool(*cached));
            }
            Event::SimPhase { index, total, name, cycles } => {
                obj.insert("event".to_string(), Json::str("sim"));
                obj.insert("phase".to_string(), Json::Num(*index as f64));
                obj.insert("phases".to_string(), Json::Num(*total as f64));
                obj.insert("name".to_string(), Json::str(name));
                obj.insert("cycles".to_string(), Json::Num(*cycles as f64));
            }
            Event::Done(body) => {
                obj.insert("event".to_string(), Json::str("done"));
                if let Json::Obj(m) = body {
                    for (k, v) in m {
                        obj.entry(k.clone()).or_insert_with(|| v.clone());
                    }
                } else {
                    obj.insert("body".to_string(), body.clone());
                }
            }
            Event::Error { message } => {
                obj.insert("event".to_string(), Json::str("error"));
                obj.insert("error".to_string(), Json::str(message));
            }
        }
        Json::Obj(obj).to_string()
    }
}

/// Where streaming partial replies go. Implementations must tolerate
/// being called from scheduler/worker threads.
pub trait EventSink: Send + Sync {
    fn emit(&self, event: &Event);
}

/// Wrap a legacy single- or multi-line response body as one terminal
/// v1 frame: `{"error":...}` objects become `error` events, other
/// objects become `done` events carrying their fields, and non-JSON
/// text (METRICS/TRACE/SLOW dumps) is carried whole under `"text"`.
pub fn wrap_v1(id: u64, legacy: &str) -> String {
    match crate::util::json::parse(legacy) {
        Ok(Json::Obj(m)) => {
            if let Some(Json::Str(msg)) = m.get("error") {
                Event::Error { message: msg.clone() }.render(id)
            } else {
                Event::Done(Json::Obj(m)).render(id)
            }
        }
        _ => Event::Done(Json::obj(vec![("text", Json::str(legacy))])).render(id),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v1_frames_parse_and_round_trip() {
        let f = Frame::parse("FTL1 42 DEPLOY vit-tiny-stage cluster-only ftl 250 lane=gold").unwrap();
        assert_eq!(f.version, Version::V1);
        assert_eq!(f.id, Some(42));
        let Request::Deploy(d) = &f.request else { panic!("expected deploy") };
        assert_eq!(d.workload, "vit-tiny-stage");
        assert_eq!(d.deadline_ms, Some(250));
        assert_eq!(d.deadline(), Some(Duration::from_millis(250)));
        assert_eq!(d.lane.as_deref(), Some("gold"));
        assert_eq!(f.render(), "FTL1 42 DEPLOY vit-tiny-stage cluster-only ftl 250 lane=gold");
        assert_eq!(Frame::parse(&f.render()).unwrap(), f);
    }

    #[test]
    fn v0_frames_have_no_id() {
        let f = Frame::parse("  PING  ").unwrap();
        assert_eq!(f.version, Version::V0);
        assert_eq!(f.id, None);
        assert_eq!(f.request, Request::Ping);
        assert_eq!(f.render(), "PING");
    }

    #[test]
    fn bare_trace_normalizes_to_default_count() {
        let f = Frame::parse("TRACE").unwrap();
        assert_eq!(f.request, Request::Trace { n: DEFAULT_DUMP_COUNT });
        assert_eq!(f.render(), "TRACE 16");
        assert_eq!(Frame::parse(&f.render()).unwrap().request, f.request);
        assert_eq!(Frame::parse("SLOW 3").unwrap().request, Request::Slow { n: 3 });
    }

    #[test]
    fn malformed_lines_error_with_legacy_messages() {
        for bad in ["", "DEPLOY", "DEPLOY x", "DEPLOY a b c d e", "NOPE x y z"] {
            let e = Frame::parse(bad).unwrap_err().to_string();
            assert!(e.contains("bad request"), "'{bad}' -> {e}");
        }
        let e = Frame::parse("DEPLOY a b c nope").unwrap_err().to_string();
        assert!(e.contains("bad deadline 'nope'"), "{e}");
        let e = Frame::parse("DEPLOY a b c lane=x lane=y").unwrap_err().to_string();
        assert!(e.contains("duplicate lane="), "{e}");
        let e = Frame::parse("FTL1 zero PING").unwrap_err().to_string();
        assert!(e.contains("bad request id"), "{e}");
        let e = Frame::parse("FTL1 7 NOPE").unwrap_err().to_string();
        assert!(e.contains("bad request"), "{e}");
    }

    #[test]
    fn id_hint_recovers_ids_from_broken_v1_lines() {
        assert_eq!(id_hint("FTL1 9 NOPE nope"), Some(9));
        assert_eq!(id_hint("FTL1 bogus DEPLOY"), None);
        assert_eq!(id_hint("PING"), None);
    }

    #[test]
    fn events_render_as_tagged_json_lines() {
        let plan = Event::Plan { digest: "d".into(), fingerprint: "f".into(), cached: false };
        let j = crate::util::json::parse(&plan.render(5)).unwrap();
        assert_eq!(j.get("v").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(j.get("id").unwrap().as_f64().unwrap(), 5.0);
        assert_eq!(j.get("event").unwrap().as_str().unwrap(), "plan");
        assert!(!plan.is_terminal());

        let sim = Event::SimPhase { index: 1, total: 3, name: "ph".into(), cycles: 99 };
        let j = crate::util::json::parse(&sim.render(5)).unwrap();
        assert_eq!(j.get("phase").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(j.get("cycles").unwrap().as_f64().unwrap(), 99.0);

        let done = Event::Done(Json::obj(vec![("outcome", Json::str("OK"))]));
        let j = crate::util::json::parse(&done.render(5)).unwrap();
        assert!(done.is_terminal());
        assert_eq!(j.get("event").unwrap().as_str().unwrap(), "done");
        assert_eq!(j.get("outcome").unwrap().as_str().unwrap(), "OK");
    }

    #[test]
    fn wrap_v1_maps_legacy_bodies_onto_terminal_events() {
        let err = wrap_v1(3, "{\"error\":\"nope\"}");
        let j = crate::util::json::parse(&err).unwrap();
        assert_eq!(j.get("event").unwrap().as_str().unwrap(), "error");
        assert_eq!(j.get("error").unwrap().as_str().unwrap(), "nope");

        let ok = wrap_v1(4, "{\"pong\":true}");
        let j = crate::util::json::parse(&ok).unwrap();
        assert_eq!(j.get("event").unwrap().as_str().unwrap(), "done");
        assert!(j.get("pong").unwrap().as_bool().unwrap());

        let text = wrap_v1(5, "# metrics\n# EOF");
        let j = crate::util::json::parse(&text).unwrap();
        assert!(j.get("text").unwrap().as_str().unwrap().contains("# EOF"));
    }
}
